fn main(){}
