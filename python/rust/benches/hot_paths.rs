fn main(){}
