fn main(){}
