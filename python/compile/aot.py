# AOT compile path: lower the L2 graphs to HLO *text* artifacts + manifest.
#
# This is the only place python runs; `make artifacts` invokes it once and
# the rust binary is self-contained afterwards. Interchange format is HLO
# text, NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
# instruction ids which the xla crate's xla_extension 0.5.1 rejects
# (`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
# cleanly (see /opt/xla-example/README.md).
#
# Artifacts are keyed by (kernel, loss, n_k, d, cap); the rust ArtifactStore
# reads artifacts/manifest.json and compiles each HLO once per process.
import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-compatible path).

    The module is printed in *generic* op form: jax 0.8's pretty-printer
    emits `stablehlo.dynamic_slice` attribute syntax that the bundled
    stablehlo parser inside mlir_module_to_xla_computation rejects; the
    generic form bypasses every custom-op pretty parser.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    text = mlir_mod.operation.get_asm(print_generic_op_form=True)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        text, use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_local_sdca(loss: str, n_k: int, d: int, cap: int) -> str:
    fn = model.make_local_sdca_round(loss)
    specs = (
        jax.ShapeDtypeStruct((n_k, d), F32),   # X
        jax.ShapeDtypeStruct((n_k,), F32),     # y
        jax.ShapeDtypeStruct((n_k,), F32),     # alpha
        jax.ShapeDtypeStruct((d,), F32),       # w
        jax.ShapeDtypeStruct((cap,), I32),     # idx
        jax.ShapeDtypeStruct((n_k,), F32),     # norms
        jax.ShapeDtypeStruct((3,), F32),       # [lam_n, gamma, H]
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_eval_objectives(loss: str, n_k: int, d: int) -> str:
    fn = model.make_eval_objectives(loss)
    specs = (
        jax.ShapeDtypeStruct((n_k, d), F32),   # X
        jax.ShapeDtypeStruct((n_k,), F32),     # y
        jax.ShapeDtypeStruct((n_k,), F32),     # alpha
        jax.ShapeDtypeStruct((d,), F32),       # w
        jax.ShapeDtypeStruct((), F32),         # gamma
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


# (kernel, loss, n_k, d, cap) — cap is the idx capacity (max H per call);
# the rust side issues multiple calls for H > cap.
# Small shapes back the test suite; the large hinge pair backs the e2e /
# figure workloads (cov-like: n = 100k over K = 4 workers, d = 54).
SPECS_QUICK = [
    ("local_sdca", "hinge", 128, 16, 256),
    ("local_sdca", "smoothed_hinge", 128, 16, 256),
    ("local_sdca", "squared", 128, 16, 256),
    ("local_sdca", "logistic", 128, 16, 256),
    ("eval_objectives", "hinge", 128, 16, 0),
    ("eval_objectives", "smoothed_hinge", 128, 16, 0),
]
SPECS_FULL = SPECS_QUICK + [
    ("local_sdca", "hinge", 25000, 54, 65536),
    ("eval_objectives", "hinge", 25000, 54, 0),
]


def artifact_name(kernel, loss, n_k, d, cap):
    if kernel == "local_sdca":
        return f"{kernel}_{loss}_{n_k}x{d}_c{cap}"
    return f"{kernel}_{loss}_{n_k}x{d}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--quick", action="store_true",
                    help="small test shapes only (skips the e2e variants)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    specs = SPECS_QUICK if args.quick else SPECS_FULL
    entries = []
    for kernel, loss, n_k, d, cap in specs:
        name = artifact_name(kernel, loss, n_k, d, cap)
        if kernel == "local_sdca":
            text = lower_local_sdca(loss, n_k, d, cap)
        elif kernel == "eval_objectives":
            text = lower_eval_objectives(loss, n_k, d)
        else:
            raise ValueError(kernel)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "file": fname,
            "kernel": kernel,
            "loss": loss,
            "n_k": n_k,
            "d": d,
            "cap": cap,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })
        print(f"lowered {name}: {len(text)} chars")

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the rust runtime (offline build: no JSON parser there)
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("#cocoa-manifest\t1\n")
        for e in entries:
            f.write("\t".join(str(e[k]) for k in
                              ("name", "file", "kernel", "loss", "n_k", "d",
                               "cap", "sha256")) + "\n")
    print(f"wrote manifest with {len(entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
