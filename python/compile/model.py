# L2: the CoCoA worker-side compute graph, assembled from the L1 kernels.
#
# Two graphs are AOT-lowered per (loss, shape) variant (see aot.py):
#
#   local_sdca_round  — one CoCoA outer-round's worth of local work on a
#                       block: H SDCA steps -> (dalpha, dw). This is the
#                       body the rust coordinator executes on every worker
#                       every round (the hot path).
#   eval_objectives   — the block's (loss_sum, conj_sum) partial objective
#                       sums used by the leader for P/D/gap.
#
# Python exists only at build time; the rust runtime feeds these graphs
# through PJRT with literals marshalled from its own data structures.
import jax.numpy as jnp

from .kernels import local_sdca as sdca_kernel
from .kernels import objective as objective_kernel


def make_local_sdca_round(loss: str):
    """Returns fn(X, y, alpha, w, idx, norms, scalars) -> (dalpha, dw).

    scalars = [lambda*n, gamma, H] as a (3,) f32 vector so one compiled
    artifact serves every (lambda, H) configuration at runtime.
    """

    def local_sdca_round(X, y, alpha, w, idx, norms, scalars):
        return sdca_kernel.local_sdca(loss, X, y, alpha, w, idx, norms, scalars)

    return local_sdca_round


def make_eval_objectives(loss: str):
    """Returns fn(X, y, alpha, w, gamma) -> (loss_sum, conj_sum).

    The leader combines partials: with S_l = sum_k loss_sum_k and
    S_c = sum_k conj_sum_k,
        P(w)     = (lambda/2)||w||^2 + S_l / n
        D(alpha) = -(lambda/2)||w||^2 - S_c / n
    ||w||^2 and the division by the *global* n live on the rust side.
    """

    def eval_objectives(X, y, alpha, w, gamma):
        loss_sum, conj_sum = objective_kernel.block_objective(
            loss, X, y, alpha, w, gamma)
        # return_tuple lowering keeps scalar outputs; promote to (1,) so the
        # rust side reads fixed-shape f32[1] buffers.
        return jnp.reshape(loss_sum, (1,)), jnp.reshape(conj_sum, (1,))

    return eval_objectives
