# L1 Pallas kernel: per-block objective partial sums.
#
# Each worker evaluates, over its local block,
#     loss_sum = sum_i loss(x_i^T w, y_i)
#     conj_sum = sum_i conj(-alpha_i)
# The leader combines the K partial pairs with (lambda/2)||w||^2 to form the
# primal P(w), dual D(alpha), and the duality gap — the paper's stopping
# criterion and the y-axis of every figure.
#
# The matvec X @ w is tiled over row blocks via the Pallas grid so that on a
# real TPU each (TILE, d) slab streams HBM->VMEM once while w stays pinned
# in VMEM; partial sums accumulate into two scalar outputs across grid
# steps. interpret=True lowers this to plain HLO for the rust PJRT client.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 128 keeps a (128, d) f32 slab <= 256 KB for d <= 512,
# comfortably inside VMEM alongside w and the accumulators.
TILE = 128


def _loss_vec(loss: str, margins, y, gamma):
    """Vectorized primal loss over a tile of margins."""
    if loss == "hinge":
        return jnp.maximum(0.0, 1.0 - y * margins)
    if loss == "smoothed_hinge":
        ya = y * margins
        quad = (1.0 - ya) ** 2 / (2.0 * gamma)
        lin = 1.0 - ya - gamma / 2.0
        return jnp.where(ya >= 1.0, 0.0, jnp.where(ya <= 1.0 - gamma, lin, quad))
    if loss == "squared":
        return 0.5 * (margins - y) ** 2
    if loss == "logistic":
        return jnp.logaddexp(0.0, -y * margins)
    raise ValueError(loss)


def _conj_vec(loss: str, alpha, y, gamma):
    """Vectorized conjugate term conj(-alpha_i).

    Feasibility is the solver's invariant (tested on the rust side); here b
    is clipped into the box so padded/boundary entries stay finite.
    """
    b = y * alpha
    if loss == "hinge":
        return -b
    if loss == "smoothed_hinge":
        return -b + gamma * b * b / 2.0
    if loss == "squared":
        return alpha * alpha / 2.0 - alpha * y
    if loss == "logistic":
        eps = 1e-12
        bc = jnp.clip(b, eps, 1.0 - eps)
        ent = bc * jnp.log(bc) + (1.0 - bc) * jnp.log(1.0 - bc)
        # entropy -> 0 at both boundaries
        return jnp.where((b <= 0.0) | (b >= 1.0), 0.0, ent)
    raise ValueError(loss)


def _kernel(loss, x_ref, y_ref, alpha_ref, w_ref, gamma_ref,
            loss_sum_ref, conj_sum_ref):
    """Grid-step body: accumulate one row tile's partial sums."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        loss_sum_ref[...] = jnp.zeros_like(loss_sum_ref)
        conj_sum_ref[...] = jnp.zeros_like(conj_sum_ref)

    X = x_ref[...]
    y = y_ref[...]
    alpha = alpha_ref[...]
    w = w_ref[...]
    gamma = gamma_ref[0]
    margins = X @ w
    loss_sum_ref[...] += jnp.sum(_loss_vec(loss, margins, y, gamma))
    conj_sum_ref[...] += jnp.sum(_conj_vec(loss, alpha, y, gamma))


def block_objective(loss: str, X, y, alpha, w, gamma):
    """Partial objective sums for one block; see module docstring.

    Requires n_k % TILE == 0 when n_k > TILE (the AOT shapes guarantee it);
    small blocks fall back to a single tile of the full height.

    Returns (loss_sum, conj_sum) as () f32 scalars.
    """
    n_k, d = X.shape
    tile = TILE if n_k % TILE == 0 and n_k >= TILE else n_k
    grid = (n_k // tile,)
    kernel = functools.partial(_kernel, loss)
    loss_sum, conj_sum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((), lambda i: ()),
            pl.BlockSpec((), lambda i: ()),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((), X.dtype),
            jax.ShapeDtypeStruct((), X.dtype),
        ),
        interpret=True,
    )(X, y, alpha, w, jnp.reshape(gamma, (1,)))
    return loss_sum, conj_sum
