# L1 Pallas kernel: LocalSDCA (Procedure B of the CoCoA paper).
#
# One invocation performs H sequential dual-coordinate-ascent steps on one
# worker's local block, entirely on-device:
#
#     for h in 0..H:
#         i      = idx[h]
#         q      = x_i . (w + dw)                 # margin against local view
#         delta  = argmax 1-D dual subproblem     # closed form / Newton
#         dalpha[i] += delta
#         dw        += (delta / lambda*n) * x_i   # rank-1 primal update
#
# and returns only (dalpha, dw) — the single pair the CoCoA coordinator
# communicates, which is the paper's entire point: H local steps, one
# message.
#
# Design notes:
#  * The loss is selected at *lowering* time (one HLO artifact per loss);
#    the coordinate maximizer is inlined so XLA sees straight-line math.
#  * H is a runtime scalar (lax.while_loop), so a single artifact serves
#    every communication/computation trade-off point (Figure 3's H sweep).
#    idx has static capacity `cap`; only idx[:H] is consumed.
#  * Randomness lives on the host: the rust coordinator supplies the
#    coordinate sequence idx, keeping the kernel deterministic and testable.
#  * Row norms are an input (precomputed once per dataset) — recomputing
#    ||x_i||^2 every step would add an O(d) pass per iteration for nothing.
#  * interpret=True: lowers to plain HLO (while + dynamic-slice + dot) that
#    the rust PJRT CPU client executes. On a real TPU the same BlockSpec
#    structure would pin X, w, dw in VMEM across all H steps (see DESIGN.md
#    section 7); the MXU is idle (rank-1 ops), the VPU dot is the unit.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LOGISTIC_NEWTON_ITERS = ref.LOGISTIC_NEWTON_ITERS
LOGISTIC_EPS = ref.LOGISTIC_EPS


def coord_delta(loss: str, q, y, a, s, gamma):
    """Traced 1-D dual maximizer; mirrors ref.coord_delta exactly.

    All arguments are scalars (traced). `s` is ||x_i||^2 / (lambda n).
    Guarded so a zero row (s == 0) yields delta == 0 instead of NaN.
    """
    s_safe = jnp.maximum(s, 1e-12)
    if loss == "hinge":
        b = jnp.clip((1.0 - y * q) / s_safe + y * a, 0.0, 1.0)
        delta = y * b - a
    elif loss == "smoothed_hinge":
        b = jnp.clip((1.0 - y * q - gamma * y * a) / (s_safe + gamma) + y * a,
                     0.0, 1.0)
        delta = y * b - a
    elif loss == "squared":
        delta = (y - q - a) / (1.0 + s_safe)
    elif loss == "logistic":
        eps = LOGISTIC_EPS

        def newton(_, delta):
            b = jnp.clip(y * (a + delta), eps, 1.0 - eps)
            g = -y * jnp.log(b / (1.0 - b)) - q - s_safe * delta
            hess = -1.0 / (b * (1.0 - b)) - s_safe
            delta = delta - g / hess
            b_new = jnp.clip(y * (a + delta), eps, 1.0 - eps)
            return y * b_new - a

        delta = jax.lax.fori_loop(0, LOGISTIC_NEWTON_ITERS, newton,
                                  jnp.zeros_like(q))
    else:
        raise ValueError(f"unknown loss {loss!r}")
    return jnp.where(s > 0.0, delta, 0.0)


def _kernel(loss, x_ref, y_ref, alpha_ref, w_ref, idx_ref, norms_ref,
            scalars_ref, dalpha_ref, dw_ref):
    """Pallas kernel body. scalars = [lam_n, gamma, H(float)]."""
    X = x_ref[...]
    y = y_ref[...]
    alpha = alpha_ref[...]
    w = w_ref[...]
    idx = idx_ref[...]
    norms = norms_ref[...]
    lam_n = scalars_ref[0]
    gamma = scalars_ref[1]
    h_steps = scalars_ref[2].astype(jnp.int32)

    n_k = X.shape[0]
    d = X.shape[1]

    def cond(state):
        h, _, _ = state
        return h < h_steps

    def body(state):
        h, dalpha, dw = state
        i = idx[h]
        x = jax.lax.dynamic_slice(X, (i, 0), (1, d)).reshape(d)
        q = jnp.dot(x, w + dw)
        a_cur = alpha[i] + dalpha[i]
        s = norms[i] / lam_n
        delta = coord_delta(loss, q, y[i], a_cur, s, gamma)
        dalpha = dalpha.at[i].add(delta)
        dw = dw + (delta / lam_n) * x
        return h + 1, dalpha, dw

    init = (jnp.int32(0), jnp.zeros(n_k, X.dtype), jnp.zeros(d, X.dtype))
    _, dalpha, dw = jax.lax.while_loop(cond, body, init)
    dalpha_ref[...] = dalpha
    dw_ref[...] = dw


def local_sdca(loss: str, X, y, alpha, w, idx, norms, scalars):
    """H-step LocalSDCA epoch on one coordinate block.

    Args:
      loss: static loss name (selects the maximizer at lowering time).
      X: (n_k, d) f32 local rows. y, alpha, norms: (n_k,) f32.
      w: (d,) f32 shared primal vector. idx: (cap,) i32 coordinate sequence.
      scalars: (3,) f32 = [lambda*n, gamma, H].

    Returns:
      (dalpha, dw): the update pair communicated by the worker.
    """
    n_k, d = X.shape
    kernel = functools.partial(_kernel, loss)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_k,), X.dtype),
            jax.ShapeDtypeStruct((d,), X.dtype),
        ),
        interpret=True,
    )(X, y, alpha, w, idx, norms, scalars)
