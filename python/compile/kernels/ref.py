# Pure-numpy correctness oracles for the L1 kernels.
#
# These are deliberately written as straight-line python/numpy loops (no jax,
# no vectorization tricks) so they are an *independent* ground truth for the
# Pallas kernels in local_sdca.py / objective.py. pytest compares the two.
#
# Conventions (SSZ13 / CoCoA paper, DESIGN.md section 5):
#   primal:  P(w) = (lambda/2)||w||^2 + (1/n) sum_i loss(x_i^T w, y_i)
#   dual:    D(a) = -(lambda/2)||A a||^2 - (1/n) sum_i conj(-a_i)
#   A_i = x_i / (lambda n),  w(a) = A a,  hinge dual box y_i a_i in [0,1].
#   s_i = ||x_i||^2 / (lambda n) is the curvature of the 1-D subproblem.
import numpy as np

LOSSES = ("hinge", "smoothed_hinge", "squared", "logistic")

# Number of Newton iterations used for the logistic coordinate maximizer.
# Must match local_sdca.py so kernel and oracle agree.
LOGISTIC_NEWTON_ITERS = 10
LOGISTIC_EPS = 1e-6


def loss_value(loss: str, a: float, y: float, gamma: float = 1.0) -> float:
    """Primal loss ell_i(a) where a = x_i^T w."""
    if loss == "hinge":
        return max(0.0, 1.0 - y * a)
    if loss == "smoothed_hinge":
        ya = y * a
        if ya >= 1.0:
            return 0.0
        if ya <= 1.0 - gamma:
            return 1.0 - ya - gamma / 2.0
        return (1.0 - ya) ** 2 / (2.0 * gamma)
    if loss == "squared":
        return 0.5 * (a - y) ** 2
    if loss == "logistic":
        # log(1 + exp(-y a)), numerically stable
        return float(np.logaddexp(0.0, -y * a))
    raise ValueError(loss)


def conjugate_value(loss: str, alpha: float, y: float, gamma: float = 1.0) -> float:
    """Conjugate term conj_i(-alpha_i) as it appears in D(a).

    For the margin losses the dual variable is feasible iff y*alpha in [0,1]
    (open interval for logistic); infeasible values return +inf.
    """
    b = y * alpha
    if loss == "hinge":
        if b < -1e-9 or b > 1.0 + 1e-9:
            return float("inf")
        return -b
    if loss == "smoothed_hinge":
        if b < -1e-9 or b > 1.0 + 1e-9:
            return float("inf")
        return -b + gamma * b * b / 2.0
    if loss == "squared":
        # ell(a) = (a-y)^2/2  =>  ell*(u) = u^2/2 + u y; conj(-alpha):
        return alpha * alpha / 2.0 - alpha * y
    if loss == "logistic":
        if b <= 0.0 or b >= 1.0:
            if b in (0.0, 1.0):
                return 0.0  # limit of the entropy at the boundary
            return float("inf")
        return float(b * np.log(b) + (1.0 - b) * np.log(1.0 - b))
    raise ValueError(loss)


def coord_delta(loss: str, q: float, y: float, a: float, s: float,
                gamma: float = 1.0) -> float:
    """Closed-form / Newton maximizer of the 1-D dual subproblem.

    Maximizes  -conj(-(a+delta)) - q*delta - s*delta^2/2  over delta,
    where q = x_i^T w_current and s = ||x_i||^2/(lambda n).
    """
    if s <= 0.0:
        return 0.0
    if loss == "hinge":
        b = np.clip((1.0 - y * q) / s + y * a, 0.0, 1.0)
        return float(y * b - a)
    if loss == "smoothed_hinge":
        b = np.clip((1.0 - y * q - gamma * y * a) / (s + gamma) + y * a, 0.0, 1.0)
        return float(y * b - a)
    if loss == "squared":
        return (y - q - a) / (1.0 + s)
    if loss == "logistic":
        eps = LOGISTIC_EPS
        delta = 0.0
        for _ in range(LOGISTIC_NEWTON_ITERS):
            b = float(np.clip(y * (a + delta), eps, 1.0 - eps))
            g = -y * np.log(b / (1.0 - b)) - q - s * delta
            hess = -1.0 / (b * (1.0 - b)) - s
            delta = delta - g / hess
            # keep the iterate strictly inside the feasible box
            b_new = float(np.clip(y * (a + delta), eps, 1.0 - eps))
            delta = y * b_new - a
        return float(delta)
    raise ValueError(loss)


def local_sdca_ref(X, y, alpha, w, idx, lam_n, gamma, H, loss):
    """Oracle for Procedure B (LocalSDCA): H coordinate steps on one block.

    Args:
      X: (n_k, d) float array, local data rows.
      y: (n_k,) labels.
      alpha: (n_k,) local dual variables at round start.
      w: (d,) shared primal vector consistent with the *global* alpha.
      idx: (cap,) int coordinate sequence; only the first H entries are used.
      lam_n: lambda * n (global n, not n_k).
      gamma: smoothing parameter for smoothed_hinge.
      H: number of inner steps.
      loss: one of LOSSES.

    Returns:
      (delta_alpha, delta_w) with delta_w == X^T delta_alpha / lam_n.
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    alpha = np.asarray(alpha, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    n_k, d = X.shape
    dalpha = np.zeros(n_k)
    dw = np.zeros(d)
    norms = (X * X).sum(axis=1)
    for h in range(H):
        i = int(idx[h])
        x = X[i]
        q = float(x @ (w + dw))
        a_cur = alpha[i] + dalpha[i]
        s = norms[i] / lam_n
        delta = coord_delta(loss, q, float(y[i]), float(a_cur), float(s), gamma)
        dalpha[i] += delta
        dw += (delta / lam_n) * x
    return dalpha, dw


def block_objective_ref(X, y, alpha, w, gamma, loss):
    """Oracle for the per-block objective partial sums.

    Returns (loss_sum, conj_sum):
      loss_sum = sum_i loss(x_i^T w, y_i)
      conj_sum = sum_i conj(-alpha_i)
    The leader combines these with (lambda/2)||w||^2 to form P and D.
    """
    X = np.asarray(X, dtype=np.float64)
    margins = X @ np.asarray(w, dtype=np.float64)
    loss_sum = sum(loss_value(loss, float(m), float(yi), gamma)
                   for m, yi in zip(margins, y))
    conj_sum = sum(conjugate_value(loss, float(ai), float(yi), gamma)
                   for ai, yi in zip(alpha, y))
    return float(loss_sum), float(conj_sum)


def primal_ref(X, y, w, lam, n, gamma, loss):
    """Full primal objective P(w) over one matrix holding all n rows."""
    loss_sum, _ = block_objective_ref(X, y, np.zeros(len(y)), w, gamma, loss)
    return 0.5 * lam * float(np.dot(w, w)) + loss_sum / n


def dual_ref(X, y, alpha, lam, n, gamma, loss):
    """Full dual objective D(alpha); w = A alpha is recomputed internally."""
    w = np.asarray(X, dtype=np.float64).T @ np.asarray(alpha, np.float64)
    w = w / (lam * n)
    _, conj_sum = block_objective_ref(X, y, alpha, w, gamma, loss)
    return -0.5 * lam * float(np.dot(w, w)) - conj_sum / n
