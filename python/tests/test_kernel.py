# Kernel-vs-oracle correctness: the CORE signal that the L1 Pallas kernel
# computes exactly Procedure B (LocalSDCA) of the paper.
#
# hypothesis sweeps shapes, losses, step counts, regularization and seeds;
# every case compares the interpret-mode Pallas kernel against the
# straight-line numpy oracle in kernels/ref.py.
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import local_sdca, objective, ref

LOSSES = list(ref.LOSSES)


def make_problem(rng, n_k, d, scale=1.0):
    """Random block with rows normalised to ||x_i|| <= 1 (paper's assumption)."""
    X = rng.normal(size=(n_k, d)).astype(np.float32) * scale
    norms = np.linalg.norm(X, axis=1, keepdims=True)
    X = X / np.maximum(1.0, norms)
    y = rng.choice([-1.0, 1.0], size=n_k).astype(np.float32)
    return X, y


def feasible_alpha(rng, y, loss):
    """Random dual-feasible starting point for the given loss."""
    n_k = len(y)
    if loss in ("hinge", "smoothed_hinge"):
        return (y * rng.uniform(0.0, 1.0, n_k)).astype(np.float32)
    if loss == "logistic":
        return (y * rng.uniform(0.05, 0.95, n_k)).astype(np.float32)
    return rng.normal(0, 0.5, n_k).astype(np.float32)


def run_kernel(loss, X, y, alpha, w, idx, lam_n, gamma, H):
    norms = (X * X).sum(axis=1).astype(np.float32)
    scalars = np.array([lam_n, gamma, H], dtype=np.float32)
    da, dw = local_sdca.local_sdca(
        loss, jnp.array(X), jnp.array(y), jnp.array(alpha), jnp.array(w),
        jnp.array(idx), jnp.array(norms), jnp.array(scalars))
    return np.asarray(da), np.asarray(dw)


@pytest.mark.parametrize("loss", LOSSES)
def test_kernel_matches_oracle_basic(loss):
    rng = np.random.default_rng(0)
    n_k, d, H, cap = 32, 8, 64, 96
    X, y = make_problem(rng, n_k, d)
    alpha = feasible_alpha(rng, y, loss)
    w = rng.normal(0, 0.1, d).astype(np.float32)
    idx = rng.integers(0, n_k, cap).astype(np.int32)
    lam_n, gamma = 0.01 * 4 * n_k, 0.5
    da, dw = run_kernel(loss, X, y, alpha, w, idx, lam_n, gamma, H)
    da_r, dw_r = ref.local_sdca_ref(X, y, alpha, w, idx, lam_n, gamma, H, loss)
    np.testing.assert_allclose(da, da_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw, dw_r, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    loss=st.sampled_from(LOSSES),
    n_k=st.integers(2, 48),
    d=st.integers(1, 24),
    H=st.integers(0, 80),
    lam=st.floats(1e-3, 1.0),
    gamma=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle_sweep(loss, n_k, d, H, lam, gamma, seed):
    rng = np.random.default_rng(seed)
    X, y = make_problem(rng, n_k, d)
    alpha = feasible_alpha(rng, y, loss)
    w = rng.normal(0, 0.1, d).astype(np.float32)
    cap = max(H, 1)
    idx = rng.integers(0, n_k, cap).astype(np.int32)
    lam_n = lam * 3 * n_k  # pretend K=3 workers: global n = 3 n_k
    da, dw = run_kernel(loss, X, y, alpha, w, idx, lam_n, gamma, H)
    da_r, dw_r = ref.local_sdca_ref(X, y, alpha, w, idx, lam_n, gamma, H, loss)
    np.testing.assert_allclose(da, da_r, rtol=5e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dw_r, rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("loss", LOSSES)
def test_h_zero_is_noop(loss):
    """H = 0 must return exactly zero updates (idx is never read)."""
    rng = np.random.default_rng(1)
    X, y = make_problem(rng, 8, 4)
    alpha = feasible_alpha(rng, y, loss)
    w = rng.normal(0, 0.1, 4).astype(np.float32)
    idx = rng.integers(0, 8, 16).astype(np.int32)
    da, dw = run_kernel(loss, X, y, alpha, w, idx, 1.0, 0.5, 0)
    assert np.all(da == 0) and np.all(dw == 0)


@pytest.mark.parametrize("loss", LOSSES)
def test_dw_consistency(loss):
    """Output invariant of Procedure A: dw == X^T dalpha / (lambda n)."""
    rng = np.random.default_rng(2)
    n_k, d = 24, 6
    X, y = make_problem(rng, n_k, d)
    alpha = feasible_alpha(rng, y, loss)
    w = np.zeros(d, np.float32)
    idx = rng.integers(0, n_k, 64).astype(np.int32)
    lam_n = 0.05 * n_k
    da, dw = run_kernel(loss, X, y, alpha, w, idx, lam_n, 0.5, 64)
    np.testing.assert_allclose(dw, X.T @ da / lam_n, rtol=1e-4, atol=1e-5)


def test_hinge_box_feasibility_preserved():
    """After any number of steps, y_i (alpha_i + dalpha_i) stays in [0,1]."""
    rng = np.random.default_rng(3)
    n_k, d = 40, 10
    X, y = make_problem(rng, n_k, d)
    alpha = feasible_alpha(rng, y, "hinge")
    w = rng.normal(0, 0.2, d).astype(np.float32)
    idx = rng.integers(0, n_k, 200).astype(np.int32)
    da, _ = run_kernel("hinge", X, y, alpha, w, idx, 0.1 * n_k, 1.0, 200)
    b = y * (alpha + da)
    assert np.all(b >= -1e-5) and np.all(b <= 1.0 + 1e-5)


def test_deterministic_given_idx():
    """Same idx sequence => bitwise-identical updates (host owns randomness)."""
    rng = np.random.default_rng(4)
    X, y = make_problem(rng, 16, 8)
    alpha = np.zeros(16, np.float32)
    w = np.zeros(8, np.float32)
    idx = rng.integers(0, 16, 32).astype(np.int32)
    out1 = run_kernel("hinge", X, y, alpha, w, idx, 1.6, 1.0, 32)
    out2 = run_kernel("hinge", X, y, alpha, w, idx, 1.6, 1.0, 32)
    assert np.array_equal(out1[0], out2[0]) and np.array_equal(out1[1], out2[1])


def test_zero_row_is_guarded():
    """A zero data row (s == 0) must produce delta == 0, not NaN."""
    X = np.zeros((4, 3), np.float32)
    X[0] = [0.5, 0.0, 0.0]
    y = np.array([1, -1, 1, -1], np.float32)
    alpha = np.zeros(4, np.float32)
    w = np.zeros(3, np.float32)
    idx = np.array([1, 2, 3, 0] * 4, np.int32)
    da, dw = run_kernel("hinge", X, y, alpha, w, idx, 2.0, 1.0, 16)
    assert np.all(np.isfinite(da)) and np.all(np.isfinite(dw))
    assert np.all(da[1:] == 0)
    assert da[0] != 0  # the non-zero row does move


def test_local_steps_increase_global_dual():
    """Each kernel call's update must not decrease D when applied alone
    (coordinate ascent on the global dual restricted to the block)."""
    rng = np.random.default_rng(5)
    n, d = 48, 12
    X, y = make_problem(rng, n, d)
    lam = 0.05
    alpha = np.zeros(n, np.float32)
    w = np.zeros(d, np.float32)
    lam_n = lam * n
    d_prev = ref.dual_ref(X, y, alpha, lam, n, 1.0, "hinge")
    for t in range(5):
        idx = rng.integers(0, n, 64).astype(np.int32)
        da, dw = run_kernel("hinge", X, y, alpha, w, idx, lam_n, 1.0, 64)
        alpha = alpha + da
        w = w + dw
        d_new = ref.dual_ref(X, y, alpha, lam, n, 1.0, "hinge")
        assert d_new >= d_prev - 1e-6
        d_prev = d_new


# --------------------------- objective kernel ---------------------------

@settings(max_examples=20, deadline=None)
@given(
    loss=st.sampled_from(LOSSES),
    n_k=st.integers(1, 300),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_objective_matches_oracle(loss, n_k, d, seed):
    rng = np.random.default_rng(seed)
    X, y = make_problem(rng, n_k, d)
    alpha = feasible_alpha(rng, y, loss)
    w = rng.normal(0, 0.3, d).astype(np.float32)
    gamma = 0.5
    ls, cs = objective.block_objective(
        loss, jnp.array(X), jnp.array(y), jnp.array(alpha), jnp.array(w),
        jnp.float32(gamma))
    ls_r, cs_r = ref.block_objective_ref(X, y, alpha, w, gamma, loss)
    np.testing.assert_allclose(float(ls), ls_r, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(cs), cs_r, rtol=1e-3, atol=1e-4)


def test_objective_tiled_equals_single_tile():
    """n_k divisible by TILE exercises the multi-step grid; result must match
    the same data evaluated as one big tile (oracle)."""
    rng = np.random.default_rng(6)
    n_k = objective.TILE * 3
    X, y = make_problem(rng, n_k, 8)
    alpha = feasible_alpha(rng, y, "hinge")
    w = rng.normal(0, 0.3, 8).astype(np.float32)
    ls, cs = objective.block_objective(
        "hinge", jnp.array(X), jnp.array(y), jnp.array(alpha), jnp.array(w),
        jnp.float32(1.0))
    ls_r, cs_r = ref.block_objective_ref(X, y, alpha, w, 1.0, "hinge")
    np.testing.assert_allclose(float(ls), ls_r, rtol=1e-4)
    np.testing.assert_allclose(float(cs), cs_r, rtol=1e-4)


def test_duality_gap_nonnegative_and_closes():
    """P(w(a)) - D(a) >= 0 always, and shrinks as SDCA progresses."""
    rng = np.random.default_rng(7)
    n, d = 64, 8
    X, y = make_problem(rng, n, d)
    lam = 0.1
    alpha = np.zeros(n, np.float32)
    w = np.zeros(d, np.float32)
    gaps = []
    for t in range(4):
        p = ref.primal_ref(X, y, w, lam, n, 1.0, "hinge")
        dd = ref.dual_ref(X, y, alpha, lam, n, 1.0, "hinge")
        gaps.append(p - dd)
        assert p - dd >= -1e-8
        idx = rng.integers(0, n, 128).astype(np.int32)
        da, dw = run_kernel("hinge", X, y, alpha, w, idx, lam * n, 1.0, 128)
        alpha, w = alpha + da, w + dw
    assert gaps[-1] < gaps[0] * 0.5
