# AOT path tests: the HLO text artifacts parse, carry the right parameter
# signature, and the manifest is consistent — everything the rust
# ArtifactStore depends on.
import hashlib
import json
import os
import subprocess
import sys

import pytest

from compile import aot


def entry_params(text: str) -> str:
    """The input half of entry_computation_layout={(...)->...}."""
    layout = text.split("entry_computation_layout={(", 1)[1]
    return layout.split(")->", 1)[0]


def test_to_hlo_text_local_sdca():
    text = aot.lower_local_sdca("hinge", 8, 4, 16)
    assert "HloModule" in text
    params = entry_params(text)
    # 7 entry parameters: X, y, alpha, w, idx, norms, scalars
    assert params.count("f32") == 6 and params.count("s32") == 1
    assert "f32[8,4]" in params and "s32[16]" in params and "f32[3]" in params


def test_to_hlo_text_eval_objectives():
    text = aot.lower_eval_objectives("hinge", 8, 4)
    assert "HloModule" in text
    params = entry_params(text)
    # 5 entry parameters: X, y, alpha, w, gamma
    assert params.count("f32") == 5
    assert "f32[8,4]" in params


def test_artifact_names_are_unique():
    names = [aot.artifact_name(*s) for s in aot.SPECS_FULL]
    assert len(names) == len(set(names))


def test_losses_lower_to_distinct_hlo():
    texts = {loss: aot.lower_local_sdca(loss, 8, 4, 16)
             for loss in ("hinge", "squared", "logistic")}
    assert len(set(texts.values())) == 3


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == len(aot.SPECS_QUICK)
    for entry in manifest["artifacts"]:
        text = (out / entry["file"]).read_text()
        assert "HloModule" in text
        assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"]
        assert {"kernel", "loss", "n_k", "d", "cap"} <= set(entry)
