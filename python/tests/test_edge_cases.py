# Edge-case coverage for the L1 kernels beyond the core sweeps in
# test_kernel.py: boundary dual points, degenerate data, label skew, grid
# tiling edges, and the exact contracts the rust runtime relies on.
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import local_sdca, objective, ref


def run_kernel(loss, X, y, alpha, w, idx, lam_n, gamma, H):
    norms = (X * X).sum(axis=1).astype(np.float32)
    scalars = np.array([lam_n, gamma, H], dtype=np.float32)
    da, dw = local_sdca.local_sdca(
        loss, jnp.array(X), jnp.array(y), jnp.array(alpha), jnp.array(w),
        jnp.array(idx), jnp.array(norms), jnp.array(scalars))
    return np.asarray(da), np.asarray(dw)


def test_alpha_at_box_boundaries_hinge():
    """Starting exactly at the dual box corners must stay feasible."""
    rng = np.random.default_rng(0)
    n_k, d = 12, 5
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X /= np.maximum(1.0, np.linalg.norm(X, axis=1, keepdims=True))
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    # half the coordinates at b=0, half at b=1
    alpha = (y * np.tile([0.0, 1.0], n_k // 2)).astype(np.float32)
    w = (X.T @ alpha / 2.0).astype(np.float32)
    idx = rng.integers(0, n_k, 48).astype(np.int32)
    da, _ = run_kernel("hinge", X, y, alpha, w, idx, 2.0, 1.0, 48)
    b = y * (alpha + da)
    assert np.all(b >= -1e-5) and np.all(b <= 1 + 1e-5)


def test_single_row_block():
    """n_k = 1: every step hits the same coordinate; must converge to the
    1-D optimum, matching the oracle exactly."""
    X = np.array([[0.6, 0.8]], np.float32)
    y = np.array([1.0], np.float32)
    alpha = np.zeros(1, np.float32)
    w = np.zeros(2, np.float32)
    idx = np.zeros(8, np.int32)
    da, dw = run_kernel("squared", X, y, alpha, w, idx, 0.5, 1.0, 8)
    da_r, dw_r = ref.local_sdca_ref(X, y, alpha, w, idx, 0.5, 1.0, 8, "squared")
    np.testing.assert_allclose(da, da_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dw, dw_r, rtol=1e-5, atol=1e-6)


def test_all_same_label():
    """Degenerate label distribution (all +1) must still be handled."""
    rng = np.random.default_rng(1)
    n_k, d = 16, 4
    X = rng.normal(size=(n_k, d)).astype(np.float32) * 0.5
    y = np.ones(n_k, np.float32)
    idx = rng.integers(0, n_k, 64).astype(np.int32)
    for loss in ref.LOSSES:
        da, dw = run_kernel(loss, X, y, np.zeros(n_k, np.float32),
                            np.zeros(d, np.float32), idx, 1.6, 0.5, 64)
        da_r, dw_r = ref.local_sdca_ref(
            X, y, np.zeros(n_k), np.zeros(d), idx, 1.6, 0.5, 64, loss)
        np.testing.assert_allclose(da, da_r, rtol=1e-4, atol=1e-5)


def test_repeated_index_sequence():
    """idx hammering one coordinate: updates must telescope exactly like
    the sequential oracle (regression guard for the dalpha accumulation)."""
    rng = np.random.default_rng(2)
    n_k, d = 8, 3
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X /= np.maximum(1.0, np.linalg.norm(X, axis=1, keepdims=True))
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    idx = np.full(32, 3, np.int32)  # only coordinate 3
    da, dw = run_kernel("hinge", X, y, np.zeros(n_k, np.float32),
                        np.zeros(d, np.float32), idx, 1.0, 1.0, 32)
    da_r, dw_r = ref.local_sdca_ref(X, y, np.zeros(n_k), np.zeros(d),
                                    idx, 1.0, 1.0, 32, "hinge")
    np.testing.assert_allclose(da, da_r, rtol=1e-5, atol=1e-6)
    assert np.all(da[np.arange(n_k) != 3] == 0)


def test_h_less_than_capacity_ignores_tail():
    """Only idx[:H] may be consumed: a garbage tail must not matter."""
    rng = np.random.default_rng(3)
    n_k, d, H = 10, 4, 7
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    base = rng.integers(0, n_k, 32).astype(np.int32)
    poisoned = base.copy()
    poisoned[H:] = 0  # different tail
    out_a = run_kernel("hinge", X, y, np.zeros(n_k, np.float32),
                       np.zeros(d, np.float32), base, 1.0, 1.0, H)
    out_b = run_kernel("hinge", X, y, np.zeros(n_k, np.float32),
                       np.zeros(d, np.float32), poisoned, 1.0, 1.0, H)
    np.testing.assert_array_equal(out_a[0], out_b[0])
    np.testing.assert_array_equal(out_a[1], out_b[1])


def test_large_lambda_small_lambda():
    """Extreme regularization scales: no NaN, matches oracle."""
    rng = np.random.default_rng(4)
    n_k, d = 12, 4
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X /= np.maximum(1.0, np.linalg.norm(X, axis=1, keepdims=True))
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    idx = rng.integers(0, n_k, 24).astype(np.int32)
    for lam_n in (1e-4, 1e4):
        da, dw = run_kernel("smoothed_hinge", X, y, np.zeros(n_k, np.float32),
                            np.zeros(d, np.float32), idx, lam_n, 0.5, 24)
        assert np.all(np.isfinite(da)) and np.all(np.isfinite(dw))
        da_r, dw_r = ref.local_sdca_ref(X, y, np.zeros(n_k), np.zeros(d),
                                        idx, lam_n, 0.5, 24, "smoothed_hinge")
        np.testing.assert_allclose(da, da_r, rtol=1e-3, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(tiles=st.integers(1, 4), d=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_objective_grid_tiling_edges(tiles, d, seed):
    """n_k exactly at TILE multiples exercises the accumulating grid."""
    rng = np.random.default_rng(seed)
    n_k = objective.TILE * tiles
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X /= np.maximum(1.0, np.linalg.norm(X, axis=1, keepdims=True))
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    alpha = (y * rng.uniform(0, 1, n_k)).astype(np.float32)
    w = rng.normal(0, 0.2, d).astype(np.float32)
    ls, cs = objective.block_objective(
        "smoothed_hinge", jnp.array(X), jnp.array(y), jnp.array(alpha),
        jnp.array(w), jnp.float32(0.5))
    ls_r, cs_r = ref.block_objective_ref(X, y, alpha, w, 0.5, "smoothed_hinge")
    np.testing.assert_allclose(float(ls), ls_r, rtol=2e-3, atol=1e-3)
    np.testing.assert_allclose(float(cs), cs_r, rtol=2e-3, atol=1e-3)


def test_objective_logistic_boundary_alpha():
    """Logistic conjugate at b in {0, 1} must return 0 (entropy limit),
    not NaN — mirrors the rust-side convention."""
    X = np.eye(3, dtype=np.float32)
    y = np.array([1.0, -1.0, 1.0], np.float32)
    alpha = np.array([0.0, -1.0, 1.0], np.float32)  # b = 0, 1, 1
    w = np.zeros(3, np.float32)
    ls, cs = objective.block_objective(
        "logistic", jnp.array(X), jnp.array(y), jnp.array(alpha),
        jnp.array(w), jnp.float32(1.0))
    assert np.isfinite(float(ls)) and np.isfinite(float(cs))
    assert abs(float(cs)) < 1e-6


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_kernel_accepts_nonuniform_row_norms(loss):
    """Rows well inside the unit ball (||x|| << 1) exercise the s_i != 1
    curvature path."""
    rng = np.random.default_rng(5)
    n_k, d = 10, 4
    scales = np.linspace(0.01, 1.0, n_k).reshape(-1, 1).astype(np.float32)
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X = scales * X / np.linalg.norm(X, axis=1, keepdims=True)
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    idx = rng.integers(0, n_k, 40).astype(np.int32)
    da, dw = run_kernel(loss, X, y, np.zeros(n_k, np.float32),
                        np.zeros(d, np.float32), idx, 2.0, 0.5, 40)
    da_r, dw_r = ref.local_sdca_ref(X, y, np.zeros(n_k), np.zeros(d),
                                    idx, 2.0, 0.5, 40, loss)
    np.testing.assert_allclose(da, da_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw, dw_r, rtol=1e-4, atol=1e-5)
