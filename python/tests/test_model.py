# L2 graph tests: shapes, dtypes, jit-ability, and the scalar-parameter
# contract the rust runtime relies on (one artifact serves every lambda/H).
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def inputs(n_k=16, d=8, cap=32, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n_k, d)).astype(np.float32)
    X /= np.maximum(1.0, np.linalg.norm(X, axis=1, keepdims=True))
    y = rng.choice([-1.0, 1.0], n_k).astype(np.float32)
    alpha = np.zeros(n_k, np.float32)
    w = np.zeros(d, np.float32)
    idx = rng.integers(0, n_k, cap).astype(np.int32)
    norms = (X * X).sum(1).astype(np.float32)
    return X, y, alpha, w, idx, norms


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_local_sdca_round_shapes(loss):
    X, y, alpha, w, idx, norms = inputs()
    fn = jax.jit(model.make_local_sdca_round(loss))
    scal = jnp.array([1.6, 0.5, 8.0], jnp.float32)
    da, dw = fn(X, y, alpha, w, idx, norms, scal)
    assert da.shape == (16,) and da.dtype == jnp.float32
    assert dw.shape == (8,) and dw.dtype == jnp.float32


def test_scalar_h_is_runtime_parameter():
    """The same jitted graph must serve different H values (no retrace of
    the while loop bound) — this is what makes one HLO artifact cover the
    whole Figure-3 H sweep."""
    X, y, alpha, w, idx, norms = inputs(cap=64)
    fn = jax.jit(model.make_local_sdca_round("hinge"))
    outs = {}
    for H in (1, 7, 64):
        da, dw = fn(X, y, alpha, w, idx, norms,
                    jnp.array([1.6, 1.0, float(H)], jnp.float32))
        outs[H] = np.asarray(da)
        da_r, _ = ref.local_sdca_ref(X, y, alpha, w, idx, 1.6, 1.0, H, "hinge")
        np.testing.assert_allclose(np.asarray(da), da_r, rtol=1e-4, atol=1e-5)
    assert fn._cache_size() == 1
    assert not np.array_equal(outs[1], outs[64])


def test_scalar_lambda_is_runtime_parameter():
    X, y, alpha, w, idx, norms = inputs()
    fn = jax.jit(model.make_local_sdca_round("hinge"))
    for lam_n in (0.5, 5.0):
        da, dw = fn(X, y, alpha, w, idx, norms,
                    jnp.array([lam_n, 1.0, 16.0], jnp.float32))
        da_r, dw_r = ref.local_sdca_ref(X, y, alpha, w, idx, lam_n, 1.0, 16,
                                        "hinge")
        np.testing.assert_allclose(np.asarray(dw), dw_r, rtol=1e-4, atol=1e-5)
    assert fn._cache_size() == 1


@pytest.mark.parametrize("loss", ["hinge", "smoothed_hinge"])
def test_eval_objectives_shapes(loss):
    X, y, alpha, w, idx, norms = inputs()
    fn = jax.jit(model.make_eval_objectives(loss))
    ls, cs = fn(X, y, alpha, w, jnp.float32(0.5))
    assert ls.shape == (1,) and cs.shape == (1,)
    ls_r, cs_r = ref.block_objective_ref(X, y, alpha, w, 0.5, loss)
    np.testing.assert_allclose(float(ls[0]), ls_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(cs[0]), cs_r, rtol=1e-4, atol=1e-5)


def test_round_composes_with_objectives():
    """One full CoCoA round on K=2 synthetic blocks through the L2 graphs:
    averaging the per-block updates must not decrease the global dual."""
    n_k, d, K = 32, 8, 2
    lam = 0.05
    n = n_k * K
    blocks = [inputs(n_k, d, cap=64, seed=s) for s in (1, 2)]
    Xg = np.vstack([b[0] for b in blocks])
    yg = np.concatenate([b[1] for b in blocks])
    round_fn = jax.jit(model.make_local_sdca_round("hinge"))
    alpha = np.zeros(n, np.float32)
    w = np.zeros(d, np.float32)
    d0 = ref.dual_ref(Xg, yg, alpha, lam, n, 1.0, "hinge")
    scal = jnp.array([lam * n, 1.0, 64.0], jnp.float32)
    dalpha = np.zeros(n, np.float32)
    dw_sum = np.zeros(d, np.float32)
    for k, (X, y, a, _, idx, norms) in enumerate(blocks):
        da, dw = round_fn(X, y, alpha[k * n_k:(k + 1) * n_k], w, idx, norms, scal)
        dalpha[k * n_k:(k + 1) * n_k] = np.asarray(da) / K
        dw_sum += np.asarray(dw) / K
    alpha += dalpha
    w += dw_sum
    np.testing.assert_allclose(
        w, Xg.T @ alpha / (lam * n), rtol=1e-4, atol=1e-6)
    d1 = ref.dual_ref(Xg, yg, alpha, lam, n, 1.0, "hinge")
    assert d1 >= d0 - 1e-8
