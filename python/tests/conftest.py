# Allow running `pytest python/tests/` from the repo root (the Makefile
# cd's into python/, but the top-level test driver does not).
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
