//! Property-based tests over the framework's invariants.
//!
//! The offline build has no proptest crate, so this file carries its own
//! lightweight property harness: each property runs over `CASES` seeded
//! random instances; on failure it reports the seed so the case replays
//! exactly (`Rng` is deterministic per seed).

use cocoa::data::{cov_like, rcv1_like, Dataset, Partition, PartitionStrategy};
use cocoa::loss::{Hinge, Logistic, Loss, LossKind, SmoothedHinge, Squared};
use cocoa::objective;
use cocoa::solvers::{Block, LocalDualMethod, LocalSdca, Sampling};
use cocoa::theory;
use cocoa::util::Rng;

const CASES: u64 = 40;

/// Run `prop` for CASES seeds, reporting the failing seed.
fn for_all(name: &str, prop: impl Fn(u64, &mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(0xfeed_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(seed, &mut rng)
        }));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

fn random_loss(rng: &mut Rng) -> Box<dyn Loss> {
    match rng.gen_range(4) {
        0 => Box::new(Hinge),
        1 => Box::new(SmoothedHinge::new(rng.gen_range_f64(0.1, 1.0))),
        2 => Box::new(Squared),
        _ => Box::new(Logistic),
    }
}

fn random_dataset(rng: &mut Rng, seed: u64) -> Dataset {
    let n = 20 + rng.gen_range(80);
    let d = 2 + rng.gen_range(12);
    if rng.gen_bool(0.3) {
        rcv1_like(n, d * 4, 3, 0.1, seed)
    } else {
        cov_like(n, d, 0.1, seed)
    }
}

fn feasible_alpha(data: &Dataset, loss: &dyn Loss, rng: &mut Rng) -> Vec<f64> {
    data.labels
        .iter()
        .map(|&y| loss.project_feasible(y * rng.gen_range_f64(0.05, 0.95), y))
        .collect()
}

#[test]
fn prop_partition_disjoint_cover() {
    for_all("partition disjoint cover", |seed, rng| {
        let n = 1 + rng.gen_range(500);
        let k = 1 + rng.gen_range(n.min(16));
        let strategy = match rng.gen_range(3) {
            0 => PartitionStrategy::Contiguous,
            1 => PartitionStrategy::RoundRobin,
            _ => PartitionStrategy::Random,
        };
        let p = Partition::new(strategy, n, k, seed);
        p.validate().expect("partition invariant violated");
        assert_eq!(p.k(), k);
        let total: usize = p.blocks.iter().map(Vec::len).sum();
        assert_eq!(total, n);
        // balance: sizes differ by at most 1
        let max = p.blocks.iter().map(Vec::len).max().unwrap();
        let min = p.blocks.iter().map(Vec::len).min().unwrap();
        assert!(max - min <= 1, "unbalanced: {max} vs {min}");
    });
}

#[test]
fn prop_duality_gap_nonnegative() {
    for_all("duality gap >= 0", |seed, rng| {
        let data = random_dataset(rng, seed);
        let loss = random_loss(rng);
        let lambda = rng.gen_range_f64(0.005, 0.5);
        let alpha = feasible_alpha(&data, loss.as_ref(), rng);
        let gap = objective::duality_gap(&data, &alpha, lambda, loss.as_ref());
        assert!(gap >= -1e-9, "gap {gap} < 0");
    });
}

#[test]
fn prop_sdca_update_is_feasible_and_consistent() {
    for_all("sdca feasibility + dw = A dalpha", |seed, rng| {
        let data = random_dataset(rng, seed);
        let n = data.n();
        let loss = random_loss(rng);
        let lambda = rng.gen_range_f64(0.01, 0.3);
        let block = Block::new(data, lambda * n as f64);
        let alpha = feasible_alpha(&block.data, loss.as_ref(), rng);
        let w = block.data.primal_from_dual(&alpha, lambda);
        let h = rng.gen_range(200);
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let up = solver.local_update(&block, loss.as_ref(), &alpha, &w, h, rng);

        // dw == A dalpha
        let mut expect = vec![0.0; block.d()];
        for (i, &da) in up.dalpha.iter().enumerate() {
            if da != 0.0 {
                block
                    .data
                    .features
                    .add_row_scaled(i, da / block.lambda_n, &mut expect);
            }
        }
        for (a, b) in expect.iter().zip(&up.dw) {
            assert!((a - b).abs() < 1e-9);
        }
        // feasibility preserved at full application
        for (i, (&a0, &da)) in alpha.iter().zip(&up.dalpha).enumerate() {
            let a1 = a0 + da;
            let conj = loss.conjugate(a1, block.data.labels[i]);
            assert!(conj.is_finite(), "coordinate {i} left the dual domain");
        }
    });
}

#[test]
fn prop_averaging_scale_preserves_feasibility() {
    // alpha + (beta/K) dalpha stays feasible for any beta in [0, K]
    // (convexity of the dual domain) — the Algorithm-1 commit step.
    for_all("scaled commit feasible", |seed, rng| {
        let data = cov_like(40 + rng.gen_range(40), 6, 0.1, seed);
        let n = data.n();
        let loss = random_loss(rng);
        let lambda = 0.05;
        let block = Block::new(data, lambda * n as f64);
        let alpha = feasible_alpha(&block.data, loss.as_ref(), rng);
        let w = block.data.primal_from_dual(&alpha, lambda);
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let up = solver.local_update(&block, loss.as_ref(), &alpha, &w, 60, rng);
        let k = 1 + rng.gen_range(8);
        let beta = rng.gen_range_f64(0.0, k as f64);
        let scale = beta / k as f64;
        for (i, (&a0, &da)) in alpha.iter().zip(&up.dalpha).enumerate() {
            let a1 = a0 + scale * da;
            let conj = loss.conjugate(a1, block.data.labels[i]);
            assert!(
                conj.is_finite(),
                "scaled commit (beta={beta}, K={k}) left the dual domain at {i}"
            );
        }
    });
}

#[test]
fn prop_local_update_never_decreases_global_dual() {
    // Coordinate ascent restricted to one block never decreases D when
    // the whole update is applied (Assumption 1's premise).
    for_all("block ascent monotone", |seed, rng| {
        let data = random_dataset(rng, seed);
        let n = data.n();
        let loss = random_loss(rng);
        let lambda = rng.gen_range_f64(0.02, 0.2);
        let block = Block::new(data, lambda * n as f64);
        let alpha = feasible_alpha(&block.data, loss.as_ref(), rng);
        let w = block.data.primal_from_dual(&alpha, lambda);
        let d0 = objective::dual(&block.data, &alpha, lambda, loss.as_ref());
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let up = solver.local_update(&block, loss.as_ref(), &alpha, &w, 50, rng);
        let alpha1: Vec<f64> = alpha.iter().zip(&up.dalpha).map(|(a, d)| a + d).collect();
        let d1 = objective::dual(&block.data, &alpha1, lambda, loss.as_ref());
        assert!(d1 >= d0 - 1e-9, "dual decreased: {d0} -> {d1}");
    });
}

#[test]
fn prop_lemma3_sigma_bounds() {
    for_all("0 <= sigma_min <= n_max", |seed, rng| {
        let data = random_dataset(rng, seed);
        let n = data.n();
        let k = 1 + rng.gen_range(n.min(6));
        let part = Partition::new(PartitionStrategy::Contiguous, n, k, seed);
        let sigma = theory::sigma_min_estimate(&data, &part, 40, seed);
        assert!(sigma >= 0.0, "sigma {sigma} < 0");
        assert!(
            sigma <= part.n_max() as f64 + 1e-6,
            "sigma {sigma} > n_max {}",
            part.n_max()
        );
    });
}

#[test]
fn prop_theta_is_contraction_and_monotone() {
    for_all("theta in (0,1], monotone in H", |_seed, rng| {
        let n = 20 + rng.gen_range(1000);
        let n_max = 1 + rng.gen_range(n);
        let lambda = rng.gen_range_f64(1e-5, 1.0);
        let gamma = rng.gen_range_f64(0.05, 2.0);
        let h = rng.gen_range(10_000);
        let t_h = theory::theta_local_sdca(h, lambda, n, gamma, n_max);
        let t_h1 = theory::theta_local_sdca(h + 1, lambda, n, gamma, n_max);
        // theta can underflow to exactly 0 for huge H — that's the
        // solved-to-optimality limit, still a valid contraction factor
        assert!((0.0..=1.0).contains(&t_h), "theta {t_h} out of range");
        assert!(t_h1 <= t_h, "theta not monotone: {t_h1} > {t_h}");
        let rate = theory::theorem2_rate(t_h, 1 + rng.gen_range(32), lambda, n, gamma,
                                          rng.gen_range_f64(0.0, n_max as f64));
        assert!((0.0..=1.0).contains(&rate), "rate {rate} out of range");
    });
}

#[test]
fn prop_loss_conjugate_fenchel_young() {
    for_all("Fenchel-Young inequality", |_seed, rng| {
        let loss = random_loss(rng);
        for _ in 0..20 {
            let y = if rng.gen_bool(0.5) { 1.0 } else { -1.0 };
            let a = rng.gen_range_f64(-3.0, 3.0);
            let alpha = loss.project_feasible(y * rng.gen_range_f64(0.01, 0.99), y);
            let lhs = loss.value(a, y) + loss.conjugate(alpha, y);
            assert!(
                lhs >= -alpha * a - 1e-8,
                "{loss:?} FY violated: {lhs} < {}",
                -alpha * a
            );
        }
    });
}

#[test]
fn prop_coord_delta_maximizes_1d_subproblem() {
    for_all("coord_delta is the 1-D argmax", |_seed, rng| {
        let loss = random_loss(rng);
        let y = if rng.gen_bool(0.5) { 1.0 } else { -1.0 };
        let a = loss.project_feasible(y * rng.gen_range_f64(0.02, 0.98), y);
        let q = rng.gen_range_f64(-2.0, 2.0);
        let s = rng.gen_range_f64(0.01, 5.0);
        let obj = |da: f64| -loss.conjugate(a + da, y) - q * da - s * da * da / 2.0;
        let star = loss.coord_delta(q, y, a, s);
        let at_star = obj(star);
        assert!(at_star.is_finite());
        for _ in 0..25 {
            let probe = star + rng.gen_range_f64(-0.5, 0.5);
            let v = obj(probe);
            assert!(
                v <= at_star + 1e-6,
                "{loss:?}: probe beats argmax by {}",
                v - at_star
            );
        }
    });
}

#[test]
fn prop_csr_dense_row_ops_agree() {
    for_all("CSR and dense row ops agree", |seed, rng| {
        let data = rcv1_like(10 + rng.gen_range(50), 30, 4, 0.1, seed);
        let dense_rows: Vec<Vec<f64>> =
            (0..data.n()).map(|i| data.features.row_dense(i)).collect();
        let w: Vec<f64> = (0..30).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
        for i in 0..data.n() {
            let sparse_dot = data.features.row_dot(i, &w);
            let dense_dot: f64 = dense_rows[i].iter().zip(&w).map(|(a, b)| a * b).sum();
            assert!((sparse_dot - dense_dot).abs() < 1e-10);
            assert!((data.norm_sq(i)
                - dense_rows[i].iter().map(|v| v * v).sum::<f64>())
            .abs()
                < 1e-10);
        }
    });
}

#[test]
fn prop_toml_algorithms_instantiate_equivalently() {
    // Config-layer migration guard: for every AlgorithmSpec the TOML
    // parser accepts, the builder path (AlgorithmSpec::instantiate) must
    // construct an Algorithm with identical name, H, and beta.
    use cocoa::algorithms::Algorithm;
    use cocoa::config::ExperimentConfig;

    for_all("toml -> Algorithm equivalence", |_seed, rng| {
        let h = 1 + rng.gen_range(500);
        let beta = 0.25 * (1 + rng.gen_range(32)) as f64;
        let sections = [
            format!("name = \"cocoa\"\nh = {h}\nbeta_k = {beta}"),
            format!("name = \"cocoa\"\nh = {h}\nsolver = \"sdca_perm\""),
            format!("name = \"cocoa_plus\"\nh = {h}"),
            format!("name = \"minibatch_cd\"\nh = {h}\nbeta_b = {beta}"),
            format!("name = \"minibatch_sgd\"\nh = {h}\nbeta = {beta}"),
            format!("name = \"local_sgd\"\nh = {h}\nbeta = {beta}"),
            "name = \"naive_cd\"".to_string(),
            "name = \"naive_sgd\"".to_string(),
            "name = \"one_shot_avg\"".to_string(),
        ];
        for section in sections {
            let text = format!(
                "lambda = 0.1\n[dataset]\nkind = \"cov_like\"\nn = 10\nd = 2\n\
                 [partition]\nk = 2\n[algorithm]\n{section}\n\
                 [loss]\nkind = \"hinge\"\n[run]\nrounds = 1\n"
            );
            let cfg = ExperimentConfig::from_toml(&text).unwrap();
            let algo = cfg.algorithm.instantiate();
            assert_eq!(algo.name(), cfg.algorithm.name(), "{section}");
            assert_eq!(algo.h(), cfg.algorithm.h(), "{section}");
            assert_eq!(algo.beta(), cfg.algorithm.beta(), "{section}");
        }
    });
}
