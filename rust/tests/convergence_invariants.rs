//! Convergence invariants of Algorithm 1 locked in as tests:
//!
//! * the duality gap certificate is nonnegative along every trajectory,
//! * for the SDCA local solver with safe averaging on smooth losses
//!   (smoothed hinge, squared), the dual objective is monotone
//!   nondecreasing round over round (coordinate ascent + convexity of the
//!   averaging step — the premise behind Theorem 2),
//! * CoCoA with K = 1 *is* single-machine SDCA: the distributed runtime
//!   reproduces a hand-rolled serial SDCA loop to 1e-10 (same seeds, same
//!   coordinate stream, same arithmetic).

use cocoa::coordinator::LocalWork;
use cocoa::data::cov_like;
use cocoa::prelude::*;
use cocoa::solvers::{Block, LocalDualMethod, LocalSdca, Sampling};
use cocoa::util::Rng;

fn session(
    data: &Dataset,
    k: usize,
    loss: LossKind,
    lambda: f64,
    seed: u64,
) -> Session {
    Trainer::on(data)
        .workers(k)
        .loss(loss)
        .lambda(lambda)
        .network(NetworkModel::free())
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn gap_nonnegative_along_every_trajectory() {
    let data = cov_like(100, 6, 0.1, 21);
    for loss in [
        LossKind::Hinge,
        LossKind::SmoothedHinge { gamma: 0.5 },
        LossKind::Squared,
        LossKind::Logistic,
    ] {
        for k in [1usize, 3] {
            let mut sess = session(&data, k, loss, 0.05, 22);
            let trace = sess
                .run(&mut Cocoa::new(30), Budget::rounds(10))
                .unwrap();
            for row in &trace.rows {
                assert!(
                    row.gap >= -1e-9,
                    "{loss:?} K={k}: negative gap {} at round {}",
                    row.gap,
                    row.round
                );
                assert!(row.primal >= row.dual - 1e-9, "{loss:?} K={k}: P < D");
            }
            sess.shutdown();
        }
    }
}

#[test]
fn dual_monotone_nondecreasing_for_sdca_on_smooth_losses() {
    // Safe averaging (beta_K = 1): each round's commit is a convex
    // combination of dual-feasible ascent steps, so D never decreases.
    let data = cov_like(120, 7, 0.1, 23);
    for loss in [LossKind::SmoothedHinge { gamma: 1.0 }, LossKind::Squared] {
        for k in [2usize, 4] {
            let mut sess = session(&data, k, loss, 0.05, 24);
            let trace = sess
                .run(&mut Cocoa::new(40), Budget::rounds(12))
                .unwrap();
            for pair in trace.rows.windows(2) {
                assert!(
                    pair[1].dual >= pair[0].dual - 1e-9,
                    "{loss:?} K={k}: dual decreased {} -> {} at round {}",
                    pair[0].dual,
                    pair[1].dual,
                    pair[1].round
                );
            }
            sess.shutdown();
        }
    }
}

#[test]
fn dual_monotone_under_counted_and_simnet_transports() {
    // The invariant is a property of the algorithm, not the fabric: it
    // must hold verbatim on the measuring/fault-injecting transports.
    let data = cov_like(80, 5, 0.1, 25);
    for transport in [
        TransportKind::Counted,
        TransportKind::SimNet(SimNetConfig::new(9).drops(0.2, 2, 1e-3)),
    ] {
        let mut sess = Trainer::on(&data)
            .workers(3)
            .loss(LossKind::Squared)
            .lambda(0.05)
            .transport(transport)
            .seed(26)
            .build()
            .unwrap();
        let trace = sess.run(&mut Cocoa::new(30), Budget::rounds(8)).unwrap();
        for pair in trace.rows.windows(2) {
            assert!(pair[1].dual >= pair[0].dual - 1e-9);
            assert!(pair[1].gap >= -1e-9);
        }
        sess.shutdown();
    }
}

#[test]
fn cocoa_k1_matches_single_machine_sdca_to_1e10() {
    let (n, d) = (60, 5);
    let data = cov_like(n, d, 0.1, 7);
    let (lambda, h, rounds) = (0.05, 25, 8);
    let seed: u64 = 11;
    for loss_kind in [
        LossKind::Hinge,
        LossKind::SmoothedHinge { gamma: 1.0 },
        LossKind::Squared,
    ] {
        // distributed: K = 1, safe averaging => commit scale 1
        let mut sess = session(&data, 1, loss_kind, lambda, seed);
        for _ in 0..rounds {
            let replies = sess.dispatch(|_| LocalWork::DualRound { h }).unwrap();
            sess.commit(&replies, 1.0).unwrap();
        }
        let w_dist = sess.w().to_vec();
        sess.shutdown();

        // serial: the same LocalSDCA stream, by hand. Worker 0 derives its
        // rng stream as seed * golden-ratio-constant + 0 (coordinator
        // spawn contract), and with K = 1 its block is the whole dataset.
        let block = Block::new(data.clone(), lambda * n as f64);
        let loss = loss_kind.build();
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let mut rng = Rng::seed_from_u64(seed.wrapping_mul(0x9e3779b97f4a7c15));
        let mut alpha = vec![0.0f64; n];
        let mut w = vec![0.0f64; d];
        for _ in 0..rounds {
            let up = solver.local_update(&block, loss.as_ref(), &alpha, &w, h, &mut rng);
            for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
                *a += da;
            }
            for (wv, dv) in w.iter_mut().zip(&up.dw) {
                *wv += dv;
            }
        }

        for (i, (a, b)) in w_dist.iter().zip(&w).enumerate() {
            assert!(
                (a - b).abs() <= 1e-10,
                "{loss_kind:?}: w[{i}] diverged: distributed {a} vs serial {b}"
            );
        }
    }
}
