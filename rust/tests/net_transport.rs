//! Multi-process transport acceptance: a leader plus K worker threads
//! speaking the real socket protocol over a Unix-domain socket must be
//! indistinguishable — trajectory, ledger, bytes — from the in-process
//! cluster, and the handshake must keep mismatched or garbage peers out
//! without disturbing the run.
//!
//! Workers run in-test as threads calling the same `run_worker_process`
//! entry point the `cocoa worker` binary uses; only the process boundary
//! is folded away, the sockets and frames are real.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use cocoa::algorithms::Cocoa;
use cocoa::config::{
    AlgorithmSpec, Backend, DatasetSpec, ExperimentConfig, PartitionSpec, RunSpec, RuntimeSpec,
};
use cocoa::data::{cov_like, PartitionStrategy};
use cocoa::driver::MaxRounds;
use cocoa::loss::LossKind;
use cocoa::netsim::NetworkModel;
use cocoa::regularizers::RegularizerKind;
use cocoa::solvers::SolverKind;
use cocoa::transport::net::run_worker_process;
use cocoa::transport::{MessageKind, NetConfig, ReconnectPolicy, TransportKind};
use cocoa::{Error, Trainer};

const N: usize = 120;
const D: usize = 8;
const NOISE: f64 = 0.1;
const SEED: u64 = 5;
const LAMBDA: f64 = 0.05;
const H: usize = 25;
const ROUNDS: u64 = 5;

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cocoa-net-{}-{tag}.sock", std::process::id()))
}

fn worker_cfg(k: usize, data_seed: u64, listen: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetSpec::CovLike { n: N, d: D, noise: NOISE, seed: data_seed },
        partition: PartitionSpec { k, strategy: PartitionStrategy::Contiguous, seed: 0 },
        algorithm: AlgorithmSpec::Cocoa { h: H, beta_k: 1.0, solver: SolverKind::Sdca },
        loss: LossKind::Hinge,
        lambda: LAMBDA,
        regularizer: RegularizerKind::default(),
        run: RunSpec {
            rounds: ROUNDS,
            target_gap: 0.0,
            target_subopt: 0.0,
            eval_every: 1,
            seed: SEED,
            backend: Backend::Native,
        },
        runtime: RuntimeSpec::default(),
        netsim: NetworkModel::free(),
        transport: TransportKind::Net(NetConfig::new(listen)),
        artifacts_dir: "artifacts".into(),
    }
}

fn spawn_workers(k: usize, data_seed: u64, listen: &str) -> Vec<thread::JoinHandle<()>> {
    (0..k)
        .map(|_| {
            let listen = listen.to_string();
            thread::spawn(move || {
                let cfg = worker_cfg(k, data_seed, &listen);
                run_worker_process(
                    &cfg,
                    &listen,
                    &ReconnectPolicy { attempts: 60, backoff_s: 0.05 },
                )
                .unwrap();
            })
        })
        .collect()
}

/// The acceptance gate: at K ∈ {1, 2, 4}, a UDS multi-process run is
/// bit-identical to the counted in-process run — every evaluated row,
/// the final w, and the per-kind wire ledger — and the socket byte
/// totals reconcile exactly with the ledger plus the framing and
/// handshake overhead the in-process fabric does not have.
#[test]
fn uds_run_is_bit_identical_to_inproc() {
    for k in [1usize, 2, 4] {
        let data = cov_like(N, D, NOISE, SEED);

        let mut twin = Trainer::on(&data)
            .workers(k)
            .lambda(LAMBDA)
            .seed(SEED)
            .transport(TransportKind::Counted)
            .build()
            .unwrap();
        let twin_trace = twin.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
        let twin_w: Vec<u64> = twin.w().iter().map(|x| x.to_bits()).collect();
        let twin_ledger = twin.ledger().unwrap().clone();
        twin.shutdown();

        let path = sock_path(&format!("bitident-k{k}"));
        let _ = std::fs::remove_file(&path);
        let listen = format!("uds:{}", path.display());
        let workers = spawn_workers(k, SEED, &listen);

        let mut session = Trainer::on(&data)
            .workers(k)
            .lambda(LAMBDA)
            .seed(SEED)
            .transport(TransportKind::Net(NetConfig::new(&listen)))
            .build()
            .unwrap();
        assert_eq!(session.transport_name(), "net");
        let trace = session.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
        let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();

        // trajectory: every evaluated row, bit for bit
        assert_eq!(trace.rows.len(), twin_trace.rows.len(), "K={k}");
        for (got, want) in trace.rows.iter().zip(twin_trace.rows.iter()) {
            assert_eq!(got.round, want.round, "K={k}");
            assert_eq!(got.primal.to_bits(), want.primal.to_bits(), "K={k} round {}", got.round);
            assert_eq!(got.dual.to_bits(), want.dual.to_bits(), "K={k} round {}", got.round);
            assert_eq!(got.gap.to_bits(), want.gap.to_bits(), "K={k} round {}", got.round);
            assert_eq!(got.inner_steps, want.inner_steps, "K={k} round {}", got.round);
            assert_eq!(
                got.bytes_measured, want.bytes_measured,
                "K={k} round {}",
                got.round
            );
        }
        assert_eq!(w, twin_w, "K={k}: final w must be bit-identical");

        // ledger: the socket fabric accounts exactly what the in-process
        // counted fabric does, kind by kind (captured before shutdown so
        // no control traffic races the comparison)
        let ledger = session.ledger().unwrap().clone();
        for kind in [
            MessageKind::Broadcast,
            MessageKind::Commit,
            MessageKind::DeltaW,
            MessageKind::EvalRequest,
            MessageKind::EvalReply,
            MessageKind::Metrics,
        ] {
            assert_eq!(ledger.bytes(kind), twin_ledger.bytes(kind), "K={k} {kind:?}");
            assert_eq!(ledger.msgs(kind), twin_ledger.msgs(kind), "K={k} {kind:?}");
        }

        // reconciliation: socket bytes = ledger payload + framing + handshake
        let stats = session.socket_stats().expect("net transport reports socket stats");
        assert_eq!(
            stats.sent_bytes + stats.recv_bytes,
            ledger.total_bytes() + stats.framing_bytes + stats.handshake_bytes,
            "K={k}: socket bytes must reconcile with the ledger"
        );
        assert_eq!(stats.payload_bytes(), ledger.total_bytes(), "K={k}");
        assert_eq!(
            stats.framing_bytes,
            4 * (stats.sent_frames + stats.recv_frames),
            "K={k}: one 4-byte length prefix per frame"
        );

        session.shutdown();
        for h in workers {
            h.join().unwrap();
        }
        let _ = std::fs::remove_file(&path);
    }
}

/// A worker loading a different experiment (here: another dataset seed)
/// must be refused at the handshake with a typed error — before any
/// training traffic — while a matching worker is accepted and the run
/// completes normally.
#[test]
fn fingerprint_mismatch_is_rejected_with_typed_error() {
    let data = cov_like(N, D, NOISE, SEED);
    let path = sock_path("mismatch");
    let _ = std::fs::remove_file(&path);
    let listen = format!("uds:{}", path.display());

    // wrong experiment: same shapes, different data seed
    let mismatched = {
        let listen = listen.clone();
        thread::spawn(move || {
            let cfg = worker_cfg(1, SEED + 1, &listen);
            run_worker_process(&cfg, &listen, &ReconnectPolicy { attempts: 60, backoff_s: 0.05 })
                .unwrap_err()
        })
    };
    let good = spawn_workers(1, SEED, &listen);

    let mut session = Trainer::on(&data)
        .workers(1)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Net(NetConfig::new(&listen)))
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(H), MaxRounds::new(2)).unwrap();
    assert_eq!(trace.rows.last().unwrap().round, 2);
    session.shutdown();

    let err = mismatched.join().unwrap();
    match err {
        Error::Handshake { reason } => {
            assert!(reason.contains("fingerprint"), "unexpected reason: {reason}")
        }
        other => panic!("expected Error::Handshake, got {other}"),
    }
    for h in good {
        h.join().unwrap();
    }
    let _ = std::fs::remove_file(&path);
}

/// An untrusted peer spraying garbage at the listener must not take a
/// worker slot or wedge the leader: the real worker still gets accepted
/// and the run completes.
#[test]
fn garbage_hello_does_not_take_a_slot() {
    let data = cov_like(N, D, NOISE, SEED);
    let path = sock_path("garbage");
    let _ = std::fs::remove_file(&path);
    let listen = format!("uds:{}", path.display());

    let garbage = {
        let path = path.clone();
        thread::spawn(move || {
            // raw socket, no protocol: a correctly-framed frame whose
            // payload is noise (bad magic), then hold the line open
            let mut s = loop {
                match std::os::unix::net::UnixStream::connect(&path) {
                    Ok(s) => break s,
                    Err(_) => thread::sleep(Duration::from_millis(10)),
                }
            };
            let payload = [0xABu8; 24];
            let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
            frame.extend_from_slice(&payload);
            let _ = s.write_all(&frame);
            let _ = s.flush();
            // the leader answers with a reject frame and closes
            let mut sink = Vec::new();
            let _ = s.read_to_end(&mut sink);
            sink
        })
    };
    let good = spawn_workers(1, SEED, &listen);

    let mut session = Trainer::on(&data)
        .workers(1)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Net(NetConfig::new(&listen)))
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(H), MaxRounds::new(2)).unwrap();
    assert_eq!(trace.rows.last().unwrap().round, 2);
    session.shutdown();

    let answer = garbage.join().unwrap();
    assert!(!answer.is_empty(), "leader should answer garbage with a reject frame");
    for h in good {
        h.join().unwrap();
    }
    let _ = std::fs::remove_file(&path);
}

/// A leader with no workers must give up with `Error::Timeout` once the
/// accept window closes — not hang, not panic.
#[test]
fn accept_timeout_is_typed() {
    let data = cov_like(40, 4, NOISE, 9);
    let path = sock_path("timeout");
    let _ = std::fs::remove_file(&path);
    let mut netcfg = NetConfig::new(format!("uds:{}", path.display()));
    netcfg.accept_timeout_s = 0.3;

    let err = Trainer::on(&data)
        .workers(1)
        .lambda(LAMBDA)
        .transport(TransportKind::Net(netcfg))
        .build()
        .unwrap_err();
    assert!(matches!(err, Error::Timeout { .. }), "expected Error::Timeout, got {err}");
    let _ = std::fs::remove_file(&path);
}

/// The net transport refuses the PJRT backend up front: workers are
/// separate processes, a single in-process engine cannot serve them.
#[test]
fn net_plus_pjrt_is_rejected_at_build() {
    let data = cov_like(40, 4, NOISE, 9);
    let err = Trainer::on(&data)
        .workers(1)
        .lambda(LAMBDA)
        .backend(Backend::Pjrt)
        .transport(TransportKind::Net(NetConfig::new("uds:/tmp/never-bound.sock")))
        .build()
        .unwrap_err();
    assert!(matches!(err, Error::InvalidTransport { .. }), "got {err}");
}
