//! Golden lasso: CoCoA with the epsilon-smoothed L1 regularizer on an
//! orthogonal design must reach the soft-thresholding *closed-form*
//! optimum (smoothing included) to 1e-8 for K ∈ {1, 2, 4}, with the
//! recovered support matching exactly — the L1 analogue of
//! `golden_ridge.rs`. Also locks in the workload's side contracts: the
//! duality-gap certificate stays valid, the counted transport measures
//! *fewer* bytes than an equivalent L2 run (prox-sparse broadcasts), and
//! the L1 path is seed-deterministic (the CI determinism job diffs the
//! artifact this file writes).

use cocoa::data::cov_like;
use cocoa::experiments::sparsity::{lasso_closed_form, lasso_design, planted_lasso};
use cocoa::prelude::*;

#[test]
fn golden_lasso_reaches_closed_form_optimum_for_k_1_2_4() {
    let (d, m) = (8usize, 6usize);
    let n = d * m;
    // z_j/n = y_j/d with the soft threshold at lambda = 0.1: columns 2
    // and 5 (|y|/8 < 0.1) are thresholded to exact zero, the other six
    // (|y|/8 >= 0.125) stay active, mixed signs
    let y_col = [1.6, -1.2, 0.1, 2.4, -2.0, -0.06, 1.0, -1.44];
    let (lambda, eps) = (0.1, 0.5);
    let w_star = lasso_closed_form(d, m, &y_col, lambda, eps);
    assert_eq!(w_star[2], 0.0);
    assert_eq!(w_star[5], 0.0);
    let data = lasso_design(d, m, &y_col);

    for k in [1usize, 2, 4] {
        let mut session = Trainer::on(&data)
            .workers(k)
            .loss(LossKind::Squared)
            .lambda(lambda)
            .regularizer(RegularizerKind::L1 { epsilon: eps })
            .seed(5)
            .label("golden_lasso")
            .build()
            .unwrap();
        let h = n / k; // one local pass per round
        let trace = session
            .run(&mut Cocoa::adding(h), Budget::rounds(1500).eval_every(1500))
            .unwrap();

        // certificate stays a certificate under the prox
        for row in &trace.rows {
            assert!(row.gap >= -1e-10, "K={k}: negative gap at round {}", row.round);
        }

        let w = session.w();
        for j in 0..d {
            assert!(
                (w[j] - w_star[j]).abs() <= 1e-8,
                "K={k}: w[{j}] = {} vs closed form {}",
                w[j],
                w_star[j]
            );
        }
        // exact support recovery: prox zeros are *exact* zeros
        for j in 0..d {
            assert_eq!(
                w[j] == 0.0,
                w_star[j] == 0.0,
                "K={k}: support mismatch at {j} (w = {})",
                w[j]
            );
        }
        assert_eq!(trace.rows.last().unwrap().w_nnz, 6, "K={k}");
        session.shutdown();
    }
}

#[test]
fn l1_dual_is_monotone_under_safe_averaging() {
    // The generalized framework's guarantee carries over: SDCA local
    // steps on the quadratic model + beta_K = 1 averaging never decrease
    // the regularized dual, smooth loss or not orthogonal data.
    let data = cov_like(100, 8, 0.1, 27);
    let mut session = Trainer::on(&data)
        .workers(4)
        .loss(LossKind::Squared)
        .lambda(0.1)
        .regularizer(RegularizerKind::L1 { epsilon: 0.5 })
        .seed(28)
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(40), Budget::rounds(12)).unwrap();
    for pair in trace.rows.windows(2) {
        assert!(
            pair[1].dual >= pair[0].dual - 1e-9,
            "dual decreased: {} -> {} at round {}",
            pair[0].dual,
            pair[1].dual,
            pair[1].round
        );
        assert!(pair[1].gap >= -1e-9);
    }
    session.shutdown();
}

#[test]
fn l1_broadcasts_measure_fewer_bytes_than_l2() {
    // The coordinator's prox-induced sparsity on the wire: with d = 400
    // and a 10-column support, the broadcast w rides the sparse encoding
    // on the L1 run while the L2 run's dense v pays full freight.
    let prob = planted_lasso(400, 2, 10, 0.1, 0.5);
    let run = |reg: Option<RegularizerKind>| {
        let mut trainer = Trainer::on(&prob.data)
            .workers(2)
            .loss(LossKind::Squared)
            .lambda(prob.lambda)
            .transport(TransportKind::Counted)
            .seed(9)
            .label("bytes");
        if let Some(kind) = reg {
            trainer = trainer.regularizer(kind);
        }
        let mut session = trainer.build().unwrap();
        let trace = session
            .run(&mut Cocoa::new(400), Budget::rounds(10).eval_every(10))
            .unwrap();
        let bytes = trace.rows.last().unwrap().bytes_measured;
        let nnz = trace.rows.last().unwrap().w_nnz;
        session.shutdown();
        (bytes, nnz)
    };
    let (l2_bytes, l2_nnz) = run(None);
    let (l1_bytes, l1_nnz) = run(Some(RegularizerKind::L1 { epsilon: 0.5 }));
    assert!(l1_nnz <= 10, "L1 run not sparse: nnz = {l1_nnz}");
    assert!(l2_nnz > 100, "L2 run unexpectedly sparse: nnz = {l2_nnz}");
    assert!(
        l1_bytes < l2_bytes,
        "prox sparsity did not shrink measured bytes: L1 {l1_bytes} >= L2 {l2_bytes}"
    );
}

#[test]
fn restore_rejects_checkpoint_from_a_different_regularizer() {
    // v is only meaningful through the matching prox: an L1 checkpoint
    // must not restore into an L2 session (or a different epsilon).
    let data = cov_like(40, 5, 0.1, 31);
    let build = |kind: Option<RegularizerKind>| {
        let mut trainer = Trainer::on(&data).workers(2).loss(LossKind::Squared).lambda(0.1);
        if let Some(kind) = kind {
            trainer = trainer.regularizer(kind);
        }
        trainer.seed(32).build().unwrap()
    };
    let mut l1 = build(Some(RegularizerKind::L1 { epsilon: 0.5 }));
    l1.run(&mut Cocoa::new(10), Budget::rounds(2)).unwrap();
    let cp = l1.checkpoint().unwrap();
    l1.shutdown();

    // same regularizer: restores fine
    let mut twin = build(Some(RegularizerKind::L1 { epsilon: 0.5 }));
    twin.restore(&cp).unwrap();
    twin.shutdown();
    // plain L2 and a different epsilon: rejected
    let mut l2 = build(None);
    assert!(l2.restore(&cp).is_err());
    l2.shutdown();
    let mut other_eps = build(Some(RegularizerKind::L1 { epsilon: 0.25 }));
    assert!(other_eps.restore(&cp).is_err());
    other_eps.shutdown();
}

#[test]
fn sgd_baselines_reject_non_l2_with_typed_error() {
    let data = cov_like(40, 5, 0.1, 3);
    let mut session = Trainer::on(&data)
        .workers(2)
        .loss(LossKind::Hinge)
        .lambda(0.1)
        .regularizer(RegularizerKind::ElasticNet { l1_ratio: 0.5 })
        .build()
        .unwrap();
    let err = session
        .run(&mut LocalSgd::new(10), Budget::rounds(2))
        .unwrap_err();
    assert!(
        matches!(err, Error::UnsupportedRegularizer { .. }),
        "wrong error: {err}"
    );
    // the session itself is still healthy for dual methods
    let trace = session.run(&mut Cocoa::new(10), Budget::rounds(2)).unwrap();
    assert!(trace.rows.last().unwrap().gap >= -1e-9);
    session.shutdown();
}

/// L1 twin of `prop_transport::seeded_determinism_artifact`: writes the
/// deterministic fingerprint of a seeded counted L1 run to
/// `target/determinism/trace_l1_<seed>.csv`. The CI determinism job runs
/// this twice with `CARGO_TEST_SEED` pinned and diffs the files, so the
/// prox path (leader-side soft threshold, sparse broadcast accounting) is
/// determinism-checked exactly like the L2 path.
#[test]
fn seeded_determinism_artifact_l1() {
    let seed: u64 = std::env::var("CARGO_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let data = cov_like(90, 7, 0.1, seed);
    let mut session = Trainer::on(&data)
        .workers(3)
        .loss(LossKind::Squared)
        .lambda(0.05)
        .regularizer(RegularizerKind::L1 { epsilon: 0.5 })
        .network(NetworkModel::ec2_like())
        .transport(TransportKind::Counted)
        .seed(seed)
        .label("l1_det")
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(25), Budget::rounds(6)).unwrap();
    let w = session.w().to_vec();
    session.shutdown();

    let mut out = String::from(
        "round,vectors,bytes_modeled,bytes_measured,w_nnz,primal_bits,dual_bits,gap_bits\n",
    );
    for r in &trace.rows {
        out.push_str(&format!(
            "{},{},{},{},{},{:016x},{:016x},{:016x}\n",
            r.round,
            r.vectors,
            r.bytes_modeled,
            r.bytes_measured,
            r.w_nnz,
            r.primal.to_bits(),
            r.dual.to_bits(),
            r.gap.to_bits(),
        ));
    }
    let fingerprint = w.iter().fold(0u64, |acc, v| acc.rotate_left(7) ^ v.to_bits());
    out.push_str(&format!("final_w_fingerprint {fingerprint:016x}\n"));

    std::fs::create_dir_all("target/determinism").unwrap();
    std::fs::write(format!("target/determinism/trace_l1_{seed}.csv"), out).unwrap();
}
