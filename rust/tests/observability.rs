//! Observability acceptance: round-phase spans, per-worker metrics
//! blocks, and the live `/metrics` endpoint must be *provably passive* —
//! a run with tracing, a JSONL span sink, and a live Prometheus scraper
//! attached is bit-identical to a bare run, in-process and over real UDS
//! sockets — and the `Metrics` wire kind must reconcile exactly in the
//! ledger and socket accounting.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cocoa::algorithms::Cocoa;
use cocoa::config::{
    AlgorithmSpec, Backend, DatasetSpec, ExperimentConfig, PartitionSpec, RunSpec, RuntimeSpec,
};
use cocoa::data::{cov_like, PartitionStrategy};
use cocoa::driver::MaxRounds;
use cocoa::loss::LossKind;
use cocoa::netsim::NetworkModel;
use cocoa::obs::{validate_span_jsonl, MetricsHub, MetricsServer, SpanSink};
use cocoa::regularizers::RegularizerKind;
use cocoa::solvers::SolverKind;
use cocoa::telemetry::Trace;
use cocoa::transport::net::run_worker_process;
use cocoa::transport::{MessageKind, NetConfig, ReconnectPolicy, TransportKind};
use cocoa::Trainer;

const N: usize = 120;
const D: usize = 8;
const NOISE: f64 = 0.1;
const SEED: u64 = 5;
const LAMBDA: f64 = 0.05;
const H: usize = 25;
const ROUNDS: u64 = 5;
const K: usize = 2;

/// Everything a trajectory is, bit for bit.
fn row_bits(tr: &Trace) -> Vec<(u64, u64, u64, u64, u64, u64, u64)> {
    tr.rows
        .iter()
        .map(|r| {
            (
                r.round,
                r.primal.to_bits(),
                r.dual.to_bits(),
                r.gap.to_bits(),
                r.sim_time_s.to_bits(),
                r.inner_steps,
                r.bytes_measured,
            )
        })
        .collect()
}

/// The bare twin every observed run is compared against: in-process,
/// counted, no tracing, no observers.
fn bare_run(data: &cocoa::data::Dataset) -> (Trace, Vec<u64>, cocoa::transport::Ledger) {
    let mut session = Trainer::on(data)
        .workers(K)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Counted)
        .build()
        .unwrap();
    assert!(!session.tracing(), "tracing must default off");
    let trace = session.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
    let w = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();
    session.shutdown();
    (trace, w, ledger)
}

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cocoa-obs-{}-{tag}.sock", std::process::id()))
}

fn worker_cfg(k: usize, listen: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetSpec::CovLike { n: N, d: D, noise: NOISE, seed: SEED },
        partition: PartitionSpec { k, strategy: PartitionStrategy::Contiguous, seed: 0 },
        algorithm: AlgorithmSpec::Cocoa { h: H, beta_k: 1.0, solver: SolverKind::Sdca },
        loss: LossKind::Hinge,
        lambda: LAMBDA,
        regularizer: RegularizerKind::default(),
        run: RunSpec {
            rounds: ROUNDS,
            target_gap: 0.0,
            target_subopt: 0.0,
            eval_every: 1,
            seed: SEED,
            backend: Backend::Native,
        },
        runtime: RuntimeSpec::default(),
        netsim: NetworkModel::free(),
        transport: TransportKind::Net(NetConfig::new(listen)),
        artifacts_dir: "artifacts".into(),
    }
}

fn spawn_workers(k: usize, listen: &str) -> Vec<thread::JoinHandle<()>> {
    (0..k)
        .map(|_| {
            let listen = listen.to_string();
            thread::spawn(move || {
                let cfg = worker_cfg(k, &listen);
                run_worker_process(
                    &cfg,
                    &listen,
                    &ReconnectPolicy { attempts: 60, backoff_s: 0.05 },
                )
                .unwrap();
            })
        })
        .collect()
}

/// One HTTP/1.0 request against the metrics UDS socket, with a short
/// connect retry (the listener thread polls at 20 ms).
fn scrape(path: &Path) -> String {
    let mut sock = None;
    for _ in 0..100 {
        match std::os::unix::net::UnixStream::connect(path) {
            Ok(s) => {
                sock = Some(s);
                break;
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
    let mut sock = sock.expect("metrics server never came up");
    sock.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    sock.flush().unwrap();
    let mut out = String::new();
    sock.read_to_string(&mut out).unwrap();
    out
}

/// Every non-comment line of a Prometheus text body is `name value` or
/// `name{labels} value` with a parseable value.
fn assert_prometheus_wellformed(body: &str) {
    assert!(!body.trim().is_empty(), "empty exposition");
    for line in body.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
        assert!(name.starts_with("cocoa_"), "foreign metric: {line}");
        assert!(
            value.parse::<f64>().is_ok() || matches!(value, "NaN" | "+Inf" | "-Inf"),
            "unparseable value: {line}"
        );
    }
}

/// In-process: tracing on, a span sink and a metrics hub attached — the
/// trajectory, final `w`, and *algorithm* ledger are bit-identical to the
/// bare run, and the always-on metrics blocks are ledgered byte-exactly
/// without being charged as algorithm communication.
#[test]
fn tracing_and_metrics_hub_are_passive_in_proc() {
    let data = cov_like(N, D, NOISE, SEED);
    let (bare_trace, bare_w, bare_ledger) = bare_run(&data);

    let mut session = Trainer::on(&data)
        .workers(K)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Counted)
        .build()
        .unwrap();
    session.set_tracing(true);
    let hub = MetricsHub::new();
    let mut hub_obs = hub.observer();
    let mut sink = SpanSink::new(Vec::new());
    let mut algo = Cocoa::new(H);
    let trace = {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.observe(&mut hub_obs).unwrap();
        driver.drain().unwrap()
    };
    let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();
    session.shutdown();

    assert_eq!(row_bits(&trace), row_bits(&bare_trace), "observed run diverged");
    assert_eq!(w, bare_w, "final w diverged");

    // metrics flow whether or not anyone listens: both runs ledger one
    // 56-byte block (16-byte header + 40-byte payload) per worker per
    // round, and neither charges it to the algorithm
    for l in [&ledger, &bare_ledger] {
        assert_eq!(l.msgs(MessageKind::Metrics), K as u64 * ROUNDS);
        assert_eq!(l.bytes(MessageKind::Metrics), 56 * K as u64 * ROUNDS);
        assert_eq!(l.total_bytes() - l.algorithm_bytes(), l.bytes(MessageKind::Metrics));
    }
    assert_eq!(ledger.algorithm_bytes(), bare_ledger.algorithm_bytes());

    // the spans streamed are structurally valid and cover all phases
    let text = String::from_utf8(sink.into_inner()).unwrap();
    let count = validate_span_jsonl(&text).unwrap();
    assert!(count > 0, "no spans streamed");
    for phase in ["broadcast", "local_solve", "reduce", "commit", "evaluate"] {
        assert!(text.contains(&format!("\"phase\": \"{phase}\"")), "missing {phase}:\n{text}");
    }
    assert!(text.contains("\"slot\": 1"), "no per-slot local_solve span");

    // the hub aggregated the same run
    let body = hub.render();
    assert_prometheus_wellformed(&body);
    assert!(body.contains(&format!("cocoa_rounds_total {ROUNDS}")), "{body}");
    assert!(body.contains("cocoa_solve_seconds_count{slot=\"1\"} 5"), "{body}");
    assert!(body.contains("cocoa_ledger_msgs_total{kind=\"metrics\"} 10"), "{body}");
}

/// UDS multi-process: a run with `--trace-out`-style span streaming, a
/// metrics hub, and a live scraper hammering `GET /metrics` throughout is
/// bit-identical to the bare in-process run; the Metrics kind reconciles
/// exactly in both the per-kind ledger and the raw socket byte totals.
#[test]
fn uds_run_with_live_scraper_is_bit_identical() {
    let data = cov_like(N, D, NOISE, SEED);
    let (bare_trace, bare_w, bare_ledger) = bare_run(&data);

    let sock = sock_path("run");
    let _ = std::fs::remove_file(&sock);
    let listen = format!("uds:{}", sock.display());
    let workers = spawn_workers(K, &listen);

    let scratch = std::env::temp_dir().join(format!("cocoa_obs_test_{}", std::process::id()));
    std::fs::create_dir_all(&scratch).unwrap();
    let jsonl = scratch.join("spans.jsonl");
    let msock = scratch.join("metrics.sock");
    let _ = std::fs::remove_file(&msock);

    let hub = MetricsHub::new();
    let server = MetricsServer::serve(&format!("uds:{}", msock.display()), hub.clone()).unwrap();

    // a scraper polling the endpoint for the whole run — passivity must
    // hold with live traffic on the metrics socket, not just with the
    // observers merely attached
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        let msock = msock.clone();
        thread::spawn(move || {
            let mut bodies = 0u32;
            while !stop.load(Ordering::Relaxed) {
                if let Ok(mut s) = std::os::unix::net::UnixStream::connect(&msock) {
                    let _ = s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n");
                    let _ = s.flush();
                    let mut out = String::new();
                    if s.read_to_string(&mut out).is_ok() && out.starts_with("HTTP/1.0 200") {
                        bodies += 1;
                    }
                }
                thread::sleep(Duration::from_millis(5));
            }
            bodies
        })
    };

    let mut session = Trainer::on(&data)
        .workers(K)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Net(NetConfig::new(&listen)))
        .build()
        .unwrap();
    session.set_tracing(true);
    let mut sink = SpanSink::create(&jsonl).unwrap();
    let mut hub_obs = hub.observer();
    let mut algo = Cocoa::new(H);
    let trace = {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.observe(&mut hub_obs).unwrap();
        driver.drain().unwrap()
    };
    let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();
    let stats = session.socket_stats().expect("net transport reports socket stats");

    // a guaranteed post-run scrape over the real socket
    let response = scrape(&msock);
    stop.store(true, Ordering::Relaxed);
    let live_bodies = scraper.join().unwrap();

    session.shutdown();
    for h in workers {
        h.join().unwrap();
    }
    server.shutdown();

    // passivity across the process boundary, with the scraper attached
    assert_eq!(row_bits(&trace), row_bits(&bare_trace), "UDS observed run diverged");
    assert_eq!(w, bare_w, "final w diverged");
    for kind in [
        MessageKind::Broadcast,
        MessageKind::Commit,
        MessageKind::DeltaW,
        MessageKind::EvalRequest,
        MessageKind::EvalReply,
        MessageKind::Metrics,
    ] {
        assert_eq!(ledger.bytes(kind), bare_ledger.bytes(kind), "{kind:?}");
        assert_eq!(ledger.msgs(kind), bare_ledger.msgs(kind), "{kind:?}");
    }

    // socket reconciliation with metrics frames in the stream
    assert_eq!(stats.payload_bytes(), ledger.total_bytes());
    assert_eq!(
        stats.sent_bytes + stats.recv_bytes,
        ledger.total_bytes() + stats.framing_bytes + stats.handshake_bytes,
        "socket bytes must reconcile with the ledger"
    );

    // the endpoint spoke valid HTTP + Prometheus text
    assert!(response.starts_with("HTTP/1.0 200 OK\r\n"), "{response}");
    let body = response.split("\r\n\r\n").nth(1).expect("response has a body");
    assert_prometheus_wellformed(body);
    assert!(body.contains(&format!("cocoa_rounds_total {ROUNDS}")), "{body}");
    assert!(body.contains("cocoa_solve_seconds_bucket{slot=\"0\""), "{body}");
    assert!(body.contains("cocoa_round_solve_seconds{stat=\"max\"}"), "{body}");
    assert!(body.contains("cocoa_solve_imbalance_ratio"), "{body}");
    assert!(body.contains("cocoa_ledger_bytes_total{kind=\"metrics\"}"), "{body}");
    assert!(body.contains("cocoa_socket_bytes_total{direction=\"sent\"}"), "{body}");
    // the scraper ran; mid-run hits are timing-dependent, the post-run
    // scrape above is the guaranteed one
    let _ = live_bodies;

    // the streamed span file validates and covers the leader phases
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let count = validate_span_jsonl(&text).unwrap();
    assert!(count > 0, "no spans streamed");
    assert!(text.contains("\"phase\": \"reduce\""), "{text}");
    assert!(text.contains("\"phase\": \"local_solve\", \"slot\": 1"), "{text}");

    let _ = std::fs::remove_dir_all(&scratch);
    let _ = std::fs::remove_file(&sock);
}
