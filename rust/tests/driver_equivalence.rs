//! Driver equivalence + event-stream invariants: the seeded golden suite
//! behind the step-wise Driver redesign.
//!
//! * `Session::run` (the batch compatibility wrapper) and a manual
//!   `Driver::step()`-until-stopped loop must produce bit-identical
//!   traces across losses (hinge / logistic / smoothed-L1 lasso) and
//!   K ∈ {1, 4}. Live runs are compared on every deterministic column
//!   (objectives, bytes, counters, stop reasons); the timing columns
//!   (`sim_time_s` / `compute_time_s`) fold in *measured* thread-CPU
//!   compute, so their bit-identity is proven through the record/replay
//!   transport, where every reply — compute times included — comes off
//!   one shared tape.
//! * The event stream obeys its grammar: exactly one terminal `Stopped`,
//!   strictly increasing rounds, evaluation cadence honored.
//! * A driver paused mid-run, checkpointed, restored into a fresh
//!   session, and resumed reaches the exact final gap of an
//!   uninterrupted run.
//! * A seeded driver run streams a JSONL artifact for the CI
//!   run-twice-and-diff determinism gate.

use std::sync::Arc;

use cocoa::coordinator::Checkpoint;
use cocoa::data::cov_like;
use cocoa::prelude::*;

struct Case {
    name: &'static str,
    loss: LossKind,
    regularizer: RegularizerKind,
    lambda: f64,
}

fn cases() -> Vec<Case> {
    vec![
        Case { name: "hinge", loss: LossKind::Hinge, regularizer: RegularizerKind::L2, lambda: 0.05 },
        Case {
            name: "logistic",
            loss: LossKind::Logistic,
            regularizer: RegularizerKind::L2,
            lambda: 0.05,
        },
        Case {
            name: "smoothed_l1",
            loss: LossKind::Squared,
            regularizer: RegularizerKind::L1 { epsilon: 0.5 },
            lambda: 0.1,
        },
    ]
}

fn build_session(case: &Case, k: usize, seed: u64) -> Session {
    let data = cov_like(96, 7, 0.1, seed);
    Trainer::on(&data)
        .workers(k)
        .loss(case.loss)
        .lambda(case.lambda)
        .regularizer(case.regularizer)
        .seed(seed)
        .label(case.name)
        .build()
        .unwrap()
}

/// Bit-exact comparison. `include_times` additionally pins the
/// `sim_time_s` / `compute_time_s` columns — only meaningful when both
/// traces come off the same replay tape (live runs measure real
/// thread-CPU compute, which is not reproducible).
fn assert_rows_bit_identical(a: &Trace, b: &Trace, context: &str, include_times: bool) {
    assert_eq!(a.rows.len(), b.rows.len(), "{context}: row counts differ");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        let ctx = format!("{context}, round {}", ra.round);
        assert_eq!(ra.round, rb.round, "{ctx}");
        if include_times {
            assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits(), "{ctx}: sim_time");
            assert_eq!(
                ra.compute_time_s.to_bits(),
                rb.compute_time_s.to_bits(),
                "{ctx}: compute"
            );
        }
        assert_eq!(ra.vectors, rb.vectors, "{ctx}: vectors");
        assert_eq!(ra.bytes_modeled, rb.bytes_modeled, "{ctx}: bytes_modeled");
        assert_eq!(ra.bytes_measured, rb.bytes_measured, "{ctx}: bytes_measured");
        assert_eq!(ra.inner_steps, rb.inner_steps, "{ctx}: inner_steps");
        assert_eq!(ra.primal.to_bits(), rb.primal.to_bits(), "{ctx}: primal");
        assert!(
            ra.dual.to_bits() == rb.dual.to_bits() || (ra.dual.is_nan() && rb.dual.is_nan()),
            "{ctx}: dual {} vs {}",
            ra.dual,
            rb.dual
        );
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "{ctx}: gap");
        assert!(
            ra.primal_subopt.to_bits() == rb.primal_subopt.to_bits()
                || (ra.primal_subopt.is_nan() && rb.primal_subopt.is_nan()),
            "{ctx}: subopt"
        );
        assert_eq!(ra.w_nnz, rb.w_nnz, "{ctx}: w_nnz");
        assert_eq!(ra.stop, rb.stop, "{ctx}: stop reason");
    }
}

/// The core golden equivalence: batch wrapper == manual step loop on
/// every deterministic column, for every loss family and K in {1, 4},
/// on an off-unit evaluation cadence (so the cadence logic itself is
/// exercised).
#[test]
fn stepwise_loop_matches_batch_run_bitwise() {
    for case in cases() {
        for k in [1usize, 4] {
            let mut session = build_session(&case, k, 11);
            let batch = session
                .run(&mut Cocoa::new(30), DriverSpec::new(MaxRounds::new(7)).eval_every(2))
                .unwrap();

            // same session, warm-started: drive the identical run by hand
            session.reset().unwrap();
            let mut sink = TraceSink::new();
            let mut algo = Cocoa::new(30);
            let mut driver = session
                .drive(&mut algo, DriverSpec::new(MaxRounds::new(7)).eval_every(2))
                .unwrap();
            driver.observe(&mut sink).unwrap();
            while !driver.step().unwrap().is_stopped() {}
            assert_eq!(driver.finished(), Some(StopReason::MaxRounds));
            drop(driver);
            let manual = sink.take().unwrap();

            let context = format!("{} K={k}", case.name);
            assert_rows_bit_identical(&batch, &manual, &context, false);
            // the final row carries the round cap as its stop reason, and
            // the cadence put rows at 0, 2, 4, 6, 7
            assert_eq!(manual.rows.last().unwrap().stop, StopReason::MaxRounds, "{context}");
            let rounds: Vec<u64> = manual.rows.iter().map(|r| r.round).collect();
            assert_eq!(rounds, vec![0, 2, 4, 6, 7], "{context}");
            session.shutdown();
        }
    }
}

/// Full bit-identity *including the timing columns*: record a batch run
/// to a transcript, then replay the tape through a manual
/// `Driver::step()` loop — every reply (measured compute times included)
/// is served from the tape, so the manual loop must reproduce the
/// recorded batch trace bit for bit, `sim_time_s` and all. This pins
/// that the step machine issues exactly the same message sequence as the
/// batch wrapper.
#[test]
fn replayed_step_loop_reproduces_batch_run_including_sim_time() {
    let all = cases();
    let case = &all[0];
    let data = cov_like(96, 7, 0.1, 17);
    let build = |transport: TransportKind| {
        Trainer::on(&data)
            .workers(3)
            .loss(case.loss)
            .lambda(case.lambda)
            .network(NetworkModel::ec2_like())
            .transport(transport)
            .seed(17)
            .label("driver_replay")
            .build()
            .unwrap()
    };
    let spec = || DriverSpec::new(MaxRounds::new(6)).eval_every(2);

    let mut recorder = build(TransportKind::Record);
    let recorded = recorder.run(&mut Cocoa::new(20), spec()).unwrap();
    let tape = Arc::new(recorder.take_transcript().expect("record keeps a tape"));
    recorder.shutdown();

    let mut replayer = build(TransportKind::Replay(tape));
    let mut sink = TraceSink::new();
    let mut algo = Cocoa::new(20);
    let mut driver = replayer.drive(&mut algo, spec()).unwrap();
    driver.observe(&mut sink).unwrap();
    while !driver.step().unwrap().is_stopped() {}
    drop(driver);
    let manual = sink.take().unwrap();
    replayer.shutdown();

    assert_rows_bit_identical(&recorded, &manual, "record vs replayed step loop", true);
}

/// Target-gap stopping: wrapper and manual loop agree on when to stop and
/// why, and the session's checkpoint remembers the reason.
#[test]
fn until_gap_equivalence_includes_stop_reason() {
    let all = cases();
    let case = &all[0];
    let mut session = build_session(case, 2, 7);
    let batch = session.run(&mut Cocoa::new(200), Budget::until_gap(0.05).max_rounds(500)).unwrap();
    assert_eq!(batch.rows.last().unwrap().stop, StopReason::Gap);
    assert_eq!(session.checkpoint().unwrap().stop, StopReason::Gap);

    session.reset().unwrap();
    let mut algo = Cocoa::new(200);
    let mut sink = TraceSink::new();
    // the composable spelling of the same budget
    let mut driver =
        session.drive(&mut algo, GapBelow::new(0.05).or(MaxRounds::new(500))).unwrap();
    driver.observe(&mut sink).unwrap();
    let manual = driver.drain().unwrap();
    assert_eq!(driver.finished(), Some(StopReason::Gap));
    drop(driver);

    assert_rows_bit_identical(&batch, &manual, "until_gap", false);
    // the observer saw exactly the drained trace
    assert_rows_bit_identical(&manual, &sink.take().unwrap(), "until_gap observer", true);
    assert_eq!(session.checkpoint().unwrap().stop, StopReason::Gap);
    session.shutdown();
}

/// Event-stream grammar: one terminal Stopped, strictly increasing
/// rounds, evaluation cadence honored (plus the forced final evaluation).
#[test]
fn event_stream_invariants_hold() {
    let all = cases();
    let case = &all[0];
    let mut session = build_session(case, 3, 5);
    let mut log = EventLog::new();
    let mut algo = Cocoa::new(20);
    let mut driver =
        session.drive(&mut algo, DriverSpec::new(MaxRounds::new(9)).eval_every(3)).unwrap();
    driver.observe(&mut log).unwrap();
    let trace = driver.drain().unwrap();
    drop(driver);
    let events = log.events();

    // exactly one Stopped, and it is the last event
    let stopped: Vec<usize> = events
        .iter()
        .enumerate()
        .filter_map(|(i, e)| e.is_stopped().then_some(i))
        .collect();
    assert_eq!(stopped, vec![events.len() - 1], "one terminal Stopped: {events:?}");

    // the first event is the round-0 snapshot
    assert!(
        matches!(events[0], RoundEvent::Evaluated { row } if row.round == 0),
        "{events:?}"
    );

    // RoundStarted rounds are exactly 1..=9, strictly increasing
    let started: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            RoundEvent::RoundStarted { round } => Some(*round),
            _ => None,
        })
        .collect();
    assert_eq!(started, (1..=9).collect::<Vec<u64>>());

    // Evaluated rounds honor the cadence: 0, 3, 6, 9 (9 is also the cap)
    let evaluated: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            RoundEvent::Evaluated { row } => Some(row.round),
            _ => None,
        })
        .collect();
    assert_eq!(evaluated, vec![0, 3, 6, 9]);
    assert_eq!(trace.rows.len(), evaluated.len());

    // each Evaluated (past the snapshot) follows its own RoundStarted
    for (i, e) in events.iter().enumerate() {
        if let RoundEvent::Evaluated { row } = e {
            if row.round > 0 {
                assert!(
                    events[..i]
                        .iter()
                        .any(|p| matches!(p, RoundEvent::RoundStarted { round } if *round == row.round)),
                    "Evaluated round {} before its RoundStarted",
                    row.round
                );
            }
        }
    }
    session.shutdown();
}

/// The acceptance scenario: pause a driver mid-run, checkpoint through a
/// save/load round-trip, restore into a *fresh* session, resume — and
/// land on the exact final gap of an uninterrupted run (every
/// deterministic column; timing columns fold in measured compute).
#[test]
fn pause_checkpoint_resume_matches_uninterrupted_run() {
    let all = cases();
    let case = &all[0];
    let total_rounds = 8u64;
    let pause_after = 3u64;

    let mut uninterrupted = build_session(case, 3, 21);
    let full = uninterrupted.run(&mut Cocoa::new(25), MaxRounds::new(total_rounds)).unwrap();
    let final_full = *full.rows.last().unwrap();
    uninterrupted.shutdown();

    // run the first `pause_after` rounds, then drop the driver mid-run
    let mut session = build_session(case, 3, 21);
    {
        let mut algo = Cocoa::new(25);
        let mut driver = session.drive(&mut algo, MaxRounds::new(total_rounds)).unwrap();
        let mut evals = 0u64;
        while evals <= pause_after {
            if let RoundEvent::Evaluated { .. } = driver.step().unwrap() {
                evals += 1; // snapshot + rounds 1..=pause_after
            }
        }
        assert_eq!(driver.rounds_completed(), pause_after);
    } // driver dropped: the session sits at a valid round boundary

    // checkpoint through the on-disk format
    let dir = std::env::temp_dir().join("cocoa_driver_equivalence");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pause.ckpt");
    session.checkpoint().unwrap().save(&path).unwrap();
    session.shutdown();
    let cp = Checkpoint::load(&path).unwrap();
    assert_eq!(cp.stop, StopReason::Running); // paused, not stopped

    // fresh session, restored state, resumed driver
    let mut resumed = build_session(case, 3, 21);
    resumed.restore(&cp).unwrap();
    let mut algo = Cocoa::new(25);
    let mut driver = resumed.drive(&mut algo, MaxRounds::new(total_rounds)).unwrap();
    driver.resume_from(pause_after).unwrap();
    let tail = driver.drain().unwrap();
    drop(driver);

    // the tail picks up at round pause_after + 1 (no duplicate snapshot)
    assert_eq!(tail.rows.first().unwrap().round, pause_after + 1);
    let final_tail = *tail.rows.last().unwrap();
    assert_eq!(final_tail.round, total_rounds);
    assert_eq!(final_tail.stop, StopReason::MaxRounds);
    assert_eq!(final_tail.gap.to_bits(), final_full.gap.to_bits(), "resumed gap diverged");
    assert_eq!(final_tail.primal.to_bits(), final_full.primal.to_bits());
    assert_eq!(final_tail.dual.to_bits(), final_full.dual.to_bits());
    assert_eq!(final_tail.vectors, final_full.vectors);
    assert_eq!(final_tail.bytes_modeled, final_full.bytes_modeled);
    assert_eq!(final_tail.inner_steps, final_full.inner_steps);
    assert_eq!(final_tail.w_nnz, final_full.w_nnz);
    resumed.shutdown();
}

/// The checkpoint-every-N policy: the driver captures on cadence, the
/// sink keeps the latest, and the latest is a usable resume point.
#[test]
fn checkpoint_observer_captures_on_cadence() {
    let all = cases();
    let case = &all[0];
    let mut session = build_session(case, 2, 13);
    let mut keeper = CheckpointSink::in_memory();
    let mut log = EventLog::new();
    let mut algo = Cocoa::new(15);
    let mut driver = session
        .drive(&mut algo, DriverSpec::new(MaxRounds::new(6)).checkpoint_every(3))
        .unwrap();
    driver.observe(&mut keeper).unwrap();
    driver.observe(&mut log).unwrap();
    driver.drain().unwrap();
    drop(driver);

    let checkpointed: Vec<u64> = log
        .events()
        .iter()
        .filter_map(|e| match e {
            RoundEvent::Checkpointed { round } => Some(*round),
            _ => None,
        })
        .collect();
    assert_eq!(checkpointed, vec![3, 6]);
    let latest = keeper.take_latest().expect("cadence captured a checkpoint");
    assert_eq!(latest.stats.rounds, 6);
    // round 6 is also the final round: the cadence checkpoint must carry
    // the true stop reason, not Running
    assert_eq!(latest.stop, StopReason::MaxRounds);
    // the captured state restores cleanly into the same-shape session
    session.restore(&latest).unwrap();
    session.shutdown();
}

/// A gap rule is dead on a primal-only (NaN-gap) method; without a round
/// cap the run could never end — the driver rejects the combination with
/// a typed error instead of spinning forever.
#[test]
fn unbounded_gap_rule_on_primal_only_method_is_rejected() {
    let all = cases();
    let case = &all[0];
    let mut session = build_session(case, 2, 19);
    let mut sgd = LocalSgd::new(10);
    let err = session
        .drive(&mut sgd, GapBelow::new(1e-3))
        .err()
        .expect("uncapped gap rule + primal-only method must not build a driver");
    assert!(matches!(err, Error::InvalidBudget { .. }), "{err}");
    assert!(err.to_string().contains("primal-only"), "{err}");
    // adding any round cap makes the run stoppable again
    let trace = session
        .run(&mut LocalSgd::new(10), GapBelow::new(1e-3).or(MaxRounds::new(3)))
        .unwrap();
    assert_eq!(trace.rows.last().unwrap().round, 3);
    assert!(trace.rows.last().unwrap().gap.is_nan());
    assert_eq!(trace.rows.last().unwrap().stop, StopReason::MaxRounds);
    // and dual methods may run uncapped on a live gap rule
    session.reset().unwrap();
    let trace = session.run(&mut Cocoa::new(200), GapBelow::new(0.05)).unwrap();
    assert_eq!(trace.rows.last().unwrap().stop, StopReason::Gap);
    session.shutdown();
}

/// Drop the two measured-time fields from a streamed JSONL row. The
/// timing columns fold in real thread-CPU measurements (not reproducible
/// across runs), so — exactly like the CSV fingerprints of the other two
/// determinism gates — the diffable artifact carries every
/// *deterministic* column and omits the clocks.
fn strip_timing(line: &str) -> String {
    match (line.find(", \"sim_time_s\""), line.find(", \"vectors\"")) {
        (Some(a), Some(b)) if a < b => format!("{}{}", &line[..a], &line[b..]),
        _ => line.to_string(),
    }
}

/// Seeded determinism artifact for CI: a driver run streaming through the
/// JSONL sink. ci.sh runs this twice with a pinned CARGO_TEST_SEED and
/// diffs the two files — any nondeterminism in the driver's event
/// machine, the transport byte accounting, or the sink encoding shows up
/// as a diff.
#[test]
fn seeded_driver_jsonl_artifact() {
    let seed: u64 = std::env::var("CARGO_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let data = cov_like(90, 7, 0.1, seed);
    let mut session = Trainer::on(&data)
        .workers(3)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .transport(TransportKind::Counted)
        .seed(seed)
        .label("driver_jsonl")
        .build()
        .unwrap();

    std::fs::create_dir_all("target/determinism").unwrap();
    let full_path = format!("target/determinism/driver_{seed}_full.jsonl");
    let mut jsonl = JsonlSink::create(&full_path).unwrap();
    let mut algo = Cocoa::new(25);
    let mut driver =
        session.drive(&mut algo, DriverSpec::new(MaxRounds::new(6)).eval_every(2)).unwrap();
    driver.observe(&mut jsonl).unwrap();
    let trace = driver.drain().unwrap();
    drop(driver);
    session.shutdown();

    let text = std::fs::read_to_string(&full_path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // meta line + one line per evaluated row (0, 2, 4, 6)
    assert_eq!(lines.len(), 1 + trace.rows.len(), "{text}");
    assert!(lines[0].contains("\"algorithm\": \"cocoa\""));
    assert!(lines[0].contains("\"dataset\": \"driver_jsonl\""));
    for (line, row) in lines[1..].iter().zip(&trace.rows) {
        assert_eq!(*line, row.to_json_object());
    }
    // measured bytes made it into the stream (counted transport)
    assert!(lines.last().unwrap().contains("\"bytes_measured\": "));

    // the CI-diffed artifact: every deterministic column, clocks stripped
    let diffable: String =
        lines.iter().map(|l| strip_timing(l) + "\n").collect::<Vec<_>>().concat();
    assert!(!diffable.contains("sim_time_s"), "{diffable}");
    assert!(diffable.contains("\"gap\": "));
    std::fs::write(format!("target/determinism/driver_{seed}.jsonl"), diffable).unwrap();
}
