//! Golden tests: ridge regression (squared loss) has a closed-form optimum
//! `(X^T X / n + lambda I) w* = X^T y / n` — CoCoA must reach it.
//!
//! * the exact block solver (`solvers/exact.rs`) on a single block lands
//!   on w* directly (the H -> inf limit),
//! * full CoCoA — both safe averaging and the CoCoA+ `adding(h)` regime —
//!   reaches `P*` within `Budget::until_subopt(1e-6)` for K in {1, 2, 4}.

use cocoa::data::{cov_like, Dataset};
use cocoa::loss::Squared;
use cocoa::objective;
use cocoa::prelude::*;
use cocoa::solvers::{Block, ExactBlockSolver, LocalDualMethod};
use cocoa::util::Rng;

/// Solve the ridge normal equations by Gaussian elimination with partial
/// pivoting (d is tiny here).
fn closed_form_ridge(data: &Dataset, lambda: f64) -> Vec<f64> {
    let (n, d) = (data.n(), data.d());
    let rows: Vec<Vec<f64>> = (0..n).map(|i| data.features.row_dense(i)).collect();
    let mut a = vec![vec![0.0f64; d]; d];
    let mut b = vec![0.0f64; d];
    for (x, &y) in rows.iter().zip(&data.labels) {
        for j in 0..d {
            b[j] += x[j] * y / n as f64;
            for l in 0..d {
                a[j][l] += x[j] * x[l] / n as f64;
            }
        }
    }
    for j in 0..d {
        a[j][j] += lambda;
    }
    // forward elimination
    for col in 0..d {
        let pivot_row = (col..d)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        a.swap(col, pivot_row);
        b.swap(col, pivot_row);
        let pivot = a[col][col];
        assert!(pivot.abs() > 1e-12, "singular ridge system");
        let above = a[col].clone();
        for row in (col + 1)..d {
            let factor = a[row][col] / pivot;
            for l in col..d {
                a[row][l] -= factor * above[l];
            }
            b[row] -= factor * b[col];
        }
    }
    // back substitution
    let mut w = vec![0.0f64; d];
    for col in (0..d).rev() {
        let mut s = b[col];
        for l in (col + 1)..d {
            s -= a[col][l] * w[l];
        }
        w[col] = s / a[col][col];
    }
    w
}

fn tiny_ridge() -> (Dataset, f64, Vec<f64>, f64) {
    let data = cov_like(24, 4, 0.2, 5);
    let lambda = 0.1;
    let w_star = closed_form_ridge(&data, lambda);
    let p_star = objective::primal(&data, &w_star, lambda, &Squared);
    (data, lambda, w_star, p_star)
}

#[test]
fn closed_form_is_a_stationary_point() {
    // sanity on the golden value itself: perturbing w* in any coordinate
    // direction cannot decrease the primal
    let (data, lambda, w_star, p_star) = tiny_ridge();
    for j in 0..w_star.len() {
        for eps in [1e-4, -1e-4] {
            let mut w = w_star.clone();
            w[j] += eps;
            let p = objective::primal(&data, &w, lambda, &Squared);
            assert!(p >= p_star - 1e-12, "w* not optimal along coordinate {j}");
        }
    }
}

#[test]
fn exact_block_solver_reaches_closed_form() {
    let (data, lambda, w_star, p_star) = tiny_ridge();
    let n = data.n();
    let block = Block::new(data.clone(), lambda * n as f64);
    let solver = ExactBlockSolver::default();
    let mut rng = Rng::seed_from_u64(1);
    let up = solver.local_update(
        &block,
        &Squared,
        &vec![0.0; n],
        &vec![0.0; data.d()],
        0,
        &mut rng,
    );
    let p = objective::primal(&data, &up.dw, lambda, &Squared);
    assert!(
        p - p_star <= 1e-6,
        "exact solver missed the ridge optimum: P - P* = {}",
        p - p_star
    );
    for (j, (a, b)) in up.dw.iter().zip(&w_star).enumerate() {
        assert!((a - b).abs() < 1e-3, "w[{j}]: exact {a} vs closed form {b}");
    }
}

#[test]
fn cocoa_averaging_and_adding_reach_closed_form_for_k_1_2_4() {
    let (data, lambda, _w_star, p_star) = tiny_ridge();
    for k in [1usize, 2, 4] {
        for adding in [false, true] {
            let mut sess = Trainer::on(&data)
                .workers(k)
                .loss(LossKind::Squared)
                .lambda(lambda)
                .seed(3)
                .label("ridge")
                .build()
                .unwrap();
            sess.set_reference_optimum(Some(p_star));
            let mut algo = if adding { Cocoa::adding(12) } else { Cocoa::new(12) };
            let budget = Budget::until_subopt(1e-6).max_rounds(20_000);
            let trace = sess.run(&mut algo, budget).unwrap();
            let last = trace.rows.last().unwrap();
            assert!(
                last.primal_subopt <= 1e-6,
                "K={k} adding={adding}: stalled at subopt {} after {} rounds",
                last.primal_subopt,
                last.round
            );
            sess.shutdown();
        }
    }
}
