//! Coordinator integration: the leader/worker runtime against the paper's
//! Algorithm-1 semantics, across partitions, losses, K, and backends —
//! driven through the `Session` facade's low-level dispatch/commit hatch.

use cocoa::coordinator::LocalWork;
use cocoa::data::{cov_like, orthogonal_blocks, rcv1_like};
use cocoa::objective;
use cocoa::prelude::*;

fn build(data: &Dataset, k: usize, loss: LossKind, lambda: f64, seed: u64) -> Session {
    Trainer::on(data)
        .workers(k)
        .loss(loss)
        .lambda(lambda)
        .network(NetworkModel::free())
        .seed(seed)
        .build()
        .unwrap()
}

/// Run T CoCoA rounds by hand and return the gap trajectory.
fn run_cocoa(session: &mut Session, t: usize, h: usize) -> Vec<f64> {
    let k = session.k() as f64;
    let mut gaps = vec![session.evaluate().unwrap().gap];
    for _ in 0..t {
        let replies = session.dispatch(|_| LocalWork::DualRound { h }).unwrap();
        session.commit(&replies, 1.0 / k).unwrap();
        gaps.push(session.evaluate().unwrap().gap);
    }
    gaps
}

#[test]
fn converges_on_every_loss() {
    let data = cov_like(120, 8, 0.1, 1);
    for loss in [
        LossKind::Hinge,
        LossKind::SmoothedHinge { gamma: 0.5 },
        LossKind::Squared,
        LossKind::Logistic,
    ] {
        let mut session = build(&data, 3, loss, 0.05, 2);
        let gaps = run_cocoa(&mut session, 12, 80);
        assert!(
            gaps.last().unwrap() < &(gaps[0] * 0.2),
            "{loss:?}: gap {} -> {}",
            gaps[0],
            gaps.last().unwrap()
        );
        for g in &gaps {
            assert!(*g >= -1e-9, "{loss:?}: negative gap {g}");
        }
        session.shutdown();
    }
}

#[test]
fn converges_on_sparse_data() {
    let data = rcv1_like(300, 500, 6, 0.1, 3);
    let mut session = build(&data, 4, LossKind::Hinge, 0.02, 4);
    let gaps = run_cocoa(&mut session, 15, 150);
    assert!(gaps.last().unwrap() < &(gaps[0] * 0.3));
    session.shutdown();
}

#[test]
fn k_equals_one_matches_serial_sdca_rate() {
    // K = 1 CoCoA with beta = 1 is exactly serial SDCA: the gap after the
    // same number of total steps must match a direct serial run closely.
    let data = cov_like(100, 6, 0.1, 5);
    let mut session = build(&data, 1, LossKind::Hinge, 0.05, 6);
    let gaps = run_cocoa(&mut session, 5, 100);
    assert!(gaps.last().unwrap() < &0.25, "K=1 run too slow: {gaps:?}");
    session.shutdown();
}

#[test]
fn partition_strategies_all_converge() {
    let data = cov_like(90, 6, 0.1, 7);
    for strategy in [
        PartitionStrategy::Contiguous,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::Random,
    ] {
        let mut session = Trainer::on(&data)
            .workers(3)
            .partition_strategy(strategy)
            .partition_seed(11)
            .loss(LossKind::Hinge)
            .lambda(0.05)
            .network(NetworkModel::free())
            .seed(8)
            .build()
            .unwrap();
        let gaps = run_cocoa(&mut session, 10, 60);
        assert!(
            gaps.last().unwrap() < &(gaps[0] * 0.3),
            "{strategy:?} failed to converge"
        );
        session.shutdown();
    }
}

#[test]
fn orthogonal_data_converges_like_k1() {
    // Lemma 3: with orthogonal blocks sigma_min = 0 and the K-machine rate
    // matches the ideal; with exact local solves one round is optimal.
    let k = 3;
    let data = orthogonal_blocks(k, 12, 4, 9);
    let mut session = Trainer::on(&data)
        .workers(k)
        .loss(LossKind::SmoothedHinge { gamma: 1.0 })
        .lambda(0.05)
        .solver(SolverKind::Exact)
        .network(NetworkModel::free())
        .seed(10)
        .build()
        .unwrap();
    // exact local solve + independent blocks: after one full round with
    // scale 1 (note: NOT 1/K, valid only because the blocks are orthogonal)
    let replies = session.dispatch(|_| LocalWork::ExactSolve).unwrap();
    session.commit(&replies, 1.0).unwrap();
    let ev = session.evaluate().unwrap();
    assert!(ev.gap < 1e-4, "orthogonal one-round gap = {}", ev.gap);
    session.shutdown();
}

#[test]
fn comm_accounting_is_exact() {
    let data = cov_like(60, 5, 0.1, 11);
    let mut session = build(&data, 4, LossKind::Hinge, 0.1, 12);
    for t in 1..=7 {
        let replies = session.dispatch(|_| LocalWork::DualRound { h: 5 }).unwrap();
        session.commit(&replies, 0.25).unwrap();
        assert_eq!(session.stats().rounds, t);
        assert_eq!(session.stats().vectors, 8 * t); // 2K per round
        assert_eq!(session.stats().inner_steps, 20 * t); // K*h
        assert_eq!(
            session.stats().bytes_modeled,
            session.stats().vectors * (5 * 8) as u64
        );
        // the inproc default measures nothing
        assert_eq!(session.stats().bytes_measured, 0);
    }
    session.shutdown();
}

#[test]
fn leader_w_equals_a_alpha_throughout() {
    // Reconstruct the implied global alpha by running the same seeds
    // through the evaluation identity: P(w) - D(alpha) >= 0 with equality
    // structure maintained requires w == A alpha exactly; a drift would
    // show up as a persistent gap floor or negative gap.
    let data = cov_like(80, 6, 0.1, 13);
    let mut session = build(&data, 2, LossKind::Squared, 0.1, 14);
    for _ in 0..10 {
        let replies = session.dispatch(|_| LocalWork::DualRound { h: 40 }).unwrap();
        session.commit(&replies, 0.5).unwrap();
        let ev = session.evaluate().unwrap();
        assert!(ev.gap >= -1e-9, "negative gap: w drifted from A alpha");
    }
    // squared loss: near-optimum the gap closes fully, which is impossible
    // if w and alpha were inconsistent
    let final_gap = session.evaluate().unwrap().gap;
    assert!(final_gap < 0.05, "gap floor {final_gap} suggests drift");
    session.shutdown();
}

#[test]
fn mixed_work_rounds_are_rejected_cleanly() {
    // dispatching a new dual round with an uncommitted pending update must
    // surface a typed Runtime error, not silently corrupt state
    let data = cov_like(40, 4, 0.1, 15);
    let mut session = build(&data, 2, LossKind::Hinge, 0.1, 16);
    let _replies = session.dispatch(|_| LocalWork::DualRound { h: 5 }).unwrap();
    // no commit here — next dispatch must fail
    let err = session.dispatch(|_| LocalWork::DualRound { h: 5 });
    assert!(matches!(err, Err(Error::Runtime { .. })));
}

#[test]
fn eval_consistent_with_direct_objective() {
    // distributed evaluation (partial sums over workers) must equal the
    // single-machine objective at the same (w, alpha)
    let data = cov_like(70, 5, 0.1, 17);
    let mut session = build(&data, 3, LossKind::Hinge, 0.08, 18);
    let replies = session.dispatch(|_| LocalWork::DualRound { h: 30 }).unwrap();
    session.commit(&replies, 1.0 / 3.0).unwrap();
    let ev = session.evaluate().unwrap();
    let p_direct = objective::primal(&data, session.w(), 0.08, &cocoa::loss::Hinge);
    assert!((ev.primal - p_direct).abs() < 1e-10);
    session.shutdown();
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    // Train 4 rounds, checkpoint, train 4 more; separately restore the
    // checkpoint into a FRESH session and train the same 4 rounds: the
    // native backend must produce bit-identical w (alpha + rng state are
    // both captured).
    let data = cov_like(90, 7, 0.1, 41);
    let run_rounds = |session: &mut Session, t: usize| {
        for _ in 0..t {
            let replies = session.dispatch(|_| LocalWork::DualRound { h: 30 }).unwrap();
            session.commit(&replies, 1.0 / 3.0).unwrap();
        }
    };

    let mut original = build(&data, 3, LossKind::Hinge, 0.05, 42);
    run_rounds(&mut original, 4);
    let cp = original.checkpoint().unwrap();
    run_rounds(&mut original, 4);
    let w_reference = original.w().to_vec();
    original.shutdown();

    // persist + reload through the file format
    let path = std::env::temp_dir().join("cocoa_resume_test/state.ckpt");
    cp.save(&path).unwrap();
    let reloaded = cocoa::coordinator::Checkpoint::load(&path).unwrap();
    assert_eq!(cp, reloaded);

    // a fresh session with a DIFFERENT seed — restore overwrites it all
    let mut resumed = build(&data, 3, LossKind::Hinge, 0.05, 999);
    resumed.restore(&reloaded).unwrap();
    run_rounds(&mut resumed, 4);
    assert_eq!(resumed.w(), w_reference.as_slice(), "resumed trajectory diverged");
    assert_eq!(resumed.stats().rounds, 8);
    resumed.shutdown();
}

#[test]
fn restore_rejects_shape_mismatch() {
    let data = cov_like(40, 5, 0.1, 43);
    let mut a = build(&data, 2, LossKind::Hinge, 0.05, 44);
    let cp = a.checkpoint().unwrap();
    a.shutdown();
    let other = cov_like(40, 5, 0.1, 43);
    let mut b = build(&other, 4, LossKind::Hinge, 0.05, 45); // K mismatch
    assert!(b.restore(&cp).is_err());
    b.shutdown();
}

#[test]
fn stragglers_inflate_simulated_time_only() {
    // A straggling worker slows the simulated barrier but must not change
    // the optimization trajectory (bulk-synchronous semantics).
    let data = cov_like(80, 6, 0.1, 61);
    let run_with = |stragglers: StragglerModel| {
        let mut session = build(&data, 4, LossKind::Hinge, 0.05, 62);
        session.set_stragglers(stragglers);
        for _ in 0..6 {
            let replies = session.dispatch(|_| LocalWork::DualRound { h: 40 }).unwrap();
            session.commit(&replies, 0.25).unwrap();
        }
        let gap = session.evaluate().unwrap().gap;
        let sim = session.stats().sim_time_s;
        session.shutdown();
        (gap, sim)
    };
    let (gap_clean, sim_clean) = run_with(StragglerModel::none());
    let (gap_slow, sim_slow) = run_with(StragglerModel {
        probability: 1.0,
        slowdown: 20.0,
        seed: 7,
    });
    assert!((gap_clean - gap_slow).abs() < 1e-12, "trajectory changed");
    assert!(
        sim_slow > sim_clean,
        "stragglers must cost simulated time: {sim_slow} !> {sim_clean}"
    );
}
