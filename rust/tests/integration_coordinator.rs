//! Coordinator integration: the leader/worker runtime against the paper's
//! Algorithm-1 semantics, across partitions, losses, K, and backends.

use cocoa::config::Backend;
use cocoa::coordinator::{Cluster, LocalWork};
use cocoa::data::{cov_like, orthogonal_blocks, rcv1_like, Partition, PartitionStrategy};
use cocoa::loss::LossKind;
use cocoa::netsim::NetworkModel;
use cocoa::objective;
use cocoa::solvers::SolverKind;

fn build(
    data: &cocoa::data::Dataset,
    k: usize,
    loss: LossKind,
    lambda: f64,
    seed: u64,
) -> Cluster {
    let part = Partition::new(PartitionStrategy::Contiguous, data.n(), k, 0);
    Cluster::build(
        &data.clone(),
        &part,
        loss,
        lambda,
        SolverKind::Sdca,
        Backend::Native,
        "artifacts",
        NetworkModel::free(),
        seed,
    )
    .unwrap()
}

/// Run T CoCoA rounds and return the gap trajectory.
fn run_cocoa(cluster: &mut Cluster, t: usize, h: usize) -> Vec<f64> {
    let k = cluster.k as f64;
    let mut gaps = vec![cluster.evaluate().unwrap().gap];
    for _ in 0..t {
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h }).unwrap();
        cluster.commit(&replies, 1.0 / k).unwrap();
        gaps.push(cluster.evaluate().unwrap().gap);
    }
    gaps
}

#[test]
fn converges_on_every_loss() {
    let data = cov_like(120, 8, 0.1, 1);
    for loss in [
        LossKind::Hinge,
        LossKind::SmoothedHinge { gamma: 0.5 },
        LossKind::Squared,
        LossKind::Logistic,
    ] {
        let mut cluster = build(&data, 3, loss, 0.05, 2);
        let gaps = run_cocoa(&mut cluster, 12, 80);
        assert!(
            gaps.last().unwrap() < &(gaps[0] * 0.2),
            "{loss:?}: gap {} -> {}",
            gaps[0],
            gaps.last().unwrap()
        );
        for g in &gaps {
            assert!(*g >= -1e-9, "{loss:?}: negative gap {g}");
        }
        cluster.shutdown();
    }
}

#[test]
fn converges_on_sparse_data() {
    let data = rcv1_like(300, 500, 6, 0.1, 3);
    let mut cluster = build(&data, 4, LossKind::Hinge, 0.02, 4);
    let gaps = run_cocoa(&mut cluster, 15, 150);
    assert!(gaps.last().unwrap() < &(gaps[0] * 0.3));
    cluster.shutdown();
}

#[test]
fn k_equals_one_matches_serial_sdca_rate() {
    // K = 1 CoCoA with beta = 1 is exactly serial SDCA: the gap after the
    // same number of total steps must match a direct serial run closely.
    let data = cov_like(100, 6, 0.1, 5);
    let mut cluster = build(&data, 1, LossKind::Hinge, 0.05, 6);
    let gaps = run_cocoa(&mut cluster, 5, 100);
    assert!(gaps.last().unwrap() < &0.25, "K=1 run too slow: {gaps:?}");
    cluster.shutdown();
}

#[test]
fn partition_strategies_all_converge() {
    let data = cov_like(90, 6, 0.1, 7);
    for strategy in [
        PartitionStrategy::Contiguous,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::Random,
    ] {
        let part = Partition::new(strategy, 90, 3, 11);
        let mut cluster = Cluster::build(
            &data,
            &part,
            LossKind::Hinge,
            0.05,
            SolverKind::Sdca,
            Backend::Native,
            "artifacts",
            NetworkModel::free(),
            8,
        )
        .unwrap();
        let gaps = run_cocoa(&mut cluster, 10, 60);
        assert!(
            gaps.last().unwrap() < &(gaps[0] * 0.3),
            "{strategy:?} failed to converge"
        );
        cluster.shutdown();
    }
}

#[test]
fn orthogonal_data_converges_like_k1() {
    // Lemma 3: with orthogonal blocks sigma_min = 0 and the K-machine rate
    // matches the ideal; with exact local solves one round is optimal.
    let k = 3;
    let data = orthogonal_blocks(k, 12, 4, 9);
    let part = Partition::new(PartitionStrategy::Contiguous, data.n(), k, 0);
    let mut cluster = Cluster::build(
        &data,
        &part,
        LossKind::SmoothedHinge { gamma: 1.0 },
        0.05,
        SolverKind::Exact,
        Backend::Native,
        "artifacts",
        NetworkModel::free(),
        10,
    )
    .unwrap();
    // exact local solve + independent blocks: after one full round with
    // scale 1 (note: NOT 1/K, valid only because the blocks are orthogonal)
    let replies = cluster.dispatch(|_| LocalWork::ExactSolve).unwrap();
    cluster.commit(&replies, 1.0).unwrap();
    let ev = cluster.evaluate().unwrap();
    assert!(ev.gap < 1e-4, "orthogonal one-round gap = {}", ev.gap);
    cluster.shutdown();
}

#[test]
fn comm_accounting_is_exact() {
    let data = cov_like(60, 5, 0.1, 11);
    let mut cluster = build(&data, 4, LossKind::Hinge, 0.1, 12);
    for t in 1..=7 {
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 5 }).unwrap();
        cluster.commit(&replies, 0.25).unwrap();
        assert_eq!(cluster.stats.rounds, t);
        assert_eq!(cluster.stats.vectors, 8 * t); // 2K per round
        assert_eq!(cluster.stats.inner_steps, 20 * t); // K*h
        assert_eq!(
            cluster.stats.bytes,
            cluster.stats.vectors * (5 * 8) as u64
        );
    }
    cluster.shutdown();
}

#[test]
fn leader_w_equals_a_alpha_throughout() {
    // Reconstruct the implied global alpha by running the same seeds
    // through the evaluation identity: P(w) - D(alpha) >= 0 with equality
    // structure maintained requires w == A alpha exactly; a drift would
    // show up as a persistent gap floor or negative gap.
    let data = cov_like(80, 6, 0.1, 13);
    let mut cluster = build(&data, 2, LossKind::Squared, 0.1, 14);
    for _ in 0..10 {
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 40 }).unwrap();
        cluster.commit(&replies, 0.5).unwrap();
        let ev = cluster.evaluate().unwrap();
        assert!(ev.gap >= -1e-9, "negative gap: w drifted from A alpha");
    }
    // squared loss: near-optimum the gap closes fully, which is impossible
    // if w and alpha were inconsistent
    let final_gap = cluster.evaluate().unwrap().gap;
    assert!(final_gap < 0.05, "gap floor {final_gap} suggests drift");
    cluster.shutdown();
}

#[test]
fn mixed_work_rounds_are_rejected_cleanly() {
    // dispatching a new dual round with an uncommitted pending update must
    // surface a Fatal error, not silently corrupt state
    let data = cov_like(40, 4, 0.1, 15);
    let mut cluster = build(&data, 2, LossKind::Hinge, 0.1, 16);
    let _replies = cluster.dispatch(|_| LocalWork::DualRound { h: 5 }).unwrap();
    // no commit here — next dispatch must fail
    let err = cluster.dispatch(|_| LocalWork::DualRound { h: 5 });
    assert!(err.is_err());
}

#[test]
fn eval_consistent_with_direct_objective() {
    // distributed evaluation (partial sums over workers) must equal the
    // single-machine objective at the same (w, alpha)
    let data = cov_like(70, 5, 0.1, 17);
    let mut cluster = build(&data, 3, LossKind::Hinge, 0.08, 18);
    let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 30 }).unwrap();
    cluster.commit(&replies, 1.0 / 3.0).unwrap();
    let ev = cluster.evaluate().unwrap();
    let p_direct = objective::primal(&data, &cluster.w, 0.08, &cocoa::loss::Hinge);
    assert!((ev.primal - p_direct).abs() < 1e-10);
    cluster.shutdown();
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    // Train 4 rounds, checkpoint, train 4 more; separately restore the
    // checkpoint into a FRESH cluster and train the same 4 rounds: the
    // native backend must produce bit-identical w (alpha + rng state are
    // both captured).
    let data = cov_like(90, 7, 0.1, 41);
    let run_rounds = |cluster: &mut Cluster, t: usize| {
        for _ in 0..t {
            let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 30 }).unwrap();
            cluster.commit(&replies, 1.0 / 3.0).unwrap();
        }
    };

    let mut original = build(&data, 3, LossKind::Hinge, 0.05, 42);
    run_rounds(&mut original, 4);
    let cp = original.checkpoint().unwrap();
    run_rounds(&mut original, 4);
    let w_reference = original.w.clone();
    original.shutdown();

    // persist + reload through the file format
    let path = std::env::temp_dir().join("cocoa_resume_test/state.ckpt");
    cp.save(&path).unwrap();
    let reloaded = cocoa::coordinator::Checkpoint::load(&path).unwrap();
    assert_eq!(cp, reloaded);

    // a fresh cluster with a DIFFERENT seed — restore overwrites it all
    let mut resumed = build(&data, 3, LossKind::Hinge, 0.05, 999);
    resumed.restore(&reloaded).unwrap();
    run_rounds(&mut resumed, 4);
    assert_eq!(resumed.w, w_reference, "resumed trajectory diverged");
    assert_eq!(resumed.stats.rounds, 8);
    resumed.shutdown();
}

#[test]
fn restore_rejects_shape_mismatch() {
    let data = cov_like(40, 5, 0.1, 43);
    let mut a = build(&data, 2, LossKind::Hinge, 0.05, 44);
    let cp = a.checkpoint().unwrap();
    a.shutdown();
    let other = cov_like(40, 5, 0.1, 43);
    let mut b = build(&other, 4, LossKind::Hinge, 0.05, 45); // K mismatch
    assert!(b.restore(&cp).is_err());
    b.shutdown();
}

#[test]
fn stragglers_inflate_simulated_time_only() {
    // A straggling worker slows the simulated barrier but must not change
    // the optimization trajectory (bulk-synchronous semantics).
    let data = cov_like(80, 6, 0.1, 61);
    let run_with = |stragglers: cocoa::netsim::StragglerModel| {
        let mut cluster = build(&data, 4, LossKind::Hinge, 0.05, 62);
        cluster.stragglers = stragglers;
        for _ in 0..6 {
            let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 40 }).unwrap();
            cluster.commit(&replies, 0.25).unwrap();
        }
        let gap = cluster.evaluate().unwrap().gap;
        let sim = cluster.stats.sim_time_s;
        cluster.shutdown();
        (gap, sim)
    };
    let (gap_clean, sim_clean) = run_with(cocoa::netsim::StragglerModel::none());
    let (gap_slow, sim_slow) = run_with(cocoa::netsim::StragglerModel {
        probability: 1.0,
        slowdown: 20.0,
        seed: 7,
    });
    assert!((gap_clean - gap_slow).abs() < 1e-12, "trajectory changed");
    assert!(
        sim_slow > sim_clean,
        "stragglers must cost simulated time: {sim_slow} !> {sim_clean}"
    );
}
