//! Integration tests for the PJRT runtime: loading the AOT JAX/Pallas
//! artifacts, executing them, and checking numerical parity with the
//! native rust solver. Requires `make artifacts` to have run.

use cocoa::config::Backend;
use cocoa::coordinator::LocalWork;
use cocoa::data::cov_like;
use cocoa::loss::{Hinge, LossKind};
use cocoa::netsim::NetworkModel;
use cocoa::objective;
use cocoa::runtime::{Engine, Manifest, PjrtLocalSdca};
use cocoa::solvers::{Block, LocalDualMethod, LocalSdca, Sampling};
use cocoa::util::Rng;
use cocoa::Trainer;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.tsv").exists()
}

/// Build a block whose shape matches the small test artifact (128 x 16).
fn artifact_block(seed: u64) -> Block {
    let data = cov_like(128, 16, 0.1, seed);
    Block::new(data, 0.01 * 128.0)
}

#[test]
fn manifest_lists_test_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = Manifest::load(&artifacts_dir()).unwrap();
    for loss in ["hinge", "smoothed_hinge", "squared", "logistic"] {
        assert!(
            m.find("local_sdca", loss, 128, 16).is_some(),
            "missing local_sdca {loss} 128x16"
        );
    }
    assert!(m.find("eval_objectives", "hinge", 128, 16).is_some());
}

#[test]
fn pjrt_matches_native_solver() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::start(artifacts_dir()).unwrap();
    let block = artifact_block(1);
    let pjrt = PjrtLocalSdca::bind(engine.handle(), 0, &block, "hinge", 1.0).unwrap();

    let alpha = vec![0.0; 128];
    let w = vec![0.0; 16];
    let h = 200;
    // identical ChaCha-free Rng streams => identical coordinate sequences
    let up_pjrt = pjrt.local_update(&block, &Hinge, &alpha, &w, h, &mut Rng::seed_from_u64(9));
    let native = LocalSdca::new(Sampling::WithReplacement);
    let up_native =
        native.local_update(&block, &Hinge, &alpha, &w, h, &mut Rng::seed_from_u64(9));

    for (a, b) in up_pjrt.dalpha.iter().zip(&up_native.dalpha) {
        assert!((a - b).abs() < 5e-3, "dalpha mismatch: {a} vs {b}");
    }
    for (a, b) in up_pjrt.dw.iter().zip(&up_native.dw) {
        assert!((a - b).abs() < 5e-3, "dw mismatch: {a} vs {b}");
    }
    // and the invariant dw = A dalpha holds for the f32 path too
    let mut expect = vec![0.0; 16];
    for (i, &da) in up_pjrt.dalpha.iter().enumerate() {
        block
            .data
            .features
            .add_row_scaled(i, da / block.lambda_n, &mut expect);
    }
    for (a, b) in expect.iter().zip(&up_pjrt.dw) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn pjrt_chunks_h_beyond_capacity() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // cap for the 128x16 artifact is 256; H = 700 forces 3 chunks
    let engine = Engine::start(artifacts_dir()).unwrap();
    let block = artifact_block(2);
    let pjrt = PjrtLocalSdca::bind(engine.handle(), 0, &block, "hinge", 1.0).unwrap();
    let up = pjrt.local_update(
        &block,
        &Hinge,
        &vec![0.0; 128],
        &vec![0.0; 16],
        700,
        &mut Rng::seed_from_u64(11),
    );
    let native = LocalSdca::new(Sampling::WithReplacement);
    let up_n = native.local_update(
        &block,
        &Hinge,
        &vec![0.0; 128],
        &vec![0.0; 16],
        700,
        &mut Rng::seed_from_u64(11),
    );
    for (a, b) in up.dw.iter().zip(&up_n.dw) {
        assert!((a - b).abs() < 1e-2, "chunked dw mismatch: {a} vs {b}");
    }
}

#[test]
fn pjrt_eval_matches_native_objective() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::start(artifacts_dir()).unwrap();
    let block = artifact_block(3);
    let handle = engine.handle();
    // register
    let n_k = 128;
    let d = 16;
    let mut x = Vec::with_capacity(n_k * d);
    for i in 0..n_k {
        for v in block.data.features.row_dense(i) {
            x.push(v as f32);
        }
    }
    let y: Vec<f32> = block.data.labels.iter().map(|&v| v as f32).collect();
    let norms: Vec<f32> = (0..n_k).map(|i| block.data.norm_sq(i) as f32).collect();
    handle.register_block(7, x, y, norms, n_k, d).unwrap();

    let alpha: Vec<f32> = block.data.labels.iter().map(|&y| 0.4 * y as f32).collect();
    let w: Vec<f32> = (0..d).map(|j| 0.01 * j as f32).collect();
    let out = handle.eval(7, "hinge", alpha.clone(), w.clone(), 1.0).unwrap();

    let alpha64: Vec<f64> = alpha.iter().map(|&v| v as f64).collect();
    let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let ls = objective::block_loss_sum(&block.data, &w64, &Hinge);
    let cs = objective::block_conj_sum(&block.data, &alpha64, &Hinge);
    assert!((out.loss_sum - ls).abs() / ls.max(1.0) < 1e-3, "{} vs {ls}", out.loss_sum);
    assert!((out.conj_sum - cs).abs() / cs.abs().max(1.0) < 1e-3, "{} vs {cs}", out.conj_sum);
}

#[test]
fn missing_artifact_shape_is_a_clean_error() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::start(artifacts_dir()).unwrap();
    let handle = engine.handle();
    handle
        .register_block(0, vec![0.0; 10 * 3], vec![1.0; 10], vec![0.0; 10], 10, 3)
        .unwrap();
    let err = handle
        .local_sdca(0, "hinge", vec![0.0; 10], vec![0.0; 3], vec![0; 4], 1.0, 1.0)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no AOT artifact"), "unhelpful error: {msg}");
}

#[test]
fn full_cluster_runs_on_pjrt_backend() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // 2 workers x 128 rows: each block matches the 128x16 artifact
    let data = cov_like(256, 16, 0.1, 5);
    let mut session = Trainer::on(&data)
        .workers(2)
        .loss(LossKind::Hinge)
        .lambda(0.01)
        .backend(Backend::Pjrt)
        .artifacts_dir(artifacts_dir().to_str().unwrap())
        .network(NetworkModel::free())
        .seed(13)
        .build()
        .unwrap();
    let g0 = session.evaluate().unwrap().gap;
    for _ in 0..6 {
        let replies = session.dispatch(|_| LocalWork::DualRound { h: 128 }).unwrap();
        session.commit(&replies, 0.5).unwrap();
    }
    let ev = session.evaluate().unwrap();
    assert!(ev.gap < g0 * 0.5, "gap barely moved on PJRT backend: {g0} -> {}", ev.gap);
    assert!(ev.gap >= -1e-6);
    session.shutdown();
}
