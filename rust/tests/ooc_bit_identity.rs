//! Out-of-core acceptance gates: training from mmap-backed shards is
//! *bit-identical* to training in memory, and the shard format's failure
//! modes surface as typed errors at the API boundary.
//!
//! * `Trainer::on_shards` at K ∈ {1, 2, 4} reproduces the
//!   `Trainer::on(&data).workers(k)` trajectory bit for bit — every
//!   deterministic TraceRow column and the final `w` — in both shard
//!   modes (`Mapped` where the platform supports mmap, `Owned`
//!   everywhere).
//! * A corrupted or truncated shard file is rejected with
//!   `Error::Shard` naming the file, not silently trained on.
//! * `workers(k)` disagreeing with the manifest, and explicit
//!   partitions on shard sets, are `Error::Config` at build time.
//! * The `[data] shards = "dir"` TOML surface round-trips: a config
//!   file drives the same bit-identical run through
//!   `ExperimentConfig::open_shards` + `trainer_shards`.

use cocoa::config::ExperimentConfig;
use cocoa::data::{rcv1_like, write_shards, Partition, PartitionStrategy, ShardMode, ShardSet};
use cocoa::prelude::*;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cocoa_ooc_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every deterministic TraceRow column, bit for bit. Timing columns fold
/// in measured thread-CPU seconds and are excluded (same convention as
/// the driver-equivalence suite).
fn assert_rows_bit_identical(a: &Trace, b: &Trace, context: &str) {
    assert_eq!(a.rows.len(), b.rows.len(), "{context}: row counts differ");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        let ctx = format!("{context}, round {}", ra.round);
        assert_eq!(ra.round, rb.round, "{ctx}");
        assert_eq!(ra.vectors, rb.vectors, "{ctx}: vectors");
        assert_eq!(ra.bytes_modeled, rb.bytes_modeled, "{ctx}: bytes_modeled");
        assert_eq!(ra.bytes_measured, rb.bytes_measured, "{ctx}: bytes_measured");
        assert_eq!(ra.inner_steps, rb.inner_steps, "{ctx}: inner_steps");
        assert_eq!(ra.primal.to_bits(), rb.primal.to_bits(), "{ctx}: primal");
        assert_eq!(ra.dual.to_bits(), rb.dual.to_bits(), "{ctx}: dual");
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "{ctx}: gap");
        assert_eq!(ra.w_nnz, rb.w_nnz, "{ctx}: w_nnz");
        assert_eq!(ra.stop, rb.stop, "{ctx}: stop reason");
    }
}

fn run_in_memory(data: &cocoa::data::Dataset, k: usize) -> (Trace, Vec<f64>) {
    let mut session = Trainer::on(data)
        .workers(k)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .seed(9)
        .label("ooc_mem")
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(20), MaxRounds::new(6)).unwrap();
    let w = session.w().to_vec();
    session.shutdown();
    (trace, w)
}

fn run_from_shards(set: &ShardSet) -> (Trace, Vec<f64>) {
    let mut session = Trainer::on_shards(set)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .seed(9)
        .label("ooc_shards")
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(20), MaxRounds::new(6)).unwrap();
    let w = session.w().to_vec();
    session.shutdown();
    (trace, w)
}

/// The tentpole acceptance: shard-backed training reproduces the
/// in-memory trajectory bit for bit at K ∈ {1, 2, 4}, in both shard
/// modes. `n` deliberately does not divide evenly by every K, so the
/// ragged-block bookkeeping is on the line too.
#[test]
fn mmap_shards_match_in_memory_bitwise() {
    let data = rcv1_like(98, 40, 8, 0.1, 7);
    for k in [1usize, 2, 4] {
        let dir = tmpdir(&format!("bitid_k{k}"));
        let set = write_shards(&data, PartitionStrategy::Contiguous, k, 0, &dir).unwrap();
        assert_eq!(set.n(), data.n());
        assert_eq!(set.d(), data.d());
        assert_eq!(set.fingerprint(), data.fingerprint(), "K={k}: fingerprint drift");

        let (mem_trace, mem_w) = run_in_memory(&data, k);
        for mode in [ShardMode::default_mode(), ShardMode::Owned] {
            let set = ShardSet::open_with_mode(&dir, mode).unwrap();
            let (ooc_trace, ooc_w) = run_from_shards(&set);
            let ctx = format!("K={k} mode={mode:?}");
            assert_rows_bit_identical(&mem_trace, &ooc_trace, &ctx);
            assert_eq!(mem_w.len(), ooc_w.len(), "{ctx}: w length");
            for (i, (a, b)) in mem_w.iter().zip(&ooc_w).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: w[{i}] {a} vs {b}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Round-robin and random partitions shard through the same writer; the
/// manifest remembers strategy + seed, so the shard-fed run must land on
/// the *same* trajectory as the in-memory run under that partition.
#[test]
fn non_contiguous_partitions_round_trip_through_shards() {
    let data = rcv1_like(90, 30, 6, 0.1, 13);
    for (strategy, pseed) in
        [(PartitionStrategy::RoundRobin, 0u64), (PartitionStrategy::Random, 5)]
    {
        let dir = tmpdir(&format!("strat_{strategy:?}"));
        let set = write_shards(&data, strategy, 3, pseed, &dir).unwrap();

        let mut mem = Trainer::on(&data)
            .workers(3)
            .partition_strategy(strategy)
            .partition_seed(pseed)
            .loss(LossKind::Logistic)
            .lambda(0.02)
            .seed(4)
            .build()
            .unwrap();
        let mem_trace = mem.run(&mut Cocoa::new(15), MaxRounds::new(5)).unwrap();
        let mem_w = mem.w().to_vec();
        mem.shutdown();

        let mut ooc = Trainer::on_shards(&set)
            .loss(LossKind::Logistic)
            .lambda(0.02)
            .seed(4)
            .build()
            .unwrap();
        let ooc_trace = ooc.run(&mut Cocoa::new(15), MaxRounds::new(5)).unwrap();
        let ooc_w = ooc.w().to_vec();
        ooc.shutdown();

        let ctx = format!("{strategy:?}");
        assert_rows_bit_identical(&mem_trace, &ooc_trace, &ctx);
        for (a, b) in mem_w.iter().zip(&ooc_w) {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: w diverged");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// A flipped byte in a shard's payload fails the section checksum and
/// surfaces as `Error::Shard` naming the file — through the full
/// `Trainer::build()` stack, not just the low-level open.
#[test]
fn corrupted_shard_is_rejected_with_a_typed_error() {
    let data = rcv1_like(60, 20, 5, 0.1, 3);
    let dir = tmpdir("corrupt");
    let set = write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();

    // flip one byte deep in shard 0's payload (past the header)
    let path = set.shard_path(0);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();

    let err = Trainer::on_shards(&set)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .seed(1)
        .build()
        .err()
        .expect("a corrupt shard must not build a session");
    match &err {
        Error::Shard { path, message } => {
            assert!(path.contains("shard_0000"), "{err}");
            assert!(!message.is_empty(), "{err}");
        }
        other => panic!("expected Error::Shard, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A truncated shard file (torn copy, partial download) is rejected the
/// same way — the header promises more bytes than the file holds.
#[test]
fn truncated_shard_is_rejected_with_a_typed_error() {
    let data = rcv1_like(60, 20, 5, 0.1, 3);
    let dir = tmpdir("truncate");
    let set = write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();

    let path = set.shard_path(1);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let err = set.open_shard(1).err().expect("a truncated shard must not open");
    assert!(matches!(err, Error::Shard { .. }), "{err}");
    assert!(err.to_string().contains("shard_0001"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The manifest is authoritative for the partition: `workers(k)` may
/// restate the manifest's K but not contradict it, and explicit
/// partitions are meaningless (rows were routed at write time).
#[test]
fn shard_partition_conflicts_are_typed_config_errors() {
    let data = rcv1_like(60, 20, 5, 0.1, 3);
    let dir = tmpdir("conflict");
    let set = write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();

    // restating the manifest's K is fine
    Trainer::on_shards(&set)
        .workers(2)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .build()
        .unwrap()
        .shutdown();

    // contradicting it is not
    let err = Trainer::on_shards(&set)
        .workers(3)
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .build()
        .err()
        .expect("workers(3) on a K=2 shard set must fail");
    assert!(matches!(err, Error::Config { .. }), "{err}");
    assert!(err.to_string().contains("does not match the shard set"), "{err}");

    // explicit partitions cannot apply to shards at all
    let err = Trainer::on_shards(&set)
        .partition(Partition::new(PartitionStrategy::Contiguous, 60, 2, 0))
        .loss(LossKind::Hinge)
        .lambda(0.05)
        .build()
        .err()
        .expect("an explicit partition on a shard set must fail");
    assert!(matches!(err, Error::Config { .. }), "{err}");
    assert!(err.to_string().contains("explicit partitions"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `[data] shards` TOML surface end to end: the config file opens
/// the set (here with `mmap = false`, forcing `Owned` mode), derives the
/// trainer, and lands on the exact in-memory trajectory.
#[test]
fn toml_data_shards_round_trips_bit_identically() {
    let data = rcv1_like(80, 24, 6, 0.1, 11);
    let dir = tmpdir("toml");
    let shard_dir = dir.join("shards");
    write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &shard_dir).unwrap();

    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        format!(
            "lambda = 0.05\n\n\
             [data]\nshards = \"{}\"\nmmap = false\n\n\
             [algorithm]\nname = \"cocoa\"\nh = 20\n\n\
             [loss]\nkind = \"hinge\"\n\n\
             [run]\nrounds = 6\nseed = 9\n",
            shard_dir.display()
        ),
    )
    .unwrap();

    let cfg = ExperimentConfig::from_toml_file(cfg_path.to_str().unwrap()).unwrap();
    let set = cfg.open_shards().unwrap();
    assert_eq!(set.mode(), ShardMode::Owned, "mmap = false must force Owned");
    assert_eq!(set.k(), 2);

    let mut session = cfg.trainer_shards(&set).build().unwrap();
    let cfg_trace = session.run(&mut Cocoa::new(20), MaxRounds::new(6)).unwrap();
    let cfg_w = session.w().to_vec();
    session.shutdown();

    let (mem_trace, mem_w) = run_in_memory(&data, 2);
    assert_rows_bit_identical(&mem_trace, &cfg_trace, "toml round trip");
    for (a, b) in mem_w.iter().zip(&cfg_w) {
        assert_eq!(a.to_bits(), b.to_bits(), "toml round trip: w diverged");
    }

    // loading a shard config as an in-memory dataset is a typed refusal,
    // not a silent fallback
    let err = cfg.dataset.load().err().expect("shards are not loadable in-memory");
    assert!(err.to_string().contains("open_shards"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
