//! Builder validation: every misconfiguration `Trainer::build` can reject
//! must come back as the right typed `Error` variant — no panics, no
//! stringly `anyhow` at the API boundary. Plus the warm-start and
//! `Aggregation::Add` end-to-end guarantees of the `Session` facade.

use cocoa::data::cov_like;
use cocoa::prelude::*;

fn data() -> Dataset {
    cov_like(40, 5, 0.1, 1)
}

#[test]
fn missing_lambda_is_typed() {
    let data = data();
    let err = Trainer::on(&data).workers(2).build().unwrap_err();
    assert!(matches!(err, Error::MissingLambda), "{err}");
}

#[test]
fn invalid_lambda_is_typed() {
    let data = data();
    for bad in [0.0, -0.5, f64::NAN, f64::INFINITY] {
        let err = Trainer::on(&data).workers(2).lambda(bad).build().unwrap_err();
        match err {
            Error::InvalidLambda { value } => {
                assert!(value == bad || (value.is_nan() && bad.is_nan()))
            }
            other => panic!("lambda {bad}: wrong variant {other}"),
        }
    }
}

#[test]
fn missing_partition_is_typed() {
    let data = data();
    let err = Trainer::on(&data).lambda(0.1).build().unwrap_err();
    assert!(matches!(err, Error::MissingPartition), "{err}");
}

#[test]
fn k_larger_than_n_is_typed() {
    let data = data(); // n = 40
    let err = Trainer::on(&data).workers(41).lambda(0.1).build().unwrap_err();
    assert!(
        matches!(err, Error::TooManyWorkers { k: 41, n: 40 }),
        "{err}"
    );
    // zero workers is equally impossible
    let err = Trainer::on(&data).workers(0).lambda(0.1).build().unwrap_err();
    assert!(matches!(err, Error::TooManyWorkers { k: 0, .. }), "{err}");
}

#[test]
fn pjrt_without_artifacts_is_typed() {
    let data = data();
    let err = Trainer::on(&data)
        .workers(2)
        .lambda(0.1)
        .backend(Backend::Pjrt)
        .artifacts_dir("/definitely/not/a/real/artifacts/dir")
        .build()
        .unwrap_err();
    match err {
        Error::MissingArtifacts { dir } => assert!(dir.contains("not/a/real")),
        other => panic!("wrong variant: {other}"),
    }
}

#[test]
fn invalid_regularizer_params_are_typed() {
    let data = data();
    for kind in [
        RegularizerKind::L1 { epsilon: 0.0 },
        RegularizerKind::L1 { epsilon: -1.0 },
        RegularizerKind::L1 { epsilon: f64::NAN },
        RegularizerKind::ElasticNet { l1_ratio: 1.0 },
        RegularizerKind::ElasticNet { l1_ratio: -0.2 },
        RegularizerKind::ElasticNet { l1_ratio: f64::INFINITY },
    ] {
        let err = Trainer::on(&data)
            .workers(2)
            .lambda(0.1)
            .regularizer(kind)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, Error::InvalidRegularizer { .. }),
            "{kind:?}: wrong variant {err}"
        );
    }
}

#[test]
fn l2_only_features_reject_other_regularizers_typed() {
    let data = data();
    // the gap-certified solver's Appendix-B certificate is L2 math
    let err = Trainer::on(&data)
        .workers(2)
        .lambda(0.1)
        .regularizer(RegularizerKind::L1 { epsilon: 0.5 })
        .solver(SolverKind::GapCertified)
        .build()
        .unwrap_err();
    assert!(matches!(err, Error::UnsupportedRegularizer { .. }), "{err}");
    // the PJRT kernels hardcode the L2 subproblem — rejected before the
    // (missing) artifacts are even looked for
    let err = Trainer::on(&data)
        .workers(2)
        .lambda(0.1)
        .regularizer(RegularizerKind::ElasticNet { l1_ratio: 0.3 })
        .backend(Backend::Pjrt)
        .artifacts_dir("/definitely/not/a/real/artifacts/dir")
        .build()
        .unwrap_err();
    assert!(matches!(err, Error::UnsupportedRegularizer { .. }), "{err}");
    // a *valid* non-L2 regularizer with the default solver builds fine
    let session = Trainer::on(&data)
        .workers(2)
        .lambda(0.1)
        .regularizer(RegularizerKind::ElasticNet { l1_ratio: 0.3 })
        .build()
        .unwrap();
    assert_eq!(
        session.regularizer(),
        RegularizerKind::ElasticNet { l1_ratio: 0.3 }
    );
    session.shutdown();
}

#[test]
fn mismatched_partition_is_typed() {
    let data = data(); // n = 40
    let wrong = Partition::new(PartitionStrategy::Contiguous, 60, 2, 0);
    let err = Trainer::on(&data)
        .partition(wrong)
        .lambda(0.1)
        .build()
        .unwrap_err();
    assert!(
        matches!(err, Error::PartitionMismatch { data_n: 40, partition_n: 60 }),
        "{err}"
    );
}

#[test]
fn errors_are_std_error_and_display() {
    let data = data();
    let err = Trainer::on(&data).workers(2).build().unwrap_err();
    let dynamic: &dyn std::error::Error = &err;
    assert!(dynamic.to_string().contains("lambda"));
}

#[test]
fn explicit_partition_builds_and_runs() {
    let data = data();
    let part = Partition::new(PartitionStrategy::RoundRobin, 40, 4, 7);
    let mut session = Trainer::on(&data)
        .partition(part)
        .lambda(0.1)
        .build()
        .unwrap();
    assert_eq!(session.k(), 4);
    let tr = session.run(&mut Cocoa::new(10), Budget::rounds(2)).unwrap();
    assert_eq!(tr.rows.last().unwrap().round, 2);
    session.shutdown();
}

#[test]
fn aggregation_add_runs_end_to_end() {
    // CoCoA+ through the whole public path: builder -> session -> trace.
    let data = cov_like(200, 8, 0.1, 3);
    let mut session = Trainer::on(&data)
        .workers(4)
        .loss(LossKind::SmoothedHinge { gamma: 1.0 })
        .lambda(0.05)
        .seed(5)
        .build()
        .unwrap();
    let trace = session
        .run(&mut Cocoa::adding(50), Budget::rounds(8))
        .unwrap();
    session.shutdown();
    assert_eq!(trace.algorithm, "cocoa_plus");
    let g0 = trace.rows.first().unwrap().gap;
    let g_end = trace.rows.last().unwrap().gap;
    assert!(g_end.is_finite() && g_end >= -1e-9, "adding diverged: {g_end}");
    assert!(g_end < g0 * 0.5, "adding made no progress: {g0} -> {g_end}");
}

#[test]
fn until_subopt_without_reference_is_typed() {
    // target_subopt can never fire without P*: fail fast instead of
    // spinning to the round cap
    let data = data();
    let mut session = Trainer::on(&data).workers(2).lambda(0.1).build().unwrap();
    let err = session
        .run(&mut Cocoa::new(10), Budget::until_subopt(1e-3))
        .unwrap_err();
    assert!(matches!(err, Error::MissingReferenceOptimum), "{err}");
    // with a reference set, the same budget runs
    session.set_reference_optimum(Some(0.0));
    session
        .run(&mut Cocoa::new(10), Budget::until_subopt(1e-3).max_rounds(2))
        .unwrap();
    session.shutdown();
}

#[test]
fn partition_seed_is_order_insensitive() {
    let data = cov_like(60, 4, 0.1, 2);
    let build = |t: Trainer| {
        let mut s = t.lambda(0.1).seed(3).build().unwrap();
        let tr = s.run(&mut Cocoa::new(5), Budget::rounds(1)).unwrap();
        let p = tr.rows.last().unwrap().primal;
        s.shutdown();
        p
    };
    let seed_first = build(
        Trainer::on(&data)
            .partition_seed(42)
            .workers(3)
            .partition_strategy(PartitionStrategy::Random),
    );
    let seed_last = build(
        Trainer::on(&data)
            .workers(3)
            .partition_strategy(PartitionStrategy::Random)
            .partition_seed(42),
    );
    assert_eq!(seed_first, seed_last, "partition_seed dropped when called first");
}

#[test]
fn zero_eval_cadence_is_rejected_typed() {
    // eval_every(0) used to be silently clamped to 1; it is now a typed
    // validation error on every road into the driver
    let data = cov_like(40, 5, 0.1, 3);
    let mut session = Trainer::on(&data).workers(2).lambda(0.1).build().unwrap();
    let err = session.run(&mut Cocoa::new(5), Budget::rounds(3).eval_every(0)).unwrap_err();
    assert!(matches!(err, Error::InvalidBudget { .. }), "{err}");
    assert!(err.to_string().contains("eval_every"), "{err}");
    // the DriverSpec cadence knob is validated the same way
    let mut algo = Cocoa::new(5);
    let err = session
        .drive(&mut algo, DriverSpec::new(MaxRounds::new(3)).eval_every(0))
        .err()
        .expect("zero cadence must not build a driver");
    assert!(matches!(err, Error::InvalidBudget { .. }), "{err}");
    // a valid budget still runs on this session afterwards
    let trace = session.run(&mut Cocoa::new(5), Budget::rounds(2)).unwrap();
    assert_eq!(trace.rows.len(), 3);
    session.shutdown();
}

#[test]
fn session_reset_reproduces_the_run_exactly() {
    // Warm-start contract: reset() + run == fresh build + run, bit for bit.
    let data = cov_like(150, 6, 0.1, 9);
    let mut session = Trainer::on(&data)
        .workers(3)
        .lambda(0.05)
        .seed(11)
        .build()
        .unwrap();
    let first = session.run(&mut Cocoa::new(30), Budget::rounds(5)).unwrap();
    session.reset().unwrap();
    let again = session.run(&mut Cocoa::new(30), Budget::rounds(5)).unwrap();
    session.shutdown();
    assert_eq!(first.rows.len(), again.rows.len());
    for (a, b) in first.rows.iter().zip(&again.rows) {
        assert_eq!(a.primal, b.primal, "round {}: warm-start diverged", a.round);
        assert_eq!(a.dual, b.dual);
        assert_eq!(a.vectors, b.vectors);
    }
}
