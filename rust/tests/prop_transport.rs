//! Transport-layer properties: trajectory invariance across backends,
//! byte-exact accounting laws, deterministic replay, and the seeded
//! determinism artifact the CI job diffs across two runs.
//!
//! Like `prop_invariants.rs`, this file carries its own lightweight
//! property harness (the offline build has no proptest crate): each
//! property runs over `CASES` seeded random instances; on failure it
//! reports the seed so the case replays exactly.

use std::sync::Arc;

use cocoa::data::cov_like;
use cocoa::prelude::*;
use cocoa::util::Rng;

const CASES: u64 = 6;

fn for_all(name: &str, prop: impl Fn(u64, &mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(0x7a45_0000 + seed);
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(seed, &mut rng)));
        if let Err(e) = result {
            panic!("property {name:?} failed at seed {seed}: {e:?}");
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Case {
    n: usize,
    d: usize,
    k: usize,
    h: usize,
    rounds: u64,
    lambda: f64,
    seed: u64,
}

fn random_case(seed: u64, rng: &mut Rng) -> Case {
    let n = 30 + rng.gen_range(90);
    Case {
        n,
        d: 3 + rng.gen_range(8),
        k: 1 + rng.gen_range(n.min(4)),
        h: 5 + rng.gen_range(40),
        rounds: 3 + rng.gen_range(4) as u64,
        lambda: rng.gen_range_f64(0.02, 0.2),
        seed,
    }
}

/// Run one CoCoA session over `case` on the given transport; returns the
/// final `w` and the trace.
fn run(case: Case, transport: TransportKind) -> (Vec<f64>, Trace) {
    let data = cov_like(case.n, case.d, 0.1, case.seed);
    let mut session = Trainer::on(&data)
        .workers(case.k)
        .loss(LossKind::SmoothedHinge { gamma: 1.0 })
        .lambda(case.lambda)
        .network(NetworkModel::ec2_like())
        .transport(transport)
        .seed(case.seed)
        .label("prop")
        .build()
        .unwrap();
    let trace = session
        .run(&mut Cocoa::new(case.h), Budget::rounds(case.rounds))
        .unwrap();
    let w = session.w().to_vec();
    session.shutdown();
    (w, trace)
}

fn assert_rows_bit_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.rows.len(), b.rows.len(), "{what}: row counts differ");
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.vectors, rb.vectors, "{what}: round {}", ra.round);
        assert_eq!(
            ra.primal.to_bits(),
            rb.primal.to_bits(),
            "{what}: primal diverged at round {}",
            ra.round
        );
        assert_eq!(
            ra.dual.to_bits(),
            rb.dual.to_bits(),
            "{what}: dual diverged at round {}",
            ra.round
        );
        assert_eq!(
            ra.gap.to_bits(),
            rb.gap.to_bits(),
            "{what}: gap diverged at round {}",
            ra.round
        );
    }
}

#[test]
fn prop_simnet_trajectory_is_bit_identical_to_inproc() {
    // SimNet injects jitter, drops/retransmits, and stragglers — but never
    // touches message contents or per-worker ordering, so final w and the
    // whole P/D/gap trace must match InProc bit for bit.
    for_all("simnet == inproc trajectories", |seed, rng| {
        let case = random_case(seed, rng);
        let simnet = SimNetConfig::new(seed)
            .jitter(2e-3)
            .drops(0.1, 3, 5e-3)
            .stragglers(0.2, 6.0);
        let (w_inproc, tr_inproc) = run(case, TransportKind::InProc);
        let (w_simnet, tr_simnet) = run(case, TransportKind::SimNet(simnet));
        assert_eq!(w_inproc.len(), w_simnet.len());
        for (a, b) in w_inproc.iter().zip(&w_simnet) {
            assert_eq!(a.to_bits(), b.to_bits(), "final w diverged (case {case:?})");
        }
        assert_rows_bit_identical(&tr_inproc, &tr_simnet, "simnet vs inproc");
    });
}

#[test]
fn prop_counted_bytes_monotone_and_invariant_across_runs() {
    for_all("counted bytes monotone + repeatable", |seed, rng| {
        let case = random_case(seed, rng);
        let (_, first) = run(case, TransportKind::Counted);
        let (_, again) = run(case, TransportKind::Counted);

        // monotone in rounds, strictly increasing once rounds happen
        for pair in first.rows.windows(2) {
            assert!(
                pair[1].bytes_measured > pair[0].bytes_measured,
                "bytes not strictly increasing: {} -> {} (case {case:?})",
                pair[0].bytes_measured,
                pair[1].bytes_measured
            );
        }
        assert_eq!(first.rows[0].bytes_measured, 0, "round 0 moved algorithm bytes");

        // invariant across repeat runs, row by row
        for (ra, rb) in first.rows.iter().zip(&again.rows) {
            assert_eq!(
                ra.bytes_measured, rb.bytes_measured,
                "byte totals differ across identical runs (round {})",
                ra.round
            );
            assert_eq!(ra.bytes_modeled, rb.bytes_modeled);
        }
    });
}

#[test]
fn prop_simnet_same_seed_same_bytes_and_gaps() {
    // The acceptance contract: same seed => identical gap trace and
    // identical byte totals across two consecutive runs.
    for_all("simnet determinism", |seed, rng| {
        let case = random_case(seed, rng);
        let cfg = SimNetConfig::new(seed ^ 0xd00d).jitter(1e-3).drops(0.2, 2, 3e-3);
        let (w1, tr1) = run(case, TransportKind::SimNet(cfg));
        let (w2, tr2) = run(case, TransportKind::SimNet(cfg));
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_rows_bit_identical(&tr1, &tr2, "simnet run 1 vs run 2");
        for (ra, rb) in tr1.rows.iter().zip(&tr2.rows) {
            assert_eq!(ra.bytes_measured, rb.bytes_measured);
        }
    });
}

#[test]
fn simnet_drops_charge_retransmission_bytes() {
    let case = Case { n: 80, d: 6, k: 4, h: 20, rounds: 6, lambda: 0.05, seed: 3 };
    let (_, clean) = run(case, TransportKind::Counted);
    let lossy = SimNetConfig::new(7).jitter(0.0).drops(0.5, 3, 1e-3);
    let (_, dropped) = run(case, TransportKind::SimNet(lossy));
    let clean_total = clean.rows.last().unwrap().bytes_measured;
    let lossy_total = dropped.rows.last().unwrap().bytes_measured;
    // 144 algorithm messages at 50% drop: retransmissions are certain
    assert!(
        lossy_total > clean_total,
        "drops did not charge extra bytes: {lossy_total} <= {clean_total}"
    );
}

#[test]
fn record_then_replay_reproduces_the_run_bit_for_bit() {
    let case = Case { n: 60, d: 5, k: 3, h: 15, rounds: 5, lambda: 0.1, seed: 11 };
    let data = cov_like(case.n, case.d, 0.1, case.seed);
    let build = |transport: TransportKind| {
        Trainer::on(&data)
            .workers(case.k)
            .loss(LossKind::SmoothedHinge { gamma: 1.0 })
            .lambda(case.lambda)
            .network(NetworkModel::ec2_like())
            .transport(transport)
            .seed(case.seed)
            .label("replay")
            .build()
            .unwrap()
    };

    let mut recorder = build(TransportKind::Record);
    let recorded = recorder
        .run(&mut Cocoa::new(case.h), Budget::rounds(case.rounds))
        .unwrap();
    let w_recorded = recorder.w().to_vec();
    let tape = Arc::new(recorder.take_transcript().expect("record keeps a tape"));
    recorder.shutdown();
    assert!(tape.sends() > 0 && tape.recvs() > 0);

    // replay: same driver, no live worker traffic — every reply (compute
    // times included) comes off the tape, so even sim_time_s reproduces
    let mut replayer = build(TransportKind::Replay(tape.clone()));
    let replayed = replayer
        .run(&mut Cocoa::new(case.h), Budget::rounds(case.rounds))
        .unwrap();
    for (a, b) in w_recorded.iter().zip(replayer.w()) {
        assert_eq!(a.to_bits(), b.to_bits(), "replayed w diverged");
    }
    assert_eq!(recorded.rows.len(), replayed.rows.len());
    for (ra, rb) in recorded.rows.iter().zip(&replayed.rows) {
        assert_eq!(ra.primal.to_bits(), rb.primal.to_bits());
        assert_eq!(ra.dual.to_bits(), rb.dual.to_bits());
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits());
        assert_eq!(ra.bytes_measured, rb.bytes_measured);
        assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
        assert_eq!(ra.compute_time_s.to_bits(), rb.compute_time_s.to_bits());
    }
    replayer.shutdown();

    // a diverging driver (one extra round) must fail with a typed error,
    // not silently fabricate data past the end of the tape
    let mut diverging = build(TransportKind::Replay(tape));
    let err = diverging
        .run(&mut Cocoa::new(case.h), Budget::rounds(case.rounds + 1))
        .unwrap_err();
    assert!(
        matches!(err, cocoa::Error::Transport { .. }),
        "divergence must surface as the typed transport error, got: {err}"
    );
    assert!(
        err.to_string().contains("replay diverged"),
        "wrong error: {err}"
    );
    diverging.shutdown();
}

/// Writes the deterministic fingerprint of a seeded SimNet run to
/// `target/determinism/trace_<seed>.csv`. The CI job runs this test twice
/// with `CARGO_TEST_SEED` pinned and diffs the two files — any
/// nondeterminism in the transport, the coordinator reduction order, or
/// the byte accounting shows up as a diff. Only deterministic columns are
/// written (no wall-clock or CPU-time derived values).
#[test]
fn seeded_determinism_artifact() {
    let seed: u64 = std::env::var("CARGO_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let case = Case {
        n: 90,
        d: 7,
        k: 3,
        h: 25,
        rounds: 6,
        lambda: 0.05,
        seed,
    };
    let cfg = SimNetConfig::new(seed).jitter(1e-3).drops(0.15, 3, 2e-3).stragglers(0.1, 4.0);
    let (w, trace) = run(case, TransportKind::SimNet(cfg));

    let mut out = String::from("round,vectors,bytes_modeled,bytes_measured,primal_bits,dual_bits,gap_bits\n");
    for r in &trace.rows {
        out.push_str(&format!(
            "{},{},{},{},{:016x},{:016x},{:016x}\n",
            r.round,
            r.vectors,
            r.bytes_modeled,
            r.bytes_measured,
            r.primal.to_bits(),
            r.dual.to_bits(),
            r.gap.to_bits(),
        ));
    }
    let fingerprint = w
        .iter()
        .fold(0u64, |acc, v| acc.rotate_left(7) ^ v.to_bits());
    out.push_str(&format!("final_w_fingerprint {fingerprint:016x}\n"));

    std::fs::create_dir_all("target/determinism").unwrap();
    std::fs::write(format!("target/determinism/trace_{seed}.csv"), out).unwrap();
}
