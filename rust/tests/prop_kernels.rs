//! Property tests for the fused hot-path kernels (`cocoa::kernels`) and
//! the sparse-first `LocalSdca` refactor built on them.
//!
//! The contract under test is *bit-exactness*: every fused kernel must
//! reproduce, bit for bit, the naive scalar reference it replaced — on
//! random sparse and dense inputs, including empty rows — and the
//! monomorphized inner loop must reproduce the generic
//! `Features::row_dot`/`add_row_scaled` implementation it replaced. This
//! is what lets the kernels ship inside the determinism-gated solver
//! without perturbing a single seeded trajectory.

use cocoa::data::{CsrMatrix, Dataset, DenseMatrix, Features};
use cocoa::kernels;
use cocoa::loss::{Hinge, Loss, SmoothedHinge, Squared};
use cocoa::solvers::{Block, LocalDualMethod, LocalSdca, Sampling};
use cocoa::util::Rng;

/// Random sorted, duplicate-free index set into [0, d) with `nnz` entries.
fn random_indices(rng: &mut Rng, d: usize, nnz: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = rng
        .sample_distinct(d, nnz)
        .into_iter()
        .map(|i| i as u32)
        .collect();
    idx.sort_unstable();
    idx
}

fn random_values(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal() * 2.0).collect()
}

#[test]
fn sparse_dot_bit_matches_naive_reference() {
    let mut rng = Rng::seed_from_u64(0xd07);
    for trial in 0..300 {
        let d = 1 + rng.gen_range(96);
        let nnz = rng.gen_range(d + 1); // 0 (empty row) up to d
        let idx = random_indices(&mut rng, d, nnz);
        let val = random_values(&mut rng, nnz);
        let w = random_values(&mut rng, d);
        let mut naive = 0.0f64;
        for (i, v) in idx.iter().zip(&val) {
            naive += v * w[*i as usize];
        }
        let fused = kernels::sparse_dot(&idx, &val, &w);
        assert_eq!(
            fused.to_bits(),
            naive.to_bits(),
            "trial {trial}: d={d} nnz={nnz}: {fused} != {naive}"
        );
    }
}

#[test]
fn sparse_axpy_bit_matches_naive_reference() {
    let mut rng = Rng::seed_from_u64(0xa991);
    for trial in 0..300 {
        let d = 1 + rng.gen_range(96);
        let nnz = rng.gen_range(d + 1);
        let idx = random_indices(&mut rng, d, nnz);
        let val = random_values(&mut rng, nnz);
        let coef = rng.normal();
        let mut fused = random_values(&mut rng, d);
        let mut naive = fused.clone();
        kernels::sparse_axpy(&idx, &val, coef, &mut fused);
        for (i, v) in idx.iter().zip(&val) {
            naive[*i as usize] += coef * v;
        }
        for (j, (a, b)) in fused.iter().zip(&naive).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "trial {trial} col {j}");
        }
    }
}

#[test]
fn sparse_norm_bit_matches_iterator_sum() {
    let mut rng = Rng::seed_from_u64(0x42);
    for _ in 0..200 {
        let nnz = rng.gen_range(40);
        let val = random_values(&mut rng, nnz);
        let naive: f64 = val.iter().map(|v| v * v).sum();
        assert_eq!(kernels::sparse_norm_sq(&val).to_bits(), naive.to_bits());
    }
}

#[test]
fn dense_dot_bit_matches_blocked_reference() {
    // the dense kernel's contract is the documented 8-lane blocked order
    // (not the naive left-to-right sum); the reference spells that order
    // out in plain loops
    let mut rng = Rng::seed_from_u64(0xde5e);
    for trial in 0..200 {
        let d = 1 + rng.gen_range(130);
        let a = random_values(&mut rng, d);
        let b = random_values(&mut rng, d);
        let mut lanes = [0.0f64; 8];
        let main = d / 8 * 8;
        for k in 0..main {
            lanes[k % 8] += a[k] * b[k];
        }
        let mut reference = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        for k in main..d {
            reference += a[k] * b[k];
        }
        let fused = kernels::dense_dot(&a, &b);
        assert_eq!(fused.to_bits(), reference.to_bits(), "trial {trial} d={d}");
    }
}

#[test]
fn dense_axpy_bit_matches_naive_reference() {
    // element updates are independent, so blocked == naive bitwise
    let mut rng = Rng::seed_from_u64(0xabc);
    for _ in 0..200 {
        let d = 1 + rng.gen_range(130);
        let a = random_values(&mut rng, d);
        let coef = rng.normal();
        let mut fused = random_values(&mut rng, d);
        let mut naive = fused.clone();
        kernels::dense_axpy(coef, &a, &mut fused);
        for (o, v) in naive.iter_mut().zip(&a) {
            *o += coef * v;
        }
        for (x, y) in fused.iter().zip(&naive) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

/// Random sparse dataset with duplicate-free rows (possibly empty).
fn random_sparse_dataset(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    let mut triplets = Vec::new();
    let mut labels = Vec::new();
    for i in 0..n {
        let nnz = rng.gen_range(8.min(d) + 1);
        for c in random_indices(rng, d, nnz) {
            triplets.push((i, c, rng.normal()));
        }
        labels.push(if rng.gen_bool(0.5) { 1.0 } else { -1.0 });
    }
    Dataset::new(Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets)), labels)
}

/// The pre-refactor `LocalSdca::local_update`, reproduced verbatim on the
/// generic `Features` accessors: the reference the monomorphized fast
/// path must match bit for bit.
fn reference_local_update(
    block: &Block,
    loss: &dyn Loss,
    alpha: &[f64],
    w: &[f64],
    h: usize,
    solver: &LocalSdca,
    rng: &mut Rng,
) -> (Vec<f64>, Vec<f64>) {
    let (sampling, curvature_scale) = (solver.sampling, solver.curvature_scale);
    let n_k = block.n_k();
    let mut dalpha = vec![0.0; n_k];
    let mut w_local = w.to_vec();
    let inv_lambda_n = curvature_scale / block.lambda_n;
    let mut perm: Vec<u32> = Vec::new();
    for step in 0..h {
        let i = match sampling {
            Sampling::WithReplacement => rng.gen_range(n_k),
            Sampling::Permutation => {
                let pos = step % n_k;
                if pos == 0 {
                    let mut p: Vec<u32> = (0..n_k as u32).collect();
                    rng.shuffle(&mut p);
                    perm = p;
                }
                perm[pos] as usize
            }
        };
        let q = block.data.features.row_dot(i, &w_local);
        let a_cur = alpha[i] + dalpha[i];
        let s = (block.data.norm_sq(i) / block.lambda_n) * curvature_scale;
        let delta = loss.coord_delta(q, block.data.labels[i], a_cur, s);
        if delta != 0.0 {
            dalpha[i] += delta;
            block.data.features.add_row_scaled(i, delta * inv_lambda_n, &mut w_local);
        }
    }
    let dw = w_local
        .iter()
        .zip(w.iter())
        .map(|(wl, w0)| (wl - w0) / curvature_scale)
        .collect();
    (dalpha, dw)
}

#[test]
fn sparse_fast_path_bit_matches_the_generic_reference() {
    let mut seed_rng = Rng::seed_from_u64(0x5eed);
    for trial in 0..8 {
        let n = 20 + seed_rng.gen_range(40);
        let d = 10 + seed_rng.gen_range(60);
        let data = random_sparse_dataset(&mut seed_rng, n, d);
        let block = Block::new(data, 0.05 * n as f64);
        let alpha = vec![0.0; n];
        let w: Vec<f64> = (0..d).map(|j| (j as f64 * 0.3).sin() * 0.1).collect();
        for (sampling, sigma) in [
            (Sampling::WithReplacement, 1.0),
            (Sampling::Permutation, 1.0),
            (Sampling::WithReplacement, 4.0),
        ] {
            for loss in [&Hinge as &dyn Loss, &Squared, &SmoothedHinge::new(0.5)] {
                let solver = if sigma == 1.0 {
                    LocalSdca::new(sampling)
                } else {
                    LocalSdca::with_curvature_scale(sampling, sigma)
                };
                let mut rng_a = Rng::seed_from_u64(trial * 31 + 7);
                let mut rng_b = rng_a.clone();
                let up = solver.local_update(&block, loss, &alpha, &w, 3 * n, &mut rng_a);
                let (ref_dalpha, ref_dw) = reference_local_update(
                    &block, loss, &alpha, &w, 3 * n, &solver, &mut rng_b,
                );
                for (a, b) in up.dalpha.iter().zip(&ref_dalpha) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dalpha diverged (trial {trial})");
                }
                for (a, b) in up.dw.iter().zip(&ref_dw) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dw diverged (trial {trial})");
                }
            }
        }
    }
}

#[test]
fn dense_fast_path_bit_matches_the_generic_reference() {
    let mut seed_rng = Rng::seed_from_u64(0xdd);
    for trial in 0..6 {
        let n = 25 + seed_rng.gen_range(30);
        let d = 3 + seed_rng.gen_range(20);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| seed_rng.normal()).collect())
            .collect();
        let labels: Vec<f64> =
            (0..n).map(|_| if seed_rng.gen_bool(0.5) { 1.0 } else { -1.0 }).collect();
        let data = Dataset::new(Features::Dense(DenseMatrix::from_rows(&rows)), labels);
        let block = Block::new(data, 0.1 * n as f64);
        let alpha = vec![0.0; n];
        let w = vec![0.0; d];
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let mut rng_a = Rng::seed_from_u64(trial + 100);
        let mut rng_b = rng_a.clone();
        let up = solver.local_update(&block, &Hinge, &alpha, &w, 2 * n, &mut rng_a);
        let (ref_dalpha, ref_dw) =
            reference_local_update(&block, &Hinge, &alpha, &w, 2 * n, &solver, &mut rng_b);
        for (a, b) in up.dalpha.iter().zip(&ref_dalpha) {
            assert_eq!(a.to_bits(), b.to_bits(), "dense dalpha diverged (trial {trial})");
        }
        for (a, b) in up.dw.iter().zip(&ref_dw) {
            assert_eq!(a.to_bits(), b.to_bits(), "dense dw diverged (trial {trial})");
        }
    }
}

#[test]
fn block_caches_match_their_definitions() {
    let mut rng = Rng::seed_from_u64(0xb10c);
    let data = random_sparse_dataset(&mut rng, 40, 30);
    let lambda_n = 0.2 * 40.0;
    let block = Block::new(data, lambda_n);
    // precomputed curvature is the same division the per-step path ran
    for i in 0..block.n_k() {
        let expect = block.data.norm_sq(i) / lambda_n;
        assert_eq!(block.curvature(i).to_bits(), expect.to_bits());
    }
    // the touch set is exactly the union of row indices, sorted, unique
    let touched = block.touched_cols().expect("sparse shard has a touch set");
    assert!(touched.windows(2).all(|p| p[0] < p[1]), "not sorted/unique");
    let mut union: Vec<u32> = Vec::new();
    match &block.data.features {
        Features::Sparse(m) => {
            for i in 0..m.rows() {
                union.extend_from_slice(m.row_view(i).0);
            }
        }
        Features::Dense(_) => unreachable!(),
    }
    union.sort_unstable();
    union.dedup();
    assert_eq!(touched, &union[..]);
}

/// Values chosen to stress floating-point edge behavior: subnormals
/// (where a fused-multiply-add or a flush-to-zero backend would diverge
/// from the scalar reference), signed zeros, huge and tiny magnitudes.
fn adversarial_values(rng: &mut Rng, n: usize) -> Vec<f64> {
    const POOL: [f64; 10] = [
        5e-324,                 // smallest positive subnormal
        1e-310,                 // subnormal
        -1e-310,                // negative subnormal
        2.2250738585072014e-308, // smallest positive normal
        1e308,
        -1e-16,
        0.0,
        -0.0,
        1.0,
        -3.5,
    ];
    (0..n)
        .map(|_| {
            if rng.gen_bool(0.5) {
                POOL[rng.gen_range(POOL.len())]
            } else {
                rng.normal() * 2.0
            }
        })
        .collect()
}

/// Lengths chosen to exercise every remainder class of an 8-lane (AVX2)
/// and 2-lane (NEON) vector body: empty, sub-width, one-past-width,
/// len % 8 in 1..=7, and larger blocks.
const ADVERSARIAL_LENS: [usize; 14] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33];

#[test]
fn dispatched_dense_kernels_bit_match_scalar_on_adversarial_shapes() {
    // `kernels::dense_dot` / `dense_axpy` go through the runtime-detected
    // backend (AVX2/NEON when available); `kernels::scalar::*` is the
    // bit-exactness ground truth. Any lane-order, FMA, or tail-handling
    // divergence in a SIMD path shows up here.
    let mut rng = Rng::seed_from_u64(0x51d0);
    for &d in &ADVERSARIAL_LENS {
        for trial in 0..40 {
            let a = adversarial_values(&mut rng, d);
            let b = adversarial_values(&mut rng, d);
            let got = kernels::dense_dot(&a, &b);
            let want = kernels::scalar::dense_dot(&a, &b);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "dense_dot diverged from scalar (d={d} trial={trial}): {got:e} != {want:e}"
            );

            let coef = if trial % 3 == 0 { 1e-310 } else { rng.normal() };
            let mut got_out = adversarial_values(&mut rng, d);
            let mut want_out = got_out.clone();
            kernels::dense_axpy(coef, &a, &mut got_out);
            kernels::scalar::dense_axpy(coef, &a, &mut want_out);
            for (j, (x, y)) in got_out.iter().zip(&want_out).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "dense_axpy diverged from scalar (d={d} trial={trial} col={j})"
                );
            }
        }
    }
}

#[test]
fn dispatched_sparse_kernels_bit_match_scalar_on_adversarial_shapes() {
    // Empty rows (nnz = 0), every gather-width remainder, and subnormal
    // values, against the unchecked scalar reference (bounds are valid
    // by construction: indices come from random_indices into [0, d)).
    let mut rng = Rng::seed_from_u64(0x5a55);
    for &nnz_target in &ADVERSARIAL_LENS {
        for trial in 0..40 {
            let d = nnz_target.max(1) + rng.gen_range(16);
            let nnz = nnz_target.min(d);
            let idx = random_indices(&mut rng, d, nnz);
            let val = adversarial_values(&mut rng, nnz);
            let w = adversarial_values(&mut rng, d);
            let got = kernels::sparse_dot(&idx, &val, &w);
            let want = unsafe { kernels::scalar::sparse_dot_unchecked(&idx, &val, &w) };
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "sparse_dot diverged from scalar (nnz={nnz} d={d} trial={trial})"
            );

            let coef = rng.normal();
            let mut got_out = adversarial_values(&mut rng, d);
            let mut want_out = got_out.clone();
            kernels::sparse_axpy(&idx, &val, coef, &mut got_out);
            unsafe { kernels::scalar::sparse_axpy_unchecked(&idx, &val, coef, &mut want_out) };
            for (j, (x, y)) in got_out.iter().zip(&want_out).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "sparse_axpy diverged from scalar (nnz={nnz} d={d} trial={trial} col={j})"
                );
            }

            let got_n = kernels::sparse_norm_sq(&val);
            let want_n = kernels::scalar::sparse_norm_sq(&val);
            assert_eq!(got_n.to_bits(), want_n.to_bits(), "sparse_norm_sq diverged");
        }
    }
}

#[test]
fn threaded_local_update_is_deterministic_and_matches_its_sequential_schedule() {
    // The deterministic-per-T contract at the solver level: for each T,
    // two runs from the same RNG state are bit-identical, and the
    // scoped-thread execution is bit-identical to the same schedule
    // replayed sequentially on one thread (so OS scheduling can never
    // leak into a trajectory). T=1 must reproduce the legacy solver.
    let mut seed_rng = Rng::seed_from_u64(0x7eaded);
    for trial in 0..4 {
        let n = 24 + seed_rng.gen_range(40);
        let d = 12 + seed_rng.gen_range(50);
        let data = random_sparse_dataset(&mut seed_rng, n, d);
        let block = Block::new(data, 0.05 * n as f64);
        let alpha = vec![0.0; n];
        let w: Vec<f64> = (0..d).map(|j| (j as f64 * 0.7).cos() * 0.2).collect();
        let h = 4 * n;
        for t in [1usize, 2, 4] {
            let solver = LocalSdca::new(Sampling::WithReplacement).with_threads(t);
            let mut rng_a = Rng::seed_from_u64(trial * 101 + 13);
            let mut rng_b = rng_a.clone();
            let mut rng_c = rng_a.clone();
            let up_a = solver.local_update(&block, &SmoothedHinge::new(0.5), &alpha, &w, h, &mut rng_a);
            let up_b = solver.local_update(&block, &SmoothedHinge::new(0.5), &alpha, &w, h, &mut rng_b);
            let up_seq = solver.local_update_sequential_schedule(
                &block, &SmoothedHinge::new(0.5), &alpha, &w, h, &mut rng_c,
            );
            for (which, other) in [("repeat run", &up_b), ("sequential schedule", &up_seq)] {
                for (a, b) in up_a.dalpha.iter().zip(&other.dalpha) {
                    assert_eq!(a.to_bits(), b.to_bits(), "T={t}: dalpha diverged vs {which}");
                }
                for (a, b) in up_a.dw.iter().zip(&other.dw) {
                    assert_eq!(a.to_bits(), b.to_bits(), "T={t}: dw diverged vs {which}");
                }
            }
            // the RNG must advance identically regardless of execution mode
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "T={t}: RNG stream diverged");
            if t == 1 {
                // T=1 is the legacy sequential path, bit for bit
                let legacy = LocalSdca::new(Sampling::WithReplacement);
                let mut rng_d = Rng::seed_from_u64(trial * 101 + 13);
                let up_legacy =
                    legacy.local_update(&block, &SmoothedHinge::new(0.5), &alpha, &w, h, &mut rng_d);
                for (a, b) in up_a.dalpha.iter().zip(&up_legacy.dalpha) {
                    assert_eq!(a.to_bits(), b.to_bits(), "T=1 diverged from the legacy solver");
                }
            }
        }
    }
}

#[test]
fn threaded_sessions_produce_bit_identical_traces_per_thread_count() {
    // Session-level determinism: for each T, two full training runs with
    // the same seed produce bit-identical TraceRow streams and final w.
    // The T=1 session must also match a session that never called
    // `.threads()` at all (the pre-threading builder path).
    use cocoa::algorithms::{Budget, Cocoa};
    use cocoa::api::Trainer;
    use cocoa::data::cov_like;
    use cocoa::loss::LossKind;

    let data = cov_like(160, 12, 0.1, 9);
    let run = |threads: Option<usize>| {
        let mut b = Trainer::on(&data)
            .workers(2)
            .loss(LossKind::SmoothedHinge { gamma: 1.0 })
            .lambda(0.05)
            .seed(7)
            .label("prop_threads");
        if let Some(t) = threads {
            b = b.threads(t);
        }
        let mut session = b.build().unwrap();
        let trace = session.run(&mut Cocoa::new(80), Budget::rounds(6)).unwrap();
        let w = session.w().to_vec();
        session.shutdown();
        (trace, w)
    };

    let (base_trace, base_w) = run(None);
    for t in [1usize, 2, 4] {
        let (t1, w1) = run(Some(t));
        let (t2, w2) = run(Some(t));
        assert_eq!(t1.rows.len(), t2.rows.len(), "T={t}: trace lengths diverged");
        for (ra, rb) in t1.rows.iter().zip(&t2.rows) {
            assert_eq!(ra.primal.to_bits(), rb.primal.to_bits(), "T={t}: primal diverged");
            assert_eq!(ra.dual.to_bits(), rb.dual.to_bits(), "T={t}: dual diverged");
            assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "T={t}: gap diverged");
            assert_eq!(ra.inner_steps, rb.inner_steps, "T={t}: inner_steps diverged");
        }
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.to_bits(), b.to_bits(), "T={t}: final w diverged between runs");
        }
        if t == 1 {
            // one thread == the builder default == the legacy path
            for (ra, rb) in t1.rows.iter().zip(&base_trace.rows) {
                assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "T=1 diverged from default");
            }
            for (a, b) in w1.iter().zip(&base_w) {
                assert_eq!(a.to_bits(), b.to_bits(), "T=1 final w diverged from default");
            }
        }
    }
}

#[test]
fn csr_rows_are_duplicate_free_and_sorted() {
    let mut rng = Rng::seed_from_u64(0xc52);
    let data = random_sparse_dataset(&mut rng, 60, 25);
    match &data.features {
        Features::Sparse(m) => {
            for i in 0..m.rows() {
                let idx = m.row_view(i).0;
                assert!(
                    idx.windows(2).all(|p| p[0] < p[1]),
                    "row {i} violates the strictly-increasing index invariant: {idx:?}"
                );
                assert!(idx.iter().all(|&c| (c as usize) < m.cols()));
            }
        }
        Features::Dense(_) => unreachable!(),
    }
}
