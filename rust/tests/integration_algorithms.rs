//! Algorithm-level integration: the Section-6 competitors produce the
//! qualitative results the paper reports — CoCoA wins on communication,
//! mini-batch methods are beta-sensitive, one-shot averaging is biased.
//! Everything runs through the public `Trainer`/`Session` surface.

use cocoa::data::cov_like;
use cocoa::objective;
use cocoa::prelude::*;

fn data() -> Dataset {
    cov_like(400, 10, 0.08, 42)
}

fn session(data: &Dataset, k: usize, net: NetworkModel, seed: u64) -> Session {
    Trainer::on(data)
        .workers(k)
        .loss(LossKind::Hinge)
        .lambda(0.02)
        .network(net)
        .seed(seed)
        .label("cov")
        .build()
        .unwrap()
}

fn p_star(data: &Dataset) -> f64 {
    objective::compute_optimum(data, 0.02, &cocoa::loss::Hinge, 1e-9, 1000).0
}

#[test]
fn cocoa_reaches_milli_accuracy_with_fewer_vectors() {
    // Figure 2's qualitative claim: per communicated vector, CoCoA makes
    // far more progress than frozen-w mini-batch CD at the same H.
    let data = data();
    let p = p_star(&data);
    let h = 100; // full local pass per round (n_k = 100 at K = 4)
    let budget = Budget::rounds(300).target_subopt(5e-4);

    let mut sess = session(&data, 4, NetworkModel::free(), 1);
    sess.set_reference_optimum(Some(p));
    let cocoa_trace = sess.run(&mut Cocoa::new(h), budget).unwrap();
    sess.reset().unwrap();
    let mb_trace = sess.run(&mut MinibatchCd::new(h), budget).unwrap();
    sess.shutdown();

    let cocoa_v = cocoa_trace.vectors_to_subopt(1e-3);
    let mb_v = mb_trace.vectors_to_subopt(1e-3);
    assert!(cocoa_v.is_some(), "cocoa never hit 1e-3");
    match mb_v {
        None => {} // mini-batch never got there within budget: consistent
        Some(v) => {
            let c = cocoa_v.unwrap();
            assert!(
                (v as f64) > 3.0 * c as f64,
                "expected >=3x vector advantage, got {c} vs {v}"
            );
        }
    }
}

#[test]
fn naive_cd_pays_heavy_communication_under_ec2_model() {
    // Same total coordinate steps; naive (H=1) pays per-round latency for
    // every step while CoCoA amortizes it H-fold.
    let data = data();
    let p = p_star(&data);
    let net = NetworkModel::ec2_like();

    let mut sess = session(&data, 4, net, 2);
    sess.set_reference_optimum(Some(p));
    let cocoa_trace = sess.run(&mut Cocoa::new(100), Budget::rounds(10)).unwrap();
    sess.reset().unwrap();
    // 1000 rounds x 1 step = same steps as cocoa
    let naive_trace = sess
        .run(&mut NaiveCd, Budget::rounds(1000).eval_every(50))
        .unwrap();
    sess.shutdown();

    let cocoa_last = cocoa_trace.rows.last().unwrap();
    let naive_last = naive_trace.rows.last().unwrap();
    assert_eq!(cocoa_last.inner_steps, naive_last.inner_steps);
    assert!(
        naive_last.sim_time_s > 10.0 * cocoa_last.sim_time_s,
        "naive sim time {} not >> cocoa {}",
        naive_last.sim_time_s,
        cocoa_last.sim_time_s
    );
}

#[test]
fn aggressive_beta_b_destabilizes_minibatch_cd() {
    // [RT13]: adding (beta_b = b) instead of averaging can diverge.
    // At minimum, the objective trajectory must be visibly worse/unstable
    // compared to the safe averaging choice on correlated data.
    let data = data();
    let h = 100;
    let b_total = (h * 4) as f64;

    let run_beta = |beta: f64, seed: u64| {
        let mut sess = session(&data, 4, NetworkModel::free(), seed);
        let tr = sess
            .run(
                &mut MinibatchCd::new(h).beta_b(beta),
                Budget::rounds(25).eval_every(25),
            )
            .unwrap();
        sess.shutdown();
        tr.rows.last().unwrap().gap
    };

    let safe = run_beta(1.0, 3);
    let aggressive = run_beta(b_total, 3);
    assert!(
        !aggressive.is_finite() || aggressive > safe,
        "adding should be worse than averaging here: {aggressive} vs {safe}"
    );
}

#[test]
fn one_shot_averaging_leaves_residual_bias() {
    // [SSZ14]: the average of locally-optimal models is NOT the optimum on
    // correlated data — one_shot must end with a materially larger gap
    // than a few CoCoA rounds at the same local effort.
    let data = data();
    let mut sess = session(&data, 4, NetworkModel::free(), 4);
    let one_shot = sess.run(&mut OneShotAvg, Budget::rounds(1)).unwrap();
    let bias_gap = one_shot.rows.last().unwrap().gap;
    assert!(bias_gap > 1e-4, "one-shot suspiciously optimal: {bias_gap}");

    sess.reset().unwrap();
    let cocoa_tr = sess
        .run(&mut Cocoa::new(100), Budget::rounds(30).eval_every(30))
        .unwrap();
    sess.shutdown();
    let cocoa_gap = cocoa_tr.rows.last().unwrap().gap;
    assert!(
        cocoa_gap < bias_gap * 0.5,
        "cocoa {cocoa_gap} should beat one-shot bias {bias_gap}"
    );
}

#[test]
fn local_sgd_beats_minibatch_sgd() {
    // The locally-updating variant dominates the frozen-gradient variant —
    // the same local-vs-frozen contrast as CD, on the SGD side.
    let data = data();
    let p = p_star(&data);
    let h = 100;
    let budget = Budget::rounds(40).eval_every(40);

    let run_algo = |algo: &mut dyn Algorithm, seed: u64| {
        let mut sess = session(&data, 4, NetworkModel::free(), seed);
        sess.set_reference_optimum(Some(p));
        let tr = sess.run(algo, budget).unwrap();
        sess.shutdown();
        tr.rows.last().unwrap().primal_subopt
    };

    let local = run_algo(&mut LocalSgd::new(h), 5);
    let frozen = run_algo(&mut MinibatchSgd::new(h), 5);
    assert!(
        local < frozen,
        "local-SGD {local} should beat mini-batch SGD {frozen}"
    );
}

#[test]
fn h_sweep_shows_communication_compute_tradeoff() {
    // Figure 3: under a costly network, larger H converges faster in
    // simulated time (up to a point) — every grid point warm-starts the
    // same session.
    let data = data();
    let p = p_star(&data);
    let mut sess = session(&data, 4, NetworkModel::ec2_like(), 6);
    sess.set_reference_optimum(Some(p));
    let mut time_at_h = Vec::new();
    for h in [1usize, 10, 100] {
        sess.reset().unwrap();
        let tr = sess
            .run(
                &mut Cocoa::new(h),
                Budget::until_subopt(1e-3).max_rounds(4000).eval_every(10),
            )
            .unwrap();
        time_at_h.push((h, tr.time_to_subopt(1e-3)));
    }
    sess.shutdown();
    let t1 = time_at_h[0].1;
    let t100 = time_at_h[2].1;
    assert!(t100.is_some(), "H=100 never reached target: {time_at_h:?}");
    if let (Some(a), Some(b)) = (t1, t100) {
        assert!(b < a, "H=100 ({b}) should beat H=1 ({a}) on a slow network");
    }
}

#[test]
fn cocoa_plus_adding_is_safe_and_competitive() {
    // The extension resolving the conclusion's open problem: beta_K = K
    // adding with sigma' = K scaled subproblems must (a) not diverge and
    // (b) be at least comparable to safe averaging per round (it typically
    // wins as K grows). Aggregation::Add, end-to-end.
    let data = data();
    let h = 100;
    let mut sess = session(&data, 8, NetworkModel::free(), 7);
    let budget = Budget::rounds(20).eval_every(20);
    let plain_tr = sess.run(&mut Cocoa::new(h), budget).unwrap();
    sess.reset().unwrap();
    let plus_tr = sess.run(&mut Cocoa::adding(h), budget).unwrap();
    sess.shutdown();
    assert_eq!(plus_tr.algorithm, "cocoa_plus");
    let plain = plain_tr.rows.last().unwrap().gap;
    let plus = plus_tr.rows.last().unwrap().gap;
    assert!(plus.is_finite() && plus > -1e-9, "cocoa+ diverged: {plus}");
    assert!(
        plus < plain * 2.0,
        "cocoa+ ({plus}) should be comparable to averaging ({plain})"
    );
}

#[test]
fn unsafe_adding_without_sigma_scaling_is_worse() {
    // beta_K = K *without* the sigma' correction (plain averaging scaled
    // to beta_k = K) is the aggressive update the paper warns about; on
    // correlated data it must do worse than CoCoA+ at the same
    // aggregation aggressiveness.
    let data = data();
    let h = 100;
    let k = 8;
    let mut sess = session(&data, k, NetworkModel::free(), 9);
    let budget = Budget::rounds(15).eval_every(15);
    let unsafe_add = sess
        .run(&mut Cocoa::averaging(h, k as f64), budget)
        .unwrap()
        .rows
        .last()
        .unwrap()
        .gap;
    sess.reset().unwrap();
    let safe_add = sess
        .run(&mut Cocoa::adding(h), budget)
        .unwrap()
        .rows
        .last()
        .unwrap()
        .gap;
    sess.shutdown();
    assert!(
        !unsafe_add.is_finite() || unsafe_add > safe_add,
        "unscaled adding ({unsafe_add}) should underperform cocoa+ ({safe_add})"
    );
}
