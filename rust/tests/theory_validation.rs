//! Theorem 2 / Proposition 1 validation across a grid of (K, H, lambda):
//! the measured per-round dual contraction must respect the predicted
//! geometric rate (the bound), and the qualitative dependencies the paper
//! derives must show up in the measurements.

use cocoa::data::cov_like;
use cocoa::experiments::theory_val::validate;
use cocoa::theory;

#[test]
fn bound_respected_across_k_grid() {
    let data = cov_like(400, 12, 0.05, 77);
    let lambda = 10.0 / 400.0;
    for k in [1usize, 2, 4, 8] {
        let rep = validate(&data, k, 60, lambda, 1.0, 12, 3).unwrap();
        assert!(
            rep.bound_respected,
            "K={k}: measured {} > predicted {}",
            rep.measured_rate, rep.predicted_rate
        );
        assert!(rep.measured_rate < 1.0, "K={k}: no progress at all");
    }
}

#[test]
fn bound_respected_across_h_grid() {
    let data = cov_like(300, 10, 0.05, 78);
    let lambda = 10.0 / 300.0;
    let mut rates = Vec::new();
    for h in [5usize, 25, 100, 400] {
        let rep = validate(&data, 3, h, lambda, 1.0, 12, 4).unwrap();
        assert!(rep.bound_respected, "H={h} violates Theorem 2");
        rates.push((h, rep.measured_rate, rep.predicted_rate));
    }
    // larger H => faster measured AND predicted per-round rate
    for pair in rates.windows(2) {
        assert!(
            pair[1].1 <= pair[0].1 + 0.05,
            "measured rate not improving with H: {rates:?}"
        );
        assert!(pair[1].2 < pair[0].2);
    }
}

#[test]
fn k1_matches_serial_sdca_theory() {
    // K = 1: Theorem 2 collapses to Theta (the remark after Lemma 3).
    let data = cov_like(200, 8, 0.05, 79);
    let lambda = 10.0 / 200.0;
    let rep = validate(&data, 1, 50, lambda, 1.0, 15, 5).unwrap();
    let theta = theory::theta_local_sdca(50, lambda, 200, 1.0, 200);
    assert!((rep.predicted_rate - theta).abs() < 1e-9);
    assert!(rep.sigma < 1e-6, "K=1 sigma should vanish: {}", rep.sigma);
}

#[test]
fn rate_prediction_is_not_vacuous() {
    // the predicted rate should be < 1 by a usable margin for sane
    // configurations — otherwise the bound predicts nothing
    let data = cov_like(300, 10, 0.05, 80);
    let lambda = 10.0 / 300.0;
    let rep = validate(&data, 4, 300, lambda, 1.0, 10, 6).unwrap();
    assert!(
        rep.predicted_rate < 0.999,
        "vacuous bound: {}",
        rep.predicted_rate
    );
}
