//! Serving acceptance: the online-serving subsystem must be *provably
//! passive* — a training run with a [`SnapshotSink`] publishing every
//! round and live scorers hammering the handle (in-process and over a
//! real UDS scoring socket) is bit-identical to a bare run — and a
//! snapshot at round `r` must score exactly like a checkpoint taken at
//! round `r`, restored offline. Continuous training rides along: the
//! post-append duality gap obeys the documented bound, warm restarts
//! resume convergence, and a live-appended session trains bit-identically
//! to a shard set grown on disk.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cocoa::coordinator::Checkpoint;
use cocoa::data::{append_shard_rows, cov_like, rcv1_like, write_shards};
use cocoa::prelude::*;
use cocoa::serve::ScoreIdentity;
use cocoa::transport::{Ledger, MessageKind};

const N: usize = 120;
const D: usize = 10;
const NOISE: f64 = 0.1;
const SEED: u64 = 7;
const LAMBDA: f64 = 0.05;
const H: usize = 25;
const ROUNDS: u64 = 5;
const K: usize = 2;

/// Everything deterministic a trajectory is, bit for bit. `sim_time_s`
/// is deliberately excluded: timing columns fold in measured thread-CPU
/// seconds, which no two runs share.
fn row_bits(tr: &Trace) -> Vec<(u64, u64, u64, u64, u64, u64)> {
    tr.rows
        .iter()
        .map(|r| {
            (
                r.round,
                r.primal.to_bits(),
                r.dual.to_bits(),
                r.gap.to_bits(),
                r.inner_steps,
                r.bytes_measured,
            )
        })
        .collect()
}

/// The bare twin every served run is compared against: in-process,
/// counted, no sink, no scorers.
fn bare_run(data: &Dataset) -> (Trace, Vec<u64>, Ledger) {
    let mut session = Trainer::on(data)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Counted)
        .build()
        .unwrap();
    let trace = session.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
    let w = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();
    session.shutdown();
    (trace, w, ledger)
}

fn assert_ledgers_match(ledger: &Ledger, bare: &Ledger) {
    for kind in [
        MessageKind::Broadcast,
        MessageKind::Commit,
        MessageKind::DeltaW,
        MessageKind::EvalRequest,
        MessageKind::EvalReply,
        MessageKind::Metrics,
    ] {
        assert_eq!(ledger.bytes(kind), bare.bytes(kind), "{kind:?} bytes");
        assert_eq!(ledger.msgs(kind), bare.msgs(kind), "{kind:?} msgs");
    }
}

/// In-process: a sink publishing every round plus a scorer thread
/// hammering the handle for the whole run change nothing — trajectory,
/// final `w`, and the per-kind ledger are bit-identical to the bare run,
/// and the final published snapshot IS the final `w`.
#[test]
fn live_scoring_is_passive_in_proc() {
    let data = cov_like(N, D, NOISE, SEED);
    let (bare_trace, bare_w, bare_ledger) = bare_run(&data);

    let mut session = Trainer::on(&data)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Counted)
        .build()
        .unwrap();
    let mut sink = SnapshotSink::for_session(&session, 1);
    let handle = sink.handle();

    // scoring traffic throughout the run — passivity must hold with
    // readers actually contending on the handle, not just attached
    let stop = Arc::new(AtomicBool::new(false));
    let scorer_thread = {
        let stop = Arc::clone(&stop);
        let scorer = Scorer::live(handle.clone());
        let batch = data.subset(&(0..16u32).collect::<Vec<_>>()).features;
        thread::spawn(move || {
            let mut scored = 0u64;
            loop {
                let out = scorer.score_batch(&batch).unwrap();
                assert_eq!(out.margins.len(), 16);
                scored += out.margins.len() as u64;
                if stop.load(Ordering::Relaxed) {
                    return scored;
                }
                thread::yield_now();
            }
        })
    };

    let mut algo = Cocoa::new(H);
    let trace = {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.drain().unwrap()
    };
    stop.store(true, Ordering::Relaxed);
    let scored = scorer_thread.join().unwrap();
    assert!(scored > 0, "the scorer never ran");

    let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();

    // the current snapshot is the committed round-R iterate, stamped
    let snap = handle.current();
    assert_eq!(snap.round, ROUNDS);
    assert_eq!(snap.epoch, ROUNDS + 1, "round-0 seed + one publish per round");
    assert_eq!(snap.fingerprint, session.fingerprint());
    assert_eq!(snap.loss, session.loss().to_string());
    let snap_bits: Vec<u64> = snap.w.iter().map(|x| x.to_bits()).collect();
    assert_eq!(snap_bits, w, "published model != leader w");
    session.shutdown();

    assert_eq!(row_bits(&trace), row_bits(&bare_trace), "served run diverged");
    assert_eq!(w, bare_w, "final w diverged");
    assert_ledgers_match(&ledger, &bare_ledger);
}

/// The staleness contract: a sink publishing every `c` rounds leaves the
/// handle at most `c - 1` completed rounds behind the trainer.
#[test]
fn publication_cadence_bounds_staleness() {
    let data = cov_like(N, D, NOISE, SEED);
    let mut session = Trainer::on(&data)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .build()
        .unwrap();
    let mut sink = SnapshotSink::for_session(&session, 2);
    let handle = sink.handle();
    let mut algo = Cocoa::new(H);
    {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.drain().unwrap();
    }
    session.shutdown();

    let snap = handle.current();
    // 5 rounds at every=2: published at rounds 0, 2, 4
    assert_eq!(snap.round, ROUNDS - 1);
    assert_eq!(snap.epoch, 3);
    assert!(ROUNDS - snap.round <= 1, "staleness exceeded every - 1");
}

/// The acceptance criterion: with `every = 1`, predictions from the
/// snapshot at round `r` are bit-identical to offline scoring with a
/// checkpoint taken at round `r`, saved, loaded, and restored into a
/// fresh session.
#[test]
fn snapshot_predictions_match_checkpoint_restored_scoring() {
    let data = cov_like(N, D, NOISE, SEED);
    let build = || {
        Trainer::on(&data)
            .workers(K)
            .loss(LossKind::Hinge)
            .lambda(LAMBDA)
            .seed(SEED)
            .build()
            .unwrap()
    };

    let mut session = build();
    let mut sink = SnapshotSink::for_session(&session, 1);
    let handle = sink.handle();
    let mut algo = Cocoa::new(H);
    {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.drain().unwrap();
    }
    let cp = session.checkpoint().unwrap();
    session.shutdown();

    let dir = std::env::temp_dir().join(format!("cocoa_serving_cp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("round_r.ckpt");
    cp.save(&path).unwrap();
    let cp = Checkpoint::load(&path).unwrap();
    assert_eq!(cp.round_counter, ROUNDS);

    // offline path: restore the checkpoint, freeze its w into a snapshot
    let mut offline = build();
    offline.restore(&cp).unwrap();
    let frozen = Scorer::frozen(ModelSnapshot {
        epoch: 0,
        round: cp.round_counter,
        w: offline.w().to_vec(),
        loss: offline.loss().to_string(),
        regularizer: offline.regularizer().to_string(),
        fingerprint: offline.fingerprint().to_string(),
    });
    offline.shutdown();

    let live_snap = handle.current();
    assert_eq!(live_snap.round, cp.round_counter, "snapshot/checkpoint round drift");
    let live = Scorer::frozen((*live_snap).clone());

    let a = live.score_batch(&data.features).unwrap();
    let b = frozen.score_batch(&data.features).unwrap();
    assert_eq!(a.margins.len(), N);
    assert_eq!(a.round, b.round);
    for (i, (x, y)) in a.margins.iter().zip(&b.margins).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "margin {i}: {x} vs {y}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// UDS serving: a `ScoreServer` on a real socket with a client scoring
/// throughout the run is passive (trajectory, w, ledger bit-identical to
/// bare), and a post-run request returns margins bit-identical to
/// offline `row_dot` against the final w.
#[test]
fn uds_score_server_with_live_traffic_is_passive() {
    let data = cov_like(N, D, NOISE, SEED);
    let (bare_trace, bare_w, bare_ledger) = bare_run(&data);

    let sock = std::env::temp_dir().join(format!("cocoa_serving_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let addr = format!("uds:{}", sock.display());

    let mut session = Trainer::on(&data)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .transport(TransportKind::Counted)
        .build()
        .unwrap();
    let mut sink = SnapshotSink::for_session(&session, 1);
    let server = ScoreServer::serve(&addr, Scorer::live(sink.handle())).unwrap();

    // a client scoring over the socket for the whole run
    let stop = Arc::new(AtomicBool::new(false));
    let client_thread = {
        let stop = Arc::clone(&stop);
        let addr = addr.clone();
        let batch = data.subset(&(0..8u32).collect::<Vec<_>>()).features;
        thread::spawn(move || {
            let mut client =
                ScoreClient::connect_with_retry(&addr, &ScoreIdentity::any(), 100, 0.01).unwrap();
            let mut scored = 0u64;
            loop {
                let out = client.score(&batch).unwrap();
                assert_eq!(out.margins.len(), 8);
                scored += out.margins.len() as u64;
                if stop.load(Ordering::Relaxed) {
                    return scored;
                }
                thread::sleep(Duration::from_millis(1));
            }
        })
    };

    let mut algo = Cocoa::new(H);
    let trace = {
        let mut driver = session.drive(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
        driver.observe(&mut sink).unwrap();
        driver.drain().unwrap()
    };
    stop.store(true, Ordering::Relaxed);
    let scored_mid_run = client_thread.join().unwrap();
    assert!(scored_mid_run > 0, "the client never scored");

    let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();
    let ledger = session.ledger().unwrap().clone();

    // guaranteed post-run request, bound to the exact identity this
    // session serves — margins must equal offline scoring bit for bit
    let identity = ScoreIdentity {
        d: data.d(),
        fingerprint: session.fingerprint().to_string(),
        loss: session.loss().to_string(),
    };
    let mut client = ScoreClient::connect_with_retry(&addr, &identity, 10, 0.05).unwrap();
    let out = client.score(&data.features).unwrap();
    assert_eq!(out.round, ROUNDS);
    assert_eq!(out.margins.len(), N);
    let w_f64 = session.w().to_vec();
    for (i, m) in out.margins.iter().enumerate() {
        let local = data.features.row_dot(i, &w_f64);
        assert_eq!(m.to_bits(), local.to_bits(), "row {i}: remote {m} vs local {local}");
    }
    assert!(server.predictions_served() >= scored_mid_run + N as u64);
    server.shutdown();
    session.shutdown();

    assert_eq!(row_bits(&trace), row_bits(&bare_trace), "UDS-served run diverged");
    assert_eq!(w, bare_w, "final w diverged");
    assert_ledgers_match(&ledger, &bare_ledger);
    let _ = std::fs::remove_file(&sock);
}

/// Continuous training: appending `m` rows at a round boundary moves the
/// duality gap by no more than the documented decomposition
/// (docs/SERVING.md), and the warm restart then *resumes* convergence
/// instead of restarting it.
///
/// With hinge + L2, appending rescales `w' = (n/n')·w` and keeps every
/// dual variable, so with `Σℓ* = conj_sum` recovered from the dual value:
///
/// ```text
/// gap' - gap = λ‖w‖²(ρ²-1)                              (≤ 0, dropped)
///            + S_new/n'                                 (new rows' loss)
///            + (S_old(w')/n' - S_old(w)/n)              (old loss re-weighted)
///            + conj_sum·(1/n' - 1/n)                    (conjugate re-weighted)
/// ```
#[test]
fn append_gap_obeys_the_documented_bound_and_warm_restart_converges() {
    let base = cov_like(N, D, NOISE, SEED);
    let batch = cov_like(40, D, NOISE, SEED ^ 0x9e);
    let hinge_sum = |ds: &Dataset, w: &[f64]| -> f64 {
        (0..ds.n())
            .map(|i| (1.0 - ds.labels[i] * ds.features.row_dot(i, w)).max(0.0))
            .sum()
    };

    let mut session = Trainer::on(&base)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .build()
        .unwrap();
    let mut algo = Cocoa::new(H);
    let pre_trace = session.run(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
    let pre = pre_trace.rows.last().unwrap();
    let (gap_pre, dual_pre) = (pre.gap, pre.dual);
    let w_pre = session.w().to_vec();
    let fp_pre = session.fingerprint().to_string();

    let n_old = base.n();
    let n_new = n_old + batch.n();
    let s_old = hinge_sum(&base, &w_pre);
    let w_norm_sq: f64 = w_pre.iter().map(|x| x * x).sum();
    // D = -(λ/2)‖w‖² - conj_sum/n  =>  conj_sum = -(D + (λ/2)‖w‖²)·n
    let conj_sum = -(dual_pre + 0.5 * LAMBDA * w_norm_sq) * n_old as f64;

    session.append_rows(&batch).unwrap();
    assert_eq!(session.n(), n_new);
    assert_ne!(session.fingerprint(), fp_pre, "append must chain the fingerprint");
    let w_post = session.w().to_vec(); // = (n_old/n_new)·w_pre under the L2 prox
    let s_old_post = hinge_sum(&base, &w_post);
    let s_new = hinge_sum(&batch, &w_post);

    let post_trace = session.run(&mut algo, MaxRounds::new(ROUNDS)).unwrap();
    let first = &post_trace.rows[0];
    assert_eq!(first.round, 0, "the post-append drive must evaluate before working");
    let gap_post = first.gap;

    let (inv_new, inv_old) = (1.0 / n_new as f64, 1.0 / n_old as f64);
    let bound = gap_pre
        + s_new * inv_new
        + (s_old_post * inv_new - s_old * inv_old).max(0.0)
        + (conj_sum * (inv_new - inv_old)).max(0.0)
        + 1e-9;
    assert!(
        gap_post <= bound,
        "post-append gap {gap_post} exceeds the documented bound {bound} \
         (gap_pre {gap_pre}, S_new {s_new}, S_old {s_old} -> {s_old_post})"
    );

    // warm restart: retained duals mean training resumes, not restarts
    let last = post_trace.rows.last().unwrap();
    assert!(last.gap.is_finite() && last.gap >= -1e-9);
    assert!(
        last.gap < gap_post,
        "warm restart made no progress: {} -> {}",
        gap_post,
        last.gap
    );
    session.shutdown();
}

/// A session that appends a batch live trains bit-identically to a shard
/// set grown on disk by `append_shard_rows` — the durable and in-memory
/// append paths are the same problem, row for row, norm for norm.
#[test]
fn live_append_matches_disk_grown_shards_bitwise() {
    let base = rcv1_like(96, 40, 8, 0.1, 11);
    let batch = rcv1_like(30, 40, 8, 0.1, 12);

    // live: build on the base, grow in memory, then train
    let mut live = Trainer::on(&base)
        .workers(K)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .label("grown")
        .build()
        .unwrap();
    live.append_rows(&batch).unwrap();
    let live_fp = live.fingerprint().to_string();
    let live_trace = live.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
    let live_w: Vec<u64> = live.w().iter().map(|x| x.to_bits()).collect();
    live.shutdown();

    // disk: shard the base, grow the set on disk, reopen, train
    let dir = std::env::temp_dir().join(format!("cocoa_serving_grow_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    write_shards(&base, PartitionStrategy::Contiguous, K, 0, &dir).unwrap();
    let set = append_shard_rows(&dir, &batch).unwrap();
    assert_eq!(set.n(), base.n() + batch.n());
    assert_eq!(set.fingerprint(), live_fp, "append fingerprint chains must agree");

    let mut disk = Trainer::on_shards(&set)
        .loss(LossKind::Hinge)
        .lambda(LAMBDA)
        .seed(SEED)
        .label("grown")
        .build()
        .unwrap();
    let disk_trace = disk.run(&mut Cocoa::new(H), MaxRounds::new(ROUNDS)).unwrap();
    let disk_w: Vec<u64> = disk.w().iter().map(|x| x.to_bits()).collect();
    disk.shutdown();

    assert_eq!(row_bits(&live_trace), row_bits(&disk_trace), "grown trajectories diverged");
    assert_eq!(live_w, disk_w, "grown final w diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
