//! CLI smoke tests: the `cocoa` binary end-to-end — gen-data, train from a
//! TOML config, optimum, and bad-input error paths.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cocoa"))
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cocoa_cli_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("repro"));
    assert!(text.contains("train"));
}

#[test]
fn no_subcommand_prints_full_usage_and_exits_nonzero() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    for cmd in ["train", "repro", "perf", "optimum", "leader", "worker"] {
        assert!(text.contains(&format!("cocoa {cmd}")), "usage is missing {cmd}: {text}");
    }
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    // an unknown subcommand names itself and shows the real usage
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("frobnicate"), "stderr: {text}");
    assert!(text.contains("cocoa leader"), "stderr: {text}");
    assert!(text.contains("cocoa worker"), "stderr: {text}");
}

#[test]
fn leader_and_workers_run_over_uds_end_to_end() {
    let dir = tmpdir("leaderworker");
    let cfg_path = dir.join("exp.toml");
    let sock = dir.join("cluster.sock");
    let _ = std::fs::remove_file(&sock);
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 200
d = 8
seed = 3

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 100

[loss]
kind = "hinge"

[run]
rounds = 5

[transport]
kind = "net"
"#,
    )
    .unwrap();
    let listen = format!("uds:{}", sock.display());
    let workers: Vec<_> = (0..2)
        .map(|_| {
            bin()
                .arg("worker")
                .args(["--config"])
                .arg(&cfg_path)
                .args(["--connect", &listen, "--attempts", "40", "--backoff-s", "0.25"])
                .spawn()
                .unwrap()
        })
        .collect();
    let out = bin()
        .arg("leader")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--listen", &listen, "--workers", "2", "--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=5"), "stdout: {stdout}");
    assert!(stdout.contains("socket: sent"), "stdout: {stdout}");
    for mut w in workers {
        let status = w.wait().unwrap();
        assert!(status.success(), "worker exited nonzero");
    }
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 7); // header + rounds 0..=5
}

#[test]
fn leader_rejects_worker_count_mismatch() {
    let dir = tmpdir("leadermismatch");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        "lambda = 0.01\n\n[dataset]\nkind = \"cov_like\"\nn = 50\nd = 4\n\n\
         [partition]\nk = 2\n\n[algorithm]\nname = \"cocoa\"\nh = 10\n",
    )
    .unwrap();
    let out = bin()
        .arg("leader")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--listen", "uds:/tmp/never-bound.sock", "--workers", "3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--workers 3"), "stderr: {text}");
}

#[test]
fn gen_data_writes_libsvm() {
    let dir = tmpdir("gendata");
    let path = dir.join("toy.svm");
    let out = bin()
        .args(["gen-data", "cov", "--n", "50", "--d", "6", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 50);
    assert!(text.lines().all(|l| l.starts_with("+1") || l.starts_with("-1")));
}

#[test]
fn train_runs_config_and_writes_trace() {
    let dir = tmpdir("train");
    let cfg_path = dir.join("exp.toml");
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 200
d = 8
seed = 3

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 100

[loss]
kind = "hinge"

[run]
rounds = 5
"#,
    )
    .unwrap();
    let out = bin()
        .arg("train")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=5"), "stdout: {stdout}");
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 7); // header + rounds 0..=5
    assert!(trace.lines().next().unwrap().starts_with("round,sim_time_s"));
}

#[test]
fn train_progress_streams_live_round_lines() {
    let dir = tmpdir("train_progress");
    let cfg_path = dir.join("exp.toml");
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 120
d = 6
seed = 5

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 40

[loss]
kind = "hinge"

[run]
rounds = 4
"#,
    )
    .unwrap();
    let out = bin()
        .arg("train")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--progress", "--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // the progress observer streams one line per evaluated round to
    // stderr (round, gap, bytes, sim time) and names the stop reason
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cocoa round"), "stderr: {stderr}");
    assert!(stderr.contains("| gap"), "stderr: {stderr}");
    assert!(stderr.contains("| sim") || stderr.contains("sim "), "stderr: {stderr}");
    assert!(stderr.contains("stopped: max_rounds"), "stderr: {stderr}");
    // one line per evaluated round: 0..=4, plus the stop line
    assert_eq!(stderr.matches("cocoa round").count(), 5, "stderr: {stderr}");
    // stdout summary and the trace file are unaffected by --progress
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=4"), "stdout: {stdout}");
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 6); // header + rounds 0..=4
}

#[test]
fn train_rejects_bad_config() {
    let dir = tmpdir("badcfg");
    let cfg_path = dir.join("bad.toml");
    std::fs::write(&cfg_path, "lambda = \"not a number\"\n").unwrap();
    let out = bin().arg("train").arg("--config").arg(&cfg_path).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn repro_table1_smoke() {
    let out = bin().args(["repro", "table1", "--smoke"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cov"));
    assert!(text.contains("rcv1"));
    assert!(text.contains("imagenet"));
}

#[test]
fn perf_smoke_writes_and_validates_bench_json() {
    let dir = tmpdir("perf");
    let path = dir.join("BENCH_hotpath.json");
    let out = bin()
        .args(["perf", "--smoke", "--seed", "7", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("dense_ridge_k1"), "stdout: {stdout}");
    assert!(stdout.contains("sparse_logistic_k4"), "stdout: {stdout}");
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.contains("\"schema_version\": 1"));
    assert!(json.contains("\"profile\": \"smoke\""));
    // the standalone validator accepts the file the run just wrote
    let check = bin().args(["perf", "--validate"]).arg(&path).output().unwrap();
    assert!(check.status.success(), "{}", String::from_utf8_lossy(&check.stderr));
    assert!(String::from_utf8_lossy(&check.stdout).contains("valid BENCH schema"));
}

#[test]
fn perf_validate_rejects_garbage() {
    let dir = tmpdir("perfbad");
    let path = dir.join("broken.json");
    std::fs::write(&path, "{\"schema_version\": 99}").unwrap();
    let out = bin().args(["perf", "--validate"]).arg(&path).output().unwrap();
    assert!(!out.status.success());
}
