//! CLI smoke tests: the `cocoa` binary end-to-end — gen-data, train from a
//! TOML config, optimum, and bad-input error paths.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_cocoa"))
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("cocoa_cli_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("repro"));
    assert!(text.contains("train"));
}

#[test]
fn no_subcommand_prints_full_usage_and_exits_nonzero() {
    let out = bin().output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    for cmd in ["train", "repro", "perf", "optimum", "leader", "worker"] {
        assert!(text.contains(&format!("cocoa {cmd}")), "usage is missing {cmd}: {text}");
    }
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    // an unknown subcommand names itself and shows the real usage
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("frobnicate"), "stderr: {text}");
    assert!(text.contains("cocoa leader"), "stderr: {text}");
    assert!(text.contains("cocoa worker"), "stderr: {text}");
}

#[test]
fn leader_and_workers_run_over_uds_end_to_end() {
    let dir = tmpdir("leaderworker");
    let cfg_path = dir.join("exp.toml");
    let sock = dir.join("cluster.sock");
    let _ = std::fs::remove_file(&sock);
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 200
d = 8
seed = 3

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 100

[loss]
kind = "hinge"

[run]
rounds = 5

[transport]
kind = "net"
"#,
    )
    .unwrap();
    let listen = format!("uds:{}", sock.display());
    let workers: Vec<_> = (0..2)
        .map(|_| {
            bin()
                .arg("worker")
                .args(["--config"])
                .arg(&cfg_path)
                .args(["--connect", &listen, "--attempts", "40", "--backoff-s", "0.25"])
                .spawn()
                .unwrap()
        })
        .collect();
    let out = bin()
        .arg("leader")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--listen", &listen, "--workers", "2", "--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=5"), "stdout: {stdout}");
    assert!(stdout.contains("socket: sent"), "stdout: {stdout}");
    for mut w in workers {
        let status = w.wait().unwrap();
        assert!(status.success(), "worker exited nonzero");
    }
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 7); // header + rounds 0..=5
}

#[test]
fn leader_rejects_worker_count_mismatch() {
    let dir = tmpdir("leadermismatch");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        "lambda = 0.01\n\n[dataset]\nkind = \"cov_like\"\nn = 50\nd = 4\n\n\
         [partition]\nk = 2\n\n[algorithm]\nname = \"cocoa\"\nh = 10\n",
    )
    .unwrap();
    let out = bin()
        .arg("leader")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--listen", "uds:/tmp/never-bound.sock", "--workers", "3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("--workers 3"), "stderr: {text}");
}

#[test]
fn gen_data_writes_libsvm() {
    let dir = tmpdir("gendata");
    let path = dir.join("toy.svm");
    let out = bin()
        .args(["gen-data", "cov", "--n", "50", "--d", "6", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 50);
    assert!(text.lines().all(|l| l.starts_with("+1") || l.starts_with("-1")));
}

#[test]
fn train_runs_config_and_writes_trace() {
    let dir = tmpdir("train");
    let cfg_path = dir.join("exp.toml");
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 200
d = 8
seed = 3

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 100

[loss]
kind = "hinge"

[run]
rounds = 5
"#,
    )
    .unwrap();
    let out = bin()
        .arg("train")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=5"), "stdout: {stdout}");
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 7); // header + rounds 0..=5
    assert!(trace.lines().next().unwrap().starts_with("round,sim_time_s"));
}

#[test]
fn train_progress_streams_live_round_lines() {
    let dir = tmpdir("train_progress");
    let cfg_path = dir.join("exp.toml");
    let trace_path = dir.join("trace.csv");
    std::fs::write(
        &cfg_path,
        r#"
lambda = 0.01

[dataset]
kind = "cov_like"
n = 120
d = 6
seed = 5

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 40

[loss]
kind = "hinge"

[run]
rounds = 4
"#,
    )
    .unwrap();
    let out = bin()
        .arg("train")
        .args(["--config"])
        .arg(&cfg_path)
        .args(["--progress", "--out"])
        .arg(&trace_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // the progress observer streams one line per evaluated round to
    // stderr (round, gap, bytes, sim time) and names the stop reason
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("cocoa round"), "stderr: {stderr}");
    assert!(stderr.contains("| gap"), "stderr: {stderr}");
    assert!(stderr.contains("| sim") || stderr.contains("sim "), "stderr: {stderr}");
    assert!(stderr.contains("stopped: max_rounds"), "stderr: {stderr}");
    // one line per evaluated round: 0..=4, plus the stop line
    assert_eq!(stderr.matches("cocoa round").count(), 5, "stderr: {stderr}");
    // stdout summary and the trace file are unaffected by --progress
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("finished: rounds=4"), "stdout: {stdout}");
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    assert_eq!(trace.lines().count(), 6); // header + rounds 0..=4
}

#[test]
fn train_rejects_bad_config() {
    let dir = tmpdir("badcfg");
    let cfg_path = dir.join("bad.toml");
    std::fs::write(&cfg_path, "lambda = \"not a number\"\n").unwrap();
    let out = bin().arg("train").arg("--config").arg(&cfg_path).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn repro_table1_smoke() {
    let out = bin().args(["repro", "table1", "--smoke"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cov"));
    assert!(text.contains("rcv1"));
    assert!(text.contains("imagenet"));
}

#[test]
fn perf_smoke_writes_and_validates_bench_json() {
    let dir = tmpdir("perf");
    let path = dir.join("BENCH_hotpath.json");
    let out = bin()
        .args(["perf", "--smoke", "--seed", "7", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("dense_ridge_k1"), "stdout: {stdout}");
    assert!(stdout.contains("sparse_logistic_k4"), "stdout: {stdout}");
    // v2 schema: the sparse workloads also run at T=4 and the report
    // names the dispatched kernel backend
    assert!(stdout.contains("sparse_logistic_k4_t4"), "stdout: {stdout}");
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.contains("\"schema_version\": 2"));
    assert!(json.contains("\"profile\": \"smoke\""));
    assert!(json.contains("\"kernel_backend\""));
    assert!(json.contains("\"threads\": 4"));
    // the standalone validator accepts the file the run just wrote, and
    // says out loud that no timing comparison happened without --baseline
    let check = bin().args(["perf", "--validate"]).arg(&path).output().unwrap();
    assert!(check.status.success(), "{}", String::from_utf8_lossy(&check.stderr));
    let check_out = String::from_utf8_lossy(&check.stdout);
    assert!(check_out.contains("schema v2 OK"), "stdout: {check_out}");
    assert!(check_out.contains("NOT compared"), "stdout: {check_out}");
}

/// The checked-in baseline, as shipped — gate tests derive candidates
/// from it by string surgery so they always match the live schema.
fn checked_in_baseline() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/benchmarks/BENCH_hotpath.json");
    std::fs::read_to_string(path).expect("benchmarks/BENCH_hotpath.json must be checked in")
}

#[test]
fn perf_gate_passes_a_candidate_matching_the_checked_in_baseline() {
    let dir = tmpdir("perfgate_pass");
    let baseline = dir.join("baseline.json");
    let candidate = dir.join("candidate.json");
    let delta = dir.join("delta.txt");
    std::fs::write(&baseline, checked_in_baseline()).unwrap();
    std::fs::write(&candidate, checked_in_baseline()).unwrap();
    let out = bin()
        .args(["perf", "--validate"])
        .arg(&candidate)
        .args(["--baseline"])
        .arg(&baseline)
        .args(["--tolerance", "0.5", "--delta"])
        .arg(&delta)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("PASS"), "stdout: {stdout}");
    // the delta report artifact is written and lists what was checked
    let report = std::fs::read_to_string(&delta).unwrap();
    assert!(report.contains("steps_per_sec"), "delta: {report}");
    assert!(report.contains("dense_ridge_k1"), "delta: {report}");
}

#[test]
fn perf_gate_fails_a_deliberately_slowed_candidate() {
    // The acceptance criterion for the gate: a slowed build must make
    // `cocoa perf --validate --baseline` exit nonzero. The candidate is
    // the checked-in baseline with every steps_per_sec cut to 400 —
    // below the 0.5-tolerance floor of 500.
    let dir = tmpdir("perfgate_fail");
    let baseline = dir.join("baseline.json");
    let candidate = dir.join("candidate.json");
    let delta = dir.join("delta.txt");
    let base = checked_in_baseline();
    assert!(base.contains("\"steps_per_sec\": 1000.0"), "baseline shape changed; update this test");
    std::fs::write(&baseline, &base).unwrap();
    std::fs::write(&candidate, base.replace("\"steps_per_sec\": 1000.0", "\"steps_per_sec\": 400.0"))
        .unwrap();
    let out = bin()
        .args(["perf", "--validate"])
        .arg(&candidate)
        .args(["--baseline"])
        .arg(&baseline)
        .args(["--tolerance", "0.5", "--delta"])
        .arg(&delta)
        .output()
        .unwrap();
    assert!(!out.status.success(), "a 2.5x slowdown must fail the gate");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("FAIL"), "stdout: {stdout}");
    assert!(stdout.contains("sparse_logistic_k4_t4"), "every workload regressed: {stdout}");
    assert!(stderr.contains("perf gate failed"), "stderr: {stderr}");
    // the delta artifact records the failure for CI upload
    let report = std::fs::read_to_string(&delta).unwrap();
    assert!(report.contains("FAIL"), "delta: {report}");
}

#[test]
fn perf_gate_self_test_tolerance_fails_a_self_comparison() {
    // ci.sh's self-test in miniature: tolerance -1 demands >= 2x the
    // file's own throughput, so comparing a report against itself must
    // exit nonzero. If this ever passes, the gate is not gating.
    let dir = tmpdir("perfgate_selftest");
    let path = dir.join("report.json");
    std::fs::write(&path, checked_in_baseline()).unwrap();
    let out = bin()
        .args(["perf", "--validate"])
        .arg(&path)
        .args(["--baseline"])
        .arg(&path)
        .args(["--tolerance", "-1"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "an impossible tolerance must fail");
}

#[test]
fn perf_validate_rejects_garbage() {
    let dir = tmpdir("perfbad");
    let path = dir.join("broken.json");
    std::fs::write(&path, "{\"schema_version\": 99}").unwrap();
    let out = bin().args(["perf", "--validate"]).arg(&path).output().unwrap();
    assert!(!out.status.success());
}
