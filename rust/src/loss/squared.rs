//! Squared loss — ridge regression inside the same framework (the paper's
//! problem class (1) covers "regularized linear regression").

use super::Loss;

/// `loss(a, y) = (a - y)^2 / 2`; `conj(-alpha) = alpha^2/2 - alpha y`
/// (unconstrained dual), 1-smooth (`gamma = 1`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Squared;

impl Loss for Squared {
    #[inline]
    fn value(&self, a: f64, y: f64) -> f64 {
        0.5 * (a - y) * (a - y)
    }

    #[inline]
    fn conjugate(&self, alpha: f64, y: f64) -> f64 {
        alpha * alpha / 2.0 - alpha * y
    }

    #[inline]
    fn subgradient(&self, a: f64, y: f64) -> f64 {
        a - y
    }

    #[inline]
    fn coord_delta(&self, q: f64, y: f64, a: f64, s: f64) -> f64 {
        if s <= 0.0 {
            return 0.0;
        }
        (y - q - a) / (1.0 + s)
    }

    fn smoothness_gamma(&self) -> Option<f64> {
        Some(1.0)
    }

    #[inline]
    fn project_feasible(&self, alpha: f64, _y: f64) -> f64 {
        alpha // unconstrained
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_delta_is_argmax;

    #[test]
    fn value_and_gradient() {
        let l = Squared;
        assert_eq!(l.value(3.0, 1.0), 2.0);
        assert_eq!(l.subgradient(3.0, 1.0), 2.0);
    }

    #[test]
    fn conjugate_fenchel_equality_at_optimum() {
        // for smooth losses equality holds when alpha = -loss'(a)
        let l = Squared;
        let (a, y) = (1.7, 0.5);
        let alpha = -l.subgradient(a, y);
        let lhs = l.value(a, y) + l.conjugate(alpha, y);
        assert!((lhs - (-alpha * a)).abs() < 1e-12);
    }

    #[test]
    fn delta_is_argmax_over_grid() {
        let l = Squared;
        for &y in &[1.0, -1.0, 0.3] {
            for &a in &[-1.0, 0.0, 2.0] {
                for &q in &[-2.0, 0.0, 1.0] {
                    for &s in &[0.1, 1.0, 5.0] {
                        assert_delta_is_argmax(&l, q, y, a, s);
                    }
                }
            }
        }
    }

    #[test]
    fn exact_solve_in_one_step_when_isolated() {
        // with w containing only this coordinate's contribution, repeated
        // updates converge geometrically; one step from 0 with q=0 lands at
        // y/(1+s)
        let l = Squared;
        let d = l.coord_delta(0.0, 2.0, 0.0, 1.0);
        assert!((d - 1.0).abs() < 1e-12);
    }
}
