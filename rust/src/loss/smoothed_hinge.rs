//! gamma-smoothed hinge — the `(1/gamma)`-smooth loss under which
//! Proposition 1 and Theorem 2 hold; the theory-validation experiments use
//! this loss so measured rates can be compared against the analysis.

use super::Loss;

/// Smoothed hinge (SSZ13):
/// `0` if `ya >= 1`; `1 - ya - gamma/2` if `ya <= 1 - gamma`;
/// `(1 - ya)^2/(2 gamma)` in between. `(1/gamma)`-smooth, and
/// `conj(-alpha) = -y alpha + (gamma/2)(y alpha)^2` on the box.
#[derive(Debug, Clone, Copy)]
pub struct SmoothedHinge {
    pub gamma: f64,
}

impl SmoothedHinge {
    pub fn new(gamma: f64) -> Self {
        assert!(gamma > 0.0, "smoothing gamma must be positive");
        SmoothedHinge { gamma }
    }
}

impl Loss for SmoothedHinge {
    #[inline]
    fn value(&self, a: f64, y: f64) -> f64 {
        let ya = y * a;
        if ya >= 1.0 {
            0.0
        } else if ya <= 1.0 - self.gamma {
            1.0 - ya - self.gamma / 2.0
        } else {
            (1.0 - ya) * (1.0 - ya) / (2.0 * self.gamma)
        }
    }

    #[inline]
    fn conjugate(&self, alpha: f64, y: f64) -> f64 {
        let b = y * alpha;
        if !(-1e-9..=1.0 + 1e-9).contains(&b) {
            return f64::INFINITY;
        }
        -b + self.gamma * b * b / 2.0
    }

    #[inline]
    fn subgradient(&self, a: f64, y: f64) -> f64 {
        let ya = y * a;
        if ya >= 1.0 {
            0.0
        } else if ya <= 1.0 - self.gamma {
            -y
        } else {
            -y * (1.0 - ya) / self.gamma
        }
    }

    #[inline]
    fn coord_delta(&self, q: f64, y: f64, a: f64, s: f64) -> f64 {
        if s <= 0.0 {
            return 0.0;
        }
        let g = self.gamma;
        let b = ((1.0 - y * q - g * y * a) / (s + g) + y * a).clamp(0.0, 1.0);
        y * b - a
    }

    fn smoothness_gamma(&self) -> Option<f64> {
        Some(self.gamma)
    }

    #[inline]
    fn project_feasible(&self, alpha: f64, y: f64) -> f64 {
        y * (y * alpha).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_delta_is_argmax;

    #[test]
    fn value_piecewise() {
        let l = SmoothedHinge::new(0.5);
        assert_eq!(l.value(2.0, 1.0), 0.0);
        // linear branch: ya = -1 <= 1 - gamma
        assert!((l.value(-1.0, 1.0) - (1.0 + 1.0 - 0.25)).abs() < 1e-12);
        // quadratic branch: ya = 0.75 in (0.5, 1)
        assert!((l.value(0.75, 1.0) - 0.0625 / 1.0).abs() < 1e-12);
    }

    #[test]
    fn smooth_limit_recovers_hinge() {
        // gamma -> 0 converges to plain hinge
        let small = SmoothedHinge::new(1e-9);
        for &a in &[-1.0, 0.0, 0.5, 2.0] {
            let h = crate::loss::Hinge;
            assert!((small.value(a, 1.0) - h.value(a, 1.0)).abs() < 1e-6);
        }
    }

    #[test]
    fn delta_is_argmax_over_grid() {
        for &gamma in &[0.1, 0.5, 1.0] {
            let l = SmoothedHinge::new(gamma);
            for &y in &[1.0, -1.0] {
                for &a in &[0.0, 0.4 * y] {
                    for &q in &[-1.5, 0.0, 1.0] {
                        for &s in &[0.2, 2.0] {
                            assert_delta_is_argmax(&l, q, y, a, s);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gradient_is_lipschitz_with_inv_gamma() {
        let gamma = 0.25;
        let l = SmoothedHinge::new(gamma);
        let pts: Vec<f64> = (-40..40).map(|i| i as f64 * 0.05).collect();
        for win in pts.windows(2) {
            let (a, b) = (win[0], win[1]);
            let lip = (l.subgradient(a, 1.0) - l.subgradient(b, 1.0)).abs()
                / (a - b).abs();
            assert!(lip <= 1.0 / gamma + 1e-9, "lipschitz {lip} > 1/gamma");
        }
    }

    #[test]
    #[should_panic]
    fn zero_gamma_rejected() {
        SmoothedHinge::new(0.0);
    }
}
