//! Hinge loss — the SVM loss used in all of the paper's experiments
//! (Section 6), with the classic closed-form SDCA coordinate update.

use super::Loss;

/// `loss(a, y) = max(0, 1 - y a)`; dual box `y alpha in [0, 1]`,
/// `conj(-alpha) = -y alpha` inside the box.
#[derive(Debug, Clone, Copy, Default)]
pub struct Hinge;

impl Loss for Hinge {
    #[inline]
    fn value(&self, a: f64, y: f64) -> f64 {
        (1.0 - y * a).max(0.0)
    }

    #[inline]
    fn conjugate(&self, alpha: f64, y: f64) -> f64 {
        let b = y * alpha;
        if !(-1e-9..=1.0 + 1e-9).contains(&b) {
            return f64::INFINITY;
        }
        -b
    }

    #[inline]
    fn subgradient(&self, a: f64, y: f64) -> f64 {
        if y * a < 1.0 {
            -y
        } else {
            0.0
        }
    }

    #[inline]
    fn coord_delta(&self, q: f64, y: f64, a: f64, s: f64) -> f64 {
        if s <= 0.0 {
            return 0.0;
        }
        let b = ((1.0 - y * q) / s + y * a).clamp(0.0, 1.0);
        y * b - a
    }

    fn smoothness_gamma(&self) -> Option<f64> {
        None // non-smooth: Theorem 2's rate does not apply directly
    }

    #[inline]
    fn project_feasible(&self, alpha: f64, y: f64) -> f64 {
        y * (y * alpha).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_delta_is_argmax;

    #[test]
    fn value_and_subgradient() {
        let l = Hinge;
        assert_eq!(l.value(0.0, 1.0), 1.0);
        assert_eq!(l.value(2.0, 1.0), 0.0);
        assert_eq!(l.value(-2.0, -1.0), 0.0);
        assert_eq!(l.subgradient(0.5, 1.0), -1.0);
        assert_eq!(l.subgradient(2.0, 1.0), 0.0);
    }

    #[test]
    fn conjugate_box() {
        let l = Hinge;
        assert_eq!(l.conjugate(0.5, 1.0), -0.5);
        assert!(l.conjugate(1.5, 1.0).is_infinite());
        assert!(l.conjugate(-0.5, 1.0).is_infinite());
        assert_eq!(l.conjugate(-0.5, -1.0), -0.5);
    }

    #[test]
    fn delta_is_argmax_over_grid() {
        let l = Hinge;
        for &y in &[1.0, -1.0] {
            for &a in &[0.0, 0.3 * y, 0.9 * y] {
                for &q in &[-1.0, 0.0, 0.5, 2.0] {
                    for &s in &[0.1, 1.0, 10.0] {
                        assert_delta_is_argmax(&l, q, y, a, s);
                    }
                }
            }
        }
    }

    #[test]
    fn delta_keeps_feasibility() {
        let l = Hinge;
        let y = -1.0;
        let a = -0.8; // b = 0.8
        let delta = l.coord_delta(-5.0, y, a, 0.5);
        let b_new = y * (a + delta);
        assert!((0.0..=1.0).contains(&b_new));
    }

    #[test]
    fn zero_row_no_move() {
        assert_eq!(Hinge.coord_delta(0.3, 1.0, 0.2, 0.0), 0.0);
    }

    #[test]
    fn project_feasible_clamps() {
        let l = Hinge;
        assert_eq!(l.project_feasible(1.2, 1.0), 1.0);
        assert_eq!(l.project_feasible(-0.2, 1.0), 0.0);
        assert_eq!(l.project_feasible(-1.2, -1.0), -1.0);
    }
}
