//! Logistic loss — regularized logistic regression; the coordinate
//! maximizer has no closed form, so a fixed Newton iteration solves the 1-D
//! subproblem (matching `kernels/local_sdca.py` step for step).

use super::Loss;

/// Newton iterations for the 1-D conjugate maximization; kept identical to
/// `python/compile/kernels/ref.py::LOGISTIC_NEWTON_ITERS`.
pub const NEWTON_ITERS: usize = 10;
const EPS: f64 = 1e-6;

/// `loss(a, y) = log(1 + exp(-y a))`; dual `b = y alpha in (0,1)` with
/// `conj(-alpha) = b log b + (1-b) log(1-b)` (negative entropy);
/// 4-smooth (`gamma = 1/4`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Logistic;

impl Loss for Logistic {
    #[inline]
    fn value(&self, a: f64, y: f64) -> f64 {
        let z = -y * a;
        // stable log(1 + e^z)
        if z > 0.0 {
            z + (1.0 + (-z).exp()).ln()
        } else {
            (1.0 + z.exp()).ln()
        }
    }

    #[inline]
    fn conjugate(&self, alpha: f64, y: f64) -> f64 {
        let b = y * alpha;
        if b <= 0.0 || b >= 1.0 {
            if b == 0.0 || b == 1.0 {
                return 0.0; // entropy limit
            }
            return f64::INFINITY;
        }
        b * b.ln() + (1.0 - b) * (1.0 - b).ln()
    }

    #[inline]
    fn subgradient(&self, a: f64, y: f64) -> f64 {
        // d/da log(1+exp(-ya)) = -y / (1 + exp(ya))
        -y / (1.0 + (y * a).exp())
    }

    #[inline]
    fn coord_delta(&self, q: f64, y: f64, a: f64, s: f64) -> f64 {
        if s <= 0.0 {
            return 0.0;
        }
        // Newton on f(delta) = -conj(-(a+delta)) - q delta - s delta^2/2:
        //   f'(delta)  = -y ln(b/(1-b)) - q - s delta,  b = y(a+delta)
        //   f''(delta) = -1/(b(1-b)) - s
        let mut delta = 0.0;
        for _ in 0..NEWTON_ITERS {
            let b = (y * (a + delta)).clamp(EPS, 1.0 - EPS);
            let g = -y * (b / (1.0 - b)).ln() - q - s * delta;
            let h = -1.0 / (b * (1.0 - b)) - s;
            delta -= g / h;
            // keep the iterate strictly inside the feasible box
            let b_new = (y * (a + delta)).clamp(EPS, 1.0 - EPS);
            delta = y * b_new - a;
        }
        delta
    }

    fn smoothness_gamma(&self) -> Option<f64> {
        Some(0.25)
    }

    #[inline]
    fn project_feasible(&self, alpha: f64, y: f64) -> f64 {
        y * (y * alpha).clamp(EPS, 1.0 - EPS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_delta_is_argmax;

    #[test]
    fn value_stable_at_extremes() {
        let l = Logistic;
        assert!(l.value(100.0, 1.0) < 1e-10);
        assert!((l.value(-100.0, 1.0) - 100.0).abs() < 1e-9);
        assert!((l.value(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn conjugate_entropy() {
        let l = Logistic;
        assert!((l.conjugate(0.5, 1.0) - (0.5f64.ln())).abs() < 1e-12);
        assert!(l.conjugate(1.5, 1.0).is_infinite());
        assert_eq!(l.conjugate(1.0, 1.0), 0.0);
    }

    #[test]
    fn subgradient_matches_finite_difference() {
        let l = Logistic;
        for &a in &[-2.0, -0.1, 0.0, 0.4, 3.0] {
            let eps = 1e-6;
            let fd = (l.value(a + eps, 1.0) - l.value(a - eps, 1.0)) / (2.0 * eps);
            assert!((l.subgradient(a, 1.0) - fd).abs() < 1e-6);
        }
    }

    #[test]
    fn delta_is_argmax_over_grid() {
        let l = Logistic;
        for &y in &[1.0, -1.0] {
            for &a in &[0.2 * y, 0.5 * y, 0.8 * y] {
                for &q in &[-1.0, 0.0, 0.8] {
                    for &s in &[0.1, 1.0, 4.0] {
                        assert_delta_is_argmax(&l, q, y, a, s);
                    }
                }
            }
        }
    }

    #[test]
    fn newton_stays_feasible_from_boundary() {
        let l = Logistic;
        // starting from alpha = 0 (the CoCoA initial point) must move
        // strictly inside (0,1) without NaN
        let delta = l.coord_delta(0.0, 1.0, 0.0, 0.5);
        assert!(delta.is_finite());
        let b = 1.0 * (0.0 + delta);
        assert!(b > 0.0 && b < 1.0, "b = {b}");
    }
}
