//! The regularized-loss-minimization problem class of eq. (1)/(2): losses,
//! Fenchel conjugates, and single-coordinate dual maximizers.
//!
//! Conventions (SSZ13, mirrored exactly by `python/compile/kernels/ref.py`):
//!
//! * primal: `P(w) = (lambda/2)||w||^2 + (1/n) sum_i loss(x_i^T w, y_i)`
//! * dual:   `D(a) = -(lambda/2)||A a||^2 - (1/n) sum_i conj(-a_i)`
//! * `A_i = x_i/(lambda n)`, `w(a) = A a`; hinge dual box `y_i a_i in [0,1]`.
//!
//! `coord_delta` solves the 1-D subproblem of Procedure B:
//! `argmax_da  -conj(-(a+da)) - q*da - s*da^2/2` with `q = x_i^T w` and
//! `s = ||x_i||^2/(lambda n)` — closed form for hinge/smoothed-hinge/squared,
//! a fixed Newton iteration for logistic.

mod hinge;
mod logistic;
mod smoothed_hinge;
mod squared;

pub use hinge::Hinge;
pub use logistic::Logistic;
pub use smoothed_hinge::SmoothedHinge;
pub use squared::Squared;

/// A loss `ell_i(a)` (with label `y`) and everything the primal-dual
/// machinery needs from it.
pub trait Loss: Send + Sync + std::fmt::Debug {
    /// Primal loss value at margin `a = x_i^T w`.
    fn value(&self, a: f64, y: f64) -> f64;

    /// Conjugate term `conj(-alpha)` as it appears in `D`; `+inf` when
    /// `alpha` is dual-infeasible.
    fn conjugate(&self, alpha: f64, y: f64) -> f64;

    /// A subgradient of `a -> value(a, y)` at `a` (drives the SGD baselines).
    fn subgradient(&self, a: f64, y: f64) -> f64;

    /// Maximizer of the 1-D dual subproblem; see module docs.
    fn coord_delta(&self, q: f64, y: f64, a: f64, s: f64) -> f64;

    /// `gamma` such that the loss is `(1/gamma)`-smooth, if smooth
    /// (Proposition 1 / Theorem 2 need it); `None` for hinge.
    fn smoothness_gamma(&self) -> Option<f64>;

    /// Clamp `alpha` into the dual-feasible set (numerical hygiene after
    /// f32 round-trips through the PJRT backend).
    fn project_feasible(&self, alpha: f64, y: f64) -> f64;
}

/// Config-friendly loss selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossKind {
    Hinge,
    SmoothedHinge { gamma: f64 },
    Squared,
    Logistic,
}

impl LossKind {
    /// Parse from config names; `gamma` applies to `smoothed_hinge`.
    pub fn from_name(name: &str, gamma: f64) -> Option<Self> {
        match name {
            "hinge" => Some(LossKind::Hinge),
            "smoothed_hinge" => Some(LossKind::SmoothedHinge { gamma }),
            "squared" => Some(LossKind::Squared),
            "logistic" => Some(LossKind::Logistic),
            _ => None,
        }
    }

    pub fn build(&self) -> Box<dyn Loss> {
        match *self {
            LossKind::Hinge => Box::new(Hinge),
            LossKind::SmoothedHinge { gamma } => Box::new(SmoothedHinge::new(gamma)),
            LossKind::Squared => Box::new(Squared),
            LossKind::Logistic => Box::new(Logistic),
        }
    }

    /// The name the AOT manifest uses for this loss's kernel artifacts.
    pub fn artifact_name(&self) -> &'static str {
        match self {
            LossKind::Hinge => "hinge",
            LossKind::SmoothedHinge { .. } => "smoothed_hinge",
            LossKind::Squared => "squared",
            LossKind::Logistic => "logistic",
        }
    }

    /// Smoothing parameter forwarded to the kernels (unused slots get 1.0).
    pub fn gamma(&self) -> f64 {
        match *self {
            LossKind::SmoothedHinge { gamma } => gamma,
            _ => 1.0,
        }
    }
}

impl std::fmt::Display for LossKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LossKind::SmoothedHinge { gamma } => write!(f, "smoothed_hinge(γ={gamma})"),
            other => write!(f, "{}", other.artifact_name()),
        }
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;

    /// Numerically verify that `coord_delta` maximizes the 1-D subproblem:
    /// the objective at `delta*` beats a grid of perturbations.
    pub fn assert_delta_is_argmax(loss: &dyn Loss, q: f64, y: f64, a: f64, s: f64) {
        let obj = |da: f64| -loss.conjugate(a + da, y) - q * da - s * da * da / 2.0;
        let star = loss.coord_delta(q, y, a, s);
        let at_star = obj(star);
        assert!(at_star.is_finite(), "objective at delta* not finite");
        for step in [-0.1, -0.01, -1e-4, 1e-4, 0.01, 0.1] {
            let v = obj(star + step);
            assert!(
                v <= at_star + 1e-9,
                "perturbation {step} improves objective: {v} > {at_star} \
                 (q={q}, y={y}, a={a}, s={s})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_names() {
        for kind in [
            LossKind::Hinge,
            LossKind::SmoothedHinge { gamma: 0.25 },
            LossKind::Squared,
            LossKind::Logistic,
        ] {
            let back = LossKind::from_name(kind.artifact_name(), kind.gamma());
            match kind {
                LossKind::SmoothedHinge { gamma } => {
                    assert_eq!(back, Some(LossKind::SmoothedHinge { gamma }))
                }
                other => assert_eq!(back, Some(other)),
            }
        }
        assert_eq!(LossKind::from_name("nope", 1.0), None);
    }

    #[test]
    fn artifact_names_match_python_losses() {
        assert_eq!(LossKind::Hinge.artifact_name(), "hinge");
        assert_eq!(
            LossKind::SmoothedHinge { gamma: 0.5 }.artifact_name(),
            "smoothed_hinge"
        );
        assert_eq!(LossKind::Squared.artifact_name(), "squared");
        assert_eq!(LossKind::Logistic.artifact_name(), "logistic");
    }

    /// Fenchel–Young: for every loss, value(a) + conj*(-alpha) >= -alpha*a
    /// pointwise, with equality at the coordinate maximizer's optimum pair.
    #[test]
    fn fenchel_young_inequality() {
        let losses: Vec<Box<dyn Loss>> = vec![
            Box::new(Hinge),
            Box::new(SmoothedHinge::new(0.5)),
            Box::new(Squared),
            Box::new(Logistic),
        ];
        for loss in &losses {
            for &y in &[1.0, -1.0] {
                for &a in &[-2.0, -0.3, 0.0, 0.7, 1.5] {
                    for &alpha in &[0.1 * y, 0.5 * y, 0.9 * y] {
                        let lhs = loss.value(a, y) + loss.conjugate(alpha, y);
                        assert!(
                            lhs >= -alpha * a - 1e-9,
                            "{loss:?} violates Fenchel–Young at a={a}, alpha={alpha}, y={y}"
                        );
                    }
                }
            }
        }
    }
}
