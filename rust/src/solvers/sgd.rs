//! Pegasos epochs — the primal SGD baselines of Section 6.
//!
//! Both SGD competitors run the same inner step (Pegasos [SSSSC10]:
//! `w <- (1 - eta_t lambda) w - eta_t loss'(x_i^T w) x_i`, `eta_t = 1/(lambda t)`);
//! they differ only in whether the primal vector is updated *locally*
//! between inner iterations (local-SGD) or all subgradients are taken
//! against the frozen round-start `w` (mini-batch SGD) — exactly the
//! distinction the paper's experiments isolate.

use crate::loss::Loss;
use crate::util::Rng;
use crate::solvers::Block;

/// What a worker hands back after an SGD epoch.
#[derive(Debug, Clone)]
pub struct SgdOutcome {
    /// local-SGD: `w_local_final - w_start`. mini-batch: the *sum* of
    /// subgradient directions `loss'(q_h) x_{i_h}` over the epoch
    /// (the leader applies the step size).
    pub dw: Vec<f64>,
    pub steps: u64,
}

/// One H-step Pegasos epoch on a block.
#[derive(Debug, Clone, Copy)]
pub struct PegasosEpoch {
    /// true => locally-updating (local-SGD); false => frozen-w mini-batch.
    pub locally_updating: bool,
    /// Global lambda (the Pegasos step size is 1/(lambda t)).
    pub lambda: f64,
}

impl PegasosEpoch {
    /// Run H steps. `t_offset` is the global step counter at epoch start so
    /// the 1/(lambda t) schedule keeps decaying across rounds.
    pub fn run(
        &self,
        block: &Block,
        loss: &dyn Loss,
        w: &[f64],
        h: usize,
        t_offset: u64,
        rng: &mut Rng,
    ) -> SgdOutcome {
        let n_k = block.n_k();
        if self.locally_updating {
            let mut w_local = w.to_vec();
            for step in 0..h {
                let t = (t_offset + step as u64 + 1) as f64;
                let eta = 1.0 / (self.lambda * t);
                let i = rng.gen_range(n_k);
                let q = block.data.features.row_dot(i, &w_local);
                let g = loss.subgradient(q, block.data.labels[i]);
                // shrink from the regularizer, then the loss step
                let shrink = 1.0 - eta * self.lambda;
                for v in w_local.iter_mut() {
                    *v *= shrink;
                }
                if g != 0.0 {
                    block
                        .data
                        .features
                        .add_row_scaled(i, -eta * g, &mut w_local);
                }
            }
            let dw = w_local.iter().zip(w).map(|(a, b)| a - b).collect();
            SgdOutcome { dw, steps: h as u64 }
        } else {
            // frozen-w: accumulate the subgradient directions only
            let mut gsum = vec![0.0; block.d()];
            for _ in 0..h {
                let i = rng.gen_range(n_k);
                let q = block.data.features.row_dot(i, w);
                let g = loss.subgradient(q, block.data.labels[i]);
                if g != 0.0 {
                    block.data.features.add_row_scaled(i, g, &mut gsum);
                }
            }
            SgdOutcome { dw: gsum, steps: h as u64 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Hinge;
    use crate::objective;
    use crate::solvers::test_util::test_block;

    #[test]
    fn local_epoch_reduces_primal_eventually() {
        let block = test_block(200, 8, 0.05, 200, 1);
        let lambda = 0.05;
        let epoch = PegasosEpoch { locally_updating: true, lambda };
        let mut w = vec![0.0; 8];
        let mut rng = Rng::seed_from_u64(2);
        let p0 = objective::primal(&block.data, &w, lambda, &Hinge);
        let mut t = 0u64;
        for _ in 0..10 {
            let out = epoch.run(&block, &Hinge, &w, 200, t, &mut rng);
            t += out.steps;
            for (wv, dv) in w.iter_mut().zip(&out.dw) {
                *wv += dv;
            }
        }
        let p1 = objective::primal(&block.data, &w, lambda, &Hinge);
        assert!(p1 < p0, "pegasos failed to descend: {p0} -> {p1}");
    }

    #[test]
    fn frozen_epoch_returns_raw_subgradient_sum() {
        let block = test_block(50, 4, 0.1, 50, 3);
        let epoch = PegasosEpoch { locally_updating: false, lambda: 0.1 };
        let w = vec![0.0; 4];
        let mut rng = Rng::seed_from_u64(4);
        let out = epoch.run(&block, &Hinge, &w, 30, 0, &mut rng);
        // at w = 0 every margin is 0 < 1, so every step contributes -y x_i;
        // the sum is bounded by H * max||x||
        let norm: f64 = out.dw.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm > 0.0 && norm <= 30.0 + 1e-9);
        assert_eq!(out.steps, 30);
    }

    #[test]
    fn deterministic_under_seed() {
        let block = test_block(30, 4, 0.1, 30, 5);
        let epoch = PegasosEpoch { locally_updating: true, lambda: 0.1 };
        let w = vec![0.0; 4];
        let a = epoch.run(&block, &Hinge, &w, 25, 0, &mut Rng::seed_from_u64(6));
        let b = epoch.run(&block, &Hinge, &w, 25, 0, &mut Rng::seed_from_u64(6));
        assert_eq!(a.dw, b.dw);
    }
}
