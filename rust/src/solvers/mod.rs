//! `LOCALDUALMETHOD` implementations (Procedure A of the paper): the
//! pluggable local optimizer each worker runs on its coordinate block, and
//! the primal SGD epoch used by the Section-6 SGD baselines.
//!
//! The framework contract (Procedure A): given the local block, the local
//! dual variables `alpha_[k]`, and a shared `w` consistent with the global
//! `alpha` (`w = A alpha`), return `(dalpha_[k], dw)` with
//! `dw = A_[k] dalpha_[k]`. CoCoA inherits the convergence of whatever
//! runs here (Theorem 2 + Assumption 1).
//!
//! With a non-L2 regularizer (see [`crate::regularizers`]) the same code
//! runs the *generalized* framework's local subproblem: the broadcast `w`
//! is the leader's prox-mapped iterate `prox(v)` (the linearization point
//! of the normalized conjugate), [`Block::lambda_n`] carries
//! `lambda_eff * n = lambda * sigma * n`, and the quadratic coupling the
//! inner loop maintains is exactly the 1-smooth upper-bound model of the
//! normalized conjugate around `v`. The solvers never see the prox — the
//! leader applies it at commit — which is what keeps the L2 fast path
//! (sigma = 1, prox = identity) the bit-identical seed arithmetic.

mod exact;
mod gap_certified;
mod sdca;
mod sgd;

pub use exact::ExactBlockSolver;
pub use gap_certified::GapCertifiedSolver;
pub use sdca::{LocalSdca, Sampling};
pub use sgd::{PegasosEpoch, SgdOutcome};

use crate::data::{Dataset, Features};
use crate::util::Rng;
use crate::loss::Loss;

/// A worker's view of its block: the local rows (already compacted to
/// local row indices by [`Dataset::subset`]) plus the problem constants,
/// and the per-shard caches the inner loop leans on:
///
/// * per-row subproblem curvatures `||x_i||^2 / (lambda n)`, divided out
///   **once per shard** instead of once per inner step;
/// * the sparse shard's column-touch set (sorted unique columns with any
///   stored entry), which bounds where local updates can move `w` — the
///   delta extraction at the end of a local round walks this set instead
///   of all `d` columns.
///
/// Construct through [`Block::new`] so the caches always match the data.
pub struct Block {
    pub data: Dataset,
    /// `lambda_eff * n` with the *global* n — the scaling constant in `A`
    /// of the sigma-normalized problem (`lambda_eff = lambda *
    /// regularizer strong convexity`; plain `lambda * n` for L2).
    pub lambda_n: f64,
    /// `norms_sq[i] / lambda_n`, precomputed (same division the per-step
    /// path used to run, so values are bit-identical).
    curv: Vec<f64>,
    /// Sorted unique touched columns; `None` for dense shards (all
    /// columns are touchable).
    touched: Option<Vec<u32>>,
}

impl Block {
    /// Build a worker block over `data` with the shard caches filled.
    pub fn new(data: Dataset, lambda_n: f64) -> Block {
        let curv = (0..data.n()).map(|i| data.norm_sq(i) / lambda_n).collect();
        let touched = match &data.features {
            Features::Sparse(m) => Some(m.touched_cols()),
            Features::Dense(_) => None,
        };
        Block { data, lambda_n, curv, touched }
    }

    pub fn n_k(&self) -> usize {
        self.data.n()
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// Curvature `s_i = ||x_i||^2 / (lambda n)` of coordinate i's
    /// 1-D subproblem (precomputed per shard).
    #[inline]
    pub fn curvature(&self, i: usize) -> f64 {
        self.curv[i]
    }

    /// The sparse shard's column-touch set (`None` on dense shards).
    pub fn touched_cols(&self) -> Option<&[u32]> {
        self.touched.as_deref()
    }

    /// Grow the block with appended rows (CSR-form, batch-local
    /// `indptr`) and rebake every cache against the new `lambda_n`.
    /// The global `n` changed, so *all* curvatures change — which is why
    /// this runs even for an empty batch, and why the whole `curv`
    /// vector is recomputed rather than extended. Same division as
    /// [`Block::new`], so a grown block is bit-identical to one built
    /// from the grown dataset directly.
    pub fn append(
        &mut self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f64],
        labels: &[f64],
        norms_sq: &[f64],
        lambda_n: f64,
    ) -> Result<(), String> {
        self.data.append_csr_rows(indptr, indices, values, labels, norms_sq)?;
        self.lambda_n = lambda_n;
        self.curv = (0..self.data.n()).map(|i| self.data.norm_sq(i) / lambda_n).collect();
        self.touched = match &self.data.features {
            Features::Sparse(m) => Some(m.touched_cols()),
            Features::Dense(_) => None,
        };
        Ok(())
    }
}

/// Result of one local round.
#[derive(Debug, Clone)]
pub struct LocalUpdate {
    pub dalpha: Vec<f64>,
    pub dw: Vec<f64>,
    /// Inner steps actually performed (exact solvers run a variable count).
    pub steps: u64,
    /// Compute seconds spent outside the worker thread (PJRT engine time);
    /// 0 for native solvers. The worker adds this to its own thread CPU
    /// time when reporting round compute.
    pub offloaded_s: f64,
}

/// Procedure A: an arbitrary dual optimization method on one block.
pub trait LocalDualMethod: Send {
    fn name(&self) -> &'static str;

    /// Run the local method for (up to) `h` steps from `(alpha, w)`.
    /// `w` must equal `A alpha` for the *global* alpha; the returned
    /// `dw` must equal `A_[k] dalpha`.
    fn local_update(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate;
}

/// Config selector for the local solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverKind {
    /// LocalSDCA, sampling with replacement (Procedure B; default).
    #[default]
    Sdca,
    /// LocalSDCA over random permutations (one pass per permutation).
    SdcaPerm,
    /// Solve the block subproblem to (near) optimality — the H -> inf
    /// block-coordinate-descent limit.
    Exact,
    /// Adaptive H: permutation-SDCA passes until the Appendix-B local
    /// duality-gap certificate fires (primal-dual stopping, Section 2).
    GapCertified,
}

impl SolverKind {
    /// Build the local solver with the given intra-worker shard count
    /// (see the deterministic-per-T contract in [`sdca`](LocalSdca)).
    /// Only the SDCA variants shard; the exact and gap-certified solvers
    /// ignore `threads` (their inner loops are inherently sequential).
    pub fn build(&self, threads: usize) -> Box<dyn LocalDualMethod> {
        match self {
            SolverKind::Sdca => {
                Box::new(LocalSdca::new(Sampling::WithReplacement).with_threads(threads))
            }
            SolverKind::SdcaPerm => {
                Box::new(LocalSdca::new(Sampling::Permutation).with_threads(threads))
            }
            SolverKind::Exact => Box::new(ExactBlockSolver::default()),
            SolverKind::GapCertified => Box::new(GapCertifiedSolver::default()),
        }
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::data::cov_like;

    pub fn test_block(n_k: usize, d: usize, lambda: f64, global_n: usize, seed: u64) -> Block {
        Block::new(cov_like(n_k, d, 0.1, seed), lambda * global_n as f64)
    }

    /// The Procedure-A output invariant: dw == A_[k] dalpha.
    pub fn assert_dw_consistent(block: &Block, up: &LocalUpdate) {
        let mut expect = vec![0.0; block.d()];
        for (i, &da) in up.dalpha.iter().enumerate() {
            if da != 0.0 {
                block
                    .data
                    .features
                    .add_row_scaled(i, da / block.lambda_n, &mut expect);
            }
        }
        for (a, b) in expect.iter().zip(&up.dw) {
            assert!((a - b).abs() < 1e-9, "dw inconsistent: {a} vs {b}");
        }
    }
}
