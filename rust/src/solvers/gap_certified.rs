//! Gap-certified local solver — uses the paper's Appendix-B local
//! primal-dual structure as its stopping rule.
//!
//! The paper notes that choosing a *primal-dual* local optimizer gives a
//! computable certificate "for free": the local duality gap
//! `g_k = P_k(w_k; w_bar) - D_k(alpha_[k]; w_bar)` (eqs. (8)/(9),
//! Proposition 4) bounds the block suboptimality `eps_{D,k}` that
//! Assumption 1 contracts. This solver runs permutation-SDCA passes until
//! `g_k <= tol` — an *adaptive* H: easy blocks stop early, hard blocks get
//! more inner work, without any tuning.

use super::{Block, LocalDualMethod, LocalSdca, LocalUpdate, Sampling};
use crate::loss::Loss;
use crate::objective;
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct GapCertifiedSolver {
    /// Stop once the local duality gap falls below this.
    pub gap_tol: f64,
    /// Hard cap on passes.
    pub max_passes: usize,
}

impl Default for GapCertifiedSolver {
    fn default() -> Self {
        GapCertifiedSolver { gap_tol: 1e-6, max_passes: 500 }
    }
}

impl LocalDualMethod for GapCertifiedSolver {
    fn name(&self) -> &'static str {
        "gap_certified_sdca"
    }

    /// `h` is treated as a *per-pass* step count hint (a full pass when 0);
    /// passes repeat until the certificate or the cap fires.
    fn local_update(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        let n_k = block.n_k();
        let per_pass = if h == 0 { n_k } else { h };
        let lambda_n = block.lambda_n;
        // lambda and n are only ever used through lambda*n here, so any
        // consistent split works for the gap computation; use n = n_k
        // scaling-free form: local_gap takes (lambda, n) separately only to
        // form lambda*n and lambda/2 norms, so pass lambda = lambda_n / n.
        let n_global_guess = n_k.max(1);
        let lambda = lambda_n / n_global_guess as f64;

        let inner = LocalSdca::new(Sampling::Permutation);
        let mut cur_alpha = alpha.to_vec();
        let mut cur_w = w.to_vec();
        let mut dalpha = vec![0.0; n_k];
        let mut dw = vec![0.0; block.d()];
        let mut steps = 0u64;
        for _ in 0..self.max_passes {
            let up = inner.local_update(block, loss, &cur_alpha, &cur_w, per_pass, rng);
            steps += up.steps;
            for i in 0..n_k {
                dalpha[i] += up.dalpha[i];
                cur_alpha[i] += up.dalpha[i];
            }
            for j in 0..block.d() {
                dw[j] += up.dw[j];
                cur_w[j] += up.dw[j];
            }
            let gap = objective::local_gap(
                &block.data,
                &cur_alpha,
                &cur_w,
                lambda,
                n_global_guess,
                loss,
            );
            if gap <= self.gap_tol {
                break;
            }
        }
        LocalUpdate { dalpha, dw, steps, offloaded_s: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::SmoothedHinge;
    use crate::solvers::test_util::{assert_dw_consistent, test_block};

    #[test]
    fn stops_on_certificate_before_cap() {
        let block = test_block(40, 6, 0.1, 40, 51);
        let loss = SmoothedHinge::new(1.0);
        let solver = GapCertifiedSolver { gap_tol: 1e-4, max_passes: 500 };
        let mut rng = Rng::seed_from_u64(52);
        let up = solver.local_update(
            &block, &loss, &vec![0.0; 40], &vec![0.0; 6], 0, &mut rng,
        );
        assert_dw_consistent(&block, &up);
        // certificate fired well before the cap of 500 * 40 steps
        assert!(up.steps < 500 * 40 / 2, "no early stop: {} steps", up.steps);
        // and the final point's block gap really is below tol
        let lambda = block.lambda_n / 40.0;
        let gap = crate::objective::local_gap(
            &block.data, &up.dalpha, &up.dw, lambda, 40, &loss,
        );
        assert!(gap <= 1e-4 + 1e-9, "gap {gap} above tol");
    }

    #[test]
    fn tighter_tol_costs_more_steps() {
        let block = test_block(40, 6, 0.1, 40, 53);
        let loss = SmoothedHinge::new(1.0);
        let loose = GapCertifiedSolver { gap_tol: 1e-2, max_passes: 500 };
        let tight = GapCertifiedSolver { gap_tol: 1e-8, max_passes: 500 };
        let a = loose.local_update(
            &block, &loss, &vec![0.0; 40], &vec![0.0; 6], 0,
            &mut Rng::seed_from_u64(54),
        );
        let b = tight.local_update(
            &block, &loss, &vec![0.0; 40], &vec![0.0; 6], 0,
            &mut Rng::seed_from_u64(54),
        );
        assert!(b.steps > a.steps, "{} !> {}", b.steps, a.steps);
    }
}
