//! Exact block solver — the `H -> inf` limit of LocalSDCA.
//!
//! Running the local subproblem to optimality makes CoCoA coincide with
//! serial/parallel *block*-coordinate descent (the remark after Lemma 3),
//! and is also the local routine of the one-shot-averaging baseline
//! [ZDW13]. Implemented as permutation-order SDCA passes until a pass
//! moves no coordinate by more than `tol`.

use super::{Block, LocalDualMethod, LocalSdca, LocalUpdate, Sampling};
use crate::util::Rng;
use crate::loss::Loss;

#[derive(Debug, Clone, Copy)]
pub struct ExactBlockSolver {
    /// Stop when the largest |delta alpha_i| in a full pass is below this.
    pub tol: f64,
    /// Hard cap on passes (safety on ill-conditioned blocks).
    pub max_passes: usize,
}

impl Default for ExactBlockSolver {
    fn default() -> Self {
        ExactBlockSolver { tol: 1e-10, max_passes: 2000 }
    }
}

impl LocalDualMethod for ExactBlockSolver {
    fn name(&self) -> &'static str {
        "exact_block"
    }

    /// `h` is ignored (the point of this solver); steps reports the actual
    /// inner iterations used.
    fn local_update(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        _h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        let n_k = block.n_k();
        let inner = LocalSdca::new(Sampling::Permutation);
        let mut dalpha = vec![0.0; n_k];
        let mut dw = vec![0.0; block.d()];
        let mut cur_alpha = alpha.to_vec();
        let mut cur_w = w.to_vec();
        let mut steps = 0u64;
        for _ in 0..self.max_passes {
            let up = inner.local_update(block, loss, &cur_alpha, &cur_w, n_k, rng);
            steps += up.steps;
            let max_move = up
                .dalpha
                .iter()
                .fold(0.0f64, |m, &v| m.max(v.abs()));
            for i in 0..n_k {
                dalpha[i] += up.dalpha[i];
                cur_alpha[i] += up.dalpha[i];
            }
            for j in 0..block.d() {
                dw[j] += up.dw[j];
                cur_w[j] += up.dw[j];
            }
            if max_move <= self.tol {
                break;
            }
        }
        LocalUpdate { dalpha, dw, steps, offloaded_s: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Loss, SmoothedHinge, Squared};
    use crate::objective;
    use crate::solvers::test_util::{assert_dw_consistent, test_block};

    #[test]
    fn reaches_block_optimum() {
        // After the exact solve, no single coordinate can improve:
        // coord_delta must be ~0 everywhere at the final point.
        let block = test_block(30, 5, 0.1, 30, 1);
        let loss = SmoothedHinge::new(0.5);
        let solver = ExactBlockSolver::default();
        let mut rng = Rng::seed_from_u64(2);
        let up = solver.local_update(
            &block,
            &loss,
            &vec![0.0; 30],
            &vec![0.0; 5],
            0,
            &mut rng,
        );
        assert_dw_consistent(&block, &up);
        let w_final: Vec<f64> = up.dw.clone();
        for i in 0..30 {
            let q = block.data.features.row_dot(i, &w_final);
            let delta = loss.coord_delta(
                q,
                block.data.labels[i],
                up.dalpha[i],
                block.curvature(i),
            );
            assert!(delta.abs() < 1e-6, "coordinate {i} still moves by {delta}");
        }
    }

    #[test]
    fn beats_fixed_h_on_dual_value() {
        let block = test_block(40, 6, 0.05, 40, 3);
        let loss = Squared;
        let lambda = 0.05;
        let mut rng = Rng::seed_from_u64(4);
        let exact = ExactBlockSolver::default().local_update(
            &block, &loss, &vec![0.0; 40], &vec![0.0; 6], 0, &mut rng,
        );
        let mut rng = Rng::seed_from_u64(4);
        let cheap = LocalSdca::new(Sampling::WithReplacement).local_update(
            &block, &loss, &vec![0.0; 40], &vec![0.0; 6], 5, &mut rng,
        );
        let d_exact = objective::dual(&block.data, &exact.dalpha, lambda, &loss);
        let d_cheap = objective::dual(&block.data, &cheap.dalpha, lambda, &loss);
        assert!(d_exact >= d_cheap, "{d_exact} < {d_cheap}");
        assert!(exact.steps > cheap.steps);
    }
}
