//! LocalSDCA — Procedure B of the paper: H randomized dual coordinate
//! ascent steps on the local block, each immediately applied to the local
//! view of `w`. This "apply updates locally while they are processed"
//! behaviour is exactly what distinguishes CoCoA from mini-batch methods.
//!
//! # Intra-worker threading (deterministic-per-T)
//!
//! With `threads = T > 1` the block is sharded into T contiguous
//! coordinate sub-ranges, each solved by its own thread exactly like a
//! CoCoA+ sub-worker: a private RNG (T seeds drawn from the round RNG up
//! front), a private copy of `w`, `h / T` of the step budget, and the
//! curvature multiplier scaled by an extra factor T — the safe-adding
//! sigma' for a T-way partition, so the summed update still never
//! decreases the dual. Shards share no mutable state and their partial
//! `dw`s are combined in pinned shard order 0..T, so the trajectory is a
//! pure function of `(seed, T)` — **deterministic per T**, independent of
//! thread scheduling, core count, or whether the shards actually run in
//! parallel ([`LocalSdca::local_update_sequential_schedule`] replays the
//! identical schedule on the caller thread; the property suite pins the
//! two bit-for-bit). `T = 1` runs the original sequential path unchanged,
//! bit-identical to every pre-threading trajectory.

use super::{Block, LocalDualMethod, LocalUpdate};
use crate::data::Features;
use crate::kernels;
use crate::util::Rng;
use crate::loss::Loss;

/// Coordinate selection scheme for the inner loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// i.i.d. uniform over the block (the paper's Procedure B; what the
    /// convergence analysis assumes).
    WithReplacement,
    /// Random permutation passes (LibLinear-style epochs; often a bit
    /// faster in practice, used by the ablation bench).
    Permutation,
}

/// The paper's recommended local solver.
#[derive(Debug, Clone, Copy)]
pub struct LocalSdca {
    pub sampling: Sampling,
    /// Subproblem curvature multiplier sigma' >= 1. The paper's Algorithm 1
    /// uses 1.0 (safe averaging, beta_K = 1). Setting sigma' = K makes the
    /// *added* (beta_K = K) updates safe — the conclusion's open question,
    /// resolved by the CoCoA+ follow-up; implemented here as an extension.
    pub curvature_scale: f64,
    /// Intra-worker shard count T (>= 1). See the module docs for the
    /// deterministic-per-T contract; 1 is the sequential legacy path.
    pub threads: usize,
}

impl LocalSdca {
    pub fn new(sampling: Sampling) -> Self {
        LocalSdca { sampling, curvature_scale: 1.0, threads: 1 }
    }

    /// sigma'-scaled variant (CoCoA+ style additive updates).
    pub fn with_curvature_scale(sampling: Sampling, sigma_prime: f64) -> Self {
        assert!(sigma_prime >= 1.0, "sigma' must be >= 1");
        LocalSdca { sampling, curvature_scale: sigma_prime, threads: 1 }
    }

    /// Set the intra-worker shard count T. Shards never outnumber the
    /// block's coordinates (the effective T is clamped per block).
    pub fn with_threads(mut self, threads: usize) -> Self {
        assert!(threads >= 1, "threads must be >= 1");
        self.threads = threads;
        self
    }

    /// Replay the exact shard schedule of [`local_update`] on the caller
    /// thread — same seeds, same sub-ranges, same pinned combine order —
    /// without spawning. Exists so the property suite can pin that the
    /// threaded execution is bit-identical to its sequential schedule
    /// (i.e. that thread scheduling can never leak into a trajectory);
    /// not intended for production use.
    ///
    /// [`local_update`]: LocalDualMethod::local_update
    #[doc(hidden)]
    pub fn local_update_sequential_schedule(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        self.update_impl(block, loss, alpha, w, h, rng, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn update_impl(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
        parallel: bool,
    ) -> LocalUpdate {
        let n_k = block.n_k();
        debug_assert_eq!(alpha.len(), n_k);
        assert_eq!(w.len(), block.d(), "w length must match block dimension");
        let t = self.threads.max(1).min(n_k.max(1));
        let mut dalpha = vec![0.0; n_k];

        if t == 1 {
            // the sequential legacy path: the full block is one shard on
            // the caller thread with the round RNG — bit-identical to
            // every pre-threading trajectory
            let mut w_local = w.to_vec();
            sdca_range(
                block,
                loss,
                alpha,
                &mut w_local,
                &mut dalpha,
                0,
                h,
                self.curvature_scale,
                self.sampling,
                rng,
            );
            let dw = extract_dw(block, &w_local, w, self.curvature_scale);
            return LocalUpdate { dalpha, dw, steps: h as u64, offloaded_s: 0.0 };
        }

        // Deterministic-per-T sharding. Everything random is fixed up
        // front: T shard seeds drawn from the round RNG (advancing it, so
        // consecutive rounds see fresh randomness), contiguous sub-range
        // bounds, and the per-shard step budget (h/T, the first h%T
        // shards taking one extra).
        let scale_eff = self.curvature_scale * t as f64;
        let seeds: Vec<u64> = (0..t).map(|_| rng.next_u64()).collect();
        let sampling = self.sampling;

        // split dalpha into the per-shard chunks [s*n_k/T, (s+1)*n_k/T)
        let mut jobs: Vec<(usize, usize, u64, &mut [f64])> = Vec::with_capacity(t);
        let mut rest: &mut [f64] = &mut dalpha;
        let mut lo = 0usize;
        for (s, &seed) in seeds.iter().enumerate() {
            let hi = (s + 1) * n_k / t;
            let tmp = rest;
            let (chunk, tail) = tmp.split_at_mut(hi - lo);
            let h_s = h / t + usize::from(s < h % t);
            jobs.push((lo, h_s, seed, chunk));
            rest = tail;
            lo = hi;
        }

        let run_shard = |lo: usize, h_s: usize, seed: u64, chunk: &mut [f64]| -> Vec<f64> {
            let mut w_local = w.to_vec();
            let mut shard_rng = Rng::seed_from_u64(seed);
            sdca_range(
                block, loss, alpha, &mut w_local, chunk, lo, h_s, scale_eff, sampling,
                &mut shard_rng,
            );
            w_local
        };

        // Shards share nothing mutable, so parallel execution computes
        // the exact bits of the sequential replay below; the only
        // ordering that matters is the pinned combine order afterwards.
        let shard_w: Vec<Vec<f64>> = if parallel {
            let run_shard = &run_shard;
            std::thread::scope(|scope| {
                let handles: Vec<_> = jobs
                    .into_iter()
                    .map(|(lo, h_s, seed, chunk)| {
                        scope.spawn(move || run_shard(lo, h_s, seed, chunk))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|j| j.join().expect("sdca shard thread panicked"))
                    .collect()
            })
        } else {
            jobs.into_iter()
                .map(|(lo, h_s, seed, chunk)| run_shard(lo, h_s, seed, chunk))
                .collect()
        };

        // Pinned reduction order: dw = sum over shards 0..T of each
        // shard's delta, always in shard index order — never in thread
        // completion order. dalpha needs no combine (disjoint chunks).
        let mut dw = vec![0.0; w.len()];
        match block.touched_cols() {
            Some(cols) => {
                for w_s in &shard_w {
                    for &j in cols {
                        let j = j as usize;
                        dw[j] += (w_s[j] - w[j]) / scale_eff;
                    }
                }
            }
            None => {
                for w_s in &shard_w {
                    for (d, (wl, w0)) in dw.iter_mut().zip(w_s.iter().zip(w)) {
                        *d += (wl - w0) / scale_eff;
                    }
                }
            }
        }
        LocalUpdate { dalpha, dw, steps: h as u64, offloaded_s: 0.0 }
    }
}

impl LocalDualMethod for LocalSdca {
    fn name(&self) -> &'static str {
        match self.sampling {
            Sampling::WithReplacement => "local_sdca",
            Sampling::Permutation => "local_sdca_perm",
        }
    }

    fn local_update(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        self.update_impl(block, loss, alpha, w, h, rng, true)
    }
}

/// The SDCA inner loop over one contiguous coordinate sub-range
/// `[lo, lo + dalpha.len())` of the block: `h` steps, each picking a
/// local coordinate (uniform or permutation over the *sub-range*),
/// judging it against `w_local`, and applying any move to `dalpha`
/// (locally indexed) and `w_local` in place. `w_local` accumulates
/// `scale_eff * dw_shard` on top of the broadcast `w`; the caller
/// recovers the shard's `dw` afterwards.
///
/// Monomorphized per storage format so each step runs the fused kernels
/// on the row slices directly: one indptr fetch per step, no per-element
/// bounds checks, the curvature division precomputed per shard. With the
/// full range and the round RNG this is arithmetic-identical to the
/// original unsharded loop — the prop_kernels suite pins that
/// bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn sdca_range(
    block: &Block,
    loss: &dyn Loss,
    alpha: &[f64],
    w_local: &mut [f64],
    dalpha: &mut [f64],
    lo: usize,
    h: usize,
    scale_eff: f64,
    sampling: Sampling,
    rng: &mut Rng,
) {
    let len = dalpha.len();
    if len == 0 {
        return;
    }
    let inv_lambda_n = scale_eff / block.lambda_n;
    let mut perm: Vec<u32> = Vec::new();
    let mut pick = |step: usize, rng: &mut Rng| -> usize {
        match sampling {
            Sampling::WithReplacement => rng.gen_range(len),
            Sampling::Permutation => {
                let pos = step % len;
                if pos == 0 {
                    perm = sample_permutation(len, rng);
                }
                perm[pos] as usize
            }
        }
    };

    match &block.data.features {
        Features::Sparse(m) => {
            for step in 0..h {
                let j = pick(step, rng);
                let i = lo + j;
                let (idx, val) = m.row_view(i);
                // SAFETY: CsrMatrix guarantees index < cols, and
                // w_local.len() == block.d() == cols (asserted by the
                // caller).
                let q = unsafe { kernels::sparse_dot_unchecked(idx, val, w_local) };
                let a_cur = alpha[i] + dalpha[j];
                let s = block.curvature(i) * scale_eff;
                let delta = loss.coord_delta(q, block.data.labels[i], a_cur, s);
                if delta != 0.0 {
                    dalpha[j] += delta;
                    // SAFETY: as above.
                    unsafe {
                        kernels::sparse_axpy_unchecked(idx, val, delta * inv_lambda_n, w_local)
                    };
                }
            }
        }
        Features::Dense(m) => {
            for step in 0..h {
                let j = pick(step, rng);
                let i = lo + j;
                let row = m.row(i);
                let q = kernels::dense_dot(row, w_local);
                let a_cur = alpha[i] + dalpha[j];
                let s = block.curvature(i) * scale_eff;
                let delta = loss.coord_delta(q, block.data.labels[i], a_cur, s);
                if delta != 0.0 {
                    dalpha[j] += delta;
                    kernels::dense_axpy(delta * inv_lambda_n, row, w_local);
                }
            }
        }
    }
}

/// Delta extraction for the single-shard path: on sparse shards only
/// touched columns can have moved; untouched columns satisfy
/// `w_local[j] == w[j]` bit-for-bit, where the old full-d pass computed
/// `(x - x)/scale == +0.0` — the same bits the zero-fill writes.
fn extract_dw(block: &Block, w_local: &[f64], w: &[f64], scale: f64) -> Vec<f64> {
    match block.touched_cols() {
        Some(cols) => {
            let mut dw = vec![0.0; w.len()];
            for &j in cols {
                let j = j as usize;
                dw[j] = (w_local[j] - w[j]) / scale;
            }
            dw
        }
        None => w_local
            .iter()
            .zip(w.iter())
            .map(|(wl, w0)| (wl - w0) / scale)
            .collect(),
    }
}

fn sample_permutation(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut p: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Hinge, SmoothedHinge};
    use crate::objective;
    use crate::solvers::test_util::{assert_dw_consistent, test_block};

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn dw_equals_a_dalpha() {
        let block = test_block(40, 6, 0.05, 80, 0);
        for sampling in [Sampling::WithReplacement, Sampling::Permutation] {
            let solver = LocalSdca::new(sampling);
            let up = solver.local_update(
                &block,
                &Hinge,
                &vec![0.0; 40],
                &vec![0.0; 6],
                120,
                &mut rng(1),
            );
            assert_eq!(up.steps, 120);
            assert_dw_consistent(&block, &up);
        }
    }

    #[test]
    fn dw_equals_a_dalpha_when_threaded() {
        // the Procedure-A contract must survive sharding: disjoint
        // dalpha chunks, per-shard w copies, pinned dw combine
        let block = test_block(40, 6, 0.05, 80, 0);
        for threads in [2usize, 4] {
            for sampling in [Sampling::WithReplacement, Sampling::Permutation] {
                let solver = LocalSdca::new(sampling).with_threads(threads);
                let up = solver.local_update(
                    &block,
                    &Hinge,
                    &vec![0.0; 40],
                    &vec![0.0; 6],
                    120,
                    &mut rng(1),
                );
                assert_eq!(up.steps, 120);
                assert_dw_consistent(&block, &up);
            }
        }
    }

    #[test]
    fn local_dual_objective_never_decreases() {
        // Every inner step is exact coordinate ascent on the global dual
        // restricted to the block => applying the *whole* local update (as
        // if K = 1) must improve D.
        let block = test_block(60, 8, 0.1, 60, 2);
        let loss = SmoothedHinge::new(0.5);
        let lambda = 0.1;
        let mut alpha = vec![0.0; 60];
        let mut w = vec![0.0; 8];
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let mut d_prev =
            objective::dual(&block.data, &alpha, lambda, &loss);
        let mut r = rng(3);
        for _ in 0..5 {
            let up = solver.local_update(&block, &loss, &alpha, &w, 90, &mut r);
            for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
                *a += da;
            }
            for (wv, dv) in w.iter_mut().zip(&up.dw) {
                *wv += dv;
            }
            let d_new = objective::dual(&block.data, &alpha, lambda, &loss);
            assert!(
                d_new >= d_prev - 1e-10,
                "dual decreased: {d_prev} -> {d_new}"
            );
            d_prev = d_new;
        }
    }

    #[test]
    fn local_dual_objective_never_decreases_threaded() {
        // sigma' = T safe-adding across the shard partition: the summed
        // sharded update must still be dual non-decreasing
        let block = test_block(60, 8, 0.1, 60, 2);
        let loss = SmoothedHinge::new(0.5);
        let lambda = 0.1;
        let mut alpha = vec![0.0; 60];
        let mut w = vec![0.0; 8];
        let solver = LocalSdca::new(Sampling::WithReplacement).with_threads(4);
        let mut d_prev = objective::dual(&block.data, &alpha, lambda, &loss);
        let mut r = rng(3);
        for _ in 0..5 {
            let up = solver.local_update(&block, &loss, &alpha, &w, 90, &mut r);
            for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
                *a += da;
            }
            for (wv, dv) in w.iter_mut().zip(&up.dw) {
                *wv += dv;
            }
            let d_new = objective::dual(&block.data, &alpha, lambda, &loss);
            assert!(
                d_new >= d_prev - 1e-10,
                "threaded dual decreased: {d_prev} -> {d_new}"
            );
            d_prev = d_new;
        }
    }

    #[test]
    fn h_zero_is_noop() {
        let block = test_block(10, 4, 0.1, 10, 4);
        for threads in [1usize, 3] {
            let solver = LocalSdca::new(Sampling::WithReplacement).with_threads(threads);
            let up = solver.local_update(
                &block,
                &Hinge,
                &vec![0.0; 10],
                &vec![0.0; 4],
                0,
                &mut rng(5),
            );
            assert!(up.dalpha.iter().all(|&v| v == 0.0));
            assert!(up.dw.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn prox_fixed_point_is_a_solver_fixed_point() {
        // The generalized-subproblem contract: run LocalSDCA on the
        // normalized block (lambda_n = lambda*sigma*n) from the *prox
        // fixed point* of a smoothed-L1 problem — alpha_i = y_i - x_i^T w
        // with w = prox(v), v = A alpha — and no coordinate moves. This
        // pins the exact interplay the coordinator relies on: solvers stay
        // prox-oblivious, yet their fixed points are the regularized
        // optima. (Tiny instance re-derived inline on purpose — a solver
        // unit test should not lean on the experiments-layer lasso
        // helpers it ultimately underpins.)
        use crate::loss::Squared;
        use crate::regularizers::{Regularizer, RegularizerKind};

        // orthogonal indicator design: 2 columns x 3 rows each
        let (d, m) = (2usize, 3usize);
        let n = d * m;
        let y_col = [0.9, 0.05]; // one active, one thresholded to zero
        let mut triplets = Vec::new();
        let mut labels = Vec::new();
        for j in 0..d {
            for r in 0..m {
                triplets.push((j * m + r, j as u32, 1.0));
                labels.push(y_col[j]);
            }
        }
        let data = crate::data::Dataset::new(
            crate::data::Features::Sparse(crate::data::CsrMatrix::from_triplets(
                n, d, &triplets,
            )),
            labels,
        );
        let (lambda, eps) = (0.1, 0.5);
        let reg = RegularizerKind::L1 { epsilon: eps }.build();
        let lambda_eff = lambda * reg.strong_convexity();

        // closed-form optimum and its dual point
        let c = m as f64 / n as f64;
        let w_star: Vec<f64> = (0..d)
            .map(|j| {
                crate::regularizers::soft_threshold(m as f64 * y_col[j] / n as f64, lambda)
                    / (lambda * eps + c)
            })
            .collect();
        let alpha: Vec<f64> = (0..n)
            .map(|i| {
                let j = i / m;
                y_col[j] - w_star[j]
            })
            .collect();
        // consistency: prox(v(alpha)) == w_star
        let v = data.primal_from_dual(&alpha, lambda_eff);
        for j in 0..d {
            assert!(
                (reg.prox_coord(v[j]) - w_star[j]).abs() < 1e-12,
                "prox(v[{j}]) != w*[{j}]"
            );
        }

        let block = Block::new(data, lambda_eff * n as f64);
        let solver = LocalSdca::new(Sampling::Permutation);
        let up = solver.local_update(&block, &Squared, &alpha, &w_star, n, &mut rng(17));
        for (i, da) in up.dalpha.iter().enumerate() {
            assert!(da.abs() < 1e-12, "coordinate {i} moved by {da} at the optimum");
        }
        assert!(up.dw.iter().all(|dv| dv.abs() < 1e-12));
    }

    #[test]
    fn deterministic_under_seed() {
        let block = test_block(25, 5, 0.2, 50, 6);
        for threads in [1usize, 2, 4] {
            let solver = LocalSdca::new(Sampling::WithReplacement).with_threads(threads);
            let a =
                solver.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
            let b =
                solver.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
            assert_eq!(a.dalpha, b.dalpha);
            assert_eq!(a.dw, b.dw);
        }
    }

    #[test]
    fn threaded_execution_matches_sequential_schedule_bitwise() {
        // the deterministic-per-T contract: running the shard schedule on
        // real threads produces the same bits as replaying it on one
        let block = test_block(30, 5, 0.2, 60, 11);
        for threads in [1usize, 2, 4] {
            for sampling in [Sampling::WithReplacement, Sampling::Permutation] {
                let solver = LocalSdca::new(sampling).with_threads(threads);
                let par = solver.local_update(
                    &block,
                    &Hinge,
                    &vec![0.0; 30],
                    &vec![0.0; 5],
                    60,
                    &mut rng(13),
                );
                let seq = solver.local_update_sequential_schedule(
                    &block,
                    &Hinge,
                    &vec![0.0; 30],
                    &vec![0.0; 5],
                    60,
                    &mut rng(13),
                );
                for (a, b) in par.dalpha.iter().zip(&seq.dalpha) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dalpha diverged at T={threads}");
                }
                for (a, b) in par.dw.iter().zip(&seq.dw) {
                    assert_eq!(a.to_bits(), b.to_bits(), "dw diverged at T={threads}");
                }
            }
        }
    }

    #[test]
    fn one_thread_is_bit_identical_to_legacy_sequential_path() {
        // with_threads(1) must not perturb the RNG stream or any
        // arithmetic relative to the original unsharded solver
        let block = test_block(25, 5, 0.2, 50, 6);
        let legacy = LocalSdca::new(Sampling::WithReplacement);
        let t1 = LocalSdca::new(Sampling::WithReplacement).with_threads(1);
        let a = legacy.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
        let b = t1.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
        for (x, y) in a.dalpha.iter().zip(&b.dalpha) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.dw.iter().zip(&b.dw) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn more_threads_than_coordinates_clamps() {
        let block = test_block(3, 4, 0.5, 6, 8);
        let solver = LocalSdca::new(Sampling::WithReplacement).with_threads(16);
        let up = solver.local_update(&block, &Hinge, &vec![0.0; 3], &vec![0.0; 4], 9, &mut rng(2));
        assert_eq!(up.steps, 9);
        assert_dw_consistent(&block, &up);
    }

    #[test]
    fn permutation_touches_every_coordinate_once_per_pass() {
        let block = test_block(16, 4, 0.5, 16, 8);
        let solver = LocalSdca::new(Sampling::Permutation);
        // one full pass: every coordinate gets exactly one chance to move;
        // with hinge from alpha=0 and w=0, every delta is non-zero
        let up = solver.local_update(
            &block,
            &Hinge,
            &vec![0.0; 16],
            &vec![0.0; 4],
            16,
            &mut rng(9),
        );
        let moved = up.dalpha.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(moved, 16);
    }
}
