//! LocalSDCA — Procedure B of the paper: H randomized dual coordinate
//! ascent steps on the local block, each immediately applied to the local
//! view of `w`. This "apply updates locally while they are processed"
//! behaviour is exactly what distinguishes CoCoA from mini-batch methods.

use super::{Block, LocalDualMethod, LocalUpdate};
use crate::data::Features;
use crate::kernels;
use crate::util::Rng;
use crate::loss::Loss;

/// Coordinate selection scheme for the inner loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// i.i.d. uniform over the block (the paper's Procedure B; what the
    /// convergence analysis assumes).
    WithReplacement,
    /// Random permutation passes (LibLinear-style epochs; often a bit
    /// faster in practice, used by the ablation bench).
    Permutation,
}

/// The paper's recommended local solver.
#[derive(Debug, Clone, Copy)]
pub struct LocalSdca {
    pub sampling: Sampling,
    /// Subproblem curvature multiplier sigma' >= 1. The paper's Algorithm 1
    /// uses 1.0 (safe averaging, beta_K = 1). Setting sigma' = K makes the
    /// *added* (beta_K = K) updates safe — the conclusion's open question,
    /// resolved by the CoCoA+ follow-up; implemented here as an extension.
    pub curvature_scale: f64,
}

impl LocalSdca {
    pub fn new(sampling: Sampling) -> Self {
        LocalSdca { sampling, curvature_scale: 1.0 }
    }

    /// sigma'-scaled variant (CoCoA+ style additive updates).
    pub fn with_curvature_scale(sampling: Sampling, sigma_prime: f64) -> Self {
        assert!(sigma_prime >= 1.0, "sigma' must be >= 1");
        LocalSdca { sampling, curvature_scale: sigma_prime }
    }
}

impl LocalDualMethod for LocalSdca {
    fn name(&self) -> &'static str {
        match self.sampling {
            Sampling::WithReplacement => "local_sdca",
            Sampling::Permutation => "local_sdca_perm",
        }
    }

    fn local_update(
        &self,
        block: &Block,
        loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        let n_k = block.n_k();
        debug_assert_eq!(alpha.len(), n_k);
        assert_eq!(w.len(), block.d(), "w length must match block dimension");
        let mut dalpha = vec![0.0; n_k];
        // Maintain w_local = w + sigma' * dw in place; dw is recovered at
        // the end. For the paper's Algorithm 1 (sigma' = 1) this is just
        // the running local view of w. For the CoCoA+ extension the whole
        // quadratic coupling of the local subproblem — the per-step
        // curvature AND the accumulated cross-coordinate term — carries
        // the sigma' factor, hence the scaled accumulation.
        let mut w_local = w.to_vec();
        let scale = self.curvature_scale;
        let inv_lambda_n = scale / block.lambda_n;
        let sampling = self.sampling;
        let mut perm: Vec<u32> = Vec::new();
        let mut pick = |step: usize, rng: &mut Rng| -> usize {
            match sampling {
                Sampling::WithReplacement => rng.gen_range(n_k),
                Sampling::Permutation => {
                    let pos = step % n_k;
                    if pos == 0 {
                        perm = sample_permutation(n_k, rng);
                    }
                    perm[pos] as usize
                }
            }
        };

        // The inner loop is monomorphized per storage format so each step
        // runs the fused kernels on the row slices directly: one indptr
        // fetch per step, no per-element bounds checks, the curvature
        // division precomputed per shard. Arithmetic (values, order) is
        // identical to the generic Features::row_dot/add_row_scaled path
        // this replaces — the prop_kernels suite pins that bit-for-bit.
        match &block.data.features {
            Features::Sparse(m) => {
                for step in 0..h {
                    let i = pick(step, rng);
                    let (idx, val) = m.row_view(i);
                    // SAFETY: CsrMatrix guarantees index < cols, and
                    // w_local.len() == block.d() == cols (asserted above).
                    let q = unsafe { kernels::sparse_dot_unchecked(idx, val, &w_local) };
                    let a_cur = alpha[i] + dalpha[i];
                    let s = block.curvature(i) * scale;
                    let delta = loss.coord_delta(q, block.data.labels[i], a_cur, s);
                    if delta != 0.0 {
                        dalpha[i] += delta;
                        // SAFETY: as above.
                        unsafe {
                            kernels::sparse_axpy_unchecked(
                                idx,
                                val,
                                delta * inv_lambda_n,
                                &mut w_local,
                            )
                        };
                    }
                }
            }
            Features::Dense(m) => {
                for step in 0..h {
                    let i = pick(step, rng);
                    let row = m.row(i);
                    let q = kernels::dense_dot(row, &w_local);
                    let a_cur = alpha[i] + dalpha[i];
                    let s = block.curvature(i) * scale;
                    let delta = loss.coord_delta(q, block.data.labels[i], a_cur, s);
                    if delta != 0.0 {
                        dalpha[i] += delta;
                        kernels::dense_axpy(delta * inv_lambda_n, row, &mut w_local);
                    }
                }
            }
        }

        // Delta extraction: on sparse shards only touched columns can have
        // moved; untouched columns satisfy w_local[j] == w[j] bit-for-bit,
        // where the old full-d pass computed (x - x)/scale == +0.0 — the
        // same bits the zero-fill writes.
        let dw = match block.touched_cols() {
            Some(cols) => {
                let mut dw = vec![0.0; w.len()];
                for &j in cols {
                    let j = j as usize;
                    dw[j] = (w_local[j] - w[j]) / scale;
                }
                dw
            }
            None => w_local
                .iter()
                .zip(w.iter())
                .map(|(wl, w0)| (wl - w0) / scale)
                .collect(),
        };
        LocalUpdate { dalpha, dw, steps: h as u64, offloaded_s: 0.0 }
    }
}

fn sample_permutation(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut p: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::{Hinge, SmoothedHinge};
    use crate::objective;
    use crate::solvers::test_util::{assert_dw_consistent, test_block};

    fn rng(seed: u64) -> Rng {
        Rng::seed_from_u64(seed)
    }

    #[test]
    fn dw_equals_a_dalpha() {
        let block = test_block(40, 6, 0.05, 80, 0);
        for sampling in [Sampling::WithReplacement, Sampling::Permutation] {
            let solver = LocalSdca::new(sampling);
            let up = solver.local_update(
                &block,
                &Hinge,
                &vec![0.0; 40],
                &vec![0.0; 6],
                120,
                &mut rng(1),
            );
            assert_eq!(up.steps, 120);
            assert_dw_consistent(&block, &up);
        }
    }

    #[test]
    fn local_dual_objective_never_decreases() {
        // Every inner step is exact coordinate ascent on the global dual
        // restricted to the block => applying the *whole* local update (as
        // if K = 1) must improve D.
        let block = test_block(60, 8, 0.1, 60, 2);
        let loss = SmoothedHinge::new(0.5);
        let lambda = 0.1;
        let mut alpha = vec![0.0; 60];
        let mut w = vec![0.0; 8];
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let mut d_prev =
            objective::dual(&block.data, &alpha, lambda, &loss);
        let mut r = rng(3);
        for _ in 0..5 {
            let up = solver.local_update(&block, &loss, &alpha, &w, 90, &mut r);
            for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
                *a += da;
            }
            for (wv, dv) in w.iter_mut().zip(&up.dw) {
                *wv += dv;
            }
            let d_new = objective::dual(&block.data, &alpha, lambda, &loss);
            assert!(
                d_new >= d_prev - 1e-10,
                "dual decreased: {d_prev} -> {d_new}"
            );
            d_prev = d_new;
        }
    }

    #[test]
    fn h_zero_is_noop() {
        let block = test_block(10, 4, 0.1, 10, 4);
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let up = solver.local_update(
            &block,
            &Hinge,
            &vec![0.0; 10],
            &vec![0.0; 4],
            0,
            &mut rng(5),
        );
        assert!(up.dalpha.iter().all(|&v| v == 0.0));
        assert!(up.dw.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn prox_fixed_point_is_a_solver_fixed_point() {
        // The generalized-subproblem contract: run LocalSDCA on the
        // normalized block (lambda_n = lambda*sigma*n) from the *prox
        // fixed point* of a smoothed-L1 problem — alpha_i = y_i - x_i^T w
        // with w = prox(v), v = A alpha — and no coordinate moves. This
        // pins the exact interplay the coordinator relies on: solvers stay
        // prox-oblivious, yet their fixed points are the regularized
        // optima. (Tiny instance re-derived inline on purpose — a solver
        // unit test should not lean on the experiments-layer lasso
        // helpers it ultimately underpins.)
        use crate::loss::Squared;
        use crate::regularizers::{Regularizer, RegularizerKind};

        // orthogonal indicator design: 2 columns x 3 rows each
        let (d, m) = (2usize, 3usize);
        let n = d * m;
        let y_col = [0.9, 0.05]; // one active, one thresholded to zero
        let mut triplets = Vec::new();
        let mut labels = Vec::new();
        for j in 0..d {
            for r in 0..m {
                triplets.push((j * m + r, j as u32, 1.0));
                labels.push(y_col[j]);
            }
        }
        let data = crate::data::Dataset::new(
            crate::data::Features::Sparse(crate::data::CsrMatrix::from_triplets(
                n, d, &triplets,
            )),
            labels,
        );
        let (lambda, eps) = (0.1, 0.5);
        let reg = RegularizerKind::L1 { epsilon: eps }.build();
        let lambda_eff = lambda * reg.strong_convexity();

        // closed-form optimum and its dual point
        let c = m as f64 / n as f64;
        let w_star: Vec<f64> = (0..d)
            .map(|j| {
                crate::regularizers::soft_threshold(m as f64 * y_col[j] / n as f64, lambda)
                    / (lambda * eps + c)
            })
            .collect();
        let alpha: Vec<f64> = (0..n)
            .map(|i| {
                let j = i / m;
                y_col[j] - w_star[j]
            })
            .collect();
        // consistency: prox(v(alpha)) == w_star
        let v = data.primal_from_dual(&alpha, lambda_eff);
        for j in 0..d {
            assert!(
                (reg.prox_coord(v[j]) - w_star[j]).abs() < 1e-12,
                "prox(v[{j}]) != w*[{j}]"
            );
        }

        let block = Block::new(data, lambda_eff * n as f64);
        let solver = LocalSdca::new(Sampling::Permutation);
        let up = solver.local_update(&block, &Squared, &alpha, &w_star, n, &mut rng(17));
        for (i, da) in up.dalpha.iter().enumerate() {
            assert!(da.abs() < 1e-12, "coordinate {i} moved by {da} at the optimum");
        }
        assert!(up.dw.iter().all(|dv| dv.abs() < 1e-12));
    }

    #[test]
    fn deterministic_under_seed() {
        let block = test_block(25, 5, 0.2, 50, 6);
        let solver = LocalSdca::new(Sampling::WithReplacement);
        let a = solver.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
        let b = solver.local_update(&block, &Hinge, &vec![0.0; 25], &vec![0.0; 5], 40, &mut rng(7));
        assert_eq!(a.dalpha, b.dalpha);
        assert_eq!(a.dw, b.dw);
    }

    #[test]
    fn permutation_touches_every_coordinate_once_per_pass() {
        let block = test_block(16, 4, 0.5, 16, 8);
        let solver = LocalSdca::new(Sampling::Permutation);
        // one full pass: every coordinate gets exactly one chance to move;
        // with hinge from alpha=0 and w=0, every delta is non-zero
        let up = solver.local_update(
            &block,
            &Hinge,
            &vec![0.0; 16],
            &vec![0.0; 4],
            16,
            &mut rng(9),
        );
        let moved = up.dalpha.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(moved, 16);
    }
}
