//! Run traces and metrics — the data behind every figure.
//!
//! Each evaluated round appends a [`TraceRow`]; a [`Trace`] serializes to
//! CSV/JSON under `results/` and answers the headline queries ("time to
//! .001-accuracy", "vectors to .001-accuracy") that Figures 1-2 and the
//! 25x claim are built from.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Which stopping criterion (if any) fired at an evaluated round — the
/// disambiguation `Budget::until_gap` vs `Budget::until_subopt` runs need
/// (both used to be indistinguishable in trace output). Non-final rows
/// record [`StopReason::Running`]; the final row records what actually
/// ended the run. Also persisted in checkpoints so a resumed session knows
/// why its source run stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StopReason {
    /// The run was still in progress at this evaluation (or no run has
    /// recorded a stop yet).
    #[default]
    Running,
    /// The round budget (`Budget::rounds` / the `until_*` cap) ran out.
    MaxRounds,
    /// The duality-gap target (`Budget::until_gap` / `target_gap`) fired.
    Gap,
    /// The primal-suboptimality target (`Budget::until_subopt` /
    /// `target_subopt`) fired.
    Subopt,
    /// A simulated-time budget (the driver's `SimTimeBelow` stopping
    /// rule) ran out.
    SimTime,
    /// A communication budget (the driver's `BytesBelow` stopping rule)
    /// ran out.
    Bytes,
}

impl StopReason {
    pub fn as_str(self) -> &'static str {
        match self {
            StopReason::Running => "running",
            StopReason::MaxRounds => "max_rounds",
            StopReason::Gap => "gap",
            StopReason::Subopt => "subopt",
            StopReason::SimTime => "sim_time",
            StopReason::Bytes => "bytes",
        }
    }

    /// Parse the `as_str` token (checkpoint/CSV round-trips).
    pub fn from_name(name: &str) -> Option<StopReason> {
        match name {
            "running" => Some(StopReason::Running),
            "max_rounds" => Some(StopReason::MaxRounds),
            "gap" => Some(StopReason::Gap),
            "subopt" => Some(StopReason::Subopt),
            "sim_time" => Some(StopReason::SimTime),
            "bytes" => Some(StopReason::Bytes),
            _ => None,
        }
    }
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One evaluated point of a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRow {
    pub round: u64,
    /// Simulated distributed time (netsim model; excludes evaluation cost).
    pub sim_time_s: f64,
    /// Accumulated worker compute only (max over workers per round).
    pub compute_time_s: f64,
    /// d-dimensional vectors communicated so far (worker->leader plus
    /// leader->worker broadcasts).
    pub vectors: u64,
    /// Bytes so far per the analytic model (`vectors * d * scalar width`).
    pub bytes_modeled: u64,
    /// Byte-exact bytes so far as measured by the transport ledger
    /// (headers, sparse dw encodings, retransmissions); 0 unless a
    /// measuring transport (counted/simnet/record/replay) is configured.
    pub bytes_measured: u64,
    /// Inner steps performed so far (sum over workers).
    pub inner_steps: u64,
    pub primal: f64,
    /// NaN for primal-only (SGD) methods.
    pub dual: f64,
    pub gap: f64,
    /// `P(w) - P*` when a reference optimum is known, else NaN.
    pub primal_subopt: f64,
    /// Nonzero count of the primal iterate `w` — the sparsity-recovery
    /// axis for L1/elastic-net runs (prox-induced exact zeros; equals the
    /// dense count on typical L2 runs).
    pub w_nnz: u64,
    /// Which stop criterion fired at this row ([`StopReason::Running`] on
    /// non-final rows).
    pub stop: StopReason,
}

impl TraceRow {
    /// The run's best-known byte count so far: the byte-exact measured
    /// total when a measuring transport is active, the analytic modeled
    /// total otherwise. The one convention shared by everything that
    /// reasons about "bytes on the wire" (progress lines, byte-budget
    /// stopping rules).
    pub fn wire_bytes(&self) -> u64 {
        if self.bytes_measured > 0 {
            self.bytes_measured
        } else {
            self.bytes_modeled
        }
    }

    /// This row as one line of the [`Trace::CSV_HEADER`] schema — the
    /// exact text [`Trace::to_csv`] writes, shared with the streaming
    /// CSV observer sink so batch files and streamed files stay
    /// byte-identical.
    pub fn csv_line(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{}",
            self.round,
            self.sim_time_s,
            self.compute_time_s,
            self.vectors,
            self.bytes_modeled,
            self.bytes_measured,
            self.inner_steps,
            self.primal,
            self.dual,
            self.gap,
            self.primal_subopt,
            self.w_nnz,
            self.stop
        )
    }

    /// This row as one JSON object — the exact object [`Trace::to_json`]
    /// nests in its `rows` array, and one line of the streaming JSONL
    /// observer sink (NaN/inf encode as `null`).
    pub fn to_json_object(&self) -> String {
        format!(
            "{{\"round\": {}, \"sim_time_s\": {}, \"compute_time_s\": {}, \"vectors\": {}, \"bytes_modeled\": {}, \"bytes_measured\": {}, \"inner_steps\": {}, \"primal\": {}, \"dual\": {}, \"gap\": {}, \"primal_subopt\": {}, \"w_nnz\": {}, \"stop\": \"{}\"}}",
            self.round,
            json_f64(self.sim_time_s),
            json_f64(self.compute_time_s),
            self.vectors,
            self.bytes_modeled,
            self.bytes_measured,
            self.inner_steps,
            json_f64(self.primal),
            json_f64(self.dual),
            json_f64(self.gap),
            json_f64(self.primal_subopt),
            self.w_nnz,
            self.stop,
        )
    }
}

/// A full run history plus identifying metadata.
#[derive(Debug, Clone)]
pub struct Trace {
    pub algorithm: String,
    pub dataset: String,
    pub k: usize,
    pub h: usize,
    pub beta: f64,
    pub lambda: f64,
    pub rows: Vec<TraceRow>,
}

impl Trace {
    pub fn new(
        algorithm: impl Into<String>,
        dataset: impl Into<String>,
        k: usize,
        h: usize,
        beta: f64,
        lambda: f64,
    ) -> Self {
        Trace {
            algorithm: algorithm.into(),
            dataset: dataset.into(),
            k,
            h,
            beta,
            lambda,
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: TraceRow) {
        self.rows.push(row);
    }

    pub fn last(&self) -> Option<&TraceRow> {
        self.rows.last()
    }

    /// First simulated time at which `primal_subopt <= eps` (Figure 1 /
    /// headline metric). None if never reached.
    pub fn time_to_subopt(&self, eps: f64) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.primal_subopt <= eps)
            .map(|r| r.sim_time_s)
    }

    /// First communicated-vector count at which `primal_subopt <= eps`
    /// (Figure 2's x-axis).
    pub fn vectors_to_subopt(&self, eps: f64) -> Option<u64> {
        self.rows
            .iter()
            .find(|r| r.primal_subopt <= eps)
            .map(|r| r.vectors)
    }

    /// First duality gap <= eps.
    pub fn time_to_gap(&self, eps: f64) -> Option<f64> {
        self.rows.iter().find(|r| r.gap <= eps).map(|r| r.sim_time_s)
    }

    /// Best (smallest) primal value seen.
    pub fn best_primal(&self) -> f64 {
        self.rows.iter().map(|r| r.primal).fold(f64::INFINITY, f64::min)
    }

    /// The CSV schema of [`Trace::to_csv`], one name per [`TraceRow`]
    /// field, in order.
    pub const CSV_HEADER: &str =
        "round,sim_time_s,compute_time_s,vectors,bytes_modeled,bytes_measured,inner_steps,primal,dual,gap,primal_subopt,w_nnz,stop";

    pub fn to_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        writeln!(f, "{}", Self::CSV_HEADER)?;
        for r in &self.rows {
            writeln!(f, "{}", r.csv_line())?;
        }
        Ok(())
    }

    /// Hand-rolled JSON writer (offline build: no serde_json). The format
    /// is stable and consumed by the plotting snippets in EXPERIMENTS.md.
    pub fn to_json<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"algorithm\": \"{}\",", json_escape(&self.algorithm))?;
        writeln!(f, "  \"dataset\": \"{}\",", json_escape(&self.dataset))?;
        writeln!(f, "  \"k\": {},", self.k)?;
        writeln!(f, "  \"h\": {},", self.h)?;
        writeln!(f, "  \"beta\": {},", json_f64(self.beta))?;
        writeln!(f, "  \"lambda\": {},", json_f64(self.lambda))?;
        writeln!(f, "  \"rows\": [")?;
        for (i, r) in self.rows.iter().enumerate() {
            let sep = if i + 1 == self.rows.len() { "" } else { "," };
            writeln!(f, "    {}{}", r.to_json_object(), sep)?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        Ok(())
    }
}

/// JSON has no NaN/inf literals; emit null for them. Shared with the
/// perf harness's `BENCH_*.json` writer so both encoders stay consistent.
pub(crate) fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Escape a string for embedding in a JSON string literal. Labels and
/// algorithm names are arbitrary caller strings ([`crate::Trainer::label`],
/// TOML configs) — a quote or backslash in one must not corrupt the
/// hand-rolled JSON writers.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Thread CPU-time clock: measures a worker's *own* compute, immune to the
/// timesharing distortion of running K worker threads on fewer cores
/// (wall-clock would inflate by the oversubscription factor).
///
/// Bound directly against the system C library (the offline build carries
/// no `libc` crate). The FFI arm is gated to the platforms whose timespec
/// layout ({i64, i64}) and clock id this shim hardcodes — 64-bit Linux
/// (CLOCK_THREAD_CPUTIME_ID = 3) and 64-bit macOS (= 16); everywhere else
/// falls back to monotonic wall time rather than risking a garbage-filled
/// struct from a mismatched ABI. The syscall result is hard-checked: a
/// wrong clock id must fail loudly, not report zero compute forever.
#[cfg(all(any(target_os = "linux", target_os = "macos"), target_pointer_width = "64"))]
pub fn thread_cpu_time_s() -> f64 {
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }
    extern "C" {
        fn clock_gettime(clock_id: i32, tp: *mut Timespec) -> i32;
    }
    #[cfg(target_os = "macos")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 16;
    #[cfg(target_os = "linux")]
    const CLOCK_THREAD_CPUTIME_ID: i32 = 3;
    let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime(CLOCK_THREAD_CPUTIME_ID) failed");
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Fallback for platforms the FFI shim is not vetted on: monotonic wall
/// time from an arbitrary process-local epoch (callers only ever
/// difference two samples; oversubscribed-core timesharing will inflate
/// these readings, unlike the thread-CPU clock).
#[cfg(not(all(any(target_os = "linux", target_os = "macos"), target_pointer_width = "64")))]
pub fn thread_cpu_time_s() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`). `None` where procfs is unavailable — the perf
/// harness records `null` rather than a fabricated number.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, t: f64, vectors: u64, subopt: f64, gap: f64) -> TraceRow {
        TraceRow {
            round,
            sim_time_s: t,
            compute_time_s: t * 0.5,
            vectors,
            bytes_modeled: vectors * 8,
            bytes_measured: vectors * 9 + 16,
            inner_steps: round * 10,
            primal: 0.5 + subopt,
            dual: 0.5 - gap + subopt,
            gap,
            primal_subopt: subopt,
            w_nnz: 3 + round,
            stop: StopReason::Running,
        }
    }

    #[test]
    fn threshold_queries() {
        let mut tr = Trace::new("cocoa", "cov", 4, 100, 1.0, 1e-4);
        tr.push(row(1, 1.0, 8, 0.1, 0.2));
        tr.push(row(2, 2.0, 16, 0.01, 0.02));
        tr.push(row(3, 3.0, 24, 0.0005, 0.001));
        assert_eq!(tr.time_to_subopt(1e-3), Some(3.0));
        assert_eq!(tr.vectors_to_subopt(0.05), Some(16));
        assert_eq!(tr.time_to_gap(0.5), Some(1.0));
        assert_eq!(tr.time_to_subopt(1e-9), None);
    }

    #[test]
    fn csv_roundtrip_row_count() {
        let mut tr = Trace::new("cocoa", "cov", 4, 100, 1.0, 1e-4);
        tr.push(row(1, 1.0, 8, 0.1, 0.2));
        tr.push(row(2, 2.0, 16, 0.01, 0.02));
        // each test writes under its own scratch dir so parallel test
        // threads (and stale leftovers) can never collide
        let dir = std::env::temp_dir().join("cocoa_trace_test_csv");
        let p = dir.join("t.csv");
        tr.to_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3); // header + 2
        let pj = dir.join("t.json");
        tr.to_json(&pj).unwrap();
        let json = std::fs::read_to_string(&pj).unwrap();
        assert!(json.contains("\"algorithm\": \"cocoa\""));
        assert!(json.contains("\"bytes_modeled\": 64"));
        assert!(json.contains("\"bytes_measured\": 88"));
        assert!(json.contains("\"w_nnz\": 4"));
        assert!(json.contains("\"stop\": \"running\""));
        assert_eq!(json.matches("\"round\":").count(), 2);
    }

    #[test]
    fn csv_schema_roundtrips() {
        // The schema contract behind every figure: the header names both
        // byte columns, and each written row parses back to the exact
        // TraceRow it came from (f64 Display is shortest-roundtrip).
        let mut tr = Trace::new("cocoa", "cov", 4, 100, 1.0, 1e-4);
        tr.push(row(1, 0.125, 8, 0.1, 0.2));
        let mut no_ref = row(2, 2.5, 16, 0.01, 0.02);
        no_ref.primal_subopt = f64::NAN; // NaN subopt (no P*) must survive
        no_ref.stop = StopReason::Gap;
        tr.push(no_ref);
        let p = std::env::temp_dir().join("cocoa_trace_test_schema/schema.csv");
        tr.to_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), Trace::CSV_HEADER);
        assert_eq!(
            Trace::CSV_HEADER.split(',').collect::<Vec<_>>(),
            vec![
                "round",
                "sim_time_s",
                "compute_time_s",
                "vectors",
                "bytes_modeled",
                "bytes_measured",
                "inner_steps",
                "primal",
                "dual",
                "gap",
                "primal_subopt",
                "w_nnz",
                "stop",
            ]
        );
        for (line, orig) in lines.zip(&tr.rows) {
            let f: Vec<&str> = line.split(',').collect();
            assert_eq!(f.len(), 13);
            let back = TraceRow {
                round: f[0].parse().unwrap(),
                sim_time_s: f[1].parse().unwrap(),
                compute_time_s: f[2].parse().unwrap(),
                vectors: f[3].parse().unwrap(),
                bytes_modeled: f[4].parse().unwrap(),
                bytes_measured: f[5].parse().unwrap(),
                inner_steps: f[6].parse().unwrap(),
                primal: f[7].parse().unwrap(),
                dual: f[8].parse().unwrap(),
                gap: f[9].parse().unwrap(),
                primal_subopt: f[10].parse().unwrap(),
                w_nnz: f[11].parse().unwrap(),
                stop: StopReason::from_name(f[12]).unwrap(),
            };
            assert_eq!(back.round, orig.round);
            assert_eq!(back.vectors, orig.vectors);
            assert_eq!(back.bytes_modeled, orig.bytes_modeled);
            assert_eq!(back.bytes_measured, orig.bytes_measured);
            assert_eq!(back.inner_steps, orig.inner_steps);
            assert_eq!(back.sim_time_s.to_bits(), orig.sim_time_s.to_bits());
            assert_eq!(back.compute_time_s.to_bits(), orig.compute_time_s.to_bits());
            assert_eq!(back.primal.to_bits(), orig.primal.to_bits());
            assert_eq!(back.dual.to_bits(), orig.dual.to_bits());
            assert_eq!(back.gap.to_bits(), orig.gap.to_bits());
            assert!(
                back.primal_subopt.to_bits() == orig.primal_subopt.to_bits()
                    || (back.primal_subopt.is_nan() && orig.primal_subopt.is_nan())
            );
            assert_eq!(back.w_nnz, orig.w_nnz);
            assert_eq!(back.stop, orig.stop);
        }
    }

    #[test]
    fn stop_reason_roundtrips() {
        for reason in [
            StopReason::Running,
            StopReason::MaxRounds,
            StopReason::Gap,
            StopReason::Subopt,
            StopReason::SimTime,
            StopReason::Bytes,
        ] {
            assert_eq!(StopReason::from_name(reason.as_str()), Some(reason));
        }
        assert_eq!(StopReason::from_name("because"), None);
        assert_eq!(StopReason::default(), StopReason::Running);
    }

    #[test]
    fn row_formatters_match_the_batch_writers() {
        // the streaming sinks reuse these exact strings: one CSV line per
        // row under the shared header, one JSON object per row
        let r = row(3, 1.5, 24, 0.01, 0.02);
        let line = r.csv_line();
        assert_eq!(line.split(',').count(), 13);
        assert!(line.starts_with("3,1.5,0.75,24,"));
        assert!(line.ends_with(",running"));
        let obj = r.to_json_object();
        assert!(obj.starts_with("{\"round\": 3,"));
        assert!(obj.ends_with("\"stop\": \"running\"}"));
        let mut nan_row = r;
        nan_row.primal_subopt = f64::NAN;
        assert!(nan_row.to_json_object().contains("\"primal_subopt\": null"));
    }

    #[test]
    fn json_escape_handles_quotes_backslashes_and_controls() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b"), "a\\\"b");
        assert_eq!(json_escape("a\\b"), "a\\\\b");
        assert_eq!(json_escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        // a hostile dataset label cannot corrupt the JSON writer
        let mut tr = Trace::new("cocoa", "rcv1 \"full\"", 1, 1, 1.0, 0.1);
        tr.push(row(1, 1.0, 8, 0.1, 0.2));
        let p = std::env::temp_dir().join("cocoa_trace_test_escape/escaped.json");
        tr.to_json(&p).unwrap();
        let json = std::fs::read_to_string(&p).unwrap();
        assert!(json.contains("\"dataset\": \"rcv1 \\\"full\\\"\""), "{json}");
    }

    #[test]
    fn peak_rss_reads_procfs_on_linux() {
        if std::path::Path::new("/proc/self/status").exists() {
            let rss = peak_rss_bytes().expect("VmHWM present on Linux");
            // a running test binary occupies at least a few pages
            assert!(rss > 64 * 1024, "implausible peak RSS {rss}");
        }
    }

    #[test]
    fn thread_clock_monotone_and_advancing() {
        let t0 = thread_cpu_time_s();
        // burn a little CPU
        let mut acc = 0.0f64;
        for i in 0..200_000 {
            acc += (i as f64).sqrt();
        }
        assert!(acc > 0.0);
        let t1 = thread_cpu_time_s();
        assert!(t1 >= t0);
        assert!(t1 - t0 < 5.0, "implausible cpu time delta");
    }
}
