//! `cocoa` — CLI launcher for the CoCoA distributed training framework.
//!
//! The [`USAGE`] string below is the single source of truth for
//! subcommands and flags (it used to be duplicated here and the two
//! copies drifted); `cocoa help` prints it verbatim.
//!
//! The binary is self-contained after `make artifacts`: python never runs
//! on this path. (Args are parsed by hand — the offline build carries no
//! clap.)

use anyhow::{anyhow, bail, Result};

use cocoa::config::ExperimentConfig;
use cocoa::coordinator::Checkpoint;
use cocoa::data;
use cocoa::driver::recovery::{run_with_recovery, RecoveryPolicy};
use cocoa::driver::{IntoDriverSpec, Observer, ProgressLine};
use cocoa::experiments::{self, figures, theory_val, Profile};
use cocoa::objective;
use cocoa::obs::{MetricsHub, MetricsServer, SpanSink};
use cocoa::perf::{self, PerfProfile};
use cocoa::regularizers::Regularizer;
use cocoa::serve::{ModelSnapshot, ScoreClient, ScoreIdentity, ScoreServer, Scorer, SnapshotSink};
use cocoa::telemetry::peak_rss_bytes;
use cocoa::transport::net::run_worker_process;
use cocoa::transport::{NetConfig, ReconnectPolicy, TransportKind};

/// Tiny argv helper: `--key value` options + positionals.
struct Args {
    positional: Vec<String>,
    options: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut options = std::collections::HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if flag_names.contains(&name) {
                    flags.insert(name.to_string());
                } else {
                    let value = it
                        .next()
                        .ok_or_else(|| anyhow!("--{name} requires a value"))?;
                    options.insert(name.to_string(), value.clone());
                }
            } else {
                positional.push(arg.clone());
            }
        }
        Ok(Args { positional, options, flags })
    }

    fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    fn req(&self, name: &str) -> Result<&str> {
        self.opt(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }
}

const USAGE: &str = "\
cocoa — communication-efficient distributed dual coordinate ascent (NIPS 2014 reproduction)

USAGE:
  cocoa train --config <toml> [--out <csv>] [--p-star <f64>] [--progress] [--threads <t>]
              [--trace-out <jsonl>] [--rss-budget-mb <mb>]
  cocoa shard --out <dir> --workers <k>
              (--libsvm <file> [--d-hint <d>] [--normalize]
                 [--strategy <contiguous|round_robin|random>] [--partition-seed <s>]
               | --synthetic <rcv1|url|kdd> --n <n> --d <d> [--nnz <per-row>] [--seed <s>])
  cocoa repro <table1|fig1|fig2|fig3|fig4|headline|sparsity|theory|all> [--smoke] [--results-dir <dir>] [--rounds <n>]
  cocoa perf [--smoke] [--out <json>] [--seed <n>]
  cocoa perf --validate <json> [--baseline <json>] [--tolerance <frac>] [--delta <path>]
  cocoa optimum --config <toml>
  cocoa gen-data <cov|rcv1|imagenet> --n <n> --d <d> [--seed <s>] --out <path>
  cocoa leader --config <toml> --listen <tcp:host:port|uds:/path> [--workers <k>] [--out <csv>]
               [--p-star <f64>] [--progress] [--checkpoint-every <n>] [--max-recoveries <m>] [--threads <t>]
               [--trace-out <jsonl>] [--metrics <tcp:host:port|uds:/path>]
  cocoa worker --config <toml> --connect <tcp:host:port|uds:/path> [--attempts <n>] [--backoff-s <s>] [--threads <t>]
  cocoa serve --model <live|ckpt> --config <toml> --listen <tcp:host:port|uds:/path>
              [--snapshot-every <n>] [--serve-s <secs>] [--progress] [--threads <t>]
  cocoa score --connect <tcp:host:port|uds:/path> --libsvm <file> [--d-hint <d>]
              [--attempts <n>] [--backoff-s <s>]

  --threads overrides [runtime] threads from the config (intra-worker shard
  count T for the local solves; trajectories are deterministic per T). In a
  leader/worker deployment every process must agree on T — it is part of
  the handshake fingerprint.

  --trace-out streams one JSON object per round-phase span (broadcast,
  local_solve, reduce, commit, evaluate; wall + CPU seconds) as
  flush-per-line JSONL. --metrics serves live Prometheus text at
  GET /metrics on the given address. Both are passive observers: the
  trajectory is bit-identical with or without them.

  perf --validate alone checks the report's structure only. Add --baseline
  to also gate steps/sec, time-to-1e-3-gap, and peak RSS within the
  --tolerance band (default 0.5 = 50%); --delta writes the comparison
  report to a file for CI artifacts.

  serve answers the scoring protocol of docs/SERVING.md. --model live
  trains the config to its budget while answering every request from the
  freshest snapshot (published every --snapshot-every rounds; the
  publisher is a passive observer, so the trajectory is bit-identical to
  an unserved run), then keeps the final model up for --serve-s seconds
  (default 0) before exiting. --model <ckpt> restores the checkpoint and
  serves it frozen; there --serve-s bounds the lifetime (default: until
  killed). score connects, binds to the served identity in a versioned
  handshake, scores a LibSVM file (.gz accepted), and prints how many
  rows the served margins classify correctly.

  shard writes per-worker on-disk partitions (the out-of-core path; see
  docs/DATA.md). Train from them with `[data] shards = \"dir\"` in the
  config — workers mmap only their own shard, so datasets larger than RAM
  train with a bounded footprint. --rss-budget-mb makes `cocoa train` exit
  nonzero if the process's peak RSS exceeded the budget (the CI gate).
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    match cmd.as_str() {
        "train" => {
            let args = Args::parse(&argv[1..], &["progress"])?;
            let p_star = args.opt("p-star").map(|s| s.parse()).transpose()?;
            train(
                args.req("config")?,
                args.opt("out").map(String::from),
                p_star,
                args.flags.contains("progress"),
                args.opt("threads").map(|s| s.parse()).transpose()?,
                args.opt("trace-out").map(String::from),
                args.opt("rss-budget-mb").map(|s| s.parse()).transpose()?,
            )
        }
        "shard" => {
            let args = Args::parse(&argv[1..], &["normalize"])?;
            shard(&args)
        }
        "repro" => {
            let args = Args::parse(&argv[1..], &["smoke"])?;
            let target = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("repro needs a target (e.g. fig1)"))?;
            let profile =
                if args.flags.contains("smoke") { Profile::Smoke } else { Profile::Paper };
            let rounds = args.opt("rounds").map(|s| s.parse()).transpose()?;
            repro(target, profile, args.opt("results-dir").unwrap_or("results"), rounds)
        }
        "perf" => {
            let args = Args::parse(&argv[1..], &["smoke"])?;
            if let Some(path) = args.opt("validate") {
                let tolerance =
                    args.opt("tolerance").map(|s| s.parse()).transpose()?.unwrap_or(0.5);
                return perf_validate(path, args.opt("baseline"), tolerance, args.opt("delta"));
            }
            let profile =
                if args.flags.contains("smoke") { PerfProfile::Smoke } else { PerfProfile::Full };
            let seed = args.opt("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
            perf_run(
                profile,
                seed,
                args.opt("out").unwrap_or("BENCH_hotpath.json"),
            )
        }
        "optimum" => {
            let args = Args::parse(&argv[1..], &[])?;
            optimum(args.req("config")?)
        }
        "gen-data" => {
            let args = Args::parse(&argv[1..], &[])?;
            let regime = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("gen-data needs a regime (cov|rcv1|imagenet)"))?;
            gen_data(
                regime,
                args.req("n")?.parse()?,
                args.req("d")?.parse()?,
                args.opt("seed").map(|s| s.parse()).transpose()?.unwrap_or(0),
                args.req("out")?,
            )
        }
        "leader" => {
            let args = Args::parse(&argv[1..], &["progress"])?;
            let p_star = args.opt("p-star").map(|s| s.parse()).transpose()?;
            leader(
                args.req("config")?,
                args.opt("listen"),
                args.opt("workers").map(|s| s.parse()).transpose()?,
                args.opt("out").map(String::from),
                p_star,
                args.flags.contains("progress"),
                args.opt("checkpoint-every").map(|s| s.parse()).transpose()?.unwrap_or(1),
                args.opt("max-recoveries").map(|s| s.parse()).transpose()?.unwrap_or(3),
                args.opt("threads").map(|s| s.parse()).transpose()?,
                args.opt("trace-out").map(String::from),
                args.opt("metrics").map(String::from),
            )
        }
        "worker" => {
            let args = Args::parse(&argv[1..], &[])?;
            worker(
                args.req("config")?,
                args.req("connect")?,
                args.opt("attempts").map(|s| s.parse()).transpose()?.unwrap_or(10),
                args.opt("backoff-s").map(|s| s.parse()).transpose()?.unwrap_or(0.2),
                args.opt("threads").map(|s| s.parse()).transpose()?,
            )
        }
        "serve" => {
            let args = Args::parse(&argv[1..], &["progress"])?;
            serve(
                args.req("model")?,
                args.req("config")?,
                args.req("listen")?,
                args.opt("snapshot-every").map(|s| s.parse()).transpose()?.unwrap_or(1),
                args.flags.contains("progress"),
                args.opt("threads").map(|s| s.parse()).transpose()?,
                args.opt("serve-s").map(|s| s.parse()).transpose()?,
            )
        }
        "score" => {
            let args = Args::parse(&argv[1..], &[])?;
            score(
                args.req("connect")?,
                args.req("libsvm")?,
                args.opt("d-hint").map(|s| s.parse()).transpose()?.unwrap_or(0),
                args.opt("attempts").map(|s| s.parse()).transpose()?.unwrap_or(10),
                args.opt("backoff-s").map(|s| s.parse()).transpose()?.unwrap_or(0.2),
            )
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn train(
    config_path: &str,
    out: Option<String>,
    p_star: Option<f64>,
    progress: bool,
    threads: Option<usize>,
    trace_out: Option<String>,
    rss_budget_mb: Option<u64>,
) -> Result<()> {
    let mut cfg = ExperimentConfig::from_toml_file(config_path)?;
    if let Some(t) = threads {
        cfg.runtime.threads = t;
    }
    // `[data] shards = "dir"` trains out-of-core: only the manifest is
    // opened here, and each worker maps just its own shard file
    let shards = match cfg.dataset.shards() {
        Some(_) => Some(cfg.open_shards()?),
        None => None,
    };
    let data = match &shards {
        Some(_) => None,
        None => Some(cfg.dataset.load()?),
    };
    match (&shards, &data) {
        (Some(set), _) => eprintln!(
            "shards {} (n={}, d={}, {:.1} MiB on disk, {:?}) | K={} | {} | loss {} | lambda {} | T={}",
            cfg.dataset.name(),
            set.n(),
            set.d(),
            set.total_bytes() as f64 / (1024.0 * 1024.0),
            set.mode(),
            set.k(),
            cfg.algorithm.name(),
            cfg.loss,
            cfg.lambda,
            cfg.runtime.threads,
        ),
        (_, Some(data)) => eprintln!(
            "dataset {} (n={}, d={}, density={:.4}) | K={} | {} | loss {} | lambda {} | T={}",
            cfg.dataset.name(),
            data.n(),
            data.d(),
            data.density(),
            cfg.partition.k,
            cfg.algorithm.name(),
            cfg.loss,
            cfg.lambda,
            cfg.runtime.threads,
        ),
        (None, None) => unreachable!("exactly one data source"),
    }
    let part_k = shards.as_ref().map(|s| s.k()).unwrap_or(cfg.partition.k);
    let mut session = match (&shards, &data) {
        (Some(set), _) => cfg.trainer_shards(set).build()?,
        (_, Some(data)) => cfg.trainer(data).build()?,
        (None, None) => unreachable!("exactly one data source"),
    };
    session.set_reference_optimum(p_star);
    let mut algorithm = cfg.algorithm.instantiate();
    let mut budget = cfg.run.budget();
    if budget.target_subopt > 0.0 && p_star.is_none() {
        eprintln!(
            "note: config sets target_subopt but no --p-star was given; \
             running to the round/gap budget instead (try `cocoa optimum`)"
        );
        budget.target_subopt = 0.0;
    }
    // span recording is passive — the trajectory is bit-identical with or
    // without it — so turn it on only when someone will read the spans
    session.set_tracing(trace_out.is_some());
    let mut sink = trace_out.as_ref().map(SpanSink::create).transpose()?;
    let trace = if progress || sink.is_some() {
        // live per-round status (round, gap, wire bytes, sim time) on
        // stderr, implemented as a driver Observer — stdout stays clean
        let mut line = ProgressLine::stderr();
        let mut driver = session.drive(algorithm.as_mut(), budget)?;
        if progress {
            driver.observe(&mut line)?;
        }
        if let Some(s) = sink.as_mut() {
            driver.observe(s)?;
        }
        driver.drain()?
    } else {
        session.run(algorithm.as_mut(), budget)?
    };
    let d = session.d();
    session.shutdown();

    let last = trace.last().expect("at least round 0 recorded");
    println!(
        "finished: rounds={} sim_time={:.3}s vectors={} P={:.6} D={:.6} gap={:.2e} stop={}",
        last.round, last.sim_time_s, last.vectors, last.primal, last.dual, last.gap, last.stop
    );
    if cfg.regularizer.build().sparsity_hint() {
        println!("sparsity: {} of {d} coordinates nonzero", last.w_nnz);
    }
    if last.bytes_measured > 0 {
        println!(
            "measured communication: {} B on the wire (modeled {} B)",
            last.bytes_measured, last.bytes_modeled
        );
    }
    let out = out.unwrap_or_else(|| {
        format!(
            "results/train_{}_{}_k{}_h{}.csv",
            cfg.dataset.name(),
            cfg.algorithm.name(),
            part_k,
            cfg.algorithm.h()
        )
    });
    trace.to_csv(&out)?;
    eprintln!("trace -> {out}");
    if let Some(path) = &trace_out {
        eprintln!("spans -> {path}");
    }
    if let Some(budget_mb) = rss_budget_mb {
        let peak = peak_rss_bytes().unwrap_or(0);
        if peak == 0 {
            eprintln!(
                "rss budget: peak RSS unavailable on this platform; \
                 --rss-budget-mb {budget_mb} not enforced"
            );
        } else if peak > budget_mb * 1024 * 1024 {
            bail!(
                "peak RSS {:.1} MiB exceeds --rss-budget-mb {budget_mb}",
                peak as f64 / (1024.0 * 1024.0)
            );
        } else {
            eprintln!(
                "rss budget: peak RSS {:.1} MiB within --rss-budget-mb {budget_mb}",
                peak as f64 / (1024.0 * 1024.0)
            );
        }
    }
    Ok(())
}

/// `cocoa shard`: write a per-worker on-disk shard set (the out-of-core
/// ingest step; see docs/DATA.md). Sources are mutually exclusive:
/// `--libsvm` streams an existing file through the single-pass sharder,
/// `--synthetic` generates an rcv1/url/kdd-regime dataset row by row.
/// Neither materializes the full dataset in memory.
fn shard(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.req("out")?);
    let k: usize = args.req("workers")?.parse()?;
    let set = if let Some(path) = args.opt("libsvm") {
        if args.opt("synthetic").is_some() {
            bail!("--libsvm and --synthetic are mutually exclusive");
        }
        let strategy_name = args.opt("strategy").unwrap_or("contiguous");
        let strategy = data::PartitionStrategy::from_name(strategy_name).ok_or_else(|| {
            anyhow!("unknown --strategy {strategy_name:?} (contiguous|round_robin|random)")
        })?;
        let partition_seed =
            args.opt("partition-seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
        let d_hint = args.opt("d-hint").map(|s| s.parse()).transpose()?.unwrap_or(0);
        data::shard_libsvm(
            path,
            &dir,
            k,
            strategy,
            partition_seed,
            d_hint,
            args.flags.contains("normalize"),
        )?
    } else if let Some(regime) = args.opt("synthetic") {
        if args.opt("strategy").is_some() || args.opt("partition-seed").is_some() {
            bail!(
                "--strategy/--partition-seed apply to --libsvm only; \
                 the streaming synthetic generators shard round-robin"
            );
        }
        let n: usize = args.req("n")?.parse()?;
        let d: usize = args.req("d")?.parse()?;
        let nnz = args.opt("nnz").map(|s| s.parse()).transpose()?.unwrap_or(16);
        let seed = args.opt("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
        match regime {
            "rcv1" => data::rcv1_stream_shards(n, d, nnz, seed, k, &dir)?,
            "url" => data::url_stream_shards(n, d, nnz, seed, k, &dir)?,
            "kdd" => data::kdd_stream_shards(n, d, nnz, seed, k, &dir)?,
            other => bail!("unknown synthetic regime {other:?} (rcv1|url|kdd)"),
        }
    } else {
        bail!("shard needs a source: --libsvm <file> or --synthetic <rcv1|url|kdd>");
    };
    eprintln!(
        "sharded n={} d={} nnz={} into K={} shards under {} \
         ({:.1} MiB on disk, fingerprint {})",
        set.n(),
        set.d(),
        set.nnz(),
        set.k(),
        dir.display(),
        set.total_bytes() as f64 / (1024.0 * 1024.0),
        set.fingerprint(),
    );
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader(
    config_path: &str,
    listen: Option<&str>,
    workers: Option<usize>,
    out: Option<String>,
    p_star: Option<f64>,
    progress: bool,
    checkpoint_every: u64,
    max_recoveries: u32,
    threads: Option<usize>,
    trace_out: Option<String>,
    metrics: Option<String>,
) -> Result<()> {
    let mut cfg = ExperimentConfig::from_toml_file(config_path)?;
    if let Some(t) = threads {
        cfg.runtime.threads = t;
    }
    // shard-backed configs never load rows into the leader: the manifest
    // supplies n/d/partition, and evaluate() is distributed anyway
    let shards = match cfg.dataset.shards() {
        Some(_) => Some(cfg.open_shards()?),
        None => None,
    };
    let data = match &shards {
        Some(_) => None,
        None => Some(cfg.dataset.load()?),
    };
    let part_k = shards.as_ref().map(|s| s.k()).unwrap_or(cfg.partition.k);
    if let Some(k) = workers {
        if k != part_k {
            bail!(
                "--workers {k} disagrees with the configured partition (k = {part_k}); \
                 every worker derives its block from the same config, so the \
                 two must match"
            );
        }
    }
    // start from the config's [transport.net] section when present so
    // timeouts/taping survive; the flag overrides the listen address
    let mut netcfg = match &cfg.transport {
        TransportKind::Net(c) => c.clone(),
        _ => NetConfig::new(""),
    };
    if let Some(addr) = listen {
        netcfg.listen = addr.to_string();
    }
    if netcfg.listen.is_empty() {
        bail!("no listen address: pass --listen or set listen under [transport.net]");
    }
    let (ln, ld) = match (&shards, &data) {
        (Some(set), _) => (set.n(), set.d()),
        (_, Some(ds)) => (ds.n(), ds.d()),
        (None, None) => unreachable!("exactly one data source"),
    };
    eprintln!(
        "leader: dataset {} (n={ln}, d={ld}) | {} | waiting for {part_k} workers on {}",
        cfg.dataset.name(),
        cfg.algorithm.name(),
        netcfg.listen,
    );
    let mut session = match (&shards, &data) {
        (Some(set), _) => cfg
            .trainer_shards(set)
            .transport(TransportKind::Net(netcfg))
            .build()?,
        (_, Some(ds)) => cfg.trainer(ds).transport(TransportKind::Net(netcfg)).build()?,
        (None, None) => unreachable!("exactly one data source"),
    };
    session.set_reference_optimum(p_star);
    let mut algorithm = cfg.algorithm.instantiate();
    let mut budget = cfg.run.budget();
    if budget.target_subopt > 0.0 && p_star.is_none() {
        eprintln!(
            "note: config sets target_subopt but no --p-star was given; \
             running to the round/gap budget instead (try `cocoa optimum`)"
        );
        budget.target_subopt = 0.0;
    }
    let policy = RecoveryPolicy { max_recoveries };
    let make_spec = || Ok(budget.into_spec()?.checkpoint_every(checkpoint_every));
    // spans feed --trace-out and the /metrics phase timings; both are
    // passive observers, so the flags only decide who listens
    session.set_tracing(trace_out.is_some() || metrics.is_some());
    let hub = MetricsHub::new();
    let server = match &metrics {
        Some(addr) => {
            let srv = MetricsServer::serve(addr, hub.clone())?;
            eprintln!("metrics: serving GET /metrics on {addr}");
            Some(srv)
        }
        None => None,
    };
    let mut line = ProgressLine::stderr();
    let mut sink = trace_out.as_ref().map(SpanSink::create).transpose()?;
    let mut hub_obs = hub.observer();
    let mut extra: Vec<&mut dyn Observer> = Vec::new();
    if progress {
        extra.push(&mut line);
    }
    if let Some(s) = sink.as_mut() {
        extra.push(s);
    }
    if metrics.is_some() {
        extra.push(&mut hub_obs);
    }
    let outcome =
        run_with_recovery(&mut session, algorithm.as_mut(), make_spec, &policy, &mut extra)?;
    let trace = outcome.trace;
    let d = session.d();
    let stats = session.socket_stats();
    // run-wide peak RSS: the workers' wire-reported maxima folded with the
    // leader's own footprint
    let run_rss = session.max_worker_rss().max(peak_rss_bytes().unwrap_or(0));
    hub.observe_leader_rss(run_rss);
    session.shutdown();

    let last = trace.last().expect("at least round 0 recorded");
    println!(
        "finished: rounds={} sim_time={:.3}s vectors={} P={:.6} D={:.6} gap={:.2e} stop={}",
        last.round, last.sim_time_s, last.vectors, last.primal, last.dual, last.gap, last.stop
    );
    if outcome.recoveries > 0 {
        println!("recoveries: {} checkpoint restores", outcome.recoveries);
    }
    if cfg.regularizer.build().sparsity_hint() {
        println!("sparsity: {} of {d} coordinates nonzero", last.w_nnz);
    }
    if let Some(s) = stats {
        println!(
            "socket: sent {} B / recv {} B in {} frames \
             (payload {} B, framing {} B, handshake {} B)",
            s.sent_bytes,
            s.recv_bytes,
            s.sent_frames + s.recv_frames,
            s.payload_bytes(),
            s.framing_bytes,
            s.handshake_bytes,
        );
    }
    if run_rss > 0 {
        println!(
            "peak RSS (leader+workers): {:.1} MiB",
            run_rss as f64 / (1024.0 * 1024.0)
        );
    }
    let out = out.unwrap_or_else(|| {
        format!(
            "results/leader_{}_{}_k{}_h{}.csv",
            cfg.dataset.name(),
            cfg.algorithm.name(),
            part_k,
            cfg.algorithm.h()
        )
    });
    trace.to_csv(&out)?;
    eprintln!("trace -> {out}");
    if let Some(path) = &trace_out {
        eprintln!("spans -> {path}");
    }
    if let Some(srv) = server {
        srv.shutdown();
    }
    Ok(())
}

fn worker(
    config_path: &str,
    connect: &str,
    attempts: u32,
    backoff_s: f64,
    threads: Option<usize>,
) -> Result<()> {
    let mut cfg = ExperimentConfig::from_toml_file(config_path)?;
    if let Some(t) = threads {
        cfg.runtime.threads = t;
    }
    eprintln!(
        "worker: dataset {} | {} | T={} | connecting to {connect}",
        cfg.dataset.name(),
        cfg.algorithm.name(),
        cfg.runtime.threads,
    );
    run_worker_process(&cfg, connect, &ReconnectPolicy { attempts, backoff_s })?;
    eprintln!("worker: clean shutdown");
    Ok(())
}

/// `cocoa serve`: answer the scoring protocol on `listen`. `--model
/// live` trains the config while serving (every request reads the
/// freshest published snapshot); `--model <ckpt>` restores the
/// checkpoint through a session (so the regularizer's prox and all
/// shape/identity validation apply) and serves the recovered `w`
/// frozen.
fn serve(
    model: &str,
    config_path: &str,
    listen: &str,
    snapshot_every: u64,
    progress: bool,
    threads: Option<usize>,
    serve_s: Option<f64>,
) -> Result<()> {
    let mut cfg = ExperimentConfig::from_toml_file(config_path)?;
    if let Some(t) = threads {
        cfg.runtime.threads = t;
    }
    let shards = match cfg.dataset.shards() {
        Some(_) => Some(cfg.open_shards()?),
        None => None,
    };
    let data = match &shards {
        Some(_) => None,
        None => Some(cfg.dataset.load()?),
    };
    let mut session = match (&shards, &data) {
        (Some(set), _) => cfg.trainer_shards(set).build()?,
        (_, Some(ds)) => cfg.trainer(ds).build()?,
        (None, None) => unreachable!("exactly one data source"),
    };

    if model == "live" {
        let mut sink = SnapshotSink::for_session(&session, snapshot_every);
        let server = ScoreServer::serve(listen, Scorer::live(sink.handle()))?;
        eprintln!(
            "serve: {} (d={}, fingerprint {}) live on {listen}, \
             snapshot every {} round(s)",
            cfg.dataset.name(),
            session.d(),
            session.fingerprint(),
            snapshot_every.max(1),
        );
        let mut algorithm = cfg.algorithm.instantiate();
        let mut budget = cfg.run.budget();
        if budget.target_subopt > 0.0 {
            eprintln!("note: target_subopt needs --p-star; serving to the round/gap budget");
            budget.target_subopt = 0.0;
        }
        let trace = {
            let mut line = ProgressLine::stderr();
            let mut driver = session.drive(algorithm.as_mut(), budget)?;
            driver.observe(&mut sink)?;
            if progress {
                driver.observe(&mut line)?;
            }
            driver.drain()?
        };
        let last = trace.last().expect("at least round 0 recorded");
        println!(
            "finished: rounds={} gap={:.2e} stop={}",
            last.round, last.gap, last.stop
        );
        if let Some(s) = serve_s {
            eprintln!("serve: final model up for {s:.1}s more on {listen}");
            std::thread::sleep(std::time::Duration::from_secs_f64(s.max(0.0)));
        }
        println!("predictions served: {}", server.predictions_served());
        server.shutdown();
        session.shutdown();
    } else {
        let cp = Checkpoint::load(model)?;
        session.restore(&cp)?;
        let snapshot = ModelSnapshot {
            epoch: 0,
            round: cp.round_counter,
            w: session.w().to_vec(),
            loss: session.loss().to_string(),
            regularizer: session.regularizer().to_string(),
            fingerprint: session.fingerprint().to_string(),
        };
        session.shutdown();
        let server = ScoreServer::serve(listen, Scorer::frozen(snapshot))?;
        eprintln!(
            "serve: frozen model from {model} (round {}) on {listen}{}",
            cp.round_counter,
            match serve_s {
                Some(s) => format!(" for {s:.1}s"),
                None => " until killed".into(),
            },
        );
        match serve_s {
            Some(s) => std::thread::sleep(std::time::Duration::from_secs_f64(s.max(0.0))),
            None => loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            },
        }
        println!("predictions served: {}", server.predictions_served());
        server.shutdown();
    }
    Ok(())
}

/// `cocoa score`: one handshake, one batch. Reads a LibSVM file (plain
/// or `.gz`), scores every row against the served model, and reports
/// how many rows the margins classify correctly — the line ci.sh greps.
fn score(
    connect: &str,
    libsvm: &str,
    d_hint: usize,
    attempts: u32,
    backoff_s: f64,
) -> Result<()> {
    let ds = data::read_libsvm(libsvm, d_hint)?;
    let mut client =
        ScoreClient::connect_with_retry(connect, &ScoreIdentity::any(), attempts, backoff_s)?;
    let id = client.identity();
    eprintln!(
        "score: bound to served model d={} loss {} fingerprint {}",
        id.d, id.loss, id.fingerprint
    );
    let scores = client.score(&ds.features)?;
    let correct = scores
        .margins
        .iter()
        .zip(&ds.labels)
        .filter(|(m, y)| (**m >= 0.0) == (**y > 0.0))
        .count();
    println!(
        "scored {} rows from {libsvm}: {correct} correct (snapshot round {}, epoch {})",
        scores.margins.len(),
        scores.round,
        scores.epoch,
    );
    Ok(())
}

fn repro(target: &str, profile: Profile, results_dir: &str, rounds: Option<u64>) -> Result<()> {
    match target {
        "table1" => {
            println!("Table 1: Datasets for Empirical Study");
            println!(
                "{:<10} {:>10} {:>8} {:>9} {:>4} {:>10}",
                "dataset", "n", "d", "density", "K", "lambda"
            );
            for row in experiments::table1(profile) {
                println!(
                    "{:<10} {:>10} {:>8} {:>9.4} {:>4} {:>10.1e}",
                    row.name, row.n, row.d, row.density, row.k, row.lambda
                );
            }
        }
        "fig1" | "fig2" => {
            let rounds = rounds.unwrap_or(default_rounds(profile));
            for ds in experiments::datasets(profile) {
                let best =
                    figures::fig1_fig2_dataset(&ds, profile, rounds, 1e-3, results_dir)?;
                println!(
                    "\n{} (K={}): suboptimality vs time / vs communicated vectors",
                    ds.name, ds.k
                );
                println!(
                    "{:<14} {:>8} {:>16} {:>18} {:>12}",
                    "algorithm", "best H", "t(.001) sim s", "vectors(.001)", "final subopt"
                );
                for b in &best {
                    println!(
                        "{:<14} {:>8} {:>16} {:>18} {:>12.2e}",
                        b.algorithm,
                        b.h,
                        b.time_to_target
                            .map(|t| format!("{t:.2}"))
                            .unwrap_or("-".into()),
                        b.vectors_to_target
                            .map(|v| v.to_string())
                            .unwrap_or("-".into()),
                        b.final_subopt
                    );
                }
                let h = figures::headline(&best, ds.name);
                if let Some(s) = h.speedup {
                    println!(
                        "  -> CoCoA speedup to .001-accuracy: {s:.1}x over {}",
                        h.best_other.unwrap().0
                    );
                }
            }
        }
        "fig3" => {
            let rounds = rounds.unwrap_or(default_rounds(profile));
            let ds = &experiments::datasets(profile)[0]; // cov, K = 4 (paper)
            let runs = figures::fig3(ds, profile, rounds, results_dir)?;
            println!("Figure 3: effect of H on CoCoA ({} K={})", ds.name, ds.k);
            println!("{:>8} {:>14} {:>14} {:>14}", "H", "rounds", "final subopt", "sim time s");
            for (h, tr) in &runs {
                let last = tr.rows.last().unwrap();
                println!(
                    "{:>8} {:>14} {:>14.2e} {:>14.2}",
                    h, last.round, last.primal_subopt, last.sim_time_s
                );
            }
        }
        "fig4" => {
            let rounds = rounds.unwrap_or(default_rounds(profile));
            let ds = &experiments::datasets(profile)[0];
            let n_k = ds.data.n() / ds.k;
            for h in [n_k, 100.min(n_k)] {
                let cells = figures::fig4(ds, h, rounds, 1e-3, results_dir)?;
                println!("\nFigure 4: beta scaling on {} at H={h}", ds.name);
                println!(
                    "{:<14} {:>10} {:>16} {:>14}",
                    "algorithm", "beta", "t(.001) sim s", "final subopt"
                );
                for c in &cells {
                    println!(
                        "{:<14} {:>10.1} {:>16} {:>14.2e}",
                        c.algorithm,
                        c.beta,
                        c.time_to_target
                            .map(|t| format!("{t:.2}"))
                            .unwrap_or("-".into()),
                        c.final_subopt
                    );
                }
            }
        }
        "headline" => {
            let rounds = rounds.unwrap_or(default_rounds(profile));
            let mut speedups = Vec::new();
            for ds in experiments::datasets(profile) {
                let best =
                    figures::fig1_fig2_dataset(&ds, profile, rounds, 1e-3, results_dir)?;
                let h = figures::headline(&best, ds.name);
                println!(
                    "{:<10} cocoa {:>10} best-other {:>22} speedup {}",
                    h.dataset,
                    h.cocoa_time.map(|t| format!("{t:.2}s")).unwrap_or("-".into()),
                    h.best_other
                        .clone()
                        .map(|(n, t)| format!("{n} {t:.2}s"))
                        .unwrap_or("-".into()),
                    h.speedup.map(|s| format!("{s:.1}x")).unwrap_or("-".into()),
                );
                if let Some(s) = h.speedup {
                    speedups.push(s);
                }
            }
            if !speedups.is_empty() {
                let geo = speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64;
                println!("geometric-mean speedup: {:.1}x (paper reports ~25x)", geo.exp());
            }
        }
        "sparsity" => {
            let rounds = rounds.unwrap_or(match profile {
                Profile::Smoke => 250,
                Profile::Paper => 400,
            });
            let runs = experiments::sparsity::sparsity_recovery(profile, rounds, results_dir)?;
            println!("Sparsity recovery: CoCoA + smoothed-L1 on the planted lasso design");
            println!(
                "{:>3} {:>8} {:>10} {:>10} {:>14} {:>12}",
                "K", "nnz", "true nnz", "support", "final subopt", "wire bytes"
            );
            for r in &runs {
                println!(
                    "{:>3} {:>8} {:>10} {:>10} {:>14.2e} {:>12}",
                    r.k,
                    r.final_nnz,
                    r.true_nnz,
                    if r.support_exact { "exact" } else { "MISSED" },
                    r.final_subopt,
                    r.bytes_measured
                );
            }
            println!("traces -> {results_dir}/fig_sparsity/lasso_K{{1,2,4}}.csv");
        }
        "theory" => {
            let data = match profile {
                Profile::Smoke => data::cov_like(600, 12, 0.05, 31),
                Profile::Paper => data::cov_like(4000, 20, 0.05, 31),
            };
            let lambda = 10.0 / data.n() as f64;
            println!("Theorem 2 validation (smoothed hinge, gamma=1, lambda={lambda:.1e}):");
            println!(
                "{:>3} {:>7} {:>10} {:>9} {:>11} {:>11} {:>6}",
                "K", "H", "Theta", "sigma", "pred rate", "meas rate", "ok"
            );
            for (k, h) in [(1usize, 50usize), (2, 50), (4, 50), (4, 200), (8, 50)] {
                let rep = theory_val::validate(&data, k, h, lambda, 1.0, 20, 7)?;
                println!(
                    "{:>3} {:>7} {:>10.4} {:>9.2} {:>11.5} {:>11.5} {:>6}",
                    rep.k,
                    rep.h,
                    rep.theta,
                    rep.sigma,
                    rep.predicted_rate,
                    rep.measured_rate,
                    if rep.bound_respected { "yes" } else { "NO" }
                );
            }
        }
        "all" => {
            for t in ["table1", "fig1", "fig3", "fig4", "sparsity", "theory"] {
                repro(t, profile, results_dir, rounds)?;
            }
        }
        other => bail!(
            "unknown repro target {other:?} \
             (try table1|fig1|fig2|fig3|fig4|headline|sparsity|theory|all)"
        ),
    }
    Ok(())
}

fn default_rounds(profile: Profile) -> u64 {
    match profile {
        Profile::Smoke => 150,
        Profile::Paper => 60,
    }
}

fn perf_run(profile: PerfProfile, seed: u64, out: &str) -> Result<()> {
    eprintln!(
        "perf: profile {} seed {seed} -> {out} \
         (3 in-memory families x K in {{1, 4}}, sparse also at T = 4, \
         plus the _ooc out-of-core and serve_ scoring families)",
        profile.as_str()
    );
    let mut report = perf::run_all(profile, seed)?;
    // the out-of-core family: stream-generate shard sets in a scratch
    // dir, train from mmap, and record dataset bytes next to peak RSS
    // (the validator then enforces rss * 2 <= dataset_bytes)
    let ooc_dir = std::env::temp_dir().join(format!("cocoa_ooc_{seed}"));
    let ooc = perf::run_ooc(profile, seed, &ooc_dir)?;
    let _ = std::fs::remove_dir_all(&ooc_dir);
    report.workloads.extend(ooc);
    // the serving family: batched scoring through live snapshots
    report.workloads.extend(perf::run_serve(profile, seed)?);
    println!(
        "{:<24} {:>3} {:>3} {:>9} {:>9} {:>13} {:>12} {:>14} {:>12}",
        "workload", "K", "T", "n", "d", "steps/s", "final gap", "t(gap 1e-3) s", "wire bytes"
    );
    for w in &report.workloads {
        println!(
            "{:<24} {:>3} {:>3} {:>9} {:>9} {:>13.0} {:>12.2e} {:>14} {:>12}",
            w.name,
            w.k,
            w.threads,
            w.n,
            w.d,
            w.steps_per_sec,
            w.final_gap,
            w.time_to_gap_1e3_s
                .map(|t| format!("{t:.3}"))
                .unwrap_or("-".into()),
            w.bytes_measured,
        );
    }
    for w in &report.workloads {
        if let (Some(pps), Some(p99)) = (w.predictions_per_sec, w.p99_latency_s) {
            println!(
                "{}: {:.0} predictions/s, p99 batch latency {:.3} ms",
                w.name,
                pps,
                p99 * 1000.0,
            );
        }
    }
    for w in &report.workloads {
        if let (Some(ds), Some(rss)) = (w.dataset_bytes, w.peak_rss_bytes) {
            println!(
                "{}: dataset {:.1} MiB on disk, peak RSS {:.1} MiB ({:.1}x headroom)",
                w.name,
                ds as f64 / (1024.0 * 1024.0),
                rss as f64 / (1024.0 * 1024.0),
                ds as f64 / rss.max(1) as f64,
            );
        }
    }
    if let Some(rss) = report.peak_rss_bytes {
        println!("peak RSS: {:.1} MiB", rss as f64 / (1024.0 * 1024.0));
    }
    report.write(out)?;
    // self-validate: the file CI uploads must always pass the same gate
    // CI runs, so a schema regression fails here first
    perf::validate_file(std::path::Path::new(out)).map_err(|e| anyhow!("{e}"))?;
    eprintln!(
        "report -> {out} (schema v{}, kernel backend {})",
        perf::SCHEMA_VERSION,
        report.kernel_backend
    );
    Ok(())
}

/// `cocoa perf --validate`: always the structural schema check; with
/// `--baseline` also the regression gate. The output states what was and
/// wasn't checked, and the process exits nonzero if the gate fails.
fn perf_validate(
    path: &str,
    baseline: Option<&str>,
    tolerance: f64,
    delta: Option<&str>,
) -> Result<()> {
    perf::validate_file(std::path::Path::new(path)).map_err(|e| anyhow!("{e}"))?;
    println!(
        "{path}: schema v{} OK (fields present, numbers finite, round times monotone)",
        perf::SCHEMA_VERSION
    );
    let Some(baseline) = baseline else {
        println!(
            "{path}: timings NOT compared — no --baseline given \
             (pass --baseline <json> to gate steps/sec, time-to-gap, and peak RSS)"
        );
        return Ok(());
    };
    let outcome = perf::compare_files(
        std::path::Path::new(path),
        std::path::Path::new(baseline),
        tolerance,
    )
    .map_err(|e| anyhow!("{e}"))?;
    let rendered = outcome.render();
    print!("{rendered}");
    if let Some(delta_path) = delta {
        std::fs::write(delta_path, &rendered)?;
        eprintln!("delta report -> {delta_path}");
    }
    if !outcome.passed() {
        bail!(
            "perf gate failed: {} regression(s) vs {baseline} at tolerance {tolerance}",
            outcome.failures.len()
        );
    }
    Ok(())
}

fn optimum(config_path: &str) -> Result<()> {
    let cfg = ExperimentConfig::from_toml_file(config_path)?;
    let data = cfg.dataset.load()?;
    let loss = cfg.loss.build();
    // honor the [regularizer] section: an L1/elastic-net config must get
    // the *regularized* optimum, not the plain-L2 one
    let p_star = if cfg.regularizer.is_l2() {
        objective::compute_optimum(&data, cfg.lambda, loss.as_ref(), 1e-9, 4000).0
    } else {
        let reg = cfg.regularizer.build();
        objective::compute_optimum_reg(&data, cfg.lambda, reg.as_ref(), loss.as_ref(), 1e-9, 4000)
            .0
    };
    println!("{p_star:.12}");
    Ok(())
}

fn gen_data(regime: &str, n: usize, d: usize, seed: u64, out: &str) -> Result<()> {
    let ds = match regime {
        "cov" => data::cov_like(n, d, 0.1, seed),
        "rcv1" => data::rcv1_like(n, d, 12, 0.1, seed),
        "imagenet" => data::imagenet_like(n, d, 0.1, seed),
        other => bail!("unknown regime {other:?} (cov|rcv1|imagenet)"),
    };
    data::write_libsvm(&ds, out)?;
    eprintln!("wrote {} rows x {} cols to {out}", ds.n(), ds.d());
    Ok(())
}
