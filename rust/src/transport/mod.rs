//! Pluggable leader <-> worker transport.
//!
//! The paper's headline claim is that rounds are expensive — so the round
//! trip itself must be a first-class, measurable object, not hard-wired
//! channels. This module abstracts the leader's view of the message fabric
//! behind the [`Transport`] trait, with four backends:
//!
//! * [`InProc`] — the plain std-channel path; zero overhead, nothing
//!   measured. The default.
//! * [`Counted`] — wraps any backend with byte-exact serialized-size
//!   accounting per [`MessageKind`] (broadcasts, delta-w replies, eval,
//!   checkpoints), using the wire layout of [`wire`]. Measured bytes (not
//!   analytic vector counts) then drive the
//!   [`netsim`](crate::netsim::NetworkModel) round time and the
//!   `bytes_measured` telemetry column.
//! * [`SimNet`] — a deterministic, seedable adversary: per-message latency
//!   jitter, bounded drop/retransmit cycles, and per-reply stragglers. It
//!   only perturbs *accounting* (bytes, simulated latency) — message
//!   contents and per-worker ordering are untouched, so the optimization
//!   trajectory is bit-identical to [`InProc`] with the same seed (tested
//!   in `tests/prop_transport.rs`).
//! * [`Record`] / [`Replay`] — record a transcript of every leader-visible
//!   event, then deterministically re-serve it: a replayed run reproduces
//!   the original trace bit for bit without any live worker traffic, and
//!   fails with a typed error the moment the driver diverges from the
//!   tape.
//! * [`net`] — the real thing: the wire layout framed over TCP or
//!   Unix-domain sockets to K `cocoa worker` *processes*, with a
//!   versioned, fingerprinted handshake, per-recv deadlines, and
//!   checkpoint-based recovery when a worker dies. Its ledger is read off
//!   the actual socket writes, so "measured bytes" stops being a
//!   simulation.
//!
//! Selection is declarative via [`TransportKind`]
//! ([`Trainer::transport`](crate::Trainer::transport) or the `[transport]`
//! TOML section); construction happens inside
//! [`Cluster::spawn`](crate::Cluster), which always builds the real
//! channel fabric and then wraps the leader endpoints (for
//! [`TransportKind::Net`] it instead binds a listener and accepts the
//! remote workers).

pub mod net;
pub mod wire;

mod replay;
mod simnet;

pub use self::net::{NetConfig, ReconnectPolicy, SocketStats};
pub use self::replay::{Record, Replay, ReplayEvent, Transcript};
pub use self::simnet::{SimNet, SimNetConfig};
pub use self::wire::{decode_dw, encode_dw, DwEncoding, MessageKind, WireError};

use std::sync::mpsc::{Receiver, Sender};

use crate::coordinator::{ToLeader, ToWorker};
use crate::error::{Error, Result};

use self::wire::KIND_COUNT;

/// Byte-exact communication ledger: message counts and serialized sizes
/// per [`MessageKind`], exactly as the wire layout of [`wire`] would have
/// carried them. Order-independent (pure sums), so totals are invariant
/// across reruns of a deterministic run.
#[derive(Debug, Clone, Copy, Default)]
pub struct Ledger {
    msgs: [u64; KIND_COUNT],
    bytes: [u64; KIND_COUNT],
    /// Wasted retransmissions injected by [`SimNet`] drops; their bytes are
    /// already included in the per-kind totals.
    pub retransmits: u64,
}

impl Ledger {
    pub(crate) fn count(&mut self, kind: MessageKind, bytes: u64) {
        self.msgs[kind.index()] += 1;
        self.bytes[kind.index()] += bytes;
    }

    pub fn bytes(&self, kind: MessageKind) -> u64 {
        self.bytes[kind.index()]
    }

    pub fn msgs(&self, kind: MessageKind) -> u64 {
        self.msgs[kind.index()]
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Algorithm communication only (broadcast + commit + delta-w) — the
    /// traffic the paper's figures charge for; eval, checkpoint, and
    /// control traffic are excluded.
    pub fn algorithm_bytes(&self) -> u64 {
        MessageKind::ALL
            .iter()
            .filter(|k| k.is_algorithm())
            .map(|k| self.bytes[k.index()])
            .sum()
    }

    /// `(kind, messages, bytes)` rows for reporting.
    pub fn rows(&self) -> impl Iterator<Item = (MessageKind, u64, u64)> + '_ {
        MessageKind::ALL
            .iter()
            .map(move |&k| (k, self.msgs[k.index()], self.bytes[k.index()]))
    }
}

/// Shared metering state of every measuring backend: the ledger plus the
/// high-water mark `take_round_bytes` drains against. One implementation
/// of the count/drain/reset laws keeps counted, simnet, record, and
/// replay byte-for-byte in agreement.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Meter {
    pub ledger: Ledger,
    round_mark: u64,
}

impl Meter {
    pub fn count(&mut self, kind: MessageKind, bytes: u64) {
        self.ledger.count(kind, bytes);
    }

    /// Algorithm bytes accumulated since the previous drain.
    pub fn drain(&mut self) -> u64 {
        let total = self.ledger.algorithm_bytes();
        let delta = total - self.round_mark;
        self.round_mark = total;
        delta
    }

    pub fn reset(&mut self) {
        *self = Meter::default();
    }
}

/// The leader's view of the leader <-> worker message fabric. One
/// transport serves one cluster; worker threads keep their raw channel
/// endpoints — the trait abstracts (and instruments) the leader side,
/// where all communication accounting lives.
pub trait Transport: Send {
    fn name(&self) -> &'static str;

    /// Deliver `msg` to worker `to`.
    fn send(&mut self, to: usize, msg: ToWorker) -> Result<()>;

    /// Block for the next leader-bound message.
    fn recv(&mut self) -> Result<ToLeader>;

    /// Byte-exact ledger, when this backend measures (`None`: unmeasured).
    fn ledger(&self) -> Option<&Ledger> {
        None
    }

    /// Measured algorithm bytes since the previous call (`None`:
    /// unmeasured). Drained by the coordinator once per round.
    fn take_round_bytes(&mut self) -> Option<u64> {
        None
    }

    /// Injected latency (jitter, retransmit timeouts, stragglers) since
    /// the previous call, max over workers — it joins the round barrier.
    fn take_round_latency(&mut self) -> f64 {
        0.0
    }

    /// Transcript recorded so far ([`Record`] backend; `None` otherwise).
    fn take_transcript(&mut self) -> Option<Transcript> {
        None
    }

    /// Re-accept connections for dead peers after a failure (net backend;
    /// wrappers forward). Returns how many connections were (re)made.
    /// Backends without a notion of reconnection return a typed error.
    fn heal(&mut self) -> Result<usize> {
        Err(Error::Transport {
            message: format!("transport {:?} does not support reconnection", self.name()),
        })
    }

    /// Raw socket accounting (net backend; wrappers forward).
    fn socket_stats(&self) -> Option<SocketStats> {
        None
    }

    /// Forget all accounting/replay state. `Session::reset` warm-start
    /// contract: a reset transport is indistinguishable from a fresh one.
    fn reset_state(&mut self) {}
}

/// The zero-overhead default: plain std channels, nothing measured.
pub struct InProc {
    to_workers: Vec<Sender<ToWorker>>,
    from_workers: Receiver<ToLeader>,
}

impl InProc {
    pub(crate) fn new(
        to_workers: Vec<Sender<ToWorker>>,
        from_workers: Receiver<ToLeader>,
    ) -> Self {
        InProc { to_workers, from_workers }
    }

    pub(crate) fn k(&self) -> usize {
        self.to_workers.len()
    }
}

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn send(&mut self, to: usize, msg: ToWorker) -> Result<()> {
        self.to_workers[to].send(msg).map_err(|_| Error::Transport {
            message: format!("worker {to} channel closed"),
        })
    }

    fn recv(&mut self) -> Result<ToLeader> {
        self.from_workers.recv().map_err(|_| Error::Transport {
            message: "all workers disconnected".into(),
        })
    }
}

/// Wraps any backend with byte-exact per-kind accounting.
pub struct Counted<T: Transport> {
    inner: T,
    meter: Meter,
}

impl<T: Transport> Counted<T> {
    pub fn over(inner: T) -> Self {
        Counted { inner, meter: Meter::default() }
    }
}

impl<T: Transport> Transport for Counted<T> {
    fn name(&self) -> &'static str {
        "counted"
    }

    fn send(&mut self, to: usize, msg: ToWorker) -> Result<()> {
        let (kind, bytes) = wire::to_worker_wire(&msg);
        self.meter.count(kind, bytes);
        self.inner.send(to, msg)
    }

    fn recv(&mut self) -> Result<ToLeader> {
        let msg = self.inner.recv()?;
        let (kind, bytes) = wire::to_leader_wire(&msg);
        self.meter.count(kind, bytes);
        Ok(msg)
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.meter.ledger)
    }

    fn take_round_bytes(&mut self) -> Option<u64> {
        Some(self.meter.drain())
    }

    fn take_round_latency(&mut self) -> f64 {
        self.inner.take_round_latency()
    }

    fn heal(&mut self) -> Result<usize> {
        self.inner.heal()
    }

    fn socket_stats(&self) -> Option<SocketStats> {
        self.inner.socket_stats()
    }

    fn reset_state(&mut self) {
        self.meter.reset();
        self.inner.reset_state();
    }
}

/// Declarative transport selection — the builder/TOML-facing side of the
/// backends above. Validated (typed) at `Trainer::build`.
#[derive(Debug, Clone, Default)]
pub enum TransportKind {
    /// Plain in-process channels; zero overhead, bytes not measured.
    #[default]
    InProc,
    /// [`InProc`] + byte-exact accounting; measured bytes drive netsim.
    Counted,
    /// Deterministic seeded fault/latency injection + accounting.
    SimNet(SimNetConfig),
    /// Byte-exact accounting + a full transcript for later [`Replay`].
    Record,
    /// Serve a previously recorded transcript (no live worker traffic).
    Replay(std::sync::Arc<Transcript>),
    /// Real sockets to K `cocoa worker` processes (TCP or UDS), with
    /// byte-exact accounting read off the socket writes. Set
    /// [`NetConfig::record`] to tape the traffic for later [`Replay`].
    Net(NetConfig),
}

impl TransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Counted => "counted",
            TransportKind::SimNet(_) => "simnet",
            TransportKind::Record => "record",
            TransportKind::Replay(_) => "replay",
            TransportKind::Net(_) => "net",
        }
    }

    /// Typed validation — called by `Trainer::build` before any thread is
    /// spawned.
    pub fn validate(&self) -> Result<()> {
        match self {
            TransportKind::SimNet(cfg) => cfg
                .validate()
                .map_err(|reason| Error::InvalidTransport { reason })?,
            TransportKind::Net(cfg) => cfg.validate()?,
            _ => {}
        }
        Ok(())
    }

    /// Wrap the leader endpoints of a freshly spawned cluster.
    pub(crate) fn build(self, inner: InProc) -> Box<dyn Transport> {
        match self {
            TransportKind::InProc => Box::new(inner),
            TransportKind::Counted => Box::new(Counted::over(inner)),
            TransportKind::SimNet(cfg) => Box::new(SimNet::over(inner, cfg)),
            TransportKind::Record => Box::new(Record::over(inner)),
            TransportKind::Replay(t) => Box::new(Replay::serve(inner, t)),
            // handled by Cluster::spawn before any channel fabric exists:
            // net workers are remote processes, not threads
            TransportKind::Net(_) => {
                unreachable!("net transport is bound by Cluster::spawn, not built over channels")
            }
        }
    }
}

impl PartialEq for TransportKind {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (TransportKind::InProc, TransportKind::InProc)
            | (TransportKind::Counted, TransportKind::Counted)
            | (TransportKind::Record, TransportKind::Record) => true,
            (TransportKind::SimNet(a), TransportKind::SimNet(b)) => a == b,
            (TransportKind::Replay(a), TransportKind::Replay(b)) => {
                std::sync::Arc::ptr_eq(a, b)
            }
            (TransportKind::Net(a), TransportKind::Net(b)) => a == b,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_sums_per_kind_and_total() {
        let mut ledger = Ledger::default();
        ledger.count(MessageKind::Broadcast, 100);
        ledger.count(MessageKind::Broadcast, 50);
        ledger.count(MessageKind::DeltaW, 30);
        ledger.count(MessageKind::EvalReply, 7);
        assert_eq!(ledger.bytes(MessageKind::Broadcast), 150);
        assert_eq!(ledger.msgs(MessageKind::Broadcast), 2);
        assert_eq!(ledger.total_bytes(), 187);
        // eval traffic is instrumentation, not algorithm communication
        assert_eq!(ledger.algorithm_bytes(), 180);
        let rows: Vec<_> = ledger.rows().collect();
        assert_eq!(rows.len(), wire::KIND_COUNT);
    }

    #[test]
    fn kind_selection_names_and_equality() {
        assert_eq!(TransportKind::default().name(), "inproc");
        assert_eq!(TransportKind::Counted.name(), "counted");
        assert_eq!(TransportKind::SimNet(SimNetConfig::new(1)).name(), "simnet");
        assert_eq!(TransportKind::InProc, TransportKind::InProc);
        assert_ne!(TransportKind::InProc, TransportKind::Counted);
        assert_eq!(
            TransportKind::SimNet(SimNetConfig::new(1)),
            TransportKind::SimNet(SimNetConfig::new(1))
        );
        assert_ne!(
            TransportKind::SimNet(SimNetConfig::new(1)),
            TransportKind::SimNet(SimNetConfig::new(2))
        );
    }

    #[test]
    fn invalid_simnet_config_is_typed() {
        let mut cfg = SimNetConfig::new(0);
        cfg.drop_prob = 1.5;
        let err = TransportKind::SimNet(cfg).validate().unwrap_err();
        assert!(matches!(err, Error::InvalidTransport { .. }), "{err}");
        assert!(TransportKind::SimNet(SimNetConfig::new(0)).validate().is_ok());
    }
}
