//! The `cocoa worker` side: connect to a leader, handshake, and serve
//! frames by driving the shared [`WorkerCore`] state machine.
//!
//! The worker derives everything from the *same* experiment config the
//! leader loaded: dataset, partition, and per-slot seed come from
//! [`native_worker_config`], the code path the in-process threads use —
//! so a multi-process run computes bit-identical updates by
//! construction, and [`run_fingerprint`](super::run_fingerprint) proves
//! both sides agree before any training traffic flows.
//!
//! Shard-backed configs (`[data] shards = "dir"`) are the out-of-core
//! deployment: the worker opens only the shard-set *manifest* before
//! connecting (fingerprint and partition both come from it), then after
//! each handshake opens just its assigned slot's shard file via
//! [`shard_worker_config`] — the full dataset never exists in any single
//! process, yet the fingerprint (and hence the trajectory) is identical
//! to the in-memory deployment's.
//!
//! Connection loss is survivable: the worker reconnects with bounded
//! exponential backoff (a fresh connection starts with a fresh core; the
//! leader's checkpoint recovery restores real state via `SetState`). A
//! handshake *rejection* is not retried — the peer is running a
//! different experiment, and retrying can never fix that.

use super::{
    decode_handshake_reply, encode_hello, read_frame, write_frame, FrameRead, HandshakeReply,
    NetAddr, ReconnectPolicy, Sock,
};
use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::worker::{CoreStep, WorkerCore};
use crate::coordinator::{native_worker_config, shard_worker_config, ToLeader};
use crate::error::{Error, Result};
use crate::transport::wire;

/// Why one serve session over one connection ended.
enum Served {
    /// Leader ordered a clean shutdown — the run is over.
    Shutdown,
    /// The connection died; reconnecting may resume the run.
    Lost(String),
}

/// Run one worker process to completion: connect to `connect`, pass the
/// fingerprint handshake, and serve the assigned block until the leader
/// orders shutdown. Returns `Ok(())` only on a clean shutdown.
pub fn run_worker_process(
    cfg: &ExperimentConfig,
    connect: &str,
    policy: &ReconnectPolicy,
) -> Result<()> {
    let addr = NetAddr::parse(connect)?;
    if cfg.run.backend == Backend::Pjrt {
        return Err(Error::InvalidTransport {
            reason: "net workers require the native backend (run.backend = \"native\")".into(),
        });
    }
    if policy.attempts == 0 || !policy.backoff_s.is_finite() || policy.backoff_s < 0.0 {
        return Err(Error::InvalidTransport {
            reason: format!(
                "reconnect policy needs attempts >= 1 and a finite backoff, got {policy:?}"
            ),
        });
    }
    // Shard-backed: open the manifest only (cheap — no row data); the
    // slot's shard file is opened after each handshake assigns it.
    let (shards, data) = match cfg.dataset.shards() {
        Some(_) => (Some(cfg.open_shards()?), None),
        None => (None, Some(cfg.dataset.load().map_err(Error::from)?)),
    };
    let partition = match (&shards, &data) {
        (Some(set), _) => {
            if cfg.partition.k != 0 && cfg.partition.k != set.k() {
                return Err(Error::Config {
                    message: format!(
                        "[partition] k = {} does not match the shard set (written for K = {})",
                        cfg.partition.k,
                        set.k()
                    ),
                });
            }
            set.partition()
        }
        (_, Some(ds)) => cfg.partition.build(ds.n()),
        (None, None) => unreachable!("exactly one data source"),
    };
    let fingerprint = match (&shards, &data) {
        (Some(set), _) => super::run_fingerprint_parts(
            set.fingerprint(),
            set.n(),
            set.d(),
            &partition,
            cfg.loss,
            cfg.regularizer,
            cfg.algorithm.solver_kind(),
            cfg.lambda,
            cfg.run.seed,
            cfg.runtime.threads,
        ),
        (_, Some(ds)) => super::run_fingerprint(
            ds,
            &partition,
            cfg.loss,
            cfg.regularizer,
            cfg.algorithm.solver_kind(),
            cfg.lambda,
            cfg.run.seed,
            cfg.runtime.threads,
        ),
        (None, None) => unreachable!("exactly one data source"),
    };

    // the slot we held on the previous connection; re-requested on
    // reconnect so recovery restores the same block when possible
    let mut held: Option<usize> = None;
    let mut failures: u32 = 0;
    // lifetime count of successful handshakes: unlike `failures` (which a
    // handshake resets), this only grows, and `connections - 1` is the
    // reconnect total reported in every metrics block
    let mut connections: u64 = 0;
    loop {
        let mut sock = match Sock::connect(&addr) {
            Ok(s) => s,
            Err(e) => {
                failures += 1;
                if failures >= policy.attempts {
                    return Err(Error::Transport {
                        message: format!(
                            "connect {connect} failed after {failures} attempts: {e}"
                        ),
                    });
                }
                std::thread::sleep(policy.delay(failures));
                continue;
            }
        };

        let slot = match handshake(&mut sock, held, fingerprint) {
            Ok(slot) => slot,
            Err(HandshakeEnd::Rejected(reason)) => return Err(Error::Handshake { reason }),
            Err(HandshakeEnd::Lost(_)) => {
                failures += 1;
                if failures >= policy.attempts {
                    return Err(Error::PeerLost {
                        worker: held.unwrap_or(usize::MAX),
                        reason: format!("leader unreachable after {failures} attempts"),
                    });
                }
                std::thread::sleep(policy.delay(failures));
                continue;
            }
        };
        if slot >= partition.blocks.len() {
            return Err(Error::Handshake {
                reason: format!(
                    "leader assigned slot {slot} of a {}-block partition",
                    partition.blocks.len()
                ),
            });
        }
        held = Some(slot);
        failures = 0; // a full handshake resets the reconnect budget
        connections += 1;

        // A fresh core per connection: zero dual state, slot-seeded rng.
        // After a recovery the leader's SetState overwrites both before
        // any round work is dispatched.
        let core_cfg = match (&shards, &data) {
            (Some(set), _) => shard_worker_config(
                set,
                slot,
                cfg.loss,
                cfg.lambda,
                cfg.regularizer,
                cfg.algorithm.solver_kind(),
                cfg.run.seed,
                cfg.runtime.threads,
            )?,
            (_, Some(ds)) => native_worker_config(
                ds,
                &partition.blocks[slot],
                cfg.loss,
                cfg.lambda,
                cfg.regularizer,
                cfg.algorithm.solver_kind(),
                cfg.run.seed,
                slot,
                cfg.runtime.threads,
            ),
            (None, None) => unreachable!("exactly one data source"),
        };
        let mut core = WorkerCore::new(core_cfg);
        core.set_reconnects(connections - 1);
        match serve(&mut sock, &mut core)? {
            Served::Shutdown => return Ok(()),
            Served::Lost(_) => {
                failures += 1;
                if failures >= policy.attempts {
                    return Err(Error::PeerLost {
                        worker: slot,
                        reason: format!("leader unreachable after {failures} attempts"),
                    });
                }
                std::thread::sleep(policy.delay(failures));
            }
        }
    }
}

enum HandshakeEnd {
    /// Typed rejection from the leader: wrong fingerprint/version/slot.
    Rejected(String),
    /// Connection-level failure before an answer; retryable.
    Lost(String),
}

/// Send the hello and wait for the slot assignment. Blocks until the
/// leader answers — a reconnecting worker queued in the listener backlog
/// waits here until the leader's recovery `heal` accepts it.
fn handshake(
    sock: &mut Sock,
    held: Option<usize>,
    fingerprint: u64,
) -> std::result::Result<usize, HandshakeEnd> {
    write_frame(sock, &encode_hello(held, fingerprint))
        .map_err(|e| HandshakeEnd::Lost(format!("hello write failed: {e}")))?;
    let frame = match read_frame(sock) {
        Ok(FrameRead::Frame(f)) => f,
        Ok(FrameRead::Eof) => {
            return Err(HandshakeEnd::Lost("leader closed before answering hello".into()))
        }
        Err(e) => return Err(HandshakeEnd::Lost(format!("handshake read failed: {e}"))),
    };
    match decode_handshake_reply(&frame) {
        Ok(HandshakeReply::Accept { slot }) => Ok(slot),
        Ok(HandshakeReply::Reject { reason }) => Err(HandshakeEnd::Rejected(reason)),
        Err(e) => Err(HandshakeEnd::Rejected(format!("undecodable handshake reply: {e}"))),
    }
}

/// Serve one connection until shutdown, connection loss, or a fatal
/// state error. `Err` means the worker must not continue (its state or
/// the leader's frames can no longer be trusted).
fn serve(sock: &mut Sock, core: &mut WorkerCore) -> Result<Served> {
    loop {
        let payload = match read_frame(sock) {
            Ok(FrameRead::Frame(p)) => p,
            Ok(FrameRead::Eof) => return Ok(Served::Lost("leader closed the connection".into())),
            Err(e) => return Ok(Served::Lost(format!("read failed: {e}"))),
        };
        // an undecodable frame from an accepted leader is not a blip —
        // the peers disagree about the protocol; bail out for good
        let msg = wire::decode_to_worker(&payload).map_err(Error::from)?;
        match core.handle(msg) {
            CoreStep::Continue => {}
            CoreStep::Reply(reply) => {
                if let Err(e) = write_frame(sock, &wire::encode_to_leader(&reply)) {
                    return Ok(Served::Lost(format!("write failed: {e}")));
                }
            }
            CoreStep::ReplyWithMetrics(reply, metrics) => {
                // the round reply first, its observability block right
                // behind it — same frame order the in-process path sends
                for msg in [reply, metrics] {
                    if let Err(e) = write_frame(sock, &wire::encode_to_leader(&msg)) {
                        return Ok(Served::Lost(format!("write failed: {e}")));
                    }
                }
            }
            CoreStep::Fatal(reply) => {
                // best-effort report to the leader, then refuse to serve:
                // the core's state is no longer trustworthy
                let _ = write_frame(sock, &wire::encode_to_leader(&reply));
                let message = match reply {
                    ToLeader::Fatal { message, .. } => message,
                    _ => "worker entered a fatal state".into(),
                };
                return Err(Error::Runtime { message });
            }
            CoreStep::Shutdown => return Ok(Served::Shutdown),
        }
    }
}
