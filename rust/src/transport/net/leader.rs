//! The leader's socket transport: K accepted worker connections behind
//! the [`Transport`] trait.
//!
//! One reader thread per connection turns frames into [`ToLeader`]
//! messages on a single event queue; `recv` drains it under a deadline.
//! Each connection carries a generation counter so events from a dead
//! connection can never be mistaken for its replacement's — a stale
//! `RoundReply` racing a reconnect is discarded by generation, not by
//! guesswork.
//!
//! Accounting: the per-kind [`Ledger`](crate::transport::Ledger) counts
//! exactly the payload bytes that crossed the socket (the encoder's
//! length equals the sizing function's by construction), while
//! [`SocketStats`] counts raw socket bytes — payloads plus 4-byte frame
//! prefixes plus handshake traffic — so the two reconcile exactly:
//! `sent + recv == ledger.total + framing + handshake`.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{
    decode_hello, encode_accept, encode_reject, read_frame, write_frame, FrameRead, NetAddr,
    NetConfig, NetListener, Sock, SocketStats, LEN_PREFIX_BYTES,
};
use crate::coordinator::{ToLeader, ToWorker};
use crate::error::{Error, Result};
use crate::transport::wire;
use crate::transport::{Ledger, Meter, Transport};

/// How often the accept loop polls its nonblocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Read deadline during a handshake — a connected-but-silent peer must
/// not stall the accept loop for the whole accept window.
const HANDSHAKE_READ_TIMEOUT: Duration = Duration::from_secs(1);

/// One worker slot's connection state.
struct Peer {
    /// Write half; `None` until the slot's first handshake completes or
    /// after the connection died.
    writer: Option<Sock>,
    reader: Option<JoinHandle<()>>,
    /// Bumped on every (re)connection; events carry the generation they
    /// were read under, and stale ones are discarded.
    gen: u64,
    alive: bool,
}

enum PeerEvent {
    Msg { slot: usize, gen: u64, msg: ToLeader, frame_bytes: u64 },
    Down { slot: usize, gen: u64, reason: String },
}

/// The real-socket [`Transport`]: see the module docs.
pub struct NetTransport {
    listener: NetListener,
    peers: Vec<Peer>,
    events: Receiver<PeerEvent>,
    events_tx: Sender<PeerEvent>,
    meter: Meter,
    stats: SocketStats,
    accept_timeout: Duration,
    recv_timeout: Duration,
    fingerprint: u64,
}

impl NetTransport {
    /// Bind the configured listener and block until all `k` workers have
    /// connected and passed the handshake (or the accept window closes
    /// with a typed [`Error::Timeout`]).
    pub(crate) fn bind(cfg: &NetConfig, k: usize, fingerprint: u64) -> Result<NetTransport> {
        let addr = NetAddr::parse(&cfg.listen)?;
        let listener = NetListener::bind(&addr)?;
        listener.set_nonblocking(true).map_err(|e| Error::Transport {
            message: format!("listener setup failed: {e}"),
        })?;
        let (events_tx, events) = channel();
        let mut t = NetTransport {
            listener,
            peers: (0..k)
                .map(|_| Peer { writer: None, reader: None, gen: 0, alive: false })
                .collect(),
            events,
            events_tx,
            meter: Meter::default(),
            stats: SocketStats::default(),
            accept_timeout: Duration::from_secs_f64(cfg.accept_timeout_s),
            recv_timeout: Duration::from_secs_f64(cfg.recv_timeout_s),
            fingerprint,
        };
        t.accept_workers()?;
        Ok(t)
    }

    /// Accept + handshake connections until every slot is alive. A
    /// rejected peer (bad fingerprint, garbage hello, cluster full) does
    /// not abort the loop — the slot stays open for a valid worker.
    fn accept_workers(&mut self) -> Result<usize> {
        let deadline = Instant::now() + self.accept_timeout;
        let mut made = 0;
        while self.peers.iter().any(|p| !p.alive) {
            match self.listener.accept() {
                Ok(sock) => {
                    if self.handshake(sock).is_ok() {
                        made += 1;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(Error::Timeout {
                            waited_s: self.accept_timeout.as_secs_f64(),
                        });
                    }
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => {
                    return Err(Error::Transport { message: format!("accept failed: {e}") })
                }
            }
        }
        Ok(made)
    }

    /// Run the handshake on one fresh connection and install it in a
    /// slot. Errors reject *this peer* only.
    fn handshake(&mut self, mut sock: Sock) -> Result<()> {
        let setup_err =
            |e: std::io::Error| Error::Handshake { reason: format!("socket setup failed: {e}") };
        sock.set_read_timeout(Some(HANDSHAKE_READ_TIMEOUT)).map_err(setup_err)?;
        let frame = match read_frame(&mut sock) {
            Ok(FrameRead::Frame(f)) => f,
            Ok(FrameRead::Eof) => {
                return Err(Error::Handshake { reason: "peer closed before hello".into() })
            }
            Err(e) => return Err(Error::Handshake { reason: format!("hello read failed: {e}") }),
        };
        self.stats.handshake_bytes += LEN_PREFIX_BYTES + frame.len() as u64;
        let hello = match decode_hello(&frame) {
            Ok(h) => h,
            Err(e) => {
                let reason = format!("bad hello: {e}");
                self.reject(&mut sock, &reason);
                return Err(Error::Handshake { reason });
            }
        };
        if hello.fingerprint != self.fingerprint {
            let reason = format!(
                "run fingerprint {:016x} does not match leader {:016x} \
                 (different dataset, partition, loss, regularizer, solver, lambda, or seed)",
                hello.fingerprint, self.fingerprint
            );
            self.reject(&mut sock, &reason);
            return Err(Error::Handshake { reason });
        }
        // a reconnecting worker gets its old slot back when free;
        // otherwise the lowest free slot (the worker builds its block
        // from whatever slot the accept assigns)
        let free = |p: &Peer| !p.alive;
        let slot = match hello.requested.filter(|&s| s < self.peers.len() && free(&self.peers[s]))
        {
            Some(s) => s,
            None => match self.peers.iter().position(free) {
                Some(s) => s,
                None => {
                    self.reject(&mut sock, "cluster full: all slots are connected");
                    return Err(Error::Handshake { reason: "cluster full".into() });
                }
            },
        };
        let accept = encode_accept(slot);
        write_frame(&mut sock, &accept)
            .map_err(|e| Error::Handshake { reason: format!("accept write failed: {e}") })?;
        self.stats.handshake_bytes += LEN_PREFIX_BYTES + accept.len() as u64;
        sock.set_read_timeout(None).map_err(setup_err)?;
        let reader_half = sock.try_clone().map_err(setup_err)?;

        let peer = &mut self.peers[slot];
        peer.gen += 1;
        let gen = peer.gen;
        // the previous reader (if any) exited when its socket died /
        // was shut down — join it before installing the replacement
        if let Some(h) = peer.reader.take() {
            let _ = h.join();
        }
        let tx = self.events_tx.clone();
        peer.reader = Some(std::thread::spawn(move || reader_loop(reader_half, slot, gen, tx)));
        peer.writer = Some(sock);
        peer.alive = true;
        Ok(())
    }

    fn reject(&mut self, sock: &mut Sock, reason: &str) {
        let frame = encode_reject(reason);
        if write_frame(sock, &frame).is_ok() {
            self.stats.handshake_bytes += LEN_PREFIX_BYTES + frame.len() as u64;
        }
    }

    /// Tear down a slot's connection (idempotent). Shutting the socket
    /// down unblocks the reader thread, so the join is prompt.
    fn drop_peer(&mut self, slot: usize) {
        let peer = &mut self.peers[slot];
        peer.alive = false;
        if let Some(w) = peer.writer.take() {
            let _ = w.shutdown();
        }
        if let Some(h) = peer.reader.take() {
            let _ = h.join();
        }
    }
}

fn reader_loop(mut sock: Sock, slot: usize, gen: u64, tx: Sender<PeerEvent>) {
    loop {
        match read_frame(&mut sock) {
            Ok(FrameRead::Frame(payload)) => match wire::decode_to_leader(&payload) {
                Ok(msg) => {
                    let frame_bytes = LEN_PREFIX_BYTES + payload.len() as u64;
                    if tx.send(PeerEvent::Msg { slot, gen, msg, frame_bytes }).is_err() {
                        return; // transport dropped
                    }
                }
                Err(e) => {
                    let _ = tx.send(PeerEvent::Down {
                        slot,
                        gen,
                        reason: format!("undecodable frame: {e}"),
                    });
                    return;
                }
            },
            Ok(FrameRead::Eof) => {
                let _ = tx.send(PeerEvent::Down {
                    slot,
                    gen,
                    reason: "connection closed".into(),
                });
                return;
            }
            Err(e) => {
                let _ = tx.send(PeerEvent::Down { slot, gen, reason: format!("read failed: {e}") });
                return;
            }
        }
    }
}

impl Transport for NetTransport {
    fn name(&self) -> &'static str {
        "net"
    }

    fn send(&mut self, to: usize, msg: ToWorker) -> Result<()> {
        if to >= self.peers.len() {
            return Err(Error::Transport {
                message: format!("send to worker {to} of a {}-worker cluster", self.peers.len()),
            });
        }
        let (kind, bytes) = wire::to_worker_wire(&msg);
        let payload = wire::encode_to_worker(&msg, to);
        debug_assert_eq!(payload.len() as u64, bytes);
        let Some(writer) = self.peers[to].writer.as_mut() else {
            return Err(Error::PeerLost { worker: to, reason: "no live connection".into() });
        };
        if let Err(e) = write_frame(writer, &payload) {
            self.drop_peer(to);
            return Err(Error::PeerLost { worker: to, reason: format!("write failed: {e}") });
        }
        self.meter.count(kind, bytes);
        self.stats.sent_bytes += LEN_PREFIX_BYTES + bytes;
        self.stats.sent_frames += 1;
        self.stats.framing_bytes += LEN_PREFIX_BYTES;
        Ok(())
    }

    fn recv(&mut self) -> Result<ToLeader> {
        loop {
            match self.events.recv_timeout(self.recv_timeout) {
                Ok(PeerEvent::Msg { slot, gen, msg, frame_bytes }) => {
                    if gen != self.peers[slot].gen || !self.peers[slot].alive {
                        continue; // from a connection we already replaced
                    }
                    let (kind, bytes) = wire::to_leader_wire(&msg);
                    self.meter.count(kind, bytes);
                    self.stats.recv_bytes += frame_bytes;
                    self.stats.recv_frames += 1;
                    self.stats.framing_bytes += LEN_PREFIX_BYTES;
                    return Ok(msg);
                }
                Ok(PeerEvent::Down { slot, gen, reason }) => {
                    if gen != self.peers[slot].gen || !self.peers[slot].alive {
                        continue;
                    }
                    self.drop_peer(slot);
                    return Err(Error::PeerLost { worker: slot, reason });
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::Timeout { waited_s: self.recv_timeout.as_secs_f64() })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("transport holds its own event sender")
                }
            }
        }
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.meter.ledger)
    }

    fn take_round_bytes(&mut self) -> Option<u64> {
        Some(self.meter.drain())
    }

    fn heal(&mut self) -> Result<usize> {
        // fold queued failures in first so every dead slot is refilled in
        // one accept pass; queued data messages (a survivor's stale
        // replies) are kept — the recovery barrier drains them
        let queued: Vec<PeerEvent> = self.events.try_iter().collect();
        for ev in queued {
            match ev {
                PeerEvent::Down { slot, gen, .. }
                    if gen == self.peers[slot].gen && self.peers[slot].alive =>
                {
                    self.drop_peer(slot)
                }
                PeerEvent::Down { .. } => {}
                msg @ PeerEvent::Msg { .. } => {
                    let _ = self.events_tx.send(msg);
                }
            }
        }
        self.accept_workers()
    }

    fn socket_stats(&self) -> Option<SocketStats> {
        Some(self.stats)
    }

    fn reset_state(&mut self) {
        self.meter.reset();
        self.stats = SocketStats::default();
    }
}

impl Drop for NetTransport {
    fn drop(&mut self) {
        for slot in 0..self.peers.len() {
            self.drop_peer(slot);
        }
    }
}
