//! Real-network transport: the [`wire`] layout framed over TCP or
//! Unix-domain sockets, so a K-worker run spans K actual processes.
//!
//! Layering, bottom up:
//!
//! * **Framing** — every message is `u32` little-endian length prefix +
//!   the exact [`wire`] encoding. [`read_frame`] treats EOF *between*
//!   frames as a clean close and EOF *inside* a frame as an error, and
//!   caps the declared length at [`MAX_FRAME_BYTES`] before allocating.
//! * **Handshake** — a connecting worker sends `Hello { requested slot,
//!   run fingerprint }`; the leader answers `Accept { slot }` or
//!   `Reject { reason }`. The hello rides the same versioned 16-byte
//!   header as every other frame, so a peer from an incompatible build
//!   fails with a typed [`WireError::BadVersion`] before any payload is
//!   interpreted, and [`run_fingerprint`] binds both sides to the same
//!   dataset + partition + loss + regularizer + solver + lambda + seed +
//!   intra-worker thread count — a worker loading different data (or one
//!   that would walk a different deterministic-per-T trajectory) is
//!   rejected, not silently wrong.
//! * **Leader** — [`NetTransport`] (in [`leader`]) implements
//!   [`Transport`](crate::transport::Transport) over the accepted
//!   sockets: per-kind byte accounting read off actual writes, per-recv
//!   deadlines ([`Error::Timeout`](crate::Error::Timeout)), dead-peer
//!   detection ([`Error::PeerLost`](crate::Error::PeerLost)), and
//!   [`heal`](crate::transport::Transport::heal) to re-accept
//!   replacements for the checkpoint-recovery path.
//! * **Worker** — [`run_worker_process`] (in [`worker`]) connects with
//!   bounded retry/backoff and drives the *same*
//!   [`WorkerCore`](crate::coordinator::worker::WorkerCore) state machine
//!   as the in-process threads, so multi-process trajectories are
//!   bit-identical to `InProc` by construction.

pub mod leader;
pub mod worker;

pub use leader::NetTransport;
pub use worker::run_worker_process;

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use super::wire::{self, WireError};
use crate::data::{Dataset, Partition};
use crate::error::{Error, Result};
use crate::loss::LossKind;
use crate::regularizers::RegularizerKind;
use crate::solvers::SolverKind;

/// The `[transport.net]` section: where the leader listens and how long
/// it waits.
#[derive(Debug, Clone, PartialEq)]
pub struct NetConfig {
    /// Leader listen address: `tcp:host:port` or `uds:/path/to.sock`.
    pub listen: String,
    /// How long `Trainer::build` (and `heal`) waits for all K workers to
    /// connect and pass the handshake.
    pub accept_timeout_s: f64,
    /// Per-`recv` deadline; expiry surfaces as a typed
    /// [`Error::Timeout`], the trigger for checkpoint recovery.
    pub recv_timeout_s: f64,
    /// Additionally tape all leader-visible traffic (like the `record`
    /// transport) for a later in-process [`Replay`](super::Replay).
    pub record: bool,
}

impl NetConfig {
    pub fn new(listen: impl Into<String>) -> Self {
        NetConfig {
            listen: listen.into(),
            accept_timeout_s: 30.0,
            recv_timeout_s: 30.0,
            record: false,
        }
    }

    /// Typed validation, called by `TransportKind::validate` at build.
    pub fn validate(&self) -> Result<()> {
        NetAddr::parse(&self.listen)?;
        for (name, v) in [
            ("accept_timeout_s", self.accept_timeout_s),
            ("recv_timeout_s", self.recv_timeout_s),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return Err(Error::InvalidTransport {
                    reason: format!("{name} must be finite and > 0, got {v}"),
                });
            }
        }
        Ok(())
    }
}

/// A parsed `tcp:host:port` / `uds:/path` endpoint.
#[derive(Debug, Clone, PartialEq)]
pub enum NetAddr {
    Tcp(String),
    Uds(PathBuf),
}

impl NetAddr {
    pub fn parse(s: &str) -> Result<NetAddr> {
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() || !rest.contains(':') {
                return Err(Error::InvalidTransport {
                    reason: format!("tcp address {rest:?} must be host:port"),
                });
            }
            Ok(NetAddr::Tcp(rest.to_string()))
        } else if let Some(rest) = s.strip_prefix("uds:") {
            if rest.is_empty() {
                return Err(Error::InvalidTransport {
                    reason: "uds address needs a socket path".into(),
                });
            }
            Ok(NetAddr::Uds(PathBuf::from(rest)))
        } else {
            Err(Error::InvalidTransport {
                reason: format!("address {s:?} must be tcp:host:port or uds:/path/to.sock"),
            })
        }
    }
}

/// How a `cocoa worker` retries a lost leader connection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconnectPolicy {
    /// Max connection attempts (initial connect and reconnects alike).
    pub attempts: u32,
    /// Base backoff; doubles per consecutive failure, capped at 5 s.
    pub backoff_s: f64,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy { attempts: 10, backoff_s: 0.2 }
    }
}

impl ReconnectPolicy {
    /// Longest single backoff sleep between connection attempts.
    pub const MAX_BACKOFF_S: f64 = 5.0;

    /// The sleep before retry number `failures` (1-based): exponential
    /// `backoff_s * 2^(failures-1)` capped at [`Self::MAX_BACKOFF_S`].
    ///
    /// Both the exponent (shift capped at 2^16) and the product are
    /// clamped *in f64 seconds space, before a `Duration` is built* —
    /// `Duration::from_secs_f64` panics on non-finite or overlarge
    /// inputs, so an uncapped product from a huge `--backoff-s` (or an
    /// overflowed shift wrapping the delay to ~0, turning reconnect into
    /// a busy-loop hammering the leader) must never reach it.
    pub fn delay(&self, failures: u32) -> Duration {
        let exp = failures.saturating_sub(1).min(16);
        let s = self.backoff_s * (1u64 << exp) as f64;
        // clamp handles inf and overlarge; NaN fails both comparisons,
        // so route it to the cap explicitly
        let s = if s.is_finite() { s.clamp(0.0, Self::MAX_BACKOFF_S) } else { Self::MAX_BACKOFF_S };
        Duration::from_secs_f64(s)
    }
}

/// Raw socket accounting on the leader side: every byte that crossed a
/// worker connection, split so it reconciles exactly with the per-kind
/// [`Ledger`](crate::transport::Ledger):
///
/// `sent_bytes + recv_bytes == ledger.total_bytes() + framing_bytes +
/// handshake_bytes`
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SocketStats {
    /// Bytes written to worker sockets after the handshake (payload +
    /// length prefixes).
    pub sent_bytes: u64,
    /// Bytes read from worker sockets after the handshake.
    pub recv_bytes: u64,
    pub sent_frames: u64,
    pub recv_frames: u64,
    /// The 4-byte length prefixes (one per post-handshake frame) — the
    /// only overhead the in-process ledger does not account.
    pub framing_bytes: u64,
    /// Hello/accept/reject traffic (both directions, prefixes included).
    pub handshake_bytes: u64,
}

impl SocketStats {
    /// Socket bytes minus framing and handshake overhead — what the
    /// in-process ledger should report for the same traffic.
    pub fn payload_bytes(&self) -> u64 {
        (self.sent_bytes + self.recv_bytes) - self.framing_bytes - self.handshake_bytes
    }
}

// ---------------------------------------------------------------------------
// Sockets: one enum over the two stream families
// ---------------------------------------------------------------------------

/// A connected stream of either family.
pub(crate) enum Sock {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Sock {
    pub(crate) fn try_clone(&self) -> io::Result<Sock> {
        Ok(match self {
            Sock::Tcp(s) => Sock::Tcp(s.try_clone()?),
            Sock::Uds(s) => Sock::Uds(s.try_clone()?),
        })
    }

    pub(crate) fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            Sock::Tcp(s) => s.set_read_timeout(dur),
            Sock::Uds(s) => s.set_read_timeout(dur),
        }
    }

    /// Shut down both directions; unblocks a reader on a cloned handle.
    pub(crate) fn shutdown(&self) -> io::Result<()> {
        match self {
            Sock::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            Sock::Uds(s) => s.shutdown(std::net::Shutdown::Both),
        }
    }

    pub(crate) fn connect(addr: &NetAddr) -> io::Result<Sock> {
        Ok(match addr {
            NetAddr::Tcp(hostport) => {
                let s = TcpStream::connect(hostport)?;
                s.set_nodelay(true)?;
                Sock::Tcp(s)
            }
            NetAddr::Uds(path) => Sock::Uds(UnixStream::connect(path)?),
        })
    }
}

impl Read for Sock {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.read(buf),
            Sock::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Sock {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Sock::Tcp(s) => s.write(buf),
            Sock::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Sock::Tcp(s) => s.flush(),
            Sock::Uds(s) => s.flush(),
        }
    }
}

/// A bound listener of either family. Dropping a UDS listener removes
/// its socket file.
pub(crate) enum NetListener {
    Tcp(TcpListener),
    Uds(UnixListener, PathBuf),
}

impl NetListener {
    pub(crate) fn bind(addr: &NetAddr) -> Result<NetListener> {
        match addr {
            NetAddr::Tcp(hostport) => {
                let l = TcpListener::bind(hostport).map_err(|e| Error::Transport {
                    message: format!("bind tcp:{hostport} failed: {e}"),
                })?;
                Ok(NetListener::Tcp(l))
            }
            NetAddr::Uds(path) => {
                // a stale socket file from a crashed run blocks the bind
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path).map_err(|e| Error::Transport {
                    message: format!("bind uds:{} failed: {e}", path.display()),
                })?;
                Ok(NetListener::Uds(l, path.clone()))
            }
        }
    }

    pub(crate) fn set_nonblocking(&self, nonblocking: bool) -> io::Result<()> {
        match self {
            NetListener::Tcp(l) => l.set_nonblocking(nonblocking),
            NetListener::Uds(l, _) => l.set_nonblocking(nonblocking),
        }
    }

    pub(crate) fn accept(&self) -> io::Result<Sock> {
        match self {
            NetListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                Ok(Sock::Tcp(s))
            }
            NetListener::Uds(l, _) => {
                let (s, _) = l.accept()?;
                Ok(Sock::Uds(s))
            }
        }
    }
}

impl Drop for NetListener {
    fn drop(&mut self) {
        if let NetListener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Length prefix in front of every frame.
pub(crate) const LEN_PREFIX_BYTES: u64 = 4;
/// Hard cap on a frame's declared length (256 MiB) — bounds what a
/// malicious or corrupted peer can make the reader allocate.
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// One `read_frame` outcome: a full frame, or a clean close.
pub(crate) enum FrameRead {
    Frame(Vec<u8>),
    /// The peer closed the stream *between* frames.
    Eof,
}

/// Write one length-prefixed frame and flush it.
pub(crate) fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME_BYTES);
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame. EOF before the first length byte is a
/// clean [`FrameRead::Eof`]; EOF anywhere later is an error (the peer
/// died mid-frame).
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<FrameRead> {
    let mut len = [0u8; 4];
    let mut got = 0;
    while got < len.len() {
        let n = r.read(&mut len[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(FrameRead::Eof);
            }
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed inside a frame length",
            ));
        }
        got += n;
    }
    let declared = u32::from_le_bytes(len) as usize;
    if declared > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("declared frame length {declared} exceeds cap {MAX_FRAME_BYTES}"),
        ));
    }
    let mut payload = vec![0u8; declared];
    r.read_exact(&mut payload)?;
    Ok(FrameRead::Frame(payload))
}

// ---------------------------------------------------------------------------
// Handshake frames
// ---------------------------------------------------------------------------

/// A worker's opening frame.
pub(crate) struct Hello {
    /// The slot a reconnecting worker held before; `None` on first
    /// connect (leader assigns the lowest free slot).
    pub requested: Option<usize>,
    pub fingerprint: u64,
}

pub(crate) fn encode_hello(requested: Option<usize>, fingerprint: u64) -> Vec<u8> {
    let slot = requested.map(|s| s as u32).unwrap_or(u32::MAX);
    let mut out = Vec::with_capacity(24);
    wire::encode_header(wire::TAG_HELLO, slot, 0, &mut out);
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out
}

pub(crate) fn decode_hello(buf: &[u8]) -> std::result::Result<Hello, WireError> {
    let (h, mut r) = wire::decode_header(buf)?;
    if h.tag != wire::TAG_HELLO {
        return Err(WireError::UnknownTag { got: h.tag });
    }
    let fingerprint = r.u64("hello fingerprint")?;
    r.finish("trailing bytes after hello")?;
    let requested = if h.worker == u32::MAX { None } else { Some(h.worker as usize) };
    Ok(Hello { requested, fingerprint })
}

/// The leader's answer to a hello.
pub(crate) enum HandshakeReply {
    Accept { slot: usize },
    Reject { reason: String },
}

pub(crate) fn encode_accept(slot: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    wire::encode_header(wire::TAG_ACCEPT, slot as u32, 0, &mut out);
    out
}

pub(crate) fn encode_reject(reason: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + 4 + reason.len());
    wire::encode_header(wire::TAG_REJECT, 0, 0, &mut out);
    out.extend_from_slice(&(reason.len() as u32).to_le_bytes());
    out.extend_from_slice(reason.as_bytes());
    out
}

pub(crate) fn decode_handshake_reply(
    buf: &[u8],
) -> std::result::Result<HandshakeReply, WireError> {
    let (h, mut r) = wire::decode_header(buf)?;
    match h.tag {
        wire::TAG_ACCEPT => {
            r.finish("trailing bytes after accept")?;
            Ok(HandshakeReply::Accept { slot: h.worker as usize })
        }
        wire::TAG_REJECT => {
            let len = r.elems("reject reason length")?;
            let raw = r.take(len, "reject reason")?;
            r.finish("trailing bytes after reject")?;
            Ok(HandshakeReply::Reject {
                reason: String::from_utf8_lossy(raw).into_owned(),
            })
        }
        got => Err(WireError::UnknownTag { got }),
    }
}

// ---------------------------------------------------------------------------
// Run fingerprint
// ---------------------------------------------------------------------------

fn fnv1a(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x100000001b3);
}

fn fnv1a_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        fnv1a(h, b as u64);
    }
}

/// One u64 binding a run's full description: dataset content fingerprint,
/// shapes, partition layout, loss, regularizer, solver, lambda, seed, and
/// the intra-worker thread count (trajectories are deterministic *per T*,
/// so peers running different T would silently diverge without it).
/// The leader and every worker compute it independently from their own
/// config + data; the handshake rejects a mismatch, so two processes can
/// only train together when they would produce bit-identical state.
#[allow(clippy::too_many_arguments)]
pub fn run_fingerprint(
    data: &Dataset,
    partition: &Partition,
    loss: LossKind,
    regularizer: RegularizerKind,
    solver: SolverKind,
    lambda: f64,
    seed: u64,
    threads: usize,
) -> u64 {
    run_fingerprint_parts(
        &data.fingerprint(),
        data.n(),
        data.d(),
        partition,
        loss,
        regularizer,
        solver,
        lambda,
        seed,
        threads,
    )
}

/// [`run_fingerprint`] from an already-computed dataset content
/// fingerprint. This is what makes the out-of-core path handshake-equal
/// to the in-memory one: a shard manifest stores the sharded dataset's
/// `Dataset::fingerprint`, so a shard-fed leader (which never holds the
/// data) and a shard-fed worker (which holds only its own block) both
/// hash the identical run description without materializing anything.
#[allow(clippy::too_many_arguments)]
pub fn run_fingerprint_parts(
    data_fingerprint: &str,
    n: usize,
    d: usize,
    partition: &Partition,
    loss: LossKind,
    regularizer: RegularizerKind,
    solver: SolverKind,
    lambda: f64,
    seed: u64,
    threads: usize,
) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    fnv1a_bytes(&mut h, data_fingerprint.as_bytes());
    fnv1a(&mut h, n as u64);
    fnv1a(&mut h, d as u64);
    fnv1a(&mut h, partition.k() as u64);
    for block in &partition.blocks {
        fnv1a(&mut h, block.len() as u64);
    }
    fnv1a_bytes(&mut h, loss.to_string().as_bytes());
    fnv1a_bytes(&mut h, regularizer.to_string().as_bytes());
    fnv1a_bytes(&mut h, format!("{solver:?}").as_bytes());
    fnv1a(&mut h, lambda.to_bits());
    fnv1a(&mut h, seed);
    fnv1a(&mut h, threads as u64);
    fnv1a(&mut h, wire::WIRE_VERSION as u64);
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Partition, PartitionStrategy};

    #[test]
    fn addr_parse_accepts_both_schemes_and_rejects_garbage() {
        assert_eq!(
            NetAddr::parse("tcp:127.0.0.1:7070").unwrap(),
            NetAddr::Tcp("127.0.0.1:7070".into())
        );
        assert_eq!(
            NetAddr::parse("uds:/tmp/cocoa.sock").unwrap(),
            NetAddr::Uds(PathBuf::from("/tmp/cocoa.sock"))
        );
        for bad in ["", "127.0.0.1:7070", "tcp:", "tcp:nohost", "uds:", "http:x"] {
            let err = NetAddr::parse(bad).unwrap_err();
            assert!(matches!(err, Error::InvalidTransport { .. }), "{bad:?}: {err}");
        }
    }

    #[test]
    fn config_validates_listen_and_timeouts() {
        assert!(NetConfig::new("uds:/tmp/x.sock").validate().is_ok());
        assert!(NetConfig::new("nope").validate().is_err());
        let mut cfg = NetConfig::new("tcp:127.0.0.1:0");
        cfg.recv_timeout_s = 0.0;
        assert!(cfg.validate().is_err());
        cfg.recv_timeout_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn backoff_schedule_doubles_then_caps() {
        let p = ReconnectPolicy::default(); // 0.2 s base
        let want = [0.2, 0.4, 0.8, 1.6, 3.2, 5.0, 5.0, 5.0];
        for (i, &w) in want.iter().enumerate() {
            let got = p.delay(i as u32 + 1).as_secs_f64();
            assert!((got - w).abs() < 1e-12, "failure {}: {} != {}", i + 1, got, w);
        }
        // failures=0 behaves like the first failure (no shift underflow)
        assert_eq!(p.delay(0), p.delay(1));
    }

    #[test]
    fn backoff_extremes_never_panic_or_wrap() {
        // huge failure counts: the shift is capped, the product clamps
        let p = ReconnectPolicy { attempts: u32::MAX, backoff_s: 0.2 };
        assert_eq!(p.delay(u32::MAX).as_secs_f64(), ReconnectPolicy::MAX_BACKOFF_S);
        // huge base: backoff_s * 2^16 would overflow Duration::from_secs_f64
        let p = ReconnectPolicy { attempts: 3, backoff_s: 1e300 };
        assert_eq!(p.delay(40).as_secs_f64(), ReconnectPolicy::MAX_BACKOFF_S);
        // infinite product routes to the cap, not a panic
        let p = ReconnectPolicy { attempts: 3, backoff_s: f64::MAX };
        assert_eq!(p.delay(17).as_secs_f64(), ReconnectPolicy::MAX_BACKOFF_S);
        // zero base is a valid immediate-retry policy
        let p = ReconnectPolicy { attempts: 3, backoff_s: 0.0 };
        assert_eq!(p.delay(5).as_secs_f64(), 0.0);
    }

    #[test]
    fn frame_roundtrip_and_clean_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        match read_frame(&mut r).unwrap() {
            FrameRead::Frame(p) => assert_eq!(p, b"hello"),
            FrameRead::Eof => panic!("expected frame"),
        }
        match read_frame(&mut r).unwrap() {
            FrameRead::Frame(p) => assert!(p.is_empty()),
            FrameRead::Eof => panic!("expected empty frame"),
        }
        assert!(matches!(read_frame(&mut r).unwrap(), FrameRead::Eof));
    }

    #[test]
    fn frame_reader_rejects_oversize_and_midframe_eof() {
        // declared length over the cap: rejected before allocation
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes().to_vec();
        let err = read_frame(&mut std::io::Cursor::new(huge)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // EOF inside the length prefix
        let err = read_frame(&mut std::io::Cursor::new(vec![1u8, 0])).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // EOF inside the payload
        let mut short = 10u32.to_le_bytes().to_vec();
        short.extend_from_slice(b"abc");
        let err = read_frame(&mut std::io::Cursor::new(short)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn handshake_frames_roundtrip() {
        let hello = decode_hello(&encode_hello(Some(3), 0xDEAD_BEEF)).unwrap();
        assert_eq!(hello.requested, Some(3));
        assert_eq!(hello.fingerprint, 0xDEAD_BEEF);
        let hello = decode_hello(&encode_hello(None, 7)).unwrap();
        assert_eq!(hello.requested, None);

        match decode_handshake_reply(&encode_accept(2)).unwrap() {
            HandshakeReply::Accept { slot } => assert_eq!(slot, 2),
            HandshakeReply::Reject { reason } => panic!("rejected: {reason}"),
        }
        match decode_handshake_reply(&encode_reject("cluster full")).unwrap() {
            HandshakeReply::Reject { reason } => assert_eq!(reason, "cluster full"),
            HandshakeReply::Accept { .. } => panic!("accepted"),
        }
        // a data frame is not a handshake reply
        let not_reply = wire::encode_to_worker(
            &crate::coordinator::ToWorker::Commit { scale: 1.0 },
            0,
        );
        assert!(decode_handshake_reply(&not_reply).is_err());
        // version mismatch is caught on the hello itself
        let mut old = encode_hello(None, 7);
        old[2] = 0;
        assert!(matches!(decode_hello(&old), Err(WireError::BadVersion { .. })));
    }

    #[test]
    fn fingerprint_separates_runs() {
        let data = crate::data::cov_like(60, 6, 0.1, 3);
        let other = crate::data::cov_like(60, 6, 0.1, 4);
        let part = |k| Partition::new(PartitionStrategy::Contiguous, 60, k, 0);
        let f = |d: &Dataset, k, lambda, seed, threads| {
            run_fingerprint(
                d,
                &part(k),
                LossKind::Hinge,
                RegularizerKind::L2,
                SolverKind::Sdca,
                lambda,
                seed,
                threads,
            )
        };
        let base = f(&data, 2, 1e-3, 0, 1);
        assert_eq!(base, f(&data, 2, 1e-3, 0, 1), "deterministic");
        // the parts form (what the shard-fed paths call) hashes
        // identically given the same run description
        assert_eq!(
            base,
            run_fingerprint_parts(
                &data.fingerprint(),
                60,
                6,
                &part(2),
                LossKind::Hinge,
                RegularizerKind::L2,
                SolverKind::Sdca,
                1e-3,
                0,
                1,
            )
        );
        assert_ne!(base, f(&other, 2, 1e-3, 0, 1), "different data");
        assert_ne!(base, f(&data, 3, 1e-3, 0, 1), "different k");
        assert_ne!(base, f(&data, 2, 1e-2, 0, 1), "different lambda");
        assert_ne!(base, f(&data, 2, 1e-3, 1, 1), "different seed");
        assert_ne!(base, f(&data, 2, 1e-3, 0, 4), "different thread count");
    }
}
