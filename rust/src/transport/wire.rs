//! Wire format: byte-exact sizing *and* real encoding of every
//! leader <-> worker message.
//!
//! The in-process backends never serialize for delivery, but the byte
//! accounting of [`Counted`](super::Counted) and friends must be *exact*,
//! not an analytic vector count — so this module pins down one concrete
//! wire layout and sizes every message against it. The net transport
//! ([`super::net`]) then ships these exact bytes over real sockets, so
//! socket-measured traffic and the in-process ledger agree to the byte:
//!
//! * every message: a 16-byte header — magic `u16` ([`MAGIC`]), format
//!   version `u8` ([`WIRE_VERSION`]), variant tag `u8`, worker `u32`,
//!   round `u64`, all little-endian,
//! * dense f64 vectors: `u32` length prefix + 8 bytes per scalar,
//! * shared-vector payloads (`dw` replies AND the `w` broadcasts): the
//!   cheaper of a dense block and a sparse `(u32 index, f64 value)` pair
//!   list — the sparse delta-encoding that makes mostly-zero round
//!   replies (tiny H, very sparse data) cheap, and that compresses the
//!   broadcast `w` when an L1/elastic-net regularizer's prox map plants
//!   exact zeros in it (lasso broadcasts shrink with the recovered
//!   support).
//!
//! Decoding is hardened against untrusted streams: truncated buffers,
//! bad magic/version, unknown tags, out-of-range indices, and oversized
//! declared lengths all come back as a typed [`WireError`] — never a
//! panic, never an attacker-sized allocation. The byte layout itself is
//! pinned by golden-bytes tests below; bump [`WIRE_VERSION`] on any
//! change so cross-process peers fail at decode time, not as silent
//! corruption.

use std::sync::Arc;

use crate::coordinator::{
    AppendBlock, LocalWork, RoundReply, ToLeader, ToWorker, WorkerMetrics, WorkerState,
};

/// Number of [`MessageKind`] variants (ledger array size).
pub const KIND_COUNT: usize = 8;

/// Message taxonomy for per-kind byte accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageKind {
    /// Leader -> worker round dispatch carrying the shared `w`.
    Broadcast = 0,
    /// Leader -> worker commit order (the `beta_K / K` fold).
    Commit = 1,
    /// Worker -> leader round reply carrying `dw` (the delta-w vector).
    DeltaW = 2,
    /// Leader -> worker evaluation request (instrumentation).
    EvalRequest = 3,
    /// Worker -> leader evaluation partial sums (instrumentation).
    EvalReply = 4,
    /// Checkpoint traffic in either direction (get/set/report state).
    Checkpoint = 5,
    /// Control traffic (reset, shutdown, fatal errors) and data
    /// management (append, set-labels). Data management is classified
    /// here rather than as a new kind because it is not *algorithm*
    /// communication — the paper's figures charge only the per-round
    /// broadcast/reduce/commit vectors, and growing the training set is
    /// an out-of-band operation, like checkpointing.
    Control = 6,
    /// Worker -> leader per-round observability block (instrumentation;
    /// never charged as algorithm communication).
    Metrics = 7,
}

impl MessageKind {
    pub const ALL: [MessageKind; KIND_COUNT] = [
        MessageKind::Broadcast,
        MessageKind::Commit,
        MessageKind::DeltaW,
        MessageKind::EvalRequest,
        MessageKind::EvalReply,
        MessageKind::Checkpoint,
        MessageKind::Control,
        MessageKind::Metrics,
    ];

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Algorithm communication (what the paper's figures charge for), as
    /// opposed to instrumentation (eval), fault tolerance (checkpoint),
    /// and control traffic.
    pub fn is_algorithm(self) -> bool {
        matches!(
            self,
            MessageKind::Broadcast | MessageKind::Commit | MessageKind::DeltaW
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            MessageKind::Broadcast => "broadcast",
            MessageKind::Commit => "commit",
            MessageKind::DeltaW => "delta_w",
            MessageKind::EvalRequest => "eval_request",
            MessageKind::EvalReply => "eval_reply",
            MessageKind::Checkpoint => "checkpoint",
            MessageKind::Control => "control",
            MessageKind::Metrics => "metrics",
        }
    }
}

/// Fixed per-message header: magic (`u16`) + version (`u8`) + variant
/// tag (`u8`) + worker id (`u32`) + round (`u64`).
pub const HEADER_BYTES: u64 = 16;
/// First two header bytes of every frame ("C0CA", little-endian).
pub const MAGIC: u16 = 0xC0CA;
/// Wire-format version; bump on any layout change. v2 added the
/// worker -> leader metrics frame ([`MessageKind::Metrics`]); v3 added
/// the continuous-training frames (append, set-labels).
pub const WIRE_VERSION: u8 = 3;
/// Length prefix of variable-size payloads.
const LEN_BYTES: u64 = 4;
/// RNG state carried by checkpoint messages (`[u64; 4]`).
const RNG_STATE_BYTES: u64 = 32;
/// Hard cap on any wire-declared element count (f64 slots). Bounds the
/// allocation a malicious peer can trigger to 256 MiB.
pub const MAX_WIRE_ELEMS: usize = 1 << 25;

// Variant tags (byte 3 of the header). Leader -> worker in 0x0_,
// worker -> leader in 0x8_, handshake frames in 0xF_.
pub(crate) const TAG_ROUND: u8 = 0x01;
pub(crate) const TAG_COMMIT: u8 = 0x02;
pub(crate) const TAG_EVAL: u8 = 0x03;
pub(crate) const TAG_GET_STATE: u8 = 0x04;
pub(crate) const TAG_SET_STATE: u8 = 0x05;
pub(crate) const TAG_RESET: u8 = 0x06;
pub(crate) const TAG_SHUTDOWN: u8 = 0x07;
pub(crate) const TAG_APPEND: u8 = 0x08;
pub(crate) const TAG_SET_LABELS: u8 = 0x09;
pub(crate) const TAG_ROUND_REPLY: u8 = 0x81;
pub(crate) const TAG_EVAL_REPLY: u8 = 0x82;
pub(crate) const TAG_STATE: u8 = 0x83;
pub(crate) const TAG_FATAL: u8 = 0x84;
pub(crate) const TAG_METRICS: u8 = 0x85;
pub(crate) const TAG_HELLO: u8 = 0xF0;
pub(crate) const TAG_ACCEPT: u8 = 0xF1;
pub(crate) const TAG_REJECT: u8 = 0xF2;

/// Typed decode failure: what went wrong, never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Buffer ended before the named field.
    Truncated { what: &'static str },
    /// First two bytes are not [`MAGIC`] — not a cocoa frame at all.
    BadMagic { got: u16 },
    /// A cocoa frame from an incompatible wire-format version.
    BadVersion { got: u8, want: u8 },
    /// Unknown variant tag for the decoding direction.
    UnknownTag { got: u8 },
    /// A declared length exceeds the decoder's allocation cap.
    Oversized { declared: u64, max: u64 },
    /// Structurally invalid payload (bad index, length mismatch, ...).
    Malformed { what: &'static str },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { what } => write!(f, "truncated frame at {what}"),
            WireError::BadMagic { got } => write!(f, "bad magic {got:#06x} (want {MAGIC:#06x})"),
            WireError::BadVersion { got, want } => {
                write!(f, "wire version {got} incompatible with {want}")
            }
            WireError::UnknownTag { got } => write!(f, "unknown message tag {got:#04x}"),
            WireError::Oversized { declared, max } => {
                write!(f, "declared length {declared} exceeds cap {max}")
            }
            WireError::Malformed { what } => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for crate::error::Error {
    fn from(e: WireError) -> Self {
        crate::error::Error::Transport { message: format!("wire: {e}") }
    }
}

type WireResult<T> = std::result::Result<T, WireError>;

/// Length-prefixed dense f64 vector.
pub fn dense_vec_bytes(len: usize) -> u64 {
    LEN_BYTES + 8 * len as u64
}

/// How a `dw` vector goes on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DwEncoding {
    /// `tag u8 + d u32 + d * f64`.
    Dense,
    /// `tag u8 + d u32 + nnz u32 + nnz * (u32 index + f64 value)`.
    Sparse,
}

/// Exact zero by bit pattern. `-0.0` counts as a nonzero so sparse
/// round-trips stay bit-identical to the dense ones (`0.0 == -0.0`
/// numerically, but the decoded vector must reproduce the input bits).
#[inline]
fn is_wire_zero(v: f64) -> bool {
    v.to_bits() == 0
}

/// Chosen encoding + exact encoded size for a `dw` payload: the sparse
/// pair list when it is strictly smaller (nnz < ~2d/3), dense otherwise.
pub fn dw_wire(dw: &[f64]) -> (DwEncoding, u64) {
    let d = dw.len() as u64;
    let nnz = dw.iter().filter(|v| !is_wire_zero(**v)).count() as u64;
    let dense = 1 + LEN_BYTES + 8 * d;
    let sparse = 1 + LEN_BYTES + LEN_BYTES + 12 * nnz;
    if sparse < dense {
        (DwEncoding::Sparse, sparse)
    } else {
        (DwEncoding::Dense, dense)
    }
}

/// Encode `dw` into the layout [`dw_wire`] sized (little-endian).
pub fn encode_dw(dw: &[f64]) -> Vec<u8> {
    let (_, bytes) = dw_wire(dw);
    let mut out = Vec::with_capacity(bytes as usize);
    encode_dw_into(dw, &mut out);
    debug_assert_eq!(out.len() as u64, bytes);
    out
}

fn encode_dw_into(dw: &[f64], out: &mut Vec<u8>) {
    let (encoding, _) = dw_wire(dw);
    out.push(match encoding {
        DwEncoding::Dense => 0u8,
        DwEncoding::Sparse => 1u8,
    });
    out.extend_from_slice(&(dw.len() as u32).to_le_bytes());
    match encoding {
        DwEncoding::Dense => {
            for v in dw {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        DwEncoding::Sparse => {
            let nnz = dw.iter().filter(|v| !is_wire_zero(**v)).count() as u32;
            out.extend_from_slice(&nnz.to_le_bytes());
            for (i, v) in dw.iter().enumerate() {
                if !is_wire_zero(*v) {
                    out.extend_from_slice(&(i as u32).to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
}

/// Decode a buffer produced by [`encode_dw`]. `None` on malformed input
/// (see [`decode_dw_strict`] for the typed reason).
pub fn decode_dw(buf: &[u8]) -> Option<Vec<f64>> {
    decode_dw_strict(buf).ok()
}

/// Decode a buffer produced by [`encode_dw`], consuming it exactly.
pub fn decode_dw_strict(buf: &[u8]) -> WireResult<Vec<f64>> {
    let mut r = Reader::new(buf);
    let dw = r.dw()?;
    r.finish("dw")?;
    Ok(dw)
}

/// A [`LocalWork`] order: kind tag (`u32`) + two parameter words covers
/// every variant (h/b/t_offset/sigma_prime).
fn local_work_bytes(_work: &LocalWork) -> u64 {
    4 + 16
}

/// `(kind, exact serialized size)` of a leader -> worker message. The
/// broadcast `w` rides the same adaptive encoding as `dw` replies: dense
/// for typical L2 iterates, the index/value pair list once a prox map
/// makes `w` mostly zero.
pub fn to_worker_wire(msg: &ToWorker) -> (MessageKind, u64) {
    match msg {
        ToWorker::Round { w, work, .. } => (
            MessageKind::Broadcast,
            HEADER_BYTES + local_work_bytes(work) + dw_wire(w).1,
        ),
        ToWorker::Commit { .. } => (MessageKind::Commit, HEADER_BYTES + 8),
        ToWorker::Eval { w } => (
            MessageKind::EvalRequest,
            HEADER_BYTES + dw_wire(w).1,
        ),
        ToWorker::GetState => (MessageKind::Checkpoint, HEADER_BYTES),
        ToWorker::SetState(ws) => (
            MessageKind::Checkpoint,
            HEADER_BYTES + RNG_STATE_BYTES + dense_vec_bytes(ws.alpha.len()),
        ),
        ToWorker::Reset | ToWorker::Shutdown => (MessageKind::Control, HEADER_BYTES),
        // lambda_n f64 + rows u32 + rows * (row-len u32) + nnz u32 +
        // nnz * (u32 index + f64 value) + rows * (label f64 + norm f64)
        ToWorker::Append { block, .. } => (
            MessageKind::Control,
            HEADER_BYTES + 16 + 20 * block.rows() as u64 + 12 * block.nnz() as u64,
        ),
        ToWorker::SetLabels { labels } => {
            (MessageKind::Control, HEADER_BYTES + dense_vec_bytes(labels.len()))
        }
    }
}

/// `(kind, exact serialized size)` of a worker -> leader message.
pub fn to_leader_wire(msg: &ToLeader) -> (MessageKind, u64) {
    match msg {
        // compute_s (f64) + steps (u64) ride along with the encoded dw
        ToLeader::Round(r) => (MessageKind::DeltaW, HEADER_BYTES + 16 + dw_wire(&r.dw).1),
        // loss_sum + conj_sum (f64 each) + has_dual (u8)
        ToLeader::Eval(_) => (MessageKind::EvalReply, HEADER_BYTES + 17),
        ToLeader::State(ws) => (
            MessageKind::Checkpoint,
            HEADER_BYTES + RNG_STATE_BYTES + dense_vec_bytes(ws.alpha.len()),
        ),
        ToLeader::Fatal { message, .. } => (
            MessageKind::Control,
            HEADER_BYTES + LEN_BYTES + message.len() as u64,
        ),
        // solve_wall_s + solve_cpu_s (f64) + inner_steps + peak_rss_bytes
        // + reconnects (u64); worker and round ride the header
        ToLeader::Metrics(_) => (MessageKind::Metrics, HEADER_BYTES + 40),
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

/// Append the fixed 16-byte header.
pub(crate) fn encode_header(tag: u8, worker: u32, round: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(WIRE_VERSION);
    out.push(tag);
    out.extend_from_slice(&worker.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
}

fn encode_worker_state(ws: &WorkerState, out: &mut Vec<u8>) {
    for word in ws.rng_state {
        out.extend_from_slice(&word.to_le_bytes());
    }
    out.extend_from_slice(&(ws.alpha.len() as u32).to_le_bytes());
    for a in &ws.alpha {
        out.extend_from_slice(&a.to_le_bytes());
    }
}

fn local_work_fields(work: &LocalWork) -> (u32, u64, u64) {
    match *work {
        LocalWork::DualRound { h } => (0, h as u64, 0),
        LocalWork::DualRoundScaled { h, sigma_prime } => (1, h as u64, sigma_prime.to_bits()),
        LocalWork::DualBatchFrozen { b } => (2, b as u64, 0),
        LocalWork::ExactSolve => (3, 0, 0),
        LocalWork::SgdLocal { h, t_offset } => (4, h as u64, t_offset),
        LocalWork::SgdFrozen { h } => (5, h as u64, 0),
    }
}

/// Serialize a leader -> worker message addressed to `to`. The encoded
/// length equals [`to_worker_wire`]'s size exactly — the ledger and the
/// socket agree by construction.
pub fn encode_to_worker(msg: &ToWorker, to: usize) -> Vec<u8> {
    let (_, sized) = to_worker_wire(msg);
    let mut out = Vec::with_capacity(sized as usize);
    let to = to as u32;
    match msg {
        ToWorker::Round { round, w, work } => {
            encode_header(TAG_ROUND, to, *round, &mut out);
            let (tag, p1, p2) = local_work_fields(work);
            out.extend_from_slice(&tag.to_le_bytes());
            out.extend_from_slice(&p1.to_le_bytes());
            out.extend_from_slice(&p2.to_le_bytes());
            encode_dw_into(w, &mut out);
        }
        ToWorker::Commit { scale } => {
            encode_header(TAG_COMMIT, to, 0, &mut out);
            out.extend_from_slice(&scale.to_le_bytes());
        }
        ToWorker::Eval { w } => {
            encode_header(TAG_EVAL, to, 0, &mut out);
            encode_dw_into(w, &mut out);
        }
        ToWorker::GetState => encode_header(TAG_GET_STATE, to, 0, &mut out),
        ToWorker::SetState(ws) => {
            encode_header(TAG_SET_STATE, to, 0, &mut out);
            encode_worker_state(ws, &mut out);
        }
        ToWorker::Reset => encode_header(TAG_RESET, to, 0, &mut out),
        ToWorker::Shutdown => encode_header(TAG_SHUTDOWN, to, 0, &mut out),
        ToWorker::Append { block, lambda_n } => {
            encode_header(TAG_APPEND, to, 0, &mut out);
            out.extend_from_slice(&lambda_n.to_le_bytes());
            out.extend_from_slice(&(block.rows() as u32).to_le_bytes());
            for win in block.indptr.windows(2) {
                out.extend_from_slice(&((win[1] - win[0]) as u32).to_le_bytes());
            }
            out.extend_from_slice(&(block.nnz() as u32).to_le_bytes());
            for (i, v) in block.indices.iter().zip(&block.values) {
                out.extend_from_slice(&i.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
            for (y, nsq) in block.labels.iter().zip(&block.norms_sq) {
                out.extend_from_slice(&y.to_le_bytes());
                out.extend_from_slice(&nsq.to_le_bytes());
            }
        }
        ToWorker::SetLabels { labels } => {
            encode_header(TAG_SET_LABELS, to, 0, &mut out);
            out.extend_from_slice(&(labels.len() as u32).to_le_bytes());
            for y in labels {
                out.extend_from_slice(&y.to_le_bytes());
            }
        }
    }
    debug_assert_eq!(out.len() as u64, sized);
    out
}

/// Serialize a worker -> leader message. The encoded length equals
/// [`to_leader_wire`]'s size exactly.
pub fn encode_to_leader(msg: &ToLeader) -> Vec<u8> {
    let (_, sized) = to_leader_wire(msg);
    let mut out = Vec::with_capacity(sized as usize);
    match msg {
        ToLeader::Round(r) => {
            encode_header(TAG_ROUND_REPLY, r.worker as u32, r.round, &mut out);
            out.extend_from_slice(&r.compute_s.to_le_bytes());
            out.extend_from_slice(&r.steps.to_le_bytes());
            encode_dw_into(&r.dw, &mut out);
        }
        ToLeader::Eval(e) => {
            encode_header(TAG_EVAL_REPLY, e.worker as u32, 0, &mut out);
            out.extend_from_slice(&e.loss_sum.to_le_bytes());
            out.extend_from_slice(&e.conj_sum.to_le_bytes());
            out.push(e.has_dual as u8);
        }
        ToLeader::State(ws) => {
            encode_header(TAG_STATE, ws.id as u32, 0, &mut out);
            encode_worker_state(ws, &mut out);
        }
        ToLeader::Fatal { worker, message } => {
            encode_header(TAG_FATAL, *worker as u32, 0, &mut out);
            out.extend_from_slice(&(message.len() as u32).to_le_bytes());
            out.extend_from_slice(message.as_bytes());
        }
        ToLeader::Metrics(m) => {
            encode_header(TAG_METRICS, m.worker as u32, m.round, &mut out);
            out.extend_from_slice(&m.solve_wall_s.to_le_bytes());
            out.extend_from_slice(&m.solve_cpu_s.to_le_bytes());
            out.extend_from_slice(&m.inner_steps.to_le_bytes());
            out.extend_from_slice(&m.peak_rss_bytes.to_le_bytes());
            out.extend_from_slice(&m.reconnects.to_le_bytes());
        }
    }
    debug_assert_eq!(out.len() as u64, sized);
    out
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Cursor over an untrusted buffer; every read is bounds-checked.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Reader { buf }
    }

    pub(crate) fn take(&mut self, n: usize, what: &'static str) -> WireResult<&'a [u8]> {
        if self.buf.len() < n {
            return Err(WireError::Truncated { what });
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    pub(crate) fn u8(&mut self, what: &'static str) -> WireResult<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u32(&mut self, what: &'static str) -> WireResult<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self, what: &'static str) -> WireResult<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self, what: &'static str) -> WireResult<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// Wire-declared element count, capped before any allocation.
    pub(crate) fn elems(&mut self, what: &'static str) -> WireResult<usize> {
        let n = self.u32(what)? as usize;
        if n > MAX_WIRE_ELEMS {
            return Err(WireError::Oversized { declared: n as u64, max: MAX_WIRE_ELEMS as u64 });
        }
        Ok(n)
    }

    fn f64_vec(&mut self, what: &'static str) -> WireResult<Vec<f64>> {
        let len = self.elems(what)?;
        let raw = self.take(8 * len, what)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn dw(&mut self) -> WireResult<Vec<f64>> {
        let tag = self.u8("dw tag")?;
        let d = self.elems("dw length")?;
        match tag {
            0 => {
                let raw = self.take(8 * d, "dw dense values")?;
                Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
            }
            1 => {
                let nnz = self.elems("dw nnz")?;
                let raw = self.take(12 * nnz, "dw sparse pairs")?;
                let mut out = vec![0.0; d];
                for chunk in raw.chunks_exact(12) {
                    let i = u32::from_le_bytes(chunk[0..4].try_into().unwrap()) as usize;
                    if i >= d {
                        return Err(WireError::Malformed { what: "sparse index out of range" });
                    }
                    out[i] = f64::from_le_bytes(chunk[4..12].try_into().unwrap());
                }
                Ok(out)
            }
            _ => Err(WireError::Malformed { what: "unknown dw encoding tag" }),
        }
    }

    fn worker_state(&mut self, id: usize) -> WireResult<WorkerState> {
        let mut rng_state = [0u64; 4];
        for word in &mut rng_state {
            *word = self.u64("rng state")?;
        }
        let alpha = self.f64_vec("alpha")?;
        Ok(WorkerState { id, rng_state, alpha })
    }

    /// Reject trailing garbage: a valid frame is consumed exactly.
    pub(crate) fn finish(&self, what: &'static str) -> WireResult<()> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(WireError::Malformed { what })
        }
    }
}

/// Decoded 16-byte header.
pub(crate) struct Header {
    pub tag: u8,
    pub worker: u32,
    pub round: u64,
}

/// Validate magic + version and split off the header.
pub(crate) fn decode_header<'a>(buf: &'a [u8]) -> WireResult<(Header, Reader<'a>)> {
    let mut r = Reader::new(buf);
    let magic = u16::from_le_bytes(r.take(2, "magic")?.try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic { got: magic });
    }
    let version = r.u8("version")?;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion { got: version, want: WIRE_VERSION });
    }
    let tag = r.u8("tag")?;
    let worker = r.u32("worker id")?;
    let round = r.u64("round")?;
    Ok((Header { tag, worker, round }, r))
}

fn decode_local_work(r: &mut Reader<'_>) -> WireResult<LocalWork> {
    let tag = r.u32("work tag")?;
    let p1 = r.u64("work param 1")?;
    let p2 = r.u64("work param 2")?;
    Ok(match tag {
        0 => LocalWork::DualRound { h: p1 as usize },
        1 => LocalWork::DualRoundScaled { h: p1 as usize, sigma_prime: f64::from_bits(p2) },
        2 => LocalWork::DualBatchFrozen { b: p1 as usize },
        3 => LocalWork::ExactSolve,
        4 => LocalWork::SgdLocal { h: p1 as usize, t_offset: p2 },
        5 => LocalWork::SgdFrozen { h: p1 as usize },
        _ => return Err(WireError::Malformed { what: "unknown local work tag" }),
    })
}

/// Decode one leader -> worker frame (the payload of a net frame).
pub fn decode_to_worker(buf: &[u8]) -> WireResult<ToWorker> {
    let (h, mut r) = decode_header(buf)?;
    let msg = match h.tag {
        TAG_ROUND => {
            let work = decode_local_work(&mut r)?;
            let w = Arc::new(r.dw()?);
            ToWorker::Round { round: h.round, w, work }
        }
        TAG_COMMIT => ToWorker::Commit { scale: r.f64("commit scale")? },
        TAG_EVAL => ToWorker::Eval { w: Arc::new(r.dw()?) },
        TAG_GET_STATE => ToWorker::GetState,
        TAG_SET_STATE => ToWorker::SetState(r.worker_state(h.worker as usize)?),
        TAG_RESET => ToWorker::Reset,
        TAG_SHUTDOWN => ToWorker::Shutdown,
        TAG_APPEND => {
            let lambda_n = r.f64("append lambda_n")?;
            let rows = r.elems("append rows")?;
            let mut indptr = Vec::with_capacity(rows + 1);
            indptr.push(0usize);
            let mut total = 0usize;
            for _ in 0..rows {
                let len = r.elems("append row length")?;
                total += len;
                if total > MAX_WIRE_ELEMS {
                    return Err(WireError::Oversized {
                        declared: total as u64,
                        max: MAX_WIRE_ELEMS as u64,
                    });
                }
                indptr.push(total);
            }
            let nnz = r.elems("append nnz")?;
            if nnz != total {
                return Err(WireError::Malformed { what: "append nnz != sum of row lengths" });
            }
            let raw = r.take(12 * nnz, "append entries")?;
            let mut indices = Vec::with_capacity(nnz);
            let mut values = Vec::with_capacity(nnz);
            for chunk in raw.chunks_exact(12) {
                indices.push(u32::from_le_bytes(chunk[0..4].try_into().unwrap()));
                values.push(f64::from_le_bytes(chunk[4..12].try_into().unwrap()));
            }
            let raw = r.take(16 * rows, "append labels")?;
            let mut labels = Vec::with_capacity(rows);
            let mut norms_sq = Vec::with_capacity(rows);
            for chunk in raw.chunks_exact(16) {
                labels.push(f64::from_le_bytes(chunk[0..8].try_into().unwrap()));
                norms_sq.push(f64::from_le_bytes(chunk[8..16].try_into().unwrap()));
            }
            ToWorker::Append {
                block: AppendBlock { indptr, indices, values, labels, norms_sq },
                lambda_n,
            }
        }
        TAG_SET_LABELS => ToWorker::SetLabels { labels: r.f64_vec("set_labels labels")? },
        got => return Err(WireError::UnknownTag { got }),
    };
    r.finish("trailing bytes after message")?;
    Ok(msg)
}

/// Decode one worker -> leader frame (the payload of a net frame).
pub fn decode_to_leader(buf: &[u8]) -> WireResult<ToLeader> {
    let (h, mut r) = decode_header(buf)?;
    let worker = h.worker as usize;
    let msg = match h.tag {
        TAG_ROUND_REPLY => {
            let compute_s = r.f64("compute_s")?;
            let steps = r.u64("steps")?;
            let dw = r.dw()?;
            ToLeader::Round(RoundReply { worker, round: h.round, dw, compute_s, steps })
        }
        TAG_EVAL_REPLY => {
            let loss_sum = r.f64("loss_sum")?;
            let conj_sum = r.f64("conj_sum")?;
            let has_dual = r.u8("has_dual")? != 0;
            ToLeader::Eval(crate::coordinator::EvalReply { worker, loss_sum, conj_sum, has_dual })
        }
        TAG_STATE => ToLeader::State(r.worker_state(worker)?),
        TAG_FATAL => {
            let len = r.elems("fatal message length")?;
            let raw = r.take(len, "fatal message")?;
            ToLeader::Fatal { worker, message: String::from_utf8_lossy(raw).into_owned() }
        }
        TAG_METRICS => ToLeader::Metrics(WorkerMetrics {
            worker,
            round: h.round,
            solve_wall_s: r.f64("metrics solve_wall_s")?,
            solve_cpu_s: r.f64("metrics solve_cpu_s")?,
            inner_steps: r.u64("metrics inner_steps")?,
            peak_rss_bytes: r.u64("metrics peak_rss_bytes")?,
            reconnects: r.u64("metrics reconnects")?,
        }),
        got => return Err(WireError::UnknownTag { got }),
    };
    r.finish("trailing bytes after message")?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EvalReply, RoundReply};

    #[test]
    fn dw_roundtrip_dense_bit_exact() {
        let dw = vec![1.5, -0.0, f64::MIN_POSITIVE / 2.0, std::f64::consts::PI, -3.25];
        let (enc, bytes) = dw_wire(&dw);
        assert_eq!(enc, DwEncoding::Dense); // -0.0 counts as nonzero by bits
        let buf = encode_dw(&dw);
        assert_eq!(buf.len() as u64, bytes);
        let back = decode_dw(&buf).unwrap();
        assert_eq!(back.len(), dw.len());
        for (a, b) in dw.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dw_roundtrip_sparse_bit_exact() {
        let mut dw = vec![0.0f64; 1000];
        dw[3] = 1.25;
        dw[999] = -std::f64::consts::E;
        let (enc, bytes) = dw_wire(&dw);
        assert_eq!(enc, DwEncoding::Sparse);
        assert_eq!(bytes, 1 + 4 + 4 + 12 * 2);
        let buf = encode_dw(&dw);
        assert_eq!(buf.len() as u64, bytes);
        let back = decode_dw(&buf).unwrap();
        for (a, b) in dw.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn negative_zero_survives_sparse_roundtrip() {
        // -0.0 is numerically zero but a distinct bit pattern; the sparse
        // path must carry it so net and in-proc trajectories stay
        // bit-identical.
        let mut dw = vec![0.0f64; 100];
        dw[7] = -0.0;
        let (enc, _) = dw_wire(&dw);
        assert_eq!(enc, DwEncoding::Sparse);
        let back = decode_dw(&encode_dw(&dw)).unwrap();
        assert_eq!(back[7].to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn sparse_wins_exactly_when_smaller() {
        // nnz where 8 + 12*nnz < 8*d flips the choice
        for d in [3usize, 10, 100] {
            for nnz in 0..=d {
                let mut dw = vec![0.0f64; d];
                for i in 0..nnz {
                    dw[i] = 1.0 + i as f64;
                }
                let (enc, bytes) = dw_wire(&dw);
                let dense = 1 + 4 + 8 * d as u64;
                let sparse = 1 + 4 + 4 + 12 * nnz as u64;
                match enc {
                    DwEncoding::Sparse => assert!(sparse < dense, "d={d} nnz={nnz}"),
                    DwEncoding::Dense => assert!(dense <= sparse, "d={d} nnz={nnz}"),
                }
                assert_eq!(bytes, dense.min(sparse));
                assert_eq!(encode_dw(&dw).len() as u64, bytes);
            }
        }
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(decode_dw(&[]).is_none());
        assert!(decode_dw(&[7, 0, 0, 0, 0]).is_none()); // unknown tag
        let mut buf = encode_dw(&[1.0, 2.0]);
        buf.pop(); // truncated payload
        assert!(decode_dw(&buf).is_none());
    }

    #[test]
    fn message_sizes_scale_with_payload() {
        // dense (every coordinate nonzero) broadcasts grow linearly in d
        let w = std::sync::Arc::new(vec![1.5f64; 100]);
        let (kind, b100) = to_worker_wire(&ToWorker::Round {
            round: 1,
            w: w.clone(),
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(kind, MessageKind::Broadcast);
        let w2 = std::sync::Arc::new(vec![1.5f64; 200]);
        let (_, b200) = to_worker_wire(&ToWorker::Round {
            round: 1,
            w: w2,
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(b200 - b100, 100 * 8);

        let (kind, commit) = to_worker_wire(&ToWorker::Commit { scale: 0.25 });
        assert_eq!(kind, MessageKind::Commit);
        assert_eq!(commit, HEADER_BYTES + 8);

        let reply = ToLeader::Round(RoundReply {
            worker: 0,
            round: 1,
            dw: vec![0.0; 50],
            compute_s: 0.0,
            steps: 5,
        });
        let (kind, bytes) = to_leader_wire(&reply);
        assert_eq!(kind, MessageKind::DeltaW);
        // all-zero dw: the sparse encoding collapses to the fixed preamble
        assert_eq!(bytes, HEADER_BYTES + 16 + 1 + 4 + 4);
    }

    #[test]
    fn prox_sparse_broadcast_shrinks_on_the_wire() {
        // A lasso-style w (few nonzeros from the prox map) must cost the
        // sparse pair-list size, far below the dense layout — this is the
        // mechanism behind smaller measured bytes on L1 runs.
        let mut w = vec![0.0f64; 500];
        for j in (0..500).step_by(100) {
            w[j] = 0.75;
        }
        let (kind, sparse_bytes) = to_worker_wire(&ToWorker::Round {
            round: 3,
            w: std::sync::Arc::new(w),
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(kind, MessageKind::Broadcast);
        let dense_equiv = to_worker_wire(&ToWorker::Round {
            round: 3,
            w: std::sync::Arc::new(vec![0.75f64; 500]),
            work: LocalWork::DualRound { h: 5 },
        })
        .1;
        assert_eq!(sparse_bytes, HEADER_BYTES + (4 + 16) + 1 + 4 + 4 + 12 * 5);
        assert!(sparse_bytes < dense_equiv / 10);
        // the eval request carries the same adaptively-encoded w
        let mut w = vec![0.0f64; 500];
        w[7] = -1.25;
        let (kind, eval_bytes) = to_worker_wire(&ToWorker::Eval {
            w: std::sync::Arc::new(w),
        });
        assert_eq!(kind, MessageKind::EvalRequest);
        assert_eq!(eval_bytes, HEADER_BYTES + 1 + 4 + 4 + 12);
    }

    #[test]
    fn kind_index_is_dense_and_stable() {
        for (i, kind) in MessageKind::ALL.iter().enumerate() {
            assert_eq!(kind.index(), i);
        }
        assert!(MessageKind::Broadcast.is_algorithm());
        assert!(MessageKind::Commit.is_algorithm());
        assert!(MessageKind::DeltaW.is_algorithm());
        assert!(!MessageKind::EvalRequest.is_algorithm());
        assert!(!MessageKind::EvalReply.is_algorithm());
        assert!(!MessageKind::Checkpoint.is_algorithm());
        assert!(!MessageKind::Control.is_algorithm());
        // metrics are instrumentation: charging them to the paper's
        // communication axis would change bytes_measured and sim-time
        assert!(!MessageKind::Metrics.is_algorithm());
    }

    // -- full codec: encoded length == sized length, bit-exact round-trips

    fn roundtrip_to_worker(msg: ToWorker, to: usize) -> ToWorker {
        let (_, sized) = to_worker_wire(&msg);
        let buf = encode_to_worker(&msg, to);
        assert_eq!(buf.len() as u64, sized, "encoded length must match sizing");
        decode_to_worker(&buf).unwrap()
    }

    fn roundtrip_to_leader(msg: ToLeader) -> ToLeader {
        let (_, sized) = to_leader_wire(&msg);
        let buf = encode_to_leader(&msg);
        assert_eq!(buf.len() as u64, sized, "encoded length must match sizing");
        decode_to_leader(&buf).unwrap()
    }

    #[test]
    fn to_worker_codec_roundtrips_every_variant() {
        let w = std::sync::Arc::new(vec![0.5, -0.0, 2.5]);
        let works = [
            LocalWork::DualRound { h: 7 },
            LocalWork::DualRoundScaled { h: 7, sigma_prime: 1.75 },
            LocalWork::DualBatchFrozen { b: 3 },
            LocalWork::ExactSolve,
            LocalWork::SgdLocal { h: 9, t_offset: 41 },
            LocalWork::SgdFrozen { h: 2 },
        ];
        for work in works {
            let back = roundtrip_to_worker(
                ToWorker::Round { round: 12, w: w.clone(), work },
                1,
            );
            match back {
                ToWorker::Round { round, w: bw, work: bwork } => {
                    assert_eq!(round, 12);
                    assert_eq!(format!("{bwork:?}"), format!("{work:?}"));
                    for (a, b) in w.iter().zip(bw.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                other => panic!("wrong variant: {other:?}"),
            }
        }

        match roundtrip_to_worker(ToWorker::Commit { scale: 0.125 }, 2) {
            ToWorker::Commit { scale } => assert_eq!(scale, 0.125),
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(matches!(roundtrip_to_worker(ToWorker::GetState, 0), ToWorker::GetState));
        assert!(matches!(roundtrip_to_worker(ToWorker::Reset, 0), ToWorker::Reset));
        assert!(matches!(roundtrip_to_worker(ToWorker::Shutdown, 0), ToWorker::Shutdown));

        let ws = WorkerState { id: 3, rng_state: [1, 2, 3, u64::MAX], alpha: vec![0.5, -1.5] };
        match roundtrip_to_worker(ToWorker::SetState(ws.clone()), 3) {
            ToWorker::SetState(back) => assert_eq!(back, ws),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn append_and_set_labels_roundtrip() {
        // two rows: [(1, 0.5), (3, -0.0)] and [] (an empty row)
        let block = AppendBlock {
            indptr: vec![0, 2, 2],
            indices: vec![1, 3],
            values: vec![0.5, -0.0],
            labels: vec![1.0, -1.0],
            norms_sq: vec![0.25, 0.0],
        };
        match roundtrip_to_worker(
            ToWorker::Append { block: block.clone(), lambda_n: 12.5 },
            1,
        ) {
            ToWorker::Append { block: back, lambda_n } => {
                assert_eq!(lambda_n, 12.5);
                assert_eq!(back.indptr, block.indptr);
                assert_eq!(back.indices, block.indices);
                assert_eq!(back.labels, block.labels);
                for (a, b) in block.values.iter().zip(&back.values) {
                    assert_eq!(a.to_bits(), b.to_bits(), "-0.0 must survive");
                }
                for (a, b) in block.norms_sq.iter().zip(&back.norms_sq) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // the zero-row append (lambda_n-only) is a legal frame too
        match roundtrip_to_worker(
            ToWorker::Append { block: AppendBlock::empty(), lambda_n: 7.0 },
            0,
        ) {
            ToWorker::Append { block: back, lambda_n } => {
                assert_eq!(lambda_n, 7.0);
                assert_eq!(back.rows(), 0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        match roundtrip_to_worker(ToWorker::SetLabels { labels: vec![1.0, -1.0, 1.0] }, 2) {
            ToWorker::SetLabels { labels } => assert_eq!(labels, vec![1.0, -1.0, 1.0]),
            other => panic!("wrong variant: {other:?}"),
        }
        // both are control traffic: never charged as algorithm bytes
        let (kind, _) = to_worker_wire(&ToWorker::Append {
            block: AppendBlock::empty(),
            lambda_n: 1.0,
        });
        assert!(!kind.is_algorithm());
        let (kind, _) = to_worker_wire(&ToWorker::SetLabels { labels: vec![1.0] });
        assert!(!kind.is_algorithm());
        // a declared nnz disagreeing with row lengths is a typed error
        let mut bad = Vec::new();
        encode_header(TAG_APPEND, 0, 0, &mut bad);
        bad.extend_from_slice(&1.0f64.to_le_bytes()); // lambda_n
        bad.extend_from_slice(&1u32.to_le_bytes()); // rows = 1
        bad.extend_from_slice(&2u32.to_le_bytes()); // row length 2
        bad.extend_from_slice(&1u32.to_le_bytes()); // nnz = 1 (!= 2)
        assert_eq!(
            decode_to_worker(&bad).unwrap_err(),
            WireError::Malformed { what: "append nnz != sum of row lengths" }
        );
    }

    #[test]
    fn to_leader_codec_roundtrips_every_variant() {
        let reply = RoundReply {
            worker: 2,
            round: 9,
            dw: vec![0.0, 1.5, 0.0, -2.25],
            compute_s: 0.0625,
            steps: 40,
        };
        match roundtrip_to_leader(ToLeader::Round(reply.clone())) {
            ToLeader::Round(back) => {
                assert_eq!(back.worker, reply.worker);
                assert_eq!(back.round, reply.round);
                assert_eq!(back.steps, reply.steps);
                assert_eq!(back.compute_s.to_bits(), reply.compute_s.to_bits());
                for (a, b) in reply.dw.iter().zip(back.dw.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let eval = EvalReply { worker: 1, loss_sum: 3.5, conj_sum: -0.25, has_dual: true };
        match roundtrip_to_leader(ToLeader::Eval(eval)) {
            ToLeader::Eval(back) => {
                assert_eq!(back.worker, 1);
                assert_eq!(back.loss_sum.to_bits(), eval.loss_sum.to_bits());
                assert_eq!(back.conj_sum.to_bits(), eval.conj_sum.to_bits());
                assert!(back.has_dual);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let ws = WorkerState { id: 0, rng_state: [9, 8, 7, 6], alpha: vec![0.0, 0.25] };
        match roundtrip_to_leader(ToLeader::State(ws.clone())) {
            ToLeader::State(back) => assert_eq!(back, ws),
            other => panic!("wrong variant: {other:?}"),
        }

        match roundtrip_to_leader(ToLeader::Fatal { worker: 3, message: "boom".into() }) {
            ToLeader::Fatal { worker, message } => {
                assert_eq!(worker, 3);
                assert_eq!(message, "boom");
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let metrics = WorkerMetrics {
            worker: 2,
            round: 11,
            solve_wall_s: 0.03125,
            solve_cpu_s: 0.015625,
            inner_steps: 400,
            peak_rss_bytes: 123_456_789,
            reconnects: 2,
        };
        let (kind, sized) = to_leader_wire(&ToLeader::Metrics(metrics));
        assert_eq!(kind, MessageKind::Metrics);
        assert_eq!(sized, HEADER_BYTES + 40);
        match roundtrip_to_leader(ToLeader::Metrics(metrics)) {
            ToLeader::Metrics(back) => {
                assert_eq!(back.worker, 2);
                assert_eq!(back.round, 11);
                assert_eq!(back.solve_wall_s.to_bits(), metrics.solve_wall_s.to_bits());
                assert_eq!(back.solve_cpu_s.to_bits(), metrics.solve_cpu_s.to_bits());
                assert_eq!(back.inner_steps, 400);
                assert_eq!(back.peak_rss_bytes, 123_456_789);
                assert_eq!(back.reconnects, 2);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn golden_bytes_pin_the_layout() {
        // Commit{scale: 1.0} to worker 2 (round field unused, 0). Any
        // change here is a wire-format break: bump WIRE_VERSION.
        let buf = encode_to_worker(&ToWorker::Commit { scale: 1.0 }, 2);
        assert_eq!(
            buf,
            vec![
                0xCA, 0xC0, // magic 0xC0CA, little-endian
                0x03, // wire version
                0x02, // tag: commit
                0x02, 0x00, 0x00, 0x00, // worker 2
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // round 0
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF0, 0x3F, // scale 1.0
            ]
        );

        // Round reply from worker 1, round 3, sparse dw [0, -2.0, 0].
        let buf = encode_to_leader(&ToLeader::Round(RoundReply {
            worker: 1,
            round: 3,
            dw: vec![0.0, -2.0, 0.0],
            compute_s: 0.5,
            steps: 4,
        }));
        assert_eq!(
            buf,
            vec![
                0xCA, 0xC0, 0x03, 0x81, // magic, version, tag: round reply
                0x01, 0x00, 0x00, 0x00, // worker 1
                0x03, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // round 3
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xE0, 0x3F, // compute_s 0.5
                0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // steps 4
                0x01, // dw: sparse
                0x03, 0x00, 0x00, 0x00, // d = 3
                0x01, 0x00, 0x00, 0x00, // nnz = 1
                0x01, 0x00, 0x00, 0x00, // index 1
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xC0, // -2.0
            ]
        );
    }

    #[test]
    fn malformed_frames_decode_to_typed_errors() {
        let good = encode_to_worker(&ToWorker::Commit { scale: 1.0 }, 0);

        // (case name, mutated frame, expected error) — decode must return
        // the typed error, never panic or allocate per attacker-declared
        // lengths.
        let commit_truncated = good[..HEADER_BYTES as usize + 3].to_vec();
        let mut bad_magic = good.clone();
        bad_magic[0] = 0xFF;
        let mut bad_version = good.clone();
        bad_version[2] = WIRE_VERSION + 1;
        let mut unknown_tag = good.clone();
        unknown_tag[3] = 0x7E;
        let mut trailing = good.clone();
        trailing.push(0);
        // sparse dw declaring d = u32::MAX: must be rejected by the cap,
        // not answered with a 32 GiB allocation
        let mut oversized = Vec::new();
        encode_header(TAG_EVAL, 0, 0, &mut oversized);
        oversized.push(1); // sparse
        oversized.extend_from_slice(&u32::MAX.to_le_bytes()); // d
        oversized.extend_from_slice(&0u32.to_le_bytes()); // nnz
        // sparse index beyond the declared dimension
        let mut bad_index = Vec::new();
        encode_header(TAG_EVAL, 0, 0, &mut bad_index);
        bad_index.push(1);
        bad_index.extend_from_slice(&2u32.to_le_bytes()); // d = 2
        bad_index.extend_from_slice(&1u32.to_le_bytes()); // nnz = 1
        bad_index.extend_from_slice(&9u32.to_le_bytes()); // index 9 >= d
        bad_index.extend_from_slice(&1.0f64.to_le_bytes());

        let cases: Vec<(&str, Vec<u8>, WireError)> = vec![
            ("empty", Vec::new(), WireError::Truncated { what: "magic" }),
            ("header only half", good[..7].to_vec(), WireError::Truncated { what: "worker id" }),
            (
                "commit payload truncated",
                commit_truncated,
                WireError::Truncated { what: "commit scale" },
            ),
            ("bad magic", bad_magic, WireError::BadMagic { got: 0xC0FF }),
            (
                "bad version",
                bad_version,
                WireError::BadVersion { got: WIRE_VERSION + 1, want: WIRE_VERSION },
            ),
            ("unknown tag", unknown_tag, WireError::UnknownTag { got: 0x7E }),
            (
                "trailing garbage",
                trailing,
                WireError::Malformed { what: "trailing bytes after message" },
            ),
            (
                "oversized declared dw",
                oversized,
                WireError::Oversized { declared: u32::MAX as u64, max: MAX_WIRE_ELEMS as u64 },
            ),
            (
                "sparse index out of range",
                bad_index,
                WireError::Malformed { what: "sparse index out of range" },
            ),
        ];
        for (name, frame, want) in cases {
            let got = decode_to_worker(&frame).unwrap_err();
            assert_eq!(got, want, "case {name:?}");
        }

        // same header validation guards the worker -> leader direction
        let mut reply = encode_to_leader(&ToLeader::Fatal { worker: 0, message: "x".into() });
        reply[2] = 0; // version 0
        assert_eq!(
            decode_to_leader(&reply).unwrap_err(),
            WireError::BadVersion { got: 0, want: WIRE_VERSION }
        );
        // fatal message length pointing past the buffer
        let mut fatal = Vec::new();
        encode_header(TAG_FATAL, 0, 0, &mut fatal);
        fatal.extend_from_slice(&100u32.to_le_bytes());
        fatal.extend_from_slice(b"short");
        assert_eq!(
            decode_to_leader(&fatal).unwrap_err(),
            WireError::Truncated { what: "fatal message" }
        );
    }
}
