//! Wire format: byte-exact sizing (and, for `dw`, real encoding) of every
//! leader <-> worker message.
//!
//! The in-process backends never serialize for delivery, but the byte
//! accounting of [`Counted`](super::Counted) and friends must be *exact*,
//! not an analytic vector count — so this module pins down one concrete
//! wire layout and sizes every message against it:
//!
//! * every message: a 16-byte header (kind tag `u32`, worker `u32`,
//!   round `u64`),
//! * dense f64 vectors: `u32` length prefix + 8 bytes per scalar,
//! * shared-vector payloads (`dw` replies AND the `w` broadcasts): the
//!   cheaper of a dense block and a sparse `(u32 index, f64 value)` pair
//!   list — the sparse delta-encoding that makes mostly-zero round
//!   replies (tiny H, very sparse data) cheap, and that compresses the
//!   broadcast `w` when an L1/elastic-net regularizer's prox map plants
//!   exact zeros in it (lasso broadcasts shrink with the recovered
//!   support).
//!
//! [`encode_dw`]/[`decode_dw`] implement the shared-vector layout for real
//! (used by the `hot_paths` bench and the round-trip tests); the rest of
//! the module only *sizes* messages, which is all the ledger needs.

use crate::coordinator::{LocalWork, ToLeader, ToWorker};

/// Number of [`MessageKind`] variants (ledger array size).
pub const KIND_COUNT: usize = 7;

/// Message taxonomy for per-kind byte accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageKind {
    /// Leader -> worker round dispatch carrying the shared `w`.
    Broadcast = 0,
    /// Leader -> worker commit order (the `beta_K / K` fold).
    Commit = 1,
    /// Worker -> leader round reply carrying `dw` (the delta-w vector).
    DeltaW = 2,
    /// Leader -> worker evaluation request (instrumentation).
    EvalRequest = 3,
    /// Worker -> leader evaluation partial sums (instrumentation).
    EvalReply = 4,
    /// Checkpoint traffic in either direction (get/set/report state).
    Checkpoint = 5,
    /// Control traffic (reset, shutdown, fatal errors).
    Control = 6,
}

impl MessageKind {
    pub const ALL: [MessageKind; KIND_COUNT] = [
        MessageKind::Broadcast,
        MessageKind::Commit,
        MessageKind::DeltaW,
        MessageKind::EvalRequest,
        MessageKind::EvalReply,
        MessageKind::Checkpoint,
        MessageKind::Control,
    ];

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Algorithm communication (what the paper's figures charge for), as
    /// opposed to instrumentation (eval), fault tolerance (checkpoint),
    /// and control traffic.
    pub fn is_algorithm(self) -> bool {
        matches!(
            self,
            MessageKind::Broadcast | MessageKind::Commit | MessageKind::DeltaW
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            MessageKind::Broadcast => "broadcast",
            MessageKind::Commit => "commit",
            MessageKind::DeltaW => "delta_w",
            MessageKind::EvalRequest => "eval_request",
            MessageKind::EvalReply => "eval_reply",
            MessageKind::Checkpoint => "checkpoint",
            MessageKind::Control => "control",
        }
    }
}

/// Fixed per-message header: kind tag (`u32`), worker id (`u32`),
/// round (`u64`).
pub const HEADER_BYTES: u64 = 16;
/// Length prefix of variable-size payloads.
const LEN_BYTES: u64 = 4;
/// RNG state carried by checkpoint messages (`[u64; 4]`).
const RNG_STATE_BYTES: u64 = 32;

/// Length-prefixed dense f64 vector.
pub fn dense_vec_bytes(len: usize) -> u64 {
    LEN_BYTES + 8 * len as u64
}

/// How a `dw` vector goes on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DwEncoding {
    /// `tag u8 + d u32 + d * f64`.
    Dense,
    /// `tag u8 + d u32 + nnz u32 + nnz * (u32 index + f64 value)`.
    Sparse,
}

/// Chosen encoding + exact encoded size for a `dw` payload: the sparse
/// pair list when it is strictly smaller (nnz < ~2d/3), dense otherwise.
pub fn dw_wire(dw: &[f64]) -> (DwEncoding, u64) {
    let d = dw.len() as u64;
    let nnz = dw.iter().filter(|v| **v != 0.0).count() as u64;
    let dense = 1 + LEN_BYTES + 8 * d;
    let sparse = 1 + LEN_BYTES + LEN_BYTES + 12 * nnz;
    if sparse < dense {
        (DwEncoding::Sparse, sparse)
    } else {
        (DwEncoding::Dense, dense)
    }
}

/// Encode `dw` into the layout [`dw_wire`] sized (little-endian).
pub fn encode_dw(dw: &[f64]) -> Vec<u8> {
    let (encoding, bytes) = dw_wire(dw);
    let mut out = Vec::with_capacity(bytes as usize);
    out.push(match encoding {
        DwEncoding::Dense => 0u8,
        DwEncoding::Sparse => 1u8,
    });
    out.extend_from_slice(&(dw.len() as u32).to_le_bytes());
    match encoding {
        DwEncoding::Dense => {
            for v in dw {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        DwEncoding::Sparse => {
            let nnz = dw.iter().filter(|v| **v != 0.0).count() as u32;
            out.extend_from_slice(&nnz.to_le_bytes());
            for (i, v) in dw.iter().enumerate() {
                if *v != 0.0 {
                    out.extend_from_slice(&(i as u32).to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    debug_assert_eq!(out.len() as u64, bytes);
    out
}

/// Decode a buffer produced by [`encode_dw`]. `None` on malformed input.
pub fn decode_dw(buf: &[u8]) -> Option<Vec<f64>> {
    let (&tag, rest) = buf.split_first()?;
    if rest.len() < 4 {
        return None;
    }
    let d = u32::from_le_bytes(rest[0..4].try_into().ok()?) as usize;
    let rest = &rest[4..];
    match tag {
        0 => {
            if rest.len() != 8 * d {
                return None;
            }
            let mut out = Vec::with_capacity(d);
            for chunk in rest.chunks_exact(8) {
                out.push(f64::from_le_bytes(chunk.try_into().ok()?));
            }
            Some(out)
        }
        1 => {
            if rest.len() < 4 {
                return None;
            }
            let nnz = u32::from_le_bytes(rest[0..4].try_into().ok()?) as usize;
            let rest = &rest[4..];
            if rest.len() != 12 * nnz {
                return None;
            }
            let mut out = vec![0.0; d];
            for chunk in rest.chunks_exact(12) {
                let i = u32::from_le_bytes(chunk[0..4].try_into().ok()?) as usize;
                if i >= d {
                    return None;
                }
                out[i] = f64::from_le_bytes(chunk[4..12].try_into().ok()?);
            }
            Some(out)
        }
        _ => None,
    }
}

/// A [`LocalWork`] order: kind tag (`u32`) + two parameter words covers
/// every variant (h/b/t_offset/sigma_prime).
fn local_work_bytes(_work: &LocalWork) -> u64 {
    4 + 16
}

/// `(kind, exact serialized size)` of a leader -> worker message. The
/// broadcast `w` rides the same adaptive encoding as `dw` replies: dense
/// for typical L2 iterates, the index/value pair list once a prox map
/// makes `w` mostly zero.
pub fn to_worker_wire(msg: &ToWorker) -> (MessageKind, u64) {
    match msg {
        ToWorker::Round { w, work, .. } => (
            MessageKind::Broadcast,
            HEADER_BYTES + local_work_bytes(work) + dw_wire(w).1,
        ),
        ToWorker::Commit { .. } => (MessageKind::Commit, HEADER_BYTES + 8),
        ToWorker::Eval { w } => (
            MessageKind::EvalRequest,
            HEADER_BYTES + dw_wire(w).1,
        ),
        ToWorker::GetState => (MessageKind::Checkpoint, HEADER_BYTES),
        ToWorker::SetState(ws) => (
            MessageKind::Checkpoint,
            HEADER_BYTES + RNG_STATE_BYTES + dense_vec_bytes(ws.alpha.len()),
        ),
        ToWorker::Reset | ToWorker::Shutdown => (MessageKind::Control, HEADER_BYTES),
    }
}

/// `(kind, exact serialized size)` of a worker -> leader message.
pub fn to_leader_wire(msg: &ToLeader) -> (MessageKind, u64) {
    match msg {
        // compute_s (f64) + steps (u64) ride along with the encoded dw
        ToLeader::Round(r) => (MessageKind::DeltaW, HEADER_BYTES + 16 + dw_wire(&r.dw).1),
        // loss_sum + conj_sum (f64 each) + has_dual (u8)
        ToLeader::Eval(_) => (MessageKind::EvalReply, HEADER_BYTES + 17),
        ToLeader::State(ws) => (
            MessageKind::Checkpoint,
            HEADER_BYTES + RNG_STATE_BYTES + dense_vec_bytes(ws.alpha.len()),
        ),
        ToLeader::Fatal { message, .. } => (
            MessageKind::Control,
            HEADER_BYTES + LEN_BYTES + message.len() as u64,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RoundReply;

    #[test]
    fn dw_roundtrip_dense_bit_exact() {
        let dw = vec![1.5, -0.0, f64::MIN_POSITIVE / 2.0, std::f64::consts::PI, -3.25];
        let (enc, bytes) = dw_wire(&dw);
        assert_eq!(enc, DwEncoding::Dense); // only one zero out of five
        let buf = encode_dw(&dw);
        assert_eq!(buf.len() as u64, bytes);
        let back = decode_dw(&buf).unwrap();
        assert_eq!(back.len(), dw.len());
        for (a, b) in dw.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dw_roundtrip_sparse_bit_exact() {
        let mut dw = vec![0.0f64; 1000];
        dw[3] = 1.25;
        dw[999] = -std::f64::consts::E;
        let (enc, bytes) = dw_wire(&dw);
        assert_eq!(enc, DwEncoding::Sparse);
        assert_eq!(bytes, 1 + 4 + 4 + 12 * 2);
        let buf = encode_dw(&dw);
        assert_eq!(buf.len() as u64, bytes);
        let back = decode_dw(&buf).unwrap();
        for (a, b) in dw.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sparse_wins_exactly_when_smaller() {
        // nnz where 8 + 12*nnz < 8*d flips the choice
        for d in [3usize, 10, 100] {
            for nnz in 0..=d {
                let mut dw = vec![0.0f64; d];
                for i in 0..nnz {
                    dw[i] = 1.0 + i as f64;
                }
                let (enc, bytes) = dw_wire(&dw);
                let dense = 1 + 4 + 8 * d as u64;
                let sparse = 1 + 4 + 4 + 12 * nnz as u64;
                match enc {
                    DwEncoding::Sparse => assert!(sparse < dense, "d={d} nnz={nnz}"),
                    DwEncoding::Dense => assert!(dense <= sparse, "d={d} nnz={nnz}"),
                }
                assert_eq!(bytes, dense.min(sparse));
                assert_eq!(encode_dw(&dw).len() as u64, bytes);
            }
        }
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(decode_dw(&[]).is_none());
        assert!(decode_dw(&[7, 0, 0, 0, 0]).is_none()); // unknown tag
        let mut buf = encode_dw(&[1.0, 2.0]);
        buf.pop(); // truncated payload
        assert!(decode_dw(&buf).is_none());
    }

    #[test]
    fn message_sizes_scale_with_payload() {
        // dense (every coordinate nonzero) broadcasts grow linearly in d
        let w = std::sync::Arc::new(vec![1.5f64; 100]);
        let (kind, b100) = to_worker_wire(&ToWorker::Round {
            round: 1,
            w: w.clone(),
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(kind, MessageKind::Broadcast);
        let w2 = std::sync::Arc::new(vec![1.5f64; 200]);
        let (_, b200) = to_worker_wire(&ToWorker::Round {
            round: 1,
            w: w2,
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(b200 - b100, 100 * 8);

        let (kind, commit) = to_worker_wire(&ToWorker::Commit { scale: 0.25 });
        assert_eq!(kind, MessageKind::Commit);
        assert_eq!(commit, HEADER_BYTES + 8);

        let reply = ToLeader::Round(RoundReply {
            worker: 0,
            round: 1,
            dw: vec![0.0; 50],
            compute_s: 0.0,
            steps: 5,
        });
        let (kind, bytes) = to_leader_wire(&reply);
        assert_eq!(kind, MessageKind::DeltaW);
        // all-zero dw: the sparse encoding collapses to the fixed preamble
        assert_eq!(bytes, HEADER_BYTES + 16 + 1 + 4 + 4);
    }

    #[test]
    fn prox_sparse_broadcast_shrinks_on_the_wire() {
        // A lasso-style w (few nonzeros from the prox map) must cost the
        // sparse pair-list size, far below the dense layout — this is the
        // mechanism behind smaller measured bytes on L1 runs.
        let mut w = vec![0.0f64; 500];
        for j in (0..500).step_by(100) {
            w[j] = 0.75;
        }
        let (kind, sparse_bytes) = to_worker_wire(&ToWorker::Round {
            round: 3,
            w: std::sync::Arc::new(w),
            work: LocalWork::DualRound { h: 5 },
        });
        assert_eq!(kind, MessageKind::Broadcast);
        let dense_equiv = to_worker_wire(&ToWorker::Round {
            round: 3,
            w: std::sync::Arc::new(vec![0.75f64; 500]),
            work: LocalWork::DualRound { h: 5 },
        })
        .1;
        assert_eq!(sparse_bytes, HEADER_BYTES + (4 + 16) + 1 + 4 + 4 + 12 * 5);
        assert!(sparse_bytes < dense_equiv / 10);
        // the eval request carries the same adaptively-encoded w
        let mut w = vec![0.0f64; 500];
        w[7] = -1.25;
        let (kind, eval_bytes) = to_worker_wire(&ToWorker::Eval {
            w: std::sync::Arc::new(w),
        });
        assert_eq!(kind, MessageKind::EvalRequest);
        assert_eq!(eval_bytes, HEADER_BYTES + 1 + 4 + 4 + 12);
    }

    #[test]
    fn kind_index_is_dense_and_stable() {
        for (i, kind) in MessageKind::ALL.iter().enumerate() {
            assert_eq!(kind.index(), i);
        }
        assert!(MessageKind::Broadcast.is_algorithm());
        assert!(MessageKind::Commit.is_algorithm());
        assert!(MessageKind::DeltaW.is_algorithm());
        assert!(!MessageKind::EvalRequest.is_algorithm());
        assert!(!MessageKind::EvalReply.is_algorithm());
        assert!(!MessageKind::Checkpoint.is_algorithm());
        assert!(!MessageKind::Control.is_algorithm());
    }
}
