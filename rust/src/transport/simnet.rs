//! SimNet — a deterministic, seedable network adversary.
//!
//! Injects the failure modes a real cluster fabric shows — per-message
//! latency jitter, dropped packets with bounded retransmission, slow
//! (straggling) reply paths — while *never* touching message contents or
//! per-worker ordering. Drops are modeled as wasted attempts: a message
//! may be "dropped" up to `max_retries` times (each charging a retransmit
//! of its full size plus a timeout latency), and the attempt after the
//! last retry always lands. Delivery is therefore guaranteed and the
//! optimization trajectory is bit-identical to [`InProc`](super::InProc);
//! only the byte ledger and the injected-latency account differ — which is
//! exactly what makes Figure-3-style sweeps and fault scenarios
//! reproducible.
//!
//! Every per-message decision is drawn from an RNG seeded by
//! `(seed, worker, direction, per-worker sequence number)`, so fates do
//! not depend on cross-worker arrival interleaving: the same seed gives
//! the same drops, the same jitter, and the same byte totals on every run.
//!
//! This is distinct from [`StragglerModel`](crate::netsim::StragglerModel),
//! which scales the *modeled compute barrier*; SimNet stragglers delay the
//! transport path of individual round replies (charged as extra injected
//! latency proportional to the straggler's measured compute).

use super::wire;
use super::{InProc, Ledger, Meter, Transport};
use crate::coordinator::{ToLeader, ToWorker};
use crate::error::Result;
use crate::util::Rng;

/// Deterministic fault/latency injection parameters. Everything is pure in
/// `seed`; see [`SimNetConfig::validate`] for the accepted ranges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimNetConfig {
    pub seed: u64,
    /// Max uniform per-message one-way latency jitter (seconds).
    pub jitter_s: f64,
    /// Per-attempt drop probability, in `[0, 1)`.
    pub drop_prob: f64,
    /// Retransmissions allowed per message; the attempt after the last
    /// retry always lands (bounded drops, guaranteed delivery).
    pub max_retries: u32,
    /// Latency charged per dropped attempt (detection timeout + resend).
    pub retry_timeout_s: f64,
    /// Probability a worker's round reply straggles, in `[0, 1]`.
    pub straggler_prob: f64,
    /// Slowdown factor of a straggling reply (>= 1): the reply is charged
    /// `(slowdown - 1) * compute_s` of extra transport latency.
    pub straggler_slowdown: f64,
}

impl SimNetConfig {
    /// Mild defaults: 1 ms jitter, 1% drops with 3 retries, no stragglers.
    pub fn new(seed: u64) -> Self {
        SimNetConfig {
            seed,
            jitter_s: 1e-3,
            drop_prob: 0.01,
            max_retries: 3,
            retry_timeout_s: 5e-3,
            straggler_prob: 0.0,
            straggler_slowdown: 1.0,
        }
    }

    /// Override the jitter amplitude.
    pub fn jitter(mut self, jitter_s: f64) -> Self {
        self.jitter_s = jitter_s;
        self
    }

    /// Override the drop/retransmit cycle.
    pub fn drops(mut self, drop_prob: f64, max_retries: u32, retry_timeout_s: f64) -> Self {
        self.drop_prob = drop_prob;
        self.max_retries = max_retries;
        self.retry_timeout_s = retry_timeout_s;
        self
    }

    /// Override straggling replies.
    pub fn stragglers(mut self, prob: f64, slowdown: f64) -> Self {
        self.straggler_prob = prob;
        self.straggler_slowdown = slowdown;
        self
    }

    /// Range checks; `Err(reason)` feeds the typed
    /// [`Error::InvalidTransport`](crate::Error::InvalidTransport).
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !self.jitter_s.is_finite() || self.jitter_s < 0.0 {
            return Err(format!("jitter_s must be finite and >= 0, got {}", self.jitter_s));
        }
        if !(0.0..1.0).contains(&self.drop_prob) {
            return Err(format!("drop_prob must be in [0, 1), got {}", self.drop_prob));
        }
        if !self.retry_timeout_s.is_finite() || self.retry_timeout_s < 0.0 {
            return Err(format!(
                "retry_timeout_s must be finite and >= 0, got {}",
                self.retry_timeout_s
            ));
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err(format!(
                "straggler_prob must be in [0, 1], got {}",
                self.straggler_prob
            ));
        }
        if !self.straggler_slowdown.is_finite() || self.straggler_slowdown < 1.0 {
            return Err(format!(
                "straggler_slowdown must be finite and >= 1, got {}",
                self.straggler_slowdown
            ));
        }
        Ok(())
    }
}

/// One message's deterministic fate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct Fate {
    /// Dropped attempts before the one that lands (`<= max_retries`).
    pub drops: u32,
    /// Injected latency: retransmit timeouts + jitter.
    pub latency_s: f64,
    /// Whether this delivery straggles (only applied to round replies).
    pub straggles: bool,
}

/// Pure in `(cfg, stream)`: the same stream id always yields the same fate.
pub(crate) fn message_fate(cfg: &SimNetConfig, stream: u64) -> Fate {
    let mut rng = Rng::seed_from_u64(stream);
    let mut drops = 0u32;
    while drops < cfg.max_retries && cfg.drop_prob > 0.0 && rng.gen_bool(cfg.drop_prob) {
        drops += 1;
    }
    let latency_s = drops as f64 * cfg.retry_timeout_s + rng.gen_f64() * cfg.jitter_s;
    let straggles = cfg.straggler_prob > 0.0 && rng.gen_bool(cfg.straggler_prob);
    Fate { drops, latency_s, straggles }
}

/// Stream id for message number `seq` to/from `worker` (direction 0 =
/// leader->worker, 1 = worker->leader).
fn stream_id(seed: u64, worker: usize, direction: u64, seq: u64) -> u64 {
    seed ^ (worker as u64 + 1).wrapping_mul(0x9e3779b97f4a7c15)
        ^ (direction + 1).wrapping_mul(0xd1b54a32d192ed03)
        ^ (seq + 1).wrapping_mul(0x2545f4914f6cdd1d)
}

/// The deterministic fault-injecting backend. See the module docs.
pub struct SimNet {
    inner: InProc,
    cfg: SimNetConfig,
    meter: Meter,
    /// Per-worker injected latency since the last drain.
    pending_lat: Vec<f64>,
    /// Per-worker sequence numbers, one per direction.
    send_seq: Vec<u64>,
    recv_seq: Vec<u64>,
}

impl SimNet {
    pub(crate) fn over(inner: InProc, cfg: SimNetConfig) -> Self {
        let k = inner.k();
        SimNet {
            inner,
            cfg,
            meter: Meter::default(),
            pending_lat: vec![0.0; k],
            send_seq: vec![0; k],
            recv_seq: vec![0; k],
        }
    }
}

impl Transport for SimNet {
    fn name(&self) -> &'static str {
        "simnet"
    }

    fn send(&mut self, to: usize, msg: ToWorker) -> Result<()> {
        let (kind, bytes) = wire::to_worker_wire(&msg);
        self.meter.count(kind, bytes);
        // faults only hit algorithm traffic: eval/checkpoint/control are
        // instrumentation and should not perturb the simulated time axis
        if kind.is_algorithm() {
            let seq = self.send_seq[to];
            self.send_seq[to] += 1;
            let fate = message_fate(&self.cfg, stream_id(self.cfg.seed, to, 0, seq));
            for _ in 0..fate.drops {
                self.meter.count(kind, bytes); // wasted retransmission
                self.meter.ledger.retransmits += 1;
            }
            self.pending_lat[to] += fate.latency_s;
        }
        self.inner.send(to, msg)
    }

    fn recv(&mut self) -> Result<ToLeader> {
        let msg = self.inner.recv()?;
        let (kind, bytes) = wire::to_leader_wire(&msg);
        self.meter.count(kind, bytes);
        if let ToLeader::Round(r) = &msg {
            let (worker, compute_s) = (r.worker, r.compute_s);
            let seq = self.recv_seq[worker];
            self.recv_seq[worker] += 1;
            let fate = message_fate(&self.cfg, stream_id(self.cfg.seed, worker, 1, seq));
            for _ in 0..fate.drops {
                self.meter.count(kind, bytes);
                self.meter.ledger.retransmits += 1;
            }
            let mut lat = fate.latency_s;
            if fate.straggles {
                lat += (self.cfg.straggler_slowdown - 1.0) * compute_s;
            }
            self.pending_lat[worker] += lat;
        }
        Ok(msg)
    }

    fn ledger(&self) -> Option<&Ledger> {
        Some(&self.meter.ledger)
    }

    fn take_round_bytes(&mut self) -> Option<u64> {
        Some(self.meter.drain())
    }

    fn take_round_latency(&mut self) -> f64 {
        let max = self.pending_lat.iter().fold(0.0f64, |m, &v| m.max(v));
        self.pending_lat.iter_mut().for_each(|v| *v = 0.0);
        max
    }

    fn reset_state(&mut self) {
        self.meter.reset();
        self.pending_lat.iter_mut().for_each(|v| *v = 0.0);
        self.send_seq.iter_mut().for_each(|v| *v = 0);
        self.recv_seq.iter_mut().for_each(|v| *v = 0);
        self.inner.reset_state();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fate_is_deterministic_per_stream() {
        let cfg = SimNetConfig::new(7).drops(0.3, 4, 2e-3).stragglers(0.5, 8.0);
        for stream in 0..200u64 {
            assert_eq!(message_fate(&cfg, stream), message_fate(&cfg, stream));
        }
    }

    #[test]
    fn drops_are_bounded_by_max_retries() {
        let cfg = SimNetConfig::new(3).drops(0.9, 2, 1e-3);
        let mut max_seen = 0;
        for stream in 0..500u64 {
            let fate = message_fate(&cfg, stream);
            assert!(fate.drops <= 2);
            max_seen = max_seen.max(fate.drops);
            // latency covers the timeouts paid
            assert!(fate.latency_s >= fate.drops as f64 * 1e-3 - 1e-15);
        }
        assert_eq!(max_seen, 2, "at 90% drop rate the cap must be hit");
    }

    #[test]
    fn zero_fault_config_injects_nothing() {
        let cfg = SimNetConfig::new(1).jitter(0.0).drops(0.0, 3, 1e-3);
        for stream in 0..100u64 {
            let fate = message_fate(&cfg, stream);
            assert_eq!(fate.drops, 0);
            assert_eq!(fate.latency_s, 0.0);
            assert!(!fate.straggles);
        }
    }

    #[test]
    fn streams_differ_across_workers_and_directions() {
        let a = stream_id(5, 0, 0, 0);
        let b = stream_id(5, 1, 0, 0);
        let c = stream_id(5, 0, 1, 0);
        let d = stream_id(5, 0, 0, 1);
        assert!(a != b && a != c && a != d && b != c);
    }

    #[test]
    fn validate_rejects_out_of_range() {
        assert!(SimNetConfig::new(0).validate().is_ok());
        assert!(SimNetConfig::new(0).jitter(-1.0).validate().is_err());
        assert!(SimNetConfig::new(0).drops(1.0, 1, 1e-3).validate().is_err());
        assert!(SimNetConfig::new(0).drops(0.1, 1, f64::NAN).validate().is_err());
        assert!(SimNetConfig::new(0).stragglers(2.0, 2.0).validate().is_err());
        assert!(SimNetConfig::new(0).stragglers(0.5, 0.5).validate().is_err());
    }
}
