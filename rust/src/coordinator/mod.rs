//! The CoCoA coordinator — Algorithm 1 of the paper as a leader/worker
//! runtime, plus the communication accounting every figure depends on.
//!
//! The leader owns the shared primal vector `w` and the network-cost
//! bookkeeping; each worker thread owns one coordinate block. One round:
//!
//! 1. broadcast `w` with a [`LocalWork`] order (K vectors down),
//! 2. workers compute locally and reply with one `dw` each (K vectors up),
//! 3. the leader reduces `w += scale * sum_k dw_k` and tells workers to
//!    fold their pending `dalpha` in with the same scale
//!    (`scale = beta_K / K`, Algorithm 1's averaging).
//!
//! Every leader-side message moves through the pluggable
//! [`Transport`](crate::transport::Transport) layer: the in-process
//! default is zero-overhead, while the measuring backends (counted /
//! simnet / record / replay) account byte-exact serialized sizes — and
//! when they do, the *measured* bytes (not the analytic vector count)
//! drive the [`netsim`](crate::netsim) round time, together with any
//! transport-injected latency (jitter, retransmits, stragglers).
//!
//! Evaluation (P/D/duality gap) flows through the same transport but is
//! *not* counted as algorithm communication — it is instrumentation.

pub mod checkpoint;
pub mod messages;
pub(crate) mod worker;

pub use checkpoint::{Checkpoint, WorkerState};
pub use messages::{AppendBlock, EvalReply, LocalWork, RoundReply, ToLeader, ToWorker, WorkerMetrics};
pub use worker::WorkerConfig;

use std::sync::mpsc::channel;

use anyhow::{anyhow, Result};

use crate::config::Backend;
use crate::data::{Dataset, Partition, ShardSet};
use crate::loss::LossKind;
use crate::netsim::{NetworkModel, StragglerModel};
use crate::objective;
use crate::obs::{Phase, Recorder, RoundObs, Span};
use crate::regularizers::{l1_norm, Regularizer, RegularizerKind};
use crate::runtime;
use crate::solvers::{Block, SolverKind};
use crate::telemetry::StopReason;
use crate::transport::{InProc, Ledger, Transcript, Transport, TransportKind};

/// Where the training rows come from: a resident [`Dataset`] (the
/// classic path — workers get `data.subset(block)`) or an on-disk
/// [`ShardSet`] (the out-of-core path — worker `kid` opens only shard
/// `kid`, typically mmap-backed, and the leader never holds the data at
/// all; evaluation was already fully distributed). The two produce
/// bit-identical trajectories — shard `kid` stores exactly
/// `data.subset(&partition.blocks[kid])`, bit for bit.
pub(crate) enum DataSource<'a> {
    Memory(&'a Dataset),
    Shards(&'a ShardSet),
}

impl DataSource<'_> {
    pub fn n(&self) -> usize {
        match self {
            DataSource::Memory(data) => data.n(),
            DataSource::Shards(set) => set.n(),
        }
    }

    pub fn d(&self) -> usize {
        match self {
            DataSource::Memory(data) => data.d(),
            DataSource::Shards(set) => set.d(),
        }
    }

    /// The dataset content fingerprint (identical across both paths: the
    /// shard manifest stores `Dataset::fingerprint` of the sharded data).
    pub fn fingerprint(&self) -> String {
        match self {
            DataSource::Memory(data) => data.fingerprint(),
            DataSource::Shards(set) => set.fingerprint().to_string(),
        }
    }
}

/// Everything [`Cluster::spawn`] needs, by name. Built and validated by
/// [`crate::Trainer`] — the only public road to a cluster.
pub(crate) struct ClusterSpec<'a> {
    pub source: DataSource<'a>,
    pub partition: &'a Partition,
    pub loss: LossKind,
    pub lambda: f64,
    pub regularizer: RegularizerKind,
    pub solver: SolverKind,
    pub backend: Backend,
    pub artifacts_dir: &'a str,
    pub net: NetworkModel,
    pub stragglers: StragglerModel,
    pub seed: u64,
    pub transport: TransportKind,
    /// Intra-worker shard count T for the local solves (>= 1; see the
    /// deterministic-per-T contract in [`crate::solvers::LocalSdca`]).
    /// Part of the run identity: trajectories are a function of
    /// `(seed, threads)`, so the net handshake fingerprints it too.
    pub threads: usize,
}

/// The per-worker rng seed: distinct, deterministic stream per worker.
/// Shared by the in-process spawn path and the net worker process so a
/// multi-process run draws bit-identical random streams.
pub(crate) fn worker_seed(seed: u64, kid: usize) -> u64 {
    seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(kid as u64)
}

/// Build worker `kid`'s full native-backend configuration from the global
/// run description. [`Cluster::spawn`] uses this for its in-process
/// threads and a `cocoa worker` process uses it for its assigned slot —
/// one code path, so the two deployments construct identical state.
#[allow(clippy::too_many_arguments)]
pub(crate) fn native_worker_config(
    data: &Dataset,
    rows: &[u32],
    loss: LossKind,
    lambda: f64,
    regularizer: RegularizerKind,
    solver: SolverKind,
    seed: u64,
    kid: usize,
    threads: usize,
) -> WorkerConfig {
    let lambda_n = lambda * regularizer.build().strong_convexity() * data.n() as f64;
    // subset() compacts the shard to contiguous local-row storage;
    // Block::new fills the per-shard caches (curvatures, sparse
    // column-touch set) the inner loop runs on.
    let block = Block::new(data.subset(rows), lambda_n);
    WorkerConfig {
        id: kid,
        block,
        loss: loss.build(),
        solver: solver.build(threads),
        lambda,
        seed: worker_seed(seed, kid),
        threads,
    }
}

/// The out-of-core counterpart of [`native_worker_config`]: build worker
/// `kid`'s configuration straight from its on-disk shard. The shard file
/// already holds exactly `data.subset(&partition.blocks[kid])` (values,
/// labels, *and* the norms a subset would recompute), so the resulting
/// [`Block`] is bit-identical to the in-memory path's — one construction
/// shared by [`Cluster::spawn`] and the `cocoa worker` process.
#[allow(clippy::too_many_arguments)]
pub(crate) fn shard_worker_config(
    set: &ShardSet,
    kid: usize,
    loss: LossKind,
    lambda: f64,
    regularizer: RegularizerKind,
    solver: SolverKind,
    seed: u64,
    threads: usize,
) -> crate::error::Result<WorkerConfig> {
    // lambda_n scales by the GLOBAL row count, not the shard's
    let lambda_n = lambda * regularizer.build().strong_convexity() * set.n() as f64;
    let block = Block::new(set.open_shard(kid)?, lambda_n);
    Ok(WorkerConfig {
        id: kid,
        block,
        loss: loss.build(),
        solver: solver.build(threads),
        lambda,
        seed: worker_seed(seed, kid),
        threads,
    })
}

/// Exact communication/time accounting for a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    pub rounds: u64,
    /// d-dimensional vectors moved (K broadcasts + K replies per round).
    pub vectors: u64,
    /// Bytes per the analytic model: `vectors * d * bytes_per_scalar`.
    pub bytes_modeled: u64,
    /// Byte-exact serialized bytes as measured by the transport, including
    /// any retransmissions. 0 unless a measuring transport (counted /
    /// simnet / record / replay) is configured.
    pub bytes_measured: u64,
    /// Sum over rounds of max-over-workers compute seconds.
    pub compute_s: f64,
    /// Simulated distributed time under the network model.
    pub sim_time_s: f64,
    /// Total inner steps across all workers.
    pub inner_steps: u64,
}

/// Leader + K worker threads over a partitioned dataset.
///
/// The leader owns *two* shared vectors: `v`, the dual combination
/// `(1/(lambda_eff n)) A alpha` the commits accumulate into, and the
/// primal iterate `w = prox(v)` that rounds and evaluations broadcast.
/// For the L2 regularizer the prox is the identity and `w` mirrors `v`
/// bit for bit — exactly the seed's single shared vector.
pub struct Cluster {
    transport: Box<dyn Transport>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub k: usize,
    pub n: usize,
    pub d: usize,
    /// The primal iterate `prox(v)` — what workers see and what
    /// [`crate::Session::w`] exposes.
    pub w: Vec<f64>,
    pub net: NetworkModel,
    /// Optional straggler injection for the simulated time axis.
    pub stragglers: crate::netsim::StragglerModel,
    pub stats: CommStats,
    pub block_sizes: Vec<usize>,
    /// Why the most recent driven run stopped (recorded by the round
    /// driver, persisted in checkpoints).
    pub last_stop: StopReason,
    /// The pre-prox shared vector the dual updates accumulate into.
    v: Vec<f64>,
    reg: Box<dyn Regularizer>,
    regularizer: RegularizerKind,
    loss: LossKind,
    lambda: f64,
    /// `lambda * sigma` — the strength of the normalized problem every
    /// objective formula and solver constant uses (== `lambda` for L2).
    lambda_eff: f64,
    round_counter: u64,
    /// The observability seam: disabled (default) it never samples a
    /// clock; enabled it records per-phase [`Span`]s the driver drains
    /// once per round via [`Cluster::take_round_obs`]. Pure observation —
    /// trajectories are bit-identical either way.
    recorder: Recorder,
    /// The metrics blocks gathered by the most recent dispatch, in slot
    /// order (drained by [`Cluster::take_round_obs`]).
    round_workers: Vec<WorkerMetrics>,
    /// Max `peak_rss_bytes` any worker has reported so far.
    max_worker_rss: u64,
    /// Cumulative lost-peer recoveries ([`Cluster::recover`] calls).
    obs_timeouts: u64,
    /// Cumulative connections healed across those recoveries.
    obs_heals: u64,
    /// The dataset content fingerprint this cluster was spawned over,
    /// chained through every [`Cluster::append_rows`] — the identity a
    /// serving handshake binds to (see [`crate::serve`]).
    fingerprint: String,
    /// Global row indices per worker block, in block (local-row) order.
    /// Owned here so appends can route new rows and `set_labels` can
    /// slice a global label vector per worker without the original
    /// [`Partition`] in hand.
    blocks: Vec<Vec<u32>>,
    /// Rows appended since spawn, across all batches. Appended row `a`
    /// (0-based in this stream) lives on worker `a % K` — one continuous
    /// round-robin stream, the same convention the durable shard append
    /// records in its manifest.
    appended: usize,
    /// Keeps the PJRT engine (and its compiled executables) alive.
    _engine: Option<runtime::Engine>,
}

impl Cluster {
    /// Spawn K worker threads over the partitioned dataset, and (for
    /// `Backend::Pjrt`) start the PJRT engine and register every block
    /// with it. Crate-private: the public road here is
    /// [`crate::Trainer::build`], which validates the spec first.
    pub(crate) fn spawn(spec: ClusterSpec<'_>) -> Result<Cluster> {
        let ClusterSpec {
            source,
            partition,
            loss,
            lambda,
            regularizer,
            solver,
            backend,
            artifacts_dir,
            net,
            stragglers,
            seed,
            transport,
            threads,
        } = spec;
        // the partition was already validated (with typed errors) by
        // Trainer::build — the only road here
        let k = partition.k();
        let n = source.n();
        let d = source.d();
        let reg = regularizer.build();
        // the normalized problem's strength: lambda * sigma. For L2
        // (sigma = 1) this is exactly lambda, so Block constants and every
        // downstream formula stay bit-identical to the seed.
        let lambda_eff = lambda * reg.strong_convexity();
        let lambda_n = lambda_eff * n as f64;

        // Net transport: the K workers are remote `cocoa worker` processes
        // that connect over TCP/UDS — no local threads, no channels. The
        // handshake fingerprint binds peers to this exact run description.
        if let TransportKind::Net(netcfg) = &transport {
            if backend == Backend::Pjrt {
                return Err(anyhow!("net transport requires the native backend"));
            }
            // Both sources hash to the same run fingerprint: the shard
            // manifest stores the sharded dataset's content fingerprint,
            // so in-memory and shard-fed leaders accept the same workers.
            let data_fingerprint = source.fingerprint();
            let fingerprint = crate::transport::net::run_fingerprint_parts(
                &data_fingerprint,
                n,
                d,
                partition,
                loss,
                regularizer,
                solver,
                lambda,
                seed,
                threads,
            );
            let sock = crate::transport::net::NetTransport::bind(netcfg, k, fingerprint)?;
            let boxed: Box<dyn Transport> = if netcfg.record {
                Box::new(crate::transport::Record::over(sock))
            } else {
                Box::new(sock)
            };
            return Ok(Cluster {
                transport: boxed,
                handles: Vec::new(),
                k,
                n,
                d,
                w: vec![0.0; d],
                net,
                stragglers,
                stats: CommStats::default(),
                block_sizes: partition.blocks.iter().map(|b| b.len()).collect(),
                last_stop: StopReason::default(),
                v: vec![0.0; d],
                reg,
                regularizer,
                loss,
                lambda,
                lambda_eff,
                round_counter: 0,
                recorder: Recorder::default(),
                round_workers: Vec::new(),
                max_worker_rss: 0,
                obs_timeouts: 0,
                obs_heals: 0,
                fingerprint: data_fingerprint,
                blocks: partition.blocks.clone(),
                appended: 0,
                _engine: None,
            });
        }

        // The PJRT path registers in-memory blocks with the engine at
        // spawn; feeding it from shards would force a full materialization
        // and defeat the out-of-core point. Rejected, not silently slow.
        if backend == Backend::Pjrt && matches!(source, DataSource::Shards(_)) {
            return Err(anyhow!("shard-backed training requires the native backend"));
        }

        let engine = match backend {
            Backend::Native => None,
            Backend::Pjrt => Some(runtime::Engine::start(artifacts_dir)?),
        };

        let (to_leader_tx, from_workers) = channel::<ToLeader>();
        let mut to_workers = Vec::with_capacity(k);
        let mut handles = Vec::with_capacity(k);
        let mut block_sizes = Vec::with_capacity(k);

        for (kid, rows) in partition.blocks.iter().enumerate() {
            let cfg = match (&backend, &engine, &source) {
                (Backend::Pjrt, Some(engine), DataSource::Memory(data)) => {
                    // subset() compacts the shard to contiguous local-row
                    // storage; Block::new fills the per-shard caches
                    // (curvatures, sparse column-touch set).
                    let block = Block::new(data.subset(rows), lambda_n);
                    let solver_impl: Box<dyn crate::solvers::LocalDualMethod> =
                        Box::new(runtime::PjrtLocalSdca::bind(
                            engine.handle(),
                            kid,
                            &block,
                            loss.artifact_name(),
                            loss.gamma(),
                        )?);
                    WorkerConfig {
                        id: kid,
                        block,
                        loss: loss.build(),
                        solver: solver_impl,
                        lambda,
                        seed: worker_seed(seed, kid),
                        // the PJRT engine runs the local solve off-thread;
                        // intra-worker sharding does not apply to it
                        threads: 1,
                    }
                }
                (_, _, DataSource::Memory(data)) => native_worker_config(
                    data,
                    rows,
                    loss,
                    lambda,
                    regularizer,
                    solver,
                    seed,
                    kid,
                    threads,
                ),
                (_, _, DataSource::Shards(set)) => {
                    let wc = shard_worker_config(
                        set, kid, loss, lambda, regularizer, solver, seed, threads,
                    )?;
                    if wc.block.n_k() != rows.len() {
                        return Err(anyhow!(
                            "shard {kid} holds {} rows but the partition block has {}",
                            wc.block.n_k(),
                            rows.len()
                        ));
                    }
                    wc
                }
            };
            block_sizes.push(cfg.block.n_k());
            let (tx, rx) = channel::<ToWorker>();
            let leader_tx = to_leader_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cocoa-worker-{kid}"))
                .spawn(move || worker::run_worker(cfg, rx, leader_tx))?;
            to_workers.push(tx);
            handles.push(handle);
        }

        let transport = transport.build(InProc::new(to_workers, from_workers));

        Ok(Cluster {
            transport,
            handles,
            k,
            n,
            d,
            w: vec![0.0; d],
            net,
            stragglers,
            stats: CommStats::default(),
            block_sizes,
            last_stop: StopReason::default(),
            v: vec![0.0; d],
            reg,
            regularizer,
            loss,
            lambda,
            lambda_eff,
            round_counter: 0,
            recorder: Recorder::default(),
            round_workers: Vec::new(),
            max_worker_rss: 0,
            obs_timeouts: 0,
            obs_heals: 0,
            fingerprint: source.fingerprint(),
            blocks: partition.blocks.clone(),
            appended: 0,
            _engine: engine,
        })
    }

    /// Refresh the primal iterate from the shared vector: `w = prox(v)`.
    /// The L2 fast path copies bits (identity prox), so seed trajectories
    /// are reproduced exactly; for L1/elastic-net this is the leader's
    /// per-commit prox step that plants exact zeros in the broadcast `w`.
    fn sync_w(&mut self) {
        if self.reg.is_identity_map() {
            self.w.copy_from_slice(&self.v);
        } else {
            self.reg.prox_into(&self.v, &mut self.w);
        }
    }

    /// Warm-start: zero all optimization state (leader `w`, worker dual
    /// blocks, rng streams, accounting, transport ledgers) while keeping
    /// the threads, their data, and any PJRT block registrations alive. A
    /// run after `reset()` is bit-identical to one on a freshly spawned
    /// cluster with the same seed. Channel ordering makes an ack
    /// unnecessary: the next dispatch on each worker channel is processed
    /// after its reset.
    pub fn reset(&mut self) -> Result<()> {
        for kid in 0..self.k {
            self.transport.send(kid, ToWorker::Reset)?;
        }
        self.transport.reset_state();
        self.w = vec![0.0; self.d];
        self.v = vec![0.0; self.d];
        self.stats = CommStats::default();
        self.last_stop = StopReason::default();
        self.round_counter = 0;
        let _ = self.recorder.drain();
        self.round_workers.clear();
        self.max_worker_rss = 0;
        self.obs_timeouts = 0;
        self.obs_heals = 0;
        Ok(())
    }

    /// Continuous training: grow the training set by `batch` without
    /// tearing the cluster down, keeping all committed dual state. Must
    /// be called at a round boundary (after `commit`); workers fail fast
    /// otherwise.
    ///
    /// New rows are routed round-robin across workers (appended row `a`
    /// of the lifetime append stream lands on worker `a % K`) and enter
    /// at `alpha = 0` — always dual-feasible. Because the shared vector
    /// is the *normalized* combination `v = (1/(lambda_eff n)) A alpha`
    /// and `n` just grew, the leader rescales `v *= n_old / n_new` and
    /// every worker rebakes its curvatures against the new
    /// `lambda_n = lambda_eff * n_new` — after which the state is
    /// exactly what a fresh cluster over the grown dataset would reach
    /// with the same alpha. That is the warm-restart guarantee: the
    /// retained duals keep their objective value, so convergence resumes
    /// instead of restarting (see `docs/SERVING.md` for the gap bound).
    ///
    /// Checkpoints taken before an append no longer match the cluster
    /// shape (`n` changed) and are rejected by [`Cluster::restore`] with
    /// the usual typed shape error. The dataset fingerprint is chained
    /// (see [`crate::data`]'s `fingerprint_chain`), so serving snapshots
    /// taken before the append are recognizably stale.
    pub fn append_rows(&mut self, batch: &Dataset) -> Result<()> {
        use crate::data::Features;
        if batch.n() == 0 {
            return Err(anyhow!("append batch has no rows"));
        }
        if batch.d() != self.d {
            return Err(anyhow!(
                "append batch has d={} but the cluster was built with d={}",
                batch.d(),
                self.d
            ));
        }
        let m = batch.n();
        let n_old = self.n;
        let n_new = n_old + m;
        if n_new > u32::MAX as usize {
            return Err(anyhow!("appended dataset exceeds u32 row indexing"));
        }
        // route the batch: one AppendBlock per worker, rows in global order
        let mut per: Vec<messages::AppendBlock> =
            (0..self.k).map(|_| messages::AppendBlock::empty()).collect();
        for j in 0..m {
            let kid = (self.appended + j) % self.k;
            let ab = &mut per[kid];
            match &batch.features {
                Features::Sparse(mtx) => {
                    let (idx, val) = mtx.row_view(j);
                    ab.indices.extend_from_slice(idx);
                    ab.values.extend_from_slice(val);
                }
                Features::Dense(mtx) => {
                    for (c, &v) in mtx.row(j).iter().enumerate() {
                        if v != 0.0 {
                            ab.indices.push(c as u32);
                            ab.values.push(v);
                        }
                    }
                }
            }
            ab.indptr.push(ab.values.len());
            ab.labels.push(batch.labels[j]);
            // ship the batch's *cached* norm so appended blocks match a
            // whole-built dataset bit for bit (normalize_rows caches 1.0)
            ab.norms_sq.push(batch.norm_sq(j));
            self.blocks[kid].push((n_old + j) as u32);
        }
        // every worker gets the append — lambda_n changed for all of
        // them, even the ones that received no rows this batch
        let lambda_n = self.lambda_eff * n_new as f64;
        for (kid, ab) in per.into_iter().enumerate() {
            self.block_sizes[kid] += ab.rows();
            self.transport.send(kid, ToWorker::Append { block: ab, lambda_n })?;
        }
        // v = (1/(lambda_eff n)) A alpha: alpha is unchanged (new rows at
        // zero), only the 1/n normalization moved
        let rescale = n_old as f64 / n_new as f64;
        for vv in self.v.iter_mut() {
            *vv *= rescale;
        }
        self.sync_w();
        self.n = n_new;
        self.appended += m;
        self.fingerprint =
            crate::data::fingerprint_chain(&self.fingerprint, &batch.fingerprint());
        Ok(())
    }

    /// Swap every worker's labels in place (global order; length must be
    /// exactly `n`). Features, norms, and curvatures are label-independent,
    /// so nothing is rebaked — this is the cheap primitive behind
    /// one-vs-rest relabeling. Retained dual variables are generally
    /// *infeasible* for new labels: callers should [`Cluster::reset`]
    /// right after unless they know better. The dataset fingerprint is
    /// deliberately left alone — it identifies the feature matrix and the
    /// labels it was spawned with; one-vs-rest views are transient.
    pub fn set_labels(&mut self, labels: &[f64]) -> Result<()> {
        if labels.len() != self.n {
            return Err(anyhow!(
                "set_labels got {} labels for n={} rows",
                labels.len(),
                self.n
            ));
        }
        for (kid, block) in self.blocks.iter().enumerate() {
            let local: Vec<f64> = block.iter().map(|&i| labels[i as usize]).collect();
            self.transport.send(kid, ToWorker::SetLabels { labels: local })?;
        }
        Ok(())
    }

    /// The dataset content fingerprint this cluster serves — spawn-time
    /// fingerprint chained through every append.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Dispatch one round of local work (per-worker via `work_for`) and
    /// gather the K replies. Accounts 2K vectors (broadcast + gather), the
    /// network-model round time, and the per-round max compute. When the
    /// transport measures bytes, the measured total (including any SimNet
    /// retransmissions) replaces the analytic vector count in the round
    /// time, and transport-injected latency joins the barrier; the round's
    /// commit bytes are charged by [`Cluster::commit`].
    pub fn dispatch(&mut self, work_for: impl Fn(usize) -> LocalWork) -> Result<Vec<RoundReply>> {
        self.round_counter += 1;
        let round = self.round_counter;
        let t_bcast = self.recorder.start();
        let w_shared = std::sync::Arc::new(self.w.clone());
        for kid in 0..self.k {
            self.transport
                .send(kid, ToWorker::Round { round, w: w_shared.clone(), work: work_for(kid) })?;
        }
        self.recorder.finish(t_bcast, round, Phase::Broadcast);
        // the gather barrier: every worker sends its Round reply chased by
        // its Metrics block, so one round drains exactly K of each
        let t_reduce = self.recorder.start();
        let mut replies: Vec<Option<RoundReply>> = vec![None; self.k];
        let mut metrics: Vec<Option<WorkerMetrics>> = vec![None; self.k];
        let mut got = 0;
        let mut got_m = 0;
        while got < self.k || got_m < self.k {
            match self.transport.recv()? {
                ToLeader::Round(r) if r.round == round => {
                    let slot = &mut replies[r.worker];
                    if slot.is_none() {
                        got += 1;
                    }
                    *slot = Some(r);
                }
                ToLeader::Round(r) => {
                    return Err(anyhow!("stale round reply {} from worker {}", r.round, r.worker))
                }
                // instrumentation must never take a run down: anything
                // stale or out of range is dropped on the floor
                ToLeader::Metrics(m) if m.round == round && m.worker < self.k => {
                    let slot = &mut metrics[m.worker];
                    if slot.is_none() {
                        got_m += 1;
                    }
                    *slot = Some(m);
                }
                ToLeader::Metrics(_) => {}
                ToLeader::Eval(_) | ToLeader::State(_) => {
                    return Err(anyhow!("unexpected reply during round"))
                }
                ToLeader::Fatal { worker, message } => {
                    return Err(anyhow!("worker {worker} failed: {message}"))
                }
            }
        }
        let replies: Vec<RoundReply> = replies.into_iter().map(Option::unwrap).collect();
        self.round_workers = metrics.into_iter().map(Option::unwrap).collect();
        for m in &self.round_workers {
            self.max_worker_rss = self.max_worker_rss.max(m.peak_rss_bytes);
            self.recorder.push(Span {
                round,
                phase: Phase::LocalSolve,
                slot: Some(m.worker),
                wall_s: m.solve_wall_s,
                cpu_s: m.solve_cpu_s,
            });
        }

        let computes: Vec<f64> = replies.iter().map(|r| r.compute_s).collect();
        let max_compute = self.stragglers.barrier_compute(round, &computes);
        let injected_s = self.transport.take_round_latency();
        let measured = self.transport.take_round_bytes();
        let vectors = 2 * self.k as u64; // w down + dw up, per worker
        self.stats.rounds += 1;
        self.stats.vectors += vectors;
        self.stats.bytes_modeled += vectors * (self.d * self.net.bytes_per_scalar) as u64;
        self.stats.inner_steps += replies.iter().map(|r| r.steps).sum::<u64>();
        self.stats.compute_s += max_compute;
        self.stats.sim_time_s += match measured {
            Some(bytes) => {
                self.stats.bytes_measured += bytes;
                self.net.round_time_bytes(max_compute + injected_s, bytes)
            }
            None => self.net.round_time(max_compute + injected_s, vectors as usize, self.d),
        };
        self.recorder.finish(t_reduce, round, Phase::Reduce);
        Ok(replies)
    }

    /// Fold the round's updates into leader and worker state:
    /// `v += scale * sum_k dw_k` then `w = prox(v)`, and
    /// `alpha_[k] += scale * dalpha_[k]` on the workers.
    /// On a measuring transport, the K commit messages are drained into
    /// `bytes_measured` here (and their transfer time into `sim_time_s`),
    /// so every round's accounting closes at its own commit and
    /// `stats.bytes_measured` always equals the ledger's algorithm bytes
    /// at round boundaries.
    pub fn commit(&mut self, replies: &[RoundReply], scale: f64) -> Result<()> {
        let t_commit = self.recorder.start();
        for reply in replies {
            for (vv, dv) in self.v.iter_mut().zip(&reply.dw) {
                *vv += scale * dv;
            }
        }
        self.sync_w();
        for kid in 0..self.k {
            self.transport.send(kid, ToWorker::Commit { scale })?;
        }
        if let Some(bytes) = self.transport.take_round_bytes() {
            self.stats.bytes_measured += bytes;
            // rides the round's existing barrier: transfer time only, the
            // per-round fixed latency was already charged at dispatch
            self.stats.sim_time_s += self.net.transfer_time_bytes(bytes);
        }
        self.recorder.finish(t_commit, self.round_counter, Phase::Commit);
        Ok(())
    }

    /// Replace `w` outright (SGD-style leader updates). Workers have no
    /// pending dual state for SGD work, so no commit is needed. The shared
    /// vector mirrors the new `w` — primal methods are L2-only (guarded by
    /// the round driver), where the prox is the identity.
    pub fn set_w(&mut self, w: Vec<f64>) {
        assert_eq!(w.len(), self.d);
        self.v.copy_from_slice(&w);
        self.w = w;
    }

    /// Distributed evaluation of P(w), D(alpha), gap at the current state.
    /// Not counted as algorithm communication (instrumentation).
    ///
    /// Replies are slotted by worker id and folded in worker order, so the
    /// floating-point reduction is deterministic regardless of arrival
    /// interleaving — transports and warm-started runs stay bit-identical.
    pub fn evaluate(&mut self) -> Result<Evaluation> {
        let t_eval = self.recorder.start();
        let w_shared = std::sync::Arc::new(self.w.clone());
        for kid in 0..self.k {
            self.transport.send(kid, ToWorker::Eval { w: w_shared.clone() })?;
        }
        let mut parts: Vec<Option<EvalReply>> = vec![None; self.k];
        let mut got = 0;
        while got < self.k {
            match self.transport.recv()? {
                ToLeader::Eval(e) => {
                    let slot = &mut parts[e.worker];
                    if slot.is_none() {
                        got += 1;
                    }
                    *slot = Some(e);
                }
                // a straggling metrics block is instrumentation: drop it
                ToLeader::Metrics(_) => {}
                ToLeader::Round(_) | ToLeader::State(_) => {
                    return Err(anyhow!("unexpected reply during eval"))
                }
                ToLeader::Fatal { worker, message } => {
                    return Err(anyhow!("worker {worker} failed: {message}"))
                }
            }
        }
        let mut loss_sum = 0.0;
        let mut conj_sum = 0.0;
        let mut has_dual = true;
        for e in parts.into_iter().map(Option::unwrap) {
            loss_sum += e.loss_sum;
            conj_sum += e.conj_sum;
            has_dual &= e.has_dual;
        }
        // The normalized pair: P = lambda_eff [ ||w||^2/2 + kappa||w||_1 ]
        // + loss/n and D = -(lambda_eff/2)||w||^2 - conj/n, both at the
        // *mapped* w = prox(v) (whose norm is exactly the normalized
        // conjugate's value at v). kappa = 0 reduces to the seed formulas
        // bit for bit.
        let kappa = self.reg.l1_weight();
        let w_norm_sq: f64 = self.w.iter().map(|v| v * v).sum();
        let w_l1 = if kappa == 0.0 { 0.0 } else { l1_norm(&self.w) };
        let primal = objective::primal_from_partials_reg(
            loss_sum,
            w_norm_sq,
            w_l1,
            self.lambda_eff,
            kappa,
            self.n,
        );
        let dual = if has_dual {
            objective::dual_from_partials(conj_sum, w_norm_sq, self.lambda_eff, self.n)
        } else {
            f64::NAN
        };
        self.recorder.finish(t_eval, self.round_counter, Phase::Evaluate);
        Ok(Evaluation { primal, dual, gap: primal - dual })
    }

    /// Capture the full optimization state (must be called at a round
    /// boundary, i.e. after `commit`). See [`checkpoint`].
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        for kid in 0..self.k {
            self.transport.send(kid, ToWorker::GetState)?;
        }
        let mut workers: Vec<Option<checkpoint::WorkerState>> = (0..self.k).map(|_| None).collect();
        let mut got = 0;
        while got < self.k {
            match self.transport.recv()? {
                ToLeader::State(ws) => {
                    let slot = &mut workers[ws.id];
                    if slot.is_none() {
                        got += 1;
                    }
                    *slot = Some(ws);
                }
                ToLeader::Fatal { worker, message } => {
                    return Err(anyhow!("worker {worker} failed: {message}"))
                }
                _ => return Err(anyhow!("unexpected reply during checkpoint")),
            }
        }
        Ok(Checkpoint {
            k: self.k,
            n: self.n,
            d: self.d,
            round_counter: self.round_counter,
            stop: self.last_stop,
            regularizer: self.regularizer.to_string(),
            stats: self.stats,
            v: self.v.clone(),
            workers: workers.into_iter().map(Option::unwrap).collect(),
        })
    }

    /// Restore a previously captured state into this cluster. The cluster
    /// must have been built over the same dataset/partition (shapes are
    /// validated; contents are the caller's responsibility).
    pub fn restore(&mut self, cp: &Checkpoint) -> Result<()> {
        if cp.k != self.k || cp.n != self.n || cp.d != self.d {
            return Err(anyhow!(
                "checkpoint shape (K={}, n={}, d={}) does not match cluster (K={}, n={}, d={})",
                cp.k, cp.n, cp.d, self.k, self.n, self.d
            ));
        }
        // v is only meaningful through the matching prox/lambda_eff: a
        // state trained under one regularizer must not be silently
        // reinterpreted by another
        if cp.regularizer != self.regularizer.to_string() {
            return Err(anyhow!(
                "checkpoint regularizer {} does not match cluster regularizer {}",
                cp.regularizer,
                self.regularizer
            ));
        }
        for ws in &cp.workers {
            self.transport.send(ws.id, ToWorker::SetState(ws.clone()))?;
        }
        self.v = cp.v.clone();
        self.sync_w();
        self.stats = cp.stats;
        self.last_stop = cp.stop;
        self.round_counter = cp.round_counter;
        Ok(())
    }

    /// Recover a net cluster after a mid-round worker failure: re-accept
    /// replacement connections for dead slots, restore every worker from
    /// `cp`, and drain all pre-failure traffic so the next dispatch starts
    /// clean. Returns the number of connections healed.
    ///
    /// The aborted round may have left survivors with in-flight `Round`
    /// replies and a staged (uncommitted) `dalpha`. `SetState` clears the
    /// stage; the `GetState` sent right behind it acts as a per-connection
    /// barrier — socket FIFO guarantees any stale reply arrives *before*
    /// the worker's `State`, so once K `State`s are in, no pre-recovery
    /// message can alias into a future round.
    pub fn recover(&mut self, cp: &Checkpoint) -> Result<usize> {
        if cp.k != self.k || cp.n != self.n || cp.d != self.d {
            return Err(anyhow!(
                "checkpoint shape (K={}, n={}, d={}) does not match cluster (K={}, n={}, d={})",
                cp.k, cp.n, cp.d, self.k, self.n, self.d
            ));
        }
        if cp.regularizer != self.regularizer.to_string() {
            return Err(anyhow!(
                "checkpoint regularizer {} does not match cluster regularizer {}",
                cp.regularizer,
                self.regularizer
            ));
        }
        let healed = self.transport.heal()?;
        // every recovery was forced by a lost or timed-out peer; both
        // counters are cumulative run-level observability
        self.obs_timeouts += 1;
        self.obs_heals += healed as u64;
        for ws in &cp.workers {
            self.transport.send(ws.id, ToWorker::SetState(ws.clone()))?;
            self.transport.send(ws.id, ToWorker::GetState)?;
        }
        let mut seen = vec![false; self.k];
        let mut got = 0;
        while got < self.k {
            match self.transport.recv()? {
                ToLeader::State(ws) if ws.id < self.k => {
                    if !seen[ws.id] {
                        seen[ws.id] = true;
                        got += 1;
                    }
                }
                // stale replies from the aborted round: drain and drop
                ToLeader::Round(_) | ToLeader::Eval(_) | ToLeader::Metrics(_) => {}
                ToLeader::State(ws) => {
                    return Err(anyhow!("state reply from unknown worker {}", ws.id))
                }
                ToLeader::Fatal { worker, message } => {
                    return Err(anyhow!("worker {worker} failed during recovery: {message}"))
                }
            }
        }
        self.v = cp.v.clone();
        self.sync_w();
        self.stats = cp.stats;
        self.last_stop = cp.stop;
        self.round_counter = cp.round_counter;
        // The aborted round's traffic really crossed the wire (it stays in
        // the ledger) but its round never completed: drop the partial
        // drain so the next round's stats don't inherit it.
        let _ = self.transport.take_round_bytes();
        let _ = self.transport.take_round_latency();
        Ok(healed)
    }

    pub fn loss(&self) -> LossKind {
        self.loss
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// The regularizer this cluster was built with.
    pub fn regularizer(&self) -> RegularizerKind {
        self.regularizer
    }

    /// `lambda * sigma` — the normalized problem's strength (== `lambda`
    /// for L2).
    pub fn lambda_eff(&self) -> f64 {
        self.lambda_eff
    }

    /// Nonzero count of the primal iterate (the sparsity-recovery axis).
    pub fn w_nnz(&self) -> u64 {
        self.w.iter().filter(|v| **v != 0.0).count() as u64
    }

    /// Largest block size (`~n` in Proposition 1).
    pub fn n_max(&self) -> usize {
        self.block_sizes.iter().copied().max().unwrap_or(0)
    }

    /// Name of the active transport backend.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Byte-exact per-kind ledger (None for the unmeasured inproc default).
    pub fn ledger(&self) -> Option<&Ledger> {
        self.transport.ledger()
    }

    /// Take the transcript recorded so far (Record transport only).
    pub fn take_transcript(&mut self) -> Option<Transcript> {
        self.transport.take_transcript()
    }

    /// Raw socket accounting (net transport only): every byte written to
    /// and read from worker connections, including framing and handshakes.
    pub fn socket_stats(&self) -> Option<crate::transport::SocketStats> {
        self.transport.socket_stats()
    }

    /// Enable/disable round-phase span recording (off by default). A pure
    /// observer toggle: trajectories, byte counts, and sim time are
    /// bit-identical either way (asserted by `tests/observability.rs`).
    pub fn set_tracing(&mut self, on: bool) {
        self.recorder.set_enabled(on);
    }

    /// Is span recording enabled?
    pub fn tracing(&self) -> bool {
        self.recorder.enabled()
    }

    /// Max `peak_rss_bytes` any worker has reported so far (0 until the
    /// first round completes, or where procfs is unavailable).
    pub fn max_worker_rss(&self) -> u64 {
        self.max_worker_rss
    }

    /// Drain everything observed about the round just completed: recorded
    /// spans (empty unless [`Cluster::set_tracing`]), the K worker metrics
    /// blocks, and cumulative ledger/socket/failure snapshots. The driver
    /// calls this once per round and fans it out to observers.
    pub fn take_round_obs(&mut self) -> RoundObs {
        let spans = self.recorder.drain();
        let workers = std::mem::take(&mut self.round_workers);
        RoundObs {
            round: self.round_counter,
            spans,
            workers,
            ledger: self.ledger().copied(),
            socket: self.socket_stats(),
            timeouts: self.obs_timeouts,
            heals: self.obs_heals,
            max_worker_rss: self.max_worker_rss,
        }
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for kid in 0..self.k {
            let _ = self.transport.send(kid, ToWorker::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Result of a distributed objective evaluation.
#[derive(Debug, Clone, Copy)]
pub struct Evaluation {
    pub primal: f64,
    /// NaN when any worker has never produced a dual update (SGD runs).
    pub dual: f64,
    pub gap: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{cov_like, PartitionStrategy};

    fn spec_cluster(data: &Dataset, part: &Partition, net: NetworkModel, seed: u64) -> Cluster {
        Cluster::spawn(ClusterSpec {
            source: DataSource::Memory(data),
            partition: part,
            loss: LossKind::Hinge,
            lambda: 0.1,
            regularizer: RegularizerKind::L2,
            solver: SolverKind::Sdca,
            backend: Backend::Native,
            artifacts_dir: "artifacts",
            net,
            stragglers: StragglerModel::none(),
            seed,
            transport: TransportKind::InProc,
            threads: 1,
        })
        .unwrap()
    }

    fn small_cluster(k: usize) -> (Cluster, Dataset) {
        let data = cov_like(60, 6, 0.1, 1);
        let part = Partition::new(PartitionStrategy::Contiguous, 60, k, 0);
        let cluster = spec_cluster(&data, &part, NetworkModel::free(), 7);
        (cluster, data)
    }

    #[test]
    fn round_accounting() {
        let (mut cluster, _) = small_cluster(3);
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 10 }).unwrap();
        assert_eq!(replies.len(), 3);
        assert_eq!(cluster.stats.rounds, 1);
        assert_eq!(cluster.stats.vectors, 6); // 2K
        assert_eq!(cluster.stats.inner_steps, 30);
        cluster.commit(&replies, 1.0 / 3.0).unwrap();
        cluster.shutdown();
    }

    #[test]
    fn w_consistency_with_global_alpha() {
        // After commits, the leader's w must equal A alpha for the global
        // alpha implied by the same seeds — checked via the duality gap
        // being finite and P >= D.
        let (mut cluster, _) = small_cluster(4);
        for _ in 0..5 {
            let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 30 }).unwrap();
            cluster.commit(&replies, 0.25).unwrap();
        }
        let ev = cluster.evaluate().unwrap();
        assert!(ev.gap.is_finite());
        assert!(ev.gap >= -1e-9, "gap {} negative", ev.gap);
        assert!(ev.primal >= ev.dual - 1e-9);
        cluster.shutdown();
    }

    #[test]
    fn dual_improves_over_rounds() {
        let (mut cluster, _) = small_cluster(2);
        let d0 = cluster.evaluate().unwrap().dual;
        for _ in 0..8 {
            let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 60 }).unwrap();
            cluster.commit(&replies, 0.5).unwrap();
        }
        let d1 = cluster.evaluate().unwrap().dual;
        assert!(d1 > d0, "dual did not improve: {d0} -> {d1}");
        cluster.shutdown();
    }

    #[test]
    fn sgd_rounds_leave_dual_nan() {
        let (mut cluster, _) = small_cluster(2);
        let replies = cluster
            .dispatch(|_| LocalWork::SgdLocal { h: 20, t_offset: 0 })
            .unwrap();
        // local-SGD reduce: average the w deltas
        let mut w = cluster.w.clone();
        for r in &replies {
            for (wv, dv) in w.iter_mut().zip(&r.dw) {
                *wv += dv / 2.0;
            }
        }
        cluster.set_w(w);
        let ev = cluster.evaluate().unwrap();
        assert!(ev.primal.is_finite());
        assert!(ev.dual.is_nan());
        cluster.shutdown();
    }

    #[test]
    fn reset_reproduces_a_fresh_cluster_bit_for_bit() {
        let (mut cluster, _) = small_cluster(3);
        let run_rounds = |cl: &mut Cluster| {
            for _ in 0..5 {
                let replies = cl.dispatch(|_| LocalWork::DualRound { h: 20 }).unwrap();
                cl.commit(&replies, 1.0 / 3.0).unwrap();
            }
            cl.w.clone()
        };
        let w_first = run_rounds(&mut cluster);
        cluster.reset().unwrap();
        assert!(cluster.w.iter().all(|&v| v == 0.0));
        assert_eq!(cluster.stats.rounds, 0);
        let w_again = run_rounds(&mut cluster);
        assert_eq!(w_first, w_again, "warm-started run diverged from fresh run");
        cluster.shutdown();
    }

    #[test]
    fn counted_transport_measures_bytes() {
        let data = cov_like(40, 5, 0.1, 2);
        let part = Partition::new(PartitionStrategy::Contiguous, 40, 2, 0);
        let mut cluster = Cluster::spawn(ClusterSpec {
            source: DataSource::Memory(&data),
            partition: &part,
            loss: LossKind::Hinge,
            lambda: 0.1,
            regularizer: RegularizerKind::L2,
            solver: SolverKind::Sdca,
            backend: Backend::Native,
            artifacts_dir: "artifacts",
            net: NetworkModel::free(),
            stragglers: StragglerModel::none(),
            seed: 3,
            transport: TransportKind::Counted,
            threads: 1,
        })
        .unwrap();
        assert_eq!(cluster.transport_name(), "counted");
        assert_eq!(cluster.stats.bytes_measured, 0);
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 10 }).unwrap();
        cluster.commit(&replies, 0.5).unwrap();
        let after_round = cluster.stats.bytes_measured;
        assert!(after_round > 0, "counted transport measured nothing");
        // eval traffic is instrumentation: it must not move algorithm bytes
        cluster.evaluate().unwrap();
        assert_eq!(cluster.stats.bytes_measured, after_round);
        let r2 = cluster.dispatch(|_| LocalWork::DualRound { h: 10 }).unwrap();
        cluster.commit(&r2, 0.5).unwrap();
        assert!(cluster.stats.bytes_measured > after_round);
        let ledger = cluster.ledger().expect("counted has a ledger");
        // at a round boundary the two byte-exact views agree
        assert_eq!(cluster.stats.bytes_measured, ledger.algorithm_bytes());
        assert!(ledger.bytes(crate::transport::MessageKind::EvalRequest) > 0);
        assert!(ledger.total_bytes() > ledger.algorithm_bytes());
        cluster.shutdown();
    }

    #[test]
    fn l1_commit_prox_maps_the_broadcast_w() {
        // Under the smoothed-L1 regularizer the leader's commit must run
        // the prox map: every |v_j| <= kappa lands on an exact zero in w,
        // and the certificate stays a valid (nonnegative) gap.
        let data = cov_like(60, 8, 0.1, 9);
        let part = Partition::new(PartitionStrategy::Contiguous, 60, 2, 0);
        let mut cluster = Cluster::spawn(ClusterSpec {
            source: DataSource::Memory(&data),
            partition: &part,
            loss: LossKind::Squared,
            lambda: 0.2,
            regularizer: RegularizerKind::L1 { epsilon: 0.5 },
            solver: SolverKind::Sdca,
            backend: Backend::Native,
            artifacts_dir: "artifacts",
            net: NetworkModel::free(),
            stragglers: StragglerModel::none(),
            seed: 10,
            transport: TransportKind::InProc,
            threads: 1,
        })
        .unwrap();
        assert_eq!(cluster.regularizer(), RegularizerKind::L1 { epsilon: 0.5 });
        assert!((cluster.lambda_eff() - 0.1).abs() < 1e-15); // lambda * eps
        for _ in 0..4 {
            let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 40 }).unwrap();
            cluster.commit(&replies, 0.5).unwrap();
        }
        let kappa = 1.0 / 0.5; // 1/epsilon
        for (j, (&wj, &vj)) in cluster.w.iter().zip(&cluster.v).enumerate() {
            let expect = crate::regularizers::soft_threshold(vj, kappa);
            assert_eq!(wj.to_bits(), expect.to_bits(), "w[{j}] not prox-mapped");
        }
        assert!(cluster.w_nnz() <= 8);
        let ev = cluster.evaluate().unwrap();
        assert!(ev.gap >= -1e-10, "regularized gap {} negative", ev.gap);
        assert!(ev.primal.is_finite() && ev.dual.is_finite());
        cluster.shutdown();
    }

    #[test]
    fn dispatch_gathers_worker_metrics_and_solve_spans() {
        let (mut cluster, _) = small_cluster(3);
        cluster.set_tracing(true);
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 10 }).unwrap();
        cluster.commit(&replies, 1.0 / 3.0).unwrap();
        let obs = cluster.take_round_obs();
        assert_eq!(obs.round, 1);
        assert_eq!(obs.workers.len(), 3);
        for (slot, m) in obs.workers.iter().enumerate() {
            assert_eq!(m.worker, slot);
            assert_eq!(m.round, 1);
            assert_eq!(m.inner_steps, 10);
            assert!(m.solve_wall_s >= 0.0 && m.solve_cpu_s >= 0.0);
        }
        // spans: broadcast + 3 local_solve + reduce + commit
        let count = |p: Phase| obs.spans.iter().filter(|s| s.phase == p).count();
        assert_eq!(count(Phase::Broadcast), 1);
        assert_eq!(count(Phase::LocalSolve), 3);
        assert_eq!(count(Phase::Reduce), 1);
        assert_eq!(count(Phase::Commit), 1);
        assert_eq!(obs.spans.len(), 6);
        // the drain took everything
        let again = cluster.take_round_obs();
        assert!(again.spans.is_empty() && again.workers.is_empty());
        cluster.shutdown();
    }

    #[test]
    fn metrics_flow_is_always_on_and_tracing_is_opt_in() {
        let (mut cluster, _) = small_cluster(2);
        assert!(!cluster.tracing());
        let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 5 }).unwrap();
        cluster.commit(&replies, 0.5).unwrap();
        let obs = cluster.take_round_obs();
        assert_eq!(obs.workers.len(), 2, "metrics blocks flow even with tracing off");
        assert!(obs.spans.is_empty(), "spans recorded while tracing disabled");
        assert!(obs.workers.iter().all(|m| m.reconnects == 0));
        cluster.shutdown();
    }

    #[test]
    fn sim_time_includes_network_cost() {
        let data = cov_like(40, 5, 0.1, 2);
        let part = Partition::new(PartitionStrategy::Contiguous, 40, 2, 0);
        let net = NetworkModel { latency_s: 1.0, bandwidth_bps: f64::INFINITY, bytes_per_scalar: 8 };
        let mut cluster = spec_cluster(&data, &part, net, 3);
        for _ in 0..3 {
            let r = cluster.dispatch(|_| LocalWork::DualRound { h: 1 }).unwrap();
            cluster.commit(&r, 0.5).unwrap();
        }
        assert!(cluster.stats.sim_time_s >= 3.0);
        cluster.shutdown();
    }
}
