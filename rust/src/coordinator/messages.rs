//! Leader <-> worker protocol.
//!
//! The message set mirrors what would cross the network on a real cluster:
//! a round dispatch carrying the shared `w` (one d-vector down per worker),
//! a reply carrying `dw` (one d-vector up per worker), a commit telling the
//! worker how to fold its pending local `dalpha` into its dual block, and
//! evaluation requests for the duality-gap certificate. Dual variables
//! never leave their worker — exactly the paper's communication pattern.

/// What a worker should run locally this round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LocalWork {
    /// CoCoA: H steps of the configured LOCALDUALMETHOD, updates applied
    /// locally as they are computed (Procedure B).
    DualRound { h: usize },
    /// CoCoA+ extension: H LocalSDCA steps on the sigma'-scaled local
    /// subproblem, making beta_K = K "adding" safe (conclusion / [MSJ+15]).
    DualRoundScaled { h: usize, sigma_prime: f64 },
    /// Mini-batch CD [TBRS13/Yan13]: `b` coordinate updates all computed
    /// against the *frozen* round-start `w` (no local application).
    DualBatchFrozen { b: usize },
    /// Solve the block subproblem to optimality (H -> inf / one-shot).
    ExactSolve,
    /// Locally-updating Pegasos epoch (local-SGD); `t_offset` continues the
    /// global 1/(lambda t) schedule across rounds.
    SgdLocal { h: usize, t_offset: u64 },
    /// Frozen-w Pegasos epoch (mini-batch SGD): returns the subgradient
    /// direction sum; the leader applies the step.
    SgdFrozen { h: usize },
}

impl LocalWork {
    /// Does this work produce a dual update that needs a later commit?
    pub fn is_dual(&self) -> bool {
        matches!(
            self,
            LocalWork::DualRound { .. }
                | LocalWork::DualRoundScaled { .. }
                | LocalWork::DualBatchFrozen { .. }
                | LocalWork::ExactSolve
        )
    }
}

/// Leader -> worker.
#[derive(Debug)]
pub enum ToWorker {
    /// Run `work` from the given shared `w`. The worker must have already
    /// committed any previous round (the leader always sends `Commit`
    /// between rounds for dual work). `w` is Arc-shared: in-process the
    /// broadcast costs one refcount per worker instead of K d-vector
    /// copies (perf iteration L3-3); the netsim model still *charges* K
    /// vectors for it, as a real cluster would pay.
    Round { round: u64, w: std::sync::Arc<Vec<f64>>, work: LocalWork },
    /// Fold the pending `dalpha` of the last dual round into the local
    /// block: `alpha_[k] += scale * dalpha_pending` (scale = beta_K / K).
    Commit { scale: f64 },
    /// Evaluate the block partial sums at `w` (and the worker's current
    /// committed `alpha_[k]`). Instrumentation: not counted as algorithm
    /// communication.
    Eval { w: std::sync::Arc<Vec<f64>> },
    /// Checkpoint: report committed state (alpha, rng). Must be sent at a
    /// round boundary (no pending dual update).
    GetState,
    /// Restore: replace committed state wholesale.
    SetState(super::checkpoint::WorkerState),
    /// Warm-start: zero the dual block, drop any pending update, and
    /// reseed the rng to its spawn-time stream — the worker becomes
    /// indistinguishable from a freshly spawned one while keeping its
    /// data block (and any PJRT binding) alive. No ack: channel ordering
    /// guarantees the next `Round` sees the reset state.
    Reset,
    /// Continuous training: extend the worker's block with new rows and
    /// rebake the curvature cache against the grown dataset
    /// (`lambda_n = lambda_eff * n_new` changes for *every* worker, so
    /// this is sent to all K workers even when `block` is empty). The
    /// retained dual variables stay put; new rows start at `alpha = 0`.
    /// Must arrive at a round boundary (no pending dual update). No ack,
    /// like `Reset`: channel ordering makes the next message see the
    /// grown block.
    Append { block: AppendBlock, lambda_n: f64 },
    /// Swap the block's labels in place (block order). Feature rows,
    /// norms and curvatures are label-independent, so this is the cheap
    /// primitive behind one-vs-rest relabeling: callers normally follow
    /// with `Reset`, because retained dual variables are only feasible
    /// for the labels they were trained against. No ack.
    SetLabels { labels: Vec<f64> },
    Shutdown,
}

/// New rows for one worker's block, CSR-style regardless of the block's
/// storage (dense blocks densify each row on arrival). `norms_sq` carries
/// the dataset-cached row norms so an appended block is bit-identical to
/// one built from the grown dataset directly (e.g. after
/// `normalize_rows`, where the cached norm is exactly 1.0 but a
/// recomputed one need not be).
#[derive(Debug, Clone, PartialEq)]
pub struct AppendBlock {
    /// `rows + 1` entries, starting at 0.
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
    pub labels: Vec<f64>,
    pub norms_sq: Vec<f64>,
}

impl AppendBlock {
    /// An append that carries no rows (sent to workers that only need
    /// the new `lambda_n`).
    pub fn empty() -> Self {
        AppendBlock {
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            labels: Vec::new(),
            norms_sq: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.labels.len()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }
}

/// Worker -> leader: result of one round.
#[derive(Debug, Clone)]
pub struct RoundReply {
    pub worker: usize,
    pub round: u64,
    /// The single communicated vector: `A_[k] dalpha` for dual work,
    /// `w_local - w` or a subgradient sum for SGD work.
    pub dw: Vec<f64>,
    /// Thread CPU seconds spent computing (excludes channel waits).
    pub compute_s: f64,
    /// Inner steps actually executed.
    pub steps: u64,
}

/// Worker -> leader: block partial sums for P/D/gap.
#[derive(Debug, Clone, Copy)]
pub struct EvalReply {
    pub worker: usize,
    pub loss_sum: f64,
    pub conj_sum: f64,
    /// Whether conj_sum is meaningful (false for SGD-only workers).
    pub has_dual: bool,
}

/// Worker -> leader: the per-round observability block, sent right after
/// every [`RoundReply`]. Pure instrumentation — it is never folded into
/// the model, never charged as algorithm communication, and dropping it
/// on the floor cannot change a trajectory.
#[derive(Debug, Clone, Copy)]
pub struct WorkerMetrics {
    pub worker: usize,
    pub round: u64,
    /// Wall-clock seconds of the local solve (includes any offload).
    pub solve_wall_s: f64,
    /// Thread CPU seconds of the local solve.
    pub solve_cpu_s: f64,
    /// Inner steps actually executed this round.
    pub inner_steps: u64,
    /// Worker-process peak RSS, via
    /// [`peak_rss_bytes`](crate::telemetry::peak_rss_bytes); 0 where
    /// procfs is missing.
    pub peak_rss_bytes: u64,
    /// Total reconnects this worker performed (net transport; 0 in-proc).
    pub reconnects: u64,
}

/// Worker -> leader envelope. `Clone` so the transport layer's
/// [`Record`](crate::transport::Record) backend can tape replies for
/// deterministic replay.
#[derive(Debug, Clone)]
pub enum ToLeader {
    Round(RoundReply),
    Eval(EvalReply),
    State(super::checkpoint::WorkerState),
    /// A worker hit an unrecoverable error (e.g. PJRT failure).
    Fatal { worker: usize, message: String },
    /// The per-round observability block (always follows a `Round`).
    Metrics(WorkerMetrics),
}
