//! Checkpoint / resume — fault-tolerance for long training runs.
//!
//! The paper's implementation sat on Spark for fault tolerance; a
//! standalone framework needs its own. A checkpoint captures the full
//! optimization state: the leader's `w` and accounting, plus each worker's
//! committed `alpha_[k]` and RNG state, so a restored run continues the
//! exact coordinate stream of the original (bit-identical native-backend
//! trajectories — tested in `integration_coordinator`).
//!
//! Format: versioned text, one record per line — robust, diffable, and
//! independent of any serialization crate (offline build):
//!
//! ```text
//! #cocoa-checkpoint v2
//! meta <k> <n> <d> <round_counter>
//! stats <rounds> <vectors> <bytes_modeled> <bytes_measured> <compute_s> <sim_time_s> <inner_steps>
//! w <d hex-f64 words>
//! worker <id> rng <s0> <s1> <s2> <s3>
//! alpha <id> <n_k hex-f64 words>
//! ```
//!
//! (v1 had a single `bytes` column; v2 splits modeled vs transport-measured
//! bytes and is not backward compatible — old checkpoints are rejected by
//! the header check.)
//!
//! Floats are stored as hex bit patterns: exact round-trip, no precision
//! loss through decimal formatting.

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// One worker's persisted state.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerState {
    pub id: usize,
    pub rng_state: [u64; 4],
    pub alpha: Vec<f64>,
}

/// The full cluster state at a round boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub k: usize,
    pub n: usize,
    pub d: usize,
    pub round_counter: u64,
    pub stats: super::CommStats,
    pub w: Vec<f64>,
    pub workers: Vec<WorkerState>,
}

impl PartialEq for super::CommStats {
    fn eq(&self, other: &Self) -> bool {
        self.rounds == other.rounds
            && self.vectors == other.vectors
            && self.bytes_modeled == other.bytes_modeled
            && self.bytes_measured == other.bytes_measured
            && self.compute_s == other.compute_s
            && self.sim_time_s == other.sim_time_s
            && self.inner_steps == other.inner_steps
    }
}

fn write_f64s(out: &mut String, values: &[f64]) {
    for v in values {
        out.push(' ');
        out.push_str(&format!("{:016x}", v.to_bits()));
    }
}

fn parse_f64s(tokens: &[&str]) -> Result<Vec<f64>> {
    tokens
        .iter()
        .map(|t| {
            u64::from_str_radix(t, 16)
                .map(f64::from_bits)
                .with_context(|| format!("bad f64 word {t:?}"))
        })
        .collect()
}

impl Checkpoint {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut text = String::new();
        text.push_str("#cocoa-checkpoint v2\n");
        text.push_str(&format!(
            "meta {} {} {} {}\n",
            self.k, self.n, self.d, self.round_counter
        ));
        text.push_str(&format!(
            "stats {} {} {} {} {:016x} {:016x} {}\n",
            self.stats.rounds,
            self.stats.vectors,
            self.stats.bytes_modeled,
            self.stats.bytes_measured,
            self.stats.compute_s.to_bits(),
            self.stats.sim_time_s.to_bits(),
            self.stats.inner_steps,
        ));
        text.push_str("w");
        write_f64s(&mut text, &self.w);
        text.push('\n');
        for ws in &self.workers {
            text.push_str(&format!(
                "worker {} rng {:016x} {:016x} {:016x} {:016x}\n",
                ws.id, ws.rng_state[0], ws.rng_state[1], ws.rng_state[2], ws.rng_state[3]
            ));
            text.push_str(&format!("alpha {}", ws.id));
            write_f64s(&mut text, &ws.alpha);
            text.push('\n');
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(text.as_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty checkpoint")?;
        if header != "#cocoa-checkpoint v2" {
            bail!("bad checkpoint header {header:?}");
        }
        let meta: Vec<&str> = lines.next().context("missing meta")?.split(' ').collect();
        if meta.len() != 5 || meta[0] != "meta" {
            bail!("bad meta line");
        }
        let (k, n, d, round_counter) = (
            meta[1].parse()?,
            meta[2].parse()?,
            meta[3].parse()?,
            meta[4].parse()?,
        );
        let st: Vec<&str> = lines.next().context("missing stats")?.split(' ').collect();
        if st.len() != 8 || st[0] != "stats" {
            bail!("bad stats line");
        }
        let stats = super::CommStats {
            rounds: st[1].parse()?,
            vectors: st[2].parse()?,
            bytes_modeled: st[3].parse()?,
            bytes_measured: st[4].parse()?,
            compute_s: f64::from_bits(u64::from_str_radix(st[5], 16)?),
            sim_time_s: f64::from_bits(u64::from_str_radix(st[6], 16)?),
            inner_steps: st[7].parse()?,
        };
        let wline: Vec<&str> = lines.next().context("missing w")?.split(' ').collect();
        if wline[0] != "w" {
            bail!("bad w line");
        }
        let w = parse_f64s(&wline[1..])?;
        if w.len() != d {
            bail!("w length {} != d {d}", w.len());
        }
        let mut workers = Vec::with_capacity(k);
        let mut pending: Option<(usize, [u64; 4])> = None;
        for line in lines {
            let toks: Vec<&str> = line.split(' ').collect();
            match toks.first().copied() {
                Some("worker") => {
                    if toks.len() != 7 || toks[2] != "rng" {
                        bail!("bad worker line");
                    }
                    let id: usize = toks[1].parse()?;
                    let rng = [
                        u64::from_str_radix(toks[3], 16)?,
                        u64::from_str_radix(toks[4], 16)?,
                        u64::from_str_radix(toks[5], 16)?,
                        u64::from_str_radix(toks[6], 16)?,
                    ];
                    pending = Some((id, rng));
                }
                Some("alpha") => {
                    let (id, rng_state) =
                        pending.take().ok_or_else(|| anyhow!("alpha before worker"))?;
                    let alpha_id: usize = toks[1].parse()?;
                    if alpha_id != id {
                        bail!("alpha id {alpha_id} != worker id {id}");
                    }
                    workers.push(WorkerState {
                        id,
                        rng_state,
                        alpha: parse_f64s(&toks[2..])?,
                    });
                }
                Some("") | None => {}
                Some(other) => bail!("unknown record {other:?}"),
            }
        }
        if workers.len() != k {
            bail!("checkpoint has {} workers, meta says {k}", workers.len());
        }
        Ok(Checkpoint { k, n, d, round_counter, stats, w, workers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            k: 2,
            n: 5,
            d: 3,
            round_counter: 7,
            stats: crate::coordinator::CommStats {
                rounds: 7,
                vectors: 28,
                bytes_modeled: 672,
                bytes_measured: 731,
                compute_s: 0.125,
                sim_time_s: 1.5e-3,
                inner_steps: 700,
            },
            w: vec![1.0, -0.5, f64::consts_hack()],
            workers: vec![
                WorkerState { id: 0, rng_state: [1, 2, 3, 4], alpha: vec![0.25, -0.75, 0.0] },
                WorkerState { id: 1, rng_state: [5, 6, 7, 8], alpha: vec![1e-300, 42.0] },
            ],
        }
    }

    trait Hack {
        fn consts_hack() -> f64;
    }
    impl Hack for f64 {
        fn consts_hack() -> f64 {
            std::f64::consts::PI // exercises a non-trivial bit pattern
        }
    }

    #[test]
    fn roundtrip_exact() {
        let cp = sample();
        let path = std::env::temp_dir().join("cocoa_ckpt_test/rt.ckpt");
        cp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn rejects_corruption() {
        let cp = sample();
        let path = std::env::temp_dir().join("cocoa_ckpt_test/bad.ckpt");
        cp.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("#cocoa-checkpoint v2", "#cocoa-checkpoint v9");
        std::fs::write(&path, &text).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn subnormal_and_special_values_survive() {
        let mut cp = sample();
        cp.w = vec![f64::MIN_POSITIVE / 2.0, -0.0, f64::MAX];
        let path = std::env::temp_dir().join("cocoa_ckpt_test/special.ckpt");
        cp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(cp.w[0].to_bits(), back.w[0].to_bits());
        assert_eq!(cp.w[1].to_bits(), back.w[1].to_bits());
        assert_eq!(cp.w[2].to_bits(), back.w[2].to_bits());
    }
}
