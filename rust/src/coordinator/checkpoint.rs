//! Checkpoint / resume — fault-tolerance for long training runs.
//!
//! The paper's implementation sat on Spark for fault tolerance; a
//! standalone framework needs its own. A checkpoint captures the full
//! optimization state: the leader's `w` and accounting, plus each worker's
//! committed `alpha_[k]` and RNG state, so a restored run continues the
//! exact coordinate stream of the original (bit-identical native-backend
//! trajectories — tested in `integration_coordinator`).
//!
//! Format: versioned text, one record per line — robust, diffable, and
//! independent of any serialization crate (offline build):
//!
//! ```text
//! #cocoa-checkpoint v3
//! meta <k> <n> <d> <round_counter>
//! stop <running|max_rounds|gap|subopt>
//! regularizer <kind token, e.g. l2 or l1(ε=0.5)>
//! stats <rounds> <vectors> <bytes_modeled> <bytes_measured> <compute_s> <sim_time_s> <inner_steps>
//! v <d hex-f64 words>
//! worker <id> rng <s0> <s1> <s2> <s3>
//! alpha <id> <n_k hex-f64 words>
//! ```
//!
//! (v1 had a single `bytes` column; v2 split modeled vs transport-measured
//! bytes; v3 renames the shared vector `w` to `v` — it is the *pre-prox*
//! dual combination, from which the primal iterate `w = prox(v)` is
//! recomputed on restore — and records which stop criterion ended the
//! checkpointed run plus the regularizer the state belongs to, so a
//! restore into a cluster with a different regularizer is rejected
//! instead of silently reinterpreting `v` through the wrong prox. No
//! version is backward compatible — old checkpoints are rejected by the
//! header check.)
//!
//! Floats are stored as hex bit patterns: exact round-trip, no precision
//! loss through decimal formatting.

use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::telemetry::StopReason;

/// One worker's persisted state.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerState {
    pub id: usize,
    pub rng_state: [u64; 4],
    pub alpha: Vec<f64>,
}

/// The full cluster state at a round boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub k: usize,
    pub n: usize,
    pub d: usize,
    pub round_counter: u64,
    /// Which stop criterion ended the checkpointed run
    /// ([`StopReason::Running`] when the run never finished a driven
    /// budget — e.g. checkpoints taken mid-sweep).
    pub stop: StopReason,
    /// Display token of the regularizer this state was trained under
    /// (e.g. `l2`, `l1(ε=0.5)`). Restore validates it against the target
    /// cluster — `v` is only meaningful through the matching prox.
    pub regularizer: String,
    pub stats: super::CommStats,
    /// The pre-prox shared vector; the primal iterate is `prox(v)`,
    /// recomputed by the restoring cluster's regularizer (for L2, `v` *is*
    /// `w`).
    pub v: Vec<f64>,
    pub workers: Vec<WorkerState>,
}

impl PartialEq for super::CommStats {
    fn eq(&self, other: &Self) -> bool {
        self.rounds == other.rounds
            && self.vectors == other.vectors
            && self.bytes_modeled == other.bytes_modeled
            && self.bytes_measured == other.bytes_measured
            && self.compute_s == other.compute_s
            && self.sim_time_s == other.sim_time_s
            && self.inner_steps == other.inner_steps
    }
}

fn write_f64s(out: &mut String, values: &[f64]) {
    for v in values {
        out.push(' ');
        out.push_str(&format!("{:016x}", v.to_bits()));
    }
}

fn parse_f64s(tokens: &[&str]) -> Result<Vec<f64>> {
    tokens
        .iter()
        .map(|t| {
            u64::from_str_radix(t, 16)
                .map(f64::from_bits)
                .with_context(|| format!("bad f64 word {t:?}"))
        })
        .collect()
}

impl Checkpoint {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut text = String::new();
        text.push_str("#cocoa-checkpoint v3\n");
        text.push_str(&format!(
            "meta {} {} {} {}\n",
            self.k, self.n, self.d, self.round_counter
        ));
        text.push_str(&format!("stop {}\n", self.stop.as_str()));
        text.push_str(&format!("regularizer {}\n", self.regularizer));
        text.push_str(&format!(
            "stats {} {} {} {} {:016x} {:016x} {}\n",
            self.stats.rounds,
            self.stats.vectors,
            self.stats.bytes_modeled,
            self.stats.bytes_measured,
            self.stats.compute_s.to_bits(),
            self.stats.sim_time_s.to_bits(),
            self.stats.inner_steps,
        ));
        text.push_str("v");
        write_f64s(&mut text, &self.v);
        text.push('\n');
        for ws in &self.workers {
            text.push_str(&format!(
                "worker {} rng {:016x} {:016x} {:016x} {:016x}\n",
                ws.id, ws.rng_state[0], ws.rng_state[1], ws.rng_state[2], ws.rng_state[3]
            ));
            text.push_str(&format!("alpha {}", ws.id));
            write_f64s(&mut text, &ws.alpha);
            text.push('\n');
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(text.as_bytes())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty checkpoint")?;
        if header != "#cocoa-checkpoint v3" {
            bail!("bad checkpoint header {header:?}");
        }
        let meta: Vec<&str> = lines.next().context("missing meta")?.split(' ').collect();
        if meta.len() != 5 || meta[0] != "meta" {
            bail!("bad meta line");
        }
        let (k, n, d, round_counter) = (
            meta[1].parse()?,
            meta[2].parse()?,
            meta[3].parse()?,
            meta[4].parse()?,
        );
        let stop_line: Vec<&str> =
            lines.next().context("missing stop")?.split(' ').collect();
        if stop_line.len() != 2 || stop_line[0] != "stop" {
            bail!("bad stop line");
        }
        let stop = StopReason::from_name(stop_line[1])
            .ok_or_else(|| anyhow!("unknown stop reason {:?}", stop_line[1]))?;
        let reg_line: Vec<&str> =
            lines.next().context("missing regularizer")?.split(' ').collect();
        if reg_line.len() != 2 || reg_line[0] != "regularizer" {
            bail!("bad regularizer line");
        }
        let regularizer = reg_line[1].to_string();
        let st: Vec<&str> = lines.next().context("missing stats")?.split(' ').collect();
        if st.len() != 8 || st[0] != "stats" {
            bail!("bad stats line");
        }
        let stats = super::CommStats {
            rounds: st[1].parse()?,
            vectors: st[2].parse()?,
            bytes_modeled: st[3].parse()?,
            bytes_measured: st[4].parse()?,
            compute_s: f64::from_bits(u64::from_str_radix(st[5], 16)?),
            sim_time_s: f64::from_bits(u64::from_str_radix(st[6], 16)?),
            inner_steps: st[7].parse()?,
        };
        let vline: Vec<&str> = lines.next().context("missing v")?.split(' ').collect();
        if vline[0] != "v" {
            bail!("bad v line");
        }
        let v = parse_f64s(&vline[1..])?;
        if v.len() != d {
            bail!("v length {} != d {d}", v.len());
        }
        let mut workers = Vec::with_capacity(k);
        let mut pending: Option<(usize, [u64; 4])> = None;
        for line in lines {
            let toks: Vec<&str> = line.split(' ').collect();
            match toks.first().copied() {
                Some("worker") => {
                    if toks.len() != 7 || toks[2] != "rng" {
                        bail!("bad worker line");
                    }
                    let id: usize = toks[1].parse()?;
                    let rng = [
                        u64::from_str_radix(toks[3], 16)?,
                        u64::from_str_radix(toks[4], 16)?,
                        u64::from_str_radix(toks[5], 16)?,
                        u64::from_str_radix(toks[6], 16)?,
                    ];
                    pending = Some((id, rng));
                }
                Some("alpha") => {
                    let (id, rng_state) =
                        pending.take().ok_or_else(|| anyhow!("alpha before worker"))?;
                    let alpha_id: usize = toks[1].parse()?;
                    if alpha_id != id {
                        bail!("alpha id {alpha_id} != worker id {id}");
                    }
                    workers.push(WorkerState {
                        id,
                        rng_state,
                        alpha: parse_f64s(&toks[2..])?,
                    });
                }
                Some("") | None => {}
                Some(other) => bail!("unknown record {other:?}"),
            }
        }
        if workers.len() != k {
            bail!("checkpoint has {} workers, meta says {k}", workers.len());
        }
        Ok(Checkpoint { k, n, d, round_counter, stop, regularizer, stats, v, workers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            k: 2,
            n: 5,
            d: 3,
            round_counter: 7,
            stop: StopReason::Gap,
            regularizer: "l1(ε=0.5)".to_string(),
            stats: crate::coordinator::CommStats {
                rounds: 7,
                vectors: 28,
                bytes_modeled: 672,
                bytes_measured: 731,
                compute_s: 0.125,
                sim_time_s: 1.5e-3,
                inner_steps: 700,
            },
            v: vec![1.0, -0.5, f64::consts_hack()],
            workers: vec![
                WorkerState { id: 0, rng_state: [1, 2, 3, 4], alpha: vec![0.25, -0.75, 0.0] },
                WorkerState { id: 1, rng_state: [5, 6, 7, 8], alpha: vec![1e-300, 42.0] },
            ],
        }
    }

    trait Hack {
        fn consts_hack() -> f64;
    }
    impl Hack for f64 {
        fn consts_hack() -> f64 {
            std::f64::consts::PI // exercises a non-trivial bit pattern
        }
    }

    #[test]
    fn roundtrip_exact() {
        let cp = sample();
        let path = std::env::temp_dir().join("cocoa_ckpt_test/rt.ckpt");
        cp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(cp, back);
    }

    #[test]
    fn rejects_corruption() {
        let cp = sample();
        let path = std::env::temp_dir().join("cocoa_ckpt_test/bad.ckpt");
        cp.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("#cocoa-checkpoint v3", "#cocoa-checkpoint v9");
        std::fs::write(&path, &text).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // an unknown stop token is rejected, not silently defaulted
        let cp = sample();
        cp.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("stop gap", "stop because");
        std::fs::write(&path, &text).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // a missing regularizer record is rejected too
        let cp = sample();
        cp.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("regularizer l1(ε=0.5)\n", "");
        std::fs::write(&path, &text).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn subnormal_and_special_values_survive() {
        let mut cp = sample();
        cp.v = vec![f64::MIN_POSITIVE / 2.0, -0.0, f64::MAX];
        let path = std::env::temp_dir().join("cocoa_ckpt_test/special.ckpt");
        cp.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(cp.v[0].to_bits(), back.v[0].to_bits());
        assert_eq!(cp.v[1].to_bits(), back.v[1].to_bits());
        assert_eq!(cp.v[2].to_bits(), back.v[2].to_bits());
    }

    #[test]
    fn stop_reason_round_trips_through_the_file() {
        for stop in [
            StopReason::Running,
            StopReason::MaxRounds,
            StopReason::Gap,
            StopReason::Subopt,
            StopReason::SimTime,
            StopReason::Bytes,
        ] {
            let mut cp = sample();
            cp.stop = stop;
            let path = std::env::temp_dir().join("cocoa_ckpt_test/stop.ckpt");
            cp.save(&path).unwrap();
            assert_eq!(Checkpoint::load(&path).unwrap().stop, stop);
        }
    }
}
