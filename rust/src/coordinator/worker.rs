//! Worker thread: owns one coordinate block (data + dual variables) and
//! executes whatever [`LocalWork`] the leader dispatches.
//!
//! The dual variables `alpha_[k]` never leave this thread — the paper's
//! communication pattern. Updates are staged: a dual round computes a
//! pending `dalpha`, the leader's `Commit { scale }` folds it in with the
//! `beta_K / K` scaling of Algorithm 1, keeping worker state exactly
//! consistent with the leader's `w` at all times.

use std::sync::mpsc::{Receiver, Sender};

use super::checkpoint::WorkerState as CheckpointState;
use super::messages::{EvalReply, LocalWork, RoundReply, ToLeader, ToWorker};
use crate::data::Features;
use crate::kernels;
use crate::loss::Loss;
use crate::objective;
use crate::solvers::{Block, ExactBlockSolver, LocalDualMethod, LocalSdca, PegasosEpoch, Sampling};
use crate::telemetry::thread_cpu_time_s;
use crate::util::Rng;

pub struct WorkerConfig {
    pub id: usize,
    pub block: Block,
    pub loss: Box<dyn Loss>,
    pub solver: Box<dyn LocalDualMethod>,
    pub lambda: f64,
    pub seed: u64,
}

pub fn run_worker(cfg: WorkerConfig, rx: Receiver<ToWorker>, tx: Sender<ToLeader>) {
    let WorkerConfig { id, block, loss, solver, lambda, seed } = cfg;
    let n_k = block.n_k();
    let mut alpha = vec![0.0f64; n_k];
    let mut pending: Option<Vec<f64>> = None;
    // alpha stays a valid dual point (D(0) = 0) until SGD work runs —
    // primal-only methods have no meaningful dual value to report.
    let mut did_sgd = false;
    let mut rng = Rng::seed_from_u64(seed);

    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Shutdown => break,
            ToWorker::Reset => {
                alpha.iter_mut().for_each(|a| *a = 0.0);
                pending = None;
                did_sgd = false;
                rng = Rng::seed_from_u64(seed);
            }
            ToWorker::Commit { scale } => {
                if let Some(d) = pending.take() {
                    for (a, da) in alpha.iter_mut().zip(&d) {
                        *a += scale * da;
                    }
                }
            }
            ToWorker::GetState => {
                if pending.is_some() {
                    let _ = tx.send(ToLeader::Fatal {
                        worker: id,
                        message: "checkpoint requested with uncommitted update".into(),
                    });
                    break;
                }
                let _ = tx.send(ToLeader::State(CheckpointState {
                    id,
                    rng_state: rng.state(),
                    alpha: alpha.clone(),
                }));
            }
            ToWorker::SetState(state) => {
                if state.alpha.len() != n_k {
                    let _ = tx.send(ToLeader::Fatal {
                        worker: id,
                        message: format!(
                            "restore alpha length {} != block size {n_k}",
                            state.alpha.len()
                        ),
                    });
                    break;
                }
                alpha = state.alpha;
                rng = Rng::from_state(state.rng_state);
                pending = None;
            }
            ToWorker::Eval { w } => {
                let loss_sum = objective::block_loss_sum(&block.data, &w, loss.as_ref());
                let conj_sum = objective::block_conj_sum(&block.data, &alpha, loss.as_ref());
                let _ = tx.send(ToLeader::Eval(EvalReply {
                    worker: id,
                    loss_sum,
                    conj_sum,
                    has_dual: !did_sgd,
                }));
            }
            ToWorker::Round { round, w, work } => {
                if pending.is_some() {
                    let _ = tx.send(ToLeader::Fatal {
                        worker: id,
                        message: "round dispatched with uncommitted dual update".into(),
                    });
                    break;
                }
                let t0 = thread_cpu_time_s();
                let (dw, steps, offloaded, dalpha) = match work {
                    LocalWork::DualRound { h } => {
                        let up = solver.local_update(
                            &block, loss.as_ref(), &alpha, &w, h, &mut rng,
                        );
                        (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
                    }
                    LocalWork::DualRoundScaled { h, sigma_prime } => {
                        let scaled =
                            LocalSdca::with_curvature_scale(Sampling::WithReplacement, sigma_prime);
                        let up = scaled.local_update(
                            &block, loss.as_ref(), &alpha, &w, h, &mut rng,
                        );
                        (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
                    }
                    LocalWork::ExactSolve => {
                        let exact = ExactBlockSolver::default();
                        let up = exact.local_update(
                            &block, loss.as_ref(), &alpha, &w, n_k, &mut rng,
                        );
                        (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
                    }
                    LocalWork::DualBatchFrozen { b } => {
                        let b = b.min(n_k);
                        // distinct coordinates, all judged against frozen w
                        let picks = rng.sample_distinct(n_k, b);
                        let mut dalpha = vec![0.0; n_k];
                        let mut dw = vec![0.0; block.d()];
                        let inv = 1.0 / block.lambda_n;
                        // monomorphized like the LocalSdca inner loop: one
                        // row_view per pick, fused kernels, cached
                        // curvature — same arithmetic, same bits
                        assert_eq!(w.len(), block.d());
                        match &block.data.features {
                            Features::Sparse(m) => {
                                for &i in picks.iter() {
                                    let (idx, val) = m.row_view(i);
                                    // SAFETY: CSR indices < cols ==
                                    // w.len() == dw.len() (asserted above)
                                    let q = unsafe {
                                        kernels::sparse_dot_unchecked(idx, val, &w)
                                    };
                                    let delta = loss.coord_delta(
                                        q,
                                        block.data.labels[i],
                                        alpha[i],
                                        block.curvature(i),
                                    );
                                    if delta != 0.0 {
                                        dalpha[i] = delta;
                                        // SAFETY: as above.
                                        unsafe {
                                            kernels::sparse_axpy_unchecked(
                                                idx,
                                                val,
                                                delta * inv,
                                                &mut dw,
                                            )
                                        };
                                    }
                                }
                            }
                            Features::Dense(m) => {
                                for &i in picks.iter() {
                                    let row = m.row(i);
                                    let q = kernels::dense_dot(row, &w);
                                    let delta = loss.coord_delta(
                                        q,
                                        block.data.labels[i],
                                        alpha[i],
                                        block.curvature(i),
                                    );
                                    if delta != 0.0 {
                                        dalpha[i] = delta;
                                        kernels::dense_axpy(delta * inv, row, &mut dw);
                                    }
                                }
                            }
                        }
                        (dw, b as u64, 0.0, Some(dalpha))
                    }
                    LocalWork::SgdLocal { h, t_offset } => {
                        let epoch = PegasosEpoch { locally_updating: true, lambda };
                        let out = epoch.run(&block, loss.as_ref(), &w, h, t_offset, &mut rng);
                        (out.dw, out.steps, 0.0, None)
                    }
                    LocalWork::SgdFrozen { h } => {
                        let epoch = PegasosEpoch { locally_updating: false, lambda };
                        let out = epoch.run(&block, loss.as_ref(), &w, h, 0, &mut rng);
                        (out.dw, out.steps, 0.0, None)
                    }
                };
                let compute_s = (thread_cpu_time_s() - t0) + offloaded;
                if let Some(d) = dalpha {
                    pending = Some(d);
                } else {
                    did_sgd = true;
                }
                let _ = tx.send(ToLeader::Round(RoundReply {
                    worker: id,
                    round,
                    dw,
                    compute_s,
                    steps,
                }));
            }
        }
    }
}
