//! Worker logic: owns one coordinate block (data + dual variables) and
//! executes whatever [`LocalWork`] the leader dispatches.
//!
//! The dual variables `alpha_[k]` never leave the worker — the paper's
//! communication pattern. Updates are staged: a dual round computes a
//! pending `dalpha`, the leader's `Commit { scale }` folds it in with the
//! `beta_K / K` scaling of Algorithm 1, keeping worker state exactly
//! consistent with the leader's `w` at all times.
//!
//! The message-handling state machine lives in [`WorkerCore`], shared by
//! the two deployment shapes: [`run_worker`] drives it over in-process
//! channels (one thread per worker), and the net worker loop
//! (`transport::net`) drives the *same* core over socket frames — so a
//! multi-process run executes bit-identical arithmetic to an in-process
//! one by construction.

use std::sync::mpsc::{Receiver, Sender};

use super::checkpoint::WorkerState as CheckpointState;
use super::messages::{EvalReply, LocalWork, RoundReply, ToLeader, ToWorker, WorkerMetrics};
use crate::data::Features;
use crate::kernels;
use crate::loss::Loss;
use crate::objective;
use crate::solvers::{Block, ExactBlockSolver, LocalDualMethod, LocalSdca, PegasosEpoch, Sampling};
use crate::telemetry::{peak_rss_bytes, thread_cpu_time_s};
use crate::util::Rng;

pub struct WorkerConfig {
    pub id: usize,
    pub block: Block,
    pub loss: Box<dyn Loss>,
    pub solver: Box<dyn LocalDualMethod>,
    pub lambda: f64,
    pub seed: u64,
    /// Intra-worker shard count T for the local solves (>= 1). `solver`
    /// was already built with it; kept here so work items that construct
    /// solvers on the fly (e.g. `DualRoundScaled`) shard identically.
    pub threads: usize,
}

/// What the transport loop driving a [`WorkerCore`] should do after one
/// message.
pub(crate) enum CoreStep {
    /// Nothing to send; keep serving.
    Continue,
    /// Send this reply and keep serving.
    Reply(ToLeader),
    /// Send the round reply, then its observability block, in that order.
    /// Two messages so the algorithm payload and the instrumentation stay
    /// separate frames on the wire (distinct [`MessageKind`]s in the
    /// ledger), and a leader that predates metrics could simply drop the
    /// second.
    ///
    /// [`MessageKind`]: crate::transport::MessageKind
    ReplyWithMetrics(ToLeader, ToLeader),
    /// Send this [`ToLeader::Fatal`] and stop serving — worker state is
    /// no longer trustworthy.
    Fatal(ToLeader),
    /// Clean shutdown requested by the leader.
    Shutdown,
}

/// One worker's full message-handling state machine.
pub(crate) struct WorkerCore {
    id: usize,
    n_k: usize,
    block: Block,
    loss: Box<dyn Loss>,
    solver: Box<dyn LocalDualMethod>,
    lambda: f64,
    seed: u64,
    threads: usize,
    alpha: Vec<f64>,
    pending: Option<Vec<f64>>,
    // alpha stays a valid dual point (D(0) = 0) until SGD work runs —
    // primal-only methods have no meaningful dual value to report.
    did_sgd: bool,
    rng: Rng,
    /// Lifetime reconnect count, reported in every metrics block. Always 0
    /// in-process; the net worker loop bumps it across re-handshakes via
    /// [`WorkerCore::set_reconnects`].
    reconnects: u64,
}

impl WorkerCore {
    pub(crate) fn new(cfg: WorkerConfig) -> Self {
        let WorkerConfig { id, block, loss, solver, lambda, seed, threads } = cfg;
        let n_k = block.n_k();
        WorkerCore {
            id,
            n_k,
            block,
            loss,
            solver,
            lambda,
            seed,
            threads,
            alpha: vec![0.0f64; n_k],
            pending: None,
            did_sgd: false,
            rng: Rng::seed_from_u64(seed),
            reconnects: 0,
        }
    }

    /// Carry a running reconnect total into a freshly constructed core
    /// (the net worker rebuilds its core on every successful reconnect).
    pub(crate) fn set_reconnects(&mut self, reconnects: u64) {
        self.reconnects = reconnects;
    }

    pub(crate) fn handle(&mut self, msg: ToWorker) -> CoreStep {
        match msg {
            ToWorker::Shutdown => CoreStep::Shutdown,
            ToWorker::Reset => {
                self.alpha.iter_mut().for_each(|a| *a = 0.0);
                self.pending = None;
                self.did_sgd = false;
                self.rng = Rng::seed_from_u64(self.seed);
                CoreStep::Continue
            }
            ToWorker::Commit { scale } => {
                if let Some(d) = self.pending.take() {
                    for (a, da) in self.alpha.iter_mut().zip(&d) {
                        *a += scale * da;
                    }
                }
                CoreStep::Continue
            }
            ToWorker::GetState => {
                if self.pending.is_some() {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: "checkpoint requested with uncommitted update".into(),
                    });
                }
                CoreStep::Reply(ToLeader::State(CheckpointState {
                    id: self.id,
                    rng_state: self.rng.state(),
                    alpha: self.alpha.clone(),
                }))
            }
            ToWorker::SetState(state) => {
                if state.alpha.len() != self.n_k {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: format!(
                            "restore alpha length {} != block size {}",
                            state.alpha.len(),
                            self.n_k
                        ),
                    });
                }
                self.alpha = state.alpha;
                self.rng = Rng::from_state(state.rng_state);
                self.pending = None;
                CoreStep::Continue
            }
            ToWorker::Append { block, lambda_n } => {
                if self.pending.is_some() {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: "append dispatched with uncommitted dual update".into(),
                    });
                }
                if block.indptr.len() != block.rows() + 1
                    || block.labels.len() != block.norms_sq.len()
                {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: "append block arrays disagree".into(),
                    });
                }
                if let Err(message) = self.block.append(
                    &block.indptr,
                    &block.indices,
                    &block.values,
                    &block.labels,
                    &block.norms_sq,
                    lambda_n,
                ) {
                    return CoreStep::Fatal(ToLeader::Fatal { worker: self.id, message });
                }
                // retained duals stay put; new rows enter at alpha = 0,
                // which is always dual-feasible (D contribution 0)
                self.alpha.resize(self.block.n_k(), 0.0);
                self.n_k = self.block.n_k();
                CoreStep::Continue
            }
            ToWorker::SetLabels { labels } => {
                if labels.len() != self.n_k {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: format!(
                            "set_labels length {} != block size {}",
                            labels.len(),
                            self.n_k
                        ),
                    });
                }
                // norms and curvatures are label-independent; nothing to
                // rebake. Retained alpha may be infeasible for the new
                // labels — the leader's contract is to Reset after.
                self.block.data.labels = labels;
                CoreStep::Continue
            }
            ToWorker::Eval { w } => {
                let loss_sum = objective::block_loss_sum(&self.block.data, &w, self.loss.as_ref());
                let conj_sum =
                    objective::block_conj_sum(&self.block.data, &self.alpha, self.loss.as_ref());
                CoreStep::Reply(ToLeader::Eval(EvalReply {
                    worker: self.id,
                    loss_sum,
                    conj_sum,
                    has_dual: !self.did_sgd,
                }))
            }
            ToWorker::Round { round, w, work } => {
                if self.pending.is_some() {
                    return CoreStep::Fatal(ToLeader::Fatal {
                        worker: self.id,
                        message: "round dispatched with uncommitted dual update".into(),
                    });
                }
                let wall0 = std::time::Instant::now();
                let t0 = thread_cpu_time_s();
                let (dw, steps, offloaded, dalpha) = self.run_round(&w, work);
                let compute_s = (thread_cpu_time_s() - t0) + offloaded;
                let solve_wall_s = wall0.elapsed().as_secs_f64();
                if let Some(d) = dalpha {
                    self.pending = Some(d);
                } else {
                    self.did_sgd = true;
                }
                // Every round reply is chased by its observability block:
                // the protocol is identical whether or not anyone listens,
                // so instrumentation can never perturb a trajectory.
                CoreStep::ReplyWithMetrics(
                    ToLeader::Round(RoundReply { worker: self.id, round, dw, compute_s, steps }),
                    ToLeader::Metrics(WorkerMetrics {
                        worker: self.id,
                        round,
                        solve_wall_s,
                        solve_cpu_s: compute_s,
                        inner_steps: steps,
                        peak_rss_bytes: peak_rss_bytes().unwrap_or(0),
                        reconnects: self.reconnects,
                    }),
                )
            }
        }
    }

    #[allow(clippy::type_complexity)]
    fn run_round(&mut self, w: &[f64], work: LocalWork) -> (Vec<f64>, u64, f64, Option<Vec<f64>>) {
        let Self { n_k, block, loss, solver, lambda, alpha, rng, threads, .. } = self;
        let n_k = *n_k;
        match work {
            LocalWork::DualRound { h } => {
                let up = solver.local_update(block, loss.as_ref(), alpha, w, h, rng);
                (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
            }
            LocalWork::DualRoundScaled { h, sigma_prime } => {
                let scaled = LocalSdca::with_curvature_scale(Sampling::WithReplacement, sigma_prime)
                    .with_threads(*threads);
                let up = scaled.local_update(block, loss.as_ref(), alpha, w, h, rng);
                (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
            }
            LocalWork::ExactSolve => {
                let exact = ExactBlockSolver::default();
                let up = exact.local_update(block, loss.as_ref(), alpha, w, n_k, rng);
                (up.dw, up.steps, up.offloaded_s, Some(up.dalpha))
            }
            LocalWork::DualBatchFrozen { b } => {
                let b = b.min(n_k);
                // distinct coordinates, all judged against frozen w
                let picks = rng.sample_distinct(n_k, b);
                let mut dalpha = vec![0.0; n_k];
                let mut dw = vec![0.0; block.d()];
                let inv = 1.0 / block.lambda_n;
                // monomorphized like the LocalSdca inner loop: one
                // row_view per pick, fused kernels, cached
                // curvature — same arithmetic, same bits
                assert_eq!(w.len(), block.d());
                match &block.data.features {
                    Features::Sparse(m) => {
                        for &i in picks.iter() {
                            let (idx, val) = m.row_view(i);
                            // SAFETY: CSR indices < cols ==
                            // w.len() == dw.len() (asserted above)
                            let q = unsafe { kernels::sparse_dot_unchecked(idx, val, w) };
                            let delta = loss.coord_delta(
                                q,
                                block.data.labels[i],
                                alpha[i],
                                block.curvature(i),
                            );
                            if delta != 0.0 {
                                dalpha[i] = delta;
                                // SAFETY: as above.
                                unsafe {
                                    kernels::sparse_axpy_unchecked(idx, val, delta * inv, &mut dw)
                                };
                            }
                        }
                    }
                    Features::Dense(m) => {
                        for &i in picks.iter() {
                            let row = m.row(i);
                            let q = kernels::dense_dot(row, w);
                            let delta = loss.coord_delta(
                                q,
                                block.data.labels[i],
                                alpha[i],
                                block.curvature(i),
                            );
                            if delta != 0.0 {
                                dalpha[i] = delta;
                                kernels::dense_axpy(delta * inv, row, &mut dw);
                            }
                        }
                    }
                }
                (dw, b as u64, 0.0, Some(dalpha))
            }
            LocalWork::SgdLocal { h, t_offset } => {
                let epoch = PegasosEpoch { locally_updating: true, lambda: *lambda };
                let out = epoch.run(block, loss.as_ref(), w, h, t_offset, rng);
                (out.dw, out.steps, 0.0, None)
            }
            LocalWork::SgdFrozen { h } => {
                let epoch = PegasosEpoch { locally_updating: false, lambda: *lambda };
                let out = epoch.run(block, loss.as_ref(), w, h, 0, rng);
                (out.dw, out.steps, 0.0, None)
            }
        }
    }
}

/// Drive a [`WorkerCore`] over in-process channels (one thread per
/// worker, the `InProc` deployment shape).
pub fn run_worker(cfg: WorkerConfig, rx: Receiver<ToWorker>, tx: Sender<ToLeader>) {
    let mut core = WorkerCore::new(cfg);
    while let Ok(msg) = rx.recv() {
        match core.handle(msg) {
            CoreStep::Continue => {}
            CoreStep::Reply(reply) => {
                let _ = tx.send(reply);
            }
            CoreStep::ReplyWithMetrics(reply, metrics) => {
                let _ = tx.send(reply);
                let _ = tx.send(metrics);
            }
            CoreStep::Fatal(reply) => {
                let _ = tx.send(reply);
                break;
            }
            CoreStep::Shutdown => break,
        }
    }
}
