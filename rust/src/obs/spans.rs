//! [`SpanSink`]: an observer streaming round-phase spans as JSONL, plus
//! the structural validator CI and the tests run over the output.
//!
//! One JSON object per line, flushed per line so a killed process loses
//! at most the line being written:
//!
//! ```json
//! {"round": 3, "phase": "local_solve", "slot": 1, "wall_s": 0.0021, "cpu_s": 0.0019}
//! ```
//!
//! Fields: `round` (u64), `phase` (one of `broadcast`, `local_solve`,
//! `reduce`, `commit`, `evaluate`), `slot` (worker index, or `null` for
//! leader-side phases), `wall_s` / `cpu_s` (finite nonnegative seconds).
//! [`validate_span_jsonl`] enforces exactly that, in the style of the
//! perf `schema.rs` gate (it reuses the same parser).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::{Phase, RoundObs};
use crate::driver::{Observer, RoundEvent, RunMeta};
use crate::error::{Error, Result};
use crate::perf::schema::{parse, Json, SchemaError};
use crate::telemetry::json_f64;

/// Streams every span of every round to `out` as flush-per-line JSONL.
pub struct SpanSink<W: Write> {
    out: W,
}

impl SpanSink<BufWriter<File>> {
    /// Create (truncate) a JSONL file, creating parent directories.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(io_err)?;
            }
        }
        let file = File::create(path).map_err(io_err)?;
        Ok(SpanSink { out: BufWriter::new(file) })
    }
}

impl<W: Write> SpanSink<W> {
    /// Stream into any writer (tests use a `Vec<u8>`).
    pub fn new(out: W) -> Self {
        SpanSink { out }
    }

    /// The writer, for tests inspecting what was streamed.
    pub fn into_inner(self) -> W {
        self.out
    }
}

fn io_err(e: std::io::Error) -> Error {
    Error::Runtime { message: format!("span sink io: {e}") }
}

impl<W: Write> Observer for SpanSink<W> {
    fn on_event(&mut self, _meta: &RunMeta, _event: &RoundEvent) -> Result<()> {
        Ok(())
    }

    fn on_round_obs(&mut self, _meta: &RunMeta, obs: &RoundObs) -> Result<()> {
        for span in &obs.spans {
            let slot = match span.slot {
                Some(s) => s.to_string(),
                None => "null".to_string(),
            };
            writeln!(
                self.out,
                "{{\"round\": {}, \"phase\": \"{}\", \"slot\": {}, \"wall_s\": {}, \"cpu_s\": {}}}",
                span.round,
                span.phase.as_str(),
                slot,
                json_f64(span.wall_s),
                json_f64(span.cpu_s),
            )
            .map_err(io_err)?;
            self.out.flush().map_err(io_err)?;
        }
        Ok(())
    }
}

fn line_err<T>(line_no: usize, message: impl Into<String>) -> std::result::Result<T, SchemaError> {
    Err(SchemaError { message: format!("span jsonl line {line_no}: {}", message.into()) })
}

/// Structurally validate span JSONL: every non-empty line is an object
/// with exactly the documented fields, a known phase name, and finite
/// nonnegative times. Returns the number of span lines.
pub fn validate_span_jsonl(text: &str) -> std::result::Result<usize, SchemaError> {
    let mut count = 0;
    for (i, line) in text.lines().enumerate() {
        let line_no = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        let doc = parse(line)
            .map_err(|e| SchemaError { message: format!("span jsonl line {line_no}: {e}") })?;
        let fields = match &doc {
            Json::Obj(fields) => fields,
            _ => return line_err(line_no, "not a JSON object"),
        };
        if fields.len() != 5 {
            return line_err(line_no, format!("expected 5 fields, found {}", fields.len()));
        }
        match doc.get("round").and_then(Json::as_f64) {
            Some(r) if r.is_finite() && r >= 0.0 && r.fract() == 0.0 => {}
            _ => return line_err(line_no, "\"round\" must be a nonnegative integer"),
        }
        match doc.get("phase").and_then(Json::as_str) {
            Some(name) if Phase::from_str(name).is_some() => {}
            Some(name) => return line_err(line_no, format!("unknown phase {name:?}")),
            None => return line_err(line_no, "missing string field \"phase\""),
        }
        match doc.get("slot") {
            Some(Json::Null) => {}
            Some(Json::Num(s)) if s.is_finite() && *s >= 0.0 && s.fract() == 0.0 => {}
            _ => return line_err(line_no, "\"slot\" must be null or a nonnegative integer"),
        }
        for key in ["wall_s", "cpu_s"] {
            match doc.get(key).and_then(Json::as_f64) {
                Some(v) if v.is_finite() && v >= 0.0 => {}
                _ => {
                    return line_err(line_no, format!("{key:?} must be finite and nonnegative"))
                }
            }
        }
        count += 1;
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;

    fn meta() -> RunMeta {
        RunMeta {
            algorithm: "cocoa".into(),
            dataset: "t".into(),
            k: 2,
            h: 5,
            beta: 1.0,
            lambda: 0.1,
        }
    }

    #[test]
    fn sink_streams_validating_jsonl() {
        let mut sink = SpanSink::new(Vec::new());
        let obs = RoundObs {
            round: 1,
            spans: vec![
                Span { round: 1, phase: Phase::Broadcast, slot: None, wall_s: 0.01, cpu_s: 0.005 },
                Span {
                    round: 1,
                    phase: Phase::LocalSolve,
                    slot: Some(0),
                    wall_s: 0.04,
                    cpu_s: 0.039,
                },
                Span { round: 1, phase: Phase::Commit, slot: None, wall_s: 0.001, cpu_s: 0.001 },
            ],
            ..RoundObs::default()
        };
        sink.on_round_obs(&meta(), &obs).unwrap();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert_eq!(validate_span_jsonl(&text).unwrap(), 3);
        assert!(text.contains("\"phase\": \"local_solve\", \"slot\": 0"));
        assert!(text.contains("\"slot\": null"));
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert_eq!(validate_span_jsonl("").unwrap(), 0);
        let good =
            r#"{"round": 1, "phase": "reduce", "slot": null, "wall_s": 0.1, "cpu_s": 0.0}"#;
        assert_eq!(validate_span_jsonl(good).unwrap(), 1);
        for (bad, needle) in [
            ("not json", "line 1"),
            (r#"{"round": 1}"#, "expected 5 fields"),
            (
                r#"{"round": 1, "phase": "warp", "slot": null, "wall_s": 0.1, "cpu_s": 0.0}"#,
                "unknown phase",
            ),
            (
                r#"{"round": -1, "phase": "reduce", "slot": null, "wall_s": 0.1, "cpu_s": 0.0}"#,
                "round",
            ),
            (
                r#"{"round": 1, "phase": "reduce", "slot": 1.5, "wall_s": 0.1, "cpu_s": 0.0}"#,
                "slot",
            ),
            (
                r#"{"round": 1, "phase": "reduce", "slot": null, "wall_s": -0.1, "cpu_s": 0.0}"#,
                "wall_s",
            ),
            (
                r#"{"round": 1, "phase": "reduce", "slot": null, "wall_s": 1e999, "cpu_s": 0.0}"#,
                "wall_s",
            ),
        ] {
            let e = validate_span_jsonl(bad).unwrap_err();
            assert!(e.message.contains(needle), "{bad:?} -> {e}");
        }
        // a bad second line reports its line number
        let two = format!("{good}\nnope");
        assert!(validate_span_jsonl(&two).unwrap_err().message.contains("line 2"));
    }
}
