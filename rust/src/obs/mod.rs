//! Span-based observability for the live cluster: round-phase tracing,
//! per-worker metrics, straggler analytics, and export surfaces.
//!
//! The paper's tradeoff (local computation vs. communication rounds) is
//! invisible in totals — a slow round could be the local solve, the
//! reduce barrier, one straggling worker, or the prox/eval step. This
//! module decomposes every driver round into typed [`Phase`] spans and
//! aggregates per-worker solve metrics into leader-side analytics:
//!
//! * [`Phase`] / [`Span`] / [`RoundObs`] — the vocabulary: one span per
//!   phase per round (`broadcast -> local_solve -> reduce -> commit ->
//!   evaluate`), carrying wall seconds, thread CPU seconds, and the
//!   worker slot for per-worker phases.
//! * [`Recorder`] — the seam the coordinator records through. Disabled
//!   (the default) it never samples a clock and never allocates; enabled
//!   it only *observes* — trajectories are bit-identical either way
//!   (asserted by `tests/observability.rs`).
//! * [`LogHistogram`] — hand-rolled log-bucketed latency histograms with
//!   exact merge, behind the per-slot straggler analytics.
//! * [`MetricsHub`] / [`MetricsObserver`] — shared aggregation state and
//!   the [`Observer`](crate::driver::Observer) that feeds it, rendered as
//!   Prometheus text exposition.
//! * [`MetricsServer`] — a minimal HTTP/1.0 responder (over the
//!   `transport/net` socket plumbing) serving `GET /metrics` from a live
//!   leader: `cocoa leader --metrics tcp:127.0.0.1:9100`.
//! * [`SpanSink`] — an observer streaming spans as flush-per-line JSONL
//!   (`cocoa train/leader --trace-out spans.jsonl`), with a structural
//!   validator ([`validate_span_jsonl`]) in the style of the perf
//!   `schema.rs` gate.
//!
//! Per-worker metrics ride the wire as their own
//! [`MessageKind::Metrics`](crate::transport::MessageKind) message —
//! excluded from `algorithm_bytes()`, so the measured-communication
//! axis and the simulated-time axis of the paper's figures are untouched
//! by construction.

pub mod histogram;
pub mod metrics;
pub mod server;
pub mod spans;

pub use histogram::LogHistogram;
pub use metrics::{MetricsHub, MetricsObserver};
pub use server::MetricsServer;
pub use spans::{validate_span_jsonl, SpanSink};

pub use crate::coordinator::WorkerMetrics;

use crate::telemetry::thread_cpu_time_s;
use crate::transport::{Ledger, SocketStats};

/// The phases a CoCoA round decomposes into, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Leader sends `w` + the round's `LocalWork` to all K workers.
    Broadcast,
    /// A worker's local dual solve (one span per slot, from the
    /// worker-reported metrics block).
    LocalSolve,
    /// Leader blocks gathering the K replies (the straggler barrier).
    Reduce,
    /// Fold the deltas into `v`, apply the prox, sync `w`.
    Commit,
    /// Distributed evaluation of P / D / gap (cadence rounds only).
    Evaluate,
}

impl Phase {
    /// All phases, in execution order (stable indices for accumulators).
    pub const ALL: [Phase; 5] = [
        Phase::Broadcast,
        Phase::LocalSolve,
        Phase::Reduce,
        Phase::Commit,
        Phase::Evaluate,
    ];

    /// Dense 0..5 index, aligned with [`Phase::ALL`].
    pub fn index(self) -> usize {
        match self {
            Phase::Broadcast => 0,
            Phase::LocalSolve => 1,
            Phase::Reduce => 2,
            Phase::Commit => 3,
            Phase::Evaluate => 4,
        }
    }

    /// Stable snake_case name (span JSONL, Prometheus labels, BENCH v3).
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Broadcast => "broadcast",
            Phase::LocalSolve => "local_solve",
            Phase::Reduce => "reduce",
            Phase::Commit => "commit",
            Phase::Evaluate => "evaluate",
        }
    }

    /// Inverse of [`Phase::as_str`].
    pub fn from_str(name: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.as_str() == name)
    }
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One timed phase of one round.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub round: u64,
    pub phase: Phase,
    /// Worker slot for per-worker phases ([`Phase::LocalSolve`]); `None`
    /// for leader-side phases.
    pub slot: Option<usize>,
    /// Elapsed wall-clock seconds.
    pub wall_s: f64,
    /// Thread CPU seconds over the same interval
    /// ([`thread_cpu_time_s`]); `wall_s - cpu_s` is time spent blocked.
    pub cpu_s: f64,
}

/// Everything observed about one completed round, handed to
/// [`Observer::on_round_obs`](crate::driver::Observer::on_round_obs).
#[derive(Debug, Clone, Default)]
pub struct RoundObs {
    pub round: u64,
    /// Leader-phase spans plus one synthesized
    /// [`Phase::LocalSolve`] span per worker slot.
    pub spans: Vec<Span>,
    /// The per-worker metrics blocks gathered this round, slot-ordered.
    pub workers: Vec<WorkerMetrics>,
    /// Snapshot of the byte-exact ledger (measuring transports only).
    pub ledger: Option<Ledger>,
    /// Snapshot of raw socket accounting (net transport only).
    pub socket: Option<SocketStats>,
    /// Cumulative recv timeouts observed by the leader.
    pub timeouts: u64,
    /// Cumulative successful `heal()` recoveries.
    pub heals: u64,
    /// Max `peak_rss_bytes` reported by any worker so far (plus the
    /// leader's own, folded in by the caller).
    pub max_worker_rss: u64,
}

/// A wall + thread-CPU clock sample; subtract two to get a span.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTimer {
    wall: std::time::Instant,
    cpu: f64,
}

/// The recording seam the coordinator instruments through.
///
/// Disabled (default) every call is a branch on a bool: no clock is
/// sampled, nothing allocates. Enabled it appends [`Span`]s that the
/// driver drains once per round. Either way it only observes — no
/// message, byte count, or trajectory value depends on it.
#[derive(Debug, Default)]
pub struct Recorder {
    enabled: bool,
    spans: Vec<Span>,
}

impl Recorder {
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Sample the clocks iff enabled.
    pub fn start(&self) -> Option<PhaseTimer> {
        if self.enabled {
            Some(PhaseTimer { wall: std::time::Instant::now(), cpu: thread_cpu_time_s() })
        } else {
            None
        }
    }

    /// Close a [`start`](Recorder::start) sample into a span.
    pub fn finish(&mut self, t: Option<PhaseTimer>, round: u64, phase: Phase) {
        if let Some(t) = t {
            self.spans.push(Span {
                round,
                phase,
                slot: None,
                wall_s: t.wall.elapsed().as_secs_f64(),
                cpu_s: (thread_cpu_time_s() - t.cpu).max(0.0),
            });
        }
    }

    /// Append a pre-built span (worker-side solve spans).
    pub fn push(&mut self, span: Span) {
        if self.enabled {
            self.spans.push(span);
        }
    }

    /// Take every span recorded since the previous drain.
    pub fn drain(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.spans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_names_round_trip_and_indices_are_dense() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
            assert_eq!(Phase::from_str(p.as_str()), Some(*p));
        }
        assert_eq!(Phase::from_str("no_such_phase"), None);
    }

    #[test]
    fn disabled_recorder_samples_nothing_and_drains_empty() {
        let mut r = Recorder::default();
        assert!(!r.enabled());
        assert!(r.start().is_none());
        r.finish(None, 0, Phase::Broadcast);
        r.push(Span { round: 0, phase: Phase::LocalSolve, slot: Some(0), wall_s: 1.0, cpu_s: 1.0 });
        assert!(r.drain().is_empty());
    }

    #[test]
    fn enabled_recorder_captures_spans_per_phase() {
        let mut r = Recorder::default();
        r.set_enabled(true);
        let t = r.start();
        assert!(t.is_some());
        r.finish(t, 3, Phase::Commit);
        r.push(Span { round: 3, phase: Phase::LocalSolve, slot: Some(1), wall_s: 0.5, cpu_s: 0.4 });
        let spans = r.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].phase, Phase::Commit);
        assert_eq!(spans[0].round, 3);
        assert!(spans[0].wall_s >= 0.0 && spans[0].cpu_s >= 0.0);
        assert_eq!(spans[1].slot, Some(1));
        assert!(r.drain().is_empty());
    }
}
