//! Leader-side straggler analytics and the Prometheus text renderer.
//!
//! [`MetricsHub`] is the shared aggregation state: per-slot
//! [`LogHistogram`]s of local-solve wall time (exact-merge, so the
//! all-slots histogram is derived without rebinning error), cumulative
//! per-phase seconds, per-round min/p50/p99/max solve times with an
//! imbalance ratio (`max/mean`, the straggler signal), counters for
//! timeouts/reconnects/heals, and snapshots of the run gauges (round,
//! gap, P, D) plus the byte-exact ledger and socket totals. A hub is
//! `Clone` (shared `Arc<Mutex<_>>`), so the
//! [`MetricsServer`](crate::obs::MetricsServer) renders from another
//! thread while the driver's [`MetricsObserver`] feeds it.

use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use super::histogram::LogHistogram;
use super::{Phase, RoundObs};
use crate::driver::{Observer, RoundEvent, RunMeta};
use crate::error::Result;
use crate::transport::{Ledger, SocketStats};

#[derive(Debug, Default)]
struct MetricsState {
    rounds_total: u64,
    last_round: u64,
    last_gap: f64,
    last_primal: f64,
    last_dual: f64,
    sim_time_s: f64,
    wire_bytes: u64,
    /// Cumulative wall seconds per [`Phase`] (local_solve = max over
    /// slots per round: the critical-path convention of BENCH v3).
    phase_seconds: [f64; 5],
    /// Per-slot local-solve wall-time histograms.
    solve_hists: Vec<LogHistogram>,
    /// Last completed round's per-slot solve stats.
    round_solve_min: f64,
    round_solve_p50: f64,
    round_solve_p99: f64,
    round_solve_max: f64,
    /// `max / mean` of the last round's per-slot solve times.
    imbalance_ratio: f64,
    timeouts: u64,
    reconnects: u64,
    heals: u64,
    max_worker_rss: u64,
    ledger: Option<Ledger>,
    socket: Option<SocketStats>,
}

/// Shared, thread-safe metrics aggregation state.
#[derive(Debug, Clone, Default)]
pub struct MetricsHub {
    inner: Arc<Mutex<MetricsState>>,
}

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    /// An [`Observer`] feeding this hub (attach via `Driver::observe`).
    pub fn observer(&self) -> MetricsObserver {
        MetricsObserver { hub: self.clone() }
    }

    fn record_event(&self, event: &RoundEvent) {
        let mut s = self.inner.lock().expect("metrics hub poisoned");
        match event {
            RoundEvent::RoundStarted { round } => {
                s.rounds_total += 1;
                s.last_round = *round;
            }
            RoundEvent::Evaluated { row } => {
                s.last_gap = row.gap;
                s.last_primal = row.primal;
                s.last_dual = row.dual;
                s.sim_time_s = row.sim_time_s;
                s.wire_bytes = row.wire_bytes();
            }
            _ => {}
        }
    }

    fn record_round(&self, obs: &RoundObs) {
        let mut s = self.inner.lock().expect("metrics hub poisoned");
        for span in &obs.spans {
            if span.phase != Phase::LocalSolve {
                s.phase_seconds[span.phase.index()] += span.wall_s;
            }
        }
        // per-slot solve analytics from the worker metrics blocks
        if !obs.workers.is_empty() {
            if s.solve_hists.len() < obs.workers.len() {
                s.solve_hists.resize_with(obs.workers.len(), LogHistogram::new);
            }
            let mut round_hist = LogHistogram::new();
            let mut sum = 0.0;
            let mut reconnects = 0;
            for m in &obs.workers {
                if let Some(h) = s.solve_hists.get_mut(m.worker) {
                    h.record(m.solve_wall_s);
                }
                round_hist.record(m.solve_wall_s);
                sum += m.solve_wall_s;
                reconnects += m.reconnects;
            }
            s.round_solve_min = round_hist.min();
            s.round_solve_p50 = round_hist.quantile(0.5);
            s.round_solve_p99 = round_hist.quantile(0.99);
            s.round_solve_max = round_hist.max();
            let mean = sum / obs.workers.len() as f64;
            s.imbalance_ratio = if mean > 0.0 { round_hist.max() / mean } else { 1.0 };
            // critical path: the barrier waits for the slowest slot
            s.phase_seconds[Phase::LocalSolve.index()] += round_hist.max();
            s.reconnects = reconnects;
        }
        s.timeouts = obs.timeouts;
        s.heals = obs.heals;
        s.max_worker_rss = s.max_worker_rss.max(obs.max_worker_rss);
        if obs.ledger.is_some() {
            s.ledger = obs.ledger;
        }
        if obs.socket.is_some() {
            s.socket = obs.socket;
        }
    }

    /// Cumulative per-phase seconds, indexed like [`Phase::ALL`].
    pub fn phase_seconds(&self) -> [f64; 5] {
        self.inner.lock().expect("metrics hub poisoned").phase_seconds
    }

    /// Render the Prometheus text exposition (format 0.0.4).
    pub fn render(&self) -> String {
        let s = self.inner.lock().expect("metrics hub poisoned");
        let mut out = String::with_capacity(4096);
        let w = &mut out;

        let _ = writeln!(w, "# HELP cocoa_rounds_total Completed CoCoA rounds.");
        let _ = writeln!(w, "# TYPE cocoa_rounds_total counter");
        let _ = writeln!(w, "cocoa_rounds_total {}", s.rounds_total);
        let _ = writeln!(w, "# HELP cocoa_round Last started round number.");
        let _ = writeln!(w, "# TYPE cocoa_round gauge");
        let _ = writeln!(w, "cocoa_round {}", s.last_round);
        for (name, help, v) in [
            ("cocoa_duality_gap", "Last evaluated duality gap.", s.last_gap),
            ("cocoa_primal_value", "Last evaluated primal objective P(w).", s.last_primal),
            ("cocoa_dual_value", "Last evaluated dual objective D(alpha).", s.last_dual),
            ("cocoa_sim_time_seconds", "Simulated distributed seconds.", s.sim_time_s),
        ] {
            let _ = writeln!(w, "# HELP {name} {help}");
            let _ = writeln!(w, "# TYPE {name} gauge");
            let _ = writeln!(w, "{name} {}", prom_f64(v));
        }
        let _ = writeln!(w, "# HELP cocoa_wire_bytes Wire bytes charged to the run so far.");
        let _ = writeln!(w, "# TYPE cocoa_wire_bytes gauge");
        let _ = writeln!(w, "cocoa_wire_bytes {}", s.wire_bytes);

        let _ = writeln!(
            w,
            "# HELP cocoa_phase_seconds_total Cumulative wall seconds per round phase \
             (local_solve = slowest slot per round)."
        );
        let _ = writeln!(w, "# TYPE cocoa_phase_seconds_total counter");
        for p in Phase::ALL {
            let _ = writeln!(
                w,
                "cocoa_phase_seconds_total{{phase=\"{}\"}} {}",
                p.as_str(),
                prom_f64(s.phase_seconds[p.index()])
            );
        }

        let _ = writeln!(
            w,
            "# HELP cocoa_solve_seconds Per-slot local-solve wall time (log-bucketed)."
        );
        let _ = writeln!(w, "# TYPE cocoa_solve_seconds histogram");
        for (slot, h) in s.solve_hists.iter().enumerate() {
            for (bound, cum) in h.cumulative() {
                let _ = writeln!(
                    w,
                    "cocoa_solve_seconds_bucket{{slot=\"{slot}\",le=\"{}\"}} {cum}",
                    prom_f64(bound)
                );
            }
            let _ = writeln!(
                w,
                "cocoa_solve_seconds_bucket{{slot=\"{slot}\",le=\"+Inf\"}} {}",
                h.count()
            );
            let _ = writeln!(w, "cocoa_solve_seconds_sum{{slot=\"{slot}\"}} {}", prom_f64(h.sum()));
            let _ = writeln!(w, "cocoa_solve_seconds_count{{slot=\"{slot}\"}} {}", h.count());
        }

        let _ = writeln!(
            w,
            "# HELP cocoa_round_solve_seconds Last round's per-slot solve-time spread."
        );
        let _ = writeln!(w, "# TYPE cocoa_round_solve_seconds gauge");
        for (stat, v) in [
            ("min", s.round_solve_min),
            ("p50", s.round_solve_p50),
            ("p99", s.round_solve_p99),
            ("max", s.round_solve_max),
        ] {
            let _ = writeln!(w, "cocoa_round_solve_seconds{{stat=\"{stat}\"}} {}", prom_f64(v));
        }
        let _ = writeln!(
            w,
            "# HELP cocoa_solve_imbalance_ratio Last round's max/mean solve time (1.0 = balanced)."
        );
        let _ = writeln!(w, "# TYPE cocoa_solve_imbalance_ratio gauge");
        let _ = writeln!(w, "cocoa_solve_imbalance_ratio {}", prom_f64(s.imbalance_ratio));

        for (name, help, v) in [
            ("cocoa_timeouts_total", "Leader recv timeouts.", s.timeouts),
            ("cocoa_reconnects_total", "Worker reconnects (sum over slots).", s.reconnects),
            ("cocoa_heals_total", "Successful heal() recoveries.", s.heals),
        ] {
            let _ = writeln!(w, "# HELP {name} {help}");
            let _ = writeln!(w, "# TYPE {name} counter");
            let _ = writeln!(w, "{name} {v}");
        }
        let _ = writeln!(
            w,
            "# HELP cocoa_peak_rss_bytes Max peak RSS over leader and workers."
        );
        let _ = writeln!(w, "# TYPE cocoa_peak_rss_bytes gauge");
        let _ = writeln!(w, "cocoa_peak_rss_bytes {}", s.max_worker_rss);

        if let Some(ledger) = &s.ledger {
            let _ = writeln!(
                w,
                "# HELP cocoa_ledger_bytes_total Byte-exact payload bytes per message kind."
            );
            let _ = writeln!(w, "# TYPE cocoa_ledger_bytes_total counter");
            for (kind, _msgs, bytes) in ledger.rows() {
                let _ = writeln!(
                    w,
                    "cocoa_ledger_bytes_total{{kind=\"{}\"}} {bytes}",
                    kind.name()
                );
            }
            let _ = writeln!(
                w,
                "# HELP cocoa_ledger_msgs_total Messages per kind in the ledger."
            );
            let _ = writeln!(w, "# TYPE cocoa_ledger_msgs_total counter");
            for (kind, msgs, _bytes) in ledger.rows() {
                let _ = writeln!(
                    w,
                    "cocoa_ledger_msgs_total{{kind=\"{}\"}} {msgs}",
                    kind.name()
                );
            }
        }
        if let Some(sock) = &s.socket {
            let _ = writeln!(
                w,
                "# HELP cocoa_socket_bytes_total Raw socket bytes (payload + framing)."
            );
            let _ = writeln!(w, "# TYPE cocoa_socket_bytes_total counter");
            let _ = writeln!(
                w,
                "cocoa_socket_bytes_total{{direction=\"sent\"}} {}",
                sock.sent_bytes
            );
            let _ = writeln!(
                w,
                "cocoa_socket_bytes_total{{direction=\"recv\"}} {}",
                sock.recv_bytes
            );
            let _ = writeln!(
                w,
                "# HELP cocoa_socket_overhead_bytes_total Framing and handshake overhead."
            );
            let _ = writeln!(w, "# TYPE cocoa_socket_overhead_bytes_total counter");
            let _ = writeln!(
                w,
                "cocoa_socket_overhead_bytes_total{{kind=\"framing\"}} {}",
                sock.framing_bytes
            );
            let _ = writeln!(
                w,
                "cocoa_socket_overhead_bytes_total{{kind=\"handshake\"}} {}",
                sock.handshake_bytes
            );
        }
        out
    }

    /// Fold the leader's own peak RSS into the reported max.
    pub fn observe_leader_rss(&self, rss: u64) {
        let mut s = self.inner.lock().expect("metrics hub poisoned");
        s.max_worker_rss = s.max_worker_rss.max(rss);
    }
}

/// Prometheus float rendering: finite values via `{}` (shortest
/// round-trip), non-finite as `NaN` / `+Inf` / `-Inf`.
fn prom_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

/// The [`Observer`] that feeds a [`MetricsHub`].
pub struct MetricsObserver {
    hub: MetricsHub,
}

impl Observer for MetricsObserver {
    fn on_event(&mut self, _meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        self.hub.record_event(event);
        Ok(())
    }

    fn on_round_obs(&mut self, _meta: &RunMeta, obs: &RoundObs) -> Result<()> {
        self.hub.record_round(obs);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WorkerMetrics;
    use crate::obs::Span;

    fn meta() -> RunMeta {
        RunMeta {
            algorithm: "cocoa".into(),
            dataset: "t".into(),
            k: 2,
            h: 10,
            beta: 1.0,
            lambda: 0.1,
        }
    }

    fn obs(round: u64) -> RoundObs {
        RoundObs {
            round,
            spans: vec![
                Span { round, phase: Phase::Broadcast, slot: None, wall_s: 0.01, cpu_s: 0.01 },
                Span { round, phase: Phase::Reduce, slot: None, wall_s: 0.05, cpu_s: 0.0 },
                Span { round, phase: Phase::Commit, slot: None, wall_s: 0.002, cpu_s: 0.002 },
            ],
            workers: vec![
                WorkerMetrics {
                    worker: 0,
                    round,
                    solve_wall_s: 0.04,
                    solve_cpu_s: 0.039,
                    inner_steps: 100,
                    peak_rss_bytes: 1 << 20,
                    reconnects: 0,
                },
                WorkerMetrics {
                    worker: 1,
                    round,
                    solve_wall_s: 0.08,
                    solve_cpu_s: 0.079,
                    inner_steps: 100,
                    peak_rss_bytes: 3 << 20,
                    reconnects: 1,
                },
            ],
            ledger: None,
            socket: None,
            timeouts: 0,
            heals: 0,
            max_worker_rss: 3 << 20,
        }
    }

    #[test]
    fn hub_accumulates_rounds_and_renders_valid_exposition() {
        let hub = MetricsHub::new();
        let mut o = hub.observer();
        let m = meta();
        o.on_event(&m, &RoundEvent::RoundStarted { round: 1 }).unwrap();
        o.on_round_obs(&m, &obs(1)).unwrap();
        o.on_event(&m, &RoundEvent::RoundStarted { round: 2 }).unwrap();
        o.on_round_obs(&m, &obs(2)).unwrap();

        let phase = hub.phase_seconds();
        assert!((phase[Phase::Broadcast.index()] - 0.02).abs() < 1e-12);
        // local_solve is the per-round max over slots, summed over rounds
        assert!((phase[Phase::LocalSolve.index()] - 0.16).abs() < 1e-12);

        let text = hub.render();
        assert!(text.contains("cocoa_rounds_total 2"));
        assert!(text.contains("cocoa_phase_seconds_total{phase=\"reduce\"}"));
        assert!(text.contains("cocoa_solve_seconds_bucket{slot=\"1\",le=\"+Inf\"} 2"));
        assert!(text.contains("cocoa_solve_seconds_count{slot=\"0\"} 2"));
        assert!(text.contains("cocoa_reconnects_total 1"));
        assert!(text.contains("cocoa_peak_rss_bytes 3145728"));
        assert!(text.contains("cocoa_solve_imbalance_ratio"));
        // every non-comment line is "name{labels} value" with a parseable value
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(!name.is_empty());
            assert!(
                value.parse::<f64>().is_ok() || matches!(value, "NaN" | "+Inf" | "-Inf"),
                "unparseable value in line: {line}"
            );
        }
    }

    #[test]
    fn imbalance_ratio_flags_the_straggler() {
        let hub = MetricsHub::new();
        let mut o = hub.observer();
        let m = meta();
        let mut one = obs(1);
        one.workers[1].solve_wall_s = 0.36; // 9x the other slot
        o.on_round_obs(&m, &one).unwrap();
        let text = hub.render();
        let ratio_line = text
            .lines()
            .find(|l| l.starts_with("cocoa_solve_imbalance_ratio"))
            .unwrap();
        let ratio: f64 = ratio_line.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!(ratio > 1.5, "ratio = {ratio}");
    }
}
