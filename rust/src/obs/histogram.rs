//! Hand-rolled log-bucketed latency histograms with exact merge.
//!
//! Fixed geometric bucket bounds (`1 µs · 2^i`, 28 buckets up to ~134 s,
//! plus overflow) shared by every instance, so merging two histograms is
//! an exact elementwise sum — no rebinning error, and quantiles of a
//! merge equal quantiles of recording the union. Exact `min`/`max`/`sum`
//! ride along; quantiles are bucket upper bounds (documented resolution:
//! one factor of 2).

/// Number of finite buckets; bucket `i` covers `(bound(i-1), bound(i)]`.
pub const BUCKETS: usize = 28;

const MIN_BOUND: f64 = 1e-6;

/// Log-bucketed histogram of nonnegative seconds.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// `counts[i]` for bucket `i`; `counts[BUCKETS]` is overflow (+Inf).
    counts: [u64; BUCKETS + 1],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: [0; BUCKETS + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Upper bound of finite bucket `i` in seconds: `1e-6 * 2^i`.
    pub fn bound(i: usize) -> f64 {
        MIN_BOUND * f64::powi(2.0, i as i32)
    }

    fn bucket_of(v: f64) -> usize {
        // linear scan: BUCKETS is small and this is never on a hot path
        for i in 0..BUCKETS {
            if v <= Self::bound(i) {
                return i;
            }
        }
        BUCKETS
    }

    /// Record one observation. Negative and NaN values clamp to 0 (they
    /// can only arise from clock skew; dropping them would desync
    /// `count` from the caller's bookkeeping).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact smallest observation (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest observation (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate for `q` in `[0, 1]`: the upper bound of the
    /// bucket holding the rank-`ceil(q * count)` observation, clamped to
    /// the exact `[min, max]` envelope. Resolution is the bucket width
    /// (a factor of 2); `q = 0`/`q = 1` are exact.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max();
        }
        let rank = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let hi = if i < BUCKETS { Self::bound(i) } else { self.max };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// Exact merge: identical fixed bounds make this an elementwise sum.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Prometheus-style cumulative buckets: `(upper_bound_s, cumulative
    /// count)` for each finite bucket, in increasing bound order. The
    /// `+Inf` bucket is [`LogHistogram::count`].
    pub fn cumulative(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        let mut acc = 0u64;
        self.counts[..BUCKETS].iter().enumerate().map(move |(i, &c)| {
            acc += c;
            (Self::bound(i), acc)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_geometric_and_bucketing_is_consistent() {
        assert_eq!(LogHistogram::bound(0), 1e-6);
        for i in 1..BUCKETS {
            assert!((LogHistogram::bound(i) / LogHistogram::bound(i - 1) - 2.0).abs() < 1e-12);
        }
        // an observation lands in the first bucket whose bound covers it
        let mut h = LogHistogram::new();
        h.record(1.5e-6); // bound(0)=1e-6 < 1.5e-6 <= bound(1)=2e-6
        let cum: Vec<_> = h.cumulative().collect();
        assert_eq!(cum[0].1, 0);
        assert_eq!(cum[1].1, 1);
    }

    #[test]
    fn min_max_sum_are_exact_and_quantiles_bracket() {
        let mut h = LogHistogram::new();
        for v in [0.001, 0.002, 0.004, 0.008, 0.5] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 0.515).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.5);
        assert_eq!(h.quantile(0.0), 0.001);
        assert_eq!(h.quantile(1.0), 0.5);
        // p50 rank is the 3rd observation (0.004): within a factor of 2
        let p50 = h.quantile(0.5);
        assert!((0.004..=0.008).contains(&p50), "p50 = {p50}");
        // quantiles never leave the exact envelope
        for q in [0.01, 0.25, 0.5, 0.75, 0.99] {
            let v = h.quantile(q);
            assert!((h.min()..=h.max()).contains(&v), "q{q} = {v}");
        }
    }

    #[test]
    fn merge_is_exact_against_recording_the_union() {
        let obs_a = [1e-5, 3e-4, 0.02, 7.0];
        let obs_b = [2e-6, 0.02, 0.9, 300.0]; // 300 s lands in overflow
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut union = LogHistogram::new();
        for v in obs_a {
            a.record(v);
            union.record(v);
        }
        for v in obs_b {
            b.record(v);
            union.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), union.count());
        assert_eq!(a.sum().to_bits(), union.sum().to_bits());
        assert_eq!(a.min(), union.min());
        assert_eq!(a.max(), union.max());
        let ca: Vec<_> = a.cumulative().collect();
        let cu: Vec<_> = union.cumulative().collect();
        assert_eq!(ca, cu);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q).to_bits(), union.quantile(q).to_bits());
        }
    }

    #[test]
    fn empty_and_degenerate_inputs_are_defined() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(-1.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }
}
