//! A minimal HTTP/1.0 responder serving `GET /metrics` from a live
//! leader, reusing the `transport/net` socket plumbing (`NetListener` /
//! `Sock`) — no HTTP library, no new dependency.
//!
//! The server owns one background thread polling a nonblocking listener;
//! each accepted connection gets one request parsed, one response
//! written, and the socket closed (`Connection: close` semantics, which
//! every Prometheus scraper and `curl`-style client speaks). Rendering
//! happens outside the driver thread via the shared [`MetricsHub`], so
//! scrapes never touch the round loop — the passivity test in
//! `tests/observability.rs` runs with a live scraper attached.

use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::metrics::MetricsHub;
use crate::error::{Error, Result};
use crate::transport::net::{NetAddr, NetListener, Sock};

/// Longest request head we will buffer before answering 400.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// A live `/metrics` endpoint; dropping it stops the listener thread.
pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: String,
}

impl MetricsServer {
    /// Bind `addr` (`tcp:host:port` or `uds:/path`) and serve `hub` until
    /// the server is dropped or [`MetricsServer::shutdown`] is called.
    pub fn serve(addr: &str, hub: MetricsHub) -> Result<MetricsServer> {
        let parsed = NetAddr::parse(addr)?;
        let listener = NetListener::bind(&parsed)?;
        listener.set_nonblocking(true).map_err(|e| Error::Transport {
            message: format!("metrics listener nonblocking failed: {e}"),
        })?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("cocoa-metrics".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok(sock) => respond(sock, &hub),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
            })
            .map_err(|e| Error::Transport {
                message: format!("metrics server thread spawn failed: {e}"),
            })?;
        Ok(MetricsServer { stop, handle: Some(handle), addr: addr.to_string() })
    }

    /// The address the server was bound on, as given.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve exactly one request on `sock`. All errors are swallowed — a
/// misbehaving scraper must never take the leader down.
fn respond(mut sock: Sock, hub: &MetricsHub) {
    let _ = sock.set_read_timeout(Some(Duration::from_secs(2)));
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // read until the blank line ending the request head (we ignore bodies)
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && head.len() < MAX_REQUEST_BYTES {
        match sock.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    let request_line = head
        .split(|&b| b == b'\r' || b == b'\n')
        .next()
        .map(|l| String::from_utf8_lossy(l).into_owned())
        .unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", "only GET is served\n".to_string())
    } else if path == "/metrics" || path.starts_with("/metrics?") {
        ("200 OK", hub.render())
    } else {
        ("404 Not Found", "try /metrics\n".to_string())
    };
    let response = format!(
        "HTTP/1.0 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len(),
    );
    let _ = sock.write_all(response.as_bytes());
    let _ = sock.flush();
    let _ = sock.shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::WorkerMetrics;
    use crate::driver::{Observer, RoundEvent, RunMeta};
    use crate::obs::{Phase, RoundObs, Span};

    fn scrape(addr: &NetAddr, request: &str) -> String {
        // the listener thread polls at 20 ms; retry connect briefly
        let mut sock = None;
        for _ in 0..100 {
            match Sock::connect(addr) {
                Ok(s) => {
                    sock = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        let mut sock = sock.expect("metrics server never came up");
        sock.write_all(request.as_bytes()).unwrap();
        sock.flush().unwrap();
        let mut out = String::new();
        sock.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_prometheus_text_over_uds_and_404s_elsewhere() {
        let dir = std::env::temp_dir().join(format!("cocoa_obs_srv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.sock");
        let addr_str = format!("uds:{}", path.display());

        let hub = MetricsHub::new();
        let meta = RunMeta {
            algorithm: "cocoa".into(),
            dataset: "t".into(),
            k: 1,
            h: 1,
            beta: 1.0,
            lambda: 0.1,
        };
        let mut obs = hub.observer();
        obs.on_event(&meta, &RoundEvent::RoundStarted { round: 1 }).unwrap();
        obs.on_round_obs(
            &meta,
            &RoundObs {
                round: 1,
                spans: vec![Span {
                    round: 1,
                    phase: Phase::Commit,
                    slot: None,
                    wall_s: 0.001,
                    cpu_s: 0.001,
                }],
                workers: vec![WorkerMetrics {
                    worker: 0,
                    round: 1,
                    solve_wall_s: 0.01,
                    solve_cpu_s: 0.01,
                    inner_steps: 5,
                    peak_rss_bytes: 1,
                    reconnects: 0,
                }],
                ..RoundObs::default()
            },
        )
        .unwrap();

        let server = MetricsServer::serve(&addr_str, hub).unwrap();
        let parsed = NetAddr::parse(&addr_str).unwrap();

        let ok = scrape(&parsed, "GET /metrics HTTP/1.0\r\n\r\n");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "{ok}");
        assert!(ok.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(ok.contains("cocoa_rounds_total 1"));
        assert!(ok.contains("# TYPE cocoa_solve_seconds histogram"));

        let missing = scrape(&parsed, "GET /nope HTTP/1.0\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.0 404 Not Found\r\n"), "{missing}");

        let post = scrape(&parsed, "POST /metrics HTTP/1.0\r\n\r\n");
        assert!(post.starts_with("HTTP/1.0 405"), "{post}");

        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
