//! Network cost model — converts counted communication into simulated
//! distributed wall-time.
//!
//! The paper's experiments ran on Spark over m1.large EC2 instances; its
//! headline claim is about wall-clock dominated by communication rounds.
//! Our workers run in-process, so per-round *compute* is measured (thread
//! CPU time, max over workers, as a real cluster would experience), and
//! *communication* is modeled from exactly counted vectors/bytes:
//!
//! `round_time = max_k compute_k + latency + bytes_on_wire / bandwidth`
//!
//! The paper's own motivation quantifies the regime: memory access ~100 ns
//! vs network ~250,000 ns (Section 1, footnote 1) — the presets below span
//! commodity-cluster to multicore so the communication/computation
//! trade-off (Figure 3) can be explored across environments.

/// Simulated interconnect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-round fixed cost (seconds): barrier + scheduling + RTT.
    pub latency_s: f64,
    /// Payload rate (bytes/second) of the reduce+broadcast path.
    pub bandwidth_bps: f64,
    /// Wire width of one scalar (8 = f64, 4 = f32).
    pub bytes_per_scalar: usize,
}

impl NetworkModel {
    /// Commodity EC2-like cluster (the paper's testbed): ~5 ms barrier,
    /// 1 Gbit/s effective reduce bandwidth.
    pub fn ec2_like() -> Self {
        NetworkModel { latency_s: 5e-3, bandwidth_bps: 125e6, bytes_per_scalar: 8 }
    }

    /// Low-latency HPC interconnect.
    pub fn infiniband() -> Self {
        NetworkModel { latency_s: 5e-5, bandwidth_bps: 5e9, bytes_per_scalar: 8 }
    }

    /// Multi-core shared memory ("communication as fast as memory access").
    pub fn multicore() -> Self {
        NetworkModel { latency_s: 1e-7, bandwidth_bps: 2e10, bytes_per_scalar: 8 }
    }

    /// No communication cost at all (isolates pure computation).
    pub fn free() -> Self {
        NetworkModel { latency_s: 0.0, bandwidth_bps: f64::INFINITY, bytes_per_scalar: 8 }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "ec2_like" => Some(Self::ec2_like()),
            "infiniband" => Some(Self::infiniband()),
            "multicore" => Some(Self::multicore()),
            "free" => Some(Self::free()),
            _ => None,
        }
    }

    /// Time to move `vectors` d-dimensional vectors through the leader in
    /// one round (gather + broadcast counted once: the reduce result going
    /// back out is one more vector per worker, folded into `vectors` by the
    /// coordinator's accounting).
    pub fn transfer_time(&self, vectors: usize, d: usize) -> f64 {
        let bytes = (vectors * d * self.bytes_per_scalar) as f64;
        if self.bandwidth_bps.is_infinite() {
            0.0
        } else {
            bytes / self.bandwidth_bps
        }
    }

    /// Full round time; see module docs.
    pub fn round_time(&self, max_compute_s: f64, vectors: usize, d: usize) -> f64 {
        max_compute_s + self.latency_s + self.transfer_time(vectors, d)
    }

    /// Time to move `bytes` through the leader in one round — the
    /// byte-exact counterpart of [`NetworkModel::transfer_time`], fed by
    /// the transport ledger's measured sizes (headers, sparse encodings,
    /// retransmissions and all) instead of the analytic vector count.
    pub fn transfer_time_bytes(&self, bytes: u64) -> f64 {
        if self.bandwidth_bps.is_infinite() {
            0.0
        } else {
            bytes as f64 / self.bandwidth_bps
        }
    }

    /// Full round time from measured bytes; see
    /// [`NetworkModel::transfer_time_bytes`].
    pub fn round_time_bytes(&self, max_compute_s: f64, bytes: u64) -> f64 {
        max_compute_s + self.latency_s + self.transfer_time_bytes(bytes)
    }
}

/// Straggler model — the bulk-synchronous failure mode of the paper's
/// Spark testbed: every CoCoA round is a barrier, so the round runs at the
/// pace of the *slowest* worker. Deterministic per (round, worker) so
/// simulated timings are replayable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerModel {
    /// Probability a given worker straggles in a given round.
    pub probability: f64,
    /// Compute-time multiplier applied to a straggling worker.
    pub slowdown: f64,
    pub seed: u64,
}

impl StragglerModel {
    pub fn none() -> Self {
        StragglerModel { probability: 0.0, slowdown: 1.0, seed: 0 }
    }

    /// Typical shared-cluster churn: 10% of workers 5x slower.
    pub fn shared_cluster() -> Self {
        StragglerModel { probability: 0.1, slowdown: 5.0, seed: 0x57a6 }
    }

    /// The multiplier worker `k` experiences in `round`.
    pub fn factor(&self, round: u64, worker: usize) -> f64 {
        if self.probability <= 0.0 {
            return 1.0;
        }
        let mut rng = crate::util::Rng::seed_from_u64(
            self.seed ^ round.wrapping_mul(0x9e3779b97f4a7c15) ^ (worker as u64) << 32,
        );
        if rng.gen_bool(self.probability) {
            self.slowdown
        } else {
            1.0
        }
    }

    /// Barrier compute time for a round: max over workers of their
    /// straggler-scaled compute.
    pub fn barrier_compute(&self, round: u64, computes: &[f64]) -> f64 {
        computes
            .iter()
            .enumerate()
            .map(|(k, &c)| c * self.factor(round, k))
            .fold(0.0, f64::max)
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self::ec2_like()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_adds_up() {
        let m = NetworkModel { latency_s: 0.01, bandwidth_bps: 1e6, bytes_per_scalar: 8 };
        // 2 vectors of 1000 doubles = 16000 bytes -> 16 ms
        let t = m.round_time(0.5, 2, 1000);
        assert!((t - (0.5 + 0.01 + 0.016)).abs() < 1e-12);
    }

    #[test]
    fn free_network_costs_nothing() {
        let m = NetworkModel::free();
        assert_eq!(m.round_time(1.0, 100, 100000), 1.0);
        assert_eq!(m.round_time_bytes(1.0, u64::MAX), 1.0);
    }

    #[test]
    fn byte_exact_round_time_matches_vector_model_at_equal_volume() {
        let m = NetworkModel { latency_s: 0.01, bandwidth_bps: 1e6, bytes_per_scalar: 8 };
        let (vectors, d) = (4, 500);
        let bytes = (vectors * d * m.bytes_per_scalar) as u64;
        let a = m.round_time(0.25, vectors, d);
        let b = m.round_time_bytes(0.25, bytes);
        assert!((a - b).abs() < 1e-15);
        // measured bytes include headers/retransmits: strictly more time
        assert!(m.round_time_bytes(0.25, bytes + 640) > a);
    }

    #[test]
    fn presets_ordered_by_speed() {
        let d = 10000;
        let ec2 = NetworkModel::ec2_like().round_time(0.0, 8, d);
        let ib = NetworkModel::infiniband().round_time(0.0, 8, d);
        let mc = NetworkModel::multicore().round_time(0.0, 8, d);
        assert!(ec2 > ib && ib > mc);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ["ec2_like", "infiniband", "multicore", "free"] {
            assert!(NetworkModel::by_name(name).is_some());
        }
        assert!(NetworkModel::by_name("carrier_pigeon").is_none());
    }

    #[test]
    fn straggler_factor_deterministic_and_bounded() {
        let m = StragglerModel::shared_cluster();
        for round in 0..50u64 {
            for k in 0..8 {
                let f = m.factor(round, k);
                assert!(f == 1.0 || f == m.slowdown);
                assert_eq!(f, m.factor(round, k)); // replayable
            }
        }
        // roughly `probability` of (round, worker) cells straggle
        let hits: usize = (0..2000u64)
            .map(|r| usize::from(m.factor(r, 0) > 1.0))
            .sum();
        assert!((100..400).contains(&hits), "straggle rate off: {hits}/2000");
        assert_eq!(StragglerModel::none().factor(3, 1), 1.0);
    }

    #[test]
    fn barrier_takes_slowest_worker() {
        let m = StragglerModel { probability: 1.0, slowdown: 10.0, seed: 1 };
        let t = m.barrier_compute(0, &[0.1, 0.2, 0.05]);
        assert!((t - 2.0).abs() < 1e-12); // 0.2 * 10
        let free = StragglerModel::none().barrier_compute(0, &[0.1, 0.2, 0.05]);
        assert!((free - 0.2).abs() < 1e-12);
    }

    #[test]
    fn communication_dominates_for_naive_updates() {
        // the paper's core motivation: H=1 rounds pay latency per update
        let m = NetworkModel::ec2_like();
        let naive_100_updates = 100.0 * m.round_time(1e-6, 4, 54);
        let cocoa_1_round = m.round_time(100.0 * 1e-6, 4, 54);
        assert!(naive_100_updates > 50.0 * cocoa_1_round);
    }
}
