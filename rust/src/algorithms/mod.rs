//! Algorithms as a first-class trait: every Section-6 method implements
//! [`Algorithm`] — a per-round `local_work()` order plus a `reduce()` that
//! folds the K replies into the shared state — and runs through the same
//! [`Session`](crate::Session) driver, so their communication/computation
//! profiles are directly comparable:
//!
//! | type            | local work                   | reduce                                         |
//! |-----------------|------------------------------|------------------------------------------------|
//! | [`Cocoa`]       | H SDCA steps, locally applied| `w += scale * sum dw` per [`Aggregation`]      |
//! | [`MinibatchCd`] | b=H coord updates, frozen w  | `w += (beta_b/(K H)) sum dw` [TBRS13/Yan13]    |
//! | [`MinibatchSgd`]| H subgradients, frozen w     | Pegasos step over the K·H batch [SSSSC10]      |
//! | [`LocalSgd`]    | H Pegasos steps, local w     | `w += (beta/K) sum (w_k - w)`                  |
//! | [`NaiveCd`]     | cocoa with H = 1             | communicate every update                       |
//! | [`NaiveSgd`]    | local_sgd with H = 1         | communicate every update                       |
//! | [`OneShotAvg`]  | solve block to optimality    | single round, average models [ZDW13]           |
//!
//! The aggregation policy of Algorithm 1 is its own type: CoCoA's safe
//! averaging (`beta_K = 1`) and the CoCoA+ adding regime (`beta_K = K`
//! with `sigma' = K` scaled subproblems, resolving the conclusion's open
//! problem) are two values of [`Aggregation`], so CoCoA+ is a constructor
//! away: [`Cocoa::adding`].

use crate::coordinator::{Cluster, LocalWork, RoundReply};
use crate::error::{Error, Result};

/// How the leader folds the K local updates into the shared state — the
/// `beta_K` knob of Algorithm 1, made a policy type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Aggregation {
    /// `w += (beta_k / K) * sum_k dw_k` — Algorithm 1. `beta_k = 1` is the
    /// always-safe choice the paper uses throughout Section 6.
    Average { beta_k: f64 },
    /// `w += sum_k dw_k` (`beta_K = K`), safe because the local subproblems
    /// are solved with `sigma' = K` scaled curvature (the CoCoA+ regime of
    /// *Adding vs. Averaging* [Ma et al.]).
    Add,
}

impl Default for Aggregation {
    fn default() -> Self {
        Aggregation::Average { beta_k: 1.0 }
    }
}

impl Aggregation {
    /// The scale the leader applies to `sum_k dw_k` at commit time.
    pub fn commit_scale(&self, k: usize) -> f64 {
        match self {
            Aggregation::Average { beta_k } => beta_k / k as f64,
            Aggregation::Add => 1.0,
        }
    }

    /// Extra curvature scaling the local subproblem must be solved with
    /// for this aggregation to be safe (`None` = unscaled).
    pub fn sigma_prime(&self, k: usize) -> Option<f64> {
        match self {
            Aggregation::Average { .. } => None,
            Aggregation::Add => Some(k as f64),
        }
    }
}

/// Per-round context handed to [`Algorithm`] hooks.
#[derive(Debug, Clone, Copy)]
pub struct RoundCtx {
    /// 1-based outer round.
    pub round: u64,
    /// Number of workers.
    pub k: usize,
    /// Regularization strength of the problem being solved.
    pub lambda: f64,
}

/// A distributed optimization method over the CoCoA runtime: per round the
/// driver dispatches `local_work(ctx, kid)` to every worker, gathers the K
/// replies, and hands them to `reduce`, which owns the leader-side update
/// (commit scaling, Pegasos steps, ...). Implement this to plug a new
/// method into [`Session::run`](crate::Session::run); all Section-6
/// baselines below are implementations.
pub trait Algorithm {
    /// Stable name used in traces, CSV paths, and figure labels.
    fn name(&self) -> &'static str;

    /// Inner steps per worker per round (0 where it is not meaningful).
    fn h(&self) -> usize;

    /// The beta knob recorded in traces (aggregation aggressiveness).
    fn beta(&self) -> f64 {
        1.0
    }

    /// Rounds this algorithm will actually run given the budget
    /// (single-round methods override this to 1).
    fn total_rounds(&self, budget_rounds: u64) -> u64 {
        budget_rounds
    }

    /// Does this method's leader update assume the plain L2 regularizer?
    /// The primal (Pegasos) SGD baselines do — their `1/(lambda t)` step
    /// and shrink are derived from `(lambda/2)||w||^2` — so the driver
    /// rejects them on L1/elastic-net sessions with a typed error instead
    /// of silently optimizing the wrong objective.
    fn requires_l2(&self) -> bool {
        false
    }

    /// Does this method maintain no dual variables? Primal-only (SGD)
    /// methods evaluate to a NaN dual and a NaN duality gap, so a
    /// gap-based stopping rule can never fire on them — the driver uses
    /// this to reject the combination when nothing else bounds the run.
    fn primal_only(&self) -> bool {
        false
    }

    /// The order broadcast to worker `worker` this round.
    fn local_work(&self, ctx: &RoundCtx, worker: usize) -> LocalWork;

    /// Fold the K replies into leader + worker state.
    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()>;
}

/// Legacy stopping criteria + instrumentation cadence for one run
/// (whichever criterion fires first stops the run).
///
/// `Budget` predates the composable
/// [`StoppingRule`](crate::driver::StoppingRule) API and is kept as a
/// compact conversion into it: anywhere a
/// [`Session::run`](crate::Session::run) /
/// [`Session::drive`](crate::Session::drive) call accepts stopping rules,
/// a `Budget` still works — it validates ([`Budget::validate`]) and
/// decomposes into `gap -> subopt -> max-rounds` rules in its historical
/// precedence order. New code should prefer the rules (`GapBelow`,
/// `MaxRounds`, `SimTimeBelow`, ... and the `or`/`and` combinators),
/// which also cover budgets `Budget` never could (simulated time, wire
/// bytes, conjunctions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Budget {
    /// Max outer rounds (T in Algorithm 1).
    pub rounds: u64,
    /// Stop when the duality gap falls to this (0 disables).
    pub target_gap: f64,
    /// Stop when `P - P*` falls to this (needs a reference optimum on the
    /// session; 0 disables).
    pub target_subopt: f64,
    /// Evaluate P/D/gap every this many rounds (instrumentation, not
    /// counted as algorithm communication).
    pub eval_every: u64,
}

impl Default for Budget {
    fn default() -> Self {
        Budget { rounds: 100, target_gap: 0.0, target_subopt: 0.0, eval_every: 1 }
    }
}

/// Runaway guard for the open-ended `until_*` constructors.
const UNTIL_ROUNDS_CAP: u64 = 100_000;

impl Budget {
    /// Run exactly up to `rounds` outer rounds.
    pub fn rounds(rounds: u64) -> Self {
        Budget { rounds, ..Budget::default() }
    }

    /// Run until the duality gap reaches `gap` (capped at 100k rounds as a
    /// runaway guard; chain [`Budget::max_rounds`] to change the cap).
    pub fn until_gap(gap: f64) -> Self {
        Budget { rounds: UNTIL_ROUNDS_CAP, target_gap: gap, ..Budget::default() }
    }

    /// Run until `P - P*` reaches `subopt` (requires
    /// [`Session::set_reference_optimum`](crate::Session::set_reference_optimum);
    /// capped at 100k rounds as a runaway guard).
    pub fn until_subopt(subopt: f64) -> Self {
        Budget { rounds: UNTIL_ROUNDS_CAP, target_subopt: subopt, ..Budget::default() }
    }

    /// Override the round cap.
    pub fn max_rounds(mut self, rounds: u64) -> Self {
        self.rounds = rounds;
        self
    }

    /// Also stop at this duality gap.
    pub fn target_gap(mut self, gap: f64) -> Self {
        self.target_gap = gap;
        self
    }

    /// Also stop at this primal suboptimality.
    pub fn target_subopt(mut self, subopt: f64) -> Self {
        self.target_subopt = subopt;
        self
    }

    /// Evaluate every `n` rounds instead of every round. `0` is rejected
    /// by [`Budget::validate`] with a typed [`Error::InvalidBudget`] when
    /// the budget reaches a driver (it used to be silently clamped to 1).
    pub fn eval_every(mut self, n: u64) -> Self {
        self.eval_every = n;
        self
    }

    /// Check the budget's internal consistency. Called by the driver
    /// conversion; exposed so config loaders can fail early.
    pub fn validate(&self) -> Result<()> {
        validate_eval_every(self.eval_every)
    }
}

/// The one eval-cadence validity check, shared by [`Budget::validate`]
/// and every driver-side cadence knob so the typed error text cannot
/// drift between roads.
pub(crate) fn validate_eval_every(n: u64) -> Result<()> {
    if n == 0 {
        return Err(Error::InvalidBudget {
            reason: "eval_every must be >= 1 (0 would never evaluate; \
                     use a larger cadence instead)"
                .into(),
        });
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Algorithm 1 and its aggregation variants
// ---------------------------------------------------------------------------

/// CoCoA (Algorithm 1): H locally-applied steps of the configured local
/// dual method per round, reduced under an [`Aggregation`] policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cocoa {
    h: usize,
    aggregation: Aggregation,
}

impl Cocoa {
    /// Safe averaging (`beta_K = 1`), the paper's default.
    pub fn new(h: usize) -> Self {
        Cocoa { h, aggregation: Aggregation::default() }
    }

    /// Averaging with an explicit `beta_k` scale (Figure 4's knob).
    pub fn averaging(h: usize, beta_k: f64) -> Self {
        Cocoa { h, aggregation: Aggregation::Average { beta_k } }
    }

    /// CoCoA+: `beta_K = K` adding over `sigma' = K` scaled subproblems.
    pub fn adding(h: usize) -> Self {
        Cocoa { h, aggregation: Aggregation::Add }
    }

    /// Override the aggregation policy.
    pub fn aggregation(mut self, aggregation: Aggregation) -> Self {
        self.aggregation = aggregation;
        self
    }
}

impl Algorithm for Cocoa {
    fn name(&self) -> &'static str {
        match self.aggregation {
            Aggregation::Average { .. } => "cocoa",
            Aggregation::Add => "cocoa_plus",
        }
    }

    fn h(&self) -> usize {
        self.h
    }

    fn beta(&self) -> f64 {
        match self.aggregation {
            Aggregation::Average { beta_k } => beta_k,
            // the adding scale is K, applied via commit_scale; traces
            // record 1.0 to match the historical cocoa_plus convention
            Aggregation::Add => 1.0,
        }
    }

    fn local_work(&self, ctx: &RoundCtx, _worker: usize) -> LocalWork {
        match self.aggregation.sigma_prime(ctx.k) {
            None => LocalWork::DualRound { h: self.h },
            Some(sigma_prime) => LocalWork::DualRoundScaled { h: self.h, sigma_prime },
        }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        cluster.commit(replies, self.aggregation.commit_scale(ctx.k))?;
        Ok(())
    }
}

/// H = 1 CoCoA: communicate after every coordinate update.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveCd;

impl Algorithm for NaiveCd {
    fn name(&self) -> &'static str {
        "naive_cd"
    }

    fn h(&self) -> usize {
        1
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::DualRound { h: 1 }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        cluster.commit(replies, 1.0 / ctx.k as f64)?;
        Ok(())
    }
}

/// Mini-batch SDCA [TBRS13/Yan13] ("mini-batch-CD" in the figures): b = H
/// distinct coordinate updates per worker, all judged against the frozen
/// round-start `w`, averaged with `beta_b / (K H)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinibatchCd {
    h: usize,
    beta_b: f64,
}

impl MinibatchCd {
    pub fn new(h: usize) -> Self {
        MinibatchCd { h, beta_b: 1.0 }
    }

    /// The batch-aggregation scale (`beta_b = b` is the aggressive adding
    /// the paper warns about).
    pub fn beta_b(mut self, beta_b: f64) -> Self {
        self.beta_b = beta_b;
        self
    }
}

impl Algorithm for MinibatchCd {
    fn name(&self) -> &'static str {
        "minibatch_cd"
    }

    fn h(&self) -> usize {
        self.h
    }

    fn beta(&self) -> f64 {
        self.beta_b
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::DualBatchFrozen { b: self.h }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let b_total = (self.h * ctx.k) as f64;
        cluster.commit(replies, self.beta_b / b_total)?;
        Ok(())
    }
}

/// Locally-updating Pegasos: H local SGD steps per round on a continued
/// global `1/(lambda t)` schedule, model deltas averaged.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LocalSgd {
    h: usize,
    beta: f64,
    /// Global Pegasos step counter, advanced by H per round.
    t: u64,
}

impl LocalSgd {
    pub fn new(h: usize) -> Self {
        LocalSgd { h, beta: 1.0, t: 0 }
    }

    pub fn beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
}

impl Algorithm for LocalSgd {
    fn name(&self) -> &'static str {
        "local_sgd"
    }

    fn requires_l2(&self) -> bool {
        true
    }

    fn primal_only(&self) -> bool {
        true
    }

    fn h(&self) -> usize {
        self.h
    }

    fn beta(&self) -> f64 {
        self.beta
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::SgdLocal { h: self.h, t_offset: self.t }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        self.t += self.h as u64;
        let scale = self.beta / ctx.k as f64;
        let mut w = cluster.w.clone();
        for r in replies {
            for (wv, dv) in w.iter_mut().zip(&r.dw) {
                *wv += scale * dv;
            }
        }
        cluster.set_w(w);
        Ok(())
    }
}

/// Communicate after every SGD step (H = 1 [`LocalSgd`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveSgd {
    t: u64,
}

impl NaiveSgd {
    pub fn new() -> Self {
        NaiveSgd::default()
    }
}

impl Algorithm for NaiveSgd {
    fn name(&self) -> &'static str {
        "naive_sgd"
    }

    fn requires_l2(&self) -> bool {
        true
    }

    fn primal_only(&self) -> bool {
        true
    }

    fn h(&self) -> usize {
        1
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::SgdLocal { h: 1, t_offset: self.t }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        self.t += 1;
        let scale = 1.0 / ctx.k as f64;
        let mut w = cluster.w.clone();
        for r in replies {
            for (wv, dv) in w.iter_mut().zip(&r.dw) {
                *wv += scale * dv;
            }
        }
        cluster.set_w(w);
        Ok(())
    }
}

/// Mini-batch Pegasos [SSSSC10]: H subgradients per worker against frozen
/// `w`, one Pegasos step over the whole K·H batch per round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinibatchSgd {
    h: usize,
    beta: f64,
}

impl MinibatchSgd {
    pub fn new(h: usize) -> Self {
        MinibatchSgd { h, beta: 1.0 }
    }

    pub fn beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
}

impl Algorithm for MinibatchSgd {
    fn name(&self) -> &'static str {
        "minibatch_sgd"
    }

    fn requires_l2(&self) -> bool {
        true
    }

    fn primal_only(&self) -> bool {
        true
    }

    fn h(&self) -> usize {
        self.h
    }

    fn beta(&self) -> f64 {
        self.beta
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::SgdFrozen { h: self.h }
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        let eta = 1.0 / (ctx.lambda * ctx.round as f64);
        let batch = (self.h * ctx.k) as f64;
        let shrink = 1.0 - eta * ctx.lambda;
        let mut w = cluster.w.clone();
        for wv in w.iter_mut() {
            *wv *= shrink;
        }
        for r in replies {
            for (wv, gv) in w.iter_mut().zip(&r.dw) {
                *wv -= eta * self.beta * gv / batch;
            }
        }
        cluster.set_w(w);
        Ok(())
    }
}

/// One-shot averaging [ZDW13]: a single round where every worker solves
/// its block to optimality and the leader averages the models.
#[derive(Debug, Clone, Copy, Default)]
pub struct OneShotAvg;

impl Algorithm for OneShotAvg {
    fn name(&self) -> &'static str {
        "one_shot_avg"
    }

    fn h(&self) -> usize {
        0
    }

    fn total_rounds(&self, _budget_rounds: u64) -> u64 {
        1
    }

    fn local_work(&self, _ctx: &RoundCtx, _worker: usize) -> LocalWork {
        LocalWork::ExactSolve
    }

    fn reduce(
        &mut self,
        cluster: &mut Cluster,
        replies: &[RoundReply],
        ctx: &RoundCtx,
    ) -> Result<()> {
        cluster.commit(replies, 1.0 / ctx.k as f64)?;
        Ok(())
    }
}

// The round loop itself lives in [`crate::driver`]: `Session::run` drains
// a step-wise `Driver`, whose event machine reproduces the historical
// batch loop bit for bit (pinned by `rust/tests/driver_equivalence.rs`).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Session, Trainer};
    use crate::data::cov_like;
    use crate::loss::LossKind;
    use crate::netsim::NetworkModel;

    fn session(k: usize, seed: u64) -> Session {
        let data = cov_like(80, 6, 0.1, seed);
        Trainer::on(&data)
            .workers(k)
            .loss(LossKind::Hinge)
            .lambda(0.05)
            .network(NetworkModel::free())
            .seed(seed)
            .label("test")
            .build()
            .unwrap()
    }

    #[test]
    fn every_algorithm_runs_and_descends() {
        let algos: Vec<Box<dyn Algorithm>> = vec![
            Box::new(Cocoa::new(40)),
            Box::new(MinibatchCd::new(10).beta_b(10.0)),
            Box::new(MinibatchSgd::new(20)),
            Box::new(LocalSgd::new(20)),
            Box::new(NaiveCd),
            Box::new(NaiveSgd::new()),
            Box::new(OneShotAvg),
        ];
        for mut algo in algos {
            let mut sess = session(2, 3);
            // naive variants process one point per round; give them
            // proportionally more rounds to show progress
            let rounds = if algo.name().starts_with("naive") { 400 } else { 12 };
            let trace = sess
                .run(algo.as_mut(), Budget::rounds(rounds).eval_every(4))
                .unwrap();
            let p0 = trace.rows.first().unwrap().primal;
            let p_end = trace.best_primal();
            assert!(p_end < p0, "{} failed to descend: {p0} -> {p_end}", algo.name());
            sess.shutdown();
        }
    }

    #[test]
    fn cocoa_gap_shrinks_geometrically_ish() {
        let mut sess = session(4, 5);
        let trace = sess.run(&mut Cocoa::new(100), Budget::rounds(20)).unwrap();
        let g0 = trace.rows[1].gap;
        let g_end = trace.rows.last().unwrap().gap;
        assert!(g_end < g0 * 0.2, "gap barely moved: {g0} -> {g_end}");
        // dual must be monotone for beta_K = 1 averaging
        for pair in trace.rows.windows(2) {
            assert!(pair[1].dual >= pair[0].dual - 1e-9);
        }
        sess.shutdown();
    }

    #[test]
    fn target_gap_stops_early() {
        let mut sess = session(2, 7);
        let budget = Budget::until_gap(0.05).max_rounds(500);
        let trace = sess.run(&mut Cocoa::new(200), budget).unwrap();
        assert!(trace.rows.last().unwrap().gap <= 0.05);
        assert!((trace.rows.len() as u64) < 500);
        sess.shutdown();
    }

    #[test]
    fn stop_reasons_distinguish_gap_from_subopt() {
        use crate::telemetry::StopReason;
        // gap criterion: final row says "gap", earlier rows say "running"
        let mut sess = session(2, 13);
        let trace = sess
            .run(&mut Cocoa::new(200), Budget::until_gap(0.05).max_rounds(500))
            .unwrap();
        assert_eq!(trace.rows.last().unwrap().stop, StopReason::Gap);
        for row in &trace.rows[..trace.rows.len() - 1] {
            assert_eq!(row.stop, StopReason::Running, "round {}", row.round);
        }
        // the checkpoint remembers why the run ended
        assert_eq!(sess.checkpoint().unwrap().stop, StopReason::Gap);

        // subopt criterion on the same session
        sess.reset().unwrap();
        sess.set_reference_optimum(Some(0.0));
        let trace = sess
            .run(&mut Cocoa::new(50), Budget::until_subopt(10.0).max_rounds(50))
            .unwrap();
        assert_eq!(trace.rows.last().unwrap().stop, StopReason::Subopt);
        assert_eq!(sess.checkpoint().unwrap().stop, StopReason::Subopt);

        // plain round budget: "max_rounds"
        sess.reset().unwrap();
        sess.set_reference_optimum(None);
        let trace = sess.run(&mut Cocoa::new(10), Budget::rounds(3)).unwrap();
        assert_eq!(trace.rows.last().unwrap().stop, StopReason::MaxRounds);
        assert_eq!(trace.rows[0].stop, StopReason::Running);
        sess.shutdown();
    }

    #[test]
    fn w_nnz_tracks_the_primal_iterate() {
        let mut sess = session(2, 15);
        let trace = sess.run(&mut Cocoa::new(40), Budget::rounds(3)).unwrap();
        assert_eq!(trace.rows[0].w_nnz, 0); // w starts at zero
        let last = trace.rows.last().unwrap();
        assert!(last.w_nnz > 0 && last.w_nnz <= sess.d() as u64);
        sess.shutdown();
    }

    #[test]
    fn one_shot_is_single_round() {
        let mut sess = session(2, 9);
        let trace = sess.run(&mut OneShotAvg, Budget::rounds(50)).unwrap();
        assert_eq!(trace.rows.last().unwrap().round, 1);
        assert_eq!(sess.stats().rounds, 1);
        sess.shutdown();
    }

    #[test]
    fn cocoa_beats_minibatch_per_round_at_same_h() {
        // The paper's core claim in micro: same number of coordinate
        // updates per round, but CoCoA's locally-applied updates make more
        // progress per communication round.
        let h = 40;
        let mut sess_a = session(4, 11);
        let tr_a = sess_a
            .run(&mut Cocoa::new(h), Budget::rounds(15).eval_every(15))
            .unwrap();
        let mut sess_b = session(4, 11);
        let tr_b = sess_b
            .run(&mut MinibatchCd::new(h), Budget::rounds(15).eval_every(15))
            .unwrap();
        let ga = tr_a.rows.last().unwrap().gap;
        let gb = tr_b.rows.last().unwrap().gap;
        assert!(ga < gb, "cocoa gap {ga} not better than minibatch {gb}");
        sess_a.shutdown();
        sess_b.shutdown();
    }

    #[test]
    fn aggregation_scales() {
        let avg = Aggregation::Average { beta_k: 1.0 };
        assert_eq!(avg.commit_scale(4), 0.25);
        assert_eq!(avg.sigma_prime(4), None);
        let add = Aggregation::Add;
        assert_eq!(add.commit_scale(4), 1.0);
        assert_eq!(add.sigma_prime(4), Some(4.0));
    }

    #[test]
    fn budget_constructors() {
        let b = Budget::default();
        assert_eq!(b.eval_every, 1);
        assert_eq!(b.target_gap, 0.0);
        let g = Budget::until_gap(1e-3);
        assert_eq!(g.target_gap, 1e-3);
        assert!(g.rounds >= 100_000);
        let s = Budget::until_subopt(1e-3).max_rounds(77).eval_every(0);
        assert_eq!(s.target_subopt, 1e-3);
        assert_eq!(s.rounds, 77);
        // eval_every(0) is no longer silently clamped: it is kept and
        // rejected with a typed error at validation time
        assert_eq!(s.eval_every, 0);
        assert!(matches!(s.validate(), Err(Error::InvalidBudget { .. })));
        assert!(s.eval_every(4).validate().is_ok());
        assert!(Budget::default().validate().is_ok());
    }
}
