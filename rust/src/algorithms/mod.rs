//! The Section-6 algorithms, all driven over the same [`Cluster`] runtime
//! so their communication/computation profiles are directly comparable:
//!
//! | name          | local work                   | leader update                                  |
//! |---------------|------------------------------|------------------------------------------------|
//! | cocoa         | H SDCA steps, locally applied| `w += (beta_K/K) sum dw` (Algorithm 1)         |
//! | minibatch_cd  | b=H coord updates, frozen w  | `w += (beta_b/(K H)) sum dw` [TBRS13/Yan13]    |
//! | minibatch_sgd | H subgradients, frozen w     | Pegasos step over the K·H batch [SSSSC10]      |
//! | local_sgd     | H Pegasos steps, local w     | `w += (beta/K) sum (w_k - w)`                  |
//! | naive_cd      | cocoa with H = 1             | communicate every update                       |
//! | naive_sgd     | local_sgd with H = 1         | communicate every update                       |
//! | one_shot_avg  | solve block to optimality    | single round, average models [ZDW13]           |

use anyhow::Result;

use crate::config::AlgorithmSpec;
use crate::coordinator::{Cluster, LocalWork};
use crate::telemetry::{Trace, TraceRow};

/// Stopping criteria for a run (whichever fires first).
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub rounds: u64,
    /// Stop when gap <= target_gap (0 disables).
    pub target_gap: f64,
    /// Stop when P - P* <= target_subopt (needs `p_star`; 0 disables).
    pub target_subopt: f64,
}

impl Budget {
    pub fn rounds(rounds: u64) -> Self {
        Budget { rounds, target_gap: 0.0, target_subopt: 0.0 }
    }
}

/// Drive `spec` on the cluster, evaluating every `eval_every` rounds.
/// `p_star`: reference optimum for the suboptimality axis (NaN-safe).
pub fn run(
    cluster: &mut Cluster,
    spec: &AlgorithmSpec,
    budget: Budget,
    eval_every: u64,
    p_star: Option<f64>,
    dataset_name: &str,
) -> Result<Trace> {
    let mut trace = Trace::new(
        spec.name(),
        dataset_name,
        cluster.k,
        spec.h(),
        spec.beta(),
        cluster.lambda(),
    );
    // round 0 snapshot
    record(cluster, &mut trace, 0, p_star)?;

    let k = cluster.k as f64;
    let lambda = cluster.lambda();
    let mut sgd_t: u64 = 0; // global Pegasos step counter

    let total_rounds = match spec {
        AlgorithmSpec::OneShotAvg => 1,
        _ => budget.rounds,
    };

    for round in 1..=total_rounds {
        match spec {
            AlgorithmSpec::Cocoa { h, beta_k, .. } => {
                let h = *h;
                let replies = cluster.dispatch(|_| LocalWork::DualRound { h })?;
                cluster.commit(&replies, beta_k / k)?;
            }
            AlgorithmSpec::CocoaPlus { h } => {
                let (h, k_usize) = (*h, cluster.k);
                let sigma_prime = k_usize as f64;
                let replies = cluster
                    .dispatch(|_| LocalWork::DualRoundScaled { h, sigma_prime })?;
                // beta_K = K adding: scale 1.0 (safe because the local
                // subproblems were solved with sigma' = K curvature)
                cluster.commit(&replies, 1.0)?;
            }
            AlgorithmSpec::NaiveCd => {
                let replies = cluster.dispatch(|_| LocalWork::DualRound { h: 1 })?;
                cluster.commit(&replies, 1.0 / k)?;
            }
            AlgorithmSpec::MinibatchCd { h, beta_b } => {
                let b_per_worker = *h;
                let replies =
                    cluster.dispatch(|_| LocalWork::DualBatchFrozen { b: b_per_worker })?;
                let b_total = (b_per_worker as f64) * k;
                cluster.commit(&replies, beta_b / b_total)?;
            }
            AlgorithmSpec::LocalSgd { h, beta } => {
                let (h, beta) = (*h, *beta);
                let t0 = sgd_t;
                let replies = cluster.dispatch(|_| LocalWork::SgdLocal { h, t_offset: t0 })?;
                sgd_t += h as u64;
                let mut w = cluster.w.clone();
                for r in &replies {
                    for (wv, dv) in w.iter_mut().zip(&r.dw) {
                        *wv += beta * dv / k;
                    }
                }
                cluster.set_w(w);
            }
            AlgorithmSpec::NaiveSgd => {
                let t0 = sgd_t;
                let replies =
                    cluster.dispatch(|_| LocalWork::SgdLocal { h: 1, t_offset: t0 })?;
                sgd_t += 1;
                let mut w = cluster.w.clone();
                for r in &replies {
                    for (wv, dv) in w.iter_mut().zip(&r.dw) {
                        *wv += dv / k;
                    }
                }
                cluster.set_w(w);
            }
            AlgorithmSpec::MinibatchSgd { h, beta } => {
                let (h, beta) = (*h, *beta);
                let replies = cluster.dispatch(|_| LocalWork::SgdFrozen { h })?;
                // one Pegasos step over the K*H mini-batch
                let t = round;
                let eta = 1.0 / (lambda * t as f64);
                let batch = (h as f64) * k;
                let mut w = cluster.w.clone();
                let shrink = 1.0 - eta * lambda;
                for wv in w.iter_mut() {
                    *wv *= shrink;
                }
                for r in &replies {
                    for (wv, gv) in w.iter_mut().zip(&r.dw) {
                        *wv -= eta * beta * gv / batch;
                    }
                }
                cluster.set_w(w);
            }
            AlgorithmSpec::OneShotAvg => {
                let replies = cluster.dispatch(|_| LocalWork::ExactSolve)?;
                cluster.commit(&replies, 1.0 / k)?;
            }
        }

        if round % eval_every == 0 || round == total_rounds {
            let row = record(cluster, &mut trace, round, p_star)?;
            let stop_gap = budget.target_gap > 0.0 && row.gap <= budget.target_gap;
            let stop_subopt = budget.target_subopt > 0.0
                && row.primal_subopt.is_finite()
                && row.primal_subopt <= budget.target_subopt;
            if stop_gap || stop_subopt {
                break;
            }
        }
    }
    Ok(trace)
}

fn record(
    cluster: &mut Cluster,
    trace: &mut Trace,
    round: u64,
    p_star: Option<f64>,
) -> Result<TraceRow> {
    let ev = cluster.evaluate()?;
    let row = TraceRow {
        round,
        sim_time_s: cluster.stats.sim_time_s,
        compute_time_s: cluster.stats.compute_s,
        vectors: cluster.stats.vectors,
        bytes: cluster.stats.bytes,
        inner_steps: cluster.stats.inner_steps,
        primal: ev.primal,
        dual: ev.dual,
        gap: ev.gap,
        primal_subopt: p_star.map(|p| ev.primal - p).unwrap_or(f64::NAN),
    };
    trace.push(row);
    Ok(row)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmSpec, Backend};
    use crate::data::{cov_like, Partition, PartitionStrategy};
    use crate::loss::LossKind;
    use crate::netsim::NetworkModel;
    use crate::solvers::SolverKind;

    fn cluster(k: usize, seed: u64) -> Cluster {
        let data = cov_like(80, 6, 0.1, seed);
        let part = Partition::new(PartitionStrategy::Contiguous, 80, k, 0);
        Cluster::build(
            &data,
            &part,
            LossKind::Hinge,
            0.05,
            SolverKind::Sdca,
            Backend::Native,
            "artifacts",
            NetworkModel::free(),
            seed,
        )
        .unwrap()
    }

    #[test]
    fn every_algorithm_runs_and_descends() {
        let specs = vec![
            AlgorithmSpec::Cocoa { h: 40, beta_k: 1.0, solver: SolverKind::Sdca },
            AlgorithmSpec::MinibatchCd { h: 10, beta_b: 10.0 },
            AlgorithmSpec::MinibatchSgd { h: 20, beta: 1.0 },
            AlgorithmSpec::LocalSgd { h: 20, beta: 1.0 },
            AlgorithmSpec::NaiveCd,
            AlgorithmSpec::NaiveSgd,
            AlgorithmSpec::OneShotAvg,
        ];
        for spec in specs {
            let mut cl = cluster(2, 3);
            // naive variants process one point per round; give them
            // proportionally more rounds to show progress
            let rounds = if spec.name().starts_with("naive") { 400 } else { 12 };
            let trace = run(&mut cl, &spec, Budget::rounds(rounds), 4, None, "test").unwrap();
            let p0 = trace.rows.first().unwrap().primal;
            let p_end = trace.best_primal();
            assert!(
                p_end < p0,
                "{} failed to descend: {p0} -> {p_end}",
                spec.name()
            );
            cl.shutdown();
        }
    }

    #[test]
    fn cocoa_gap_shrinks_geometrically_ish() {
        let mut cl = cluster(4, 5);
        let spec = AlgorithmSpec::Cocoa { h: 100, beta_k: 1.0, solver: SolverKind::Sdca };
        let trace = run(&mut cl, &spec, Budget::rounds(20), 1, None, "test").unwrap();
        let g0 = trace.rows[1].gap;
        let g_end = trace.rows.last().unwrap().gap;
        assert!(g_end < g0 * 0.2, "gap barely moved: {g0} -> {g_end}");
        // dual must be monotone for beta_K = 1 averaging
        for pair in trace.rows.windows(2) {
            assert!(pair[1].dual >= pair[0].dual - 1e-9);
        }
        cl.shutdown();
    }

    #[test]
    fn target_gap_stops_early() {
        let mut cl = cluster(2, 7);
        let spec = AlgorithmSpec::Cocoa { h: 200, beta_k: 1.0, solver: SolverKind::Sdca };
        let budget = Budget { rounds: 500, target_gap: 0.05, target_subopt: 0.0 };
        let trace = run(&mut cl, &spec, budget, 1, None, "test").unwrap();
        assert!(trace.rows.last().unwrap().gap <= 0.05);
        assert!((trace.rows.len() as u64) < 500);
        cl.shutdown();
    }

    #[test]
    fn one_shot_is_single_round() {
        let mut cl = cluster(2, 9);
        let trace =
            run(&mut cl, &AlgorithmSpec::OneShotAvg, Budget::rounds(50), 1, None, "test").unwrap();
        assert_eq!(trace.rows.last().unwrap().round, 1);
        assert_eq!(cl.stats.rounds, 1);
        cl.shutdown();
    }

    #[test]
    fn cocoa_beats_minibatch_per_round_at_same_h() {
        // The paper's core claim in micro: same number of coordinate
        // updates per round, but CoCoA's locally-applied updates make more
        // progress per communication round.
        let h = 40;
        let mut cl_a = cluster(4, 11);
        let cocoa = AlgorithmSpec::Cocoa { h, beta_k: 1.0, solver: SolverKind::Sdca };
        let tr_a = run(&mut cl_a, &cocoa, Budget::rounds(15), 15, None, "t").unwrap();
        let mut cl_b = cluster(4, 11);
        let mb = AlgorithmSpec::MinibatchCd { h, beta_b: 1.0 };
        let tr_b = run(&mut cl_b, &mb, Budget::rounds(15), 15, None, "t").unwrap();
        let ga = tr_a.rows.last().unwrap().gap;
        let gb = tr_b.rows.last().unwrap().gap;
        assert!(ga < gb, "cocoa gap {ga} not better than minibatch {gb}");
        cl_a.shutdown();
        cl_b.shutdown();
    }
}
