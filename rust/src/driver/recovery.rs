//! Checkpoint-based failure recovery for the round loop.
//!
//! [`run_with_recovery`] drives a run to completion like
//! [`Driver::drain`](super::Driver::drain), but survives the typed
//! connection failures the net transport reports
//! ([`Error::Timeout`](crate::Error::Timeout) /
//! [`Error::PeerLost`](crate::Error::PeerLost)): it aborts the damaged
//! round, restores every peer from the newest checkpoint via
//! [`Session::recover`], and resumes the round loop where the checkpoint
//! left it. Because checkpoints capture the full optimization state —
//! including the worker rng streams — a recovered run's trajectory is
//! bit-identical to one that never failed.
//!
//! The loop keeps its own [`CheckpointSink`] attached to every attempt,
//! and takes one eager checkpoint before the first round so a crash
//! before the first cadence checkpoint is still recoverable (it rolls
//! back to round 0). Trace rows from rounds the rollback undid are
//! discarded; the resumed driver re-evaluates them, so the assembled
//! [`Trace`] is exactly the uninterrupted one.
//!
//! Any other error — a fatal worker state, a handshake rejection, a
//! plain transport bug — propagates immediately, as does a failure
//! budget exhausted by `max_recoveries` back-to-back losses.

use crate::algorithms::Algorithm;
use crate::api::Session;
use crate::coordinator::Checkpoint;
use crate::error::{Error, Result};
use crate::telemetry::{StopReason, Trace, TraceRow};

use super::observers::{CheckpointSink, Observer};
use super::{DriverSpec, RoundEvent, RunMeta};

/// How hard to try before giving up on a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Max checkpoint restores per run. Each *successful* recovery still
    /// counts: a flapping cluster should fail loudly, not loop forever.
    pub max_recoveries: u32,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { max_recoveries: 3 }
    }
}

/// What a recovered run produced.
#[derive(Debug, Clone)]
pub struct RecoveryOutcome {
    /// The run's trace — identical to an uninterrupted run's.
    pub trace: Trace,
    /// Why the run stopped.
    pub stop: StopReason,
    /// How many checkpoint restores the run needed (0 = clean run).
    pub recoveries: u32,
}

/// Drive `algorithm` to completion, recovering from worker failures.
///
/// `make_spec` is called once per attempt ([`DriverSpec`]s own their
/// stopping rule, so a fresh one is needed per driver); it must describe
/// the same run each time, with `checkpoint_every > 0` for any rollback
/// to be cheaper than starting over. `extra` observers are re-attached
/// to every attempt and see the spliced event stream (rows of rounds a
/// rollback undid are re-emitted by the resumed driver).
pub fn run_with_recovery(
    session: &mut Session,
    algorithm: &mut dyn Algorithm,
    mut make_spec: impl FnMut() -> Result<DriverSpec>,
    policy: &RecoveryPolicy,
    extra: &mut [&mut dyn Observer],
) -> Result<RecoveryOutcome> {
    // the floor to roll back to if a round fails before the first
    // cadence checkpoint exists
    let mut last_cp: Checkpoint = session.checkpoint()?;
    let mut sink = CheckpointSink::in_memory();
    let mut rows: Vec<TraceRow> = Vec::new();
    let mut meta: Option<RunMeta> = None;
    let mut recoveries: u32 = 0;
    let mut resume_at: u64 = last_cp.round_counter;
    let stop: StopReason;

    'attempts: loop {
        let failure: Error;
        {
            let mut driver = session.drive(&mut *algorithm, make_spec()?)?;
            if resume_at > 0 {
                driver.resume_from(resume_at)?;
            }
            driver.observe(&mut sink)?;
            for obs in extra.iter_mut() {
                driver.observe(&mut **obs)?;
            }
            if meta.is_none() {
                meta = Some(driver.meta().clone());
            }
            loop {
                match driver.step() {
                    Ok(RoundEvent::Evaluated { row }) => rows.push(row),
                    Ok(RoundEvent::Stopped { reason }) => {
                        stop = reason;
                        break 'attempts;
                    }
                    Ok(_) => {}
                    Err(e) => {
                        failure = e;
                        break;
                    }
                }
            }
        }
        // only connection-level losses are recoverable; anything else
        // (fatal worker state, rejected handshake, divergence) is not a
        // failure a checkpoint can undo
        let recoverable = matches!(failure, Error::Timeout { .. } | Error::PeerLost { .. });
        if !recoverable || recoveries >= policy.max_recoveries {
            return Err(failure);
        }
        if let Some(cp) = sink.latest() {
            if cp.round_counter > last_cp.round_counter {
                last_cp = cp.clone();
            }
        }
        session.recover(&last_cp)?;
        recoveries += 1;
        resume_at = last_cp.round_counter;
        if resume_at == 0 {
            // the resumed driver redoes the round-0 snapshot; drop ours
            rows.clear();
        } else {
            rows.retain(|r| r.round <= resume_at);
        }
    }

    let meta = meta.expect("the driver ran at least once");
    let mut trace = meta.new_trace();
    for row in rows {
        trace.push(row);
    }
    Ok(RecoveryOutcome { trace, stop, recoveries })
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;
    use std::thread;
    use std::time::Duration;

    use super::*;
    use crate::algorithms::Cocoa;
    use crate::api::Trainer;
    use crate::config::{
        AlgorithmSpec, Backend, DatasetSpec, ExperimentConfig, PartitionSpec, RunSpec, RuntimeSpec,
    };
    use crate::coordinator::worker::{CoreStep, WorkerCore};
    use crate::coordinator::{native_worker_config, ToWorker};
    use crate::data::{cov_like, Partition, PartitionStrategy};
    use crate::driver::MaxRounds;
    use crate::loss::LossKind;
    use crate::netsim::NetworkModel;
    use crate::regularizers::RegularizerKind;
    use crate::solvers::SolverKind;
    use crate::transport::net::{
        decode_handshake_reply, encode_hello, read_frame, run_fingerprint, run_worker_process,
        write_frame, FrameRead, HandshakeReply, NetAddr, Sock,
    };
    use crate::transport::wire;
    use crate::transport::{NetConfig, ReconnectPolicy, TransportKind};

    const N: usize = 120;
    const D: usize = 8;
    const NOISE: f64 = 0.1;
    const SEED: u64 = 3;
    const LAMBDA: f64 = 0.05;
    const K: usize = 2;
    const H: usize = 30;
    const ROUNDS: u64 = 6;

    fn sock_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cocoa-recovery-{}-{tag}.sock", std::process::id()))
    }

    fn worker_cfg(listen: &str) -> ExperimentConfig {
        ExperimentConfig {
            dataset: DatasetSpec::CovLike { n: N, d: D, noise: NOISE, seed: SEED },
            partition: PartitionSpec { k: K, strategy: PartitionStrategy::Contiguous, seed: 0 },
            algorithm: AlgorithmSpec::Cocoa { h: H, beta_k: 1.0, solver: SolverKind::Sdca },
            loss: LossKind::Hinge,
            lambda: LAMBDA,
            regularizer: RegularizerKind::default(),
            run: RunSpec {
                rounds: ROUNDS,
                target_gap: 0.0,
                target_subopt: 0.0,
                eval_every: 1,
                seed: SEED,
                backend: Backend::Native,
            },
            runtime: RuntimeSpec::default(),
            netsim: NetworkModel::free(),
            transport: TransportKind::Net(NetConfig::new(listen)),
            artifacts_dir: "artifacts".into(),
        }
    }

    fn connect_with_retry(addr: &NetAddr) -> Sock {
        for _ in 0..400 {
            if let Ok(s) = Sock::connect(addr) {
                return s;
            }
            thread::sleep(Duration::from_millis(10));
        }
        panic!("listener never came up at {addr:?}");
    }

    /// A worker that speaks the real protocol but drops its connection —
    /// no reply, no farewell — the moment it sees its `die_at`-th Round
    /// dispatch, leaving the leader mid-round with a half-reduced update.
    fn dying_worker(listen: String, die_at: usize) {
        let addr = NetAddr::parse(&listen).unwrap();
        let mut sock = connect_with_retry(&addr);
        let data = cov_like(N, D, NOISE, SEED);
        let partition = Partition::new(PartitionStrategy::Contiguous, N, K, 0);
        let fp = run_fingerprint(
            &data,
            &partition,
            LossKind::Hinge,
            RegularizerKind::default(),
            SolverKind::Sdca,
            LAMBDA,
            SEED,
            1,
        );
        write_frame(&mut sock, &encode_hello(None, fp)).unwrap();
        let frame = match read_frame(&mut sock).unwrap() {
            FrameRead::Frame(f) => f,
            FrameRead::Eof => panic!("leader hung up during handshake"),
        };
        let slot = match decode_handshake_reply(&frame).unwrap() {
            HandshakeReply::Accept { slot } => slot,
            HandshakeReply::Reject { reason } => panic!("rejected: {reason}"),
        };
        let mut core = WorkerCore::new(native_worker_config(
            &data,
            &partition.blocks[slot],
            LossKind::Hinge,
            LAMBDA,
            RegularizerKind::default(),
            SolverKind::Sdca,
            SEED,
            slot,
            1,
        ));
        let mut rounds_seen = 0usize;
        loop {
            let payload = match read_frame(&mut sock).unwrap() {
                FrameRead::Frame(p) => p,
                FrameRead::Eof => return,
            };
            let msg = wire::decode_to_worker(&payload).unwrap();
            if matches!(msg, ToWorker::Round { .. }) {
                rounds_seen += 1;
                if rounds_seen == die_at {
                    return; // mid-round vanish: socket closes, no reply
                }
            }
            match core.handle(msg) {
                CoreStep::Continue => {}
                CoreStep::Reply(reply) => {
                    write_frame(&mut sock, &wire::encode_to_leader(&reply)).unwrap()
                }
                CoreStep::ReplyWithMetrics(reply, metrics) => {
                    write_frame(&mut sock, &wire::encode_to_leader(&reply)).unwrap();
                    write_frame(&mut sock, &wire::encode_to_leader(&metrics)).unwrap();
                }
                CoreStep::Fatal(reply) => panic!("worker went fatal: {reply:?}"),
                CoreStep::Shutdown => return,
            }
        }
    }

    fn honest_worker(listen: String) -> thread::JoinHandle<()> {
        thread::spawn(move || {
            let cfg = worker_cfg(&listen);
            run_worker_process(&cfg, &listen, &ReconnectPolicy { attempts: 60, backoff_s: 0.05 })
                .unwrap();
        })
    }

    /// The acceptance gate: kill one worker mid-round; the run recovers
    /// from the last checkpoint and finishes with the exact trajectory —
    /// every evaluated row and the final w, bit for bit — of a run that
    /// never failed.
    #[test]
    fn killed_worker_recovers_to_identical_trajectory() {
        // uninterrupted twin over counted in-proc channels
        let data = cov_like(N, D, NOISE, SEED);
        let mut twin = Trainer::on(&data)
            .workers(K)
            .lambda(LAMBDA)
            .seed(SEED)
            .transport(TransportKind::Counted)
            .build()
            .unwrap();
        let twin_trace = twin
            .run(&mut Cocoa::new(H), DriverSpec::new(MaxRounds::new(ROUNDS)))
            .unwrap();
        let twin_w: Vec<u64> = twin.w().iter().map(|x| x.to_bits()).collect();
        twin.shutdown();

        let path = sock_path("kill");
        let _ = std::fs::remove_file(&path);
        let listen = format!("uds:{}", path.display());

        // worker A dies on its 3rd Round dispatch (checkpoints land at
        // rounds 2 and 4, so the rollback target is round 2); worker B
        // stays honest throughout
        let evil = {
            let listen = listen.clone();
            thread::spawn(move || dying_worker(listen, 3))
        };
        let honest = honest_worker(listen.clone());

        let mut session = Trainer::on(&data)
            .workers(K)
            .lambda(LAMBDA)
            .seed(SEED)
            .transport(TransportKind::Net(NetConfig::new(&listen)))
            .build()
            .unwrap();

        // only now — both original workers hold slots — may the
        // replacement connect; it waits in the listener backlog until
        // recovery's heal() accepts it into the dead slot
        let replacement = honest_worker(listen.clone());

        let outcome = run_with_recovery(
            &mut session,
            &mut Cocoa::new(H),
            || Ok(DriverSpec::new(MaxRounds::new(ROUNDS)).checkpoint_every(2)),
            &RecoveryPolicy::default(),
            &mut [],
        )
        .unwrap();

        assert_eq!(outcome.recoveries, 1, "expected exactly one recovery");
        assert_eq!(outcome.stop, StopReason::MaxRounds);
        let w: Vec<u64> = session.w().iter().map(|x| x.to_bits()).collect();
        assert_eq!(w, twin_w, "recovered w must be bit-identical to the twin's");

        assert_eq!(outcome.trace.rows.len(), twin_trace.rows.len());
        for (got, want) in outcome.trace.rows.iter().zip(twin_trace.rows.iter()) {
            assert_eq!(got.round, want.round);
            assert_eq!(got.primal.to_bits(), want.primal.to_bits(), "round {}", got.round);
            assert_eq!(got.dual.to_bits(), want.dual.to_bits(), "round {}", got.round);
            assert_eq!(got.gap.to_bits(), want.gap.to_bits(), "round {}", got.round);
            assert_eq!(got.inner_steps, want.inner_steps, "round {}", got.round);
            assert_eq!(got.bytes_measured, want.bytes_measured, "round {}", got.round);
        }

        session.shutdown();
        evil.join().unwrap();
        honest.join().unwrap();
        replacement.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }

    /// A non-network error must propagate untouched: recovery only eats
    /// the typed connection-loss variants.
    #[test]
    fn non_connection_errors_propagate() {
        let data = cov_like(40, 4, NOISE, 7);
        let mut session = Trainer::on(&data).workers(2).lambda(0.1).build().unwrap();
        let err = run_with_recovery(
            &mut session,
            &mut Cocoa::new(5),
            // eval_every = 0 is rejected by the driver with a typed
            // error that has nothing to do with the network
            || Ok(DriverSpec::new(MaxRounds::new(3)).eval_every(0)),
            &RecoveryPolicy::default(),
            &mut [],
        )
        .unwrap_err();
        assert!(
            !matches!(err, Error::Timeout { .. } | Error::PeerLost { .. }),
            "unexpected: {err}"
        );
        session.shutdown();
    }

    /// A clean run through the recovery loop is exactly `Session::run`.
    #[test]
    fn clean_run_matches_plain_drain() {
        let data = cov_like(60, 6, NOISE, 11);
        let mut a = Trainer::on(&data).workers(2).lambda(0.1).seed(1).build().unwrap();
        let plain = a.run(&mut Cocoa::new(10), DriverSpec::new(MaxRounds::new(4))).unwrap();
        a.shutdown();

        let mut b = Trainer::on(&data).workers(2).lambda(0.1).seed(1).build().unwrap();
        let outcome = run_with_recovery(
            &mut b,
            &mut Cocoa::new(10),
            || Ok(DriverSpec::new(MaxRounds::new(4)).checkpoint_every(2)),
            &RecoveryPolicy::default(),
            &mut [],
        )
        .unwrap();
        b.shutdown();

        assert_eq!(outcome.recoveries, 0);
        assert_eq!(outcome.trace.rows.len(), plain.rows.len());
        for (got, want) in outcome.trace.rows.iter().zip(plain.rows.iter()) {
            assert_eq!(got.gap.to_bits(), want.gap.to_bits(), "round {}", got.round);
        }
    }
}
