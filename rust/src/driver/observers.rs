//! Observers — pluggable telemetry and persistence sinks for a
//! [`Driver`](crate::driver::Driver) run.
//!
//! The driver notifies every attached [`Observer`] of each
//! [`RoundEvent`](crate::driver::RoundEvent) in stream order, so what used
//! to be hardwired into the training loop (trace construction, CSV
//! writing, progress printing, checkpoint policy) is now a set of
//! composable sinks:
//!
//! * [`TraceSink`] — builds a [`Trace`] incrementally (what
//!   [`Session::run`](crate::Session::run) uses under the hood).
//! * [`CsvSink`] / [`JsonlSink`] — stream every evaluated row to a writer
//!   as it happens, flushed per row so the file is row-complete even if
//!   the process dies mid-run. Every *deterministic* column of two seeded
//!   runs is identical (the CI determinism gate diffs a timing-stripped
//!   JSONL artifact; the two clock columns fold in measured thread-CPU
//!   compute).
//! * [`CheckpointSink`] — receives the full [`Checkpoint`] payloads the
//!   driver captures on its `checkpoint_every` cadence and keeps the
//!   latest (optionally persisting each to a directory).
//! * [`ProgressLine`] — a live per-round status line (round, gap, wire
//!   bytes, simulated time), what `cocoa train --progress` attaches.
//! * [`EventLog`] — records the raw event stream (tests, debugging).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::coordinator::Checkpoint;
use crate::error::{Error, Result};
use crate::obs::RoundObs;
use crate::telemetry::Trace;

use super::{RoundEvent, RunMeta};

/// A passive subscriber to a driver's event stream. All hooks default to
/// no-ops except [`Observer::on_event`]; errors propagate out of
/// [`Driver::step`](crate::driver::Driver::step) and end the run.
pub trait Observer {
    /// Called once, before any event of the run is delivered.
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        let _ = meta;
        Ok(())
    }

    /// Called for every event, in stream order (the same order
    /// [`Driver::step`](crate::driver::Driver::step) returns them).
    fn on_event(&mut self, meta: &RunMeta, event: &RoundEvent) -> Result<()>;

    /// Called with the full checkpoint payload whenever the driver's
    /// `checkpoint_every` cadence captures one (the corresponding
    /// [`RoundEvent::Checkpointed`] carries only the round number, so the
    /// event stream stays small and `Copy`).
    fn on_checkpoint(&mut self, meta: &RunMeta, checkpoint: &Checkpoint) -> Result<()> {
        let _ = (meta, checkpoint);
        Ok(())
    }

    /// Called once per completed round (and once for the round-0
    /// snapshot) with everything the cluster observed about it: phase
    /// spans, per-worker metrics, ledger/socket snapshots. Default no-op,
    /// so observers that only care about the event stream are untouched.
    /// Consumed by [`SpanSink`](crate::obs::SpanSink) and
    /// [`MetricsObserver`](crate::obs::MetricsObserver).
    fn on_round_obs(&mut self, meta: &RunMeta, obs: &RoundObs) -> Result<()> {
        let _ = (meta, obs);
        Ok(())
    }

    /// Called once per completed round (and once for the round-0
    /// snapshot) with the leader's current primal iterate `w` — the model
    /// the run would serve if it stopped right now. Default no-op. `w` is
    /// a borrowed view of the leader's live vector: copy what you keep
    /// (see [`SnapshotSink`](crate::serve::SnapshotSink), which publishes
    /// round-stamped copies to concurrent scorers).
    fn on_model(&mut self, meta: &RunMeta, round: u64, w: &[f64]) -> Result<()> {
        let _ = (meta, round, w);
        Ok(())
    }
}

fn io_err(e: std::io::Error) -> Error {
    Error::Runtime { message: format!("observer sink I/O error: {e}") }
}

/// Builds a [`Trace`] incrementally from `Evaluated` events — one row per
/// evaluation, identical to what the batch wrapper returns. Take the
/// finished trace with [`TraceSink::take`] after the driver is done (or
/// dropped mid-run: the trace then holds the rows seen so far).
#[derive(Default)]
pub struct TraceSink {
    trace: Option<Trace>,
}

impl TraceSink {
    pub fn new() -> Self {
        TraceSink::default()
    }

    /// The trace built so far (None before the run started).
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Take ownership of the built trace.
    pub fn take(&mut self) -> Option<Trace> {
        self.trace.take()
    }
}

impl Observer for TraceSink {
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        self.trace = Some(meta.new_trace());
        Ok(())
    }

    fn on_event(&mut self, _meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        if let RoundEvent::Evaluated { row } = event {
            if let Some(trace) = self.trace.as_mut() {
                trace.push(*row);
            }
        }
        Ok(())
    }
}

/// Streams every evaluated row to a writer in the exact
/// [`Trace::to_csv`] format (header first), flushing when the run stops.
pub struct CsvSink<W: Write> {
    out: W,
}

impl CsvSink<std::io::BufWriter<std::fs::File>> {
    /// Stream to a file (parent directories created).
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent).map_err(io_err)?;
        }
        let file = std::fs::File::create(path).map_err(io_err)?;
        Ok(CsvSink::new(std::io::BufWriter::new(file)))
    }
}

impl<W: Write> CsvSink<W> {
    pub fn new(out: W) -> Self {
        CsvSink { out }
    }

    /// Recover the writer (e.g. the byte buffer in tests).
    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write> Observer for CsvSink<W> {
    fn on_start(&mut self, _meta: &RunMeta) -> Result<()> {
        writeln!(self.out, "{}", Trace::CSV_HEADER).map_err(io_err)
    }

    fn on_event(&mut self, _meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        match event {
            RoundEvent::Evaluated { row } => {
                // flush per row: the durability point of a streaming sink
                // is that rows survive a mid-run crash, and evaluations
                // are far too infrequent for the flush to matter
                writeln!(self.out, "{}", row.csv_line())
                    .and_then(|()| self.out.flush())
                    .map_err(io_err)
            }
            RoundEvent::Stopped { .. } => self.out.flush().map_err(io_err),
            _ => Ok(()),
        }
    }
}

/// Streams the run as JSON Lines: one meta object first, then one row
/// object per evaluation (the same objects [`Trace::to_json`] nests in
/// its `rows` array). Every deterministic column of a seeded run
/// reproduces exactly — the CI determinism gate diffs two seeded runs
/// after stripping the two measured-clock fields.
pub struct JsonlSink<W: Write> {
    out: W,
}

impl JsonlSink<std::io::BufWriter<std::fs::File>> {
    /// Stream to a file (parent directories created).
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent).map_err(io_err)?;
        }
        let file = std::fs::File::create(path).map_err(io_err)?;
        Ok(JsonlSink::new(std::io::BufWriter::new(file)))
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> Self {
        JsonlSink { out }
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write> Observer for JsonlSink<W> {
    fn on_start(&mut self, meta: &RunMeta) -> Result<()> {
        writeln!(self.out, "{}", meta.to_json_object()).map_err(io_err)
    }

    fn on_event(&mut self, _meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        match event {
            RoundEvent::Evaluated { row } => {
                // flush per row (see CsvSink): crash-durable streaming
                writeln!(self.out, "{}", row.to_json_object())
                    .and_then(|()| self.out.flush())
                    .map_err(io_err)
            }
            RoundEvent::Stopped { .. } => self.out.flush().map_err(io_err),
            _ => Ok(()),
        }
    }
}

/// Receives the checkpoints captured on the driver's `checkpoint_every`
/// cadence. Always keeps the latest in memory ([`CheckpointSink::latest`]
/// / [`CheckpointSink::take_latest`] — feed it to
/// [`Session::restore`](crate::Session::restore) to resume); with
/// [`CheckpointSink::to_dir`] every capture is also persisted as
/// `round_NNNNNN.ckpt`.
#[derive(Default)]
pub struct CheckpointSink {
    dir: Option<PathBuf>,
    latest: Option<Checkpoint>,
    saved: Vec<PathBuf>,
}

impl CheckpointSink {
    /// Keep only the latest checkpoint, in memory.
    pub fn in_memory() -> Self {
        CheckpointSink::default()
    }

    /// Also persist every captured checkpoint under `dir`.
    pub fn to_dir(dir: impl Into<PathBuf>) -> Self {
        CheckpointSink { dir: Some(dir.into()), latest: None, saved: Vec::new() }
    }

    /// The most recent checkpoint captured (None before the first).
    pub fn latest(&self) -> Option<&Checkpoint> {
        self.latest.as_ref()
    }

    /// Take ownership of the most recent checkpoint.
    pub fn take_latest(&mut self) -> Option<Checkpoint> {
        self.latest.take()
    }

    /// Paths written so far (empty for [`CheckpointSink::in_memory`]).
    pub fn saved_paths(&self) -> &[PathBuf] {
        &self.saved
    }
}

impl Observer for CheckpointSink {
    fn on_event(&mut self, _meta: &RunMeta, _event: &RoundEvent) -> Result<()> {
        Ok(())
    }

    fn on_checkpoint(&mut self, _meta: &RunMeta, checkpoint: &Checkpoint) -> Result<()> {
        if let Some(dir) = &self.dir {
            let path = dir.join(format!("round_{:06}.ckpt", checkpoint.stats.rounds));
            checkpoint
                .save(&path)
                .map_err(|e| Error::Runtime { message: format!("checkpoint save: {e:#}") })?;
            self.saved.push(path);
        }
        self.latest = Some(checkpoint.clone());
        Ok(())
    }
}

/// Render a byte count in human binary units: exact bytes below 1 KiB,
/// one decimal above (`120 B`, `1.5 KiB`, `5.1 MiB`, `3.0 GiB`).
fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut value = bytes as f64;
    let mut unit = 0;
    while value >= 1024.0 && unit + 1 < UNITS.len() {
        value /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{value:.1} {}", UNITS[unit])
    }
}

/// A live status line per evaluated round — algorithm, round, duality
/// gap, communicated bytes (measured when a measuring transport is
/// active, modeled otherwise) in human units, simulated time, and the
/// run's rounds-per-simulated-second rate — plus a final line naming the
/// stop reason. What `cocoa train --progress` attaches.
///
/// The rate is `round / sim_time_s` — derived from deterministic columns,
/// so two seeded runs print bit-identical lines.
pub struct ProgressLine<W: Write> {
    out: W,
}

impl ProgressLine<std::io::Stderr> {
    /// Print to stderr (keeps stdout clean for machine-readable output).
    pub fn stderr() -> Self {
        ProgressLine { out: std::io::stderr() }
    }
}

impl<W: Write> ProgressLine<W> {
    pub fn new(out: W) -> Self {
        ProgressLine { out }
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write> Observer for ProgressLine<W> {
    fn on_event(&mut self, meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        match event {
            RoundEvent::Evaluated { row } => {
                let rate = if row.sim_time_s > 0.0 {
                    format!("{:.2}", row.round as f64 / row.sim_time_s)
                } else {
                    "-".to_string()
                };
                writeln!(
                    self.out,
                    "{} round {:>6} | gap {:>10.3e} | {:>10} | sim {:>9.3}s | {:>8} r/s",
                    meta.algorithm,
                    row.round,
                    row.gap,
                    human_bytes(row.wire_bytes()),
                    row.sim_time_s,
                    rate,
                )
                .map_err(io_err)
            }
            RoundEvent::Stopped { reason } => {
                writeln!(self.out, "{} stopped: {}", meta.algorithm, reason).map_err(io_err)
            }
            _ => Ok(()),
        }
    }
}

/// Records the raw event stream (tests assert ordering invariants on it;
/// also handy for debugging a custom driver loop). Each event is stamped
/// with a monotonic capture time ([`EventLog::timestamps`]), so a log can
/// localize *when* in wall time a run's phases happened without any
/// system-clock dependence.
pub struct EventLog {
    /// Monotonic origin every stamp is measured from (log creation).
    origin: Instant,
    events: Vec<RoundEvent>,
    stamps: Vec<Duration>,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog { origin: Instant::now(), events: Vec::new(), stamps: Vec::new() }
    }
}

impl EventLog {
    pub fn new() -> Self {
        EventLog::default()
    }

    pub fn events(&self) -> &[RoundEvent] {
        &self.events
    }

    /// Monotonic capture offsets from log creation, one per event, in
    /// stream order — nondecreasing by construction ([`Instant`] can
    /// never go backwards).
    pub fn timestamps(&self) -> &[Duration] {
        &self.stamps
    }

    pub fn into_events(self) -> Vec<RoundEvent> {
        self.events
    }
}

impl Observer for EventLog {
    fn on_event(&mut self, _meta: &RunMeta, event: &RoundEvent) -> Result<()> {
        self.events.push(*event);
        self.stamps.push(self.origin.elapsed());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{StopReason, TraceRow};

    fn meta() -> RunMeta {
        RunMeta {
            algorithm: "cocoa".into(),
            dataset: "unit".into(),
            k: 2,
            h: 5,
            beta: 1.0,
            lambda: 0.1,
        }
    }

    fn row(round: u64) -> TraceRow {
        TraceRow {
            round,
            sim_time_s: round as f64 * 0.5,
            compute_time_s: round as f64 * 0.25,
            vectors: round * 4,
            bytes_modeled: round * 32,
            bytes_measured: round * 40,
            inner_steps: round * 10,
            primal: 0.75,
            dual: 0.25,
            gap: 0.5,
            primal_subopt: f64::NAN,
            w_nnz: 3,
            stop: StopReason::Running,
        }
    }

    #[test]
    fn trace_sink_collects_evaluated_rows() {
        let meta = meta();
        let mut sink = TraceSink::new();
        assert!(sink.trace().is_none());
        sink.on_start(&meta).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(0) }).unwrap();
        sink.on_event(&meta, &RoundEvent::RoundStarted { round: 1 }).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(1) }).unwrap();
        sink.on_event(&meta, &RoundEvent::Stopped { reason: StopReason::MaxRounds }).unwrap();
        let trace = sink.take().unwrap();
        assert_eq!(trace.algorithm, "cocoa");
        assert_eq!(trace.dataset, "unit");
        assert_eq!(trace.rows.len(), 2);
        assert_eq!(trace.rows[1].round, 1);
        assert!(sink.take().is_none());
    }

    #[test]
    fn csv_sink_streams_header_and_rows() {
        let meta = meta();
        let mut sink = CsvSink::new(Vec::new());
        sink.on_start(&meta).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(0) }).unwrap();
        sink.on_event(&meta, &RoundEvent::Checkpointed { round: 1 }).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(2) }).unwrap();
        sink.on_event(&meta, &RoundEvent::Stopped { reason: StopReason::Gap }).unwrap();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 rows, events don't leak in
        assert_eq!(lines[0], Trace::CSV_HEADER);
        assert_eq!(lines[1], row(0).csv_line());
        assert_eq!(lines[2], row(2).csv_line());
    }

    #[test]
    fn jsonl_sink_streams_meta_then_rows() {
        let meta = meta();
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_start(&meta).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(1) }).unwrap();
        sink.on_event(&meta, &RoundEvent::Stopped { reason: StopReason::MaxRounds }).unwrap();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"algorithm\": \"cocoa\""), "{}", lines[0]);
        assert_eq!(lines[1], row(1).to_json_object());
        // NaN encodes as null, not as an invalid JSON literal
        assert!(lines[1].contains("\"primal_subopt\": null"), "{}", lines[1]);
    }

    #[test]
    fn human_bytes_picks_binary_units() {
        for (bytes, expect) in [
            (0, "0 B"),
            (120, "120 B"),
            (1023, "1023 B"),
            (1024, "1.0 KiB"),
            (1536, "1.5 KiB"),
            (1_048_576, "1.0 MiB"),
            (3_221_225_472, "3.0 GiB"),
            // GiB is the largest unit: it absorbs anything bigger
            (5_497_558_138_880, "5120.0 GiB"),
        ] {
            assert_eq!(human_bytes(bytes), expect, "{bytes}");
        }
    }

    #[test]
    fn progress_line_renders_exact_human_unit_lines() {
        let meta = meta();
        let mut sink = ProgressLine::new(Vec::new());
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(0) }).unwrap();
        sink.on_event(&meta, &RoundEvent::Evaluated { row: row(3) }).unwrap();
        let mut big = row(3);
        big.bytes_measured = 153_600; // 150 KiB
        sink.on_event(&meta, &RoundEvent::Evaluated { row: big }).unwrap();
        sink.on_event(&meta, &RoundEvent::Stopped { reason: StopReason::Gap }).unwrap();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // pinned exactly: these lines are the CLI's human surface, and
        // every column is deterministic (the rate derives from round and
        // simulated time, never a wall clock)
        assert_eq!(
            lines[0],
            "cocoa round      0 | gap   5.000e-1 |        0 B | sim     0.000s |        - r/s"
        );
        assert_eq!(
            lines[1],
            "cocoa round      3 | gap   5.000e-1 |      120 B | sim     1.500s |     2.00 r/s"
        );
        assert_eq!(
            lines[2],
            "cocoa round      3 | gap   5.000e-1 |  150.0 KiB | sim     1.500s |     2.00 r/s"
        );
        assert_eq!(lines[3], "cocoa stopped: gap");
    }

    #[test]
    fn event_log_records_the_stream_in_order() {
        let meta = meta();
        let mut log = EventLog::new();
        log.on_event(&meta, &RoundEvent::Evaluated { row: row(0) }).unwrap();
        log.on_event(&meta, &RoundEvent::RoundStarted { round: 1 }).unwrap();
        log.on_event(&meta, &RoundEvent::Stopped { reason: StopReason::MaxRounds }).unwrap();
        assert_eq!(log.events().len(), 3);
        assert!(matches!(log.events()[1], RoundEvent::RoundStarted { round: 1 }));
        // one monotonic stamp per event, nondecreasing in stream order
        let stamps = log.timestamps();
        assert_eq!(stamps.len(), 3);
        assert!(stamps.windows(2).all(|w| w[0] <= w[1]), "{stamps:?}");
        assert!(matches!(
            log.into_events().pop(),
            Some(RoundEvent::Stopped { reason: StopReason::MaxRounds })
        ));
    }
}
