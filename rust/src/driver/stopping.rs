//! Composable stopping rules — the open replacement for the closed
//! [`Budget`](crate::algorithms::Budget) struct.
//!
//! A [`StoppingRule`] inspects one [`Observation`] per completed round and
//! answers "should the run end, and why". Rules compose: [`Any`] stops at
//! the first rule that fires (short-circuit OR, first-listed wins — which
//! is how the legacy `Budget` precedence *gap > subopt > max-rounds* is
//! expressed), [`All`] latches each rule as it fires and stops once every
//! rule has (AND across the whole run, not a single instant). The
//! [`StoppingRule::or`] / [`StoppingRule::and`] combinator methods build
//! these inline:
//!
//! ```
//! use cocoa::driver::stopping::{GapBelow, MaxRounds, StoppingRule};
//! // stop at gap <= 1e-3, but never run more than 500 rounds
//! let rule = GapBelow::new(1e-3).or(MaxRounds::new(500));
//! assert_eq!(rule.round_cap(), Some(500));
//! ```
//!
//! Rules that need evaluation data ([`GapBelow`], [`SuboptBelow`]) can
//! only fire at evaluated rounds — the unevaluated [`Observation`] carries
//! NaN objective fields, and NaN comparisons are false. Accounting rules
//! ([`SimTimeBelow`], [`BytesBelow`], [`MaxRounds`]) fire on any round.

use crate::algorithms::Budget;
use crate::telemetry::StopReason;

/// What a [`StoppingRule`] sees after each completed round (and once for
/// the round-0 snapshot is *not* checked — rules first run after round 1,
/// matching the legacy driver, which never stopped before doing work).
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Rounds completed so far (driver-local numbering, starting at 1).
    pub round: u64,
    /// Whether P/D/gap were computed this round. When `false` the four
    /// objective fields below are NaN and eval-based rules cannot fire.
    pub evaluated: bool,
    pub primal: f64,
    /// NaN for primal-only (SGD) methods even when evaluated.
    pub dual: f64,
    pub gap: f64,
    /// `P(w) - P*`; NaN unless evaluated *and* a reference optimum is set.
    pub primal_subopt: f64,
    /// Simulated distributed seconds so far (netsim model).
    pub sim_time_s: f64,
    /// d-dimensional vectors communicated so far.
    pub vectors: u64,
    /// Analytic bytes so far (`vectors * d * scalar width`).
    pub bytes_modeled: u64,
    /// Byte-exact wire bytes so far; 0 unless a measuring transport is
    /// configured.
    pub bytes_measured: u64,
    /// Inner coordinate/SGD steps so far, summed over workers.
    pub inner_steps: u64,
}

impl Observation {
    /// The run's best-known byte count: measured when a measuring
    /// transport is active, modeled otherwise — the same convention as
    /// [`TraceRow::wire_bytes`](crate::telemetry::TraceRow::wire_bytes).
    pub fn wire_bytes(&self) -> u64 {
        if self.bytes_measured > 0 {
            self.bytes_measured
        } else {
            self.bytes_modeled
        }
    }
}

/// A stopping criterion for a [`Driver`](crate::driver::Driver) run.
///
/// `check` is called once per completed round; returning `Some(reason)`
/// ends the run with that reason (recorded in the final trace row, the
/// cluster's checkpoint, and the `Stopped` event). Implementations may
/// keep state (`&mut self`) — [`All`] uses this to latch fired rules.
pub trait StoppingRule {
    /// Human-readable description (logs, debugging, error messages).
    fn describe(&self) -> String;

    /// Inspect the completed round; `Some(reason)` stops the run.
    fn check(&mut self, obs: &Observation) -> Option<StopReason>;

    /// The last round this rule could possibly allow, if it bounds the
    /// run at all. The driver forces an evaluation at this round so the
    /// final trace row always exists (the legacy `Budget` behavior).
    fn round_cap(&self) -> Option<u64> {
        None
    }

    /// Does this rule need
    /// [`Session::set_reference_optimum`](crate::Session::set_reference_optimum)?
    /// The driver fails fast with a typed error instead of spinning to a
    /// round cap that a NaN suboptimality can never beat.
    fn requires_reference_optimum(&self) -> bool {
        false
    }

    /// Can this rule *only* fire off a duality-gap certificate? Primal-
    /// only (SGD) methods evaluate to a NaN gap, so such a rule is dead
    /// on them; when it is also the run's only way to stop (no round
    /// cap), the driver rejects the combination instead of spinning
    /// forever. `Any` propagates with all() (one live alternative can
    /// still stop the run), `All` with any() (one dead requirement makes
    /// the conjunction unsatisfiable).
    fn requires_dual_certificate(&self) -> bool {
        false
    }

    /// Stop when *either* rule fires (first-listed wins ties).
    fn or<R>(self, other: R) -> Any
    where
        Self: Sized + 'static,
        R: StoppingRule + 'static,
    {
        Any::new(vec![Box::new(self), Box::new(other)])
    }

    /// Stop once *both* rules have fired (each latches when it first
    /// fires; they need not fire on the same round).
    fn and<R>(self, other: R) -> All
    where
        Self: Sized + 'static,
        R: StoppingRule + 'static,
    {
        All::new(vec![Box::new(self), Box::new(other)])
    }
}

impl StoppingRule for Box<dyn StoppingRule> {
    fn describe(&self) -> String {
        (**self).describe()
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        (**self).check(obs)
    }

    fn round_cap(&self) -> Option<u64> {
        (**self).round_cap()
    }

    fn requires_reference_optimum(&self) -> bool {
        (**self).requires_reference_optimum()
    }

    fn requires_dual_certificate(&self) -> bool {
        (**self).requires_dual_certificate()
    }
}

/// Stop after `n` completed rounds ([`StopReason::MaxRounds`]) — the `T`
/// of Algorithm 1, as a rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MaxRounds {
    rounds: u64,
}

impl MaxRounds {
    pub fn new(rounds: u64) -> Self {
        MaxRounds { rounds }
    }
}

impl StoppingRule for MaxRounds {
    fn describe(&self) -> String {
        format!("max_rounds({})", self.rounds)
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        (obs.round >= self.rounds).then_some(StopReason::MaxRounds)
    }

    fn round_cap(&self) -> Option<u64> {
        Some(self.rounds)
    }
}

/// Stop when the duality gap reaches `eps` ([`StopReason::Gap`]) — the
/// paper's primary certificate. Only fires at evaluated rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GapBelow {
    eps: f64,
}

impl GapBelow {
    pub fn new(eps: f64) -> Self {
        GapBelow { eps }
    }
}

impl StoppingRule for GapBelow {
    fn describe(&self) -> String {
        format!("gap<={:e}", self.eps)
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        // NaN gap (unevaluated round, or an SGD method's missing dual
        // certificate) compares false: the rule simply cannot fire
        (obs.gap <= self.eps).then_some(StopReason::Gap)
    }

    fn requires_dual_certificate(&self) -> bool {
        true
    }
}

/// Stop when `P(w) - P*` reaches `eps` ([`StopReason::Subopt`]). Needs a
/// reference optimum on the session; only fires at evaluated rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SuboptBelow {
    eps: f64,
}

impl SuboptBelow {
    pub fn new(eps: f64) -> Self {
        SuboptBelow { eps }
    }
}

impl StoppingRule for SuboptBelow {
    fn describe(&self) -> String {
        format!("subopt<={:e}", self.eps)
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        (obs.primal_subopt.is_finite() && obs.primal_subopt <= self.eps)
            .then_some(StopReason::Subopt)
    }

    fn requires_reference_optimum(&self) -> bool {
        true
    }
}

/// Keep running while the simulated distributed time stays below
/// `limit_s`; fire ([`StopReason::SimTime`]) on the first round that
/// reaches it — a wall-clock budget on the netsim axis, checked every
/// round (no evaluation needed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimTimeBelow {
    limit_s: f64,
}

impl SimTimeBelow {
    pub fn new(limit_s: f64) -> Self {
        SimTimeBelow { limit_s }
    }
}

impl StoppingRule for SimTimeBelow {
    fn describe(&self) -> String {
        format!("sim_time<{:e}s", self.limit_s)
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        (obs.sim_time_s >= self.limit_s).then_some(StopReason::SimTime)
    }
}

/// Keep running while the communicated bytes stay below `limit`; fire
/// ([`StopReason::Bytes`]) on the first round that reaches it. Uses the
/// byte-exact measured total when a measuring transport is active, the
/// analytic modeled total otherwise — so the rule works on every
/// transport and tightens automatically when real wire sizes are known.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BytesBelow {
    limit: u64,
}

impl BytesBelow {
    pub fn new(limit: u64) -> Self {
        BytesBelow { limit }
    }
}

impl StoppingRule for BytesBelow {
    fn describe(&self) -> String {
        format!("bytes<{}", self.limit)
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        (obs.wire_bytes() >= self.limit).then_some(StopReason::Bytes)
    }
}

/// Short-circuit OR: stops at the first child rule that fires, in listed
/// order (so earlier rules win ties — the legacy `Budget` precedence
/// *gap > subopt > max-rounds* is `Any([gap, subopt, max])`). An empty
/// `Any` never fires.
pub struct Any {
    rules: Vec<Box<dyn StoppingRule>>,
}

impl Any {
    pub fn new(rules: Vec<Box<dyn StoppingRule>>) -> Self {
        Any { rules }
    }

    /// Append one more alternative (keeps `a.or(b).or(c)` flat-ish when
    /// built manually).
    pub fn push(&mut self, rule: impl StoppingRule + 'static) {
        self.rules.push(Box::new(rule));
    }
}

impl StoppingRule for Any {
    fn describe(&self) -> String {
        let inner: Vec<String> = self.rules.iter().map(|r| r.describe()).collect();
        format!("any({})", inner.join(", "))
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        self.rules.iter_mut().find_map(|r| r.check(obs))
    }

    fn round_cap(&self) -> Option<u64> {
        // the run ends no later than the *tightest* child cap
        self.rules.iter().filter_map(|r| r.round_cap()).min()
    }

    fn requires_reference_optimum(&self) -> bool {
        // legacy Budget semantics: a subopt target fails fast without P*
        // even when other criteria could stop the run first
        self.rules.iter().any(|r| r.requires_reference_optimum())
    }

    fn requires_dual_certificate(&self) -> bool {
        // one alternative that does not need the gap keeps the run
        // stoppable (also covers the empty Any, which never fires)
        self.rules.iter().all(|r| r.requires_dual_certificate())
    }
}

/// Latching AND: each child rule is remembered once it first fires; the
/// run stops on the round the *last* outstanding rule fires, with that
/// rule's reason. An empty `All` never fires.
pub struct All {
    rules: Vec<Box<dyn StoppingRule>>,
    fired: Vec<Option<StopReason>>,
}

impl All {
    pub fn new(rules: Vec<Box<dyn StoppingRule>>) -> Self {
        let fired = vec![None; rules.len()];
        All { rules, fired }
    }

    /// Append one more requirement.
    pub fn push(&mut self, rule: impl StoppingRule + 'static) {
        self.rules.push(Box::new(rule));
        self.fired.push(None);
    }
}

impl StoppingRule for All {
    fn describe(&self) -> String {
        let inner: Vec<String> = self.rules.iter().map(|r| r.describe()).collect();
        format!("all({})", inner.join(", "))
    }

    fn check(&mut self, obs: &Observation) -> Option<StopReason> {
        if self.rules.is_empty() {
            return None;
        }
        let mut newly = None;
        for (rule, slot) in self.rules.iter_mut().zip(self.fired.iter_mut()) {
            if slot.is_none() {
                if let Some(reason) = rule.check(obs) {
                    *slot = Some(reason);
                    newly = Some(reason);
                }
            }
        }
        if self.fired.iter().all(|s| s.is_some()) {
            // the reason of the rule that completed the conjunction
            newly.or_else(|| self.fired.last().copied().flatten())
        } else {
            None
        }
    }

    fn round_cap(&self) -> Option<u64> {
        // bounded only if *every* requirement is bounded; then the run
        // ends no later than the loosest child cap
        let mut cap = 0u64;
        for rule in &self.rules {
            cap = cap.max(rule.round_cap()?);
        }
        if self.rules.is_empty() {
            None
        } else {
            Some(cap)
        }
    }

    fn requires_reference_optimum(&self) -> bool {
        self.rules.iter().any(|r| r.requires_reference_optimum())
    }

    fn requires_dual_certificate(&self) -> bool {
        // a conjunction with one gap-only requirement can never complete
        // on a primal-only method
        self.rules.iter().any(|r| r.requires_dual_certificate())
    }
}

/// The rules a legacy [`Budget`] describes, in its historical precedence
/// order (*gap > subopt > round cap*). Shared by the
/// [`IntoDriverSpec`](crate::driver::IntoDriverSpec) impl on `Budget`.
pub(crate) fn budget_rules(budget: &Budget) -> Any {
    let mut rules: Vec<Box<dyn StoppingRule>> = Vec::new();
    if budget.target_gap > 0.0 {
        rules.push(Box::new(GapBelow::new(budget.target_gap)));
    }
    if budget.target_subopt > 0.0 {
        rules.push(Box::new(SuboptBelow::new(budget.target_subopt)));
    }
    rules.push(Box::new(MaxRounds::new(budget.rounds)));
    Any::new(rules)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(round: u64, gap: f64, subopt: f64) -> Observation {
        Observation {
            round,
            evaluated: gap.is_finite(),
            primal: 0.5,
            dual: 0.5 - gap,
            gap,
            primal_subopt: subopt,
            sim_time_s: round as f64 * 0.25,
            vectors: round * 8,
            bytes_modeled: round * 64,
            bytes_measured: 0,
            inner_steps: round * 100,
        }
    }

    #[test]
    fn atomic_rules_fire_on_their_thresholds() {
        assert_eq!(MaxRounds::new(3).check(&obs(3, 1.0, f64::NAN)), Some(StopReason::MaxRounds));
        assert_eq!(MaxRounds::new(3).check(&obs(2, 1.0, f64::NAN)), None);
        assert_eq!(GapBelow::new(0.1).check(&obs(1, 0.05, f64::NAN)), Some(StopReason::Gap));
        assert_eq!(GapBelow::new(0.1).check(&obs(1, 0.5, f64::NAN)), None);
        // NaN gap (unevaluated round) can never fire the gap rule
        assert_eq!(GapBelow::new(0.1).check(&obs(1, f64::NAN, f64::NAN)), None);
        assert_eq!(SuboptBelow::new(0.1).check(&obs(1, 0.5, 0.05)), Some(StopReason::Subopt));
        assert_eq!(SuboptBelow::new(0.1).check(&obs(1, 0.5, f64::NAN)), None);
        assert!(SuboptBelow::new(0.1).requires_reference_optimum());
        assert!(!GapBelow::new(0.1).requires_reference_optimum());
        assert_eq!(SimTimeBelow::new(0.5).check(&obs(2, 1.0, f64::NAN)), Some(StopReason::SimTime));
        assert_eq!(SimTimeBelow::new(0.6).check(&obs(2, 1.0, f64::NAN)), None);
        assert_eq!(BytesBelow::new(128).check(&obs(2, 1.0, f64::NAN)), Some(StopReason::Bytes));
        assert_eq!(BytesBelow::new(129).check(&obs(2, 1.0, f64::NAN)), None);
    }

    #[test]
    fn bytes_rule_prefers_measured_over_modeled() {
        let mut o = obs(2, 1.0, f64::NAN);
        o.bytes_measured = 1_000; // modeled says 128, the wire says 1000
        assert_eq!(BytesBelow::new(500).check(&o), Some(StopReason::Bytes));
        o.bytes_measured = 100;
        assert_eq!(BytesBelow::new(500).check(&o), None);
    }

    #[test]
    fn any_first_listed_rule_wins_ties() {
        // gap and max-rounds both fire at round 3: gap listed first wins,
        // the legacy Budget precedence
        let mut rule = GapBelow::new(0.1).or(MaxRounds::new(3));
        assert_eq!(rule.check(&obs(3, 0.05, f64::NAN)), Some(StopReason::Gap));
        let mut rule = MaxRounds::new(3).or(GapBelow::new(0.1));
        assert_eq!(rule.check(&obs(3, 0.05, f64::NAN)), Some(StopReason::MaxRounds));
    }

    #[test]
    fn any_caps_tighten_and_all_caps_loosen() {
        let any = GapBelow::new(0.1).or(MaxRounds::new(10)).or(MaxRounds::new(7));
        assert_eq!(any.round_cap(), Some(7));
        let all = MaxRounds::new(10).and(MaxRounds::new(7));
        assert_eq!(all.round_cap(), Some(10));
        // one unbounded requirement makes the conjunction unbounded
        let all = MaxRounds::new(10).and(GapBelow::new(0.1));
        assert_eq!(all.round_cap(), None);
        assert_eq!(GapBelow::new(0.1).round_cap(), None);
    }

    #[test]
    fn all_latches_rules_across_rounds() {
        // gap fires at round 2, min-rounds at round 5: the conjunction
        // completes at round 5 even though the gap has bounced back up
        let mut rule = GapBelow::new(0.1).and(MaxRounds::new(5));
        assert_eq!(rule.check(&obs(2, 0.05, f64::NAN)), None); // gap latched
        assert_eq!(rule.check(&obs(3, 0.9, f64::NAN)), None);
        assert_eq!(rule.check(&obs(5, 0.9, f64::NAN)), Some(StopReason::MaxRounds));
    }

    #[test]
    fn combinators_propagate_reference_optimum_requirement() {
        assert!(GapBelow::new(0.1).or(SuboptBelow::new(0.1)).requires_reference_optimum());
        assert!(MaxRounds::new(5).and(SuboptBelow::new(0.1)).requires_reference_optimum());
        assert!(!GapBelow::new(0.1).or(MaxRounds::new(5)).requires_reference_optimum());
    }

    #[test]
    fn combinators_propagate_dual_certificate_requirement() {
        assert!(GapBelow::new(0.1).requires_dual_certificate());
        assert!(!MaxRounds::new(5).requires_dual_certificate());
        // Any: one live (non-gap) alternative keeps the run stoppable
        assert!(!GapBelow::new(0.1).or(MaxRounds::new(5)).requires_dual_certificate());
        assert!(GapBelow::new(0.1).or(GapBelow::new(0.2)).requires_dual_certificate());
        // All: one dead (gap-only) requirement blocks the conjunction
        assert!(MaxRounds::new(5).and(GapBelow::new(0.1)).requires_dual_certificate());
        assert!(!MaxRounds::new(5).and(SimTimeBelow::new(1.0)).requires_dual_certificate());
    }

    #[test]
    fn observation_wire_bytes_prefers_measured() {
        let mut o = obs(2, 1.0, f64::NAN);
        assert_eq!(o.wire_bytes(), o.bytes_modeled);
        o.bytes_measured = 999;
        assert_eq!(o.wire_bytes(), 999);
    }

    #[test]
    fn budget_conversion_keeps_legacy_precedence_and_cap() {
        let b = Budget::until_gap(1e-3).max_rounds(40).target_subopt(1e-2);
        let mut rules = budget_rules(&b);
        assert_eq!(rules.round_cap(), Some(40));
        assert!(rules.requires_reference_optimum());
        // both targets met on the same round: gap wins
        assert_eq!(rules.check(&obs(5, 1e-4, 1e-3)), Some(StopReason::Gap));
        let plain = budget_rules(&Budget::rounds(7));
        assert_eq!(plain.round_cap(), Some(7));
        assert!(!plain.requires_reference_optimum());
        assert!(plain.describe().contains("max_rounds(7)"));
    }

    #[test]
    fn empty_combinators_never_fire() {
        let mut any = Any::new(Vec::new());
        assert_eq!(any.check(&obs(1, 0.0, 0.0)), None);
        let mut all = All::new(Vec::new());
        assert_eq!(all.check(&obs(1, 0.0, 0.0)), None);
        assert_eq!(all.round_cap(), None);
    }
}
