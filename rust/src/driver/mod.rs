//! The step-wise round driver — the caller owns the round boundary.
//!
//! The legacy entry point buried round iteration, evaluation cadence,
//! stopping, and trace construction inside a closed batch loop. This
//! module inverts that control: [`Session::drive`](crate::Session::drive)
//! yields a [`Driver`], a resumable round state machine whose
//! [`Driver::step`] advances the run one event at a time:
//!
//! ```no_run
//! use cocoa::prelude::*;
//! use cocoa::data::cov_like;
//!
//! # fn main() -> cocoa::Result<()> {
//! let data = cov_like(1_000, 10, 0.1, 1);
//! let mut session = Trainer::on(&data).workers(2).lambda(0.05).build()?;
//! let mut algo = Cocoa::new(100);
//! let mut driver = session.drive(&mut algo, GapBelow::new(1e-3).or(MaxRounds::new(200)))?;
//! loop {
//!     match driver.step()? {
//!         RoundEvent::Evaluated { row } => println!("round {} gap {:.2e}", row.round, row.gap),
//!         RoundEvent::Stopped { reason } => { println!("done: {reason}"); break; }
//!         _ => {}
//!     }
//! }
//! # Ok(())
//! # }
//! ```
//!
//! One `step()` call returns the next [`RoundEvent`] of the run, doing a
//! round of distributed work when one is needed to produce it. The event
//! stream of a run is always, in order:
//!
//! 1. one `Evaluated` for the round-0 snapshot (skipped on resumed runs),
//! 2. per round: `RoundStarted`, then `Evaluated` if the evaluation
//!    cadence (or a firing stopping rule, or the final round) calls for
//!    it, then `Checkpointed` if the checkpoint cadence does,
//! 3. exactly one terminal `Stopped` (further `step()` calls keep
//!    returning it without re-notifying observers).
//!
//! Stopping is a composable [`StoppingRule`] (see [`stopping`]); trace
//! building, streaming persistence, progress printing, and checkpoint
//! retention are pluggable [`Observer`]s (see [`observers`]). The legacy
//! [`Budget`] converts into rules via [`IntoDriverSpec`], and
//! [`Session::run`](crate::Session::run) is now a thin wrapper that
//! drains a driver — producing bit-identical traces to the old loop.

pub mod observers;
pub mod recovery;
pub mod stopping;

pub use observers::{CheckpointSink, CsvSink, EventLog, JsonlSink, Observer, ProgressLine, TraceSink};
pub use recovery::{run_with_recovery, RecoveryOutcome, RecoveryPolicy};
pub use stopping::{
    All, Any, BytesBelow, GapBelow, MaxRounds, Observation, SimTimeBelow, StoppingRule,
    SuboptBelow,
};

use std::collections::VecDeque;

use crate::algorithms::{validate_eval_every, Algorithm, Budget, RoundCtx};
use crate::coordinator::{Cluster, Evaluation};
use crate::error::{Error, Result};
use crate::obs::RoundObs;
use crate::telemetry::{json_escape, json_f64, StopReason, Trace, TraceRow};

/// Identifying metadata of one driven run — what a [`Trace`] header
/// carries, available to observers before the first row exists.
#[derive(Debug, Clone, PartialEq)]
pub struct RunMeta {
    /// Stable algorithm name (trace/CSV labels).
    pub algorithm: String,
    /// Dataset label the session was built with.
    pub dataset: String,
    /// Worker count.
    pub k: usize,
    /// Inner steps per worker per round.
    pub h: usize,
    /// Aggregation aggressiveness recorded in traces.
    pub beta: f64,
    /// Regularization strength.
    pub lambda: f64,
}

impl RunMeta {
    /// An empty [`Trace`] carrying this metadata.
    pub fn new_trace(&self) -> Trace {
        Trace::new(
            self.algorithm.clone(),
            self.dataset.clone(),
            self.k,
            self.h,
            self.beta,
            self.lambda,
        )
    }

    /// One-line JSON object (the first line of a [`JsonlSink`] stream).
    /// The name and label are arbitrary caller strings, so they are
    /// JSON-escaped.
    pub fn to_json_object(&self) -> String {
        format!(
            "{{\"algorithm\": \"{}\", \"dataset\": \"{}\", \"k\": {}, \"h\": {}, \"beta\": {}, \"lambda\": {}}}",
            json_escape(&self.algorithm),
            json_escape(&self.dataset),
            self.k,
            self.h,
            json_f64(self.beta),
            json_f64(self.lambda),
        )
    }
}

/// One event of a driven run. `Copy` on purpose: event streams are cheap
/// to tee to any number of observers and to record wholesale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RoundEvent {
    /// Round `round`'s local work + reduce ran. Observers see it before
    /// the round's evaluation; as a [`Driver::step`] return value it
    /// means "the round ran, nothing else was due".
    RoundStarted { round: u64 },
    /// P/D/gap were evaluated and a trace row built (round 0 is the
    /// pre-work snapshot).
    Evaluated { row: TraceRow },
    /// The driver captured a checkpoint at this round boundary (the
    /// payload goes to [`Observer::on_checkpoint`]).
    Checkpointed { round: u64 },
    /// The run ended. Terminal: emitted exactly once per run.
    Stopped { reason: StopReason },
}

impl RoundEvent {
    /// Is this the terminal event of the run?
    pub fn is_stopped(&self) -> bool {
        matches!(self, RoundEvent::Stopped { .. })
    }
}

/// Everything a [`Driver`] needs beyond the algorithm: the stopping rule
/// plus the instrumentation cadences. Built explicitly, or implicitly
/// from anything implementing [`IntoDriverSpec`] (a bare rule, a legacy
/// [`Budget`]).
pub struct DriverSpec {
    stopping: Box<dyn StoppingRule>,
    eval_every: u64,
    checkpoint_every: u64,
}

impl DriverSpec {
    /// A spec stopping on `rule`, evaluating every round, never
    /// checkpointing.
    pub fn new(rule: impl StoppingRule + 'static) -> Self {
        DriverSpec { stopping: Box::new(rule), eval_every: 1, checkpoint_every: 0 }
    }

    /// Evaluate P/D/gap every `n` rounds instead of every round
    /// (validated at [`Session::drive`](crate::Session::drive): 0 is a
    /// typed [`Error::InvalidBudget`], not a silent clamp).
    pub fn eval_every(mut self, n: u64) -> Self {
        self.eval_every = n;
        self
    }

    /// Capture a checkpoint every `n` rounds and hand it to the
    /// observers' [`Observer::on_checkpoint`] hooks (0 = never, the
    /// default).
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.checkpoint_every = n;
        self
    }
}

/// Conversion into a [`DriverSpec`] — the argument type of
/// [`Session::drive`](crate::Session::drive) and
/// [`Session::run`](crate::Session::run). Implemented by `DriverSpec`
/// itself, by every [`StoppingRule`], and by the legacy [`Budget`]
/// (validated, then decomposed into `gap -> subopt -> max-rounds` rules
/// in its historical precedence order).
pub trait IntoDriverSpec {
    fn into_spec(self) -> Result<DriverSpec>;
}

impl IntoDriverSpec for DriverSpec {
    fn into_spec(self) -> Result<DriverSpec> {
        Ok(self)
    }
}

impl<S: StoppingRule + 'static> IntoDriverSpec for S {
    fn into_spec(self) -> Result<DriverSpec> {
        Ok(DriverSpec::new(self))
    }
}

impl IntoDriverSpec for Budget {
    fn into_spec(self) -> Result<DriverSpec> {
        self.validate()?;
        Ok(DriverSpec {
            stopping: Box::new(stopping::budget_rules(&self)),
            eval_every: self.eval_every,
            checkpoint_every: 0,
        })
    }
}

/// A resumable round state machine over one algorithm and one live
/// cluster. Created by [`Session::drive`](crate::Session::drive); the
/// session and algorithm stay mutably borrowed until the driver is
/// dropped.
///
/// [`Driver::step`] yields the run's events one at a time;
/// [`Driver::drain`] steps to the terminal `Stopped` and returns the
/// collected [`Trace`] (what [`Session::run`](crate::Session::run)
/// does). A paused driver can simply be dropped — the session then holds
/// a valid round boundary, ready for
/// [`Session::checkpoint`](crate::Session::checkpoint); a later driver
/// over the restored state continues the run via [`Driver::resume_from`].
pub struct Driver<'d> {
    cluster: &'d mut Cluster,
    algorithm: &'d mut dyn Algorithm,
    stopping: Box<dyn StoppingRule>,
    observers: Vec<&'d mut dyn Observer>,
    meta: RunMeta,
    p_star: Option<f64>,
    eval_every: u64,
    checkpoint_every: u64,
    /// Rounds completed, driver-local (resumed drivers start above 0).
    round: u64,
    /// Hard round bound: the algorithm's own truncation applied to the
    /// stopping rule's cap (`u64::MAX` = unbounded). The driver forces an
    /// evaluation at this round so the final trace row always exists.
    round_cap: u64,
    started: bool,
    snapshot_done: bool,
    finished: Option<StopReason>,
    queue: VecDeque<RoundEvent>,
}

impl<'d> Driver<'d> {
    pub(crate) fn new(
        cluster: &'d mut Cluster,
        algorithm: &'d mut dyn Algorithm,
        spec: DriverSpec,
        p_star: Option<f64>,
        label: &str,
    ) -> Result<Self> {
        let DriverSpec { stopping, eval_every, checkpoint_every } = spec;
        validate_eval_every(eval_every)?;
        if stopping.requires_reference_optimum() && p_star.is_none() {
            // without P* the subopt observation is NaN and the criterion
            // can never fire — fail fast instead of spinning to a cap
            return Err(Error::MissingReferenceOptimum);
        }
        if algorithm.requires_l2() && !cluster.regularizer().is_l2() {
            return Err(Error::UnsupportedRegularizer {
                regularizer: cluster.regularizer().to_string(),
                context: format!("the primal-SGD baseline {:?}", algorithm.name()),
            });
        }
        if algorithm.primal_only()
            && stopping.requires_dual_certificate()
            && stopping.round_cap().is_none()
        {
            // a gap rule is dead on a NaN-gap method; with nothing else
            // bounding the run, step() would spin forever — fail fast
            return Err(Error::InvalidBudget {
                reason: format!(
                    "stopping rule {} can only fire on a duality-gap certificate, but \
                     {} is a primal-only method (its gap is always NaN) and no round \
                     cap bounds the run — add .or(MaxRounds::new(...))",
                    stopping.describe(),
                    algorithm.name(),
                ),
            });
        }
        let round_cap = algorithm.total_rounds(stopping.round_cap().unwrap_or(u64::MAX));
        let meta = RunMeta {
            algorithm: algorithm.name().to_string(),
            dataset: label.to_string(),
            k: cluster.k,
            h: algorithm.h(),
            beta: algorithm.beta(),
            lambda: cluster.lambda(),
        };
        Ok(Driver {
            cluster,
            algorithm,
            stopping,
            observers: Vec::new(),
            meta,
            p_star,
            eval_every,
            checkpoint_every,
            round: 0,
            round_cap,
            started: false,
            snapshot_done: false,
            finished: None,
            queue: VecDeque::new(),
        })
    }

    /// The run's identifying metadata.
    pub fn meta(&self) -> &RunMeta {
        &self.meta
    }

    /// Rounds completed so far (driver-local numbering).
    pub fn rounds_completed(&self) -> u64 {
        self.round
    }

    /// `Some(reason)` once the terminal `Stopped` event has been emitted.
    pub fn finished(&self) -> Option<StopReason> {
        self.finished
    }

    /// Attach an observer. Must happen before the first [`Driver::step`]
    /// so every observer sees the complete event stream (a typed error
    /// otherwise).
    pub fn observe(&mut self, observer: &'d mut dyn Observer) -> Result<()> {
        if self.started {
            return Err(Error::Runtime {
                message: "observers must be attached before the first step() \
                          (the run's event stream has already begun)"
                    .into(),
            });
        }
        self.observers.push(observer);
        Ok(())
    }

    /// Continue a run that already completed `rounds_done` rounds (a
    /// session restored from a checkpoint): driver-local numbering starts
    /// there and the round-0 snapshot evaluation is skipped. Must be
    /// called before the first [`Driver::step`].
    pub fn resume_from(&mut self, rounds_done: u64) -> Result<()> {
        if self.started {
            return Err(Error::Runtime {
                message: "resume_from must be called before the first step()".into(),
            });
        }
        self.round = rounds_done;
        self.snapshot_done = rounds_done > 0;
        Ok(())
    }

    /// Change the evaluation cadence (adaptive callers may retune it
    /// between steps; 0 is rejected with a typed error).
    pub fn set_eval_every(&mut self, n: u64) -> Result<()> {
        validate_eval_every(n)?;
        self.eval_every = n;
        Ok(())
    }

    /// Change the checkpoint cadence (0 disables).
    pub fn set_checkpoint_every(&mut self, n: u64) {
        self.checkpoint_every = n;
    }

    /// Advance the run and return its next event (see the module docs
    /// for the exact stream grammar). After the terminal `Stopped` event,
    /// further calls return it again without re-notifying observers.
    pub fn step(&mut self) -> Result<RoundEvent> {
        if let Some(event) = self.queue.pop_front() {
            return Ok(event);
        }
        if let Some(reason) = self.finished {
            return Ok(RoundEvent::Stopped { reason });
        }
        if !self.started {
            self.started = true;
            for obs in self.observers.iter_mut() {
                obs.on_start(&self.meta)?;
            }
        }
        if !self.snapshot_done {
            // round-0 snapshot: record the starting point before any work
            // (stopping rules are not consulted here — the legacy loop
            // never stopped before doing work)
            self.snapshot_done = true;
            let ev = self.cluster.evaluate()?;
            let row = self.make_row(0, ev, StopReason::Running);
            self.notify(RoundEvent::Evaluated { row })?;
            self.notify_round_obs()?;
            return Ok(self.queue.pop_front().expect("snapshot event queued"));
        }
        if self.round >= self.round_cap {
            // nothing left to run (a zero-round budget, or a resume at or
            // past the cap): terminal without work
            return self.finish(StopReason::MaxRounds);
        }

        // --- exactly one CoCoA round ---
        self.round += 1;
        let round = self.round;
        self.notify(RoundEvent::RoundStarted { round })?;
        let ctx = RoundCtx { round, k: self.cluster.k, lambda: self.cluster.lambda() };
        {
            let algorithm = &mut *self.algorithm;
            let replies = self.cluster.dispatch(|kid| algorithm.local_work(&ctx, kid))?;
            algorithm.reduce(self.cluster, &replies, &ctx)?;
        }

        let eval_due = round % self.eval_every == 0 || round == self.round_cap;
        let mut reason: Option<StopReason> = None;
        if eval_due {
            let ev = self.cluster.evaluate()?;
            let obs = self.observation(round, Some(&ev));
            reason = self.stopping.check(&obs);
            if reason.is_none() && round == self.round_cap {
                // the algorithm truncated the run below every rule's cap
                // (single-round methods): the round budget is what ended it
                reason = Some(StopReason::MaxRounds);
            }
            let row = self.make_row(round, ev, reason.unwrap_or(StopReason::Running));
            self.notify(RoundEvent::Evaluated { row })?;
        } else {
            let obs = self.observation(round, None);
            reason = self.stopping.check(&obs);
            if let Some(r) = reason {
                // an accounting rule fired off the evaluation cadence:
                // evaluate now so the final trace row exists
                let ev = self.cluster.evaluate()?;
                let row = self.make_row(round, ev, r);
                self.notify(RoundEvent::Evaluated { row })?;
            }
        }
        // the round is now fully observed (dispatch/commit/eval spans +
        // worker metrics): drain it to the on_round_obs hooks
        self.notify_round_obs()?;
        if let Some(r) = reason {
            // record the stop on the cluster *before* any cadence
            // checkpoint below, so a checkpoint captured on the final
            // round persists the true reason, not Running
            self.cluster.last_stop = r;
        }
        if self.checkpoint_every > 0 && round % self.checkpoint_every == 0 {
            let cp = self.cluster.checkpoint()?;
            for obs in self.observers.iter_mut() {
                obs.on_checkpoint(&self.meta, &cp)?;
            }
            self.notify(RoundEvent::Checkpointed { round })?;
        }
        if let Some(r) = reason {
            return self.finish(r);
        }
        Ok(self.queue.pop_front().expect("round produced at least RoundStarted"))
    }

    /// Step until the terminal `Stopped` event, collecting every
    /// evaluated row into a [`Trace`] — the batch behavior
    /// [`Session::run`](crate::Session::run) wraps.
    pub fn drain(&mut self) -> Result<Trace> {
        let mut trace = self.meta.new_trace();
        loop {
            match self.step()? {
                RoundEvent::Evaluated { row } => trace.push(row),
                RoundEvent::Stopped { .. } => return Ok(trace),
                RoundEvent::RoundStarted { .. } | RoundEvent::Checkpointed { .. } => {}
            }
        }
    }

    fn finish(&mut self, reason: StopReason) -> Result<RoundEvent> {
        self.cluster.last_stop = reason;
        self.finished = Some(reason);
        self.notify(RoundEvent::Stopped { reason })?;
        Ok(self.queue.pop_front().expect("stop event queued"))
    }

    fn notify(&mut self, event: RoundEvent) -> Result<()> {
        for obs in self.observers.iter_mut() {
            obs.on_event(&self.meta, &event)?;
        }
        self.queue.push_back(event);
        Ok(())
    }

    /// Drain the cluster's per-round observability and fan it out. Not a
    /// [`RoundEvent`]: [`RoundObs`] is heavyweight telemetry, kept off the
    /// `Copy` event stream and delivered through its own default-no-op
    /// hook so existing observers are untouched.
    fn notify_round_obs(&mut self) -> Result<()> {
        let obs: RoundObs = self.cluster.take_round_obs();
        for o in self.observers.iter_mut() {
            o.on_round_obs(&self.meta, &obs)?;
        }
        // the model hook rides the same cadence: once per completed round
        // (and once for the round-0 snapshot), after the round's state is
        // fully committed — w is exactly what a checkpoint at this
        // boundary would persist
        let round = self.round;
        for o in self.observers.iter_mut() {
            o.on_model(&self.meta, round, &self.cluster.w)?;
        }
        Ok(())
    }

    fn make_row(&self, round: u64, ev: Evaluation, stop: StopReason) -> TraceRow {
        TraceRow {
            round,
            sim_time_s: self.cluster.stats.sim_time_s,
            compute_time_s: self.cluster.stats.compute_s,
            vectors: self.cluster.stats.vectors,
            bytes_modeled: self.cluster.stats.bytes_modeled,
            bytes_measured: self.cluster.stats.bytes_measured,
            inner_steps: self.cluster.stats.inner_steps,
            primal: ev.primal,
            dual: ev.dual,
            gap: ev.gap,
            primal_subopt: self.p_star.map(|p| ev.primal - p).unwrap_or(f64::NAN),
            w_nnz: self.cluster.w_nnz(),
            stop,
        }
    }

    fn observation(&self, round: u64, ev: Option<&Evaluation>) -> Observation {
        let stats = &self.cluster.stats;
        let (primal, dual, gap) = match ev {
            Some(e) => (e.primal, e.dual, e.gap),
            None => (f64::NAN, f64::NAN, f64::NAN),
        };
        Observation {
            round,
            evaluated: ev.is_some(),
            primal,
            dual,
            gap,
            primal_subopt: match (ev, self.p_star) {
                (Some(e), Some(p)) => e.primal - p,
                _ => f64::NAN,
            },
            sim_time_s: stats.sim_time_s,
            vectors: stats.vectors,
            bytes_modeled: stats.bytes_modeled,
            bytes_measured: stats.bytes_measured,
            inner_steps: stats.inner_steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Cocoa;
    use crate::api::{Session, Trainer};
    use crate::data::cov_like;
    use crate::loss::LossKind;

    fn session(k: usize, seed: u64) -> Session {
        let data = cov_like(80, 6, 0.1, seed);
        Trainer::on(&data)
            .workers(k)
            .loss(LossKind::Hinge)
            .lambda(0.05)
            .seed(seed)
            .label("driver_unit")
            .build()
            .unwrap()
    }

    #[test]
    fn step_stream_matches_the_documented_grammar() {
        let mut sess = session(2, 3);
        let mut algo = Cocoa::new(20);
        let mut driver = sess.drive(&mut algo, MaxRounds::new(3)).unwrap();
        // snapshot first
        let first = driver.step().unwrap();
        assert!(matches!(first, RoundEvent::Evaluated { row } if row.round == 0));
        // then RoundStarted/Evaluated pairs, terminated by one Stopped
        let mut events = vec![first];
        loop {
            let ev = driver.step().unwrap();
            events.push(ev);
            if ev.is_stopped() {
                break;
            }
        }
        assert!(
            matches!(events.last(), Some(RoundEvent::Stopped { reason: StopReason::MaxRounds })),
            "{events:?}"
        );
        let rounds: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                RoundEvent::RoundStarted { round } => Some(*round),
                _ => None,
            })
            .collect();
        assert_eq!(rounds, vec![1, 2, 3]);
        assert_eq!(driver.rounds_completed(), 3);
        assert_eq!(driver.finished(), Some(StopReason::MaxRounds));
        // terminal event is idempotent
        assert!(driver.step().unwrap().is_stopped());
        drop(driver);
        sess.shutdown();
    }

    #[test]
    fn zero_round_budget_stops_without_work() {
        let mut sess = session(2, 5);
        let mut algo = Cocoa::new(10);
        let mut driver = sess.drive(&mut algo, MaxRounds::new(0)).unwrap();
        assert!(matches!(driver.step().unwrap(), RoundEvent::Evaluated { row } if row.round == 0));
        assert!(matches!(
            driver.step().unwrap(),
            RoundEvent::Stopped { reason: StopReason::MaxRounds }
        ));
        assert_eq!(driver.rounds_completed(), 0);
        drop(driver);
        assert_eq!(sess.stats().rounds, 0);
        sess.shutdown();
    }

    #[test]
    fn sim_time_rule_stops_off_the_eval_cadence_with_a_final_row() {
        let data = cov_like(60, 5, 0.1, 7);
        let mut sess = Trainer::on(&data)
            .workers(2)
            .lambda(0.05)
            .network(crate::netsim::NetworkModel {
                latency_s: 1.0,
                bandwidth_bps: f64::INFINITY,
                bytes_per_scalar: 8,
            })
            .seed(7)
            .build()
            .unwrap();
        // every round costs >= 1 simulated second; the budget allows ~3.
        // eval_every(100) means no round is on the evaluation cadence, so
        // the stop must force the final evaluation itself.
        let spec = DriverSpec::new(SimTimeBelow::new(3.0)).eval_every(100);
        let mut algo = Cocoa::new(5);
        let mut driver = sess.drive(&mut algo, spec).unwrap();
        let trace = driver.drain().unwrap();
        drop(driver);
        assert_eq!(trace.rows.len(), 2, "snapshot + forced final row");
        let last = trace.rows.last().unwrap();
        assert_eq!(last.stop, StopReason::SimTime);
        assert!(last.sim_time_s >= 3.0);
        assert_eq!(sess.checkpoint().unwrap().stop, StopReason::SimTime);
        sess.shutdown();
    }

    #[test]
    fn observers_must_attach_and_resume_before_first_step() {
        let mut sess = session(2, 9);
        let mut algo = Cocoa::new(10);
        let mut log = EventLog::new();
        let mut driver = sess.drive(&mut algo, MaxRounds::new(2)).unwrap();
        driver.step().unwrap();
        assert!(matches!(driver.observe(&mut log), Err(Error::Runtime { .. })));
        assert!(matches!(driver.resume_from(5), Err(Error::Runtime { .. })));
        assert!(matches!(driver.set_eval_every(0), Err(Error::InvalidBudget { .. })));
        drop(driver);
        sess.shutdown();
    }

    #[test]
    fn run_meta_json_object_is_stable() {
        let meta = RunMeta {
            algorithm: "cocoa".into(),
            dataset: "cov".into(),
            k: 4,
            h: 100,
            beta: 1.0,
            lambda: 1e-4,
        };
        let json = meta.to_json_object();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"algorithm\": \"cocoa\""));
        assert!(json.contains("\"lambda\": 0.0001"));
        let trace = meta.new_trace();
        assert_eq!(trace.algorithm, "cocoa");
        assert_eq!(trace.k, 4);

        // labels are arbitrary caller strings: quotes must be escaped,
        // not corrupt the JSONL meta line
        let hostile = RunMeta { dataset: "rcv1 \"full\"".into(), ..meta };
        assert!(
            hostile.to_json_object().contains("\"dataset\": \"rcv1 \\\"full\\\"\""),
            "{}",
            hostile.to_json_object()
        );
    }
}
