//! The `cocoa serve` endpoint: a [`ScoreServer`] answering the scoring
//! protocol of [`serve::wire`](super::wire) over TCP or UDS, and the
//! matching [`ScoreClient`].
//!
//! The server reuses the net-transport plumbing wholesale — `NetAddr` /
//! `NetListener` / `Sock` and the length-prefixed `write_frame` /
//! `read_frame` — and follows the `MetricsServer` shape: one background
//! thread polling a nonblocking listener, connections served one at a
//! time. v1 limitations, by design: no concurrent connections (a scoring
//! client finishes its batch exchange and disconnects), and a ~1 s
//! per-read deadline inside a connection, so an idle client is dropped
//! rather than wedging the accept loop (reconnect to resume). Scoring
//! reads model state only through a [`Scorer`]'s snapshot handle, so a
//! server attached to a live training run never perturbs it.

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::wire::{
    decode_score_frame, encode_score_accept, encode_score_hello, encode_score_reject,
    encode_score_reply, encode_score_request, RemoteScores, ScoreBatch, ScoreFrame,
    ScoreIdentity,
};
use super::Scorer;
use crate::data::Features;
use crate::error::{Error, Result};
use crate::transport::net::{
    read_frame, write_frame, FrameRead, NetAddr, NetListener, ReconnectPolicy, Sock,
};

/// How long one in-connection read may stall before the connection is
/// dropped (keeps a dead or idle client from wedging the single-threaded
/// accept loop).
const READ_TIMEOUT: Duration = Duration::from_secs(1);

/// A live scoring endpoint; dropping it stops the listener thread.
pub struct ScoreServer {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    addr: String,
    served: Arc<AtomicU64>,
}

impl ScoreServer {
    /// Bind `addr` (`tcp:host:port` or `uds:/path`) and serve `scorer`
    /// until the server is dropped or [`ScoreServer::shutdown`] runs.
    /// The scorer may be [`Scorer::live`] over a training run's
    /// [`SnapshotHandle`](super::SnapshotHandle) or [`Scorer::frozen`]
    /// over a checkpoint-restored model — the protocol is identical.
    pub fn serve(addr: &str, scorer: Scorer) -> Result<ScoreServer> {
        let parsed = NetAddr::parse(addr)?;
        let listener = NetListener::bind(&parsed)?;
        listener.set_nonblocking(true).map_err(|e| Error::Transport {
            message: format!("score listener nonblocking failed: {e}"),
        })?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let (stop_t, served_t) = (Arc::clone(&stop), Arc::clone(&served));
        let handle = std::thread::Builder::new()
            .name("cocoa-score".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok(sock) => serve_connection(sock, &scorer, &served_t),
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
            })
            .map_err(|e| Error::Transport {
                message: format!("score server thread spawn failed: {e}"),
            })?;
        Ok(ScoreServer { stop, handle: Some(handle), addr: addr.to_string(), served })
    }

    /// The address the server was bound on, as given.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Total margins answered so far (across all connections) — the
    /// counter behind the `serve_` perf workloads and the CI smoke gate.
    pub fn predictions_served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the listener thread. In-flight reads
    /// finish within the [`READ_TIMEOUT`] deadline.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ScoreServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve one client: handshake, then request/reply until the client
/// closes (or errs, or stalls past the read deadline). All failures end
/// the connection — a misbehaving client must never take the server
/// down.
fn serve_connection(mut sock: Sock, scorer: &Scorer, served: &AtomicU64) {
    let _ = sock.set_read_timeout(Some(READ_TIMEOUT));
    // handshake: the snapshot read here fixes the identity the client
    // binds to; margins still track later publications (live serving)
    let snap = scorer.snapshot();
    let hello = match read_frame(&mut sock) {
        Ok(FrameRead::Frame(buf)) => match decode_score_frame(&buf) {
            Ok(ScoreFrame::Hello(id)) => id,
            Ok(_) | Err(_) => {
                let _ = write_frame(&mut sock, &encode_score_reject("expected a score hello"));
                return;
            }
        },
        Ok(FrameRead::Eof) | Err(_) => return,
    };
    let mismatch = if hello.d != 0 && hello.d != snap.d() {
        Some(format!("width mismatch: client expects d={}, serving d={}", hello.d, snap.d()))
    } else if !hello.fingerprint.is_empty() && hello.fingerprint != snap.fingerprint {
        Some(format!(
            "dataset fingerprint mismatch: client expects {:?}, serving {:?}",
            hello.fingerprint, snap.fingerprint
        ))
    } else if !hello.loss.is_empty() && hello.loss != snap.loss {
        Some(format!(
            "loss mismatch: client expects {:?}, serving {:?}",
            hello.loss, snap.loss
        ))
    } else {
        None
    };
    if let Some(reason) = mismatch {
        let _ = write_frame(&mut sock, &encode_score_reject(&reason));
        return;
    }
    let accepted = ScoreIdentity {
        d: snap.d(),
        fingerprint: snap.fingerprint.clone(),
        loss: snap.loss.clone(),
    };
    if write_frame(&mut sock, &encode_score_accept(&accepted)).is_err() {
        return;
    }

    loop {
        let batch = match read_frame(&mut sock) {
            Ok(FrameRead::Frame(buf)) => match decode_score_frame(&buf) {
                Ok(ScoreFrame::Request(batch)) => batch,
                Ok(_) => {
                    let _ = write_frame(
                        &mut sock,
                        &encode_score_reject("expected a score request"),
                    );
                    return;
                }
                Err(e) => {
                    let _ = write_frame(&mut sock, &encode_score_reject(&e.to_string()));
                    return;
                }
            },
            Ok(FrameRead::Eof) | Err(_) => return,
        };
        // re-read per request so a live run's latest snapshot answers
        let snap = scorer.snapshot();
        let features = match batch.into_features(snap.d()) {
            Ok(f) => f,
            Err(e) => {
                let _ = write_frame(&mut sock, &encode_score_reject(&e.to_string()));
                return;
            }
        };
        let scored = match scorer.score_batch(&features) {
            Ok(s) => s,
            Err(e) => {
                let _ = write_frame(&mut sock, &encode_score_reject(&e.to_string()));
                return;
            }
        };
        served.fetch_add(scored.margins.len() as u64, Ordering::Relaxed);
        let reply = RemoteScores {
            epoch: scored.epoch,
            round: scored.round,
            margins: scored.margins,
        };
        if write_frame(&mut sock, &encode_score_reply(&reply)).is_err() {
            return;
        }
    }
}

/// A connected scoring client. One handshake binds it to the served
/// model's identity; [`ScoreClient::score`] then answers batches until
/// the client is dropped (closing the connection).
pub struct ScoreClient {
    sock: Sock,
    identity: ScoreIdentity,
}

impl ScoreClient {
    /// Connect to `addr` and handshake with `expect` (see
    /// [`ScoreIdentity::any`] for an unconstrained bind). A server-side
    /// identity mismatch surfaces as a typed [`Error::Handshake`]
    /// carrying the server's reason.
    pub fn connect(addr: &str, expect: &ScoreIdentity) -> Result<ScoreClient> {
        let parsed = NetAddr::parse(addr)?;
        let sock = Sock::connect(&parsed).map_err(|e| Error::Transport {
            message: format!("score connect to {addr} failed: {e}"),
        })?;
        Self::handshake(sock, expect)
    }

    /// [`ScoreClient::connect`] with bounded retry (exponential backoff,
    /// same schedule as worker reconnects) — for clients racing a server
    /// that is still binding, e.g. the CI smoke scoring a training run
    /// it just launched. Handshake *rejects* are not retried: the server
    /// is up and will keep saying no.
    pub fn connect_with_retry(
        addr: &str,
        expect: &ScoreIdentity,
        attempts: u32,
        backoff_s: f64,
    ) -> Result<ScoreClient> {
        let policy = ReconnectPolicy { attempts: attempts.max(1), backoff_s };
        let mut failures = 0u32;
        loop {
            match Self::connect(addr, expect) {
                Ok(client) => return Ok(client),
                Err(e @ Error::Handshake { .. }) => return Err(e),
                Err(e) => {
                    failures += 1;
                    if failures >= policy.attempts {
                        return Err(e);
                    }
                    std::thread::sleep(policy.delay(failures));
                }
            }
        }
    }

    fn handshake(mut sock: Sock, expect: &ScoreIdentity) -> Result<ScoreClient> {
        let _ = sock.set_read_timeout(Some(Duration::from_secs(30)));
        write_frame(&mut sock, &encode_score_hello(expect)).map_err(|e| Error::Transport {
            message: format!("score hello write failed: {e}"),
        })?;
        match read_frame(&mut sock) {
            Ok(FrameRead::Frame(buf)) => match decode_score_frame(&buf) {
                Ok(ScoreFrame::Accept(identity)) => Ok(ScoreClient { sock, identity }),
                Ok(ScoreFrame::Reject(reason)) => Err(Error::Handshake { reason }),
                Ok(_) => Err(Error::Handshake {
                    reason: "server answered the hello with a non-handshake frame".into(),
                }),
                Err(e) => Err(Error::Handshake { reason: format!("undecodable reply: {e}") }),
            },
            Ok(FrameRead::Eof) => Err(Error::Handshake {
                reason: "server closed the connection during the handshake".into(),
            }),
            Err(e) => Err(Error::Transport {
                message: format!("score handshake read failed: {e}"),
            }),
        }
    }

    /// The identity the server accepted with (its actual `d`,
    /// fingerprint, and loss token — useful after a wildcard hello).
    pub fn identity(&self) -> &ScoreIdentity {
        &self.identity
    }

    /// Score every row of `features` remotely; margins come back in row
    /// order, stamped with the answering snapshot's round and epoch.
    pub fn score(&mut self, features: &Features) -> Result<RemoteScores> {
        let batch = ScoreBatch::from_features(features);
        write_frame(&mut self.sock, &encode_score_request(&batch)).map_err(|e| {
            Error::Score { message: format!("score request write failed: {e}") }
        })?;
        match read_frame(&mut self.sock) {
            Ok(FrameRead::Frame(buf)) => match decode_score_frame(&buf) {
                Ok(ScoreFrame::Reply(scores)) => {
                    if scores.margins.len() != features.rows() {
                        return Err(Error::Score {
                            message: format!(
                                "server answered {} margins for {} rows",
                                scores.margins.len(),
                                features.rows()
                            ),
                        });
                    }
                    Ok(scores)
                }
                Ok(ScoreFrame::Reject(reason)) => Err(Error::Score { message: reason }),
                Ok(_) => Err(Error::Score {
                    message: "server answered a request with a non-reply frame".into(),
                }),
                Err(e) => Err(Error::Score { message: format!("undecodable reply: {e}") }),
            },
            Ok(FrameRead::Eof) => Err(Error::Score {
                message: "server closed the connection mid-exchange".into(),
            }),
            Err(e) => Err(Error::Score { message: format!("score reply read failed: {e}") }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;
    use crate::serve::ModelSnapshot;

    fn uds_addr(tag: &str) -> (std::path::PathBuf, String) {
        let dir = std::env::temp_dir().join(format!("cocoa_serve_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("score.sock");
        let addr = format!("uds:{}", path.display());
        (dir, addr)
    }

    fn snap(w: Vec<f64>) -> ModelSnapshot {
        ModelSnapshot {
            epoch: 4,
            round: 17,
            w,
            loss: "hinge".into(),
            regularizer: "l2".into(),
            fingerprint: "fp-test".into(),
        }
    }

    #[test]
    fn remote_margins_match_local_scoring_bit_for_bit() {
        let (dir, addr) = uds_addr("roundtrip");
        let data = cov_like(30, 8, 0.4, 3);
        let w: Vec<f64> = (0..8).map(|j| 0.3 * (j as f64 - 4.0)).collect();
        let local = Scorer::frozen(snap(w.clone()))
            .score_batch(&data.features)
            .unwrap();
        let server = ScoreServer::serve(&addr, Scorer::frozen(snap(w))).unwrap();

        let mut client =
            ScoreClient::connect_with_retry(&addr, &ScoreIdentity::any(), 100, 0.01).unwrap();
        assert_eq!(client.identity().d, 8);
        assert_eq!(client.identity().fingerprint, "fp-test");
        assert_eq!(client.identity().loss, "hinge");
        let remote = client.score(&data.features).unwrap();
        assert_eq!(remote.round, 17);
        assert_eq!(remote.epoch, 4);
        assert_eq!(remote.margins.len(), local.margins.len());
        for (a, b) in remote.margins.iter().zip(&local.margins) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(server.predictions_served(), 30);

        // a second batch on the same connection
        let again = client.score(&data.features).unwrap();
        assert_eq!(again.margins.len(), 30);
        assert_eq!(server.predictions_served(), 60);

        drop(client);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identity_mismatches_get_typed_rejects() {
        let (dir, addr) = uds_addr("reject");
        let server = ScoreServer::serve(&addr, Scorer::frozen(snap(vec![0.0; 6]))).unwrap();

        // wrong fingerprint
        let expect = ScoreIdentity { d: 0, fingerprint: "other".into(), loss: String::new() };
        let err = ScoreClient::connect_with_retry(&addr, &expect, 100, 0.01).unwrap_err();
        match err {
            Error::Handshake { reason } => assert!(reason.contains("fingerprint"), "{reason}"),
            other => panic!("{other}"),
        }
        // wrong loss token
        let expect = ScoreIdentity { d: 0, fingerprint: String::new(), loss: "squared".into() };
        let err = ScoreClient::connect_with_retry(&addr, &expect, 100, 0.01).unwrap_err();
        match err {
            Error::Handshake { reason } => assert!(reason.contains("loss"), "{reason}"),
            other => panic!("{other}"),
        }
        // wrong width
        let expect = ScoreIdentity { d: 9, fingerprint: String::new(), loss: String::new() };
        let err = ScoreClient::connect_with_retry(&addr, &expect, 100, 0.01).unwrap_err();
        match err {
            Error::Handshake { reason } => assert!(reason.contains("width"), "{reason}"),
            other => panic!("{other}"),
        }
        // matching identity still binds after the rejects
        let ok = ScoreIdentity { d: 6, fingerprint: "fp-test".into(), loss: "hinge".into() };
        let client = ScoreClient::connect_with_retry(&addr, &ok, 100, 0.01).unwrap();
        drop(client);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_width_batch_is_rejected_not_served() {
        let (dir, addr) = uds_addr("badbatch");
        let server = ScoreServer::serve(&addr, Scorer::frozen(snap(vec![0.0; 4]))).unwrap();
        let mut client =
            ScoreClient::connect_with_retry(&addr, &ScoreIdentity::any(), 100, 0.01).unwrap();
        // 8-wide rows against a 4-wide model: typed scoring error with
        // the server's reason, not a hang or a panic
        let wide = cov_like(5, 8, 1.0, 1);
        let err = client.score(&wide.features).unwrap_err();
        match err {
            Error::Score { message } => assert!(message.contains("out of range"), "{message}"),
            other => panic!("{other}"),
        }
        drop(client);
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
