//! The scoring wire protocol: request/reply frames riding the same
//! versioned 16-byte header (`MAGIC` + `WIRE_VERSION` + tag) and the
//! same length-prefixed framing as the training transport, so a peer
//! from an incompatible build fails with a typed
//! [`WireError::BadVersion`] before any payload is interpreted.
//!
//! Exchange, in order:
//!
//! 1. client -> server [`ScoreFrame::Hello`] — the identity the client
//!    expects (`d` / dataset fingerprint / loss token; `0` or `""` means
//!    "any").
//! 2. server -> client [`ScoreFrame::Accept`] with the served model's
//!    actual identity, or [`ScoreFrame::Reject`] with a typed reason
//!    (fingerprint mismatch, loss mismatch, width mismatch).
//! 3. client -> server [`ScoreFrame::Request`] — a CSR batch; server ->
//!    client [`ScoreFrame::Reply`] — margins stamped with the snapshot's
//!    round and epoch. Repeat until the client closes.
//!
//! Tags live in the `0xE_` block (training frames use `0x0_`/`0x8_`,
//! net handshake `0xF_`), so a scoring frame accidentally delivered to a
//! training decoder is an [`WireError::UnknownTag`], never a
//! misinterpretation.

use crate::data::{CsrMatrix, Features};
use crate::error::Error;
use crate::transport::wire::{decode_header, encode_header, Reader, WireError};

pub(crate) const TAG_SCORE_HELLO: u8 = 0xE0;
pub(crate) const TAG_SCORE_ACCEPT: u8 = 0xE1;
pub(crate) const TAG_SCORE_REJECT: u8 = 0xE2;
pub(crate) const TAG_SCORE_REQUEST: u8 = 0xE3;
pub(crate) const TAG_SCORE_REPLY: u8 = 0xE4;

type WireResult<T> = std::result::Result<T, WireError>;

/// What a scoring peer claims (hello) or serves (accept): feature
/// width, dataset fingerprint, loss token. In a hello, `d = 0` and
/// empty strings are wildcards — a client that doesn't know the
/// training identity can still bind, but one that states an identity
/// gets a typed reject instead of silently-wrong margins.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScoreIdentity {
    pub d: usize,
    pub fingerprint: String,
    pub loss: String,
}

impl ScoreIdentity {
    /// A hello that binds to whatever the server serves.
    pub fn any() -> ScoreIdentity {
        ScoreIdentity { d: 0, fingerprint: String::new(), loss: String::new() }
    }
}

/// A batch of rows to score, in CSR form (batch-local `indptr`).
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreBatch {
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl ScoreBatch {
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// CSR view of `features` rows (dense rows shed their exact-zero
    /// entries — `w . x` is unchanged, and the server rebuilds a sparse
    /// matrix anyway).
    pub fn from_features(features: &Features) -> ScoreBatch {
        let mut batch =
            ScoreBatch { indptr: vec![0], indices: Vec::new(), values: Vec::new() };
        for i in 0..features.rows() {
            match features {
                Features::Sparse(m) => {
                    let (idx, val) = m.row_view(i);
                    batch.indices.extend_from_slice(idx);
                    batch.values.extend_from_slice(val);
                }
                Features::Dense(m) => {
                    for (c, &v) in m.row(i).iter().enumerate() {
                        if v.to_bits() != 0 {
                            batch.indices.push(c as u32);
                            batch.values.push(v);
                        }
                    }
                }
            }
            batch.indptr.push(batch.values.len());
        }
        batch
    }

    /// Validate against the served width and build a scorable matrix.
    /// Typed [`Error::Score`] on out-of-range or non-increasing indices
    /// — a malformed batch must never panic the server.
    pub fn into_features(self, d: usize) -> Result<Features, Error> {
        for row in self.indptr.windows(2) {
            let mut prev: Option<u32> = None;
            for &c in &self.indices[row[0]..row[1]] {
                if c as usize >= d {
                    return Err(Error::Score {
                        message: format!("batch column {c} out of range for d={d}"),
                    });
                }
                if prev.is_some_and(|p| c <= p) {
                    return Err(Error::Score {
                        message: "batch row indices must be strictly increasing".into(),
                    });
                }
                prev = Some(c);
            }
        }
        let rows = self.rows();
        Ok(Features::Sparse(CsrMatrix::from_validated_parts(
            rows,
            d,
            self.indptr,
            self.indices,
            self.values,
        )))
    }
}

/// Margins answered by a remote scorer, stamped with the snapshot that
/// produced them (same stamps as a local
/// [`ScoredBatch`](crate::serve::ScoredBatch)).
#[derive(Debug, Clone, PartialEq)]
pub struct RemoteScores {
    pub epoch: u64,
    pub round: u64,
    pub margins: Vec<f64>,
}

/// One decoded scoring frame (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum ScoreFrame {
    Hello(ScoreIdentity),
    Accept(ScoreIdentity),
    Reject(String),
    Request(ScoreBatch),
    Reply(RemoteScores),
}

fn put_str(s: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn take_str(r: &mut Reader<'_>, what: &'static str) -> WireResult<String> {
    let len = r.elems(what)?;
    let raw = r.take(len, what)?;
    String::from_utf8(raw.to_vec()).map_err(|_| WireError::Malformed { what })
}

fn identity_payload(id: &ScoreIdentity, out: &mut Vec<u8>) {
    out.extend_from_slice(&(id.d as u32).to_le_bytes());
    put_str(&id.fingerprint, out);
    put_str(&id.loss, out);
}

fn identity_from(r: &mut Reader<'_>) -> WireResult<ScoreIdentity> {
    let d = r.u32("score identity d")? as usize;
    let fingerprint = take_str(r, "score identity fingerprint")?;
    let loss = take_str(r, "score identity loss")?;
    Ok(ScoreIdentity { d, fingerprint, loss })
}

pub(crate) fn encode_score_hello(id: &ScoreIdentity) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + id.fingerprint.len() + id.loss.len());
    encode_header(TAG_SCORE_HELLO, 0, 0, &mut out);
    identity_payload(id, &mut out);
    out
}

pub(crate) fn encode_score_accept(id: &ScoreIdentity) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + id.fingerprint.len() + id.loss.len());
    encode_header(TAG_SCORE_ACCEPT, 0, 0, &mut out);
    identity_payload(id, &mut out);
    out
}

pub(crate) fn encode_score_reject(reason: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(20 + reason.len());
    encode_header(TAG_SCORE_REJECT, 0, 0, &mut out);
    put_str(reason, &mut out);
    out
}

pub(crate) fn encode_score_request(batch: &ScoreBatch) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(28 + 4 * batch.rows() + 12 * batch.nnz());
    encode_header(TAG_SCORE_REQUEST, 0, 0, &mut out);
    out.extend_from_slice(&(batch.rows() as u32).to_le_bytes());
    for row in batch.indptr.windows(2) {
        out.extend_from_slice(&((row[1] - row[0]) as u32).to_le_bytes());
    }
    out.extend_from_slice(&(batch.nnz() as u32).to_le_bytes());
    for (&c, &v) in batch.indices.iter().zip(&batch.values) {
        out.extend_from_slice(&c.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub(crate) fn encode_score_reply(scores: &RemoteScores) -> Vec<u8> {
    let mut out = Vec::with_capacity(28 + 8 * scores.margins.len());
    encode_header(TAG_SCORE_REPLY, 0, scores.round, &mut out);
    out.extend_from_slice(&scores.epoch.to_le_bytes());
    out.extend_from_slice(&(scores.margins.len() as u32).to_le_bytes());
    for &m in &scores.margins {
        out.extend_from_slice(&m.to_le_bytes());
    }
    out
}

/// Decode one scoring frame of either direction; typed [`WireError`] on
/// anything else (including training-protocol frames).
pub(crate) fn decode_score_frame(buf: &[u8]) -> WireResult<ScoreFrame> {
    let (h, mut r) = decode_header(buf)?;
    let frame = match h.tag {
        TAG_SCORE_HELLO => ScoreFrame::Hello(identity_from(&mut r)?),
        TAG_SCORE_ACCEPT => ScoreFrame::Accept(identity_from(&mut r)?),
        TAG_SCORE_REJECT => ScoreFrame::Reject(take_str(&mut r, "score reject reason")?),
        TAG_SCORE_REQUEST => {
            let rows = r.elems("score request rows")?;
            let mut indptr = Vec::with_capacity(rows + 1);
            indptr.push(0usize);
            let mut total = 0usize;
            for _ in 0..rows {
                let len = r.elems("score request row length")?;
                total += len;
                if total > crate::transport::wire::MAX_WIRE_ELEMS {
                    return Err(WireError::Oversized {
                        declared: total as u64,
                        max: crate::transport::wire::MAX_WIRE_ELEMS as u64,
                    });
                }
                indptr.push(total);
            }
            let nnz = r.elems("score request nnz")?;
            if nnz != total {
                return Err(WireError::Malformed {
                    what: "score request nnz != sum of row lengths",
                });
            }
            let raw = r.take(12 * nnz, "score request entries")?;
            let mut indices = Vec::with_capacity(nnz);
            let mut values = Vec::with_capacity(nnz);
            for chunk in raw.chunks_exact(12) {
                indices.push(u32::from_le_bytes(chunk[0..4].try_into().unwrap()));
                values.push(f64::from_le_bytes(chunk[4..12].try_into().unwrap()));
            }
            ScoreFrame::Request(ScoreBatch { indptr, indices, values })
        }
        TAG_SCORE_REPLY => {
            let epoch = r.u64("score reply epoch")?;
            let count = r.elems("score reply count")?;
            let raw = r.take(8 * count, "score reply margins")?;
            let margins = raw
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            ScoreFrame::Reply(RemoteScores { epoch, round: h.round, margins })
        }
        got => return Err(WireError::UnknownTag { got }),
    };
    r.finish("trailing bytes after score frame")?;
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;

    #[test]
    fn identity_frames_roundtrip_including_wildcards() {
        let id = ScoreIdentity { d: 54, fingerprint: "abc123".into(), loss: "hinge".into() };
        match decode_score_frame(&encode_score_hello(&id)).unwrap() {
            ScoreFrame::Hello(got) => assert_eq!(got, id),
            other => panic!("{other:?}"),
        }
        match decode_score_frame(&encode_score_accept(&id)).unwrap() {
            ScoreFrame::Accept(got) => assert_eq!(got, id),
            other => panic!("{other:?}"),
        }
        match decode_score_frame(&encode_score_hello(&ScoreIdentity::any())).unwrap() {
            ScoreFrame::Hello(got) => {
                assert_eq!(got.d, 0);
                assert!(got.fingerprint.is_empty() && got.loss.is_empty());
            }
            other => panic!("{other:?}"),
        }
        match decode_score_frame(&encode_score_reject("loss mismatch")).unwrap() {
            ScoreFrame::Reject(reason) => assert_eq!(reason, "loss mismatch"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn request_roundtrips_sparse_and_dense_batches() {
        for density in [0.2, 1.0] {
            let data = cov_like(17, 9, density, 5);
            let batch = ScoreBatch::from_features(&data.features);
            let wire = encode_score_request(&batch);
            let got = match decode_score_frame(&wire).unwrap() {
                ScoreFrame::Request(b) => b,
                other => panic!("{other:?}"),
            };
            assert_eq!(got, batch);
            // the rebuilt matrix scores identically to the original rows
            let w: Vec<f64> = (0..9).map(|j| 0.1 * (j as f64 + 1.0)).collect();
            let rebuilt = got.into_features(9).unwrap();
            for i in 0..17 {
                let a = data.features.row_dot(i, &w);
                let b = rebuilt.row_dot(i, &w);
                assert!((a - b).abs() < 1e-15, "row {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn reply_roundtrips_with_stamps() {
        let scores =
            RemoteScores { epoch: 7, round: 42, margins: vec![1.5, -0.25, 0.0, -0.0] };
        let got = match decode_score_frame(&encode_score_reply(&scores)).unwrap() {
            ScoreFrame::Reply(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(got.epoch, 7);
        assert_eq!(got.round, 42);
        assert_eq!(got.margins.len(), 4);
        // bit-exact margins, including the negative zero
        for (a, b) in got.margins.iter().zip(&scores.margins) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn malformed_frames_are_typed_not_panics() {
        // training frame into the score decoder: unknown tag
        let training = crate::transport::wire::encode_to_worker(
            &crate::coordinator::ToWorker::Commit { scale: 1.0 },
            0,
        );
        assert!(matches!(
            decode_score_frame(&training),
            Err(WireError::UnknownTag { .. })
        ));
        // nnz disagreeing with the row lengths
        let batch = ScoreBatch { indptr: vec![0, 2], indices: vec![1, 3], values: vec![1.0, 2.0] };
        let mut bad = encode_score_request(&batch);
        let nnz_at = bad.len() - 2 * 12 - 4;
        bad[nnz_at] = 9;
        assert!(matches!(
            decode_score_frame(&bad),
            Err(WireError::Malformed { .. })
        ));
        // out-of-range / unsorted columns are typed at into_features
        let oob = ScoreBatch { indptr: vec![0, 1], indices: vec![9], values: vec![1.0] };
        assert!(oob.into_features(4).is_err());
        let unsorted =
            ScoreBatch { indptr: vec![0, 2], indices: vec![3, 1], values: vec![1.0, 2.0] };
        assert!(unsorted.into_features(4).is_err());
    }
}
