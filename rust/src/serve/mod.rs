//! Online serving: live model snapshots published by a training run, a
//! batched [`Scorer`] over them, and the request/reply scoring protocol
//! behind `cocoa serve` — see `docs/SERVING.md` for the full contract.
//!
//! The design keeps serving strictly *passive* with respect to training:
//!
//! * The driver's [`on_model`](crate::driver::Observer::on_model) hook
//!   hands a [`SnapshotSink`] the leader's primal iterate once per round;
//!   on its cadence the sink copies `w` into an immutable, round-stamped
//!   [`ModelSnapshot`] and swaps it into a shared [`SnapshotHandle`].
//!   Training never blocks on readers: publication replaces an
//!   `Arc<ModelSnapshot>` under a write lock held for one pointer swap,
//!   and readers clone the `Arc` under a shared lock held for one clone —
//!   both O(1) critical sections, no allocation, no waiting on scoring
//!   traffic. The passivity test in `tests/serving.rs` pins that a run
//!   with live scorers attached is bit-identical to a bare run.
//! * A [`Scorer`] answers batched margin queries from whatever snapshot
//!   is current, routing every row product through
//!   [`Features::row_dot`](crate::data::Features) — the same fused
//!   sparse gather-dot kernels the training inner loop uses.
//! * [`MulticlassScorer`] holds K frozen one-vs-rest snapshots and
//!   answers argmax class predictions, scoring the K models in parallel
//!   (deterministically: ties break to the lowest class index).
//!
//! Snapshots carry the **dataset fingerprint** and the **loss /
//! regularizer tokens** of the run that produced them; the scoring
//! handshake ([`ScoreServer`] / [`ScoreClient`]) rejects a client bound
//! to a different dataset or loss with a typed reason instead of serving
//! margins that silently mean something else.
//!
//! Staleness bound: a sink publishing `every = c` sees the model at most
//! `c - 1` completed rounds behind the trainer (the round-0 snapshot and
//! every round divisible by `c` are published). With `c = 1` a snapshot
//! at round `r` is bit-identical to the `w` a checkpoint taken at round
//! `r` would restore — pinned by a test.

mod server;
mod wire;

pub use server::{ScoreClient, ScoreServer};
pub use wire::{RemoteScores, ScoreBatch, ScoreIdentity};

use std::sync::{Arc, RwLock};

use crate::data::Features;
use crate::driver::{Observer, RoundEvent, RunMeta};
use crate::error::{Error, Result};

/// One immutable, round-stamped view of the model: everything a scorer
/// needs to answer (and to refuse) prediction requests.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSnapshot {
    /// Publication sequence number (1-based; 0 for the pre-run snapshot
    /// a [`SnapshotSink`] is seeded with). Strictly increasing per sink,
    /// so a client can detect model turnover even when the round number
    /// repeats across warm restarts.
    pub epoch: u64,
    /// The completed round this iterate belongs to.
    pub round: u64,
    /// The primal iterate `w` (a private copy — never aliased with the
    /// leader's live vector).
    pub w: Vec<f64>,
    /// Loss token (the [`LossKind`](crate::loss::LossKind) display form,
    /// e.g. `"hinge"`); margins are only meaningful under the loss the
    /// model was trained for.
    pub loss: String,
    /// Regularizer token (display form, e.g. `"l2"`).
    pub regularizer: String,
    /// Dataset fingerprint of the session that produced the snapshot
    /// (chained through appended batches — see
    /// [`Session::fingerprint`](crate::Session::fingerprint)).
    pub fingerprint: String,
}

impl ModelSnapshot {
    /// Feature width the snapshot scores.
    pub fn d(&self) -> usize {
        self.w.len()
    }
}

/// Shared, lock-free-read access to the latest [`ModelSnapshot`].
///
/// Cloning the handle is cheap (an `Arc` clone); every clone observes
/// the same publication stream. [`current`](SnapshotHandle::current)
/// never blocks on a publisher for more than one pointer swap — the
/// write lock is held only to replace the inner `Arc`, never while
/// copying model data.
#[derive(Clone)]
pub struct SnapshotHandle {
    inner: Arc<RwLock<Arc<ModelSnapshot>>>,
}

impl SnapshotHandle {
    /// A handle seeded with `initial` (epoch 0 by convention).
    pub fn new(initial: ModelSnapshot) -> SnapshotHandle {
        SnapshotHandle { inner: Arc::new(RwLock::new(Arc::new(initial))) }
    }

    /// The latest published snapshot. O(1): clones the inner `Arc` under
    /// a shared lock; the returned snapshot stays valid (and immutable)
    /// however many publications follow.
    pub fn current(&self) -> Arc<ModelSnapshot> {
        // a poisoned lock means a publisher panicked mid-swap; the Arc
        // swap itself cannot be observed half-done, so the value is fine
        match self.inner.read() {
            Ok(g) => Arc::clone(&g),
            Err(poisoned) => Arc::clone(&poisoned.into_inner()),
        }
    }

    /// Replace the current snapshot (publisher side).
    pub fn publish(&self, snapshot: ModelSnapshot) {
        let next = Arc::new(snapshot);
        match self.inner.write() {
            Ok(mut g) => *g = next,
            Err(poisoned) => *poisoned.into_inner() = next,
        }
    }
}

/// A driver [`Observer`] that publishes [`ModelSnapshot`]s to a
/// [`SnapshotHandle`] on a fixed round cadence.
///
/// Strictly passive: it copies the borrowed `w` it is handed and touches
/// nothing in the cluster, so attaching one leaves the training
/// trajectory bit-identical (pinned in `tests/serving.rs`). Construct
/// per run with [`SnapshotSink::for_session`] so the identity tokens
/// (loss, regularizer, dataset fingerprint) match what the session will
/// actually train — after [`Session::append_rows`](crate::Session::append_rows)
/// moves the fingerprint, build a fresh sink for the next run.
pub struct SnapshotSink {
    handle: SnapshotHandle,
    every: u64,
    epoch: u64,
    loss: String,
    regularizer: String,
    fingerprint: String,
}

impl SnapshotSink {
    /// A sink bound to `session`'s identity, publishing every `every`
    /// completed rounds (`every` is clamped to at least 1; the round-0
    /// snapshot is always published). The handle starts at epoch 0 with
    /// the session's current `w`, so scorers have a model before the
    /// first round commits.
    pub fn for_session(session: &crate::Session, every: u64) -> SnapshotSink {
        let loss = session.loss().to_string();
        let regularizer = session.regularizer().to_string();
        let fingerprint = session.fingerprint().to_string();
        let handle = SnapshotHandle::new(ModelSnapshot {
            epoch: 0,
            round: 0,
            w: session.w().to_vec(),
            loss: loss.clone(),
            regularizer: regularizer.clone(),
            fingerprint: fingerprint.clone(),
        });
        SnapshotSink { handle, every: every.max(1), epoch: 0, loss, regularizer, fingerprint }
    }

    /// A handle scorers can read from (clone freely across threads).
    pub fn handle(&self) -> SnapshotHandle {
        self.handle.clone()
    }
}

impl Observer for SnapshotSink {
    fn on_event(&mut self, _meta: &RunMeta, _event: &RoundEvent) -> Result<()> {
        Ok(())
    }

    fn on_model(&mut self, _meta: &RunMeta, round: u64, w: &[f64]) -> Result<()> {
        if round % self.every == 0 {
            self.epoch += 1;
            self.handle.publish(ModelSnapshot {
                epoch: self.epoch,
                round,
                w: w.to_vec(),
                loss: self.loss.clone(),
                regularizer: self.regularizer.clone(),
                fingerprint: self.fingerprint.clone(),
            });
        }
        Ok(())
    }
}

/// Margins for one scored batch, stamped with the snapshot that
/// produced them.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoredBatch {
    /// Publication epoch of the snapshot used.
    pub epoch: u64,
    /// Round of the snapshot used.
    pub round: u64,
    /// `x_i . w` per batch row, in row order.
    pub margins: Vec<f64>,
}

/// Batched predictions over the current snapshot of a [`SnapshotHandle`]
/// (live serving) or over one frozen [`ModelSnapshot`] (checkpoint
/// serving) — the two paths produce bit-identical margins for the same
/// `w`, which is what lets the snapshot-vs-checkpoint test pin round-`r`
/// equivalence.
pub struct Scorer {
    handle: SnapshotHandle,
}

impl Scorer {
    /// Score from whatever `handle` currently publishes (each batch
    /// re-reads, so a long-lived scorer follows the training run).
    pub fn live(handle: SnapshotHandle) -> Scorer {
        Scorer { handle }
    }

    /// Score a fixed snapshot (e.g. `w` restored from a checkpoint).
    pub fn frozen(snapshot: ModelSnapshot) -> Scorer {
        Scorer { handle: SnapshotHandle::new(snapshot) }
    }

    /// The snapshot the next [`score_batch`](Scorer::score_batch) would
    /// use.
    pub fn snapshot(&self) -> Arc<ModelSnapshot> {
        self.handle.current()
    }

    /// Margins `x_i . w` for every row of `batch` against the current
    /// snapshot, through the fused sparse/dense gather-dot kernels. The
    /// snapshot is read once per call, so all rows of one batch score
    /// against the same model even while training publishes mid-batch.
    pub fn score_batch(&self, batch: &Features) -> Result<ScoredBatch> {
        let snap = self.handle.current();
        let margins = margins_against(batch, &snap.w)?;
        Ok(ScoredBatch { epoch: snap.epoch, round: snap.round, margins })
    }
}

/// `x_i . w` per row, with the width check every scoring path shares.
fn margins_against(batch: &Features, w: &[f64]) -> Result<Vec<f64>> {
    if batch.cols() != w.len() {
        return Err(Error::Score {
            message: format!(
                "batch has d={} features but the model has d={}",
                batch.cols(),
                w.len()
            ),
        });
    }
    Ok((0..batch.rows()).map(|i| batch.row_dot(i, w)).collect())
}

/// One-vs-rest serving: K frozen per-class snapshots answering argmax
/// class predictions, scored in parallel (one thread per class, joined
/// in class order — predictions are deterministic, ties break to the
/// lowest class index).
pub struct MulticlassScorer {
    models: Vec<Arc<ModelSnapshot>>,
}

impl MulticlassScorer {
    /// Build from per-class snapshots (index = class id). All models
    /// must share the feature width and dataset fingerprint — K models
    /// from different data answer a question nobody asked.
    pub fn new(models: Vec<ModelSnapshot>) -> Result<MulticlassScorer> {
        let first = models.first().ok_or_else(|| Error::Score {
            message: "multiclass scorer needs at least one class model".into(),
        })?;
        let (d, fp) = (first.d(), first.fingerprint.clone());
        for (c, m) in models.iter().enumerate() {
            if m.d() != d {
                return Err(Error::Score {
                    message: format!("class {c} model has d={} but class 0 has d={d}", m.d()),
                });
            }
            if m.fingerprint != fp {
                return Err(Error::Score {
                    message: format!(
                        "class {c} model fingerprint {:?} != class 0 fingerprint {fp:?}",
                        m.fingerprint
                    ),
                });
            }
        }
        Ok(MulticlassScorer { models: models.into_iter().map(Arc::new).collect() })
    }

    /// Number of classes served.
    pub fn classes(&self) -> usize {
        self.models.len()
    }

    /// Per-class margins for every row: `margins[c][i] = x_i . w_c`,
    /// computed with one scoring thread per class.
    pub fn margins(&self, batch: &Features) -> Result<Vec<Vec<f64>>> {
        for (c, m) in self.models.iter().enumerate() {
            if batch.cols() != m.d() {
                return Err(Error::Score {
                    message: format!(
                        "batch has d={} features but class {c} model has d={}",
                        batch.cols(),
                        m.d()
                    ),
                });
            }
        }
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(self.models.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .models
                .iter()
                .map(|m| scope.spawn(move || margins_against(batch, &m.w).expect("width checked")))
                .collect();
            // joining in spawn (= class) order keeps the output
            // deterministic regardless of which thread finishes first
            for h in handles {
                out.push(h.join().expect("class scoring thread panicked"));
            }
        });
        Ok(out)
    }

    /// Argmax class per row (ties to the lowest class index).
    pub fn predict(&self, batch: &Features) -> Result<Vec<usize>> {
        let margins = self.margins(batch)?;
        Ok((0..batch.rows())
            .map(|i| {
                let mut best = 0usize;
                for c in 1..margins.len() {
                    if margins[c][i] > margins[best][i] {
                        best = c;
                    }
                }
                best
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{cov_like, Dataset};

    fn snap(epoch: u64, round: u64, w: Vec<f64>) -> ModelSnapshot {
        ModelSnapshot {
            epoch,
            round,
            w,
            loss: "hinge".into(),
            regularizer: "l2".into(),
            fingerprint: "fp".into(),
        }
    }

    #[test]
    fn handle_publish_and_read() {
        let h = SnapshotHandle::new(snap(0, 0, vec![0.0; 3]));
        assert_eq!(h.current().epoch, 0);
        let reader = h.clone();
        h.publish(snap(1, 5, vec![1.0, 2.0, 3.0]));
        let seen = reader.current();
        assert_eq!(seen.epoch, 1);
        assert_eq!(seen.round, 5);
        assert_eq!(seen.w, vec![1.0, 2.0, 3.0]);
        // an Arc taken before a publish stays valid and unchanged
        let old = reader.current();
        h.publish(snap(2, 6, vec![9.0, 9.0, 9.0]));
        assert_eq!(old.epoch, 1);
        assert_eq!(reader.current().epoch, 2);
    }

    #[test]
    fn scorer_matches_manual_dots_dense_and_sparse() {
        let data: Dataset = cov_like(40, 7, 0.3, 11);
        let w: Vec<f64> = (0..7).map(|j| (j as f64 + 1.0) * 0.25).collect();
        let scorer = Scorer::frozen(snap(3, 9, w.clone()));
        let scored = scorer.score_batch(&data.features).unwrap();
        assert_eq!(scored.epoch, 3);
        assert_eq!(scored.round, 9);
        assert_eq!(scored.margins.len(), 40);
        for i in 0..40 {
            let mut want = 0.0;
            for (j, wj) in w.iter().enumerate() {
                want += data.features.row_dense(i)[j] * wj;
            }
            assert!(
                (scored.margins[i] - want).abs() < 1e-12,
                "row {i}: {} vs {want}",
                scored.margins[i]
            );
        }
    }

    #[test]
    fn scorer_rejects_width_mismatch_typed() {
        let data = cov_like(10, 4, 0.5, 2);
        let scorer = Scorer::frozen(snap(1, 1, vec![0.0; 5]));
        let err = scorer.score_batch(&data.features).unwrap_err();
        assert!(matches!(err, Error::Score { .. }), "{err}");
    }

    #[test]
    fn multiclass_argmax_is_deterministic_and_tie_breaks_low() {
        let data = cov_like(25, 6, 0.4, 7);
        // class 1 dominated by class 0 everywhere; class 2 is class 0
        // exactly, so ties must resolve to class 0
        let w0: Vec<f64> = vec![1.0; 6];
        let models = vec![
            snap(1, 1, w0.clone()),
            snap(1, 1, vec![0.0; 6]),
            snap(1, 1, w0.clone()),
        ];
        let mc = MulticlassScorer::new(models).unwrap();
        assert_eq!(mc.classes(), 3);
        let preds = mc.predict(&data.features).unwrap();
        let single = Scorer::frozen(snap(1, 1, w0)).score_batch(&data.features).unwrap();
        for (i, &p) in preds.iter().enumerate() {
            if single.margins[i] > 0.0 {
                assert_eq!(p, 0, "row {i} positive margin must pick the tied-lowest class");
            }
        }
        // repeated calls are identical (parallel join order is pinned)
        assert_eq!(preds, mc.predict(&data.features).unwrap());
    }

    #[test]
    fn multiclass_rejects_mismatched_models() {
        let err = MulticlassScorer::new(vec![]).unwrap_err();
        assert!(matches!(err, Error::Score { .. }), "{err}");
        let err =
            MulticlassScorer::new(vec![snap(1, 1, vec![0.0; 3]), snap(1, 1, vec![0.0; 4])])
                .unwrap_err();
        assert!(matches!(err, Error::Score { .. }), "{err}");
        let mut other = snap(1, 1, vec![0.0; 3]);
        other.fingerprint = "other".into();
        let err = MulticlassScorer::new(vec![snap(1, 1, vec![0.0; 3]), other]).unwrap_err();
        assert!(matches!(err, Error::Score { .. }), "{err}");
    }
}
