//! # CoCoA — Communication-Efficient Distributed Dual Coordinate Ascent
//!
//! A production-shaped reproduction of Jaggi, Smith, Takáč, Terhorst,
//! Hofmann & Jordan, *Communication-Efficient Distributed Dual Coordinate
//! Ascent* (NIPS 2014).
//!
//! The crate implements the paper's full experimental system:
//!
//! * [`data`] — dense/CSR datasets, a LibSVM loader, the synthetic workload
//!   generators matching the paper's three dataset regimes, and the
//!   coordinate-block [`data::Partition`] the framework distributes over.
//! * [`loss`] — the regularized-loss-minimization problem class of eq. (1):
//!   hinge, smoothed hinge, squared and logistic losses with their Fenchel
//!   conjugates and closed-form/Newton single-coordinate dual maximizers.
//! * [`solvers`] — `LOCALDUALMETHOD` implementations (Procedure A): the
//!   paper's LocalSDCA (Procedure B), a permuted-order variant, and the
//!   exact block solver that realizes the `H -> inf` block-coordinate-
//!   descent limit discussed after Lemma 3.
//! * [`coordinator`] — Algorithm 1 as a leader/worker runtime: real worker
//!   threads owning disjoint data + dual blocks, message-passing rounds,
//!   `beta_K`-scaled reduces, exact communication accounting.
//! * [`algorithms`] — every Section-6 competitor configured over the same
//!   runtime: mini-batch SDCA, mini-batch SGD (Pegasos), locally-updating
//!   SGD, naive distributed CD/SGD, and one-shot averaging.
//! * [`objective`] — primal/dual objectives and the duality-gap certificate.
//! * [`netsim`] — the network cost model that turns counted communication
//!   into simulated distributed wall-time.
//! * [`runtime`] — the PJRT backend: loads the AOT-compiled JAX/Pallas HLO
//!   artifacts (built once by `make artifacts`) and serves them to workers
//!   from a dedicated engine thread. Python never runs at training time.
//! * [`theory`] — Proposition 1's Θ, Lemma 3's σ_min estimator, and the
//!   Theorem 2 rate, used to validate measured convergence against the
//!   paper's analysis.
//! * [`telemetry`] / [`config`] / [`experiments`] — traces, TOML experiment
//!   configs, and the harnesses that regenerate Table 1 and Figures 1–4.

pub mod algorithms;
pub mod config;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod loss;
pub mod netsim;
pub mod objective;
pub mod runtime;
pub mod solvers;
pub mod telemetry;
pub mod theory;

pub use config::ExperimentConfig;
pub use coordinator::Cluster;
pub use data::{Dataset, Partition};
pub use loss::LossKind;
