//! # CoCoA — Communication-Efficient Distributed Dual Coordinate Ascent
//!
//! A production-shaped reproduction of Jaggi, Smith, Takáč, Terhorst,
//! Hofmann & Jordan, *Communication-Efficient Distributed Dual Coordinate
//! Ascent* (NIPS 2014), built around three public types — a builder, a
//! session, and a step-wise driver:
//!
//! * [`Trainer`] — a typed builder describing the problem (data, partition,
//!   loss, lambda, regularizer, local solver, backend, network model,
//!   seed). All
//!   validation happens at [`Trainer::build`], which returns a typed
//!   [`Error`] — never a panic, never a stringly error.
//! * [`Session`] — the live cluster the builder yields: the leader plus K
//!   worker threads owning disjoint coordinate blocks. One session runs
//!   many algorithms ([`Session::run`]) and warm-starts between runs
//!   ([`Session::reset`] keeps the threads, data, and PJRT bindings).
//!
//! The training loop itself is open: [`Session::drive`] yields a
//! [`Driver`] — a resumable round state machine whose `step()` advances
//! exactly one unit of the run and returns a typed
//! [`RoundEvent`] (`RoundStarted`, `Evaluated`, `Checkpointed`,
//! `Stopped`). Stopping criteria are composable
//! [`StoppingRule`](driver::StoppingRule)s (`GapBelow`, `MaxRounds`,
//! `SimTimeBelow`, `BytesBelow`, ... under `or`/`and` combinators);
//! telemetry and persistence are pluggable
//! [`Observer`](driver::Observer)s (incremental trace builder, streaming
//! CSV/JSONL sinks, checkpoint retention, a live progress line).
//! [`Session::run`] is the batch wrapper over the same machine, so the
//! one-call path and the manual step loop produce bit-identical traces.
//!
//! Algorithms are a first-class trait ([`Algorithm`]): per round the driver
//! asks the algorithm for each worker's [`coordinator::LocalWork`], gathers
//! the K replies, and hands them to the algorithm's `reduce`. All seven
//! Section-6 baselines ship as implementations, and the `beta_K`
//! aggregation knob of Algorithm 1 is its own policy type
//! ([`Aggregation`]), which makes CoCoA+ a constructor away.
//!
//! ## 30-second API tour
//!
//! ```no_run
//! use cocoa::prelude::*;
//! use cocoa::data::cov_like;
//!
//! fn main() -> cocoa::Result<()> {
//!     // 1. a dataset and a session: K = 4 worker threads, hinge SVM
//!     let data = cov_like(8_000, 54, 0.1, 42);
//!     let mut session = Trainer::on(&data)
//!         .workers(4)
//!         .loss(LossKind::Hinge)
//!         .lambda(1.0 / data.n() as f64)
//!         .network(NetworkModel::ec2_like())
//!         .seed(7)
//!         .build()?;
//!
//!     // 2. batch mode: run until a composable stopping rule fires; the
//!     //    trace's `stop` column records which criterion actually ended
//!     //    the run (gap listed first, so it wins ties)
//!     let h = data.n() / 4; // one local pass per round
//!     let trace = session.run(
//!         &mut Cocoa::new(h),
//!         GapBelow::new(1e-3).or(MaxRounds::new(50)),
//!     )?;
//!     let last = trace.rows.last().unwrap();
//!     println!("gap {:.2e} after {} rounds (stop = {})", last.gap, last.round, last.stop);
//!
//!     // 3. step mode: the caller owns the round boundary. `step()`
//!     //    yields typed events — drive one round at a time, inspect,
//!     //    adapt, pause whenever you like
//!     session.reset()?;
//!     let mut plus = Cocoa::adding(h); // CoCoA+: beta_K = K adding
//!     let mut driver = session.drive(&mut plus, MaxRounds::new(10))?;
//!     loop {
//!         match driver.step()? {
//!             RoundEvent::Evaluated { row } => {
//!                 println!("round {:>3}  gap {:.2e}", row.round, row.gap)
//!             }
//!             RoundEvent::Stopped { reason } => {
//!                 println!("stopped: {reason}");
//!                 break;
//!             }
//!             _ => {}
//!         }
//!     }
//!     drop(driver); // releases the session for the next run
//!
//!     // 4. observers: stream every evaluated row to disk and print a
//!     //    live progress line, while a simulated-time budget (with a
//!     //    round-cap safety net) decides when to stop
//!     session.reset()?;
//!     let mut csv = CsvSink::create("results/quickstart.csv")?;
//!     let mut progress = ProgressLine::stderr();
//!     let mut algo = Cocoa::new(h);
//!     let mut driver = session.drive(
//!         &mut algo,
//!         SimTimeBelow::new(30.0).or(MaxRounds::new(200)),
//!     )?;
//!     driver.observe(&mut csv)?;
//!     driver.observe(&mut progress)?;
//!     let trace = driver.drain()?;
//!     drop(driver);
//!     println!("simulated {:.1}s", trace.rows.last().unwrap().sim_time_s);
//!
//!     // 5. the rest of the problem space is pluggable too: swap the
//!     //    regularizer for a lasso workload with exact zeros...
//!     let mut lasso = Trainer::on(&data)
//!         .workers(4)
//!         .loss(LossKind::Squared)
//!         .lambda(0.05)
//!         .regularizer(RegularizerKind::L1 { epsilon: 0.5 })
//!         .build()?;
//!     let trace = lasso.run(&mut Cocoa::new(h), MaxRounds::new(10))?;
//!     println!("lasso: {} nonzero of {}", trace.rows.last().unwrap().w_nnz, lasso.d());
//!
//!     // ...or the transport, to stop on *measured* wire bytes
//!     let mut counted = Trainer::on(&data)
//!         .workers(4)
//!         .lambda(1.0 / data.n() as f64)
//!         .network(NetworkModel::ec2_like())
//!         .transport(TransportKind::Counted)
//!         .build()?;
//!     let trace = counted.run(
//!         &mut Cocoa::new(h),
//!         BytesBelow::new(64 << 20).or(MaxRounds::new(100)),
//!     )?;
//!     println!(
//!         "measured {} B on the wire (modeled {} B)",
//!         trace.rows.last().unwrap().bytes_measured,
//!         trace.rows.last().unwrap().bytes_modeled,
//!     );
//!     Ok(())
//! }
//! ```
//!
//! The legacy [`Budget`] struct still works everywhere a stopping rule
//! does (it validates and converts into `gap -> subopt -> max-rounds`
//! rules in its historical precedence order), so pre-driver call sites
//! keep compiling unchanged.
//!
//! Swap [`TransportKind::Counted`] for `TransportKind::SimNet(...)` to
//! inject deterministic latency jitter, bounded drops/retransmits, and
//! stragglers (same seed, same trajectory, bit for bit), or
//! `TransportKind::Record`/`Replay` to tape a run and re-serve it.
//!
//! ## Multi-process deployment
//!
//! `TransportKind::Net(...)` moves the same protocol onto real sockets —
//! TCP (`tcp:host:port`) or Unix-domain (`uds:/path`) — with the workers
//! as separate OS processes. The CLI wires it up from one shared config:
//!
//! ```text
//! cocoa worker --config exp.toml --connect uds:/tmp/cocoa.sock &   # x K
//! cocoa leader --config exp.toml --listen uds:/tmp/cocoa.sock --workers K
//! ```
//!
//! Every worker loads the same TOML, derives its own data block and
//! per-slot seed from it, and proves agreement in a versioned handshake:
//! a fingerprint over the dataset, partition, loss, regularizer, solver,
//! lambda, seed, and wire version. A peer from a different experiment —
//! or a different wire version — is rejected with a typed
//! [`Error::Handshake`] before any training traffic flows. Because the
//! socket frames carry the exact in-process wire encoding, a K-process
//! run's trajectory is bit-identical to the in-process one, and the
//! transport [`Ledger`](transport::Ledger) still accounts every payload
//! byte (socket-level overhead is reported separately via
//! [`Session::socket_stats`]: length prefixes + handshake frames, and
//! nothing else).
//!
//! Failures are survivable on both sides. Workers reconnect with bounded
//! exponential backoff; the leader turns a dead connection into a typed
//! [`Error::PeerLost`] (or [`Error::Timeout`]) at the failed round, and
//! [`driver::recovery::run_with_recovery`] rolls the cluster back to the
//! newest checkpoint, re-accepts a replacement worker
//! ([`Session::recover`]), and resumes — the recovered trajectory is
//! bit-identical to one that never failed, because checkpoints carry the
//! worker rng streams.
//!
//! ## Out-of-core data
//!
//! Datasets larger than RAM train from on-disk shards. `cocoa shard`
//! (or [`data::shard_libsvm`] / [`data::write_shards`] / the streaming
//! `*_stream_shards` generators) writes one checksummed CSR file per
//! worker plus a manifest, without ever materializing the dataset; a
//! [`data::ShardSet`] opens the directory back up and
//! [`Trainer::on_shards`] builds a session whose workers read their own
//! shard — memory-mapped by default ([`data::ShardMode`]), so peak RSS
//! stays a small fraction of the data's size. Shards store the same row
//! bytes and bit-exact cached norms as [`data::Dataset::subset`] under
//! the manifest's partition, so shard-backed trajectories are
//! bit-identical to in-memory ones (pinned by
//! `rust/tests/ooc_bit_identity.rs`); corrupt or truncated files are
//! rejected with typed [`Error::Shard`] before any kernel sees them.
//! The full contract lives in `docs/DATA.md`.
//!
//! ## Layers
//!
//! * [`data`] — dense/CSR datasets, the LibSVM loader + streaming shard
//!   ingester, the synthetic workload generators matching the paper's
//!   three dataset regimes (in-memory and streamed-to-shard variants),
//!   the mmap-backed [`data::ShardSet`] store, and the coordinate-block
//!   [`data::Partition`] the framework distributes over (contract:
//!   `docs/DATA.md`).
//! * [`loss`] — the regularized-loss-minimization problem class of eq. (1):
//!   hinge, smoothed hinge, squared and logistic losses with their Fenchel
//!   conjugates and closed-form/Newton single-coordinate dual maximizers.
//! * [`regularizers`] — the pluggable `Omega(w)` of the generalized
//!   problem: plain L2, epsilon-smoothed L1 (lasso with exact zeros,
//!   ProxCoCoA-style), and elastic net, each carrying its conjugate, prox
//!   map, and strong-convexity constant. Choosing L1 makes the broadcast
//!   `w` sparse, which the counted transport's adaptive encoding turns
//!   into measurably smaller wire bytes.
//! * [`kernels`] — the fused scalar kernels under every solver hot path:
//!   sparse/dense dot, axpy, scaled update, and nnz-aware norms, each with
//!   a documented (and property-tested) bit-exact accumulation order. The
//!   sparse gather kernels skip per-element bounds checks soundly — the
//!   CSR type owns the index invariant.
//! * [`solvers`] — `LOCALDUALMETHOD` implementations (Procedure A): the
//!   paper's LocalSDCA (Procedure B), a permuted-order variant, and the
//!   exact block solver that realizes the `H -> inf` limit. Worker
//!   [`solvers::Block`]s carry per-shard caches (precomputed curvatures,
//!   the sparse column-touch set) so inner loops never recompute them.
//! * [`perf`] — the reproducible performance harness behind `cocoa perf`:
//!   standardized workloads (dense ridge, rcv1-density sparse logistic,
//!   smoothed-L1 lasso, each at K ∈ {1, 4}, plus the `_ooc` out-of-core
//!   family training from mmap shards) emitting a schema-versioned
//!   `BENCH_hotpath.json` (steps/sec, time-to-1e-3-gap, wire bytes, peak
//!   RSS vs on-disk dataset bytes) that CI validates as a smoke gate.
//! * [`coordinator`] — Algorithm 1 as a leader/worker runtime: real worker
//!   threads owning disjoint data + dual blocks, message-passing rounds,
//!   exact communication accounting.
//! * [`transport`] — the pluggable leader<->worker message fabric: the
//!   zero-overhead in-process default, byte-exact counted accounting, a
//!   deterministic seedable fault injector (SimNet), transcript
//!   record/replay, and a real-socket backend ([`transport::net`]: TCP /
//!   Unix-domain, versioned fingerprinted handshake, reconnect + leader
//!   `heal`) behind `cocoa leader` / `cocoa worker`.
//! * [`algorithms`] — the [`Algorithm`] trait, the [`Aggregation`] policy,
//!   and every Section-6 competitor as an implementation.
//! * [`driver`] — the step-wise round state machine behind every run:
//!   [`Driver`] with typed [`RoundEvent`]s, composable
//!   [`driver::stopping`] rules, and pluggable [`driver::observers`]
//!   (trace builder, streaming CSV/JSONL, checkpoint policy, progress).
//! * [`api`] — the [`Trainer`] builder and [`Session`] facade, including
//!   the continuous-training surface ([`Session::append_rows`] grows the
//!   live problem with new rows under retained dual state;
//!   [`Session::set_labels`] relabels in place for one-vs-rest reuse).
//! * [`serve`] — online serving: round-stamped [`serve::ModelSnapshot`]s
//!   published by a passive [`serve::SnapshotSink`] observer, batched
//!   [`serve::Scorer`]/[`serve::MulticlassScorer`] prediction through the
//!   fused gather-dot kernels, and the `cocoa serve` / `cocoa score`
//!   request/reply protocol ([`serve::ScoreServer`] /
//!   [`serve::ScoreClient`]) over the net-transport framing (contract:
//!   `docs/SERVING.md`).
//! * [`objective`] — primal/dual objectives and the duality-gap certificate.
//! * [`netsim`] — the network cost model that turns counted communication
//!   into simulated distributed wall-time.
//! * [`obs`] — span-based observability for the live cluster: per-round
//!   phase spans (`broadcast -> local_solve -> reduce -> commit ->
//!   evaluate`) through a recorder seam that is provably passive, per-worker
//!   metrics carried on their own non-algorithm wire message, log-bucketed
//!   straggler histograms, a JSONL span sink (`--trace-out`), and a live
//!   Prometheus `/metrics` endpoint (`cocoa leader --metrics`).
//! * [`runtime`] — the PJRT backend: loads the AOT-compiled JAX/Pallas HLO
//!   artifacts (built once by `make artifacts`) and serves them to workers
//!   from a dedicated engine thread. Python never runs at training time.
//! * [`theory`] — Proposition 1's Θ, Lemma 3's σ_min estimator, and the
//!   Theorem 2 rate, used to validate measured convergence against the
//!   paper's analysis.
//! * [`telemetry`] / [`config`] / [`experiments`] — traces, TOML experiment
//!   configs, and the harnesses that regenerate Table 1 and Figures 1–4.

pub mod algorithms;
pub mod api;
pub mod config;
pub mod error;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod experiments;
pub mod kernels;
pub mod loss;
pub mod netsim;
pub mod objective;
pub mod obs;
pub mod perf;
pub mod regularizers;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod telemetry;
pub mod theory;
pub mod transport;

pub use algorithms::{Aggregation, Algorithm, Budget};
pub use api::{Session, Trainer};
pub use config::ExperimentConfig;
pub use coordinator::Cluster;
pub use data::{Dataset, Partition};
pub use driver::{Driver, DriverSpec, IntoDriverSpec, RoundEvent, RunMeta};
pub use error::{Error, Result};
pub use loss::LossKind;
pub use regularizers::RegularizerKind;
pub use transport::TransportKind;

/// One-line import for the common path:
/// `use cocoa::prelude::*;`
pub mod prelude {
    pub use crate::algorithms::{
        Aggregation, Algorithm, Budget, Cocoa, LocalSgd, MinibatchCd, MinibatchSgd, NaiveCd,
        NaiveSgd, OneShotAvg, RoundCtx,
    };
    pub use crate::api::{Session, Trainer};
    pub use crate::config::{AlgorithmSpec, Backend, ExperimentConfig};
    pub use crate::data::{Dataset, Partition, PartitionStrategy};
    pub use crate::driver::{
        All, Any, BytesBelow, CheckpointSink, CsvSink, Driver, DriverSpec, EventLog, GapBelow,
        IntoDriverSpec, JsonlSink, MaxRounds, Observation, Observer, ProgressLine, RoundEvent,
        RunMeta, SimTimeBelow, StoppingRule, SuboptBelow, TraceSink,
    };
    pub use crate::error::{Error, Result};
    pub use crate::loss::LossKind;
    pub use crate::netsim::{NetworkModel, StragglerModel};
    pub use crate::regularizers::RegularizerKind;
    pub use crate::serve::{
        ModelSnapshot, MulticlassScorer, ScoreClient, ScoreServer, Scorer, SnapshotHandle,
        SnapshotSink,
    };
    pub use crate::solvers::SolverKind;
    pub use crate::telemetry::{StopReason, Trace, TraceRow};
    pub use crate::transport::{SimNetConfig, Transcript, TransportKind};
}
