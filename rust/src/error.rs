//! The crate-level error type — every public API entry point ([`crate::Trainer`],
//! [`crate::Session`], [`crate::config::ExperimentConfig`]) returns these
//! typed variants instead of ad-hoc `anyhow!` strings, so callers can match
//! on *what* went wrong (a missing lambda vs. a dead worker) rather than
//! parsing messages.

use std::fmt;

/// Everything that can go wrong at the API boundary.
#[derive(Debug)]
#[non_exhaustive]
pub enum Error {
    /// `Trainer::build` called without `.lambda(...)` — the regularizer has
    /// no sane default (the paper tunes it per dataset, Table 1).
    MissingLambda,
    /// Lambda must be finite and strictly positive.
    InvalidLambda { value: f64 },
    /// `Trainer::build` called without `.workers(k)` or `.partition(p)`.
    MissingPartition,
    /// More workers than data points: at least one block would be empty.
    TooManyWorkers { k: usize, n: usize },
    /// An explicit partition covers a different number of rows than the
    /// dataset the trainer was built on.
    PartitionMismatch { data_n: usize, partition_n: usize },
    /// The partition violates its own invariants (non-disjoint blocks,
    /// out-of-range indices, ...).
    InvalidPartition { reason: String },
    /// `Backend::Pjrt` selected but the artifacts directory is missing its
    /// `manifest.tsv` (run `make artifacts` first).
    MissingArtifacts { dir: String },
    /// A run budget / driver spec failed validation — e.g. an evaluation
    /// cadence of 0, which would disable evaluation entirely (the old
    /// behavior silently clamped it to 1).
    InvalidBudget { reason: String },
    /// A run budget stops on primal suboptimality (`target_subopt > 0`)
    /// but the session has no reference optimum to measure against — call
    /// [`Session::set_reference_optimum`](crate::Session::set_reference_optimum)
    /// first (otherwise the run could only ever exhaust its round cap).
    MissingReferenceOptimum,
    /// A regularizer configuration failed validation (non-positive L1
    /// smoothing epsilon, an elastic-net ratio outside `[0, 1)`, ...).
    InvalidRegularizer { reason: String },
    /// A valid regularizer was combined with a feature that assumes plain
    /// L2 — the PJRT kernel artifacts, the Appendix-B gap-certified local
    /// solver, or the primal (Pegasos) SGD baselines.
    UnsupportedRegularizer { regularizer: String, context: String },
    /// A LibSVM file failed to parse (`line` is 1-based; 0 for file-level
    /// problems).
    Libsvm { line: usize, message: String },
    /// An on-disk shard set failed to write, open, or verify: I/O errors,
    /// a bad magic/version, a checksum mismatch, a violated CSR
    /// invariant, or a shard/manifest disagreement. `path` names the
    /// offending file (or the shard directory for set-level problems).
    Shard { path: String, message: String },
    /// A transport configuration failed validation (out-of-range SimNet
    /// parameters such as `drop_prob >= 1` or a slowdown below 1).
    InvalidTransport { reason: String },
    /// A transport failed at runtime: worker channels closed, or a replay
    /// diverged from its recorded transcript.
    Transport { message: String },
    /// A net-transport receive (or accept) deadline expired with no
    /// worker traffic. Recoverable: heal the cluster and resume from the
    /// last checkpoint (see `driver::recovery`).
    Timeout { waited_s: f64 },
    /// A connected worker's socket died (EOF, I/O error, or an
    /// undecodable frame). Recoverable like [`Error::Timeout`].
    PeerLost { worker: usize, reason: String },
    /// A net-transport handshake was rejected: wire-version mismatch, a
    /// run-fingerprint that doesn't match the leader's config + data, or
    /// a slot conflict. Not recoverable by retrying — the peer is
    /// running a different experiment (or a different build).
    Handshake { reason: String },
    /// A scoring request failed: the batch's feature width doesn't match
    /// the served model, a malformed score frame, or a dead scoring
    /// connection (see [`crate::serve`]).
    Score { message: String },
    /// A TOML experiment config failed to parse or validate.
    Config { message: String },
    /// A runtime failure after construction (worker death, PJRT engine
    /// error, I/O while writing traces).
    Runtime { message: String },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::MissingLambda => {
                write!(f, "no regularization strength: call Trainer::lambda(...)")
            }
            Error::InvalidLambda { value } => {
                write!(f, "lambda must be finite and > 0, got {value}")
            }
            Error::MissingPartition => {
                write!(f, "no partition: call Trainer::workers(k) or Trainer::partition(...)")
            }
            Error::TooManyWorkers { k, n } => {
                write!(f, "{k} workers over {n} rows: at least one block would be empty")
            }
            Error::PartitionMismatch { data_n, partition_n } => write!(
                f,
                "partition covers {partition_n} rows but the dataset has {data_n}"
            ),
            Error::InvalidPartition { reason } => write!(f, "invalid partition: {reason}"),
            Error::MissingArtifacts { dir } => write!(
                f,
                "PJRT backend selected but {dir}/manifest.tsv does not exist \
                 (run `make artifacts` first)"
            ),
            Error::InvalidBudget { reason } => write!(f, "invalid budget: {reason}"),
            Error::MissingReferenceOptimum => write!(
                f,
                "budget stops on suboptimality but no reference optimum is set: \
                 call Session::set_reference_optimum(Some(p_star)) first"
            ),
            Error::InvalidRegularizer { reason } => {
                write!(f, "invalid regularizer config: {reason}")
            }
            Error::UnsupportedRegularizer { regularizer, context } => write!(
                f,
                "regularizer {regularizer} is not supported by {context} \
                 (only the plain l2 regularizer is)"
            ),
            Error::Libsvm { line, message } => {
                if *line == 0 {
                    write!(f, "libsvm parse error: {message}")
                } else {
                    write!(f, "libsvm parse error at line {line}: {message}")
                }
            }
            Error::Shard { path, message } => {
                write!(f, "shard data error at {path}: {message}")
            }
            Error::InvalidTransport { reason } => {
                write!(f, "invalid transport config: {reason}")
            }
            Error::Transport { message } => write!(f, "transport error: {message}"),
            Error::Timeout { waited_s } => {
                write!(f, "timed out after {waited_s} s waiting for worker traffic")
            }
            Error::PeerLost { worker, reason } => {
                write!(f, "lost worker {worker}: {reason}")
            }
            Error::Handshake { reason } => write!(f, "handshake rejected: {reason}"),
            Error::Score { message } => write!(f, "scoring error: {message}"),
            Error::Config { message } => write!(f, "config error: {message}"),
            Error::Runtime { message } => write!(f, "runtime error: {message}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<anyhow::Error> for Error {
    /// Internal plumbing (the coordinator) speaks `anyhow`; a typed crate
    /// [`Error`] traveling through it (e.g. a transport failure) is
    /// recovered by downcast instead of being erased into `Runtime`.
    fn from(e: anyhow::Error) -> Self {
        match e.downcast::<Error>() {
            Ok(typed) => typed,
            Err(e) => Error::Runtime { message: format!("{e:#}") },
        }
    }
}

/// Crate-wide result alias; defaults to the crate [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let msgs = [
            Error::MissingLambda.to_string(),
            Error::InvalidLambda { value: -1.0 }.to_string(),
            Error::TooManyWorkers { k: 8, n: 4 }.to_string(),
            Error::MissingArtifacts { dir: "artifacts".into() }.to_string(),
            Error::InvalidBudget { reason: "eval_every must be >= 1".into() }.to_string(),
            Error::InvalidTransport { reason: "drop_prob must be in [0, 1)".into() }.to_string(),
            Error::Transport { message: "replay diverged at event 3".into() }.to_string(),
            Error::Timeout { waited_s: 30.0 }.to_string(),
            Error::PeerLost { worker: 2, reason: "connection closed".into() }.to_string(),
            Error::Handshake { reason: "wire version 2 incompatible with 1".into() }.to_string(),
            Error::Shard {
                path: "shards/shard_0001.bin".into(),
                message: "section 2 checksum mismatch (corrupt shard)".into(),
            }
            .to_string(),
        ];
        assert!(msgs[0].contains("lambda"));
        assert!(msgs[1].contains("-1"));
        assert!(msgs[2].contains("8 workers"));
        assert!(msgs[3].contains("manifest.tsv"));
        assert!(msgs[4].contains("eval_every"));
        assert!(msgs[5].contains("drop_prob"));
        assert!(msgs[6].contains("replay diverged"));
        assert!(msgs[7].contains("30"));
        assert!(msgs[8].contains("worker 2"));
        assert!(msgs[9].contains("wire version"));
        assert!(msgs[10].contains("shard_0001.bin") && msgs[10].contains("checksum"));
    }

    #[test]
    fn anyhow_conversion_preserves_chain() {
        let e = anyhow::anyhow!("inner").context("outer");
        let err: Error = e.into();
        let msg = err.to_string();
        assert!(msg.contains("outer") && msg.contains("inner"), "{msg}");
    }

    #[test]
    fn anyhow_roundtrip_recovers_typed_variants() {
        // a typed error that passed through the coordinator's anyhow layer
        // must come back as itself, not as Runtime
        let typed = Error::Transport { message: "replay diverged at event 3".into() };
        let through: anyhow::Error = typed.into();
        let back: Error = through.into();
        assert!(matches!(back, Error::Transport { .. }), "{back}");
        // the recovery path matches on these two after they cross the
        // coordinator's anyhow layer — they must survive the round trip
        let through: anyhow::Error = Error::PeerLost { worker: 1, reason: "eof".into() }.into();
        let back: Error = through.into();
        assert!(matches!(back, Error::PeerLost { worker: 1, .. }), "{back}");
        let through: anyhow::Error = Error::Timeout { waited_s: 5.0 }.into();
        let back: Error = through.into();
        assert!(matches!(back, Error::Timeout { .. }), "{back}");
    }
}
