//! Epsilon-smoothed L1 — lasso workloads inside the dual framework.

use super::Regularizer;

/// `Omega(w) = ||w||_1 + (epsilon/2)||w||^2` — the bounded-curvature
/// treatment of pure L1 from the framework's L1 follow-up (1512.04011):
/// the dual machinery needs a strongly convex regularizer, and the small
/// quadratic term supplies exactly that (`sigma = epsilon`) while the
/// soft-threshold prox keeps *exact* zeros in `w`.
///
/// Normalized constants: `kappa = 1/epsilon`, `lambda_eff = lambda *
/// epsilon` — so the prox threshold in primal units is
/// `lambda_eff * kappa = lambda`, independent of the smoothing. Smaller
/// `epsilon` tracks the pure-L1 optimum more closely but conditions the
/// dual worse (coordinate curvatures scale with `1/(lambda n epsilon)`),
/// so inner loops need more steps; `0.1`–`1.0` is a practical range.
#[derive(Debug, Clone, Copy)]
pub struct SmoothedL1 {
    epsilon: f64,
}

impl SmoothedL1 {
    /// `epsilon` must be finite and strictly positive (validated with a
    /// typed error at `Trainer::build`; asserted here for direct users).
    pub fn new(epsilon: f64) -> Self {
        assert!(
            epsilon.is_finite() && epsilon > 0.0,
            "l1 smoothing epsilon must be finite and > 0, got {epsilon}"
        );
        SmoothedL1 { epsilon }
    }

    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }
}

impl Regularizer for SmoothedL1 {
    fn name(&self) -> &'static str {
        "l1"
    }

    fn strong_convexity(&self) -> f64 {
        self.epsilon
    }

    fn l1_weight(&self) -> f64 {
        1.0 / self.epsilon
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primal_threshold_is_lambda_for_any_epsilon() {
        // lambda_eff * kappa = lambda * epsilon * (1/epsilon) = lambda:
        // the user-facing sparsity level does not move with the smoothing.
        for eps in [0.1, 0.5, 2.0] {
            let r = SmoothedL1::new(eps);
            let lambda = 0.25;
            let lambda_eff = lambda * r.strong_convexity();
            assert!((lambda_eff * r.l1_weight() - lambda).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn zero_epsilon_panics() {
        let _ = SmoothedL1::new(0.0);
    }
}
