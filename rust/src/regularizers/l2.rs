//! The paper's original regularizer: `Omega(w) = (1/2)||w||^2`.

use super::Regularizer;

/// Plain L2 — `sigma = 1`, no L1 part. Its prox map is the identity, so
/// the leader's shared vector `v` *is* the primal iterate `w` and every
/// trajectory matches the seed implementation bit for bit.
#[derive(Debug, Clone, Copy, Default)]
pub struct L2;

impl Regularizer for L2 {
    fn name(&self) -> &'static str {
        "l2"
    }

    fn strong_convexity(&self) -> f64 {
        1.0
    }

    fn l1_weight(&self) -> f64 {
        0.0
    }
}
