//! Pluggable regularizers — the `g(w)` of the generalized primal-dual
//! setup (CoCoA's general framework / the L1 treatment of ProxCoCoA).
//!
//! The seed reproduced problem (1) for the L2 regularizer only; this
//! subsystem makes the regularizer a first-class object with its own
//! conjugate and prox operator, opening lasso and elastic-net workloads:
//!
//! `P(w) = lambda * Omega(w) + (1/n) sum_i loss(x_i^T w, y_i)`
//!
//! with `Omega` one of
//!
//! * [`L2`]           — `(1/2)||w||^2` (the paper's original problem),
//! * [`SmoothedL1`]   — `||w||_1 + (eps/2)||w||^2`, the epsilon-smoothed
//!   L1 of the L1-regularized distributed-optimization follow-up
//!   (1512.04011): the small quadratic term restores the strong convexity
//!   the dual machinery needs while keeping exact zeros in `w`,
//! * [`ElasticNet`]   — `eta||w||_1 + ((1-eta)/2)||w||^2`.
//!
//! ## The normalization that keeps L2 bit-identical
//!
//! Every supported `Omega` is `sigma`-strongly convex with an L1 part:
//! `Omega(w) = mu||w||_1 + (sigma/2)||w||^2`. Dividing by `sigma` and
//! folding it into the regularization strength gives the *normalized*
//! problem the whole runtime operates on:
//!
//! `P(w) = lambda_eff * [ (1/2)||w||^2 + kappa ||w||_1 ] + (1/n) sum loss`
//!
//! with `lambda_eff = lambda * sigma` and `kappa = mu / sigma`. The shared
//! vector the coordinator owns is `v = (1/(lambda_eff n)) sum_i alpha_i x_i`
//! — exactly the seed's `w = A alpha` when `kappa = 0` — and the primal
//! iterate is the prox/gradient map of the normalized conjugate:
//!
//! `w_j = prox(v_j) = soft(v_j, kappa)`,
//! `Omega_norm*(v) = (1/2)||soft(v, kappa)||^2 = (1/2)||w||^2`.
//!
//! Consequences the rest of the crate leans on:
//!
//! * the dual keeps the seed's shape `D = -(lambda_eff/2)||w||^2 - conj/n`
//!   with the *mapped* `w`, and the primal only gains the
//!   `lambda_eff * kappa * ||w||_1` term,
//! * the local solvers are untouched: they optimize the generalized
//!   framework's quadratic model of the local subproblem (smoothness `1`
//!   of the normalized conjugate) through the existing
//!   `Block { lambda_n = lambda_eff * n }` constants,
//! * for L2, `sigma = 1`, `kappa = 0`: `lambda_eff == lambda`, the prox is
//!   the identity, and every trajectory is bit-identical to the seed's.
//!
//! The leader applies the prox once per commit ([`Regularizer::prox_into`])
//! — the "prox step" whose dense/sparse-column kernels the `hot_paths`
//! bench tracks — and prox-induced exact zeros in the broadcast `w` are
//! what the counted transport's adaptive sparse encoding compresses on L1
//! runs.

mod elastic_net;
mod l1;
mod l2;

pub use elastic_net::ElasticNet;
pub use l1::SmoothedL1;
pub use l2::L2;

/// Soft-thresholding `sign(v) * max(|v| - k, 0)` — the prox operator of
/// `k ||.||_1` (and, for `k = 0`, exactly the identity).
#[inline]
pub fn soft_threshold(v: f64, k: f64) -> f64 {
    if v > k {
        v - k
    } else if v < -k {
        v + k
    } else {
        0.0
    }
}

/// `||w||_1` (the partial sum the regularized primal needs next to
/// `||w||^2`).
pub fn l1_norm(w: &[f64]) -> f64 {
    w.iter().map(|v| v.abs()).sum()
}

/// A regularizer `Omega(w) = mu||w||_1 + (sigma/2)||w||^2` for the
/// generalized problem `P(w) = lambda Omega(w) + (1/n) sum_i loss_i`.
///
/// Implementations provide the two constants; values, conjugates, and the
/// prox map all follow from them (see the module docs for the
/// normalization). Everything is per-coordinate separable.
pub trait Regularizer: Send + Sync + std::fmt::Debug {
    /// Stable name used in traces, errors, and checkpoint records.
    fn name(&self) -> &'static str;

    /// `sigma` — the strong-convexity constant of `Omega` (the coefficient
    /// of its quadratic part). The runtime folds it into
    /// `lambda_eff = lambda * sigma`.
    fn strong_convexity(&self) -> f64;

    /// `kappa = mu / sigma` — the L1 weight of the sigma-normalized
    /// regularizer (the soft-threshold level of the prox map).
    fn l1_weight(&self) -> f64;

    /// Advertises that the prox map produces exact zeros, i.e. the
    /// `w_nnz` trace column is a meaningful sparsity-recovery axis (the
    /// CLI prints it for such runs). Purely informational: the wire layer
    /// picks dense vs sparse encodings from the actual nonzero count, not
    /// from this hint.
    fn sparsity_hint(&self) -> bool {
        self.l1_weight() > 0.0
    }

    /// Is the prox map the identity (the L2 fast path: the leader skips
    /// the map and keeps `w == v` bit-for-bit)?
    fn is_identity_map(&self) -> bool {
        self.l1_weight() == 0.0
    }

    /// The per-coordinate prox/gradient map `w_j = d/dv Omega_norm*(v_j)`.
    #[inline]
    fn prox_coord(&self, v: f64) -> f64 {
        soft_threshold(v, self.l1_weight())
    }

    /// Apply the prox map to a whole shared vector (the leader's
    /// per-commit "prox step"; dense kernel in the `hot_paths` bench).
    fn prox_into(&self, v: &[f64], w: &mut [f64]) {
        debug_assert_eq!(v.len(), w.len());
        let k = self.l1_weight();
        for (wj, &vj) in w.iter_mut().zip(v) {
            *wj = soft_threshold(vj, k);
        }
    }

    /// Normalized regularizer value `Omega_norm(w) = (1/2)||w||^2 +
    /// kappa||w||_1` (multiply by `lambda_eff` for the primal term).
    fn value(&self, w: &[f64]) -> f64 {
        let norm_sq: f64 = w.iter().map(|v| v * v).sum();
        0.5 * norm_sq + self.l1_weight() * l1_norm(w)
    }

    /// Normalized conjugate `Omega_norm*(v) = (1/2)||soft(v, kappa)||^2`
    /// (multiply by `lambda_eff` for the dual term).
    fn conjugate(&self, v: &[f64]) -> f64 {
        let k = self.l1_weight();
        0.5 * v
            .iter()
            .map(|&vj| {
                let s = soft_threshold(vj, k);
                s * s
            })
            .sum::<f64>()
    }
}

/// Config-friendly regularizer selector (the `[regularizer]` TOML section
/// and [`Trainer::regularizer`](crate::Trainer::regularizer)).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RegularizerKind {
    /// `(1/2)||w||^2` — the paper's original problem (default).
    #[default]
    L2,
    /// `||w||_1 + (epsilon/2)||w||^2` — epsilon-smoothed L1 (lasso-style
    /// sparsity with exact zeros; `epsilon` trades dual conditioning
    /// against closeness to the pure-L1 optimum).
    L1 { epsilon: f64 },
    /// `l1_ratio ||w||_1 + ((1 - l1_ratio)/2)||w||^2`; `l1_ratio` must be
    /// in `[0, 1)` (use [`RegularizerKind::L1`] for the pure-L1 limit).
    ElasticNet { l1_ratio: f64 },
}

impl RegularizerKind {
    /// Parse from config names; `param` is `epsilon` for `l1` and
    /// `l1_ratio` for `elastic_net` (ignored for `l2`).
    pub fn from_name(name: &str, param: f64) -> Option<Self> {
        match name {
            "l2" => Some(RegularizerKind::L2),
            "l1" => Some(RegularizerKind::L1 { epsilon: param }),
            "elastic_net" => Some(RegularizerKind::ElasticNet { l1_ratio: param }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RegularizerKind::L2 => "l2",
            RegularizerKind::L1 { .. } => "l1",
            RegularizerKind::ElasticNet { .. } => "elastic_net",
        }
    }

    pub fn is_l2(&self) -> bool {
        matches!(self, RegularizerKind::L2)
    }

    /// Range-check the parameters; `Err(reason)` feeds the typed
    /// `Error::InvalidRegularizer` at `Trainer::build`.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            RegularizerKind::L2 => Ok(()),
            RegularizerKind::L1 { epsilon } => {
                if !epsilon.is_finite() || epsilon <= 0.0 {
                    Err(format!(
                        "l1 smoothing epsilon must be finite and > 0, got {epsilon} \
                         (the dual machinery needs the (epsilon/2)||w||^2 term's strong convexity)"
                    ))
                } else {
                    Ok(())
                }
            }
            RegularizerKind::ElasticNet { l1_ratio } => {
                if !l1_ratio.is_finite() || !(0.0..1.0).contains(&l1_ratio) {
                    Err(format!(
                        "elastic_net l1_ratio must be in [0, 1), got {l1_ratio} \
                         (for the pure-L1 limit use kind = \"l1\" with a smoothing epsilon)"
                    ))
                } else {
                    Ok(())
                }
            }
        }
    }

    pub fn build(&self) -> Box<dyn Regularizer> {
        match *self {
            RegularizerKind::L2 => Box::new(L2),
            RegularizerKind::L1 { epsilon } => Box::new(SmoothedL1::new(epsilon)),
            RegularizerKind::ElasticNet { l1_ratio } => Box::new(ElasticNet::new(l1_ratio)),
        }
    }
}

impl std::fmt::Display for RegularizerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegularizerKind::L2 => write!(f, "l2"),
            RegularizerKind::L1 { epsilon } => write!(f, "l1(ε={epsilon})"),
            RegularizerKind::ElasticNet { l1_ratio } => {
                write!(f, "elastic_net(η={l1_ratio})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<RegularizerKind> {
        vec![
            RegularizerKind::L2,
            RegularizerKind::L1 { epsilon: 0.5 },
            RegularizerKind::ElasticNet { l1_ratio: 0.3 },
        ]
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(2.0, 0.5), 1.5);
        assert_eq!(soft_threshold(-2.0, 0.5), -1.5);
        assert_eq!(soft_threshold(0.3, 0.5), 0.0);
        assert_eq!(soft_threshold(-0.3, 0.5), 0.0);
        // k = 0 is exactly the identity (the L2 fast path's contract)
        for v in [3.25, -1.5, 0.0, f64::MIN_POSITIVE] {
            assert_eq!(soft_threshold(v, 0.0).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn l2_is_identity_map_with_unit_strength() {
        let r = L2;
        assert_eq!(r.strong_convexity(), 1.0);
        assert_eq!(r.l1_weight(), 0.0);
        assert!(r.is_identity_map());
        assert!(!r.sparsity_hint());
        let v = [1.5, -2.0, 0.0];
        let mut w = [0.0; 3];
        r.prox_into(&v, &mut w);
        assert_eq!(w, v);
        // Omega_norm == Omega_norm* for the self-dual L2
        assert!((r.value(&v) - r.conjugate(&v)).abs() < 1e-15);
    }

    #[test]
    fn l1_and_elastic_net_constants() {
        let l1 = SmoothedL1::new(0.5);
        assert_eq!(l1.strong_convexity(), 0.5);
        assert_eq!(l1.l1_weight(), 2.0); // mu/sigma = 1/0.5
        assert!(l1.sparsity_hint() && !l1.is_identity_map());

        let en = ElasticNet::new(0.25);
        assert_eq!(en.strong_convexity(), 0.75);
        assert!((en.l1_weight() - 0.25 / 0.75).abs() < 1e-15);

        // eta = 0 degenerates to L2 exactly
        let en0 = ElasticNet::new(0.0);
        assert_eq!(en0.l1_weight(), 0.0);
        assert!(en0.is_identity_map());
        assert_eq!(en0.strong_convexity(), 1.0);
    }

    #[test]
    fn prox_minimizes_its_objective() {
        // prox(v) = argmin_u (1/2)(u - v)^2 + kappa|u|: the returned point
        // must beat a grid of perturbations for every kind.
        for kind in all_kinds() {
            let reg = kind.build();
            let k = reg.l1_weight();
            let obj = |u: f64, v: f64| 0.5 * (u - v) * (u - v) + k * u.abs();
            for &v in &[-2.0, -0.9, -0.1, 0.0, 0.4, 1.7] {
                let star = reg.prox_coord(v);
                let at_star = obj(star, v);
                for step in [-0.1, -1e-3, 1e-3, 0.1] {
                    assert!(
                        obj(star + step, v) >= at_star - 1e-12,
                        "{kind}: prox({v}) = {star} not a minimizer"
                    );
                }
            }
        }
    }

    #[test]
    fn fenchel_young_for_normalized_pair() {
        // Omega_norm(w) + Omega_norm*(v) >= w . v, equality at w = prox(v).
        for kind in all_kinds() {
            let reg = kind.build();
            let v = [1.2, -0.7, 0.05, -2.4, 0.0];
            for w in [
                [0.5, -0.5, 0.0, -1.0, 0.3],
                [1.2, -0.7, 0.05, -2.4, 0.0],
                [0.0, 0.0, 0.0, 0.0, 0.0],
            ] {
                let dot: f64 = w.iter().zip(&v).map(|(a, b)| a * b).sum();
                assert!(
                    reg.value(&w) + reg.conjugate(&v) >= dot - 1e-12,
                    "{kind}: Fenchel-Young violated"
                );
            }
            // equality at the prox point
            let mut w_star = [0.0; 5];
            reg.prox_into(&v, &mut w_star);
            let dot: f64 = w_star.iter().zip(&v).map(|(a, b)| a * b).sum();
            let slack = reg.value(&w_star) + reg.conjugate(&v) - dot;
            assert!(slack.abs() < 1e-12, "{kind}: slack {slack} at prox point");
        }
    }

    #[test]
    fn kind_roundtrips_through_names_and_validates() {
        for kind in all_kinds() {
            let param = match kind {
                RegularizerKind::L2 => 0.0,
                RegularizerKind::L1 { epsilon } => epsilon,
                RegularizerKind::ElasticNet { l1_ratio } => l1_ratio,
            };
            assert_eq!(RegularizerKind::from_name(kind.name(), param), Some(kind));
            assert!(kind.validate().is_ok(), "{kind}");
            assert_eq!(kind.build().name(), kind.name());
        }
        assert_eq!(RegularizerKind::from_name("l0", 1.0), None);
        assert!(RegularizerKind::L1 { epsilon: 0.0 }.validate().is_err());
        assert!(RegularizerKind::L1 { epsilon: f64::NAN }.validate().is_err());
        assert!(RegularizerKind::ElasticNet { l1_ratio: 1.0 }.validate().is_err());
        assert!(RegularizerKind::ElasticNet { l1_ratio: -0.1 }.validate().is_err());
        assert!(RegularizerKind::default().is_l2());
    }

    #[test]
    fn l1_norm_sums_absolutes() {
        assert_eq!(l1_norm(&[1.0, -2.5, 0.0, 0.5]), 4.0);
        assert_eq!(l1_norm(&[]), 0.0);
    }
}
