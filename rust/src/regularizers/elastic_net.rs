//! Elastic net — the L1/L2 mixture.

use super::Regularizer;

/// `Omega(w) = eta ||w||_1 + ((1 - eta)/2)||w||^2` with mixing ratio
/// `eta = l1_ratio` in `[0, 1)`. `eta = 0` is exactly [`super::L2`];
/// `eta -> 1` approaches pure L1 (use [`super::SmoothedL1`] there — the
/// strong convexity `sigma = 1 - eta` vanishes at the limit, which is why
/// `eta = 1` is rejected with a typed error at `Trainer::build`).
#[derive(Debug, Clone, Copy)]
pub struct ElasticNet {
    l1_ratio: f64,
}

impl ElasticNet {
    /// `l1_ratio` must be finite and in `[0, 1)` (validated with a typed
    /// error at `Trainer::build`; asserted here for direct users).
    pub fn new(l1_ratio: f64) -> Self {
        assert!(
            l1_ratio.is_finite() && (0.0..1.0).contains(&l1_ratio),
            "elastic_net l1_ratio must be in [0, 1), got {l1_ratio}"
        );
        ElasticNet { l1_ratio }
    }

    pub fn l1_ratio(&self) -> f64 {
        self.l1_ratio
    }
}

impl Regularizer for ElasticNet {
    fn name(&self) -> &'static str {
        "elastic_net"
    }

    fn strong_convexity(&self) -> f64 {
        1.0 - self.l1_ratio
    }

    fn l1_weight(&self) -> f64 {
        self.l1_ratio / (1.0 - self.l1_ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_recover_the_mixture() {
        // lambda_eff * (1/2)||w||^2 term carries lambda(1 - eta)/2 and the
        // L1 term lambda_eff * kappa = lambda * eta — the mixture as
        // written, just renormalized.
        let eta = 0.4;
        let r = ElasticNet::new(eta);
        let lambda = 0.2;
        let lambda_eff = lambda * r.strong_convexity();
        assert!((lambda_eff - lambda * (1.0 - eta)).abs() < 1e-15);
        assert!((lambda_eff * r.l1_weight() - lambda * eta).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "l1_ratio")]
    fn ratio_one_panics() {
        let _ = ElasticNet::new(1.0);
    }
}
