//! Figure harnesses: the code that regenerates Figures 1-4 and the
//! headline 25x claim. Each writes per-run traces as CSV under
//! `results/figN/` and returns structured summaries for the CLI tables.
//!
//! All sweeps run on ONE warm-started [`Session`] per dataset:
//! [`Session::reset`] reuses the spawned worker threads between grid
//! points instead of re-partitioning and re-spawning per run (identical
//! trajectories — reset restores the spawn-time rng streams).

use crate::algorithms::{Algorithm, Cocoa, LocalSgd, MinibatchCd, MinibatchSgd};
use crate::api::Session;
use crate::config::Backend;
use crate::driver::{IntoDriverSpec, MaxRounds, StoppingRule, SuboptBelow};
use crate::error::Result;
use crate::loss::LossKind;
use crate::telemetry::Trace;
use crate::transport::TransportKind;

use super::{cached_optimum, make_session, ExpDataset, Profile};

/// The four Section-6 competitors at a given per-round H.
pub fn competitors(h: usize) -> Vec<Box<dyn Algorithm>> {
    vec![
        Box::new(Cocoa::new(h)),
        Box::new(MinibatchCd::new(h)),
        Box::new(LocalSgd::new(h)),
        Box::new(MinibatchSgd::new(h)),
    ]
}

/// H grid relative to a block size: the paper sweeps H from 1 to ~n_k
/// (processing nearly all local data per round was best for the
/// locally-updating methods).
pub fn h_grid(n_k: usize, profile: Profile) -> Vec<usize> {
    let fracs: &[f64] = match profile {
        Profile::Smoke => &[0.01, 0.1, 1.0],
        Profile::Paper => &[0.001, 0.01, 0.1, 0.5, 1.0],
    };
    let mut grid: Vec<usize> = fracs
        .iter()
        .map(|f| ((n_k as f64 * f).round() as usize).max(1))
        .collect();
    grid.dedup();
    grid
}

/// One algorithm's best-H result on one dataset.
pub struct BestH {
    pub algorithm: &'static str,
    pub h: usize,
    /// Simulated seconds to reach `target` suboptimality (None = never).
    pub time_to_target: Option<f64>,
    /// Communicated vectors to reach it.
    pub vectors_to_target: Option<u64>,
    pub final_subopt: f64,
    pub trace: Trace,
}

/// Reset-then-run: every grid point starts from the spawn-identical state.
fn warm_run(
    session: &mut Session,
    algo: &mut dyn Algorithm,
    stopping: impl IntoDriverSpec,
) -> Result<Trace> {
    session.reset()?;
    session.run(algo, stopping)
}

/// Run every competitor over the H grid on one dataset and keep the best-H
/// trace per algorithm — the exact construction of Figures 1 and 2
/// ("for all competing methods, we present the result for the batch size
/// that yields the best performance").
pub fn fig1_fig2_dataset(
    ds: &ExpDataset,
    profile: Profile,
    rounds: u64,
    target: f64,
    results_dir: &str,
) -> Result<Vec<BestH>> {
    let p_star = cached_optimum(ds, LossKind::Hinge, results_dir)?;
    let n_k = ds.data.n() / ds.k;
    let grid = h_grid(n_k, profile);
    // overshoot the target 4x before the round cap ends the sweep point
    // (subopt listed first: it names the stop when both fire together)
    let stopping = || SuboptBelow::new(target / 4.0).or(MaxRounds::new(rounds));

    let mut session = make_session(
        ds,
        LossKind::Hinge,
        Backend::Native,
        "artifacts",
        17,
        TransportKind::InProc,
    )?;
    session.set_reference_optimum(Some(p_star));

    let mut best: Vec<Option<BestH>> = vec![None, None, None, None];
    for &h in &grid {
        for (slot, mut algo) in competitors(h).into_iter().enumerate() {
            let trace = warm_run(&mut session, algo.as_mut(), stopping())?;
            let candidate = BestH {
                algorithm: algo.name(),
                h,
                time_to_target: trace.time_to_subopt(target),
                vectors_to_target: trace.vectors_to_subopt(target),
                final_subopt: trace
                    .rows
                    .last()
                    .map(|r| r.primal_subopt)
                    .unwrap_or(f64::INFINITY),
                trace,
            };
            let better = match &best[slot] {
                None => true,
                Some(cur) => match (candidate.time_to_target, cur.time_to_target) {
                    (Some(a), Some(b)) => a < b,
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => candidate.final_subopt < cur.final_subopt,
                },
            };
            if better {
                best[slot] = Some(candidate);
            }
        }
    }
    session.shutdown();
    let best: Vec<BestH> = best.into_iter().map(Option::unwrap).collect();
    // persist the winning traces: the series of Figures 1 and 2
    for b in &best {
        let path = format!(
            "{results_dir}/fig1_fig2/{}_{}_h{}.csv",
            ds.name, b.algorithm, b.h
        );
        b.trace.to_csv(path)?;
    }
    Ok(best)
}

/// Figure 3: the effect of H on CoCoA (cov dataset, K = 4 in the paper).
/// The whole sweep warm-starts one session (see the module docs).
///
/// This sweep runs on the byte-exact `counted` transport: the measured
/// wire bytes (headers, sparse dw encodings — not the analytic vector
/// count) drive the netsim round time, so the H trade-off reflects what a
/// real fabric would carry. The `bytes_measured` CSV column is populated.
pub fn fig3(
    ds: &ExpDataset,
    profile: Profile,
    rounds: u64,
    results_dir: &str,
) -> Result<Vec<(usize, Trace)>> {
    let p_star = cached_optimum(ds, LossKind::Hinge, results_dir)?;
    let n_k = ds.data.n() / ds.k;
    let mut grid = vec![1usize];
    grid.extend(h_grid(n_k, profile));
    grid.dedup();
    let mut session = make_session(
        ds,
        LossKind::Hinge,
        Backend::Native,
        "artifacts",
        19,
        TransportKind::Counted,
    )?;
    session.set_reference_optimum(Some(p_star));
    let mut out = Vec::new();
    for h in grid {
        let trace = warm_run(&mut session, &mut Cocoa::new(h), MaxRounds::new(rounds))?;
        trace.to_csv(format!("{results_dir}/fig3/cocoa_h{h}.csv"))?;
        out.push((h, trace));
    }
    session.shutdown();
    Ok(out)
}

/// One (algorithm, beta) cell of Figure 4.
pub struct BetaCell {
    pub algorithm: &'static str,
    pub beta: f64,
    pub time_to_target: Option<f64>,
    pub final_subopt: f64,
}

/// Figure 4: scaling the averaging step by beta, for two batch sizes
/// (paper: H = 1e5 and H = 100 on cov). beta ranges over [1, K] for the
/// K-averaged methods and [1, b] analogues for mini-batch CD.
pub fn fig4(
    ds: &ExpDataset,
    h: usize,
    rounds: u64,
    target: f64,
    results_dir: &str,
) -> Result<Vec<BetaCell>> {
    let p_star = cached_optimum(ds, LossKind::Hinge, results_dir)?;
    let k = ds.k as f64;
    let b_total = (h * ds.k) as f64;
    let mut cells = Vec::new();
    let betas_k: Vec<f64> = vec![1.0, (k / 2.0).max(1.0), k];
    let betas_b: Vec<f64> =
        vec![1.0, (b_total / 100.0).max(1.0), (b_total / 10.0).max(1.0), b_total];
    let stopping = || SuboptBelow::new(target / 4.0).or(MaxRounds::new(rounds));

    let mut session = make_session(
        ds,
        LossKind::Hinge,
        Backend::Native,
        "artifacts",
        23,
        TransportKind::InProc,
    )?;
    session.set_reference_optimum(Some(p_star));

    let mut run_one = |session: &mut Session,
                       mut algo: Box<dyn Algorithm>,
                       beta: f64|
     -> Result<()> {
        let trace = warm_run(session, algo.as_mut(), stopping())?;
        trace.to_csv(format!(
            "{results_dir}/fig4/{}_h{}_beta{}.csv",
            algo.name(),
            h,
            beta
        ))?;
        cells.push(BetaCell {
            algorithm: algo.name(),
            beta,
            time_to_target: trace.time_to_subopt(target),
            final_subopt: trace
                .rows
                .last()
                .map(|r| r.primal_subopt)
                .unwrap_or(f64::INFINITY),
        });
        Ok(())
    };

    for &beta in &betas_k {
        run_one(&mut session, Box::new(Cocoa::averaging(h, beta)), beta)?;
        run_one(&mut session, Box::new(LocalSgd::new(h).beta(beta)), beta)?;
        run_one(&mut session, Box::new(MinibatchSgd::new(h).beta(beta)), beta)?;
    }
    for &beta in &betas_b {
        run_one(&mut session, Box::new(MinibatchCd::new(h).beta_b(beta)), beta)?;
    }
    session.shutdown();
    Ok(cells)
}

/// The headline number: how much faster CoCoA reaches `target`
/// suboptimality than the best competitor (paper: ~25x on average).
pub struct Headline {
    pub dataset: &'static str,
    pub cocoa_time: Option<f64>,
    pub best_other: Option<(String, f64)>,
    pub speedup: Option<f64>,
}

pub fn headline(best: &[BestH], dataset: &'static str) -> Headline {
    let cocoa = best.iter().find(|b| b.algorithm == "cocoa");
    let cocoa_time = cocoa.and_then(|b| b.time_to_target);
    let best_other = best
        .iter()
        .filter(|b| b.algorithm != "cocoa")
        .filter_map(|b| b.time_to_target.map(|t| (b.algorithm.to_string(), t)))
        .min_by(|a, b| a.1.total_cmp(&b.1));
    let speedup = match (cocoa_time, &best_other) {
        (Some(c), Some((_, o))) if c > 0.0 => Some(o / c),
        _ => None,
    };
    Headline { dataset, cocoa_time, best_other, speedup }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h_grid_scales_with_block() {
        let g = h_grid(1000, Profile::Paper);
        assert!(g.contains(&1000));
        assert!(g.iter().all(|&h| h >= 1));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn competitors_are_the_papers_four() {
        let names: Vec<_> = competitors(10).iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["cocoa", "minibatch_cd", "local_sgd", "minibatch_sgd"]);
    }

    #[test]
    fn headline_math() {
        let mk = |alg: &'static str, t: Option<f64>| BestH {
            algorithm: alg,
            h: 1,
            time_to_target: t,
            vectors_to_target: t.map(|x| x as u64),
            final_subopt: 0.0,
            trace: Trace::new(alg, "x", 1, 1, 1.0, 0.1),
        };
        let best = vec![
            mk("cocoa", Some(2.0)),
            mk("minibatch_cd", Some(50.0)),
            mk("local_sgd", Some(10.0)),
            mk("minibatch_sgd", None),
        ];
        let h = headline(&best, "cov");
        assert_eq!(h.speedup, Some(5.0));
        assert_eq!(h.best_other.unwrap().0, "local_sgd");
    }
}
