//! Theorem 2 / Proposition 1 validation — our addition to the paper's
//! empirical section: run CoCoA with the smooth loss the analysis assumes
//! and check the measured dual convergence against the predicted geometric
//! rate.

use crate::algorithms::Cocoa;
use crate::api::Trainer;
use crate::data::{Dataset, Partition, PartitionStrategy};
use crate::driver::MaxRounds;
use crate::error::Result;
use crate::loss::LossKind;
use crate::netsim::NetworkModel;
use crate::theory;

pub struct TheoryReport {
    pub k: usize,
    pub h: usize,
    pub theta: f64,
    pub sigma: f64,
    pub predicted_rate: f64,
    /// Geometric-mean measured per-round contraction of D* - D(alpha^t).
    pub measured_rate: f64,
    /// Theorem 2 is an upper bound: measured <= predicted must hold.
    pub bound_respected: bool,
}

/// Run CoCoA (smoothed hinge, exact sampling regime) and compare the
/// measured dual contraction to the Theorem 2 prediction.
pub fn validate(
    data: &Dataset,
    k: usize,
    h: usize,
    lambda: f64,
    gamma: f64,
    rounds: u64,
    seed: u64,
) -> Result<TheoryReport> {
    let n = data.n();
    let part = Partition::new(PartitionStrategy::Contiguous, n, k, 0);
    let loss = LossKind::SmoothedHinge { gamma };

    // theory quantities
    let n_max = part.n_max();
    let theta = theory::theta_local_sdca(h, lambda, n, gamma, n_max);
    let sigma = theory::sigma_min_estimate(data, &part, 100, seed);
    let predicted_rate = theory::theorem2_rate(theta, k, lambda, n, gamma, sigma);

    // the true dual optimum (tight serial solve)
    let loss_impl = loss.build();
    let (_, w_star) = crate::objective::compute_optimum(
        data, lambda, loss_impl.as_ref(), 1e-10, 4_000,
    );
    // D* == P* at optimality (strong duality; smooth loss)
    let d_star = crate::objective::primal(data, &w_star, lambda, loss_impl.as_ref());

    let mut session = Trainer::on(data)
        .partition(part)
        .loss(loss)
        .lambda(lambda)
        .network(NetworkModel::free())
        .seed(seed)
        .label("theory")
        .build()?;
    let trace = session.run(&mut Cocoa::new(h), MaxRounds::new(rounds))?;
    session.shutdown();

    // measured geometric-mean contraction of the dual suboptimality
    let subopts: Vec<f64> = trace
        .rows
        .iter()
        .map(|r| (d_star - r.dual).max(1e-15))
        .collect();
    let first = subopts.first().copied().unwrap_or(1.0);
    let last = subopts.last().copied().unwrap_or(1.0);
    let steps = (subopts.len() - 1).max(1) as f64;
    let measured_rate = (last / first).powf(1.0 / steps);

    Ok(TheoryReport {
        k,
        h,
        theta,
        sigma,
        predicted_rate,
        measured_rate,
        bound_respected: measured_rate <= predicted_rate + 1e-6,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;

    #[test]
    fn theorem2_bound_holds_on_small_problem() {
        let data = cov_like(300, 10, 0.05, 21);
        let rep = validate(&data, 3, 50, 0.05, 1.0, 15, 4).unwrap();
        assert!(rep.theta < 1.0 && rep.theta > 0.0);
        assert!(rep.sigma >= 0.0);
        assert!(rep.predicted_rate < 1.0);
        assert!(
            rep.bound_respected,
            "measured {} > predicted {}",
            rep.measured_rate, rep.predicted_rate
        );
    }

    #[test]
    fn more_local_work_converges_faster_per_round() {
        let data = cov_like(240, 8, 0.05, 22);
        let fast = validate(&data, 2, 120, 0.1, 1.0, 10, 5).unwrap();
        let slow = validate(&data, 2, 5, 0.1, 1.0, 10, 5).unwrap();
        assert!(
            fast.measured_rate < slow.measured_rate,
            "H=120 rate {} !< H=5 rate {}",
            fast.measured_rate,
            slow.measured_rate
        );
        // and the theory predicts the same ordering
        assert!(fast.predicted_rate < slow.predicted_rate);
    }
}
