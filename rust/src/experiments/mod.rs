//! Experiment harnesses that regenerate every table and figure of the
//! paper's evaluation (Section 6) on the synthetic dataset regimes.
//!
//! * [`table1`] — the dataset summary table.
//! * [`figures::fig1_fig2`] — suboptimality vs time and vs communicated
//!   vectors, best-H per algorithm (Figures 1 and 2 share runs).
//! * [`figures::fig3`] — the H communication/computation trade-off.
//! * [`figures::fig4`] — the beta scaling sweep.
//! * [`figures::headline`] — the "25x to .001-accuracy" ratio.
//! * [`theory_val`] — Theorem 2 / Proposition 1 validation (our addition).
//! * [`sparsity`] — the sparsity-recovery figure for the L1 workload the
//!   regularizers subsystem opens (nonzero count + suboptimality vs
//!   rounds across K, exact closed-form reference).
//!
//! Everything is exposed as library functions so the CLI (`cocoa repro`),
//! the criterion benches, and the integration tests drive the same code.

pub mod figures;
pub mod sparsity;
pub mod theory_val;

use anyhow::Result;

use crate::config::Backend;
use crate::data::{self, Dataset, Partition, PartitionStrategy};
use crate::loss::LossKind;
use crate::netsim::NetworkModel;
use crate::objective;
use crate::transport::TransportKind;

/// Experiment scale. `Smoke` keeps integration tests fast; `Paper` is the
/// scaled-down-but-faithful reproduction grid (full regimes, 1-core budget).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    Smoke,
    Paper,
}

/// One benchmark dataset: the paper's Table-1 row analogue.
pub struct ExpDataset {
    pub name: &'static str,
    pub data: Dataset,
    pub k: usize,
    pub lambda: f64,
}

impl ExpDataset {
    pub fn partition(&self) -> Partition {
        Partition::new(PartitionStrategy::Contiguous, self.data.n(), self.k, 0)
    }
}

/// The three dataset regimes of Table 1, scaled per profile. K matches the
/// paper (4 / 8 / 32); lambda = 1/n as in the paper's source experiments.
pub fn datasets(profile: Profile) -> Vec<ExpDataset> {
    match profile {
        Profile::Smoke => vec![
            ExpDataset {
                name: "cov",
                data: data::cov_like(1200, 54, 0.1, 11),
                k: 4,
                lambda: 1.0 / 1200.0,
            },
            ExpDataset {
                name: "rcv1",
                data: data::rcv1_like(1600, 800, 8, 0.1, 12),
                k: 8,
                lambda: 1.0 / 1600.0,
            },
            ExpDataset {
                name: "imagenet",
                data: data::imagenet_like(640, 1024, 0.1, 13),
                k: 32,
                lambda: 1.0 / 640.0,
            },
        ],
        Profile::Paper => vec![
            ExpDataset {
                name: "cov",
                data: data::cov_like(100_000, 54, 0.1, 11),
                k: 4,
                lambda: 1e-5,
            },
            ExpDataset {
                name: "rcv1",
                data: data::rcv1_like(50_000, 10_000, 12, 0.1, 12),
                k: 8,
                lambda: 2e-5,
            },
            ExpDataset {
                name: "imagenet",
                data: data::imagenet_like(4_000, 16_000, 0.1, 13),
                k: 32,
                lambda: 2.5e-4,
            },
        ],
    }
}

/// The network model all reproduction figures use (the paper's testbed is
/// a commodity EC2 cluster).
pub fn default_net() -> NetworkModel {
    NetworkModel::ec2_like()
}

/// Reference optimum `P*`, cached on disk under `results/optima/` keyed by
/// the dataset fingerprint (computing it runs single-machine SDCA to
/// gap < 1e-8 — minutes on the Paper profile, so the cache matters).
pub fn cached_optimum(
    ds: &ExpDataset,
    loss: LossKind,
    results_dir: &str,
) -> Result<f64> {
    let dir = std::path::Path::new(results_dir).join("optima");
    std::fs::create_dir_all(&dir)?;
    let key = format!(
        "{}_{}_{}_{}.json",
        ds.name,
        ds.data.fingerprint(),
        loss.artifact_name(),
        ds.lambda
    );
    let path = dir.join(key);
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(v) = text.trim().parse::<f64>() {
            return Ok(v);
        }
    }
    let loss_impl = loss.build();
    let (p_star, _) = objective::compute_optimum(
        &ds.data,
        ds.lambda,
        loss_impl.as_ref(),
        1e-8,
        2_000,
    );
    std::fs::write(&path, format!("{p_star:.17}"))?;
    Ok(p_star)
}

/// Table 1: the dataset summary rows.
pub struct Table1Row {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub density: f64,
    pub k: usize,
    pub lambda: f64,
}

pub fn table1(profile: Profile) -> Vec<Table1Row> {
    datasets(profile)
        .into_iter()
        .map(|ds| Table1Row {
            name: ds.name,
            n: ds.data.n(),
            d: ds.data.d(),
            density: ds.data.density(),
            k: ds.k,
            lambda: ds.lambda,
        })
        .collect()
}

/// Build a [`Session`](crate::Session) for an experiment dataset with the
/// standard settings (LocalSDCA, EC2-like network) and the given
/// transport. Use [`TransportKind::InProc`] for pure-speed sweeps and
/// [`TransportKind::Counted`] where measured wire bytes should drive the
/// simulated time axis (the fig3 sweeps do).
pub fn make_session(
    ds: &ExpDataset,
    loss: LossKind,
    backend: Backend,
    artifacts_dir: &str,
    seed: u64,
    transport: TransportKind,
) -> crate::error::Result<crate::Session> {
    crate::Trainer::on(&ds.data)
        .partition(ds.partition())
        .loss(loss)
        .lambda(ds.lambda)
        .backend(backend)
        .artifacts_dir(artifacts_dir)
        .network(default_net())
        .transport(transport)
        .seed(seed)
        .label(ds.name)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_datasets_have_paper_regimes() {
        let ds = datasets(Profile::Smoke);
        assert_eq!(ds.len(), 3);
        let cov = &ds[0];
        assert!(cov.data.n() > cov.data.d()); // n >> d
        let rcv = &ds[1];
        assert!(rcv.data.density() < 0.05); // sparse
        let img = &ds[2];
        assert!(img.data.d() > img.data.n()); // n << d
        assert_eq!((cov.k, rcv.k, img.k), (4, 8, 32)); // paper's K
    }

    #[test]
    fn table1_rows_match_datasets() {
        let rows = table1(Profile::Smoke);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "cov");
        assert!(rows[1].density < 0.05);
    }

    #[test]
    fn optimum_cache_roundtrip() {
        let ds = ExpDataset {
            name: "cov",
            data: data::cov_like(150, 8, 0.1, 3),
            k: 2,
            lambda: 0.01,
        };
        let dir = std::env::temp_dir().join("cocoa_optcache");
        let dir = dir.to_str().unwrap();
        let a = cached_optimum(&ds, LossKind::Hinge, dir).unwrap();
        let b = cached_optimum(&ds, LossKind::Hinge, dir).unwrap();
        assert_eq!(a, b);
        assert!(a.is_finite() && a > 0.0);
    }
}
