//! Sparsity-recovery figure — the workload the regularizers subsystem
//! opens: CoCoA with the epsilon-smoothed L1 regularizer on a planted
//! orthogonal lasso design, tracking the nonzero count of `w` and the
//! primal suboptimality vs rounds across K ∈ {1, 2, 4}.
//!
//! The design is chosen so the optimum is *closed form* (soft
//! thresholding per coordinate, smoothing included), which gives the
//! figure an exact reference: the `w_nnz` trace column must land on the
//! true support, and the suboptimality axis is measured against the exact
//! `P*`. Runs use the counted transport, so the figure also reports the
//! measured wire bytes — smaller than an equivalent L2 run because the
//! prox-sparse broadcasts take the adaptive sparse encoding.

use anyhow::Result;

use crate::algorithms::Cocoa;
use crate::data::{CsrMatrix, Dataset, Features};
use crate::driver::{DriverSpec, MaxRounds};
use crate::loss::LossKind;
use crate::objective;
use crate::regularizers::{soft_threshold, RegularizerKind};
use crate::telemetry::Trace;
use crate::transport::TransportKind;
use crate::Trainer;

use super::Profile;

/// A planted lasso instance with its exact solution.
pub struct LassoProblem {
    pub data: Dataset,
    pub lambda: f64,
    pub epsilon: f64,
    /// Column indices whose closed-form optimum is nonzero.
    pub true_support: Vec<usize>,
    /// The exact (smoothed-lasso) optimum, coordinate-wise soft threshold.
    pub w_star: Vec<f64>,
    /// `P(w_star)` — the exact reference for the suboptimality axis.
    pub p_star: f64,
}

/// The orthogonal indicator design every lasso golden/figure instance is
/// built on: `d` columns, `m` rows per column, each row the indicator of
/// its column (so `X^T X = m I` and the lasso optimum is coordinate-wise
/// closed form — see [`lasso_closed_form`]). Labels are constant per
/// column (`y_col[j]`). Rows are grouped by column, so a contiguous
/// partition into K | d blocks keeps blocks orthogonal.
pub fn lasso_design(d: usize, m: usize, y_col: &[f64]) -> Dataset {
    assert_eq!(y_col.len(), d);
    let n = d * m;
    let mut triplets = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for j in 0..d {
        for r in 0..m {
            triplets.push((j * m + r, j as u32, 1.0));
            labels.push(y_col[j]);
        }
    }
    Dataset::new(Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets)), labels)
}

/// The exact smoothed-lasso optimum on [`lasso_design`]:
/// `w_j = soft(z_j/n, lambda) / (lambda*epsilon + m/n)` with `z_j = m
/// y_col[j]` (the prox threshold in primal units is exactly `lambda` for
/// the epsilon-smoothed L1).
pub fn lasso_closed_form(
    d: usize,
    m: usize,
    y_col: &[f64],
    lambda: f64,
    epsilon: f64,
) -> Vec<f64> {
    assert_eq!(y_col.len(), d);
    let n = (d * m) as f64;
    let c = m as f64 / n;
    (0..d)
        .map(|j| soft_threshold(m as f64 * y_col[j] / n, lambda) / (lambda * epsilon + c))
        .collect()
}

/// Build the planted instance: the first `active` columns carry responses
/// 2.5x above the soft threshold (alternating sign); the rest sit at 0.4x
/// below it, so the optimum's support is exactly the active set.
pub fn planted_lasso(
    d: usize,
    rows_per_col: usize,
    active: usize,
    lambda: f64,
    epsilon: f64,
) -> LassoProblem {
    assert!(active <= d);
    let m = rows_per_col;
    // z_j / n = y_j * m / n = y_j / d, so y_j = d * (target z_j / n)
    let y_col: Vec<f64> = (0..d)
        .map(|j| {
            let sign = if j % 2 == 0 { 1.0 } else { -1.0 };
            let z_over_n = if j < active { 2.5 * lambda } else { 0.4 * lambda };
            sign * z_over_n * d as f64
        })
        .collect();
    let data = lasso_design(d, m, &y_col);
    let w_star = lasso_closed_form(d, m, &y_col, lambda, epsilon);
    let true_support: Vec<usize> = (0..d).filter(|&j| w_star[j] != 0.0).collect();
    let reg = RegularizerKind::L1 { epsilon }.build();
    let p_star = objective::primal_reg(
        &data,
        &w_star,
        lambda,
        reg.as_ref(),
        &crate::loss::Squared,
    );
    LassoProblem { data, lambda, epsilon, true_support, w_star, p_star }
}

/// One K's run of the sparsity-recovery sweep.
pub struct SparsityRun {
    pub k: usize,
    pub trace: Trace,
    /// Nonzeros of the final iterate (== `true_nnz` on a recovered run).
    pub final_nnz: u64,
    pub true_nnz: usize,
    /// Final nonzero pattern matches the closed-form support exactly.
    pub support_exact: bool,
    pub final_subopt: f64,
    /// Byte-exact wire bytes (counted transport; prox-sparse broadcasts).
    pub bytes_measured: u64,
}

/// Problem scale per profile.
fn problem(profile: Profile) -> LassoProblem {
    match profile {
        Profile::Smoke => planted_lasso(8, 6, 3, 0.1, 0.5),
        Profile::Paper => planted_lasso(64, 32, 8, 0.05, 0.5),
    }
}

/// Run CoCoA+ (adding, sigma' = K) with the smoothed-L1 regularizer for
/// K ∈ {1, 2, 4}; write one trace CSV per K under
/// `<results_dir>/fig_sparsity/` (the `w_nnz` and `primal_subopt` columns
/// are the figure's two axes).
pub fn sparsity_recovery(
    profile: Profile,
    rounds: u64,
    results_dir: &str,
) -> Result<Vec<SparsityRun>> {
    let prob = problem(profile);
    let n = prob.data.n();
    let mut runs = Vec::new();
    for k in [1usize, 2, 4] {
        let mut session = Trainer::on(&prob.data)
            .workers(k)
            .loss(LossKind::Squared)
            .lambda(prob.lambda)
            .regularizer(RegularizerKind::L1 { epsilon: prob.epsilon })
            .transport(TransportKind::Counted)
            .seed(7)
            .label("lasso_planted")
            .build()?;
        session.set_reference_optimum(Some(prob.p_star));
        let h = n / k; // one local pass per round
        let trace = session.run(
            &mut Cocoa::adding(h),
            DriverSpec::new(MaxRounds::new(rounds)).eval_every(10),
        )?;
        trace.to_csv(format!("{results_dir}/fig_sparsity/lasso_K{k}.csv"))?;

        let w = session.w();
        let support: Vec<usize> =
            (0..w.len()).filter(|&j| w[j] != 0.0).collect();
        let last = *trace.rows.last().expect("at least round 0");
        runs.push(SparsityRun {
            k,
            final_nnz: last.w_nnz,
            true_nnz: prob.true_support.len(),
            support_exact: support == prob.true_support,
            final_subopt: last.primal_subopt,
            bytes_measured: last.bytes_measured,
            trace,
        });
        session.shutdown();
    }
    Ok(runs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_problem_is_internally_consistent() {
        let prob = planted_lasso(8, 6, 3, 0.1, 0.5);
        assert_eq!(prob.data.n(), 48);
        assert_eq!(prob.data.d(), 8);
        assert_eq!(prob.true_support, vec![0, 1, 2]);
        // active coordinates alternate sign; inactive are exact zeros
        assert!(prob.w_star[0] > 0.0 && prob.w_star[1] < 0.0 && prob.w_star[2] > 0.0);
        assert!(prob.w_star[3..].iter().all(|&v| v == 0.0));
        assert!(prob.p_star.is_finite());
        // w* really is optimal: any perturbed point has a higher primal
        let reg = RegularizerKind::L1 { epsilon: prob.epsilon }.build();
        for j in [0usize, 5] {
            for step in [-0.01, 0.01] {
                let mut w = prob.w_star.clone();
                w[j] += step;
                let p = objective::primal_reg(
                    &prob.data,
                    &w,
                    prob.lambda,
                    reg.as_ref(),
                    &crate::loss::Squared,
                );
                assert!(p >= prob.p_star, "perturbing w*[{j}] improved P");
            }
        }
    }

    #[test]
    fn smoke_sweep_recovers_support_for_every_k() {
        let dir = std::env::temp_dir().join("cocoa_sparsity_fig");
        let runs =
            sparsity_recovery(Profile::Smoke, 250, dir.to_str().unwrap()).unwrap();
        assert_eq!(runs.len(), 3);
        for run in &runs {
            assert_eq!(run.true_nnz, 3);
            assert!(
                run.support_exact,
                "K={}: support missed (nnz {})",
                run.k, run.final_nnz
            );
            assert_eq!(run.final_nnz, 3);
            assert!(
                run.final_subopt.abs() < 1e-6,
                "K={}: subopt {}",
                run.k,
                run.final_subopt
            );
            assert!(run.bytes_measured > 0);
            // nnz is monotone nonincreasing on this design after round 0
            // (w starts at 0, jumps to the touched set, then thresholds
            // prune it) — at minimum the last row must not exceed d
            assert!(run.trace.rows.iter().all(|r| r.w_nnz <= 8));
        }
    }
}
