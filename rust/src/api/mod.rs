//! The public entry point: a typed [`Trainer`] builder that validates the
//! whole problem description at `build()` time, and the [`Session`] facade
//! it yields — a reusable handle over the spawned leader/worker cluster.
//!
//! ```no_run
//! use cocoa::prelude::*;
//! use cocoa::data::cov_like;
//!
//! # fn main() -> cocoa::Result<()> {
//! let data = cov_like(8_000, 54, 0.1, 42);
//! let mut session = Trainer::on(&data)
//!     .workers(4)
//!     .loss(LossKind::Hinge)
//!     .lambda(1.0 / data.n() as f64)
//!     .network(NetworkModel::ec2_like())
//!     .seed(7)
//!     .build()?;
//! let trace = session.run(&mut Cocoa::new(2_000), GapBelow::new(1e-3).or(MaxRounds::new(10)))?;
//! println!("final gap: {:.2e}", trace.rows.last().unwrap().gap);
//! # Ok(())
//! # }
//! ```

use std::path::Path;

use crate::algorithms::Algorithm;
use crate::config::Backend;
use crate::coordinator::{
    Checkpoint, Cluster, ClusterSpec, CommStats, DataSource, Evaluation, LocalWork, RoundReply,
};
use crate::data::{Dataset, Partition, PartitionStrategy, ShardSet};
use crate::driver::{Driver, IntoDriverSpec};
use crate::error::{Error, Result};
use crate::loss::LossKind;
use crate::netsim::{NetworkModel, StragglerModel};
use crate::regularizers::RegularizerKind;
use crate::solvers::SolverKind;
use crate::telemetry::Trace;
use crate::transport::{Ledger, Transcript, TransportKind};

/// What the trainer trains on: a resident dataset or an on-disk shard
/// set (see [`Trainer::on`] / [`Trainer::on_shards`]).
#[derive(Debug, Clone)]
enum SourceChoice<'a> {
    Memory(&'a Dataset),
    Shards(&'a ShardSet),
}

/// How the trainer partitions the data over workers.
#[derive(Debug, Clone)]
enum PartitionChoice {
    /// K equal blocks under a strategy (the common case).
    Workers { k: usize, strategy: PartitionStrategy, seed: u64 },
    /// A caller-supplied partition (full control).
    Explicit(Partition),
}

/// Typed builder for a distributed training [`Session`].
///
/// Required: the dataset ([`Trainer::on`]), a partition
/// ([`Trainer::workers`] or [`Trainer::partition`]), and
/// [`Trainer::lambda`]. Everything else has the paper's defaults: hinge
/// loss, LocalSDCA, native backend, free network, seed 0. All validation
/// happens in [`Trainer::build`], which returns a typed [`Error`] instead
/// of panicking or stringly failing.
#[derive(Debug, Clone)]
pub struct Trainer<'a> {
    source: SourceChoice<'a>,
    partition: Option<PartitionChoice>,
    loss: LossKind,
    lambda: Option<f64>,
    regularizer: RegularizerKind,
    solver: SolverKind,
    backend: Backend,
    artifacts_dir: String,
    net: NetworkModel,
    stragglers: StragglerModel,
    seed: u64,
    label: String,
    transport: TransportKind,
    threads: usize,
}

impl<'a> Trainer<'a> {
    /// Start describing a training run over `data`.
    pub fn on(data: &'a Dataset) -> Self {
        Self::from_source(SourceChoice::Memory(data))
    }

    /// Start describing a training run over an on-disk [`ShardSet`]
    /// (written by [`write_shards`](crate::data::write_shards),
    /// [`shard_libsvm`](crate::data::shard_libsvm), or `cocoa shard`).
    ///
    /// The out-of-core path: worker `kid` opens only shard `kid`
    /// (mmap-backed when supported) and the full dataset is never
    /// materialized in memory. The partition is fixed by the shard-set
    /// manifest, so [`Trainer::workers`] is optional — calling it with a
    /// `k` other than the set's shard count is a typed [`Error::Config`],
    /// and [`Trainer::partition`] (an explicit partition) is rejected.
    /// Trajectories are bit-identical to [`Trainer::on`] over the same
    /// data with the manifest's partition.
    pub fn on_shards(set: &'a ShardSet) -> Self {
        Self::from_source(SourceChoice::Shards(set))
    }

    fn from_source(source: SourceChoice<'a>) -> Self {
        Trainer {
            source,
            partition: None,
            loss: LossKind::Hinge,
            lambda: None,
            regularizer: RegularizerKind::default(),
            solver: SolverKind::default(),
            backend: Backend::default(),
            artifacts_dir: "artifacts".into(),
            net: NetworkModel::free(),
            stragglers: StragglerModel::none(),
            seed: 0,
            label: "dataset".into(),
            transport: TransportKind::InProc,
            threads: 1,
        }
    }

    /// Partition into `k` contiguous equal blocks (override the strategy
    /// with [`Trainer::partition_strategy`]).
    pub fn workers(mut self, k: usize) -> Self {
        let (strategy, seed) = match self.partition {
            Some(PartitionChoice::Workers { strategy, seed, .. }) => (strategy, seed),
            _ => (PartitionStrategy::Contiguous, 0),
        };
        self.partition = Some(PartitionChoice::Workers { k, strategy, seed });
        self
    }

    /// Choose how rows are assigned to the `k` blocks of
    /// [`Trainer::workers`] (contiguous / round-robin / random).
    pub fn partition_strategy(mut self, strategy: PartitionStrategy) -> Self {
        self.partition = Some(match self.partition {
            Some(PartitionChoice::Workers { k, seed, .. }) => {
                PartitionChoice::Workers { k, strategy, seed }
            }
            // strategy before workers: remember it with a placeholder K
            // that build() rejects if workers() never follows
            _ => PartitionChoice::Workers { k: 0, strategy, seed: 0 },
        });
        self
    }

    /// Seed for the `Random` partition strategy. Like
    /// [`Trainer::partition_strategy`], order-insensitive with respect to
    /// [`Trainer::workers`].
    pub fn partition_seed(mut self, seed: u64) -> Self {
        self.partition = Some(match self.partition {
            Some(PartitionChoice::Workers { k, strategy, .. }) => {
                PartitionChoice::Workers { k, strategy, seed }
            }
            // seed before workers: placeholder K that build() rejects if
            // workers() never follows
            _ => PartitionChoice::Workers { k: 0, strategy: PartitionStrategy::Contiguous, seed },
        });
        self
    }

    /// Use an explicit, caller-built [`Partition`] (validated at build).
    pub fn partition(mut self, partition: Partition) -> Self {
        self.partition = Some(PartitionChoice::Explicit(partition));
        self
    }

    /// The loss of problem (1). Default: hinge (SVM).
    pub fn loss(mut self, loss: LossKind) -> Self {
        self.loss = loss;
        self
    }

    /// Regularization strength (required — the paper tunes it per dataset).
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = Some(lambda);
        self
    }

    /// The regularizer `Omega` of `P(w) = lambda Omega(w) + loss term`.
    /// Default: plain L2 (the paper's problem). Pick
    /// [`RegularizerKind::L1`] for lasso-style sparsity or
    /// [`RegularizerKind::ElasticNet`] for the mixture; parameters are
    /// range-checked (typed `Error::InvalidRegularizer`) at
    /// [`Trainer::build`], and combinations that assume L2 — the PJRT
    /// backend, the gap-certified local solver — are rejected with
    /// `Error::UnsupportedRegularizer`.
    pub fn regularizer(mut self, regularizer: RegularizerKind) -> Self {
        self.regularizer = regularizer;
        self
    }

    /// The local dual method workers run (Procedure A). Default: LocalSDCA.
    pub fn solver(mut self, solver: SolverKind) -> Self {
        self.solver = solver;
        self
    }

    /// Execution backend for the inner loop. Default: native rust.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Where AOT HLO artifacts live (only read for [`Backend::Pjrt`]).
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Network cost model for the simulated-time axis. Default: free
    /// (communication costs nothing unless you model it).
    pub fn network(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Straggler injection for the simulated-time axis.
    pub fn stragglers(mut self, stragglers: StragglerModel) -> Self {
        self.stragglers = stragglers;
        self
    }

    /// Transport backend for leader <-> worker messages. Default: plain
    /// in-process channels (zero overhead, bytes not measured). Pick
    /// [`TransportKind::Counted`] to measure byte-exact communication,
    /// [`TransportKind::SimNet`] for deterministic fault injection, or
    /// [`TransportKind::Record`]/[`TransportKind::Replay`] for transcript
    /// record/replay. Validated (typed) at [`Trainer::build`].
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// Master seed; each worker derives a distinct deterministic stream.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Intra-worker shard count T for the local solves. Default 1 (the
    /// sequential path). Runs are deterministic *per T* — same seed and
    /// same T reproduce bit-identically, but different T values follow
    /// different (equally valid) trajectories; see the contract in
    /// [`LocalSdca`](crate::solvers::LocalSdca). Validated at
    /// [`Trainer::build`].
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Dataset label recorded in traces and CSV paths.
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = label.into();
        self
    }

    /// Validate the description and spawn the worker cluster.
    pub fn build(self) -> Result<Session> {
        let n = match &self.source {
            SourceChoice::Memory(data) => data.n(),
            SourceChoice::Shards(set) => set.n(),
        };

        let lambda = self.lambda.ok_or(Error::MissingLambda)?;
        if !lambda.is_finite() || lambda <= 0.0 {
            return Err(Error::InvalidLambda { value: lambda });
        }

        let partition = match (&self.source, self.partition) {
            // Shard sets carry their partition in the manifest — the rows
            // were physically routed by it at write time, so nothing else
            // can be honored. workers(k) may restate the shard count;
            // anything else is a typed error, not a silent repartition.
            (SourceChoice::Shards(set), choice) => {
                match choice {
                    None | Some(PartitionChoice::Workers { k: 0, .. }) => {}
                    Some(PartitionChoice::Workers { k, .. }) if k == set.k() => {}
                    Some(PartitionChoice::Workers { k, .. }) => {
                        return Err(Error::Config {
                            message: format!(
                                "workers({k}) does not match the shard set (written \
                                 for K = {}); reshard or drop the workers() call",
                                set.k()
                            ),
                        });
                    }
                    Some(PartitionChoice::Explicit(_)) => {
                        return Err(Error::Config {
                            message: "explicit partitions cannot apply to a shard set \
                                      (rows were already routed by the manifest's \
                                      partition at write time)"
                                .into(),
                        });
                    }
                }
                set.partition()
            }
            (SourceChoice::Memory(_), None) => return Err(Error::MissingPartition),
            (SourceChoice::Memory(_), Some(PartitionChoice::Workers { k, strategy, seed })) => {
                if k == 0 || k > n {
                    return Err(Error::TooManyWorkers { k, n });
                }
                Partition::new(strategy, n, k, seed)
            }
            (SourceChoice::Memory(_), Some(PartitionChoice::Explicit(p))) => {
                if p.n() != n {
                    return Err(Error::PartitionMismatch { data_n: n, partition_n: p.n() });
                }
                if p.k() > n {
                    return Err(Error::TooManyWorkers { k: p.k(), n });
                }
                p
            }
        };
        partition
            .validate()
            .map_err(|reason| Error::InvalidPartition { reason })?;

        self.regularizer
            .validate()
            .map_err(|reason| Error::InvalidRegularizer { reason })?;
        if !self.regularizer.is_l2() {
            // features whose math hardcodes (lambda/2)||w||^2
            if self.backend == Backend::Pjrt {
                return Err(Error::UnsupportedRegularizer {
                    regularizer: self.regularizer.to_string(),
                    context: "the PJRT backend (its AOT kernels fix the L2 subproblem)".into(),
                });
            }
            if self.solver == SolverKind::GapCertified {
                return Err(Error::UnsupportedRegularizer {
                    regularizer: self.regularizer.to_string(),
                    context: "the gap_certified solver (the Appendix-B local \
                              certificate is derived for L2)"
                        .into(),
                });
            }
        }

        if self.threads == 0 || self.threads > 256 {
            return Err(Error::Config {
                message: format!(
                    "threads must be in 1..=256 (1 = the sequential path), got {}",
                    self.threads
                ),
            });
        }

        self.transport.validate()?;
        if matches!(self.transport, TransportKind::Net(_)) && self.backend == Backend::Pjrt {
            return Err(Error::InvalidTransport {
                reason: "the net transport requires the native backend (workers are \
                         separate processes; a PJRT engine cannot span them)"
                    .into(),
            });
        }

        if self.backend == Backend::Pjrt {
            if matches!(self.source, SourceChoice::Shards(_)) {
                return Err(Error::Config {
                    message: "the pjrt backend cannot train from shards (it registers \
                              in-memory blocks at spawn); use Backend::Native"
                        .into(),
                });
            }
            if !Path::new(&self.artifacts_dir).join("manifest.tsv").exists() {
                return Err(Error::MissingArtifacts { dir: self.artifacts_dir });
            }
        }

        let cluster = Cluster::spawn(ClusterSpec {
            source: match self.source {
                SourceChoice::Memory(data) => DataSource::Memory(data),
                SourceChoice::Shards(set) => DataSource::Shards(set),
            },
            partition: &partition,
            loss: self.loss,
            lambda,
            regularizer: self.regularizer,
            solver: self.solver,
            backend: self.backend,
            artifacts_dir: &self.artifacts_dir,
            net: self.net,
            stragglers: self.stragglers,
            seed: self.seed,
            transport: self.transport,
            threads: self.threads,
        })?;
        Ok(Session { cluster, label: self.label, p_star: None })
    }
}

/// A live distributed training session: the leader plus K spawned worker
/// threads, reusable across runs ([`Session::reset`] warm-starts the next
/// run on the same threads instead of re-partitioning and re-spawning).
pub struct Session {
    cluster: Cluster,
    label: String,
    p_star: Option<f64>,
}

impl Session {
    /// Drive `algorithm` until the stopping criteria end the run, and
    /// return the full trace (one row per evaluation on the spec's
    /// cadence). Accepts a composable
    /// [`StoppingRule`](crate::driver::StoppingRule), a
    /// [`DriverSpec`](crate::driver::DriverSpec), or a legacy
    /// [`Budget`](crate::algorithms::Budget) — this is a thin
    /// compatibility wrapper that drains a [`Session::drive`] driver, so
    /// batch runs and manual step loops produce bit-identical traces.
    pub fn run(
        &mut self,
        algorithm: &mut dyn Algorithm,
        stopping: impl IntoDriverSpec,
    ) -> Result<Trace> {
        let mut driver = self.drive(algorithm, stopping)?;
        driver.drain()
    }

    /// Open the round loop: a resumable [`Driver`] state machine whose
    /// [`step()`](Driver::step) advances the run one event at a time
    /// (round work, evaluations, checkpoints, the terminal stop), with
    /// pluggable [`Observer`](crate::driver::Observer)s for telemetry and
    /// persistence. The session and algorithm stay mutably borrowed until
    /// the driver is dropped; dropping it mid-run leaves the session at a
    /// valid round boundary (checkpointable, resumable).
    pub fn drive<'d>(
        &'d mut self,
        algorithm: &'d mut dyn Algorithm,
        stopping: impl IntoDriverSpec,
    ) -> Result<Driver<'d>> {
        Driver::new(&mut self.cluster, algorithm, stopping.into_spec()?, self.p_star, &self.label)
    }

    /// Warm-start: zero the optimization state (w, dual blocks, rng
    /// streams, stats) while keeping the worker threads, their data
    /// blocks, and any PJRT bindings alive. After `reset()` a run is
    /// bit-identical to one on a freshly built session with the same
    /// seed — minus the partition/spawn/registration cost.
    pub fn reset(&mut self) -> Result<()> {
        self.cluster.reset()?;
        Ok(())
    }

    /// Reference optimum `P*` for the suboptimality axis of subsequent
    /// runs (`None` clears it; rows record NaN without one).
    pub fn set_reference_optimum(&mut self, p_star: Option<f64>) {
        self.p_star = p_star;
    }

    /// Continuous training: grow the live problem with `batch` (same
    /// feature width `d`; any row count ≥ 1) without tearing the cluster
    /// down. Appended rows are dealt round-robin over the K workers by
    /// their position in the lifetime append stream, retained dual
    /// variables are kept (new rows start at the feasible `alpha = 0`),
    /// and the leader rescales its accumulator for the new `n` so the
    /// invariant `v = (1/(lambda_eff n)) A alpha` holds over the grown
    /// matrix. Must be called at a round boundary (mid-round appends are
    /// a worker fault, surfaced as a typed error on the next dispatch).
    /// The session's [`Session::fingerprint`] advances by chaining in the
    /// batch's fingerprint; old [`Checkpoint`]s no longer restore (shape
    /// mismatch), so checkpoint again after appending. See
    /// `docs/SERVING.md` for the duality-gap growth bound.
    pub fn append_rows(&mut self, batch: &Dataset) -> Result<()> {
        Ok(self.cluster.append_rows(batch)?)
    }

    /// Swap every row's label in place (row order = global dataset
    /// order), leaving features, norms, curvatures, and the partition
    /// untouched. This is the one-vs-rest lever: curvatures are
    /// label-independent, so one session can train K binary problems by
    /// relabeling between runs. Retained duals are generally infeasible
    /// for the new labels — call [`Session::reset`] before the next run.
    pub fn set_labels(&mut self, labels: &[f64]) -> Result<()> {
        Ok(self.cluster.set_labels(labels)?)
    }

    /// Fingerprint of the dataset the session currently trains on: the
    /// source's fingerprint at build time, chained (order-sensitive)
    /// through every appended batch. Scoring clients bind to this to
    /// reject snapshots from a different dataset; relabeling via
    /// [`Session::set_labels`] deliberately does *not* move it (OVR label
    /// views are transient).
    pub fn fingerprint(&self) -> &str {
        self.cluster.fingerprint()
    }

    /// Straggler injection for the simulated-time axis.
    pub fn set_stragglers(&mut self, stragglers: StragglerModel) {
        self.cluster.stragglers = stragglers;
    }

    /// Distributed evaluation of P(w), D(alpha), duality gap.
    pub fn evaluate(&mut self) -> Result<Evaluation> {
        Ok(self.cluster.evaluate()?)
    }

    /// Capture the full optimization state (round boundary only).
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        Ok(self.cluster.checkpoint()?)
    }

    /// Restore a previously captured state (shapes validated).
    pub fn restore(&mut self, cp: &Checkpoint) -> Result<()> {
        Ok(self.cluster.restore(cp)?)
    }

    /// Recover a net-transport session after a worker failure: re-accept
    /// replacement connections for dead slots
    /// ([`Transport::heal`](crate::transport::Transport::heal)), restore
    /// every worker from `cp`, and drain pre-failure traffic. Returns
    /// how many connections were healed. On non-net transports this
    /// fails with the transport's typed no-reconnection error — see
    /// [`run_with_recovery`](crate::driver::recovery::run_with_recovery)
    /// for the full resume loop built on top.
    pub fn recover(&mut self, cp: &Checkpoint) -> Result<usize> {
        Ok(self.cluster.recover(cp)?)
    }

    /// The shared primal model.
    pub fn w(&self) -> &[f64] {
        &self.cluster.w
    }

    /// Communication/time accounting so far.
    pub fn stats(&self) -> &CommStats {
        &self.cluster.stats
    }

    pub fn k(&self) -> usize {
        self.cluster.k
    }

    pub fn n(&self) -> usize {
        self.cluster.n
    }

    pub fn d(&self) -> usize {
        self.cluster.d
    }

    pub fn lambda(&self) -> f64 {
        self.cluster.lambda()
    }

    pub fn loss(&self) -> LossKind {
        self.cluster.loss()
    }

    /// The regularizer the session was built with.
    pub fn regularizer(&self) -> RegularizerKind {
        self.cluster.regularizer()
    }

    /// Nonzero count of the current primal iterate `w` (prox-induced
    /// exact zeros — the sparsity-recovery axis on L1/elastic-net runs).
    pub fn w_nnz(&self) -> u64 {
        self.cluster.w_nnz()
    }

    /// Largest block size (`~n` in Proposition 1).
    pub fn n_max(&self) -> usize {
        self.cluster.n_max()
    }

    /// Name of the active transport backend
    /// (`inproc`/`counted`/`simnet`/`record`/`replay`).
    pub fn transport_name(&self) -> &'static str {
        self.cluster.transport_name()
    }

    /// Byte-exact per-kind communication ledger. `None` on the unmeasured
    /// in-process default.
    pub fn ledger(&self) -> Option<&Ledger> {
        self.cluster.ledger()
    }

    /// Take the transcript recorded so far. `Some` only on the
    /// [`TransportKind::Record`] backend; feed it to
    /// [`TransportKind::Replay`] on a twin session to re-serve the run
    /// deterministically.
    pub fn take_transcript(&mut self) -> Option<Transcript> {
        self.cluster.take_transcript()
    }

    /// Raw socket accounting (net transport only): every byte written to
    /// and read from worker connections, split into payload, framing,
    /// and handshake so it reconciles exactly with [`Session::ledger`].
    pub fn socket_stats(&self) -> Option<crate::transport::SocketStats> {
        self.cluster.socket_stats()
    }

    /// Enable/disable round-phase span recording (off by default). A pure
    /// observer toggle — trajectories are bit-identical either way; turn
    /// it on when attaching a [`SpanSink`](crate::obs::SpanSink) or
    /// serving [`MetricsHub`](crate::obs::MetricsHub) so per-phase
    /// timings flow.
    pub fn set_tracing(&mut self, on: bool) {
        self.cluster.set_tracing(on);
    }

    /// Is round-phase span recording enabled?
    pub fn tracing(&self) -> bool {
        self.cluster.tracing()
    }

    /// Max peak RSS any worker has reported so far (0 before the first
    /// round, or where procfs is unavailable). Combine with the leader's
    /// own [`peak_rss_bytes`](crate::telemetry::peak_rss_bytes) for the
    /// run-wide max.
    pub fn max_worker_rss(&self) -> u64 {
        self.cluster.max_worker_rss()
    }

    /// Low-level escape hatch: dispatch one round of hand-chosen
    /// [`LocalWork`] (instrumentation, custom drivers, tests). Prefer
    /// [`Session::run`] with an [`Algorithm`].
    pub fn dispatch(&mut self, work_for: impl Fn(usize) -> LocalWork) -> Result<Vec<RoundReply>> {
        Ok(self.cluster.dispatch(work_for)?)
    }

    /// Low-level escape hatch: fold replies in with an explicit scale.
    pub fn commit(&mut self, replies: &[RoundReply], scale: f64) -> Result<()> {
        Ok(self.cluster.commit(replies, scale)?)
    }

    /// Replace `w` outright (SGD-style leader updates).
    pub fn set_w(&mut self, w: Vec<f64>) {
        self.cluster.set_w(w);
    }

    /// Join all worker threads. Dropping the session does the same.
    pub fn shutdown(self) {
        self.cluster.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Budget, Cocoa};
    use crate::data::cov_like;

    #[test]
    fn builder_defaults_and_overrides() {
        let data = cov_like(60, 5, 0.1, 1);
        let mut sess = Trainer::on(&data)
            .workers(3)
            .lambda(0.1)
            .label("t")
            .build()
            .unwrap();
        assert_eq!(sess.k(), 3);
        assert_eq!(sess.n(), 60);
        assert_eq!(sess.d(), 5);
        assert_eq!(sess.lambda(), 0.1);
        let tr = sess.run(&mut Cocoa::new(20), Budget::rounds(3)).unwrap();
        assert_eq!(tr.dataset, "t");
        assert_eq!(tr.rows.len(), 4); // round 0 + 3
        sess.shutdown();
    }

    #[test]
    fn partition_strategy_order_is_flexible() {
        let data = cov_like(30, 4, 0.1, 2);
        // strategy first, workers after — must still build
        let sess = Trainer::on(&data)
            .partition_strategy(PartitionStrategy::RoundRobin)
            .workers(2)
            .lambda(0.1)
            .build()
            .unwrap();
        assert_eq!(sess.k(), 2);
        sess.shutdown();
        // strategy alone never gets a K: typed error, no panic
        let err = Trainer::on(&data)
            .partition_strategy(PartitionStrategy::RoundRobin)
            .lambda(0.1)
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::TooManyWorkers { k: 0, .. }), "{err}");
    }

    #[test]
    fn counted_transport_feeds_measured_bytes() {
        let data = cov_like(60, 5, 0.1, 4);
        let mut sess = Trainer::on(&data)
            .workers(2)
            .lambda(0.1)
            .transport(TransportKind::Counted)
            .build()
            .unwrap();
        assert_eq!(sess.transport_name(), "counted");
        let tr = sess.run(&mut Cocoa::new(10), Budget::rounds(3)).unwrap();
        let last = tr.rows.last().unwrap();
        assert!(last.bytes_measured > 0);
        assert!(last.bytes_modeled > 0);
        // measured bytes are per-row monotone
        for pair in tr.rows.windows(2) {
            assert!(pair[1].bytes_measured >= pair[0].bytes_measured);
        }
        assert!(sess.ledger().is_some());
        assert!(sess.take_transcript().is_none()); // counted does not tape
        sess.shutdown();
    }

    #[test]
    fn invalid_transport_is_typed_at_build() {
        let data = cov_like(30, 4, 0.1, 5);
        let mut cfg = crate::transport::SimNetConfig::new(0);
        cfg.straggler_slowdown = 0.25;
        let err = Trainer::on(&data)
            .workers(2)
            .lambda(0.1)
            .transport(TransportKind::SimNet(cfg))
            .build()
            .unwrap_err();
        assert!(matches!(err, Error::InvalidTransport { .. }), "{err}");
    }

    #[test]
    fn regularizer_flows_through_the_builder() {
        let data = cov_like(60, 6, 0.1, 6);
        let mut sess = Trainer::on(&data)
            .workers(2)
            .loss(LossKind::Squared)
            .lambda(0.2)
            .regularizer(RegularizerKind::L1 { epsilon: 0.5 })
            .build()
            .unwrap();
        assert_eq!(sess.regularizer(), RegularizerKind::L1 { epsilon: 0.5 });
        let tr = sess.run(&mut Cocoa::new(30), Budget::rounds(6)).unwrap();
        for row in &tr.rows {
            assert!(row.gap >= -1e-9, "round {}: gap {}", row.round, row.gap);
        }
        assert!(sess.w_nnz() <= 6);
        assert_eq!(
            sess.w_nnz(),
            sess.w().iter().filter(|v| **v != 0.0).count() as u64
        );
        sess.shutdown();
    }

    #[test]
    fn reference_optimum_feeds_subopt_axis() {
        let data = cov_like(50, 4, 0.1, 3);
        let mut sess = Trainer::on(&data).workers(2).lambda(0.1).build().unwrap();
        let tr = sess.run(&mut Cocoa::new(10), Budget::rounds(2)).unwrap();
        assert!(tr.rows.last().unwrap().primal_subopt.is_nan());
        sess.set_reference_optimum(Some(0.0));
        sess.reset().unwrap();
        let tr = sess.run(&mut Cocoa::new(10), Budget::rounds(2)).unwrap();
        assert!(tr.rows.last().unwrap().primal_subopt.is_finite());
        sess.shutdown();
    }
}
